"""Kernel-vs-reference correctness: the CORE build-time signal.

Hypothesis-style sweeps (seeded rng over shapes/dtypes/parameters) assert
the Pallas kernels match the pure-jnp oracles to float tolerance before
any artifact is emitted.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels.gemm_kernel import gemm_pallas
from compile.kernels.ref import gemm_ref, stencil_ref, stencil_sweeps_ref
from compile.kernels.stencil_kernel import stencil_pallas, stencil_sweeps_pallas


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Stencil kernel
# ---------------------------------------------------------------------------

STENCIL_CASES = [
    # (H, W, block_rows, alpha)
    (16, 16, 4, 0.25),
    (16, 16, 16, 0.25),
    (32, 8, 8, 0.1),
    (64, 64, 16, 0.25),
    (8, 128, 2, 0.5),
    (128, 64, 32, 0.01),
]


@pytest.mark.parametrize("h,w,br,alpha", STENCIL_CASES)
def test_stencil_matches_ref(h, w, br, alpha):
    key = jax.random.PRNGKey(h * 1000 + w * 10 + br)
    padded = rand(key, (h + 2, w + 2))
    got = stencil_pallas(padded, alpha=alpha, block_rows=br)
    want = stencil_ref(padded, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_stencil_sweep_shapes_sweep():
    # Seeded random sweep over shapes — hypothesis-style.
    rng = np.random.RandomState(42)
    for _ in range(20):
        br = int(rng.choice([1, 2, 4, 8]))
        h = br * int(rng.randint(1, 9))
        w = int(rng.randint(3, 65))
        alpha = float(rng.uniform(0.0, 1.0))
        key = jax.random.PRNGKey(rng.randint(0, 2**31))
        padded = rand(key, (h + 2, w + 2))
        got = stencil_pallas(padded, alpha=alpha, block_rows=br)
        want = stencil_ref(padded, alpha)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_stencil_rejects_nondivisible_blocks():
    padded = jnp.zeros((18, 18))
    with pytest.raises(ValueError):
        stencil_pallas(padded, block_rows=5)


def test_stencil_constant_field_is_fixed_point():
    # A uniform field has zero Laplacian: the sweep must not change it.
    padded = jnp.full((34, 34), 3.25)
    out = stencil_pallas(padded, alpha=0.25, block_rows=8)
    np.testing.assert_allclose(out, jnp.full((32, 32), 3.25), rtol=1e-7)


def test_stencil_multi_sweep_matches_ref():
    key = jax.random.PRNGKey(7)
    padded = rand(key, (18, 18))
    for sweeps in [1, 2, 5]:
        got = stencil_sweeps_pallas(padded, alpha=0.2, sweeps=sweeps, block_rows=4)
        want = stencil_sweeps_ref(padded, alpha=0.2, sweeps=sweeps)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_stencil_f64():
    key = jax.random.PRNGKey(3)
    padded = rand(key, (10, 10), dtype=jnp.float32).astype(jnp.float64)
    got = stencil_pallas(padded, alpha=0.25, block_rows=4)
    want = stencil_ref(padded, 0.25)
    np.testing.assert_allclose(got, want, rtol=1e-12)


# ---------------------------------------------------------------------------
# GEMM kernel
# ---------------------------------------------------------------------------

GEMM_CASES = [
    # (M, K, N, bm, bn)
    (128, 128, 128, 128, 128),
    (128, 64, 128, 64, 64),
    (256, 32, 128, 128, 128),
    (64, 256, 64, 32, 32),
    (8, 8, 8, 8, 8),
]


@pytest.mark.parametrize("m,k,n,bm,bn", GEMM_CASES)
def test_gemm_matches_ref(m, k, n, bm, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m + k + n))
    a = rand(k1, (m, k))
    b = rand(k2, (k, n))
    got = gemm_pallas(a, b, bm=bm, bn=bn)
    want = gemm_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_random_shape_sweep():
    rng = np.random.RandomState(1234)
    for _ in range(15):
        bm = int(rng.choice([8, 16, 32]))
        bn = int(rng.choice([8, 16, 32]))
        m = bm * int(rng.randint(1, 5))
        n = bn * int(rng.randint(1, 5))
        k = int(rng.randint(1, 97))
        k1, k2 = jax.random.split(jax.random.PRNGKey(rng.randint(0, 2**31)))
        a = rand(k1, (m, k))
        b = rand(k2, (k, n))
        got = gemm_pallas(a, b, bm=bm, bn=bn)
        np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-4, atol=1e-4)


def test_gemm_rejects_bad_shapes():
    with pytest.raises(ValueError):
        gemm_pallas(jnp.zeros((8, 4)), jnp.zeros((5, 8)))
    with pytest.raises(ValueError):
        gemm_pallas(jnp.zeros((10, 4)), jnp.zeros((4, 8)), bm=4, bn=4)


def test_gemm_identity():
    a = jnp.eye(32, dtype=jnp.float32)
    b = rand(jax.random.PRNGKey(0), (32, 32))
    np.testing.assert_allclose(gemm_pallas(a, b, bm=32, bn=32), b, rtol=1e-6)
