"""L2 model-level tests: step functions compose the kernels correctly and
lower to HLO text that parses."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.ref import gemm_ref, stencil_ref


def test_stencil_step_outputs():
    key = jax.random.PRNGKey(0)
    padded = jax.random.normal(key, (34, 34), dtype=jnp.float32)
    out, residual = model.stencil_step(padded, alpha=0.25, block_rows=8)
    want = stencil_ref(padded, 0.25)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)
    want_res = jnp.sum((want - padded[1:-1, 1:-1]) ** 2)
    np.testing.assert_allclose(residual, want_res, rtol=1e-4)


def test_stencil_step_residual_zero_on_fixed_point():
    padded = jnp.full((18, 18), 2.0)
    _, residual = model.stencil_step(padded, block_rows=4)
    assert float(residual) == pytest.approx(0.0, abs=1e-10)


def test_summa_tile_accumulates():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    c = jax.random.normal(k1, (64, 64), dtype=jnp.float32)
    a = jax.random.normal(k2, (64, 32), dtype=jnp.float32)
    b = jax.random.normal(k3, (32, 64), dtype=jnp.float32)
    got = model.summa_tile(c, a, b)
    np.testing.assert_allclose(got, c + gemm_ref(a, b), rtol=1e-4, atol=1e-4)


def test_summa_composes_to_full_matmul():
    # Accumulating over K-panels reproduces the full product — the SUMMA
    # invariant the Rust coordinator relies on.
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = jax.random.normal(k1, (64, 128), dtype=jnp.float32)
    b = jax.random.normal(k2, (128, 64), dtype=jnp.float32)
    c = jnp.zeros((64, 64), dtype=jnp.float32)
    for p in range(4):
        c = model.summa_tile(c, a[:, p * 32:(p + 1) * 32], b[p * 32:(p + 1) * 32, :])
    np.testing.assert_allclose(c, gemm_ref(a, b), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("name,fn,specs", aot.catalog(), ids=lambda v: v if isinstance(v, str) else "")
def test_catalog_lowers_to_hlo_text(name, fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), f"{name}: not HLO text"
    assert "ENTRY" in text
    # return_tuple=True → root is a tuple
    assert "tuple" in text or ")" in text


def test_emit_writes_artifact_and_meta(tmp_path):
    name, fn, specs = aot.catalog()[1]  # small stencil
    aot.emit(fn, specs, name, str(tmp_path))
    hlo = (tmp_path / f"{name}.hlo.txt").read_text()
    meta = (tmp_path / f"{name}.meta").read_text().strip().splitlines()
    assert hlo.startswith("HloModule")
    assert meta[0].startswith("input float32 ")
    assert any(l.startswith("output float32") for l in meta)
    # stencil: 1 input, 2 outputs (field + residual)
    assert sum(l.startswith("input") for l in meta) == 1
    assert sum(l.startswith("output") for l in meta) == 2
