"""L1 Pallas kernel: blocked GEMM tile for the distributed SUMMA matmul.

The PGAS matmul example (``examples/matmul.rs``) distributes ``C = A @ B``
block-cyclically over units; every SUMMA step broadcasts an ``A``-panel and
a ``B``-panel over the team and each unit multiplies its local panels. This
kernel is that local multiply.

Hardware adaptation: tiles are MXU-shaped — ``(bm, bn) = (128, 128)``
output blocks with the full ``K`` panel resident, i.e. the classic
``A(bm,K) × B(K,bn)`` inner-product schedule. ``preferred_element_type``
pins the accumulator to f32. ``interpret=True`` for CPU-PJRT executability.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def gemm_pallas(a, b, *, bm: int = 128, bn: int = 128):
    """Blocked ``a @ b``.

    Args:
      a: ``(M, K)`` f32.
      b: ``(K, N)`` f32.
      bm, bn: output tile shape; must divide ``M`` / ``N``. Defaults are
        MXU-systolic-array-shaped.

    Returns:
      ``(M, N)`` f32 product.
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims differ: {k} vs {k2}")
    bm = min(bm, m)
    bn = min(bn, n)
    if m % bm or n % bn:
        raise ValueError(f"tile ({bm},{bn}) must divide ({m},{n})")
    return pl.pallas_call(
        _gemm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b)
