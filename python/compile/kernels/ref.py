"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest asserts kernel ≈ ref before any artifact ships)."""

import jax.numpy as jnp


def stencil_ref(padded, alpha: float = 0.25):
    """5-point stencil sweep over a halo-padded block: reference."""
    center = padded[1:-1, 1:-1]
    up = padded[:-2, 1:-1]
    down = padded[2:, 1:-1]
    left = padded[1:-1, :-2]
    right = padded[1:-1, 2:]
    return center + alpha * (up + down + left + right - 4.0 * center)


def stencil_sweeps_ref(padded, alpha: float = 0.25, sweeps: int = 1):
    """Multiple fused sweeps (halo not re-exchanged): reference."""
    out = padded
    for _ in range(sweeps):
        out = out.at[1:-1, 1:-1].set(stencil_ref(out, alpha))
    return out[1:-1, 1:-1]


def gemm_ref(a, b):
    """Matrix product accumulated in f32: reference."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
