"""L1 Pallas kernel: 5-point stencil sweep (heat diffusion step).

This is the per-unit local compute of the distributed stencil application
(the kind of shared-memory-style scientific code the paper's PGAS model
targets). The unit's local block arrives *with its halo* (shape
``(H+2, W+2)``) — the halo rows/columns were fetched from the neighbouring
units' partitions with one-sided ``dart_get``/``dart_put`` — and one sweep
produces the updated ``(H, W)`` interior.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the kernel is blocked
over rows; each grid step loads a ``(block_rows + 2, W + 2)`` window and
writes a ``(block_rows, W)`` output tile, expressing the HBM↔VMEM schedule
via the grid + BlockSpec. On this CPU image Pallas MUST run with
``interpret=True`` (real TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil_kernel(in_ref, out_ref, *, alpha: float, block_rows: int):
    """One row-block of the 5-point stencil.

    ``in_ref`` is the full padded array (resident ref); the kernel
    dynamically slices its ``(block_rows+2, W+2)`` window — overlapping
    windows cannot be expressed as non-overlapping BlockSpec tiles, so the
    halo rows are re-read per block, which is exactly the double-buffered
    overlap a TPU schedule would stream.
    """
    i = pl.program_id(0)
    x = in_ref[...]
    wp = x.shape[1]
    window = jax.lax.dynamic_slice(x, (i * block_rows, 0), (block_rows + 2, wp))
    center = window[1:-1, 1:-1]
    up = window[:-2, 1:-1]
    down = window[2:, 1:-1]
    left = window[1:-1, :-2]
    right = window[1:-1, 2:]
    out_ref[...] = center + alpha * (up + down + left + right - 4.0 * center)


def stencil_pallas(padded, *, alpha: float = 0.25, block_rows: int = 16):
    """One stencil sweep over a halo-padded local block.

    Args:
      padded: ``(H+2, W+2)`` float array — interior plus one halo cell on
        every side.
      alpha: diffusion coefficient (baked into the compiled artifact).
      block_rows: rows per grid step; must divide ``H``.

    Returns:
      ``(H, W)`` updated interior.
    """
    hp, wp = padded.shape
    h, w = hp - 2, wp - 2
    if h % block_rows != 0:
        raise ValueError(f"block_rows={block_rows} must divide H={h}")
    nblocks = h // block_rows
    kernel = functools.partial(_stencil_kernel, alpha=alpha, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((hp, wp), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), padded.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(padded)


def stencil_sweeps_pallas(padded, *, alpha: float = 0.25, sweeps: int = 1,
                          block_rows: int = 16):
    """Multiple in-block sweeps fused into one artifact.

    Between *fused* sweeps the halo is NOT re-exchanged, so the outer rows
    progressively stale — valid for the inner iterations of over-decomposed
    domains, and the standard trade of halo traffic against redundant
    compute. The interior is recomputed from the previous sweep's output
    re-padded with the original halo.
    """
    out = padded
    for _ in range(sweeps):
        interior = stencil_pallas(out, alpha=alpha, block_rows=block_rows)
        out = out.at[1:-1, 1:-1].set(interior)
    return out[1:-1, 1:-1]
