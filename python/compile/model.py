"""L2: the JAX compute graphs of the PGAS example applications.

Each function here is the *whole* per-unit compute step that gets lowered
once by ``aot.py`` into an HLO-text artifact; the Rust coordinator executes
the artifact on its PJRT CPU client from the request path (Python never
runs at runtime).

The functions call the L1 Pallas kernels so that kernel and surrounding
graph lower into one fused HLO module.
"""

import jax.numpy as jnp

from .kernels.gemm_kernel import gemm_pallas
from .kernels.stencil_kernel import stencil_pallas


def stencil_step(padded, *, alpha: float = 0.25, block_rows: int = 16):
    """One halo-exchanged stencil step: sweep + residual.

    Args:
      padded: ``(H+2, W+2)`` local block with halo.

    Returns:
      ``(out, residual)`` — the updated ``(H, W)`` interior and the local
      sum of squared updates (reduced over the team by the coordinator to
      drive convergence logging).
    """
    out = stencil_pallas(padded, alpha=alpha, block_rows=block_rows)
    residual = jnp.sum((out - padded[1:-1, 1:-1]) ** 2)
    return out, residual


def summa_tile(c_acc, a_panel, b_panel):
    """One SUMMA accumulation step: ``C += A_panel @ B_panel``.

    Args:
      c_acc: ``(mb, nb)`` running local accumulator.
      a_panel: ``(mb, kb)`` broadcast panel of A.
      b_panel: ``(kb, nb)`` broadcast panel of B.
    """
    return c_acc + gemm_pallas(a_panel, b_panel)
