"""AOT bridge: lower the L2 JAX step functions to HLO **text** artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads
the text with ``HloModuleProto::from_text_file`` and compiles it on its
PJRT CPU client. Text — NOT ``lowered.compile()`` / serialized protos —
because jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids that
the image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly.

Every artifact ``<name>.hlo.txt`` ships with a ``<name>.meta`` sidecar
describing its I/O signature in a line format the Rust side parses:

    input f32 66 66
    output f32 64 64
    output f32

Usage: ``python -m compile.aot [--out-dir ../artifacts]``
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _meta_line(kind, aval) -> str:
    dims = " ".join(str(d) for d in aval.shape)
    return f"{kind} {aval.dtype} {dims}".rstrip()


def emit(fn, args, name: str, out_dir: str) -> None:
    """Lower ``fn(*args)``, write ``<name>.hlo.txt`` + ``<name>.meta``."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    # I/O signature sidecar.
    outs = jax.eval_shape(fn, *args)
    flat_outs = jax.tree_util.tree_leaves(outs)
    lines = [_meta_line("input", a) for a in args]
    lines += [_meta_line("output", o) for o in flat_outs]
    with open(os.path.join(out_dir, f"{name}.meta"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {hlo_path} ({len(text)} chars, {len(args)} in / {len(flat_outs)} out)")


# Artifact catalog: every (name, fn, example-args) the system ships.
def catalog():
    arts = []
    # Stencil steps for the block sizes the examples/benches use.
    for h, w, br in [(64, 64, 16), (32, 32, 8), (128, 64, 16)]:
        arts.append(
            (
                f"stencil_f32_{h}x{w}",
                functools.partial(model.stencil_step, alpha=0.25, block_rows=br),
                (_spec((h + 2, w + 2)),),
            )
        )
    # SUMMA tiles.
    for mb, kb, nb in [(128, 128, 128), (64, 64, 64)]:
        arts.append(
            (
                f"summa_f32_{mb}x{kb}x{nb}",
                model.summa_tile,
                (_spec((mb, nb)), _spec((mb, kb)), _spec((kb, nb))),
            )
        )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--only", default=None, help="emit only artifacts whose name contains this")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    for name, fn, specs in catalog():
        if args.only and args.only not in name:
            continue
        emit(fn, specs, name, out_dir)
    # Build stamp so `make` can skip rebuilds.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
