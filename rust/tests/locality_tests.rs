//! Tests of the locality-aware runtime: `unit_locality`,
//! `team_split_locality` (caching, teardown, edge cases), the
//! hierarchical two-level collectives, and their flat fallbacks.

use dart::dart::{LocalityScope, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use dart::simnet::{CoreCoord, PinPolicy, Topology};
use dart::testing::{world, WorldBuilder};
use std::time::Instant;

/// 12 units round-robin over a 3-node Hermit cluster: every power-of-two
/// rank distance crosses nodes (2^k mod 3 != 0), so this is the placement
/// where locality-blind trees hurt most — 4 units per node.
fn three_node() -> WorldBuilder {
    world(12).nodes(3).placement(PinPolicy::ScatterNode).pools(1 << 16, 1 << 16)
}

// ---------------------------------------------------------------------------
// unit_locality
// ---------------------------------------------------------------------------

#[test]
fn unit_locality_matches_placement() {
    three_node().launch(|env| {
        for u in 0..12 {
            let c = env.unit_locality(u).unwrap();
            assert_eq!(c.node, u as usize % 3, "unit {u} node");
        }
        assert!(env.same_node(0, 3).unwrap());
        assert!(!env.same_node(0, 1).unwrap());
        assert_eq!(env.team_node_span(DART_TEAM_ALL).unwrap(), 3);
        assert!(env.unit_locality(-1).is_err());
        assert!(env.unit_locality(12).is_err());
    })
}

// ---------------------------------------------------------------------------
// team_split_locality
// ---------------------------------------------------------------------------

#[test]
fn split_groups_members_by_node() {
    three_node().launch(|env| {
        let split = env.team_split_locality(DART_TEAM_ALL, LocalityScope::Node).unwrap();
        assert_eq!(split.domains, 3);
        // My node-local team holds exactly the units sharing my node.
        let my_node = env.unit_locality(env.myid()).unwrap().node;
        let local_members = env.team_get_group(split.local).unwrap();
        let expect: Vec<i32> = (0..12).filter(|u| *u as usize % 3 == my_node).collect();
        assert_eq!(local_members.members(), expect.as_slice());
        // Leaders = each node's lowest unit; only they see the team id.
        let am_leader = env.myid() < 3;
        assert_eq!(split.is_leader, am_leader);
        assert_eq!(split.leaders.is_some(), am_leader);
        if let Some(lt) = split.leaders {
            let leaders = env.team_get_group(lt).unwrap();
            assert_eq!(leaders.members(), &[0, 1, 2]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn split_single_node_topology_leader_team_is_singleton() {
    // Flat (single-node) topology: the local team mirrors the parent and
    // the leader team is a singleton holding unit 0.
    world(4).pools(1 << 16, 1 << 16).launch(|env| {
        let split = env.team_split_locality(DART_TEAM_ALL, LocalityScope::Node).unwrap();
        assert_eq!(split.domains, 1);
        assert_eq!(env.team_size(split.local).unwrap(), 4);
        assert_eq!(split.is_leader, env.myid() == 0);
        if let Some(lt) = split.leaders {
            assert_eq!(env.myid(), 0);
            assert_eq!(env.team_size(lt).unwrap(), 1);
            assert_eq!(env.team_get_group(lt).unwrap().members(), &[0]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn split_numa_scope_distinguishes_domains() {
    // 4 units round-robin over the NUMA domains of one Hermit node.
    let cfg = world(4).nodes(1).placement(PinPolicy::ScatterNuma).pools(1 << 16, 1 << 16);
    cfg.launch(|env| {
        // Node scope: one node -> degenerate split.
        let by_node = env.team_split_locality(DART_TEAM_ALL, LocalityScope::Node).unwrap();
        assert_eq!(by_node.domains, 1);
        // Numa scope: four singleton domains, everyone is a leader.
        let by_numa = env.team_split_locality(DART_TEAM_ALL, LocalityScope::Numa).unwrap();
        assert_eq!(by_numa.domains, 4);
        assert_eq!(env.team_size(by_numa.local).unwrap(), 1);
        assert!(by_numa.is_leader);
        let lt = by_numa.leaders.unwrap();
        assert_eq!(env.team_size(lt).unwrap(), 4);
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn split_oversubscribed_placement_wraps() {
    // 5 units on a 2-node, 1-core-per-node machine: Block placement wraps
    // modulo the 2 cores, so units 0,2,4 share node 0 and 1,3 share node 1.
    let topo = Topology { nodes: 2, numa_per_node: 1, cores_per_numa: 1 };
    world(5).pools(1 << 16, 1 << 16).topology(topo).launch(|env| {
        let split = env.team_split_locality(DART_TEAM_ALL, LocalityScope::Node).unwrap();
        assert_eq!(split.domains, 2);
        let local = env.team_get_group(split.local).unwrap();
        if env.myid() % 2 == 0 {
            assert_eq!(local.members(), &[0, 2, 4]);
        } else {
            assert_eq!(local.members(), &[1, 3]);
        }
        assert_eq!(split.is_leader, env.myid() < 2);
        if let Some(lt) = split.leaders {
            assert_eq!(env.team_get_group(lt).unwrap().members(), &[0, 1]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn split_is_cached_and_destroyed_with_parent() {
    three_node().launch(|env| {
        let baseline = env.live_teams().len();
        let grp = env.group_all();
        let t = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        let s1 = env.team_split_locality(t, LocalityScope::Node).unwrap();
        let after_split = env.live_teams().len();
        assert!(after_split > baseline + 1, "split must create sub-teams");
        // Second call: served from the cache — same ids, no new teams.
        let s2 = env.team_split_locality(t, LocalityScope::Node).unwrap();
        assert_eq!(s1, s2);
        assert_eq!(env.live_teams().len(), after_split);
        assert_eq!(env.locality_splits_cached(), 1);
        // Destroying the parent cascades: sub-teams and cache entry go too.
        env.team_destroy(t).unwrap();
        assert_eq!(env.live_teams().len(), baseline);
        assert_eq!(env.locality_splits_cached(), 0);
        // A fresh team gets a fresh split (ids are never reused).
        let t2 = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        let s3 = env.team_split_locality(t2, LocalityScope::Node).unwrap();
        assert_ne!(s3.local, s1.local, "stale split id served after destroy");
        env.team_destroy(t2).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn split_sub_teams_cannot_be_destroyed_directly() {
    // Destroying a split-owned sub-team directly would invalidate the
    // split cache only on that sub-team's members (team_destroy is
    // collective over them, not the parent), so it is rejected; the
    // parent destroy is the supported teardown and still works after the
    // rejected attempt.
    three_node().launch(|env| {
        let grp = env.group_all();
        let t = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        let split = env.team_split_locality(t, LocalityScope::Node).unwrap();
        assert!(env.team_destroy(split.local).is_err(), "direct local-team destroy must fail");
        if let Some(lt) = split.leaders {
            assert!(env.team_destroy(lt).is_err(), "direct leader-team destroy must fail");
        }
        env.team_destroy(t).unwrap();
        assert_eq!(env.locality_splits_cached(), 0);
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

// ---------------------------------------------------------------------------
// Hierarchical collectives: correctness + decomposition metrics
// ---------------------------------------------------------------------------

#[test]
fn hier_allreduce_bit_equal_to_flat() {
    // Integer-valued f64 contributions keep every addition exact, so the
    // different reduction orders must agree bit for bit; u64 is exact by
    // construction. Run the same reduction flat and hierarchical.
    let reduce_with = |hier: bool| -> Vec<(u64, u64)> {
        three_node().hierarchical(hier).collect(|env| {
            let me = env.myid() as usize;
            let mine_f = vec![(me * 7 + 3) as f64; 64];
            let mine_u = vec![(me as u64) << 20 | 0x3F; 64];
            let mut red_f = vec![0f64; 64];
            let mut red_u = vec![0u64; 64];
            env.allreduce(DART_TEAM_ALL, &mine_f, &mut red_f, MpiOp::Sum).unwrap();
            env.allreduce(DART_TEAM_ALL, &mine_u, &mut red_u, MpiOp::Sum).unwrap();
            assert!(red_f.iter().all(|&x| x == red_f[0]));
            (red_f[0].to_bits(), red_u[0])
        })
    };
    let flat = reduce_with(false);
    let hier = reduce_with(true);
    assert_eq!(flat, hier, "hierarchical allreduce must be bit-identical");
    // And the value itself is the analytic sum.
    let want: f64 = (0..12).map(|u| (u * 7 + 3) as f64).sum();
    assert_eq!(f64::from_bits(flat[0].0), want);
}

#[test]
fn hier_allreduce_decomposition_is_observable() {
    three_node().hierarchical(true).launch(|env| {
        let mine = [env.myid() as u64];
        let mut red = [0u64];
        env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
        assert_eq!(red[0], (0..12).sum::<u64>());
        // Two intra-node phases (reduce + fan-out) on every unit; the
        // leader exchange only on leaders.
        assert_eq!(env.metrics.hier_coll_intra_ops.get(), 2);
        let expect_inter = u64::from(env.myid() < 3);
        assert_eq!(env.metrics.hier_coll_inter_ops.get(), expect_inter);
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn hier_falls_back_flat_on_single_node() {
    world(4).pools(1 << 16, 1 << 16).hierarchical(true).launch(|env| {
        let mine = [env.myid() as u64 + 1];
        let mut red = [0u64];
        env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
        assert_eq!(red[0], 10);
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut b = [0u8; 4];
        if env.myid() == 2 {
            b = [7; 4];
        }
        env.bcast(DART_TEAM_ALL, &mut b, 2).unwrap();
        assert_eq!(b, [7; 4]);
        // Flat paths bumped no hierarchical counters and created no teams.
        assert_eq!(env.metrics.hier_coll_intra_ops.get(), 0);
        assert_eq!(env.metrics.hier_coll_inter_ops.get(), 0);
        assert_eq!(env.locality_splits_cached(), 0);
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn hier_bcast_delivers_from_every_root() {
    three_node().hierarchical(true).launch(|env| {
        for root in [0usize, 5, 11] {
            let mut buf = [0u8; 16];
            if env.team_myid(DART_TEAM_ALL).unwrap() == root {
                buf = [root as u8 ^ 0xA5; 16];
            }
            env.bcast(DART_TEAM_ALL, &mut buf, root).unwrap();
            assert_eq!(buf, [root as u8 ^ 0xA5; 16], "root {root}");
        }
        assert!(env.metrics.hier_coll_intra_ops.get() > 0);
        env.barrier(DART_TEAM_ALL).unwrap();
    })
}

#[test]
fn hier_allgather_matches_flat_with_uneven_nodes() {
    // 5 units over 2 nodes (ScatterNode): nodes hold 3 and 2 units — the
    // padding path of the hierarchical allgather.
    let gather_with = |hier: bool| -> Vec<Vec<u32>> {
        world(5)
            .nodes(2)
            .placement(PinPolicy::ScatterNode)
            .pools(1 << 16, 1 << 16)
            .hierarchical(hier)
            .collect(|env| {
                let me = env.myid() as u32;
                let mine = [me * 11 + 1, me * 11 + 2];
                let mut all = [0u32; 10];
                env.allgather(
                    DART_TEAM_ALL,
                    dart::mpisim::as_bytes(&mine),
                    dart::mpisim::as_bytes_mut(&mut all),
                )
                .unwrap();
                all.to_vec()
            })
    };
    let flat = gather_with(false);
    let hier = gather_with(true);
    assert_eq!(flat, hier, "hierarchical allgather must match the flat result");
    let want: Vec<u32> = (0..5u32).flat_map(|u| [u * 11 + 1, u * 11 + 2]).collect();
    assert_eq!(flat[0], want);
}

#[test]
fn hier_barrier_synchronizes() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let phase = AtomicUsize::new(0);
    three_node().hierarchical(true).launch(|env| {
        phase.fetch_add(1, Ordering::SeqCst);
        env.barrier(DART_TEAM_ALL).unwrap();
        assert_eq!(phase.load(Ordering::SeqCst), 12);
        assert!(env.metrics.hier_coll_intra_ops.get() >= 2);
    })
}

#[test]
fn hier_allreduce_models_less_time_than_flat_on_multinode() {
    // The acceptance bar: on a multi-node topology where every binomial
    // hop crosses nodes (3-node round-robin), the two-level allreduce —
    // one interconnect crossing per node instead of one per tree edge —
    // completes in strictly less modelled time than the flat path.
    let time_with = |hier: bool| -> f64 {
        let medians = three_node().hierarchical(hier).collect(|env| {
            let mine = vec![env.myid() as u64; 1024]; // 8 KiB, E1 regime
            let mut red = vec![0u64; 1024];
            // Warm the split cache outside the timed region.
            env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
            let mut med = dart::bench_util::Samples::new();
            for _ in 0..15 {
                env.barrier(DART_TEAM_ALL).unwrap();
                let t = Instant::now();
                env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
                med.push(t.elapsed().as_nanos() as f64);
            }
            med.median()
        });
        medians[0]
    };
    let flat = time_with(false);
    let hier = time_with(true);
    assert!(hier < flat, "hierarchical allreduce not faster: hier={hier}ns flat={flat}ns");
}

// ---------------------------------------------------------------------------
// Custom placements keep working through the locality API
// ---------------------------------------------------------------------------

#[test]
fn split_respects_custom_placement() {
    // Units deliberately placed so that unit 0 is alone on node 1 and
    // units 1..=3 share node 0 — leader order must follow unit ids, not
    // node indices.
    let coords = vec![
        CoreCoord { node: 1, numa: 0, core: 0 },
        CoreCoord { node: 0, numa: 0, core: 0 },
        CoreCoord { node: 0, numa: 1, core: 0 },
        CoreCoord { node: 0, numa: 0, core: 1 },
    ];
    world(4)
        .pools(1 << 16, 1 << 16)
        .topology(Topology::hermit(2))
        .placement(PinPolicy::Custom(coords))
        .hierarchical(true)
        .launch(|env| {
            let split = env.team_split_locality(DART_TEAM_ALL, LocalityScope::Node).unwrap();
            assert_eq!(split.domains, 2);
            let local = env.team_get_group(split.local).unwrap();
            if env.myid() == 0 {
                assert_eq!(local.members(), &[0]);
            } else {
                assert_eq!(local.members(), &[1, 2, 3]);
            }
            // Leaders: unit 0 (node 1) and unit 1 (node 0), sorted by unit id.
            assert_eq!(split.is_leader, env.myid() <= 1);
            if let Some(lt) = split.leaders {
                assert_eq!(env.team_get_group(lt).unwrap().members(), &[0, 1]);
            }
            // A hierarchical reduction over this placement still sums right.
            let mut red = [0u64];
            env.allreduce(DART_TEAM_ALL, &[1u64], &mut red, MpiOp::Sum).unwrap();
            assert_eq!(red[0], 4);
            env.barrier(DART_TEAM_ALL).unwrap();
        })
}
