//! Seeded chaos suite: the nine standing runtime invariants swept across
//! many fault seeds (`dart::testing::chaos`), plus the determinism oracle
//! — a fixed seed must replay an *identical* injected-event trace — and
//! the `Metrics` mirror of the world-global fault counters.
//!
//! Re-run one counterexample with
//! `DART_CHAOS_SEEDS=0x<seed> cargo test --test chaos_tests`.

use dart::dart::{FaultEvent, FaultStats, DART_TEAM_ALL};
use dart::mpisim::ProgressMode;
use dart::simnet::{CostModel, PinPolicy};
use dart::testing::chaos;
use dart::testing::world;
use std::sync::Mutex;

/// Seeds per invariant sweep (override with `DART_CHAOS_SEEDS`).
const SWEEP: usize = 50;

#[test]
fn flush_completes_all_under_chaos() {
    let stats = chaos::chaos_check(
        "flush_completes_all",
        &chaos::seeds(SWEEP),
        chaos::flush_completes_all,
    );
    // The canary sweep: every fault class must demonstrably fire, or the
    // whole suite is testing a friendly network and proving nothing.
    assert!(stats.jitter_events > 0, "no jitter injected: {stats:?}");
    assert!(stats.slow_channel_msgs > 0, "no slow channels: {stats:?}");
    assert!(stats.straggler_msgs > 0, "no straggler traffic: {stats:?}");
    assert!(stats.reorders > 0, "no completions reordered: {stats:?}");
    assert!(stats.starved_ticks > 0, "no progress ticks starved: {stats:?}");
}

#[test]
fn mcs_fifo_handoff_survives_chaos() {
    let stats = chaos::chaos_check("mcs_fifo", &chaos::seeds(SWEEP), chaos::mcs_fifo);
    assert!(stats.total() > 0, "fault plan never fired: {stats:?}");
}

#[test]
fn nonblocking_collectives_match_blocking_under_chaos() {
    let stats = chaos::chaos_check(
        "nonblocking_matches_blocking",
        &chaos::seeds(SWEEP),
        chaos::nonblocking_matches_blocking,
    );
    // The icoll completion bookings ride the faulted channel model.
    assert!(stats.jitter_events > 0, "collective bookings never jittered: {stats:?}");
}

#[test]
fn hierarchical_collectives_bit_equal_to_flat_under_chaos() {
    let stats = chaos::chaos_check(
        "hier_matches_flat",
        &chaos::seeds(SWEEP),
        chaos::hier_matches_flat,
    );
    assert!(stats.total() > 0, "fault plan never fired: {stats:?}");
}

#[test]
fn kv_backends_agree_under_chaos() {
    let stats =
        chaos::chaos_check("kv_backends_agree", &chaos::seeds(SWEEP), chaos::kv_backends_agree);
    assert!(stats.total() > 0, "fault plan never fired: {stats:?}");
}

#[test]
fn work_queue_retires_exactly_once_under_chaos() {
    let stats = chaos::chaos_check(
        "work_queue_exactly_once",
        &chaos::seeds(SWEEP),
        chaos::work_queue_exactly_once,
    );
    // The queue's CAS traffic rides the faulted channels: reorder and
    // straggler classes must demonstrably fire across the sweep.
    assert!(stats.reorders > 0, "no completions reordered: {stats:?}");
    assert!(stats.straggler_msgs > 0, "no straggler traffic: {stats:?}");
}

#[test]
fn vector_growth_bit_equal_to_prealloc_under_chaos() {
    let stats = chaos::chaos_check(
        "vector_growth_matches_prealloc",
        &chaos::seeds(SWEEP),
        chaos::vector_growth_matches_prealloc,
    );
    assert!(stats.total() > 0, "fault plan never fired: {stats:?}");
}

#[test]
fn bfs_levels_deterministic_under_chaos() {
    let stats = chaos::chaos_check(
        "bfs_levels_deterministic",
        &chaos::seeds(SWEEP),
        chaos::bfs_levels_deterministic,
    );
    // The claim CASes and adjacency pulls ride the faulted channels.
    assert!(stats.reorders > 0, "no completions reordered: {stats:?}");
    assert!(stats.jitter_events > 0, "no jitter injected: {stats:?}");
}

#[test]
fn sample_sort_is_permutation_under_chaos() {
    let stats = chaos::chaos_check(
        "sample_sort_is_permutation",
        &chaos::seeds(SWEEP),
        chaos::sample_sort_is_permutation,
    );
    assert!(stats.total() > 0, "fault plan never fired: {stats:?}");
}

// ---------------------------------------------------------------------
// Determinism oracle
// ---------------------------------------------------------------------

const ORACLE_SEED: u64 = 0xD150_77E5;

/// One oracle world: 2 units on 2 nodes, `Polling` progress, and only
/// unit 0 initiating — so every channel has a single booking thread,
/// every engine tick is program-ordered, and the injected-event trace is
/// a pure function of the seed. Returns unit 0's view of the trace.
fn oracle_run() -> (Vec<FaultEvent>, FaultStats) {
    let out: Mutex<Option<(Vec<FaultEvent>, FaultStats)>> = Mutex::new(None);
    world(2)
        .nodes(2)
        .cost(CostModel::zero())
        .placement(PinPolicy::ScatterNode)
        .pools(1 << 16, 1 << 16)
        .progress(ProgressMode::Polling)
        .faults(ORACLE_SEED)
        .launch(|env| {
            let g = env.team_memalloc_aligned(DART_TEAM_ALL, 8 * 64).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            if env.myid() == 0 {
                for i in 0..64u64 {
                    env.put_async(g.with_unit(1).add(8 * i), &i.to_ne_bytes()).unwrap();
                    if i % 8 == 0 {
                        env.progress_poll();
                    }
                }
                env.flush_all(g).unwrap();
            }
            env.barrier(DART_TEAM_ALL).unwrap();
            if env.myid() == 0 {
                *out.lock().unwrap() = Some((env.fault_trace(), env.fault_stats()));
            }
            env.team_memfree(DART_TEAM_ALL, g).unwrap();
        });
    out.into_inner().unwrap().expect("unit 0 captured no trace")
}

#[test]
fn fixed_seed_replays_identical_event_trace() {
    let (trace_a, stats_a) = oracle_run();
    let (trace_b, stats_b) = oracle_run();
    assert!(stats_a.total() > 0, "oracle seed injected nothing: {stats_a:?}");
    assert!(!trace_a.is_empty(), "oracle seed produced an empty trace");
    assert_eq!(stats_a, stats_b, "fault stats diverged between identical runs");
    assert_eq!(
        trace_a, trace_b,
        "injected-event trace diverged between identical runs of seed {ORACLE_SEED:#x}"
    );
}

// ---------------------------------------------------------------------
// Metrics mirror
// ---------------------------------------------------------------------

#[test]
fn fault_counters_mirror_into_unit_metrics() {
    let per_unit = world(4)
        .nodes(2)
        .cost(CostModel::zero())
        .placement(PinPolicy::ScatterNode)
        .pools(1 << 16, 1 << 16)
        .progress(ProgressMode::Polling)
        .faults(0xF4017_5EED)
        .collect(|env| {
            let units = env.size();
            let g = env.team_memalloc_aligned(DART_TEAM_ALL, 8 * 16).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            let peer = ((env.myid() as usize + 1) % units) as i32;
            for i in 0..16u64 {
                env.put_async(g.with_unit(peer).add(8 * i), &i.to_ne_bytes()).unwrap();
            }
            env.flush_all(g).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            // `fault_stats` is a sync point: the world-global counters are
            // mirrored into this unit's Metrics before being returned, and
            // nothing books events after the barrier above.
            let stats = env.fault_stats();
            let mirrored = (
                env.metrics.fault_jitter_events.get(),
                env.metrics.fault_reorders.get(),
                env.metrics.fault_starved_ticks.get(),
            );
            env.team_memfree(DART_TEAM_ALL, g).unwrap();
            (stats, mirrored)
        });
    let stats0 = per_unit[0].0;
    assert!(stats0.total() > 0, "fault plan never fired: {stats0:?}");
    for (unit, (stats, (jitter, reorders, starved))) in per_unit.iter().enumerate() {
        assert_eq!(*jitter, stats.jitter_events, "unit {unit} jitter mirror out of sync");
        assert_eq!(*reorders, stats.reorders, "unit {unit} reorder mirror out of sync");
        assert_eq!(*starved, stats.starved_ticks, "unit {unit} starved-tick mirror out of sync");
    }
}

#[test]
fn friendly_world_keeps_fault_counters_at_zero() {
    let per_unit = world(2).pools(1 << 16, 1 << 16).collect(|env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 8 * 4).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let peer = ((env.myid() as usize + 1) % env.size()) as i32;
        env.put_async(g.with_unit(peer), &7u64.to_ne_bytes()).unwrap();
        env.flush_all(g).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let stats = env.fault_stats();
        let trace = env.fault_trace();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
        (stats, trace.len(), env.metrics.fault_jitter_events.get())
    });
    for (stats, trace_len, jitter_metric) in per_unit {
        assert_eq!(stats, FaultStats::default(), "faults fired with no plan installed");
        assert_eq!(trace_len, 0);
        assert_eq!(jitter_metric, 0);
    }
}
