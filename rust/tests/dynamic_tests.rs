//! Tests of the dynamic global memory subsystem: `memattach`/`memdetach`
//! through every one-sided path, attach-token publication, lazy remote
//! cache invalidation via the detach generation, the allocator's
//! exhaust → free → realloc contract (both memory-model halves), the
//! growable `dash::Vector` (bit-equality with a preallocated `Array`
//! through ≥ 3 doublings), the `dash::WorkQueue` ring protocol, and the
//! `apps::wqueue` task farm's exactly-once oracle.

use dart::apps::wqueue::{reference_result, run_distributed, WqueueConfig};
use dart::dart::{run, DartConfig, DartErr, GlobalPtr, DART_TEAM_ALL};
use dart::dash::{Array, Pattern, Vector, WorkQueue};
use dart::mpisim::MpiOp;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

fn cfg(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 16, 1 << 17)
}

/// Attach + allgather the directory — the idiom every dynamic structure
/// uses to make per-unit regions globally reachable.
fn attach_all(env: &dart::dart::DartEnv, nbytes: u64) -> Vec<GlobalPtr> {
    let mine = env.memattach(nbytes).unwrap();
    let mut recv = vec![0u8; 16 * env.size()];
    env.allgather(DART_TEAM_ALL, &mine.to_bits().to_ne_bytes(), &mut recv).unwrap();
    recv.chunks_exact(16)
        .map(|c| GlobalPtr::from_bits(u128::from_ne_bytes(c.try_into().unwrap())))
        .collect()
}

// ---------------------------------------------------------------------------
// memattach / memdetach through the one-sided engine
// ---------------------------------------------------------------------------

#[test]
fn dynamic_put_get_roundtrip_with_publish() {
    run(cfg(2), |env| {
        let me = env.myid();
        if me == 0 {
            let g = env.memattach(256).unwrap();
            assert!(g.is_dynamic() && !g.is_collective());
            assert!(g.segid < 0, "dynamic segid must be negative, got {}", g.segid);
            env.gptr_publish(g, 1).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap(); // peer wrote
            let mut buf = [0u8; 8];
            env.local_read(g.add(64), &mut buf).unwrap();
            assert_eq!(u64::from_ne_bytes(buf), 0xFEED_F00D);
            env.barrier(DART_TEAM_ALL).unwrap(); // peer read back
            env.memdetach(g).unwrap();
        } else {
            let g = env.gptr_accept(0).unwrap();
            assert!(g.is_dynamic());
            // Fresh attached memory reads as zero.
            let mut buf = [0u8; 8];
            env.get_blocking(g, &mut buf).unwrap();
            assert_eq!(u64::from_ne_bytes(buf), 0);
            env.put_blocking(g.add(64), &0xFEED_F00Du64.to_ne_bytes()).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            env.get_blocking(g.add(64), &mut buf).unwrap();
            assert_eq!(u64::from_ne_bytes(buf), 0xFEED_F00D);
            env.barrier(DART_TEAM_ALL).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn dynamic_memory_supports_every_onesided_path() {
    let total = AtomicU64::new(0);
    run(cfg(4), |env| {
        let p = env.size();
        let me = env.myid() as usize;
        let dir = attach_all(env, 512);
        let right = dir[(me + 1) % p];

        // Deferred puts + flush, then a blocking get of the same cells.
        env.put_async(right, &(me as u64).to_ne_bytes()).unwrap();
        env.flush_all(right).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut buf = [0u8; 8];
        env.get_blocking(dir[me], &mut buf).unwrap();
        assert_eq!(u64::from_ne_bytes(buf) as usize, (me + p - 1) % p);

        // Strided put into the neighbour: 4 blocks of one u64, stride 2.
        let src: Vec<u64> = (0..4).map(|i| 100 + i).collect();
        env.put_strided_async(right.add(64), dart::mpisim::as_bytes(&src), 4, 8, 16).unwrap();
        env.flush_all(right).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        for i in 0..4u64 {
            env.get_blocking(dir[me].add(64 + i * 16), &mut buf).unwrap();
            assert_eq!(u64::from_ne_bytes(buf), 100 + i);
        }

        // Atomics: everyone accumulates into unit 0's counter cell, then
        // fetch_and_op / compare_and_swap verify the total.
        let counter = dir[0].add(256);
        env.accumulate_async(counter, &[3u64], MpiOp::Sum).unwrap();
        env.flush_all(counter).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let seen = env.fetch_and_op(counter, 0u64, MpiOp::NoOp).unwrap();
        assert_eq!(seen as usize, 3 * p);
        if me == 0 {
            let old = env.compare_and_swap(counter, 3 * p as u64, 7u64).unwrap();
            assert_eq!(old as usize, 3 * p);
            total.store(env.fetch_and_op(counter, 0u64, MpiOp::NoOp).unwrap(), Ordering::SeqCst);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.memdetach(dir[me]).unwrap();
    })
    .unwrap();
    assert_eq!(total.load(Ordering::SeqCst), 7);
}

#[test]
fn gptr_bcast_distributes_attach_tokens() {
    run(cfg(4), |env| {
        let me = env.myid();
        let mut g = if me == 2 { env.memattach(64).unwrap() } else { GlobalPtr::NULL };
        env.gptr_bcast(DART_TEAM_ALL, &mut g, 2).unwrap();
        assert!(g.is_dynamic());
        assert_eq!(g.unitid, 2);
        env.accumulate_async(g, &[1u64], MpiOp::Sum).unwrap();
        env.flush_all(g).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        assert_eq!(env.fetch_and_op(g, 0u64, MpiOp::NoOp).unwrap(), env.size() as u64);
        env.barrier(DART_TEAM_ALL).unwrap();
        if me == 2 {
            env.memdetach(g).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn detach_invalidates_remote_caches_lazily() {
    run(cfg(2), |env| {
        if env.myid() == 0 {
            let g = env.memattach(128).unwrap();
            env.gptr_publish(g, 1).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap(); // peer cached a resolution
            env.memdetach(g).unwrap();
            // Owner-side error checks while we're here: double detach and
            // detaching a non-dynamic pointer are rejected.
            assert!(matches!(env.memdetach(g), Err(DartErr::InvalidGptr(_))));
            let sym = env.memalloc(64).unwrap();
            assert!(matches!(env.memdetach(sym), Err(DartErr::InvalidGptr(_))));
            env.memfree(sym).unwrap();
            // Re-attach: the replacement region must be reachable while
            // the dead token stays dead.
            let g2 = env.memattach(128).unwrap();
            assert_ne!(g2.offset, g.offset, "attach tokens are never reused");
            env.gptr_publish(g2, 1).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap(); // peer re-resolved
            let mut buf = [0u8; 8];
            env.local_read(g2, &mut buf).unwrap();
            assert_eq!(u64::from_ne_bytes(buf), 42);
            env.memdetach(g2).unwrap();
        } else {
            let g = env.gptr_accept(0).unwrap();
            // Populate my segment cache with a live resolution.
            env.put_blocking(g, &1u64.to_ne_bytes()).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap(); // owner detaches
            let g2 = env.gptr_accept(0).unwrap();
            // The cached entry is stale (detach bumped the window
            // generation): the next op re-resolves and fails cleanly.
            let mut buf = [0u8; 8];
            assert!(
                matches!(env.get_blocking(g, &mut buf), Err(DartErr::InvalidGptr(_))),
                "operation on a detached region must fail after re-resolution"
            );
            env.put_blocking(g2, &42u64.to_ne_bytes()).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn dyn_metrics_and_gauge_track_attach_lifecycle() {
    run(cfg(1), |env| {
        let before = env.metrics.dyn_attach_ops.get();
        assert_eq!(env.dyn_attached_bytes(), 0);
        let a = env.memattach(100).unwrap();
        let b = env.memattach(28).unwrap();
        assert_eq!(env.dyn_attached_bytes(), 128);
        assert_eq!(env.metrics.dyn_attach_ops.get(), before + 2);
        env.memdetach(a).unwrap();
        assert_eq!(env.dyn_attached_bytes(), 28);
        env.memdetach(b).unwrap();
        assert_eq!(env.dyn_attached_bytes(), 0);
        assert_eq!(env.metrics.dyn_detach_ops.get(), 2);
        assert_eq!(env.metrics.dyn_bytes_attached.peak(), 128);
        assert!(matches!(env.memattach(0), Err(DartErr::Invalid(_))));
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Satellite: pool exhaustion — typed error, coalescing free, realloc
// ---------------------------------------------------------------------------

#[test]
fn memalloc_exhaustion_reports_oom_and_recovers_after_free() {
    // 1 KiB non-collective pool: 16 × 64-byte blocks, then typed OOM.
    run(DartConfig::with_units(1).with_pools(1 << 10, 1 << 12), |env| {
        let mut live = Vec::new();
        loop {
            match env.memalloc(64) {
                Ok(g) => live.push(g),
                Err(DartErr::OutOfMemory { requested, pool }) => {
                    assert_eq!(requested, 64);
                    assert_eq!(pool, 1 << 10);
                    break;
                }
                Err(e) => panic!("expected OutOfMemory, got {e}"),
            }
        }
        assert_eq!(live.len(), 16, "1 KiB pool must yield exactly 16 × 64 B");
        // Freeing any single block makes a same-size alloc succeed again…
        env.memfree(live.remove(7)).unwrap();
        let again = env.memalloc(64).unwrap();
        env.memfree(again).unwrap();
        // …and freeing two *adjacent* blocks coalesces into one extent a
        // double-size request fits (the free-list coalescing contract).
        let a = live.remove(3);
        let b = live.remove(3);
        assert_eq!(b.offset, a.offset + 64, "test premise: blocks adjacent");
        env.memfree(a).unwrap();
        env.memfree(b).unwrap();
        assert!(matches!(env.memalloc(192), Err(DartErr::OutOfMemory { .. })));
        let wide = env.memalloc(128).unwrap();
        assert_eq!(wide.offset, a.offset, "coalesced extent is first fit");
        env.memfree(wide).unwrap();
        for g in live {
            env.memfree(g).unwrap();
        }
        // Fully drained: the original capacity is whole again.
        let all = env.memalloc(1 << 10).unwrap();
        env.memfree(all).unwrap();
    })
    .unwrap();
}

#[test]
fn team_memalloc_exhaustion_reports_oom_and_recovers_after_free() {
    run(DartConfig::with_units(2).with_pools(1 << 10, 1 << 10), |env| {
        let team = DART_TEAM_ALL;
        let a = env.team_memalloc_aligned(team, 512).unwrap();
        let b = env.team_memalloc_aligned(team, 256).unwrap();
        match env.team_memalloc_aligned(team, 512) {
            Err(DartErr::OutOfMemory { requested, pool }) => {
                assert_eq!(requested, 512);
                assert_eq!(pool, 1 << 10);
            }
            other => panic!("expected OutOfMemory, got {other:?}"),
        }
        env.team_memfree(team, a).unwrap();
        // The freed front extent is coalescible with the tail: after both
        // frees a full-pool allocation must succeed.
        let c = env.team_memalloc_aligned(team, 512).unwrap();
        env.team_memfree(team, b).unwrap();
        env.team_memfree(team, c).unwrap();
        let all = env.team_memalloc_aligned(team, 1 << 10).unwrap();
        env.team_memfree(team, all).unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// dash::Vector — growth, bit-equality, append disciplines
// ---------------------------------------------------------------------------

fn elem(g: u64) -> u64 {
    g.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (g >> 11)
}

#[test]
fn vector_growth_is_bit_identical_to_preallocated_array() {
    run(cfg(4), |env| {
        let team = DART_TEAM_ALL;
        let p = env.size();
        let me = env.team_myid(team).unwrap();
        let mut v = Vector::<u64>::with_capacity(env, team, p).unwrap();
        let cap0 = v.capacity();
        // 16 collective pushes of p elements: capacity p → 16p, four
        // doublings (the acceptance floor is three).
        for _ in 0..16 {
            let base = v.len().unwrap();
            let g = v.push(elem((base + me) as u64)).unwrap();
            assert_eq!(g, base + me, "push slots land in team-rank order");
        }
        let n = v.len().unwrap();
        assert_eq!(n, 16 * p);
        let doublings = (v.capacity() / cap0).ilog2();
        assert!(doublings >= 3, "only {doublings} doublings ({cap0} → {})", v.capacity());

        // Oracle: a preallocated Array over the final capacity, same
        // BLOCKED pattern, same values, default tail.
        let arr = Array::<u64>::new(env, team, Pattern::blocked(v.capacity(), p).unwrap()).unwrap();
        arr.with_local(|loc| {
            for (i, slot) in loc.iter_mut().enumerate() {
                let g = arr.pattern().local_to_global(me, i);
                *slot = if g < n { elem(g as u64) } else { 0 };
            }
        })
        .unwrap();
        env.barrier(team).unwrap();
        assert_eq!(
            v.read_local().unwrap(),
            arr.read_local().unwrap(),
            "unit {me}: grown vector is not bit-identical to the preallocated array"
        );
        // Element access still agrees after growth (random probes).
        for g in [0, 1, n / 2, n - 1] {
            assert_eq!(v.get(g).unwrap(), elem(g as u64));
        }
        arr.free().unwrap();
        v.free().unwrap();
    })
    .unwrap();
}

#[test]
fn vector_push_back_global_claims_and_rejects_at_capacity() {
    run(cfg(2), |env| {
        let team = DART_TEAM_ALL;
        let v = Vector::<u64>::with_capacity(env, team, 8).unwrap();
        if env.myid() == 0 {
            for i in 0..8u64 {
                let idx = v.push_back_global(elem(i)).unwrap();
                assert_eq!(idx, i as usize);
            }
            // Full: the claim is rolled back and the error is typed.
            assert!(matches!(v.push_back_global(9), Err(DartErr::Invalid(_))));
            assert_eq!(v.len().unwrap(), 8, "failed append must restore the length");
        }
        env.barrier(team).unwrap();
        assert_eq!(v.len().unwrap(), 8);
        for i in 0..8u64 {
            assert_eq!(v.get(i as usize).unwrap(), elem(i));
        }
        env.barrier(team).unwrap();
        v.free().unwrap();
    })
    .unwrap();
}

#[test]
fn vector_reserve_preserves_contents_and_copy_roundtrips() {
    run(cfg(4), |env| {
        let team = DART_TEAM_ALL;
        let mut v = Vector::<u32>::with_capacity(env, team, 8).unwrap();
        let vals: Vec<u32> = (0..8).map(|i| 1000 + i).collect();
        if env.myid() == 0 {
            v.copy_in(0, &vals).unwrap();
        }
        env.barrier(team).unwrap();
        v.reserve(100).unwrap(); // 8 → 128, four doublings
        assert_eq!(v.capacity(), 128);
        let mut out = vec![0u32; 8];
        v.copy_out(0, &mut out).unwrap();
        assert_eq!(out, vals);
        // The grown tail keeps the default fill.
        assert_eq!(v.get(127).unwrap(), 0);
        v.free().unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// dash::WorkQueue — ring protocol + the task farm's exactly-once oracle
// ---------------------------------------------------------------------------

#[test]
fn work_queue_fifo_full_and_empty_semantics() {
    run(cfg(2), |env| {
        let q = WorkQueue::new(env, DART_TEAM_ALL, 4).unwrap();
        assert_eq!(q.ring_capacity(), 4);
        assert_eq!(q.nrings(), 2);
        if env.myid() == 0 {
            assert_eq!(q.try_pop_from(0).unwrap(), None, "fresh ring is empty");
            for i in 10..14u64 {
                assert!(q.push(i).unwrap());
            }
            assert!(!q.push(99).unwrap(), "5th push into a 4-slot ring must report full");
            // FIFO per ring, zero is a legal payload after a drain.
            for i in 10..14u64 {
                assert_eq!(q.try_pop_from(0).unwrap(), Some(i));
            }
            assert!(q.push(0).unwrap());
            assert_eq!(q.pop().unwrap(), Some(0));
            assert_eq!(q.pop().unwrap(), None);
            // Cross-ring: push to the peer's ring, steal it right back.
            assert!(q.push_to(1, 77).unwrap());
            let steals = env.metrics.wq_steals.get();
            assert_eq!(q.pop().unwrap(), Some(77));
            assert_eq!(env.metrics.wq_steals.get(), steals + 1);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        q.free().unwrap();
    })
    .unwrap();
}

#[test]
fn work_queue_concurrent_producers_consumers_exactly_once() {
    // Every unit pushes a disjoint tagged range to *unit 1's* ring (tiny,
    // to force full-ring retries) while every unit concurrently drains via
    // pop(); the multiset union of drained items must be exactly the
    // pushed set — no loss, no duplication, under real contention.
    let seen = Mutex::new(Vec::<u64>::new());
    let per_unit = 40u64;
    run(cfg(4), |env| {
        let p = env.size() as u64;
        let me = env.myid() as u64;
        let q = WorkQueue::new(env, DART_TEAM_ALL, 3).unwrap();
        let mut drained = Vec::new();
        let mut pushed = 0u64;
        while pushed < per_unit {
            if q.push_to(1, me * per_unit + pushed).unwrap() {
                pushed += 1;
            } else if let Some(item) = q.pop().unwrap() {
                drained.push(item);
            }
        }
        // Drain until the global count accounts for everything: tally via
        // an allreduce-style loop on a barrier cadence.
        loop {
            while let Some(item) = q.pop().unwrap() {
                drained.push(item);
            }
            let mine = [drained.len() as u64];
            let mut total = [0u64];
            env.allreduce(DART_TEAM_ALL, &mine, &mut total, MpiOp::Sum).unwrap();
            if total[0] == p * per_unit {
                break;
            }
        }
        seen.lock().unwrap().extend(&drained);
        env.barrier(DART_TEAM_ALL).unwrap();
        q.free().unwrap();
    })
    .unwrap();
    let mut all = seen.into_inner().unwrap();
    all.sort_unstable();
    let want: Vec<u64> = (0..4 * per_unit).collect();
    assert_eq!(all, want, "drained multiset differs from the pushed set");
}

#[test]
fn wqueue_task_farm_matches_sequential_reference() {
    let cfg_wq = WqueueConfig { tasks: 300, ring_capacity: 8, seed: 0xBEEF, team: DART_TEAM_ALL };
    let want = reference_result(&cfg_wq);
    run(cfg(4), |env| {
        let report = run_distributed(env, &cfg_wq).unwrap();
        assert_eq!(report.retired, 300);
        assert_eq!(report.checksum, want);
    })
    .unwrap();
}
