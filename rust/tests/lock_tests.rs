//! Tests of the MCS queue lock (§IV-B6) under real contention:
//! mutual exclusion of `lock_acquire`, `lock_try_acquire` semantics while
//! the lock is held and fought over, and the FIFO hand-off order of the
//! queue (release-order fairness).

use dart::dart::{run, DartConfig, GlobalPtr, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use std::sync::Mutex;
use std::time::Duration;

fn cfg(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 16, 1 << 16)
}

/// Allocate `slots` u64 cells on unit 0's non-collective partition,
/// initialized to `init`, and broadcast the pointer to the team.
fn shared_cells(env: &dart::dart::DartEnv, slots: usize, init: u64) -> GlobalPtr {
    let mut bits = [0u8; 16];
    if env.myid() == 0 {
        let g = env.memalloc((slots * 8) as u64).unwrap();
        for s in 0..slots {
            env.local_write(g.add((s * 8) as u64), &init.to_ne_bytes()).unwrap();
        }
        bits = g.to_bits().to_ne_bytes();
    }
    env.bcast(DART_TEAM_ALL, &mut bits, 0).unwrap();
    GlobalPtr::from_bits(u128::from_ne_bytes(bits))
}

fn free_shared(env: &dart::dart::DartEnv, g: GlobalPtr) {
    env.barrier(DART_TEAM_ALL).unwrap();
    if env.myid() == 0 {
        env.memfree(g).unwrap();
    }
}

#[test]
fn contended_acquire_preserves_mutual_exclusion() {
    const ITERS: usize = 25;
    const UNITS: usize = 4;
    run(cfg(UNITS), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        let counter = shared_cells(env, 1, 0);
        env.barrier(DART_TEAM_ALL).unwrap();
        // Unsynchronized read-modify-write on a shared cell: only mutual
        // exclusion makes the final count exact.
        for _ in 0..ITERS {
            env.lock_acquire(&lock).unwrap();
            let mut cur = [0u8; 8];
            env.get_blocking(counter, &mut cur).unwrap();
            let next = u64::from_ne_bytes(cur) + 1;
            env.put_blocking(counter, &next.to_ne_bytes()).unwrap();
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut fin = [0u8; 8];
        env.get_blocking(counter, &mut fin).unwrap();
        assert_eq!(u64::from_ne_bytes(fin), (UNITS * ITERS) as u64, "lost updates");
        free_shared(env, counter);
        env.lock_free(lock).unwrap();
    })
    .unwrap();
}

#[test]
fn try_acquire_fails_while_held_without_enqueueing() {
    run(cfg(3), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            assert!(env.lock_try_acquire(&lock).unwrap());
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() != 0 {
            // Held elsewhere: must fail immediately, NOT queue us.
            assert!(!env.lock_try_acquire(&lock).unwrap());
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            // Nobody queued behind the try_acquire failures, so this
            // release must not block on a phantom successor.
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 1 {
            assert!(env.lock_try_acquire(&lock).unwrap(), "freed lock must be takeable");
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
    })
    .unwrap();
}

#[test]
fn try_acquire_under_contention_admits_one_holder_at_a_time() {
    const ROUNDS: usize = 30;
    run(cfg(4), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        // `occupant` holds the id of whoever is inside the critical
        // section, u64::MAX when empty.
        let occupant = shared_cells(env, 1, u64::MAX);
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut wins = 0u64;
        for _ in 0..ROUNDS {
            if env.lock_try_acquire(&lock).unwrap() {
                let mut cur = [0u8; 8];
                env.get_blocking(occupant, &mut cur).unwrap();
                assert_eq!(
                    u64::from_ne_bytes(cur),
                    u64::MAX,
                    "acquired the lock but the critical section was occupied"
                );
                env.put_blocking(occupant, &(env.myid() as u64).to_ne_bytes()).unwrap();
                std::thread::sleep(Duration::from_micros(200));
                let mut chk = [0u8; 8];
                env.get_blocking(occupant, &mut chk).unwrap();
                assert_eq!(
                    u64::from_ne_bytes(chk),
                    env.myid() as u64,
                    "another unit entered the critical section while I held the lock"
                );
                env.put_blocking(occupant, &u64::MAX.to_ne_bytes()).unwrap();
                env.lock_release(&lock).unwrap();
                wins += 1;
            }
            std::thread::yield_now();
        }
        let mut total = [0u64];
        env.allreduce(DART_TEAM_ALL, &[wins], &mut total, MpiOp::Sum).unwrap();
        assert!(total[0] >= 1, "nobody ever won a contended try_acquire");
        free_shared(env, occupant);
        env.lock_free(lock).unwrap();
    })
    .unwrap();
}

#[test]
fn mixed_acquire_and_try_acquire_contention_stays_consistent() {
    // Blocking acquirers and try-acquirers interleave on the same lock:
    // exercises the try_acquire CAS racing against lock_acquire's
    // tail-swap + predecessor registration (the successor cell must be
    // reset BEFORE the tail swap or a registration can be lost and the
    // hand-off deadlocks). The shared counter catches lost updates.
    const ITERS: usize = 20;
    run(cfg(4), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        let counter = shared_cells(env, 1, 0);
        env.barrier(DART_TEAM_ALL).unwrap();
        let blocking = env.myid() % 2 == 0;
        let mut updates = 0u64;
        for _ in 0..ITERS {
            let entered = if blocking {
                env.lock_acquire(&lock).unwrap();
                true
            } else {
                env.lock_try_acquire(&lock).unwrap()
            };
            if entered {
                let mut cur = [0u8; 8];
                env.get_blocking(counter, &mut cur).unwrap();
                let next = u64::from_ne_bytes(cur) + 1;
                env.put_blocking(counter, &next.to_ne_bytes()).unwrap();
                env.lock_release(&lock).unwrap();
                updates += 1;
            }
            std::thread::yield_now();
        }
        let mut total = [0u64];
        env.allreduce(DART_TEAM_ALL, &[updates], &mut total, MpiOp::Sum).unwrap();
        let mut fin = [0u8; 8];
        env.get_blocking(counter, &mut fin).unwrap();
        assert_eq!(u64::from_ne_bytes(fin), total[0], "lost updates under mixed contention");
        // The blocking acquirers always get through.
        assert!(total[0] >= (2 * ITERS) as u64);
        free_shared(env, counter);
        env.lock_free(lock).unwrap();
    })
    .unwrap();
}

#[test]
fn release_hands_off_in_enqueue_order() {
    // MCS fairness: waiters are served in the order they swapped
    // themselves into the tail. Unit 0 takes the lock; each waiter spins
    // until its predecessor is the observed queue tail before enqueueing
    // itself, so the enqueue order is 1, 2, 3 *deterministically* (no
    // wall-clock staggering); unit 0 releases only once unit 3 is the
    // tail. The recorded acquisition order must match.
    const UNITS: usize = 4;
    let order = Mutex::new(Vec::<u64>::new());
    run(cfg(UNITS), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        // Cell 0: next free log slot; cells 1..=3: the log itself.
        let log = shared_cells(env, UNITS, 0);
        env.barrier(DART_TEAM_ALL).unwrap();
        let me = env.myid();
        if me == 0 {
            env.lock_acquire(&lock).unwrap(); // tail is now 0
        }
        env.barrier(DART_TEAM_ALL).unwrap(); // everyone knows 0 holds it
        if me > 0 {
            // Enqueue strictly after my predecessor has swapped itself in.
            while env.lock_tail(&lock).unwrap() != (me - 1) as i64 {
                std::thread::yield_now();
            }
            env.lock_acquire(&lock).unwrap();
            let slot = env.fetch_and_op(log, 1u64, MpiOp::Sum).unwrap();
            env.put_blocking(log.add(8 * (1 + slot)), &(me as u64).to_ne_bytes()).unwrap();
            env.lock_release(&lock).unwrap();
        } else {
            // Release only once the whole queue has built up behind me.
            while env.lock_tail(&lock).unwrap() != (UNITS - 1) as i64 {
                std::thread::yield_now();
            }
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if me == 0 {
            let mut buf = [0u8; 8 * UNITS];
            env.get_blocking(log, &mut buf).unwrap();
            let served: Vec<u64> = buf[8..]
                .chunks_exact(8)
                .map(|c| u64::from_ne_bytes(c.try_into().unwrap()))
                .collect();
            *order.lock().unwrap() = served;
        }
        free_shared(env, log);
        env.lock_free(lock).unwrap();
    })
    .unwrap();
    assert_eq!(
        order.into_inner().unwrap(),
        vec![1, 2, 3],
        "MCS queue served waiters out of their enqueue order"
    );
}

#[test]
fn lock_misuse_is_reported_not_undefined() {
    use dart::dart::DartErr;
    run(cfg(2), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            // Release without holding.
            assert!(matches!(env.lock_release(&lock), Err(DartErr::LockMisuse(_))));
            env.lock_acquire(&lock).unwrap();
            // Re-entrant acquire and try_acquire are contract violations.
            assert!(matches!(env.lock_acquire(&lock), Err(DartErr::LockMisuse(_))));
            assert!(matches!(env.lock_try_acquire(&lock), Err(DartErr::LockMisuse(_))));
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
    })
    .unwrap();
}
