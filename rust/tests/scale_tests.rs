//! Tier-1 scale smoke: a 256-unit world runs one barrier + allreduce +
//! put/flush round under both execution modes, producing bit-identical
//! results, with the pooled mode's concurrently runnable ranks bounded
//! by the configured slot limit and the channel table staying sparse —
//! plus the irregular-workload agreement sweep: BFS and sample sort must
//! be bit-identical across flat/hier collectives, fast path on/off, and
//! both execution modes.

use dart::apps::bfs::{self, BfsConfig, BfsSummary};
use dart::apps::samplesort::{self, KeyDist, SortConfig};
use dart::dart::{UnitId, DART_TEAM_ALL};
use dart::dash::GraphConfig;
use dart::mpisim::{ExecMode, MpiOp};
use dart::simnet::PinPolicy;
use dart::testing::world;
use std::sync::Mutex;

const UNITS: usize = 256;
const NODES: usize = 16;
const RED: usize = 64;
const PUT_BYTES: usize = 256;
/// Slot limit for the pooled run — small enough that the bound bites
/// (256 ranks contend for 8 slots) regardless of the host's core count.
const SLOTS: usize = 8;

/// What one round leaves behind on each unit.
#[derive(Clone, Copy, Default, PartialEq, Debug)]
struct Outcome {
    red_first: u64,
    red_last: u64,
    ring_byte: u8,
}

fn round(exec: ExecMode) -> Vec<(Outcome, Option<(usize, usize)>, usize)> {
    world(UNITS)
        .nodes(NODES)
        .placement(PinPolicy::ScatterNode)
        .pools(1 << 14, 1 << 18)
        .exec(exec, SLOTS)
        .collect(|env| {
            let n = env.size();
            let me = env.myid() as usize;
            let g = env.team_memalloc_aligned(DART_TEAM_ALL, PUT_BYTES as u64).unwrap();
            let mine = vec![me as u64 + 1; RED];
            let mut red = vec![0u64; RED];
            env.barrier(DART_TEAM_ALL).unwrap();
            env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
            let src = vec![(me & 0xFF) as u8; PUT_BYTES];
            let right = ((me + 1) % n) as UnitId;
            env.put_async(g.with_unit(right), &src).unwrap();
            env.flush_all(g).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            let writer = (me + n - 1) % n;
            let mut got = vec![0u8; PUT_BYTES];
            env.local_read(g.with_unit(me as UnitId), &mut got).unwrap();
            assert!(got.iter().all(|&b| b == (writer & 0xFF) as u8), "unit {me}: wrong ring bytes");
            let result = (
                Outcome { red_first: red[0], red_last: red[RED - 1], ring_byte: got[0] },
                env.exec_gate_stats(),
                env.active_channels(),
            );
            env.team_memfree(DART_TEAM_ALL, g).unwrap();
            result
        })
}

#[test]
fn smoke_256_units_both_exec_modes() {
    let per_rank = round(ExecMode::ThreadPerRank);
    let pooled = round(ExecMode::Pooled);

    // The allreduce over unit ids has a closed form — both modes must
    // produce it exactly, on every unit.
    let expect = (UNITS as u64 * (UNITS as u64 + 1)) / 2;
    assert_eq!(per_rank[0].0.red_first, expect);
    let outcomes = |v: &[(Outcome, Option<(usize, usize)>, usize)]| {
        v.iter().map(|r| r.0).collect::<Vec<_>>()
    };
    assert_eq!(
        outcomes(&per_rank),
        outcomes(&pooled),
        "pooled world computed different results"
    );

    // Thread-per-rank has no gate; pooled respects its slot limit.
    assert_eq!(per_rank[0].1, None);
    let (limit, peak) = pooled[0].1.expect("pooled world must expose gate stats");
    assert_eq!(limit, SLOTS);
    assert!(
        (1..=SLOTS).contains(&peak),
        "peak runnable {peak} outside [1, {SLOTS}] — the pool bound did not hold"
    );

    // Lazily-populated channels: a logarithmic round on 256 units must
    // populate nowhere near the 65 536 eager pairs.
    let channels = pooled[0].2;
    assert!(channels > 0 && channels < UNITS * UNITS / 8, "channel table not sparse: {channels}");
}

// ---------------------------------------------------------------------
// Irregular-workload cross-config agreement
// ---------------------------------------------------------------------

/// What one (hier, fastpath, exec) cell leaves behind: the BFS level
/// summary and the sample sort's oracle checksums.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct IrregularOutcome {
    bfs: BfsSummary,
    sort_multiset: u64,
    sort_position: u64,
}

fn irregular_cell(hier: bool, fastpath: bool, exec: ExecMode) -> IrregularOutcome {
    let graph = GraphConfig { scale: 6, edge_factor: 8, seed: 0xA6EE_D0C5 };
    let bfs_cfg = BfsConfig { graph, root: 0, combine: hier, team: DART_TEAM_ALL };
    let sort_cfg = SortConfig {
        n: 1 << 10,
        seed: 0xA6EE_D0C5,
        dist: KeyDist::Skewed,
        oversample: 8,
        team: DART_TEAM_ALL,
    };
    let out: Mutex<Option<IrregularOutcome>> = Mutex::new(None);
    world(8)
        .nodes(2)
        .placement(PinPolicy::ScatterNode)
        .pools(1 << 17, 1 << 19)
        .shmem(true)
        .fastpath(fastpath)
        .hierarchical(hier)
        .exec(exec, 4)
        .launch(|env| {
            let b = bfs::run_distributed(env, &bfs_cfg).unwrap();
            let s = samplesort::run_distributed(env, &sort_cfg).unwrap();
            assert!(s.sorted_ok, "sort output not globally sorted");
            assert_eq!(s.checksum_in, s.checksum_out, "sort lost or invented keys");
            if env.myid() == 0 {
                *out.lock().unwrap() = Some(IrregularOutcome {
                    bfs: b.summary,
                    sort_multiset: s.checksum_out,
                    sort_position: s.position_checksum,
                });
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        });
    out.into_inner().unwrap().expect("unit 0 captured no outcome")
}

/// BFS levels and the sorted permutation are functions of (graph seed,
/// key stream) alone — every runtime configuration axis must be
/// invisible: flat vs hierarchical collectives (with intra-node claim
/// combining riding the hier cells), the shmem fast path on vs off, and
/// thread-per-rank vs pooled execution. All eight cells must agree
/// bit-for-bit with each other and with the sequential oracles.
#[test]
fn irregular_workloads_agree_across_configs() {
    let mut cells = Vec::new();
    for exec in [ExecMode::ThreadPerRank, ExecMode::Pooled] {
        for hier in [false, true] {
            for fastpath in [false, true] {
                cells.push(((hier, fastpath, exec), irregular_cell(hier, fastpath, exec)));
            }
        }
    }
    let baseline = cells[0].1;
    for (label, cell) in &cells[1..] {
        assert_eq!(
            *cell, baseline,
            "config {label:?} diverged from {:?}",
            (false, false, ExecMode::ThreadPerRank)
        );
    }

    let graph = GraphConfig { scale: 6, edge_factor: 8, seed: 0xA6EE_D0C5 };
    let oracle = bfs::reference_summary(&BfsConfig {
        graph,
        root: 0,
        combine: false,
        team: DART_TEAM_ALL,
    });
    assert_eq!(baseline.bfs, oracle, "distributed BFS disagrees with the sequential oracle");
    let (multiset, position) = samplesort::reference_checksums(&SortConfig {
        n: 1 << 10,
        seed: 0xA6EE_D0C5,
        dist: KeyDist::Skewed,
        oversample: 8,
        team: DART_TEAM_ALL,
    });
    assert_eq!(
        (baseline.sort_multiset, baseline.sort_position),
        (multiset, position),
        "distributed sort disagrees with the sequential oracle"
    );
}
