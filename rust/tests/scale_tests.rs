//! Tier-1 scale smoke: a 256-unit world runs one barrier + allreduce +
//! put/flush round under both execution modes, producing bit-identical
//! results, with the pooled mode's concurrently runnable ranks bounded
//! by the configured slot limit and the channel table staying sparse.

use dart::dart::{UnitId, DART_TEAM_ALL};
use dart::mpisim::{ExecMode, MpiOp};
use dart::simnet::PinPolicy;
use dart::testing::world;

const UNITS: usize = 256;
const NODES: usize = 16;
const RED: usize = 64;
const PUT_BYTES: usize = 256;
/// Slot limit for the pooled run — small enough that the bound bites
/// (256 ranks contend for 8 slots) regardless of the host's core count.
const SLOTS: usize = 8;

/// What one round leaves behind on each unit.
#[derive(Clone, Copy, Default, PartialEq, Debug)]
struct Outcome {
    red_first: u64,
    red_last: u64,
    ring_byte: u8,
}

fn round(exec: ExecMode) -> Vec<(Outcome, Option<(usize, usize)>, usize)> {
    world(UNITS)
        .nodes(NODES)
        .placement(PinPolicy::ScatterNode)
        .pools(1 << 14, 1 << 18)
        .exec(exec, SLOTS)
        .collect(|env| {
            let n = env.size();
            let me = env.myid() as usize;
            let g = env.team_memalloc_aligned(DART_TEAM_ALL, PUT_BYTES as u64).unwrap();
            let mine = vec![me as u64 + 1; RED];
            let mut red = vec![0u64; RED];
            env.barrier(DART_TEAM_ALL).unwrap();
            env.allreduce(DART_TEAM_ALL, &mine, &mut red, MpiOp::Sum).unwrap();
            let src = vec![(me & 0xFF) as u8; PUT_BYTES];
            let right = ((me + 1) % n) as UnitId;
            env.put_async(g.with_unit(right), &src).unwrap();
            env.flush_all(g).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            let writer = (me + n - 1) % n;
            let mut got = vec![0u8; PUT_BYTES];
            env.local_read(g.with_unit(me as UnitId), &mut got).unwrap();
            assert!(got.iter().all(|&b| b == (writer & 0xFF) as u8), "unit {me}: wrong ring bytes");
            let result = (
                Outcome { red_first: red[0], red_last: red[RED - 1], ring_byte: got[0] },
                env.exec_gate_stats(),
                env.active_channels(),
            );
            env.team_memfree(DART_TEAM_ALL, g).unwrap();
            result
        })
}

#[test]
fn smoke_256_units_both_exec_modes() {
    let per_rank = round(ExecMode::ThreadPerRank);
    let pooled = round(ExecMode::Pooled);

    // The allreduce over unit ids has a closed form — both modes must
    // produce it exactly, on every unit.
    let expect = (UNITS as u64 * (UNITS as u64 + 1)) / 2;
    assert_eq!(per_rank[0].0.red_first, expect);
    let outcomes = |v: &[(Outcome, Option<(usize, usize)>, usize)]| {
        v.iter().map(|r| r.0).collect::<Vec<_>>()
    };
    assert_eq!(
        outcomes(&per_rank),
        outcomes(&pooled),
        "pooled world computed different results"
    );

    // Thread-per-rank has no gate; pooled respects its slot limit.
    assert_eq!(per_rank[0].1, None);
    let (limit, peak) = pooled[0].1.expect("pooled world must expose gate stats");
    assert_eq!(limit, SLOTS);
    assert!(
        (1..=SLOTS).contains(&peak),
        "peak runnable {peak} outside [1, {SLOTS}] — the pool bound did not hold"
    );

    // Lazily-populated channels: a logarithmic round on 256 units must
    // populate nowhere near the 65 536 eager pairs.
    let channels = pooled[0].2;
    assert!(channels > 0 && channels < UNITS * UNITS / 8, "channel table not sparse: {channels}");
}
