//! Tests of the asynchronous progress engine and the nonblocking
//! collectives, across the three progress modes (Caller/Thread/Polling).
//!
//! The semantics under test are the ISSUE's acceptance bar: an ibarrier
//! completes only after all units enter; an ibcast delivers byte-for-byte
//! what the blocking bcast delivers; `Thread` mode completes an async put
//! with zero explicit flushes; and stencil2d achieves nonzero overlap
//! (asserted via `Metrics`) while `Caller` mode achieves exactly zero.

use dart::apps::stencil2d::{self, Stencil2dConfig};
use dart::dart::{run, DartConfig, ProgressMode, DART_TEAM_ALL};
use dart::mpisim::MpiOp;
use dart::runtime::{artifacts_dir, Engine};
use dart::simnet::CostModel;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

fn cfg(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 16, 1 << 16)
}

// ---------------------------------------------------------------------------
// Nonblocking-collective semantics
// ---------------------------------------------------------------------------

#[test]
fn barrier_async_completes_only_after_all_units_enter() {
    let released = AtomicBool::new(false);
    run(cfg(3), |env| {
        if env.myid() == 2 {
            // Hold the barrier back, then release: the flag flips strictly
            // before this unit enters, so any completion observed while
            // the flag is down is a semantics bug.
            std::thread::sleep(Duration::from_millis(20));
            released.store(true, Ordering::SeqCst);
            let h = env.barrier_async(DART_TEAM_ALL).unwrap();
            env.coll_wait(h).unwrap();
        } else {
            let mut h = env.barrier_async(DART_TEAM_ALL).unwrap();
            while !released.load(Ordering::SeqCst) {
                assert!(!env.coll_test(&mut h), "ibarrier completed before all units entered");
                std::thread::yield_now();
            }
            while !env.coll_test(&mut h) {
                std::thread::yield_now();
            }
        }
    })
    .unwrap();
}

#[test]
fn bcast_async_equals_blocking_bcast_byte_for_byte() {
    run(cfg(4), |env| {
        for root in 0..4 {
            let me = env.team_myid(DART_TEAM_ALL).unwrap();
            let payload: Vec<u8> = (0..64).map(|i| (i * 13 + root * 7) as u8).collect();
            let mut blocking = if me == root { payload.clone() } else { vec![0u8; 64] };
            env.bcast(DART_TEAM_ALL, &mut blocking, root).unwrap();
            let mut nonblocking = if me == root { payload.clone() } else { vec![0u8; 64] };
            let h = env.bcast_async(DART_TEAM_ALL, &mut nonblocking, root).unwrap();
            env.coll_wait(h).unwrap();
            assert_eq!(nonblocking, blocking, "root {root}");
            assert!(env.metrics.coll_phases.get() >= 2, "init + completion phases");
        }
    })
    .unwrap();
}

#[test]
fn allgather_async_and_allreduce_async_match_blocking() {
    run(cfg(5), |env| {
        let me = env.team_myid(DART_TEAM_ALL).unwrap();
        // allgather
        let mine = [me as u8; 4];
        let mut blocking = [0u8; 20];
        env.allgather(DART_TEAM_ALL, &mine, &mut blocking).unwrap();
        let mut nonblocking = [0u8; 20];
        let h = env.allgather_async(DART_TEAM_ALL, &mine, &mut nonblocking).unwrap();
        env.coll_wait(h).unwrap();
        assert_eq!(nonblocking, blocking);
        // allreduce (integer, so reduction order cannot matter)
        let vals = [me as i64, 1];
        let mut blocking_sum = [0i64; 2];
        env.allreduce(DART_TEAM_ALL, &vals, &mut blocking_sum, MpiOp::Sum).unwrap();
        let mut nb_sum = [0i64; 2];
        let h = env.allreduce_async(DART_TEAM_ALL, &vals, &mut nb_sum, MpiOp::Sum).unwrap();
        env.coll_wait(h).unwrap();
        assert_eq!(nb_sum, blocking_sum);
        assert_eq!(nb_sum, [10, 5]);
    })
    .unwrap();
}

#[test]
fn coll_test_all_and_wait_all_complete_a_batch() {
    run(cfg(2), |env| {
        let h1 = env.barrier_async(DART_TEAM_ALL).unwrap();
        let h2 = env.barrier_async(DART_TEAM_ALL).unwrap();
        let mut batch = vec![h1, h2];
        let deadline = Instant::now() + Duration::from_secs(30);
        while !env.coll_test_all(&mut batch) {
            assert!(Instant::now() < deadline, "batch never completed");
            std::thread::yield_now();
        }
        // wait_all on completed handles is a no-op.
        env.coll_wait_all(batch).unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Thread-mode asynchronous progress
// ---------------------------------------------------------------------------

#[test]
fn thread_mode_completes_async_put_with_zero_explicit_flushes() {
    let cfg = cfg(2).with_cost(CostModel::hermit()).with_progress_mode(ProgressMode::Thread);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            env.put_async(g.with_unit(1), &[7u8; 32]).unwrap();
            assert_eq!(env.metrics.flushes.get(), 0);
            // The background service must retire the operation without any
            // completion call from this unit.
            let deadline = Instant::now() + Duration::from_secs(30);
            while env.async_pending() > 0 {
                assert!(Instant::now() < deadline, "progress thread never retired the put");
                std::thread::yield_now();
            }
            assert_eq!(env.metrics.flushes.get(), 0, "completion must not have flushed");
            assert_eq!(env.metrics.overlap_ops.get(), 1);
            assert!(env.metrics.overlap_bytes.get() >= 32);
            assert!(env.engine_ticks() > 0);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 1 {
            let mut got = [0u8; 32];
            env.local_read(g.with_unit(1), &mut got).unwrap();
            assert_eq!(got, [7u8; 32]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn thread_mode_advances_collective_during_compute() {
    let cfg = cfg(2).with_cost(CostModel::hermit()).with_progress_mode(ProgressMode::Thread);
    run(cfg, |env| {
        let mine = [env.myid() as i64 + 1];
        let mut out = [0i64];
        let mut h = env.allreduce_async(DART_TEAM_ALL, &mine, &mut out, MpiOp::Sum).unwrap();
        // Compute (sleep) without touching the runtime; the background
        // thread performs the reduction and books the fan-out meanwhile.
        std::thread::sleep(Duration::from_millis(10));
        while !env.coll_test(&mut h) {
            std::thread::yield_now();
        }
        assert_eq!(out, [3]);
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Progress-mode ablation through the stencil2d app
// ---------------------------------------------------------------------------

fn have_artifacts() {
    let dir = if artifacts_dir().exists() { artifacts_dir() } else { "../artifacts".into() };
    assert!(dir.exists(), "artifacts/ not found — run `make artifacts` before `cargo test`");
    std::env::set_var("DART_ARTIFACTS", &dir);
}

#[test]
fn stencil2d_achieves_nonzero_overlap_in_polling_mode() {
    have_artifacts();
    let steps = 4;
    let cfg2d = Stencil2dConfig::block32(2, 2, steps);
    let seen = Mutex::new(Vec::new());
    run(DartConfig::with_units(4).with_progress_mode(ProgressMode::Polling), |env| {
        let engine = Engine::new().expect("engine");
        let r = stencil2d::run_distributed(env, &engine, &cfg2d).expect("run");
        seen.lock().unwrap().push((
            env.metrics.overlap_bytes.get(),
            env.metrics.progress_ticks.get(),
            r.global_checksum,
        ));
    })
    .unwrap();
    let want = stencil2d::reference_checksum(&cfg2d);
    for &(overlap_bytes, ticks, checksum) in seen.lock().unwrap().iter() {
        // Every unit initiates its halo gets, assembles the interior, and
        // polls before flushing — so the engine must have retired traffic.
        assert!(overlap_bytes > 0, "no overlap achieved in Polling mode");
        assert!(ticks >= steps as u64, "fewer polls than steps");
        let rel = (checksum - want).abs() / want.abs().max(1e-12);
        assert!(rel < 1e-5, "overlap changed the numerics: {checksum} vs {want}");
    }
}

#[test]
fn stencil2d_overlap_is_exactly_zero_in_caller_mode() {
    have_artifacts();
    let cfg2d = Stencil2dConfig::block32(2, 2, 3);
    run(DartConfig::with_units(4), |env| {
        let engine = Engine::new().expect("engine");
        stencil2d::run_distributed(env, &engine, &cfg2d).expect("run");
        // Caller mode: nobody ticks, the flush pays for everything.
        assert_eq!(env.metrics.overlap_bytes.get(), 0);
        assert_eq!(env.metrics.progress_ticks.get(), 0);
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Engine bookkeeping
// ---------------------------------------------------------------------------

#[test]
fn polling_initiations_retire_earlier_ops() {
    // Zero-cost model: completion instants are "now", so the poll at the
    // second initiation retires the first op, deterministically.
    let cfg = cfg(2).with_progress_mode(ProgressMode::Polling);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            env.put_async(g.with_unit(1), &[1u8; 8]).unwrap();
            env.put_async(g.with_unit(1), &[2u8; 8]).unwrap();
            assert!(env.metrics.overlap_ops.get() >= 1, "poll at initiation retired nothing");
            env.flush_all(g).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn caller_mode_flush_still_completes_everything() {
    // The engine changes who pays for completion, never whether it
    // happens: Caller-mode flushes remain a full completion barrier.
    run(cfg(2), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.local_write(g.with_unit(env.myid()), &[env.myid() as u8 + 1; 64]).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let peer = (env.myid() + 1) % 2;
        let mut got = [0u8; 64];
        env.get_async(g.with_unit(peer), &mut got).unwrap();
        assert_eq!(env.async_pending(), 1);
        env.flush(g.with_unit(peer)).unwrap();
        assert_eq!(env.async_pending(), 0);
        assert_eq!(got, [peer as u8 + 1; 64]);
        assert_eq!(env.metrics.overlap_bytes.get(), 0);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}
