//! Tests of the unified communication engine: cached segment resolution,
//! single-request vector (strided) transfers, and explicit flush batching.

use dart::apps::stencil2d::{self, Stencil2dConfig};
use dart::dart::{run, DartConfig, DartHandle, DART_TEAM_ALL};
use dart::runtime::{artifacts_dir, Engine};
use dart::testing::prop::{forall, Rng};
use std::sync::Mutex;

fn cfg(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 16, 1 << 16)
}

// ---------------------------------------------------------------------------
// Vector-path strided transfers == the per-block loop, byte for byte
// ---------------------------------------------------------------------------

#[test]
fn prop_vector_strided_get_matches_per_block_loop() {
    forall(
        "vector-get-equivalence",
        20,
        |rng| {
            let count = rng.range(1, 24);
            let block = rng.range(1, 17);
            let stride = (block + rng.below(24)) as u64;
            let seed = rng.next_u64();
            (count, block, stride, seed)
        },
        |&(count, block, stride, seed)| {
            let failed = Mutex::new(None::<String>);
            run(cfg(2), |env| {
                let g = env.team_memalloc_aligned(DART_TEAM_ALL, 4096).unwrap();
                // Unit 1 fills its segment with a deterministic random field.
                if env.myid() == 1 {
                    let mut rng = Rng::new(seed);
                    let field = rng.bytes(4096);
                    env.local_write(g.with_unit(1), &field).unwrap();
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                if env.myid() == 0 {
                    let target = g.with_unit(1);
                    let mut vector = vec![0u8; count * block];
                    let h = env
                        .get_strided(target, &mut vector, count, block, stride)
                        .unwrap();
                    env.wait(h).unwrap();
                    // The formulation the engine replaced: one op per block.
                    let mut per_block = vec![0u8; count * block];
                    let mut handles: Vec<DartHandle> = Vec::with_capacity(count);
                    for (i, chunk) in per_block.chunks_exact_mut(block).enumerate() {
                        handles.push(env.get(target.add(i as u64 * stride), chunk).unwrap());
                    }
                    env.waitall(handles).unwrap();
                    if vector != per_block {
                        *failed.lock().unwrap() = Some(format!(
                            "vector != per-block for count={count} block={block} stride={stride}"
                        ));
                    }
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                env.team_memfree(DART_TEAM_ALL, g).unwrap();
            })
            .unwrap();
            match failed.into_inner().unwrap() {
                Some(m) => Err(m),
                None => Ok(()),
            }
        },
    );
}

#[test]
fn prop_vector_strided_put_scatters_like_per_block_loop() {
    forall(
        "vector-put-equivalence",
        20,
        |rng| {
            let count = rng.range(1, 20);
            let block = rng.range(1, 13);
            let stride = (block + rng.below(16)) as u64;
            let seed = rng.next_u64();
            (count, block, stride, seed)
        },
        |&(count, block, stride, seed)| {
            let failed = Mutex::new(None::<String>);
            run(cfg(2), |env| {
                let seg = 2048usize;
                let g = env.team_memalloc_aligned(DART_TEAM_ALL, seg as u64).unwrap();
                if env.myid() == 0 {
                    let mut rng = Rng::new(seed);
                    let payload = rng.bytes(count * block);
                    let h = env
                        .put_strided(g.with_unit(1), &payload, count, block, stride)
                        .unwrap();
                    env.wait(h).unwrap();
                    // Model the scatter locally.
                    let mut want = vec![0u8; seg];
                    for i in 0..count {
                        let dst = i * stride as usize;
                        want[dst..dst + block].copy_from_slice(&payload[i * block..(i + 1) * block]);
                    }
                    let mut got = vec![0u8; seg];
                    env.get_blocking(g.with_unit(1), &mut got).unwrap();
                    if got != want {
                        *failed.lock().unwrap() = Some(format!(
                            "scatter mismatch for count={count} block={block} stride={stride}"
                        ));
                    }
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                env.team_memfree(DART_TEAM_ALL, g).unwrap();
            })
            .unwrap();
            match failed.into_inner().unwrap() {
                Some(m) => Err(m),
                None => Ok(()),
            }
        },
    );
}

#[test]
fn strided_transfer_is_one_request_one_metric_bump() {
    run(cfg(2), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 4096).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let before_gets = env.metrics.gets.get();
            let mut col = vec![0u8; 32 * 4];
            let h = env.get_strided(g.with_unit(1), &mut col, 32, 4, 64).unwrap();
            env.wait(h).unwrap();
            // 32 blocks, ONE operation booked.
            assert_eq!(env.metrics.gets.get() - before_gets, 1);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Segment cache: hit accounting + invalidation on free/destroy
// ---------------------------------------------------------------------------

#[test]
fn segment_cache_hits_after_first_resolution() {
    run(cfg(2), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 256).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let peer = (env.myid() + 1) % 2;
        let misses_before = env.metrics.cache_misses.get();
        for i in 0..50u64 {
            env.put_blocking(g.with_unit(peer).add(i % 32 * 8), &[i as u8; 8]).unwrap();
        }
        // One slow-path walk for the (team, peer, allocation) triple; the
        // other 49 ops hit the cache regardless of their offsets.
        assert_eq!(env.metrics.cache_misses.get() - misses_before, 1);
        assert!(env.metrics.cache_hits.get() >= 49);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn segment_cache_invalidated_on_memfree_and_offset_reuse() {
    run(cfg(2), |env| {
        let me = env.myid();
        let peer = (me + 1) % 2;
        let g1 = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        // Populate the cache (the entry holds the allocation's window).
        env.put_blocking(g1.with_unit(peer), &[0xAA; 8]).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        assert!(env.segment_cache_live() >= 1);
        // The free must succeed: team_memfree asserts exclusive ownership
        // of the window, so a stale cached `Rc` would make it fail.
        env.team_memfree(DART_TEAM_ALL, g1).unwrap();
        assert_eq!(env.segment_cache_live(), 0);
        // First-fit reallocation lands at the same pool offset...
        let g2 = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        assert_eq!(g2.offset, g1.offset, "expected first-fit reuse of the pool offset");
        // ...and traffic through the numerically identical pointer goes to
        // the NEW window, not a stale cached resolution.
        env.put_blocking(g2.with_unit(peer), &[0xBB; 8]).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut got = [0u8; 8];
        env.get_blocking(g2.with_unit(me), &mut got).unwrap();
        assert_eq!(got, [0xBB; 8]);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g2).unwrap();
    })
    .unwrap();
}

#[test]
fn segment_cache_invalidated_on_team_destroy() {
    run(cfg(2), |env| {
        let grp = env.group_all();
        let t = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        let g = env.team_memalloc_aligned(t, 64).unwrap();
        let peer = (env.myid() + 1) % 2;
        env.put_blocking(g.with_unit(peer), &[1; 8]).unwrap();
        env.barrier(t).unwrap();
        assert!(env.segment_cache_live() >= 1);
        // Destroy with the allocation still live and the cache warm:
        // team_destroy frees every table window under an exclusive-
        // ownership check, so a stale cached `Rc` would make it fail.
        env.team_destroy(t).unwrap();
        assert_eq!(env.segment_cache_live(), 0);
    })
    .unwrap();
}

#[test]
fn segment_cache_off_still_correct() {
    let cfg = cfg(2).with_segment_cache(false);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        let peer = (env.myid() + 1) % 2;
        env.put_blocking(g.with_unit(peer), &[9; 8]).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut got = [0u8; 8];
        env.get_blocking(g.with_unit(env.myid()), &mut got).unwrap();
        assert_eq!(got, [9; 8]);
        assert_eq!(env.metrics.cache_hits.get(), 0, "cache disabled yet hitting");
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Explicit flush batching
// ---------------------------------------------------------------------------

#[test]
fn deferred_puts_batch_under_one_flush_all() {
    run(cfg(4), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        if env.myid() == 0 {
            // Three deferred puts to three targets, ONE completion call.
            for u in 1..4 {
                env.put_async(g.with_unit(u), &[u as u8; 8]).unwrap();
            }
            env.flush_all(g).unwrap();
            assert_eq!(env.metrics.flushes.get(), 1);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() != 0 {
            let mut got = [0u8; 8];
            env.local_read(g.with_unit(env.myid()), &mut got).unwrap();
            assert_eq!(got, [env.myid() as u8; 8]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn deferred_get_completes_at_flush() {
    run(cfg(2), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.local_write(g.with_unit(env.myid()), &[env.myid() as u8 + 5; 64]).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let peer = (env.myid() + 1) % 2;
        let mut got = [0u8; 64];
        env.get_async(g.with_unit(peer), &mut got).unwrap();
        env.flush(g.with_unit(peer)).unwrap();
        assert_eq!(got, [peer as u8 + 5; 64]);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// The intra-node zero-copy fast path (shmem windows + same-node target)
// ---------------------------------------------------------------------------

/// 4 units round-robin over 2 Hermit nodes with shared-memory windows:
/// units 0 and 2 share node 0, units 1 and 3 share node 1.
fn shmem_cfg() -> DartConfig {
    DartConfig::hermit(4, 2)
        .with_pin(dart::simnet::PinPolicy::ScatterNode)
        .with_pools(1 << 16, 1 << 16)
        .with_shmem_windows(true)
}

#[test]
fn locality_fastpath_intra_node_only() {
    run(shmem_cfg(), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 256).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            // Intra-node target (unit 2): the puts take the fast path —
            // complete on issue, nothing registered with the engine.
            for i in 0..4u64 {
                env.put_async(g.with_unit(2).add(i * 8), &[0xC0 + i as u8; 8]).unwrap();
            }
            assert!(env.metrics.locality_fastpath_ops.get() > 0);
            assert_eq!(env.metrics.locality_fastpath_ops.get(), 4);
            assert_eq!(env.async_pending(), 0, "fast-path ops must not be queued");
            env.flush_all(g).unwrap(); // still legal, nothing left to wait on

            // Inter-node target (unit 1): the deferred path, fast-path
            // counter untouched.
            let before = env.metrics.locality_fastpath_ops.get();
            env.put_async(g.with_unit(1), &[0xEE; 8]).unwrap();
            env.flush_all(g).unwrap();
            assert_eq!(env.metrics.locality_fastpath_ops.get(), before);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 2 {
            let mut got = [0u8; 8];
            env.local_read(g.with_unit(2).add(8), &mut got).unwrap();
            assert_eq!(got, [0xC1; 8]);
            assert_eq!(env.metrics.locality_fastpath_ops.get(), 0, "passive side");
        }
        if env.myid() == 1 {
            let mut got = [0u8; 8];
            env.local_read(g.with_unit(1), &mut got).unwrap();
            assert_eq!(got, [0xEE; 8]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn locality_fastpath_get_completes_in_place() {
    run(shmem_cfg(), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.local_write(g.with_unit(env.myid()), &[env.myid() as u8 + 0x30; 64]).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            // Same-node get: data valid immediately, no flush needed.
            let mut got = [0u8; 64];
            env.get_async(g.with_unit(2), &mut got).unwrap();
            assert_eq!(got, [0x32; 64]);
            assert_eq!(env.metrics.locality_fastpath_ops.get(), 1);
            assert_eq!(env.async_pending(), 0);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn locality_fastpath_off_keeps_deferred_semantics() {
    run(shmem_cfg().with_locality_fastpath(false), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            env.put_async(g.with_unit(2), &[0x77; 8]).unwrap();
            assert_eq!(env.metrics.locality_fastpath_ops.get(), 0, "fast path disabled");
            env.flush_all(g).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 2 {
            let mut got = [0u8; 8];
            env.local_read(g.with_unit(2), &mut got).unwrap();
            assert_eq!(got, [0x77; 8]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn no_fastpath_without_shmem_windows() {
    // Regular windows: same-node targets still go through the deferred
    // path — the fast path is a shmem-window property, not a distance one.
    let cfg = DartConfig::hermit(2, 1).with_pools(1 << 16, 1 << 16);
    run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            env.put_async(g.with_unit(1), &[5; 8]).unwrap();
            assert_eq!(env.metrics.locality_fastpath_ops.get(), 0);
            env.flush_all(g).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// The acceptance bar: stencil2d's halo exchange, one request per neighbour
// ---------------------------------------------------------------------------

fn have_artifacts() {
    let dir = if artifacts_dir().exists() { artifacts_dir() } else { "../artifacts".into() };
    assert!(dir.exists(), "artifacts/ not found — run `make artifacts` before `cargo test`");
    std::env::set_var("DART_ARTIFACTS", &dir);
}

#[test]
fn stencil2d_issues_one_rma_op_per_neighbour_per_iteration() {
    have_artifacts();
    let steps = 6;
    let cfg2d = Stencil2dConfig::block32(2, 2, steps);
    let counts = Mutex::new(Vec::new());
    run(DartConfig::with_units(4), |env| {
        let engine = Engine::new().expect("engine");
        let r = stencil2d::run_distributed(env, &engine, &cfg2d).expect("run");
        counts.lock().unwrap().push((
            env.myid(),
            env.metrics.gets.get(),
            env.metrics.puts.get(),
            env.metrics.flushes.get(),
            env.metrics.cache_misses.get(),
            r.global_checksum,
        ));
    })
    .unwrap();
    let want = stencil2d::reference_checksum(&cfg2d);
    for &(unit, gets, puts, flushes, misses, checksum) in counts.lock().unwrap().iter() {
        // In a 2×2 unit grid every unit has exactly 2 neighbours (one row,
        // one column); the column halo is ONE vector-typed get, not one
        // get per row — so exactly 2 one-sided operations per iteration.
        assert_eq!(gets, (2 * steps) as u64, "unit {unit}: gets per run");
        assert_eq!(puts, 0, "unit {unit}: halo exchange must be get-only");
        // One flush_all completes the whole exchange phase.
        assert_eq!(flushes, steps as u64, "unit {unit}: one flush per step");
        // The dereference chain runs a bounded number of times, not O(ops):
        // self + 2 neighbours + the flush target.
        assert!(misses <= 4, "unit {unit}: {misses} slow-path resolutions");
        let rel = (checksum - want).abs() / want.abs().max(1e-12);
        assert!(rel < 1e-5, "unit {unit}: checksum {checksum} vs {want}");
    }
}
