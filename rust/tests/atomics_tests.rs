//! Property tests for the lock-free atomics hot path: exactness under
//! concurrency, CAS linearizability, element granularity of multi-element
//! accumulates, and bit-equality of the CPU-atomic fast path against the
//! modelled path — plus the kvstore's cross-backend agreement oracle.

use dart::apps::kvstore::{run_kv, KvBackend, KvConfig};
use dart::dart::DART_TEAM_ALL;
use dart::mpisim::{as_bytes_mut, ExecMode, MpiOp};
use dart::testing::prop::{forall, Rng};
use dart::testing::{world, WorldBuilder};

/// Every unit hammers one shared counter with `fetch_and_op(Sum)` of
/// random deltas; the counter must end at exactly the wrapping sum of
/// every delta issued — a single lost update breaks the equality.
#[test]
fn concurrent_fetch_and_op_sums_are_exact() {
    forall(
        "fetch_and_op-sum-exact",
        5,
        |r| (2 + r.below(7), 1 + r.below(64), r.next_u64()),
        |&(units, ops, seed)| {
            let per_unit = world(units).collect(|env| {
                let g = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
                let c0 = g.with_unit(env.team_unit_l2g(DART_TEAM_ALL, 0).unwrap());
                if env.myid() == 0 {
                    env.local_write(c0, &0u64.to_ne_bytes()).unwrap();
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                let mut rng = Rng::new(seed ^ env.myid() as u64);
                let mut mine = 0u64;
                for _ in 0..ops {
                    let d = rng.next_u64();
                    mine = mine.wrapping_add(d);
                    env.fetch_and_op(c0, d, MpiOp::Sum).unwrap();
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                let mut got = [0u8; 8];
                env.get_blocking(c0, &mut got).unwrap();
                env.barrier(DART_TEAM_ALL).unwrap();
                env.team_memfree(DART_TEAM_ALL, g).unwrap();
                (mine, u64::from_ne_bytes(got))
            });
            let total = per_unit.iter().fold(0u64, |acc, &(m, _)| acc.wrapping_add(m));
            match per_unit.iter().find(|&&(_, fin)| fin != total) {
                None => Ok(()),
                Some(&(_, fin)) => {
                    Err(format!("counter ended at {fin}, issued deltas sum to {total}"))
                }
            }
        },
    );
}

/// All units race `compare_and_swap(slot, 0, myid + 1)` on a series of
/// fresh slots. Linearizability demands exactly one winner per slot, and
/// the slot must hold precisely the winner's value.
#[test]
fn cas_crowns_exactly_one_winner_per_slot() {
    forall(
        "cas-single-winner",
        4,
        |r| (2 + r.below(7), 1 + r.below(8)),
        |&(units, rounds)| {
            let per_unit = world(units).collect(|env| {
                let g = env.team_memalloc_aligned(DART_TEAM_ALL, (rounds * 8) as u64).unwrap();
                let base = g.with_unit(env.team_unit_l2g(DART_TEAM_ALL, 0).unwrap());
                if env.myid() == 0 {
                    env.local_write(base, &vec![0u8; rounds * 8]).unwrap();
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                let mut wins = Vec::with_capacity(rounds);
                for s in 0..rounds {
                    let slot = base.add((s * 8) as u64);
                    let old = env.compare_and_swap(slot, 0u64, env.myid() as u64 + 1).unwrap();
                    wins.push(old == 0);
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                // CAS succeeds at most once per slot ever, so after the
                // barrier every slot's value is final.
                let mut cells = vec![0u64; rounds];
                env.get_blocking(base, as_bytes_mut(&mut cells)).unwrap();
                env.barrier(DART_TEAM_ALL).unwrap();
                env.team_memfree(DART_TEAM_ALL, g).unwrap();
                (wins, cells)
            });
            let mut bad = Vec::new();
            for s in 0..rounds {
                let winners: Vec<usize> = (0..units).filter(|&u| per_unit[u].0[s]).collect();
                if winners.len() != 1 {
                    bad.push(format!("slot {s}: {} winners", winners.len()));
                    continue;
                }
                let expect = winners[0] as u64 + 1;
                for (u, (_, cells)) in per_unit.iter().enumerate() {
                    if cells[s] != expect {
                        bad.push(format!(
                            "slot {s}: unit {u} read {}, winner wrote {expect}",
                            cells[s]
                        ));
                    }
                }
            }
            if bad.is_empty() {
                Ok(())
            } else {
                Err(bad.join("; "))
            }
        },
    );
}

/// Units issue overlapping multi-element `accumulate(Sum)` batches into
/// one array. Element-granularity atomicity means every single element
/// ends at its exact serial total, even where batches overlap mid-way.
#[test]
fn multi_element_accumulates_are_element_granular() {
    forall(
        "accumulate-element-granularity",
        4,
        |r| (2 + r.below(6), 4 + r.below(29), 1 + r.below(12), r.next_u64()),
        |&(units, n, batches, seed)| {
            // Serial replay of every unit's deterministic plan.
            let mut expected = vec![0u64; n];
            for u in 0..units {
                let mut rng = Rng::new(seed ^ u as u64);
                for _ in 0..batches {
                    let start = rng.below(n);
                    let len = 1 + rng.below(n - start);
                    for (j, e) in expected[start..start + len].iter_mut().enumerate() {
                        *e = e.wrapping_add((u + j) as u64 + 1);
                    }
                }
            }
            let per_unit = world(units).collect(|env| {
                let g = env.team_memalloc_aligned(DART_TEAM_ALL, (n * 8) as u64).unwrap();
                let base = g.with_unit(env.team_unit_l2g(DART_TEAM_ALL, 0).unwrap());
                if env.myid() == 0 {
                    env.local_write(base, &vec![0u8; n * 8]).unwrap();
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                let u = env.myid() as usize;
                let mut rng = Rng::new(seed ^ u as u64);
                for _ in 0..batches {
                    let start = rng.below(n);
                    let len = 1 + rng.below(n - start);
                    let src: Vec<u64> = (0..len).map(|j| (u + j) as u64 + 1).collect();
                    env.accumulate(base.add((start * 8) as u64), &src, MpiOp::Sum).unwrap();
                }
                env.flush_all(g).unwrap();
                env.barrier(DART_TEAM_ALL).unwrap();
                let mut buf = vec![0u64; n];
                env.get_blocking(base, as_bytes_mut(&mut buf)).unwrap();
                env.barrier(DART_TEAM_ALL).unwrap();
                env.team_memfree(DART_TEAM_ALL, g).unwrap();
                buf
            });
            match per_unit.iter().find(|got| **got != expected) {
                None => Ok(()),
                Some(got) => Err(format!("expected {expected:?}, got {got:?}")),
            }
        },
    );
}

/// One seeded commutative atomic mix (element `e` always gets `Sum` for
/// even `e`, `Bxor` for odd — per-element single ops keep the final state
/// interleaving-free), run once per fast-path setting. Returns the final
/// array contents and the team-total fast-path hit counter.
fn atomic_mix_contents(
    units: usize,
    n: usize,
    ops: usize,
    seed: u64,
    fastpath: bool,
) -> (Vec<u64>, u64) {
    let per_unit = world(units).shmem(true).fastpath(fastpath).collect(|env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, (n * 8) as u64).unwrap();
        let base = g.with_unit(env.team_unit_l2g(DART_TEAM_ALL, 0).unwrap());
        if env.myid() == 0 {
            env.local_write(base, &vec![0u8; n * 8]).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut rng = Rng::new(seed ^ env.myid() as u64);
        for _ in 0..ops {
            let e = rng.below(n);
            let tgt = base.add((e * 8) as u64);
            let op = if e % 2 == 0 { MpiOp::Sum } else { MpiOp::Bxor };
            let delta = rng.next_u64();
            if rng.bool() {
                env.accumulate(tgt, &[delta], op).unwrap();
            } else {
                env.fetch_and_op(tgt, delta, op).unwrap();
            }
        }
        env.flush_all(g).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut buf = vec![0u64; n];
        env.get_blocking(base, as_bytes_mut(&mut buf)).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
        (buf, env.metrics.atomic_fastpath_ops.get())
    });
    let contents = per_unit[0].0.clone();
    assert!(
        per_unit.iter().all(|(c, _)| *c == contents),
        "units disagree on final array contents"
    );
    let hits = per_unit.iter().map(|&(_, h)| h).sum();
    (contents, hits)
}

/// The intra-node CPU-atomic fast path must be bit-identical to the
/// modelled path: same seeded mix, shmem windows on, only the fast-path
/// knob differs — and the knob must actually engage (hits > 0 on, = 0
/// off).
#[test]
fn fastpath_and_modelled_path_agree_bitwise() {
    forall(
        "fastpath-bit-equality",
        3,
        |r| (2 + r.below(5), 4 + r.below(13), r.next_u64()),
        |&(units, n, seed)| {
            let (fast, fast_hits) = atomic_mix_contents(units, n, 64, seed, true);
            let (slow, slow_hits) = atomic_mix_contents(units, n, 64, seed, false);
            if fast_hits == 0 {
                return Err("fast-path run never hit the CPU-atomic fast path".into());
            }
            if slow_hits != 0 {
                return Err("modelled run hit the fast path with the knob off".into());
            }
            if fast == slow {
                Ok(())
            } else {
                Err(format!("contents diverge:\n  fast {fast:?}\n  slow {slow:?}"))
            }
        },
    );
}

fn kv_test_cfg() -> KvConfig {
    KvConfig {
        keys: 128,
        ops_per_unit: 300,
        get_percent: 60,
        zipf_exponent: 0.9,
        seed: 0x0DDB_A11,
        slots_per_unit: 256,
        locks: 16,
        flush_every: 8,
        team: DART_TEAM_ALL,
    }
}

fn kv_checksum(builder: WorldBuilder, backend: KvBackend) -> (u64, u64, u64) {
    let kv = kv_test_cfg();
    let per_unit = builder.collect(|env| {
        let report = run_kv(env, &kv, backend).unwrap();
        assert_eq!(report.ops, report.sets + report.gets, "op accounting broke");
        assert_eq!(report.ops, 8 * kv.ops_per_unit as u64);
        (report.checksum, report.atomic_fastpath_ops, report.hits)
    });
    assert!(per_unit.iter().all(|r| *r == per_unit[0]), "units disagree on the team report");
    per_unit[0]
}

/// The kvstore's oracle: all three backends — and the pooled exec mode,
/// and the shmem fast-path configuration — fill the store to the exact
/// same final contents.
#[test]
fn kvstore_backends_agree_on_final_contents() {
    let (cas, _, _) = kv_checksum(world(8), KvBackend::CasLockFree);
    let (mcs, _, _) = kv_checksum(world(8), KvBackend::McsLockPerBucket);
    let (own, _, _) = kv_checksum(world(8), KvBackend::OwnerShards);
    assert_eq!(cas, mcs, "lock-free and MCS backends disagree on final contents");
    assert_eq!(cas, own, "lock-free and owner-computes backends disagree on final contents");

    // Pooled execution must not change the answer.
    let pooled = world(8).exec(ExecMode::Pooled, 4);
    let (cas_pooled, _, _) = kv_checksum(pooled, KvBackend::CasLockFree);
    assert_eq!(cas, cas_pooled, "pooled execution changed the final contents");

    // With shmem windows on a single node, the whole run rides the
    // CPU-atomic fast path — and still agrees.
    let (cas_fast, fastpath_ops, hits) = kv_checksum(world(8).shmem(true), KvBackend::CasLockFree);
    assert_eq!(cas, cas_fast, "fast-path run changed the final contents");
    assert!(fastpath_ops > 0, "single-node shmem run never used the fast path");
    // Sanity: a 60%-GET zipfian mix against keys it also SETs hits often.
    assert!(hits > 0, "zipfian mix produced zero GET hits");
}
