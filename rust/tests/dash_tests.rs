//! Tests of the `dash` layer: pattern index-map bijectivity (property
//! tests over every variant, uneven tails included), container access
//! tiers, owner-computes algorithms, the histogram app, and the
//! redistribution acceptance bar (bit-exact BLOCKED → BLOCKCYCLIC with
//! coalescing asserted through `Metrics::dash_coalesced_runs`).

use dart::apps::histogram::{self, HistogramConfig};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::dash::{algorithms, Array, Matrix, Pattern};
use dart::mpisim::MpiOp;
use dart::testing::prop::{forall, Rng};
use std::sync::Mutex;

fn cfg(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 16, 1 << 17)
}

// ---------------------------------------------------------------------------
// Pattern properties: bijective maps, exact coverage, run partitions
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Shape {
    Blocked(usize, usize),
    Cyclic(usize, usize),
    BlockCyclic(usize, usize, usize),
    Tiled(usize, usize, usize, usize, usize, usize),
}

fn gen_shape(rng: &mut Rng) -> Shape {
    match rng.below(4) {
        // Deliberately include n < p, n % p != 0 and p == 1 tails.
        0 => Shape::Blocked(rng.range(1, 300), rng.range(1, 9)),
        1 => Shape::Cyclic(rng.range(1, 300), rng.range(1, 9)),
        2 => Shape::BlockCyclic(rng.range(1, 300), rng.range(1, 9), rng.range(1, 18)),
        _ => Shape::Tiled(
            rng.range(1, 21),
            rng.range(1, 21),
            rng.range(1, 7),
            rng.range(1, 7),
            rng.range(1, 4),
            rng.range(1, 4),
        ),
    }
}

fn build(shape: &Shape) -> Pattern {
    match *shape {
        Shape::Blocked(n, p) => Pattern::blocked(n, p).unwrap(),
        Shape::Cyclic(n, p) => Pattern::cyclic(n, p).unwrap(),
        Shape::BlockCyclic(n, p, b) => Pattern::block_cyclic(n, p, b).unwrap(),
        Shape::Tiled(r, c, tr, tc, pr, pc) => Pattern::tiled(r, c, tr, tc, pr, pc).unwrap(),
    }
}

#[test]
fn prop_pattern_maps_are_bijective_and_cover_exactly_once() {
    forall("pattern-bijective", 400, gen_shape, |shape| {
        let pat = build(shape);
        let (n, p) = (pat.len(), pat.nunits());
        let extents: Vec<usize> = (0..p).map(|u| pat.local_extent(u)).collect();
        if extents.iter().sum::<usize>() != n {
            return Err(format!("extents {extents:?} do not sum to n={n}"));
        }
        if pat.max_local_extent() != extents.iter().copied().max().unwrap_or(0) {
            return Err("max_local_extent disagrees with the extents".into());
        }
        let mut seen: Vec<Vec<bool>> = extents.iter().map(|&e| vec![false; e]).collect();
        for g in 0..n {
            let (u, l) = pat.global_to_local(g);
            if u >= p {
                return Err(format!("g={g} mapped to unit {u} ≥ {p}"));
            }
            if l >= extents[u] {
                return Err(format!("g={g} mapped beyond unit {u}'s extent {}", extents[u]));
            }
            if seen[u][l] {
                return Err(format!("slot ({u},{l}) hit twice (at g={g})"));
            }
            seen[u][l] = true;
            if pat.local_to_global(u, l) != g {
                return Err(format!("inverse broken: g={g} → ({u},{l}) → {}",
                    pat.local_to_global(u, l)));
            }
        }
        // Every slot hit exactly once ⇒ with the extent sum above this is
        // a bijection onto [0, n).
        if seen.iter().any(|unit| unit.iter().any(|&s| !s)) {
            return Err("some local slot never hit".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pattern_runs_partition_any_subrange() {
    forall("pattern-runs", 300, gen_shape, |shape| {
        let pat = build(shape);
        let n = pat.len();
        // A deterministic, shape-dependent subrange (plus the full range).
        for (start, len) in [(0, n), (n / 3, n - n / 3 - n / 5)] {
            if len == 0 {
                continue;
            }
            let mut g = start;
            for run in pat.runs(start, len) {
                if run.len == 0 {
                    return Err("zero-length run".into());
                }
                if run.global != g {
                    return Err(format!("runs skipped from {g} to {}", run.global));
                }
                for k in 0..run.len {
                    let (u, l) = pat.global_to_local(run.global + k);
                    if u != run.unit || l != run.local + k {
                        return Err(format!(
                            "run at g={} not contiguous on unit {} at element {k}",
                            run.global, run.unit
                        ));
                    }
                }
                g += run.len;
            }
            if g != start + len {
                return Err(format!("runs ended at {g}, want {}", start + len));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_block_iter_walks_local_storage_in_order() {
    forall("pattern-block-iter", 300, gen_shape, |shape| {
        let pat = build(shape);
        for u in 0..pat.nunits() {
            let mut l = 0;
            for run in pat.block_iter(u) {
                if run.unit != u || run.local != l {
                    return Err(format!("unit {u}: local order broken at offset {l}"));
                }
                if pat.local_to_global(u, run.local) != run.global {
                    return Err(format!("unit {u}: wrong global anchor at offset {l}"));
                }
                l += run.len;
            }
            if l != pat.local_extent(u) {
                return Err(format!(
                    "unit {u}: block_iter covered {l} of {}",
                    pat.local_extent(u)
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Containers: element access, bulk coalesced transfers, local views
// ---------------------------------------------------------------------------

#[test]
fn array_bulk_copy_in_out_roundtrip_across_patterns() {
    run(cfg(4), |env| {
        let n = 103usize; // uneven on purpose
        let pats = [
            Pattern::blocked(n, 4).unwrap(),
            Pattern::cyclic(n, 4).unwrap(),
            Pattern::block_cyclic(n, 4, 8).unwrap(),
        ];
        for pat in pats {
            let a: Array<'_, u64> = Array::new(env, DART_TEAM_ALL, pat).unwrap();
            if env.myid() == 0 {
                let data: Vec<u64> = (0..n as u64).map(|i| i * 31 + 7).collect();
                let ops = a.copy_in(0, &data).unwrap();
                assert!(ops >= 1);
            }
            env.barrier(DART_TEAM_ALL).unwrap();
            // Every unit bulk-reads a subrange...
            let mut out = vec![0u64; 50];
            a.copy_out(13, &mut out).unwrap();
            for (k, v) in out.iter().enumerate() {
                assert_eq!(*v, (13 + k) as u64 * 31 + 7);
            }
            // ...and spot-reads single elements.
            assert_eq!(a.get(42).unwrap(), 42 * 31 + 7);
            assert_eq!(a.get(n - 1).unwrap(), (n as u64 - 1) * 31 + 7);
            // Out-of-range access is an error, not a panic.
            assert!(a.get(n).is_err());
            assert!(a.copy_out(n - 1, &mut [0u64; 2]).is_err());
            env.barrier(DART_TEAM_ALL).unwrap();
            a.free().unwrap();
        }
    })
    .unwrap();
}

#[test]
fn blocked_copy_in_is_one_op_per_unit() {
    run(cfg(4), |env| {
        let n = 64usize;
        let a: Array<'_, u64> = Array::blocked(env, DART_TEAM_ALL, n).unwrap();
        if env.myid() == 0 {
            let data: Vec<u64> = (0..n as u64).collect();
            let before = env.metrics.dash_coalesced_runs.get();
            let ops = a.copy_in(0, &data).unwrap();
            // 64 elements over 4 blocked partitions → exactly 4 runs.
            assert_eq!(ops, 4);
            assert_eq!(env.metrics.dash_coalesced_runs.get() - before, 4);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        a.free().unwrap();
    })
    .unwrap();
}

#[test]
fn algorithms_fill_transform_sum_minmax_with_uneven_tails() {
    run(cfg(4), |env| {
        // n=5 over 4 units: blocked leaves unit 3 with an EMPTY partition.
        let small: Array<'_, i64> = Array::blocked(env, DART_TEAM_ALL, 5).unwrap();
        algorithms::fill(&small, 3).unwrap();
        assert_eq!(algorithms::sum(&small).unwrap(), 15);
        let n = 103usize;
        let a: Array<'_, f64> = Array::block_cyclic(env, DART_TEAM_ALL, n, 8).unwrap();
        algorithms::fill(&a, 1.0).unwrap();
        assert_eq!(algorithms::sum(&a).unwrap(), n as f64);
        // v(g) = (g - 60)² + g: unique minimum at g=60, maximum at g=0.
        algorithms::transform(&a, |g, _| {
            let d = g as f64 - 60.0;
            d * d + g as f64
        })
        .unwrap();
        let (min_at, min_v) = algorithms::min_element(&a).unwrap();
        assert_eq!(min_at, 60);
        assert_eq!(min_v, 60.0);
        let (max_at, max_v) = algorithms::max_element(&a).unwrap();
        assert_eq!(max_at, 0);
        assert_eq!(max_v, 3600.0);
        // NaN must never beat real values — even as the very first local
        // element of the lowest-indexed unit (g=0), where a naive
        // candidate scan would let it poison every comparison.
        a.put(0, f64::NAN).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let (min_at, min_v) = algorithms::min_element(&a).unwrap();
        assert_eq!((min_at, min_v), (60, 60.0));
        let (max_at, max_v) = algorithms::max_element(&a).unwrap();
        assert_eq!((max_at, max_v), (1, 3482.0)); // (1-60)² + 1
        env.barrier(DART_TEAM_ALL).unwrap();
        a.free().unwrap();
        small.free().unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Matrix on a TILED pattern: dims, element access, halo accessors
// ---------------------------------------------------------------------------

#[test]
fn matrix_tiled_access_local_dims_and_halo_gets() {
    run(cfg(4), |env| {
        let (rows, cols) = (10usize, 14usize); // ragged 3×4 tiles on a 2×2 grid
        let m: Matrix<'_, i64> = Matrix::new(env, DART_TEAM_ALL, rows, cols, 3, 4, 2, 2).unwrap();
        let me = env.team_myid(DART_TEAM_ALL).unwrap();
        let pat = *m.pattern();
        m.with_local(|local| {
            for (l, v) in local.iter_mut().enumerate() {
                let g = pat.local_to_global(me, l);
                *v = ((g / cols) * 100 + g % cols) as i64;
            }
        })
        .unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        // Dense local matrices tile the global one exactly.
        assert_eq!(m.local_rows() * m.local_cols(), pat.local_extent(me));
        let cells = [(m.local_rows() * m.local_cols()) as u64];
        let mut total = [0u64];
        env.allreduce(DART_TEAM_ALL, &cells, &mut total, MpiOp::Sum).unwrap();
        assert_eq!(total[0], (rows * cols) as u64);
        // Element reads across the whole matrix, any owner.
        for i in 0..rows {
            for j in 0..cols {
                assert_eq!(m.get(i, j).unwrap(), (i * 100 + j) as i64, "at ({i},{j})");
            }
        }
        // Halo shapes: a row segment inside one tile (ONE get)...
        let mut row = vec![0i64; 4];
        m.get_row_async(3, 4, &mut row).unwrap();
        m.flush().unwrap();
        assert_eq!(row, vec![304, 305, 306, 307]);
        // ...and a column segment inside one tile (ONE strided get).
        let mut col = vec![0i64; 3];
        m.get_col_async(3, 5, &mut col).unwrap();
        m.flush().unwrap();
        assert_eq!(col, vec![305, 405, 505]);
        // Segments crossing a tile boundary are rejected, not split.
        let mut bad = vec![0i64; 4];
        assert!(m.get_row_async(0, 2, &mut bad).is_err());
        assert!(m.get_col_async(2, 5, &mut bad).is_err());
        env.barrier(DART_TEAM_ALL).unwrap();
        m.free().unwrap();
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Redistribution: the acceptance bar + cross-pattern stress
// ---------------------------------------------------------------------------

#[test]
fn copy_redistributes_blocked_to_blockcyclic_bit_exactly_with_coalescing() {
    let n = 1024usize;
    let blk = 16usize;
    run(cfg(4), |env| {
        let src: Array<'_, f64> = Array::blocked(env, DART_TEAM_ALL, n).unwrap();
        let dst: Array<'_, f64> = Array::block_cyclic(env, DART_TEAM_ALL, n, blk).unwrap();
        // A value with a non-trivial mantissa at every index.
        let v = |g: usize| g as f64 * 1.000000119 + 0.5;
        algorithms::transform(&src, |g, _| v(g)).unwrap();
        let runs0 = env.metrics.dash_coalesced_runs.get();
        let bytes0 = env.metrics.dash_redist_bytes.get();
        let ops = algorithms::copy(&src, &dst).unwrap();
        let issued = env.metrics.dash_coalesced_runs.get() - runs0;
        assert_eq!(ops, issued, "returned op count must match the metric");
        // Coalescing: my 256-element blocked partition moves in 16-element
        // destination runs → 16 operations, NOT 256.
        assert_eq!(issued, (n / 4 / blk) as u64);
        assert_eq!(env.metrics.dash_redist_bytes.get() - bytes0, (n / 4 * 8) as u64);
        // Team-wide: fewer one-sided ops than elements (the acceptance bar).
        let mut team_ops = [0u64];
        env.allreduce(DART_TEAM_ALL, &[issued], &mut team_ops, MpiOp::Sum).unwrap();
        assert_eq!(team_ops[0], (n / blk) as u64);
        assert!(team_ops[0] < n as u64);
        // Bit-exact: every unit audits its own destination partition.
        let me = env.team_myid(DART_TEAM_ALL).unwrap();
        let local = dst.read_local().unwrap();
        for (l, got) in local.iter().enumerate() {
            let g = dst.pattern().local_to_global(me, l);
            assert_eq!(got.to_bits(), v(g).to_bits(), "element {g} not bit-exact");
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        dst.free().unwrap();
        src.free().unwrap();
    })
    .unwrap();
}

#[test]
fn copy_redistributes_across_every_pattern_pair() {
    run(cfg(4), |env| {
        let (rows, cols) = (12usize, 16usize);
        let n = rows * cols;
        let mk = |which: usize| -> Pattern {
            match which {
                0 => Pattern::blocked(n, 4).unwrap(),
                1 => Pattern::cyclic(n, 4).unwrap(),
                2 => Pattern::block_cyclic(n, 4, 8).unwrap(),
                _ => Pattern::tiled(rows, cols, 5, 6, 2, 2).unwrap(), // ragged tiles
            }
        };
        let v = |g: usize| (g as u32).wrapping_mul(2_654_435_761).wrapping_add(97);
        for s in 0..4 {
            for d in 0..4 {
                let src: Array<'_, u32> = Array::new(env, DART_TEAM_ALL, mk(s)).unwrap();
                let dst: Array<'_, u32> = Array::new(env, DART_TEAM_ALL, mk(d)).unwrap();
                algorithms::transform(&src, |g, _| v(g)).unwrap();
                algorithms::copy(&src, &dst).unwrap();
                let me = env.team_myid(DART_TEAM_ALL).unwrap();
                let local = dst.read_local().unwrap();
                for (l, got) in local.iter().enumerate() {
                    let g = dst.pattern().local_to_global(me, l);
                    assert_eq!(*got, v(g), "pair {s}→{d}, element {g}");
                }
                env.barrier(DART_TEAM_ALL).unwrap();
                dst.free().unwrap();
                src.free().unwrap();
            }
        }
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// The histogram app end to end
// ---------------------------------------------------------------------------

#[test]
fn histogram_counts_match_sequential_reference() {
    let units = 4;
    let hcfg = HistogramConfig::quick(97, 500);
    let reports = Mutex::new(Vec::new());
    let hc = hcfg.clone();
    run(cfg(units), |env| {
        let r = histogram::run_distributed(env, &hc).unwrap();
        reports.lock().unwrap().push(r);
    })
    .unwrap();
    let want = histogram::reference_counts(units, &hcfg);
    let want_total: u64 = want.iter().sum();
    assert_eq!(want_total, (units * 500) as u64);
    let want_checksum: u64 = want.iter().enumerate().map(|(i, c)| i as u64 * c).sum();
    let mut want_modal = (0usize, want[0]);
    for (i, &c) in want.iter().enumerate() {
        if c > want_modal.1 {
            want_modal = (i, c);
        }
    }
    let reports = reports.into_inner().unwrap();
    assert_eq!(reports.len(), units);
    for r in reports {
        assert_eq!(r.total, want_total);
        assert_eq!(r.checksum, want_checksum);
        assert_eq!(r.modal_bin, want_modal);
    }
}
