//! Cross-module DART integration scenarios: overlapping teams, allocator
//! churn under real windows, config variants, and failure paths.

use dart::dart::{run, DartConfig, DartErr, DartGroup, GlobalPtr, DART_TEAM_ALL};
use dart::mpisim::{as_bytes, as_bytes_mut, MpiOp};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

fn cfg(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 16, 1 << 17)
}

#[test]
fn overlapping_teams_concurrent_traffic() {
    // Teams {0,1,2} and {2,3,4} share unit 2; traffic on both teams in the
    // same phase must stay isolated (separate pools + windows).
    run(cfg(5), |env| {
        let t_low = env.team_create(DART_TEAM_ALL, &DartGroup::from_units(vec![0, 1, 2])).unwrap();
        let t_high = env.team_create(DART_TEAM_ALL, &DartGroup::from_units(vec![2, 3, 4])).unwrap();
        let me = env.myid();

        let mut gs = Vec::new();
        if let Some(t) = t_low {
            let g = env.team_memalloc_aligned(t, 64).unwrap();
            let r = env.team_myid(t).unwrap();
            let next = env.team_unit_l2g(t, (r + 1) % 3).unwrap();
            env.put_blocking(g.with_unit(next), &[0xA0; 8]).unwrap();
            gs.push((t, g));
        }
        if let Some(t) = t_high {
            let g = env.team_memalloc_aligned(t, 64).unwrap();
            let r = env.team_myid(t).unwrap();
            let next = env.team_unit_l2g(t, (r + 1) % 3).unwrap();
            env.put_blocking(g.with_unit(next), &[0xB; 8]).unwrap();
            gs.push((t, g));
        }
        for (t, _) in &gs {
            env.barrier(*t).unwrap();
        }
        // Unit 2 is in both teams and must see both values, in the right
        // allocations.
        if me == 2 {
            assert_eq!(gs.len(), 2);
            for (i, (_, g)) in gs.iter().enumerate() {
                let mut buf = [0u8; 8];
                env.get_blocking(g.with_unit(2), &mut buf).unwrap();
                let want = if i == 0 { 0xA0 } else { 0xB };
                assert_eq!(buf, [want; 8]);
            }
        }
        for (t, g) in gs {
            env.barrier(t).unwrap();
            env.team_memfree(t, g).unwrap();
            env.team_destroy(t).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn non_collective_alloc_churn_with_traffic() {
    // Alloc/free cycles with live cross-unit puts between them: the
    // free-list must recycle offsets without corrupting live allocations.
    run(cfg(2), |env| {
        let me = env.myid();
        let mut live: Vec<(GlobalPtr, u8)> = Vec::new();
        for round in 0..10u8 {
            let g = env.memalloc(128).unwrap();
            env.local_write(g, &[round; 128]).unwrap();
            live.push((g, round));
            if round % 3 == 2 {
                let (old, _) = live.remove(0);
                env.memfree(old).unwrap();
            }
            // Survivors intact?
            for (g, tag) in &live {
                let mut buf = [0u8; 128];
                env.local_read(*g, &mut buf).unwrap();
                assert_eq!(buf, [*tag; 128], "round {round}");
            }
        }
        // Cross-unit read of the peer's newest allocation (exchange
        // pointers through the world allocation).
        let ex = env.team_memalloc_aligned(DART_TEAM_ALL, 16).unwrap();
        let newest = live.last().unwrap().0;
        env.put_blocking(
            ex.with_unit(me).add(0),
            &newest.to_bits().to_ne_bytes(),
        )
        .unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let peer = (me + 1) % 2;
        let mut bits = [0u8; 16];
        env.get_blocking(ex.with_unit(peer), &mut bits).unwrap();
        let peer_g = GlobalPtr::from_bits(u128::from_ne_bytes(bits));
        let mut buf = [0u8; 128];
        env.get_blocking(peer_g, &mut buf).unwrap();
        assert_eq!(buf, [live.last().unwrap().1; 128]);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, ex).unwrap();
        for (g, _) in live {
            env.memfree(g).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn indexed_teamlist_variant_full_suite() {
    // The ablation-A2 configuration must behave identically.
    let mut c = cfg(4);
    c.indexed_teamlist = true;
    run(c, |env| {
        let grp = DartGroup::from_units(vec![0, 2]);
        let t = env.team_create(DART_TEAM_ALL, &grp).unwrap();
        if let Some(t) = t {
            let g = env.team_memalloc_aligned(t, 32).unwrap();
            let r = env.team_myid(t).unwrap();
            env.put_blocking(g.with_unit(env.myid()), &[r as u8 + 1; 4]).unwrap();
            env.barrier(t).unwrap();
            let other = env.team_unit_l2g(t, (r + 1) % 2).unwrap();
            let mut buf = [0u8; 4];
            env.get_blocking(g.with_unit(other), &mut buf).unwrap();
            assert_eq!(buf, [((r + 1) % 2) as u8 + 1; 4]);
            env.barrier(t).unwrap();
            env.team_memfree(t, g).unwrap();
            env.team_destroy(t).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
    })
    .unwrap();
}

#[test]
fn pool_exhaustion_reports_oom_and_recovers() {
    run(cfg(2), |env| {
        // team pool is 128 KiB; exhaust it.
        let a = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 16).unwrap();
        let b = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 16).unwrap();
        match env.team_memalloc_aligned(DART_TEAM_ALL, 8) {
            Err(DartErr::OutOfMemory { .. }) => {}
            other => panic!("expected OOM, got {other:?}"),
        }
        env.team_memfree(DART_TEAM_ALL, b).unwrap();
        let c = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 12).unwrap();
        env.team_memfree(DART_TEAM_ALL, c).unwrap();
        env.team_memfree(DART_TEAM_ALL, a).unwrap();
    })
    .unwrap();
}

#[test]
fn accumulate_across_teams() {
    run(cfg(4), |env| {
        let evens = env.team_create(DART_TEAM_ALL, &DartGroup::from_units(vec![0, 2])).unwrap();
        // World-level counter accumulated by everyone, team-level by evens.
        let wc = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        env.accumulate(wc.with_unit(0), &[1i64], MpiOp::Sum).unwrap();
        if let Some(t) = evens {
            let tc = env.team_memalloc_aligned(t, 8).unwrap();
            let owner = env.team_unit_l2g(t, 0).unwrap();
            env.accumulate(tc.with_unit(owner), &[10i64], MpiOp::Sum).unwrap();
            env.barrier(t).unwrap();
            if env.team_myid(t).unwrap() == 0 {
                let mut v = [0i64];
                env.get_blocking_typed(tc.with_unit(owner), &mut v).unwrap();
                assert_eq!(v[0], 20);
            }
            env.barrier(t).unwrap();
            env.team_memfree(t, tc).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let mut v = [0i64];
            env.get_blocking_typed(wc.with_unit(0), &mut v).unwrap();
            assert_eq!(v[0], 4);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, wc).unwrap();
        if let Some(t) = evens {
            env.team_destroy(t).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn locks_on_subteams() {
    // A lock on a sub-team synchronizes only its members; outsiders make
    // progress freely.
    let outside_progress = AtomicUsize::new(0);
    run(cfg(4), |env| {
        let grp = DartGroup::from_units(vec![1, 3]);
        let t = env.team_create(DART_TEAM_ALL, &grp).unwrap();
        if let Some(t) = t {
            let lock = env.lock_init(t).unwrap();
            let counter = env.team_memalloc_aligned(t, 8).unwrap();
            let owner = env.team_unit_l2g(t, 0).unwrap();
            for _ in 0..20 {
                env.lock_acquire(&lock).unwrap();
                let mut v = [0i64];
                env.get_blocking_typed(counter.with_unit(owner), &mut v).unwrap();
                v[0] += 1;
                env.put_blocking_typed(counter.with_unit(owner), &v).unwrap();
                env.lock_release(&lock).unwrap();
            }
            env.barrier(t).unwrap();
            if env.team_myid(t).unwrap() == 0 {
                let mut v = [0i64];
                env.get_blocking_typed(counter.with_unit(owner), &mut v).unwrap();
                assert_eq!(v[0], 40);
            }
            env.barrier(t).unwrap();
            env.lock_free(lock).unwrap();
            env.team_memfree(t, counter).unwrap();
        } else {
            outside_progress.fetch_add(1, Ordering::SeqCst);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if let Some(t) = t {
            env.team_destroy(t).unwrap();
        }
    })
    .unwrap();
    assert_eq!(outside_progress.load(Ordering::SeqCst), 2);
}

#[test]
fn collectives_typed_roundtrips() {
    run(cfg(4), |env| {
        // reduce to a non-zero root
        let mine = [env.myid() as f64, 1.0];
        let mut out = [0f64; 2];
        env.reduce(DART_TEAM_ALL, &mine, &mut out, MpiOp::Sum, 2).unwrap();
        if env.team_myid(DART_TEAM_ALL).unwrap() == 2 {
            assert_eq!(out, [6.0, 4.0]);
        }
        // scatter from root 1
        let send: Vec<u8> = if env.myid() == 1 { (0..8).collect() } else { vec![] };
        let mut mine2 = [0u8; 2];
        env.scatter(DART_TEAM_ALL, &send, &mut mine2, 1).unwrap();
        assert_eq!(mine2, [2 * env.myid() as u8, 2 * env.myid() as u8 + 1]);
        // alltoall
        let me = env.myid() as u8;
        let send3: Vec<u8> = (0..4).flat_map(|j| [me, j]).collect();
        let mut recv3 = vec![0u8; 8];
        env.alltoall(DART_TEAM_ALL, &send3, &mut recv3, 2).unwrap();
        for src in 0..4 {
            assert_eq!(&recv3[src * 2..src * 2 + 2], &[src as u8, me]);
        }
    })
    .unwrap();
}

#[test]
fn hermit_cost_model_full_stack() {
    // The whole DART stack under the calibrated cost model: correctness is
    // unchanged, and inter-node blocking puts are slower than intra-NUMA.
    let times = Mutex::new(Vec::new());
    run(DartConfig::hermit(2, 2).with_pools(1 << 14, 1 << 14), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 4096).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let buf = [1u8; 256];
            let mut best = f64::INFINITY;
            for _ in 0..30 {
                let t = std::time::Instant::now();
                env.put_blocking(g.with_unit(1), &buf).unwrap();
                best = best.min(t.elapsed().as_nanos() as f64);
            }
            times.lock().unwrap().push(best);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    let intra = times.into_inner().unwrap()[0];
    // intra-NUMA baseline ≈ 350ns modelled latency; must be visible.
    assert!(intra > 250.0, "cost model not applied: {intra}ns");
}

#[test]
fn group_api_and_team_round_trip_every_subset() {
    // For a 4-unit world, EVERY non-empty subset forms a working team.
    run(cfg(4), |env| {
        for mask in 1u32..16 {
            let members: Vec<i32> = (0..4).filter(|u| mask & (1 << u) != 0).collect();
            let grp = DartGroup::from_units(members.clone());
            let t = env.team_create(DART_TEAM_ALL, &grp).unwrap();
            if members.contains(&env.myid()) {
                let t = t.unwrap();
                assert_eq!(env.team_size(t).unwrap(), members.len());
                let g = env.team_get_group(t).unwrap();
                assert_eq!(g.members(), &members[..]);
                env.barrier(t).unwrap();
                env.team_destroy(t).unwrap();
            } else {
                assert!(t.is_none());
            }
        }
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// team_memalloc_aligned edge cases: the documented contract
// ---------------------------------------------------------------------------

#[test]
fn team_memalloc_zero_bytes_is_an_error_on_every_member() {
    run(cfg(3), |env| {
        // A zero-extent window has no addressable location; the documented
        // behaviour is a DartErr::Invalid on EVERY member, leaving the
        // pool untouched.
        match env.team_memalloc_aligned(DART_TEAM_ALL, 0) {
            Err(DartErr::Invalid(_)) => {}
            other => panic!("zero-byte alloc must fail with Invalid, got {other:?}"),
        }
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        assert_eq!(g.offset, 0, "failed zero-byte alloc must not consume pool space");
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn team_memalloc_odd_sizes_round_per_member_and_stay_symmetric() {
    use dart::dart::translation::DART_ALIGN;
    // 3 units, 5 and 13 bytes: neither a multiple of the team size nor of
    // DART_ALIGN. The documented contract: `nbytes` is PER MEMBER (never
    // divided across the team), rounded up to DART_ALIGN granularity, and
    // the pool offset is identical on every member.
    run(cfg(3), |env| {
        let a = env.team_memalloc_aligned(DART_TEAM_ALL, 5).unwrap();
        let b = env.team_memalloc_aligned(DART_TEAM_ALL, 13).unwrap();
        assert_eq!(a.offset % DART_ALIGN, 0);
        assert_eq!(b.offset % DART_ALIGN, 0);
        assert_eq!(b.offset, a.offset + 8, "5 bytes must round to one 8-byte granule");
        // Identical offsets everywhere — the aligned/symmetric property.
        let mut offs = vec![0u64; 3];
        env.allgather(DART_TEAM_ALL, &a.offset.to_ne_bytes(), as_bytes_mut(&mut offs))
            .unwrap();
        assert!(offs.iter().all(|&o| o == a.offset), "offsets diverged: {offs:?}");
        // The rounded 16-byte extent of `b` is fully addressable on every
        // member: write the tail bytes beyond the requested 13.
        let peer = (env.myid() + 1) % 3;
        env.put_blocking(b.with_unit(peer).add(8), &[0xEE; 8]).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut got = [0u8; 8];
        env.local_read(b.with_unit(env.myid()).add(8), &mut got).unwrap();
        assert_eq!(got, [0xEE; 8]);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, b).unwrap();
        env.team_memfree(DART_TEAM_ALL, a).unwrap();
    })
    .unwrap();
}
