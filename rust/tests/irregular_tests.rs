//! Irregular-workload property suite: the distributed BFS and sample
//! sort checked against their sequential oracles across seeds, unit
//! counts, and the degenerate inputs that break naive decompositions —
//! empty graphs, disconnected components, all-equal / pre-sorted /
//! reverse-sorted key streams, and inputs smaller than the team. Plus
//! the zero-extent regressions: empty `dash` patterns and arrays must be
//! legal, inert citizens of every collective algorithm.

use dart::apps::bfs::{self, BfsConfig, BfsSummary};
use dart::apps::samplesort::{self, KeyDist, SortConfig};
use dart::dart::{run, DartConfig, DART_TEAM_ALL};
use dart::dash::{algorithms, Array, GraphConfig, Pattern};
use dart::testing::prop::Rng;
use std::sync::Mutex;

fn cfg(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 18, 1 << 20)
}

/// The sweep's seed list — deterministic, ≥ 10 seeds per oracle sweep.
fn sweep_seeds(n: usize) -> Vec<u64> {
    let mut rng = Rng::new(0x1AAE_607A_7E57);
    (0..n).map(|_| rng.next_u64()).collect()
}

// ---------------------------------------------------------------------------
// BFS: oracle sweeps
// ---------------------------------------------------------------------------

/// Ten seeded R-MAT graphs, each traversed flat and with intra-node
/// combining, every unit's levels audited in `run_checked`: parent edges
/// must exist, levels must be exactly the oracle's BFS distances, and
/// unreached vertices must stay unclaimed.
#[test]
fn bfs_matches_oracle_across_seeds() {
    run(cfg(4), |env| {
        for seed in sweep_seeds(10) {
            for combine in [false, true] {
                let mut bfs = BfsConfig::quick(5, 4, seed);
                bfs.combine = combine;
                let report = bfs::run_checked(env, &bfs).unwrap();
                assert_eq!(report.summary, bfs::reference_summary(&bfs));
                assert!(report.summary.reached >= 1, "root must reach itself");
            }
        }
    })
    .unwrap();
}

/// The level summary is a pure function of the graph seed — the world
/// size (including the degenerate 1-unit world and a count that does not
/// divide the vertex count) must be invisible.
#[test]
fn bfs_agrees_across_unit_counts() {
    let bfs = BfsConfig::quick(5, 4, 0x5CA1_AB1E);
    let oracle = bfs::reference_summary(&bfs);
    for units in [1usize, 2, 5, 8] {
        let out: Mutex<Option<BfsSummary>> = Mutex::new(None);
        run(cfg(units), |env| {
            let report = bfs::run_checked(env, &bfs).unwrap();
            if env.myid() == 0 {
                *out.lock().unwrap() = Some(report.summary);
            }
        })
        .unwrap();
        let got = out.into_inner().unwrap().expect("unit 0 captured no summary");
        assert_eq!(got, oracle, "{units}-unit world diverged from the oracle");
    }
}

/// `edge_factor: 0` produces a graph with no edges at all — the empty
/// adjacency array (a zero-length BLOCKED pattern) must build, and the
/// traversal must reach exactly the root at level 0.
#[test]
fn bfs_handles_an_edgeless_graph() {
    run(cfg(4), |env| {
        let bfs = BfsConfig {
            graph: GraphConfig { scale: 4, edge_factor: 0, seed: 7 },
            root: 5,
            combine: false,
            team: DART_TEAM_ALL,
        };
        let report = bfs::run_checked(env, &bfs).unwrap();
        assert_eq!(report.summary.reached, 1, "only the root is reachable");
        assert_eq!(report.summary.max_level, 0);
        assert_eq!(report.nedges_stored, 0);
    })
    .unwrap();
}

/// Sparse R-MAT graphs are disconnected: the sweep must include at least
/// one graph whose traversal leaves vertices unreached, and `run_checked`
/// must still pass on every one (unreached ⇒ parent stays -1).
#[test]
fn bfs_handles_disconnected_components() {
    let seeds = sweep_seeds(4);
    let disconnected = seeds.iter().any(|&seed| {
        let bfs = BfsConfig::quick(6, 1, seed);
        bfs::reference_summary(&bfs).reached < bfs.graph.nverts() as u64
    });
    assert!(disconnected, "every sparse graph was connected — the sweep proves nothing");
    run(cfg(4), |env| {
        for seed in seeds.iter() {
            let bfs = BfsConfig::quick(6, 1, *seed);
            let report = bfs::run_checked(env, &bfs).unwrap();
            assert_eq!(report.summary, bfs::reference_summary(&bfs));
        }
    })
    .unwrap();
}

// ---------------------------------------------------------------------------
// Sample sort: oracle sweeps
// ---------------------------------------------------------------------------

/// Ten seeded uniform key streams through the full pipeline, every
/// unit's output partition audited against `sort_unstable` on the same
/// stream in `run_checked`.
#[test]
fn sort_matches_oracle_across_seeds() {
    run(cfg(4), |env| {
        for seed in sweep_seeds(10) {
            let sort = SortConfig::quick(256, seed);
            let report = samplesort::run_checked(env, &sort).unwrap();
            assert!(report.sorted_ok);
            assert_eq!(report.checksum_in, report.checksum_out);
            assert_eq!(report.count, 256);
        }
    })
    .unwrap();
}

/// The degenerate key distributions: heavy duplicates (empty buckets),
/// all keys equal (every element lands in bucket 0), already sorted, and
/// reverse sorted. Each must survive splitter selection and produce the
/// oracle's permutation.
#[test]
fn sort_handles_degenerate_key_distributions() {
    run(cfg(4), |env| {
        for dist in [KeyDist::Skewed, KeyDist::AllEqual, KeyDist::Sorted, KeyDist::Reverse] {
            for seed in [0x0DD5_EED5u64, 0xFACE_0FF5] {
                let sort = SortConfig { n: 240, seed, dist, oversample: 4, team: DART_TEAM_ALL };
                let report = samplesort::run_checked(env, &sort).unwrap();
                assert!(report.sorted_ok, "{dist:?}: not sorted");
                assert_eq!(report.checksum_in, report.checksum_out, "{dist:?}: not a permutation");
            }
        }
    })
    .unwrap();
}

/// Inputs smaller than the team — including the empty input, whose
/// every decomposition (input, buckets, output) is a zero-length
/// pattern — must sort without a special case.
#[test]
fn sort_handles_inputs_smaller_than_the_team() {
    run(cfg(4), |env| {
        for n in [0usize, 1, 3, 5] {
            let sort = SortConfig::quick(n, 0x7E57_5EED);
            let report = samplesort::run_checked(env, &sort).unwrap();
            assert!(report.sorted_ok, "n={n}: not sorted");
            assert_eq!(report.count, n as u64, "n={n}: wrong key count");
            assert_eq!(report.checksum_in, report.checksum_out, "n={n}: not a permutation");
        }
    })
    .unwrap();
}

/// The unit-count axis is invisible to the output: the same key stream
/// sorted by 1, 2, 5, and 8 units lands every key at the same global
/// position (bit-identical position checksum, audited per-unit).
#[test]
fn sort_agrees_across_unit_counts() {
    let sort = SortConfig::quick(300, 0xC0C0_A5EED);
    let (multiset, position) = samplesort::reference_checksums(&sort);
    for units in [1usize, 2, 5, 8] {
        let out: Mutex<Option<(u64, u64)>> = Mutex::new(None);
        run(cfg(units), |env| {
            let report = samplesort::run_checked(env, &sort).unwrap();
            if env.myid() == 0 {
                *out.lock().unwrap() = Some((report.checksum_out, report.position_checksum));
            }
        })
        .unwrap();
        let got = out.into_inner().unwrap().expect("unit 0 captured no checksums");
        assert_eq!(got, (multiset, position), "{units}-unit world diverged from the oracle");
    }
}

// ---------------------------------------------------------------------------
// Zero-extent regressions: empty patterns and arrays are legal and inert
// ---------------------------------------------------------------------------

/// The sharp edge the sort's empty buckets exposed: zero-length
/// distributions must construct, report themselves empty, and behave as
/// no-ops in the access tiers and collective algorithms instead of
/// erroring at `Pattern::new`.
#[test]
fn empty_arrays_are_legal_and_inert() {
    run(cfg(4), |env| {
        let a: Array<'_, u64> = Array::blocked(env, DART_TEAM_ALL, 0).unwrap();
        assert!(a.is_empty());
        assert_eq!(a.len(), 0);
        assert_eq!(a.local_len(), 0);
        assert_eq!(a.read_local().unwrap(), Vec::<u64>::new());
        // Element access out of an empty domain is an error, not a panic.
        assert!(a.get(0).is_err());
        // Zero-length bulk transfers issue zero one-sided operations.
        assert_eq!(a.copy_in(0, &[]).unwrap(), 0);
        assert_eq!(a.copy_in_async(0, &[]).unwrap(), 0);
        let mut none: [u64; 0] = [];
        assert_eq!(a.copy_out(0, &mut none).unwrap(), 0);
        // Collective algorithms: sum of nothing is zero, extremum of
        // nothing is an error (it used to panic), copy of nothing is a
        // zero-op barrier.
        assert_eq!(algorithms::sum(&a).unwrap(), 0);
        assert!(algorithms::min_element(&a).is_err());
        assert!(algorithms::max_element(&a).is_err());
        let b: Array<'_, u64> = Array::cyclic(env, DART_TEAM_ALL, 0).unwrap();
        assert_eq!(algorithms::copy(&a, &b).unwrap(), 0);
        env.barrier(DART_TEAM_ALL).unwrap();
        b.free().unwrap();
        a.free().unwrap();
    })
    .unwrap();
}

/// Redistribution with fewer elements than units: some units hold a
/// zero-length partition and must participate only in the barriers while
/// the data still lands bit-exactly.
#[test]
fn copy_redistributes_with_zero_extent_units() {
    run(cfg(4), |env| {
        let me = env.team_myid(DART_TEAM_ALL).unwrap();
        for n in [1usize, 2, 3] {
            let src: Array<'_, u64> =
                Array::new(env, DART_TEAM_ALL, Pattern::blocked(n, 4).unwrap()).unwrap();
            let dst: Array<'_, u64> =
                Array::new(env, DART_TEAM_ALL, Pattern::cyclic(n, 4).unwrap()).unwrap();
            algorithms::transform(&src, |g, _| (g as u64 + 1) * 0x9E37).unwrap();
            algorithms::copy(&src, &dst).unwrap();
            let local = dst.read_local().unwrap();
            for (l, got) in local.iter().enumerate() {
                let g = dst.pattern().local_to_global(me, l);
                assert_eq!(*got, (g as u64 + 1) * 0x9E37, "n={n}, element {g}");
            }
            env.barrier(DART_TEAM_ALL).unwrap();
            dst.free().unwrap();
            src.free().unwrap();
        }
    })
    .unwrap();
}
