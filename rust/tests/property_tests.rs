//! Property-based tests over the runtime's core invariants, using the
//! in-repo `dart::testing::prop` framework (seeded, reproducible).

use dart::dart::group::DartGroup;
use dart::dart::translation::{FreeListAllocator, DART_ALIGN};
use dart::dart::{DartConfig, GlobalPtr, DART_TEAM_ALL};
use dart::mpisim::Group as MpiGroup;
use dart::testing::prop::{forall, Rng};
use std::collections::BTreeSet;
use std::sync::Mutex;

// ---------------------------------------------------------------------------
// DART groups: sortedness + set semantics under random op sequences
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum GroupOp {
    Add(i32),
    Del(i32),
    UnionWith(Vec<i32>),
    IntersectWith(Vec<i32>),
}

fn gen_group_ops(rng: &mut Rng) -> Vec<GroupOp> {
    let n_ops = rng.range(1, 40);
    (0..n_ops)
        .map(|_| match rng.below(4) {
            0 => GroupOp::Add(rng.below(32) as i32),
            1 => GroupOp::Del(rng.below(32) as i32),
            2 => GroupOp::UnionWith(rng.subset(32).into_iter().map(|u| u as i32).collect()),
            _ => GroupOp::IntersectWith(rng.subset(32).into_iter().map(|u| u as i32).collect()),
        })
        .collect()
}

#[test]
fn prop_group_matches_set_model_and_stays_sorted() {
    let world = MpiGroup::new((0..32).collect());
    forall("group-set-model", 300, gen_group_ops, |ops| {
        let mut g = DartGroup::new();
        let mut model: BTreeSet<i32> = BTreeSet::new();
        for op in ops {
            match op {
                GroupOp::Add(u) => {
                    g.addmember(*u, &world).map_err(|e| e.to_string())?;
                    model.insert(*u);
                }
                GroupOp::Del(u) => {
                    g.delmember(*u);
                    model.remove(u);
                }
                GroupOp::UnionWith(us) => {
                    g = DartGroup::union(&g, &DartGroup::from_units(us.clone()));
                    model.extend(us.iter().copied());
                }
                GroupOp::IntersectWith(us) => {
                    g = DartGroup::intersect(&g, &DartGroup::from_units(us.clone()));
                    model = model.intersection(&us.iter().copied().collect()).copied().collect();
                }
            }
            if !g.is_sorted_invariant() {
                return Err(format!("group lost sortedness: {:?}", g.members()));
            }
        }
        let got: Vec<i32> = g.members().to_vec();
        let want: Vec<i32> = model.into_iter().collect();
        if got != want {
            return Err(format!("set model mismatch: got {got:?}, want {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_group_union_commutes_and_mpi_union_does_not_sort() {
    forall(
        "union-commutes",
        300,
        |rng| {
            let a: Vec<i32> = rng.subset(24).into_iter().map(|u| u as i32).collect();
            let b: Vec<i32> = rng.subset(24).into_iter().map(|u| u as i32).collect();
            (a, b)
        },
        |(a, b)| {
            let ga = DartGroup::from_units(a.clone());
            let gb = DartGroup::from_units(b.clone());
            let u1 = DartGroup::union(&ga, &gb);
            let u2 = DartGroup::union(&gb, &ga);
            if u1 != u2 {
                return Err(format!("DART union not commutative: {u1:?} vs {u2:?}"));
            }
            if !u1.is_sorted_invariant() {
                return Err("union output unsorted".into());
            }
            // DART splits are a partition.
            let parts = u1.split(3).map_err(|e| e.to_string())?;
            let rejoined = parts.iter().fold(DartGroup::new(), |acc, p| DartGroup::union(&acc, p));
            if rejoined != u1 {
                return Err("split/union not a partition".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Free-list allocator: model-based alloc/free with invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_allocator_never_overlaps_and_coalesces() {
    forall(
        "allocator-model",
        200,
        |rng| {
            let n_ops = rng.range(1, 60);
            (0..n_ops)
                .map(|_| (rng.bool(), rng.range(1, 600) as u64))
                .collect::<Vec<(bool, u64)>>()
        },
        |ops| {
            let mut a = FreeListAllocator::new(4096);
            let mut live: Vec<(u64, u64)> = Vec::new(); // (base, rounded len)
            for &(is_alloc, len) in ops {
                if is_alloc || live.is_empty() {
                    if let Ok(base) = a.alloc(len) {
                        let rounded = len.div_ceil(DART_ALIGN) * DART_ALIGN;
                        if base % DART_ALIGN != 0 {
                            return Err(format!("unaligned base {base}"));
                        }
                        // no overlap with anything live
                        for &(b, l) in &live {
                            if base < b + l && b < base + rounded {
                                return Err(format!(
                                    "overlap: new [{base},{}) with [{b},{})",
                                    base + rounded,
                                    b + l
                                ));
                            }
                        }
                        if base + rounded > 4096 {
                            return Err("allocation beyond pool".into());
                        }
                        live.push((base, rounded));
                    }
                } else {
                    let idx = (len as usize) % live.len();
                    let (base, _) = live.swap_remove(idx);
                    a.free(base).map_err(|e| e.to_string())?;
                }
                if !a.check_invariants() {
                    return Err("allocator invariants broken".into());
                }
            }
            // Free everything → a full-size alloc must succeed (full
            // coalescing).
            for (base, _) in live.drain(..) {
                a.free(base).map_err(|e| e.to_string())?;
            }
            a.alloc(4096).map_err(|_| "full coalescing failed".to_string())?;
            Ok(())
        },
    );
}

#[test]
fn prop_allocator_deterministic_replicas() {
    // The aligned-allocation property: two members running the same
    // collective sequence get identical offsets.
    forall(
        "allocator-determinism",
        200,
        |rng| {
            let n_ops = rng.range(1, 50);
            (0..n_ops).map(|_| (rng.below(4) != 0, rng.range(1, 300) as u64)).collect::<Vec<_>>()
        },
        |ops| {
            let mut a = FreeListAllocator::new(1 << 14);
            let mut b = FreeListAllocator::new(1 << 14);
            let mut live = Vec::new();
            for &(is_alloc, len) in ops {
                if is_alloc || live.is_empty() {
                    let ra = a.alloc(len);
                    let rb = b.alloc(len);
                    match (ra, rb) {
                        (Ok(x), Ok(y)) if x == y => live.push(x),
                        (Err(_), Err(_)) => {}
                        other => return Err(format!("replicas diverged: {other:?}")),
                    }
                } else {
                    let idx = (len as usize) % live.len();
                    let base = live.swap_remove(idx);
                    a.free(base).map_err(|e| e.to_string())?;
                    b.free(base).map_err(|e| e.to_string())?;
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Global pointers
// ---------------------------------------------------------------------------

#[test]
fn prop_gptr_bits_roundtrip() {
    forall(
        "gptr-roundtrip",
        1000,
        |rng| GlobalPtr {
            unitid: rng.next_u64() as i32,
            segid: rng.next_u64() as i16,
            flags: rng.next_u64() as u16,
            offset: rng.next_u64(),
        },
        |g| {
            let back = GlobalPtr::from_bits(g.to_bits());
            if back != *g {
                return Err(format!("roundtrip: {g:?} → {back:?}"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// MPI group semantics vs DART expectations
// ---------------------------------------------------------------------------

#[test]
fn prop_mpi_translate_ranks_consistent() {
    forall(
        "translate-ranks",
        300,
        |rng| {
            let g1: Vec<usize> = rng.subset(16);
            let g2: Vec<usize> = rng.subset(16);
            (g1, g2)
        },
        |(m1, m2)| {
            let g1 = MpiGroup::new(m1.clone());
            let g2 = MpiGroup::new(m2.clone());
            let all: Vec<usize> = (0..g1.size()).collect();
            let tr = g1.translate_ranks(&all, &g2).map_err(|e| e.to_string())?;
            for (r1, t) in all.iter().zip(&tr) {
                let world = m1[*r1];
                match t {
                    Some(r2) => {
                        if m2[*r2] != world {
                            return Err(format!("translate maps {world} to {}", m2[*r2]));
                        }
                    }
                    None => {
                        if m2.contains(&world) {
                            return Err(format!("missed member {world}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// End-to-end DART property: random symmetric put/get traffic vs a model
// ---------------------------------------------------------------------------

#[test]
fn prop_random_put_get_traffic_matches_model() {
    // Random rounds of all-units-write / barrier / all-units-read over a
    // shared symmetric allocation must behave like a plain array model.
    forall(
        "pgas-traffic",
        12,
        |rng| {
            let units = rng.range(2, 5);
            let rounds = rng.range(1, 5);
            let seed = rng.next_u64();
            (units, rounds, seed)
        },
        |&(units, rounds, seed)| {
            let failed = Mutex::new(None::<String>);
            dart::dart::run(
                DartConfig::with_units(units).with_pools(1 << 14, 1 << 14),
                |env| {
                    let slots = env.size();
                    let g = env.team_memalloc_aligned(DART_TEAM_ALL, (slots * 8) as u64).unwrap();
                    // model[u][s] mirrors unit u's slot s.
                    let mut model = vec![vec![0u64; slots]; slots];
                    let mut rng = Rng::new(seed);
                    for round in 0..rounds {
                        // Every unit writes one value into one slot of one
                        // target — the SAME schedule on every unit (SPMD),
                        // but only my own writes are issued by me.
                        for writer in 0..slots {
                            let target = rng.below(slots);
                            let slot = writer; // slot = writer ⇒ no write conflicts
                            let val = rng.next_u64() ^ (round as u64) << 32;
                            model[target][slot] = val;
                            if writer == env.myid() as usize {
                                let dst = g.with_unit(target as i32).add((slot * 8) as u64);
                                env.put_blocking(dst, &val.to_ne_bytes()).unwrap();
                            }
                        }
                        env.barrier(DART_TEAM_ALL).unwrap();
                        // Every unit audits one random target.
                        let audit = rng.below(slots);
                        let mut got = vec![0u64; slots];
                        env.get_blocking(
                            g.with_unit(audit as i32),
                            dart::mpisim::as_bytes_mut(&mut got),
                        )
                        .unwrap();
                        if got != model[audit] {
                            *failed.lock().unwrap() = Some(format!(
                                "unit {} round {round}: target {audit} holds {got:?}, want {:?}",
                                env.myid(),
                                model[audit]
                            ));
                        }
                        env.barrier(DART_TEAM_ALL).unwrap();
                    }
                    env.team_memfree(DART_TEAM_ALL, g).unwrap();
                },
            )
            .unwrap();
            match failed.into_inner().unwrap() {
                Some(msg) => Err(msg),
                None => Ok(()),
            }
        },
    );
}

// ---------------------------------------------------------------------------
// mpisim collectives vs plain-array models, random shapes
// ---------------------------------------------------------------------------

#[test]
fn prop_collectives_match_models() {
    use dart::mpisim::{as_bytes, as_bytes_mut, MpiOp, MpiType, World, WorldConfig};
    forall(
        "collectives-model",
        15,
        |rng| {
            let units = rng.range(1, 7);
            let elems = rng.range(1, 33);
            let seed = rng.next_u64();
            (units, elems, seed)
        },
        |&(units, elems, seed)| {
            let failed = Mutex::new(None::<String>);
            World::run(WorldConfig::local(units), |mpi| {
                let c = mpi.comm_world();
                let mut rng = Rng::new(seed ^ 0xC011);
                // Same pseudo-random matrix on every rank (SPMD).
                let data: Vec<Vec<i64>> = (0..units)
                    .map(|_| (0..elems).map(|_| rng.next_u64() as i64 % 1000).collect())
                    .collect();
                let mine = &data[c.rank()];

                // allreduce(sum) == column sums
                let mut sum = vec![0i64; elems];
                c.allreduce(as_bytes(mine), as_bytes_mut(&mut sum), MpiOp::Sum, MpiType::I64)
                    .unwrap();
                let want: Vec<i64> =
                    (0..elems).map(|j| data.iter().map(|r| r[j]).sum()).collect();
                if sum != want {
                    *failed.lock().unwrap() = Some(format!("allreduce: {sum:?} != {want:?}"));
                }

                // allgather == concatenation in rank order
                let mut all = vec![0i64; units * elems];
                c.allgather(as_bytes(mine), as_bytes_mut(&mut all)).unwrap();
                let flat: Vec<i64> = data.iter().flatten().copied().collect();
                if all != flat {
                    *failed.lock().unwrap() = Some("allgather mismatch".into());
                }

                // scan(max) == running column max over ranks 0..=me
                let mut scanned = vec![0i64; elems];
                c.scan(as_bytes(mine), as_bytes_mut(&mut scanned), MpiOp::Max, MpiType::I64)
                    .unwrap();
                let want: Vec<i64> = (0..elems)
                    .map(|j| data[..=c.rank()].iter().map(|r| r[j]).max().unwrap())
                    .collect();
                if scanned != want {
                    *failed.lock().unwrap() = Some("scan mismatch".into());
                }

                // bcast from a random (but SPMD-agreed) root
                let root = (seed as usize) % units;
                let mut b = if c.rank() == root { data[root].clone() } else { vec![0; elems] };
                c.bcast(as_bytes_mut(&mut b), root).unwrap();
                if b != data[root] {
                    *failed.lock().unwrap() = Some("bcast mismatch".into());
                }
            });
            match failed.into_inner().unwrap() {
                Some(m) => Err(m),
                None => Ok(()),
            }
        },
    );
}

#[test]
fn prop_alltoall_is_transpose() {
    use dart::mpisim::{World, WorldConfig};
    forall(
        "alltoall-transpose",
        10,
        |rng| (rng.range(1, 7), rng.range(1, 9)),
        |&(units, chunk)| {
            let failed = Mutex::new(None::<String>);
            World::run(WorldConfig::local(units), |mpi| {
                let c = mpi.comm_world();
                let me = c.rank() as u8;
                let send: Vec<u8> =
                    (0..units).flat_map(|j| vec![me ^ j as u8; chunk]).collect();
                let mut recv = vec![0u8; units * chunk];
                c.alltoall(&send, &mut recv, chunk).unwrap();
                for src in 0..units {
                    let want = vec![src as u8 ^ me; chunk];
                    if &recv[src * chunk..(src + 1) * chunk] != want.as_slice() {
                        *failed.lock().unwrap() =
                            Some(format!("rank {me}: chunk from {src} wrong"));
                    }
                }
            });
            match failed.into_inner().unwrap() {
                Some(m) => Err(m),
                None => Ok(()),
            }
        },
    );
}

#[test]
fn prop_team_create_destroy_sequences_preserve_registry() {
    // Random create/destroy interleavings: live teams always resolvable,
    // destroyed teams never, ids strictly increasing.
    forall(
        "team-lifecycle",
        10,
        |rng| (rng.range(2, 5), rng.next_u64()),
        |&(units, seed)| {
            let failed = Mutex::new(None::<String>);
            dart::dart::run(
                DartConfig::with_units(units).with_pools(1 << 14, 1 << 14),
                |env| {
                    let mut rng = Rng::new(seed);
                    let grp = env.group_all();
                    let mut live = Vec::new();
                    let mut max_id = DART_TEAM_ALL;
                    for _ in 0..12 {
                        // Same SPMD decision everywhere.
                        if rng.bool() || live.is_empty() {
                            let t = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
                            if t <= max_id {
                                *failed.lock().unwrap() =
                                    Some(format!("id {t} not increasing (max {max_id})"));
                            }
                            max_id = t;
                            live.push(t);
                        } else {
                            let idx = rng.below(live.len());
                            let t = live.swap_remove(idx);
                            env.team_destroy(t).unwrap();
                            if env.team_myid(t).is_ok() {
                                *failed.lock().unwrap() =
                                    Some(format!("destroyed team {t} still resolves"));
                            }
                        }
                        for &t in &live {
                            if env.team_size(t).is_err() {
                                *failed.lock().unwrap() =
                                    Some(format!("live team {t} does not resolve"));
                            }
                        }
                    }
                },
            )
            .unwrap();
            match failed.into_inner().unwrap() {
                Some(msg) => Err(msg),
                None => Ok(()),
            }
        },
    );
}
