//! Stress and semantics tests of the MPI-3 substrate under concurrency —
//! the behaviours DART's correctness rests on.

use dart::mpisim::{
    as_bytes, as_bytes_mut, Group, LockKind, MpiOp, MpiType, RmaRequest, Win, World, WorldConfig,
    ANY_SOURCE,
};
use dart::simnet::{CostModel, PinPolicy, Topology};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[test]
fn p2p_flood_many_to_one_any_source() {
    // 7 senders × 50 tagged messages funneled into rank 0 via ANY_SOURCE;
    // per-pair ordering must hold even under interleaving.
    World::run(WorldConfig::local(8), |mpi| {
        let c = mpi.comm_world();
        if c.rank() == 0 {
            let mut last_seen = vec![-1i64; 8];
            for _ in 0..7 * 50 {
                let (data, st) = c.recv_vec(ANY_SOURCE, 3).unwrap();
                let seq = i64::from_ne_bytes(data.try_into().unwrap());
                assert!(seq > last_seen[st.source], "overtaking from {}", st.source);
                last_seen[st.source] = seq;
            }
        } else {
            for seq in 0..50i64 {
                c.send(&seq.to_ne_bytes(), 0, 3).unwrap();
            }
        }
    });
}

#[test]
fn collective_storm_interleaved_kinds() {
    // A long fixed program of mixed collectives on two communicators;
    // any tag/context leakage between them deadlocks or corrupts.
    World::run(WorldConfig::local(6), |mpi| {
        let world = mpi.comm_world();
        let sub = world.split(Some((mpi.world_rank() % 2) as i32), 0).unwrap().unwrap();
        for round in 0..30u64 {
            world.barrier().unwrap();
            let mut v = [round * 10 + 1];
            sub.bcast(as_bytes_mut(&mut v), 0).unwrap();
            assert_eq!(v[0], round * 10 + 1);
            let mine = [mpi.world_rank() as u64];
            let mut sum = [0u64];
            sub.allreduce(as_bytes(&mine), as_bytes_mut(&mut sum), MpiOp::Sum, MpiType::U64)
                .unwrap();
            let expect: u64 = (0..6u64).filter(|r| *r as usize % 2 == mpi.world_rank() % 2).sum();
            assert_eq!(sum[0], expect);
            let mut all = [0u64; 6];
            world.allgather(as_bytes(&mine), as_bytes_mut(&mut all)).unwrap();
            assert_eq!(all, [0, 1, 2, 3, 4, 5]);
        }
    });
}

#[test]
fn window_concurrent_disjoint_puts() {
    // Every rank owns a distinct stripe of every segment: all-to-all puts
    // with no conflicts must all land.
    const N: usize = 6;
    World::run(WorldConfig::local(N), |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, N * 8).unwrap();
        win.lock_all().unwrap();
        let me = c.rank() as u64;
        for target in 0..N {
            let val = (me << 32) | target as u64;
            win.put(&val.to_ne_bytes(), target, c.rank() * 8).unwrap();
        }
        win.flush_all().unwrap();
        c.barrier().unwrap();
        let mut mine = vec![0u64; N];
        win.read_local(0, as_bytes_mut(&mut mine)).unwrap();
        for (writer, &v) in mine.iter().enumerate() {
            assert_eq!(v, ((writer as u64) << 32) | me, "stripe from {writer}");
        }
        win.unlock_all().unwrap();
        c.barrier().unwrap();
        win.free().unwrap();
    });
}

#[test]
fn rma_request_waitall_bulk() {
    World::run(WorldConfig::local(2), |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, 1 << 16).unwrap();
        win.lock_all().unwrap();
        if c.rank() == 0 {
            let mut reqs = Vec::new();
            for i in 0..512u64 {
                let r = win.rput(&i.to_ne_bytes(), 1, (i as usize) * 8).unwrap();
                reqs.push(r);
            }
            assert!(reqs.len() == 512);
            RmaRequest::waitall(reqs);
        }
        c.barrier().unwrap();
        if c.rank() == 1 {
            let mut all = vec![0u64; 512];
            win.read_local(0, as_bytes_mut(&mut all)).unwrap();
            for (i, &v) in all.iter().enumerate() {
                assert_eq!(v, i as u64);
            }
        }
        win.unlock_all().unwrap();
        c.barrier().unwrap();
    });
}

#[test]
fn atomics_mixed_fetch_ops() {
    // Concurrent Sum/Band/Bor fetch-ops against one counter must linearize:
    // with only Sum(+1) from N ranks × K times, final == N*K, and every
    // fetched value is unique in [0, N*K).
    const N: usize = 6;
    const K: usize = 40;
    let seen = Mutex::new(vec![false; N * K]);
    World::run(WorldConfig::local(N), |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, 8).unwrap();
        win.lock_all().unwrap();
        for _ in 0..K {
            let old = win.fetch_and_op_with(1i64, 0, 0, MpiOp::Sum).unwrap();
            let mut s = seen.lock().unwrap();
            assert!(!s[old as usize], "duplicate ticket {old}");
            s[old as usize] = true;
        }
        c.barrier().unwrap();
        if c.rank() == 0 {
            let mut v = [0i64];
            win.read_local(0, as_bytes_mut(&mut v)).unwrap();
            assert_eq!(v[0], (N * K) as i64);
        }
        win.unlock_all().unwrap();
        c.barrier().unwrap();
    });
    assert!(seen.into_inner().unwrap().iter().all(|&b| b));
}

#[test]
fn exclusive_lock_blocks_shared_and_vice_versa() {
    use std::sync::atomic::AtomicI32;
    let in_exclusive = AtomicI32::new(0);
    World::run(WorldConfig::local(4), |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, 8).unwrap();
        for _ in 0..25 {
            if c.rank() % 2 == 0 {
                win.lock(LockKind::Exclusive, 0).unwrap();
                let v = in_exclusive.fetch_add(1, Ordering::SeqCst);
                assert_eq!(v, 0, "two holders inside exclusive epoch");
                in_exclusive.fetch_sub(1, Ordering::SeqCst);
                win.unlock(0).unwrap();
            } else {
                win.lock(LockKind::Shared, 0).unwrap();
                assert_eq!(in_exclusive.load(Ordering::SeqCst), 0, "shared overlaps exclusive");
                win.unlock(0).unwrap();
            }
        }
        c.barrier().unwrap();
        win.free().unwrap();
    });
}

#[test]
fn comm_create_excludes_non_members_traffic() {
    World::run(WorldConfig::local(4), |mpi| {
        let world = mpi.comm_world();
        let g = Group::new(vec![1, 2]);
        let sub = world.create_from_group(&g).unwrap();
        // Members talk on sub; outsiders blast world with the same tag.
        if let Some(sub) = sub {
            if sub.rank() == 0 {
                sub.send(b"inner", 1, 5).unwrap();
            } else {
                let (m, _) = sub.recv_vec(0, 5).unwrap();
                assert_eq!(m, b"inner");
            }
        } else {
            // rank 0 sends a decoy world message with the same tag to rank 2
            if world.rank() == 0 {
                world.send(b"decoy", 2, 5).unwrap();
            }
        }
        world.barrier().unwrap();
        // The decoy must still be in rank 2's world mailbox (not consumed
        // by the sub-communicator recv).
        if world.rank() == 2 {
            let (m, _) = world.recv_vec(0, 5).unwrap();
            assert_eq!(m, b"decoy");
        }
    });
}

#[test]
fn cost_model_shapes_latency_tiers() {
    // With the Hermit cost model, a blocking transfer inter-node must take
    // measurably longer than intra-NUMA (the simnet substitution doing its
    // job inside the full MPI stack).
    let lat = |pin: PinPolicy| -> f64 {
        let out = Mutex::new(0f64);
        let cfg = WorldConfig {
            nranks: 2,
            topology: Topology::hermit(2),
            pin,
            cost: CostModel::hermit(),
            pin_os_threads: false,
            progress: dart::mpisim::ProgressMode::Caller,
            exec: dart::mpisim::ExecMode::ThreadPerRank,
            max_os_threads: 0,
        };
        World::run(cfg, |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 4096).unwrap();
            win.lock_all().unwrap();
            c.barrier().unwrap();
            if c.rank() == 0 {
                let buf = [7u8; 512];
                let mut best = f64::INFINITY;
                for _ in 0..50 {
                    let t = std::time::Instant::now();
                    win.put(&buf, 1, 0).unwrap();
                    win.flush(1).unwrap();
                    best = best.min(t.elapsed().as_nanos() as f64);
                }
                *out.lock().unwrap() = best;
            }
            c.barrier().unwrap();
            win.unlock_all().unwrap();
        });
        out.into_inner().unwrap()
    };
    let intra = lat(PinPolicy::Block);
    let inter_numa = lat(PinPolicy::ScatterNuma);
    let inter_node = lat(PinPolicy::ScatterNode);
    assert!(intra < inter_numa, "intra {intra} !< inter-NUMA {inter_numa}");
    assert!(inter_numa < inter_node, "inter-NUMA {inter_numa} !< inter-node {inter_node}");
}

#[test]
fn e1_protocol_jump_is_measurable() {
    // DTCT(8 KiB) must exceed DTCT(4 KiB) by clearly more than the pure
    // linear bandwidth term — the Figs 8/9 jump.
    let out = Mutex::new((0f64, 0f64));
    World::run(WorldConfig::hermit(2, 1), |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, 1 << 14).unwrap();
        win.lock_all().unwrap();
        c.barrier().unwrap();
        if c.rank() == 0 {
            let mut best4 = f64::INFINITY;
            let mut best8 = f64::INFINITY;
            let b4 = vec![1u8; 4096];
            let b8 = vec![1u8; 8192];
            for _ in 0..50 {
                let t = std::time::Instant::now();
                win.put(&b4, 1, 0).unwrap();
                win.flush(1).unwrap();
                best4 = best4.min(t.elapsed().as_nanos() as f64);
                let t = std::time::Instant::now();
                win.put(&b8, 1, 0).unwrap();
                win.flush(1).unwrap();
                best8 = best8.min(t.elapsed().as_nanos() as f64);
            }
            *out.lock().unwrap() = (best4, best8);
        }
        c.barrier().unwrap();
        win.unlock_all().unwrap();
    });
    let (t4, t8) = out.into_inner().unwrap();
    // Linear growth alone would be ~4096/10 ≈ 410 ns; the E1 switch adds
    // ~900 ns + double copy ≈ 2700 ns. Require at least 3× the linear term.
    assert!(t8 - t4 > 1200.0, "no E1 jump: t4={t4} t8={t8}");
}

#[test]
fn nonblocking_channel_overlap_beats_serial_latency() {
    // 32 rputs drained by one waitall must finish well below 32 sequential
    // blocking DTCTs — the virtual-time channel models pipelining: only
    // the serialization term occupies the channel; the wire latency (the
    // dominant term for small messages) is paid once, not per op. Use the
    // inter-node tier (1.4 µs latency) so the modelled effect dominates
    // the software cost even in unoptimized builds.
    let out = Mutex::new((0f64, 0f64));
    let mut cfg = WorldConfig::hermit(2, 2);
    cfg.pin = PinPolicy::ScatterNode;
    World::run(cfg, |mpi| {
        let c = mpi.comm_world();
        let win = Win::allocate(&c, 1 << 16).unwrap();
        win.lock_all().unwrap();
        c.barrier().unwrap();
        if c.rank() == 0 {
            let buf = vec![3u8; 1024];
            // serial blocking (best of 3 to shed scheduler noise)
            let mut serial = f64::INFINITY;
            let mut overlapped = f64::INFINITY;
            for _ in 0..3 {
                let t = std::time::Instant::now();
                for _ in 0..32 {
                    win.put(&buf, 1, 0).unwrap();
                    win.flush(1).unwrap();
                }
                serial = serial.min(t.elapsed().as_nanos() as f64);
                let t = std::time::Instant::now();
                let reqs: Vec<_> = (0..32).map(|_| win.rput(&buf, 1, 0).unwrap()).collect();
                RmaRequest::waitall(reqs);
                overlapped = overlapped.min(t.elapsed().as_nanos() as f64);
            }
            *out.lock().unwrap() = (serial, overlapped);
        }
        c.barrier().unwrap();
        win.unlock_all().unwrap();
    });
    let (serial, overlapped) = out.into_inner().unwrap();
    assert!(
        overlapped < serial * 0.7,
        "no overlap benefit: serial={serial} overlapped={overlapped}"
    );
}

#[test]
fn window_free_then_reallocate_many_cycles() {
    World::run(WorldConfig::local(3), |mpi| {
        let c = mpi.comm_world();
        for cycle in 0..20u8 {
            let win = Win::allocate(&c, 256).unwrap();
            win.lock_all().unwrap();
            let next = (c.rank() + 1) % 3;
            win.put(&[cycle; 16], next, 0).unwrap();
            win.flush(next).unwrap();
            c.barrier().unwrap();
            let mut got = [0u8; 16];
            win.read_local(0, &mut got).unwrap();
            assert_eq!(got, [cycle; 16]);
            win.unlock_all().unwrap();
            win.free().unwrap();
        }
    });
}

#[test]
fn oversubscribed_world_still_correct() {
    // More ranks than modelled cores (and far more than physical cores):
    // correctness must be placement-independent.
    let sum = AtomicU64::new(0);
    let mut cfg = WorldConfig::local(12);
    cfg.topology = Topology::flat(4);
    World::run(cfg, |mpi| {
        let c = mpi.comm_world();
        let mine = [mpi.world_rank() as u64];
        let mut out = [0u64];
        c.allreduce(as_bytes(&mine), as_bytes_mut(&mut out), MpiOp::Sum, MpiType::U64).unwrap();
        assert_eq!(out[0], 66);
        sum.fetch_add(1, Ordering::SeqCst);
    });
    assert_eq!(sum.load(Ordering::SeqCst), 12);
}
