//! Integration: the Rust PJRT executor runs the real AOT artifacts and the
//! numerics match the Python reference computations.
//!
//! Requires `make artifacts` to have run (the Makefile orders this before
//! `cargo test`).

use dart::runtime::{artifacts_dir, Engine};

fn engine() -> Engine {
    // Tests run from the workspace root; fall back to ../artifacts when
    // invoked from a subdirectory.
    let dir = if artifacts_dir().exists() { artifacts_dir() } else { "../artifacts".into() };
    assert!(
        dir.exists(),
        "artifacts/ not found — run `make artifacts` before `cargo test`"
    );
    Engine::with_dir(dir).expect("PJRT CPU client")
}

/// CPU reference of the 5-point stencil step (mirrors ref.py).
fn stencil_ref(padded: &[f32], hp: usize, wp: usize, alpha: f32) -> (Vec<f32>, f32) {
    let (h, w) = (hp - 2, wp - 2);
    let at = |i: usize, j: usize| padded[i * wp + j];
    let mut out = vec![0f32; h * w];
    let mut residual = 0f64;
    for i in 0..h {
        for j in 0..w {
            let c = at(i + 1, j + 1);
            let v = c + alpha * (at(i, j + 1) + at(i + 2, j + 1) + at(i + 1, j) + at(i + 1, j + 2)
                - 4.0 * c);
            out[i * w + j] = v;
            residual += ((v - c) as f64).powi(2);
        }
    }
    (out, residual as f32)
}

#[test]
fn discovery_sees_catalog() {
    let e = engine();
    let names = e.available().unwrap();
    assert!(names.iter().any(|n| n == "stencil_f32_64x64"), "catalog missing: {names:?}");
    assert!(names.iter().any(|n| n == "summa_f32_128x128x128"));
}

#[test]
fn stencil_artifact_matches_reference() {
    let e = engine();
    let exe = e.load("stencil_f32_32x32").unwrap();
    assert_eq!(exe.artifact().inputs[0].dims, vec![34, 34]);

    // Deterministic pseudo-random field.
    let mut x = 123456789u64;
    let mut rnd = || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((x >> 33) as f32 / (1u64 << 31) as f32) - 1.0
    };
    let padded: Vec<f32> = (0..34 * 34).map(|_| rnd()).collect();

    let outs = exe.run_f32(&[&padded]).unwrap();
    assert_eq!(outs.len(), 2);
    let (want, want_res) = stencil_ref(&padded, 34, 34, 0.25);
    assert_eq!(outs[0].len(), 32 * 32);
    for (g, w) in outs[0].iter().zip(&want) {
        assert!((g - w).abs() < 1e-4, "stencil mismatch: {g} vs {w}");
    }
    let res = outs[1][0];
    assert!((res - want_res).abs() / want_res.max(1e-6) < 1e-3, "residual {res} vs {want_res}");
}

#[test]
fn stencil_fixed_point_has_zero_residual() {
    let e = engine();
    let exe = e.load("stencil_f32_32x32").unwrap();
    let padded = vec![2.5f32; 34 * 34];
    let outs = exe.run_f32(&[&padded]).unwrap();
    assert!(outs[0].iter().all(|&v| (v - 2.5).abs() < 1e-6));
    assert!(outs[1][0].abs() < 1e-10);
}

#[test]
fn summa_artifact_accumulates_product() {
    let e = engine();
    let exe = e.load("summa_f32_64x64x64").unwrap();
    let n = 64usize;
    // C = I, A = diag(2), B = ones ⇒ C + A@B = 1 + 2 everywhere on diag...
    // use simple structured matrices with a closed form: A = row-index
    // matrix? Keep it simple: A = I*2, B = ones → A@B = 2*ones.
    let mut c = vec![0f32; n * n];
    for i in 0..n {
        c[i * n + i] = 1.0;
    }
    let mut a = vec![0f32; n * n];
    for i in 0..n {
        a[i * n + i] = 2.0;
    }
    let b = vec![1f32; n * n];
    let outs = exe.run_f32(&[&c, &a, &b]).unwrap();
    assert_eq!(outs.len(), 1);
    for i in 0..n {
        for j in 0..n {
            let want = 2.0 + if i == j { 1.0 } else { 0.0 };
            let got = outs[0][i * n + j];
            assert!((got - want).abs() < 1e-5, "C[{i},{j}] = {got}, want {want}");
        }
    }
}

#[test]
fn shape_validation_beats_pjrt_abort() {
    let e = engine();
    let exe = e.load("stencil_f32_32x32").unwrap();
    let too_small = vec![0f32; 10];
    let err = exe.run_f32(&[&too_small]).unwrap_err();
    assert!(err.to_string().contains("expected"), "got: {err}");
    let err = exe.run_f32(&[]).unwrap_err();
    assert!(matches!(err, dart::runtime::RuntimeErr::Shape { .. }));
}

#[test]
fn executable_cache_returns_same_instance() {
    let e = engine();
    let a = e.load("stencil_f32_32x32").unwrap();
    let b = e.load("stencil_f32_32x32").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}
