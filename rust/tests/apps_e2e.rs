//! End-to-end application tests: the full three-layer stack (DART one-sided
//! communication + AOT JAX/Pallas artifacts on PJRT) against
//! single-threaded references. Requires `make artifacts`.

use dart::apps::matmul::{self, SummaConfig};
use dart::apps::stencil::{self, StencilConfig};
use dart::dart::{run, DartConfig};
use dart::runtime::{artifacts_dir, Engine};
use std::sync::Mutex;

fn have_artifacts() -> bool {
    let dir = if artifacts_dir().exists() { artifacts_dir() } else { "../artifacts".into() };
    if !dir.exists() {
        panic!("artifacts/ not found — run `make artifacts` before `cargo test`");
    }
    std::env::set_var("DART_ARTIFACTS", &dir);
    true
}

#[test]
fn stencil_two_units_matches_reference() {
    assert!(have_artifacts());
    let cfg = StencilConfig::block32(25);
    let report = Mutex::new(None);
    run(DartConfig::with_units(2), |env| {
        let engine = Engine::new().expect("engine");
        let r = stencil::run_distributed(env, &engine, &cfg).expect("run");
        if env.myid() == 0 {
            *report.lock().unwrap() = Some(r);
        }
    })
    .unwrap();
    let r = report.into_inner().unwrap().unwrap();
    let (ref_grid, ref_res) = stencil::run_reference(2 * 32, 32, 25, 0.25);
    let ref_sum: f64 = ref_grid.iter().map(|&v| v as f64).sum();
    let rel = (r.global_checksum - ref_sum).abs() / ref_sum.abs().max(1e-12);
    assert!(rel < 1e-5, "checksum {} vs {ref_sum}", r.global_checksum);
    // residual curve decreasing + matches reference at every step
    assert_eq!(r.residuals.len(), 25);
    for (i, (d, rr)) in r.residuals.iter().zip(&ref_res).enumerate() {
        let rel = (d - rr).abs() / rr.max(1e-12);
        assert!(rel < 1e-3, "step {i}: {d} vs {rr}");
    }
    assert!(r.residuals.last().unwrap() < &r.residuals[0]);
}

#[test]
fn stencil_four_units_block32() {
    assert!(have_artifacts());
    let cfg = StencilConfig::block32(10);
    let report = Mutex::new(None);
    run(DartConfig::with_units(4), |env| {
        let engine = Engine::new().expect("engine");
        let r = stencil::run_distributed(env, &engine, &cfg).expect("run");
        if env.myid() == 0 {
            *report.lock().unwrap() = Some(r);
        }
    })
    .unwrap();
    let r = report.into_inner().unwrap().unwrap();
    let (ref_grid, _) = stencil::run_reference(4 * 32, 32, 10, 0.25);
    let ref_sum: f64 = ref_grid.iter().map(|&v| v as f64).sum();
    let rel = (r.global_checksum - ref_sum).abs() / ref_sum.abs().max(1e-12);
    assert!(rel < 1e-5);
}

#[test]
fn stencil_single_unit_degenerate() {
    // One unit: no halo traffic at all; must still match the reference.
    assert!(have_artifacts());
    let cfg = StencilConfig::block32(8);
    let report = Mutex::new(None);
    run(DartConfig::with_units(1), |env| {
        let engine = Engine::new().expect("engine");
        let r = stencil::run_distributed(env, &engine, &cfg).expect("run");
        *report.lock().unwrap() = Some(r);
    })
    .unwrap();
    let r = report.into_inner().unwrap().unwrap();
    let (ref_grid, _) = stencil::run_reference(32, 32, 8, 0.25);
    let ref_sum: f64 = ref_grid.iter().map(|&v| v as f64).sum();
    assert!((r.global_checksum - ref_sum).abs() / ref_sum.abs().max(1e-12) < 1e-5);
}

#[test]
fn summa_three_units_matches_reference() {
    assert!(have_artifacts());
    let cfg = SummaConfig::block64();
    let blocks = Mutex::new(vec![Vec::new(); 3]);
    run(DartConfig::with_units(3), |env| {
        let engine = Engine::new().expect("engine");
        let r = matmul::run_distributed(env, &engine, &cfg).expect("run");
        blocks.lock().unwrap()[env.team_myid(cfg.team).unwrap()] = r.c_local;
    })
    .unwrap();
    let c_dist: Vec<f32> = blocks.into_inner().unwrap().concat();
    let c_ref = matmul::reference(3, cfg.mb, cfg.kb, cfg.nb);
    assert_eq!(c_dist.len(), c_ref.len());
    for (i, (d, r)) in c_dist.iter().zip(&c_ref).enumerate() {
        assert!((d - r).abs() < 1e-3, "C[{i}]: {d} vs {r}");
    }
}

#[test]
fn summa_under_hermit_cost_model() {
    // Same numerics with network costs injected (placement must not change
    // results, only timing).
    assert!(have_artifacts());
    let cfg = SummaConfig::block64();
    let norm = Mutex::new(0f64);
    run(DartConfig::hermit(2, 2), |env| {
        let engine = Engine::new().expect("engine");
        let r = matmul::run_distributed(env, &engine, &cfg).expect("run");
        if env.myid() == 0 {
            *norm.lock().unwrap() = r.global_norm;
        }
    })
    .unwrap();
    let c_ref = matmul::reference(2, cfg.mb, cfg.kb, cfg.nb);
    let ref_norm = c_ref.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
    let got = norm.into_inner().unwrap();
    assert!((got - ref_norm).abs() / ref_norm < 1e-5, "{got} vs {ref_norm}");
}

#[test]
fn stencil2d_matches_reference() {
    // 2×2 unit grid, 32×32 blocks: row halos (contiguous gets) + column
    // halos (strided gets) + Pallas sweep, vs the sequential reference.
    assert!(have_artifacts());
    let cfg = dart::apps::stencil2d::Stencil2dConfig::block32(2, 2, 12);
    let report = Mutex::new(None);
    run(DartConfig::with_units(4), |env| {
        let engine = Engine::new().expect("engine");
        let r = dart::apps::stencil2d::run_distributed(env, &engine, &cfg).expect("run");
        if env.myid() == 0 {
            *report.lock().unwrap() = Some(r);
        }
    })
    .unwrap();
    let r = report.into_inner().unwrap().unwrap();
    let want = dart::apps::stencil2d::reference_checksum(&cfg);
    let rel = (r.global_checksum - want).abs() / want.abs().max(1e-12);
    assert!(rel < 1e-5, "2D checksum {} vs {want}", r.global_checksum);
    assert!(r.residuals.last().unwrap() < &r.residuals[0], "not converging");
}

#[test]
fn stencil2d_wide_unit_grid() {
    // Asymmetric 3×1 decomposition: only column halos are exercised.
    assert!(have_artifacts());
    let cfg = dart::apps::stencil2d::Stencil2dConfig::block32(3, 1, 8);
    let report = Mutex::new(None);
    run(DartConfig::with_units(3), |env| {
        let engine = Engine::new().expect("engine");
        let r = dart::apps::stencil2d::run_distributed(env, &engine, &cfg).expect("run");
        if env.myid() == 0 {
            *report.lock().unwrap() = Some(r);
        }
    })
    .unwrap();
    let r = report.into_inner().unwrap().unwrap();
    let want = dart::apps::stencil2d::reference_checksum(&cfg);
    let rel = (r.global_checksum - want).abs() / want.abs().max(1e-12);
    assert!(rel < 1e-5, "3×1 checksum {} vs {want}", r.global_checksum);
}

#[test]
fn stencil2d_rejects_bad_grid() {
    assert!(have_artifacts());
    let cfg = dart::apps::stencil2d::Stencil2dConfig::block32(2, 2, 1);
    run(DartConfig::with_units(3), |env| {
        let engine = Engine::new().expect("engine");
        let r = dart::apps::stencil2d::run_distributed(env, &engine, &cfg);
        assert!(r.is_err(), "2×2 grid on 3 units must fail");
    })
    .unwrap();
}
