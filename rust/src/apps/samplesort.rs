//! Distributed sample sort — the second irregular workload: the
//! *destination* of every element is decided by the data.
//!
//! The classic four-phase recipe over the dash layer:
//!
//! 1. **local sort** — each unit sorts its BLOCKED partition in place
//!    (zero network, owner-computes);
//! 2. **splitter selection** — every unit contributes `oversample`
//!    regular samples of its sorted partition (empty partitions send
//!    `u64::MAX` sentinels), one allgather replicates the `p·s` samples,
//!    and every unit independently derives the identical `p-1` splitters;
//! 3. **bucketed redistribution** — per-unit bucket counts are
//!    allgathered (`p×p`), every unit computes its exclusive write
//!    offsets into each destination bucket, and ships each bucket slice
//!    with one [`crate::dash::Array::copy_in_async`] — the run-coalescing
//!    machinery batches ALL buckets behind a single flush, and empty
//!    buckets (skewed or all-equal inputs) are zero-op legal;
//! 4. **local merge** — each unit k-way merges the `p` sorted chunks it
//!    received, then publishes its bucket into a BLOCKED output array
//!    (a second, possibly unit-spanning coalesced redistribution) so the
//!    result is a plain dash array any oracle can compare against.
//!
//! The output is deterministic — duplicates are indistinguishable `u64`s
//! — so the positional checksum is bit-identical across flat/hier
//! collectives, fastpath on/off, and both exec modes; permutation
//! preservation (count + order-independent mixed checksum) is exactly
//! invariant nine of the chaos harness.

use crate::dart::{DartEnv, DartErr, DartResult, TeamId, DART_TEAM_ALL};
use crate::dash::{algorithms, Array};
use crate::mpisim::{as_bytes, as_bytes_mut, MpiOp};
use crate::testing::prop::Rng;

/// Input key distributions, including the degenerate shapes that break
/// naive splitter selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyDist {
    /// Independent uniform 64-bit keys.
    Uniform,
    /// Heavily duplicated keys drawn from a small value range (bucket
    /// skew: some buckets overflow, others are empty).
    Skewed,
    /// Every key identical — all elements route to one bucket.
    AllEqual,
    /// Already sorted ascending.
    Sorted,
    /// Sorted descending.
    Reverse,
}

/// Parameters of a distributed sample-sort run.
#[derive(Debug, Clone)]
pub struct SortConfig {
    /// Total element count (distributed BLOCKED; 0 is legal and sorts to
    /// an empty array).
    pub n: usize,
    /// Key-stream seed.
    pub seed: u64,
    /// Input distribution shape.
    pub dist: KeyDist,
    /// Regular samples per unit for splitter selection.
    pub oversample: usize,
    /// Team the run is collective over.
    pub team: TeamId,
}

impl SortConfig {
    /// A small default configuration over `DART_TEAM_ALL`.
    pub fn quick(n: usize, seed: u64) -> Self {
        SortConfig { n, seed, dist: KeyDist::Uniform, oversample: 8, team: DART_TEAM_ALL }
    }
}

/// The key at global index `g` — a pure function, so the input is
/// replayable by the sequential oracle and identical for any team size.
pub fn key_at(cfg: &SortConfig, g: usize) -> u64 {
    match cfg.dist {
        KeyDist::Uniform => Rng::new(cfg.seed ^ g as u64).next_u64(),
        KeyDist::Skewed => {
            let span = (cfg.n as u64 / 8).max(1);
            Rng::new(cfg.seed ^ g as u64).next_u64() % span
        }
        KeyDist::AllEqual => 0xA11E_0A11,
        KeyDist::Sorted => g as u64,
        KeyDist::Reverse => (cfg.n - 1 - g) as u64,
    }
}

/// Sequential oracle: the fully sorted key stream.
pub fn reference_sorted(cfg: &SortConfig) -> Vec<u64> {
    let mut keys: Vec<u64> = (0..cfg.n).map(|g| key_at(cfg, g)).collect();
    keys.sort_unstable();
    keys
}

/// Order-independent multiset checksum term for one key (a splitmix
/// draw, so multiset changes don't cancel the way plain sums can).
fn mix(key: u64) -> u64 {
    Rng::new(key).next_u64()
}

/// What the oracle predicts for `cfg`: `(multiset checksum, position
/// checksum)` — compare against [`SortReport::checksum_out`] and
/// [`SortReport::position_checksum`].
pub fn reference_checksums(cfg: &SortConfig) -> (u64, u64) {
    let sorted = reference_sorted(cfg);
    let multiset = sorted.iter().fold(0u64, |acc, &k| acc.wrapping_add(mix(k)));
    let position = sorted.iter().enumerate().fold(0u64, |acc, (g, &k)| {
        acc.wrapping_add((g as u64 + 1).wrapping_mul(mix(k)))
    });
    (multiset, position)
}

/// Result of a run (identical on every unit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SortReport {
    /// Total elements sorted (= `cfg.n`).
    pub count: u64,
    /// Order-independent checksum of the input multiset.
    pub checksum_in: u64,
    /// Order-independent checksum of the output multiset — equal to
    /// `checksum_in` iff the sort is a permutation (invariant nine).
    pub checksum_out: u64,
    /// Position-weighted checksum `Σ (g+1)·mix(out[g])` of the output
    /// array — pins the exact output order across configurations.
    pub position_checksum: u64,
    /// Global sortedness verified (local scans + one boundary allgather).
    pub sorted_ok: bool,
    /// Largest bucket (elements), the skew measure.
    pub max_bucket: u64,
    /// Coalesced one-sided operations issued for both redistributions,
    /// summed over the team.
    pub redist_ops: u64,
}

/// The distributed sort core: returns the report plus the sorted output
/// array (still allocated) so callers can validate before freeing.
fn sort_core<'e>(
    env: &'e DartEnv,
    cfg: &SortConfig,
) -> DartResult<(SortReport, Array<'e, u64>)> {
    if cfg.oversample == 0 {
        return Err(DartErr::Invalid("sample sort needs oversample > 0".into()));
    }
    let team = cfg.team;
    let p = env.team_size(team)?;
    let me = env.team_myid(team)?;
    let s = cfg.oversample;

    // Phase 0+1: materialize the keyed input, then sort my partition.
    let input: Array<'e, u64> = Array::blocked(env, team, cfg.n)?;
    algorithms::transform(&input, |g, _| key_at(cfg, g))?;
    let mut sorted = input.read_local()?;
    sorted.sort_unstable();
    let checksum_in_local: u64 = sorted.iter().fold(0u64, |acc, &k| acc.wrapping_add(mix(k)));
    input.free()?;

    // Phase 2: regular samples (MAX sentinels from empty partitions),
    // one allgather, identical splitters everywhere.
    let mut samples = vec![u64::MAX; s];
    if !sorted.is_empty() {
        for (i, slot) in samples.iter_mut().enumerate() {
            *slot = sorted[i * sorted.len() / s];
        }
    }
    let mut all_samples = vec![0u64; s * p];
    env.allgather(team, as_bytes(&samples), as_bytes_mut(&mut all_samples))?;
    all_samples.sort_unstable();
    let splitters: Vec<u64> = (1..p).map(|j| all_samples[j * s]).collect();
    let bucket_of = |k: u64| splitters.partition_point(|&sp| sp < k);

    // Phase 3a: bucket counts, allgathered p×p so every unit knows both
    // the bucket totals and its exclusive write offset in each bucket.
    let mut counts = vec![0u64; p];
    for &k in &sorted {
        counts[bucket_of(k)] += 1;
    }
    let mut all_counts = vec![0u64; p * p];
    env.allgather(team, as_bytes(&counts), as_bytes_mut(&mut all_counts))?;
    let bucket_total = |j: usize| (0..p).map(|r| all_counts[r * p + j]).sum::<u64>();
    let my_offset = |j: usize| (0..me).map(|r| all_counts[r * p + j]).sum::<u64>();
    let cap = (0..p).map(bucket_total).max().unwrap_or(0) as usize;

    // Phase 3b: the bucketed redistribution — one coalesced deferred
    // scatter per destination bucket (empty slices are zero-op), ONE
    // flush, one barrier. `cap` slots per unit lines bucket `j` up with
    // global index `j·cap` in the BLOCKED receive array.
    let recv: Array<'e, u64> = Array::blocked(env, team, cap * p)?;
    let mut ops = 0u64;
    let mut pos = 0usize;
    for j in 0..p {
        let len = counts[j] as usize;
        ops += recv.copy_in_async(j * cap + my_offset(j) as usize, &sorted[pos..pos + len])?;
        pos += len;
    }
    recv.flush()?;
    env.barrier(team)?;

    // Phase 4: k-way merge of the p sorted chunks in my bucket.
    let slots = recv.read_local()?;
    let mut chunks: Vec<&[u64]> = Vec::with_capacity(p);
    let mut base = 0usize;
    for r in 0..p {
        let len = all_counts[r * p + me] as usize;
        chunks.push(&slots[base..base + len]);
        base += len;
    }
    let mut merged = Vec::with_capacity(base);
    let mut heads = vec![0usize; p];
    loop {
        let mut best: Option<(u64, usize)> = None;
        for (r, chunk) in chunks.iter().enumerate() {
            if heads[r] < chunk.len() {
                let k = chunk[heads[r]];
                if best.map_or(true, |(bk, _)| k < bk) {
                    best = Some((k, r));
                }
            }
        }
        match best {
            Some((k, r)) => {
                merged.push(k);
                heads[r] += 1;
            }
            None => break,
        }
    }
    recv.free()?;
    let checksum_out_local: u64 = merged.iter().fold(0u64, |acc, &k| acc.wrapping_add(mix(k)));

    // Local sortedness + cross-bucket boundary check (empty buckets are
    // skipped by making their min/max sentinels that always pass).
    let locally_sorted = merged.windows(2).all(|w| w[0] <= w[1]);
    let bounds = if merged.is_empty() {
        [u64::MAX, 0]
    } else {
        [merged[0], *merged.last().unwrap()]
    };
    let mut all_bounds = vec![0u64; 2 * p];
    env.allgather(team, as_bytes(&bounds), as_bytes_mut(&mut all_bounds))?;
    let mut boundary_ok = true;
    let mut prev_max: Option<u64> = None;
    for r in 0..p {
        let (mn, mx) = (all_bounds[2 * r], all_bounds[2 * r + 1]);
        if mn == u64::MAX && mx == 0 {
            continue;
        }
        if let Some(pm) = prev_max {
            boundary_ok &= pm <= mn;
        }
        prev_max = Some(mx);
    }

    // Publish my bucket into the BLOCKED output array — the second
    // bucketed redistribution, whose runs genuinely span units.
    let out: Array<'e, u64> = Array::blocked(env, team, cfg.n)?;
    let out_base = (0..me).map(bucket_total).sum::<u64>() as usize;
    ops += out.copy_in_async(out_base, &merged)?;
    out.flush()?;
    env.barrier(team)?;

    // Position checksum from the output's owner-local view.
    let pat = *out.pattern();
    let out_local = out.read_local()?;
    let position_local: u64 = out_local.iter().enumerate().fold(0u64, |acc, (l, &k)| {
        acc.wrapping_add(((pat.local_to_global(me, l) as u64) + 1).wrapping_mul(mix(k)))
    });

    // Replicated report.
    let flags = u64::from(!(locally_sorted && boundary_ok));
    let mut sums = [0u64; 5];
    env.allreduce(
        team,
        &[
            merged.len() as u64,
            checksum_in_local,
            checksum_out_local,
            position_local,
            ops,
        ],
        &mut sums,
        MpiOp::Sum,
    )?;
    let mut bad = [0u64];
    env.allreduce(team, &[flags], &mut bad, MpiOp::Max)?;
    let report = SortReport {
        count: sums[0],
        checksum_in: sums[1],
        checksum_out: sums[2],
        position_checksum: sums[3],
        sorted_ok: bad[0] == 0,
        max_bucket: cap as u64,
        redist_ops: sums[4],
    };
    Ok((report, out))
}

/// Run the distributed sample sort. Collective over `cfg.team`; every
/// unit returns the same report.
pub fn run_distributed(env: &DartEnv, cfg: &SortConfig) -> DartResult<SortReport> {
    let (report, out) = sort_core(env, cfg)?;
    out.free()?;
    Ok(report)
}

/// Run the distributed sort and verify the output array element-by-
/// element against [`reference_sorted`]: each unit compares its owned
/// partition of the output to the oracle's slice — a full positional
/// equality check with zero extra communication. Returns the report, or
/// an `Err` naming the first mismatch.
pub fn run_checked(env: &DartEnv, cfg: &SortConfig) -> DartResult<SortReport> {
    let (report, out) = sort_core(env, cfg)?;
    let oracle = reference_sorted(cfg);
    let me = env.team_myid(cfg.team)?;
    let pat = *out.pattern();
    let local = out.read_local()?;
    let mut verdict: DartResult<()> = Ok(());
    for (l, &k) in local.iter().enumerate() {
        let g = pat.local_to_global(me, l);
        if oracle[g] != k {
            verdict = Err(DartErr::Invalid(format!(
                "out[{g}] = {k}, oracle says {}",
                oracle[g]
            )));
            break;
        }
    }
    // Agree on the verdict before the collective free.
    let mut any = [0u64];
    env.allreduce(cfg.team, &[u64::from(verdict.is_err())], &mut any, MpiOp::Max)?;
    out.free()?;
    verdict?;
    if any[0] != 0 {
        return Err(DartErr::Invalid("sort validation failed on another unit".into()));
    }
    Ok(report)
}
