//! Distributed histogram — the `dash` layer's canonical workload.
//!
//! Every unit draws a deterministic stream of samples and bins them into
//! a **cyclic-distributed** [`crate::dash::Array`]`<u64>` (cyclic because
//! real histograms are skewed: round-robin bins spread the hot bins over
//! the team instead of concentrating them on one owner).
//!
//! Accumulation is **lock-free** in the classic reduction shape: each
//! unit fills a private full-width partial, ONE `allreduce` combines
//! them, and each unit then writes only *its own* bins of the reduced
//! result through the owner-computes local view — zero one-sided traffic
//! and zero lock acquisitions, versus `bins × units` remote atomic
//! `accumulate`s for the naive PGAS formulation.
//!
//! On multi-node launches with
//! [`crate::dart::DartConfig::hierarchical_collectives`] enabled, that
//! allreduce is the **hierarchical two-level** one: node partials combine
//! intra-node first and cross the interconnect once per node, not once
//! per unit — the app-level win the `perf_locality` bench measures
//! (counts are `u64`, so the hierarchical result is bit-identical to the
//! flat one).
//!
//! The final counts are verified with the owner-computes algorithms:
//! [`crate::dash::algorithms::sum`] must equal the total sample count and
//! [`crate::dash::algorithms::max_element`] picks the modal bin, both
//! replicated on every unit.

use crate::dart::{DartEnv, DartErr, DartResult, TeamId, DART_TEAM_ALL};
use crate::dash::{algorithms, Array};
use crate::testing::prop::Rng;

/// Parameters of a distributed histogram run.
#[derive(Debug, Clone)]
pub struct HistogramConfig {
    /// Number of histogram bins (cyclic-distributed over the team).
    pub bins: usize,
    /// Samples drawn per unit.
    pub items_per_unit: usize,
    /// Stream seed (unit `u` draws from `seed ^ u`).
    pub seed: u64,
    /// Team the run is collective over.
    pub team: TeamId,
}

impl HistogramConfig {
    /// A small default configuration over `DART_TEAM_ALL`.
    pub fn quick(bins: usize, items_per_unit: usize) -> Self {
        HistogramConfig { bins, items_per_unit, seed: 0x9215_0CAB, team: DART_TEAM_ALL }
    }
}

/// Result of a run (identical on every unit).
#[derive(Debug, Clone)]
pub struct HistogramReport {
    /// Total samples counted across the team (= `units × items_per_unit`).
    pub total: u64,
    /// `(bin index, count)` of the fullest bin (ties → lowest index).
    pub modal_bin: (usize, u64),
    /// Order-independent checksum `Σ bin_index · count`.
    pub checksum: u64,
}

/// The bin a sample value falls into.
#[inline]
fn bin_of(value: u64, bins: usize) -> usize {
    (value % bins as u64) as usize
}

/// Sequential reference: the full histogram every unit's streams produce
/// (deterministic, so any rank — or a test — can replay it).
pub fn reference_counts(units: usize, cfg: &HistogramConfig) -> Vec<u64> {
    let mut counts = vec![0u64; cfg.bins];
    for u in 0..units {
        let mut rng = Rng::new(cfg.seed ^ u as u64);
        for _ in 0..cfg.items_per_unit {
            counts[bin_of(rng.next_u64(), cfg.bins)] += 1;
        }
    }
    counts
}

/// Run the distributed histogram. Collective over `cfg.team`.
pub fn run_distributed(env: &DartEnv, cfg: &HistogramConfig) -> DartResult<HistogramReport> {
    if cfg.bins == 0 || cfg.items_per_unit == 0 {
        return Err(DartErr::Invalid("histogram needs bins > 0 and items > 0".into()));
    }
    let team = cfg.team;
    let me = env.team_myid(team)?;
    let hist: Array<'_, u64> = Array::cyclic(env, team, cfg.bins)?;

    // --- lock-free accumulation: private partial, one allreduce.
    let mut partial = vec![0u64; cfg.bins];
    let mut rng = Rng::new(cfg.seed ^ me as u64);
    for _ in 0..cfg.items_per_unit {
        partial[bin_of(rng.next_u64(), cfg.bins)] += 1;
    }
    let mut reduced = vec![0u64; cfg.bins];
    // Rides the hierarchical two-level path on multi-node launches with
    // `DartConfig::hierarchical_collectives` on (one interconnect crossing
    // per node); bit-identical either way for u64 sums.
    env.allreduce(team, &partial, &mut reduced, crate::mpisim::MpiOp::Sum)?;

    // --- owner-computes publication: each unit writes only its own bins.
    let pat = *hist.pattern();
    hist.with_local(|local| {
        for (l, slot) in local.iter_mut().enumerate() {
            *slot = reduced[pat.local_to_global(me, l)];
        }
    })?;
    env.barrier(team)?;

    // --- verification through the algorithms layer (replicated results).
    let total = algorithms::sum(&hist)?;
    let modal_bin = algorithms::max_element(&hist)?;
    let local = hist.read_local()?;
    let my_weighted: u64 =
        local.iter().enumerate().map(|(l, c)| pat.local_to_global(me, l) as u64 * c).sum();
    let mut weighted = [0u64];
    env.allreduce(team, &[my_weighted], &mut weighted, crate::mpisim::MpiOp::Sum)?;
    let checksum = weighted[0];

    env.barrier(team)?;
    hist.free()?;
    Ok(HistogramReport { total, modal_bin, checksum })
}
