//! Distributed histogram — the `dash` layer's canonical workload.
//!
//! Every unit draws a deterministic stream of samples and bins them into
//! a **cyclic-distributed** [`crate::dash::Array`]`<u64>` (cyclic because
//! real histograms are skewed: round-robin bins spread the hot bins over
//! the team instead of concentrating them on one owner).
//!
//! Accumulation is **lock-free** in two stages: each unit first fills a
//! private full-width partial (plain local adds), then publishes every
//! non-empty bin with one deferred atomic
//! [`crate::dash::Array::accumulate`] — the engine's `accumulate_async`
//! hot path — and completes the whole combine phase with ONE
//! [`crate::dash::Array::flush`]. No locks, no per-op round trips, and
//! same-node bins complete via the CPU-atomic fast path; counts are
//! `u64`, so the result is exact and identical on every path. (The
//! previous formulation combined partials with an `allreduce` and
//! owner-computes publication; the atomic formulation sends only the
//! non-empty bins, which for skewed streams is far less traffic, and it
//! exercises the runtime's atomic hot path.)
//!
//! The final counts are verified with the owner-computes algorithms:
//! [`crate::dash::algorithms::sum`] must equal the total sample count and
//! [`crate::dash::algorithms::max_element`] picks the modal bin, both
//! replicated on every unit.

use crate::dart::{DartEnv, DartErr, DartResult, TeamId, DART_TEAM_ALL};
use crate::dash::{algorithms, Array};
use crate::testing::prop::Rng;

/// Parameters of a distributed histogram run.
#[derive(Debug, Clone)]
pub struct HistogramConfig {
    /// Number of histogram bins (cyclic-distributed over the team).
    pub bins: usize,
    /// Samples drawn per unit.
    pub items_per_unit: usize,
    /// Stream seed (unit `u` draws from `seed ^ u`).
    pub seed: u64,
    /// Team the run is collective over.
    pub team: TeamId,
}

impl HistogramConfig {
    /// A small default configuration over `DART_TEAM_ALL`.
    pub fn quick(bins: usize, items_per_unit: usize) -> Self {
        HistogramConfig { bins, items_per_unit, seed: 0x9215_0CAB, team: DART_TEAM_ALL }
    }
}

/// Result of a run (identical on every unit).
#[derive(Debug, Clone)]
pub struct HistogramReport {
    /// Total samples counted across the team (= `units × items_per_unit`).
    pub total: u64,
    /// `(bin index, count)` of the fullest bin (ties → lowest index).
    pub modal_bin: (usize, u64),
    /// Order-independent checksum `Σ bin_index · count`.
    pub checksum: u64,
}

/// The bin a sample value falls into.
#[inline]
fn bin_of(value: u64, bins: usize) -> usize {
    (value % bins as u64) as usize
}

/// Sequential reference: the full histogram every unit's streams produce
/// (deterministic, so any rank — or a test — can replay it).
pub fn reference_counts(units: usize, cfg: &HistogramConfig) -> Vec<u64> {
    let mut counts = vec![0u64; cfg.bins];
    for u in 0..units {
        let mut rng = Rng::new(cfg.seed ^ u as u64);
        for _ in 0..cfg.items_per_unit {
            counts[bin_of(rng.next_u64(), cfg.bins)] += 1;
        }
    }
    counts
}

/// Run the distributed histogram. Collective over `cfg.team`.
pub fn run_distributed(env: &DartEnv, cfg: &HistogramConfig) -> DartResult<HistogramReport> {
    if cfg.bins == 0 || cfg.items_per_unit == 0 {
        return Err(DartErr::Invalid("histogram needs bins > 0 and items > 0".into()));
    }
    let team = cfg.team;
    let me = env.team_myid(team)?;
    let hist: Array<'_, u64> = Array::cyclic(env, team, cfg.bins)?;

    // --- lock-free accumulation: private partial, then one deferred
    // atomic accumulate per non-empty bin and a single flush. Exact for
    // u64 counts regardless of interleaving; same-node bins ride the
    // CPU-atomic fast path.
    let mut partial = vec![0u64; cfg.bins];
    let mut rng = Rng::new(cfg.seed ^ me as u64);
    for _ in 0..cfg.items_per_unit {
        partial[bin_of(rng.next_u64(), cfg.bins)] += 1;
    }
    for (g, &count) in partial.iter().enumerate() {
        if count != 0 {
            hist.accumulate(g, count, crate::mpisim::MpiOp::Sum)?;
        }
    }
    hist.flush()?;
    env.barrier(team)?;
    let pat = *hist.pattern();

    // --- verification through the algorithms layer (replicated results).
    let total = algorithms::sum(&hist)?;
    let modal_bin = algorithms::max_element(&hist)?;
    let local = hist.read_local()?;
    let my_weighted: u64 =
        local.iter().enumerate().map(|(l, c)| pat.local_to_global(me, l) as u64 * c).sum();
    let mut weighted = [0u64];
    env.allreduce(team, &[my_weighted], &mut weighted, crate::mpisim::MpiOp::Sum)?;
    let checksum = weighted[0];

    env.barrier(team)?;
    hist.free()?;
    Ok(HistogramReport { total, modal_bin, checksum })
}
