//! Distributed key-value store — the atomics hot path's stress workload.
//!
//! Every unit replays a seeded **zipfian** GET/SET mix (hot keys are
//! genuinely hot, like real caches) against one shared
//! [`crate::dash::HashMap`], through three interchangeable write
//! disciplines over the *same* storage layout:
//!
//! - [`KvBackend::CasLockFree`] — the lock-free hot path:
//!   `compare_and_swap` slot claims plus deferred `accumulate_async`
//!   publication, flushed every [`KvConfig::flush_every`] writes;
//! - [`KvBackend::McsLockPerBucket`] — SETs serialize on a stripe of MCS
//!   queue locks ([`crate::dart::DartEnv::lock_init`], paper §IV-B6)
//!   covering the key's bucket, then use plain read-modify-write
//!   ([`crate::dash::HashMap::put_exclusive`]); GETs stay lock-free;
//! - [`KvBackend::OwnerShards`] — owner-computes sharding: units batch
//!   requests by consistent-hash owner, ship them with the runtime's
//!   eager messages, and owners apply plain local operations
//!   ([`crate::dash::HashMap::local_put`]).
//!
//! SET values are a pure function of the key ([`value_of`]), so the final
//! store contents depend only on *which* keys were set — never on the
//! interleaving — and all three backends must agree on
//! [`crate::dash::HashMap::content_checksum`]. That equality is this
//! app's correctness oracle (asserted by the tests and the `perf_kv`
//! bench); the bench additionally times the backends against each other
//! under contention.

use crate::dart::{DartEnv, DartErr, DartLock, DartResult, TeamId, DART_TEAM_ALL};
use crate::dash::HashMap;
use crate::mpisim::as_bytes;
use crate::testing::prop::Rng;

/// Message tag for owner-computes request batches.
const TAG_KV_REQ: i32 = 7001;
/// Message tag for owner-computes GET-reply batches.
const TAG_KV_REP: i32 = 7002;

/// Request word 0 of a GET.
const OP_GET: u64 = 0;
/// Request word 0 of a SET.
const OP_SET: u64 = 1;

/// The write discipline a run drives the store with (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvBackend {
    /// Lock-free CAS claims + deferred atomic publication.
    CasLockFree,
    /// MCS stripe lock per bucket around plain read-modify-write SETs.
    McsLockPerBucket,
    /// Owner-computes sharding over eager messages.
    OwnerShards,
}

impl KvBackend {
    /// All backends, in the order benches and tests sweep them.
    pub const ALL: [KvBackend; 3] =
        [KvBackend::CasLockFree, KvBackend::McsLockPerBucket, KvBackend::OwnerShards];

    /// Stable short name (bench JSON rows, test labels).
    pub fn label(&self) -> &'static str {
        match self {
            KvBackend::CasLockFree => "cas",
            KvBackend::McsLockPerBucket => "mcs",
            KvBackend::OwnerShards => "owner",
        }
    }
}

/// Parameters of a key-value store run.
#[derive(Debug, Clone)]
pub struct KvConfig {
    /// Distinct keys in the universe (keys are `0..keys`; index 0 is the
    /// zipfian-hottest).
    pub keys: usize,
    /// Operations each unit issues.
    pub ops_per_unit: usize,
    /// Share of GETs in the mix, `0..=100`.
    pub get_percent: u32,
    /// Zipf exponent `s` (popularity ∝ `1/(rank+1)^s`; 0 = uniform).
    pub zipf_exponent: f64,
    /// Stream seed (unit `u` draws from `seed ^ u`).
    pub seed: u64,
    /// Requested hashmap slots per unit (sized for load factor ≤ 1/8 in
    /// the shipped configs; buckets overflow past ~16 colliding keys).
    pub slots_per_unit: usize,
    /// MCS lock stripes (only the `McsLockPerBucket` backend allocates
    /// them).
    pub locks: usize,
    /// `CasLockFree` flush cadence: complete deferred publications every
    /// this many SETs (plus one final flush).
    pub flush_every: usize,
    /// Team the run is collective over.
    pub team: TeamId,
}

impl KvConfig {
    /// A small default mix over `DART_TEAM_ALL`: 75% GETs over 256 hot
    /// keys, zipf 0.99 — the classic cache-workload shape.
    pub fn quick(ops_per_unit: usize) -> Self {
        KvConfig {
            keys: 256,
            ops_per_unit,
            get_percent: 75,
            zipf_exponent: 0.99,
            seed: 0x5EED_CAFE,
            slots_per_unit: 512,
            locks: 64,
            flush_every: 32,
            team: DART_TEAM_ALL,
        }
    }
}

/// Team-aggregated result of a run (identical on every unit).
#[derive(Debug, Clone)]
pub struct KvReport {
    /// Total operations issued across the team.
    pub ops: u64,
    /// SETs issued.
    pub sets: u64,
    /// GETs issued.
    pub gets: u64,
    /// GETs that found their key.
    pub hits: u64,
    /// Lost `compare_and_swap` slot claims (lock-free backend contention).
    pub cas_retries: u64,
    /// Runtime atomic operations issued during the run
    /// ([`crate::dart::Metrics::atomic_ops`] delta, team sum).
    pub atomic_ops: u64,
    /// Atomics completed on the intra-node CPU-atomic fast path
    /// ([`crate::dart::Metrics::atomic_fastpath_ops`] delta, team sum).
    pub atomic_fastpath_ops: u64,
    /// Canonical final-content checksum — must be identical across
    /// backends and execution modes for the same config.
    pub checksum: u64,
    /// Median modelled per-operation latency, team-max of the per-unit
    /// percentiles (ns). For the batched owner-computes backend the whole
    /// exchange is amortized uniformly over its operations.
    pub p50_ns: f64,
    /// 95th-percentile modelled per-operation latency (ns, team-max).
    pub p95_ns: f64,
    /// 99th-percentile modelled per-operation latency (ns, team-max).
    pub p99_ns: f64,
}

/// The value a SET of `key` always writes — a pure function of the key
/// (splitmix64 finalizer), so final contents are interleaving-free.
pub fn value_of(key: u64) -> u64 {
    let mut z = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Zipfian sampler over `0..n` via a precomputed normalized CDF.
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        // 53 uniform mantissa bits → u ∈ [0, 1).
        let u = (rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// One drawn operation: `(key, is_get)`. The draw order is fixed so every
/// backend replays the identical stream.
fn draw(zipf: &Zipf, rng: &mut Rng, get_percent: u32) -> (u64, bool) {
    let key = zipf.sample(rng) as u64;
    let is_get = rng.below(100) < get_percent as usize;
    (key, is_get)
}

/// Run the key-value workload through `backend`. Collective over
/// `cfg.team`; every unit gets the same [`KvReport`].
pub fn run_kv(env: &DartEnv, cfg: &KvConfig, backend: KvBackend) -> DartResult<KvReport> {
    if cfg.keys == 0 || cfg.ops_per_unit == 0 {
        return Err(DartErr::Invalid("kvstore needs keys > 0 and ops > 0".into()));
    }
    if cfg.get_percent > 100 {
        return Err(DartErr::Invalid("kvstore get_percent must be 0..=100".into()));
    }
    if cfg.locks == 0 || cfg.flush_every == 0 {
        return Err(DartErr::Invalid("kvstore needs locks > 0 and flush_every > 0".into()));
    }
    let team = cfg.team;
    let me = env.team_myid(team)?;
    let atomic_ops0 = env.metrics.atomic_ops.get();
    let fastpath0 = env.metrics.atomic_fastpath_ops.get();

    let map: HashMap<'_, u64, u64> = HashMap::new(env, team, cfg.slots_per_unit)?;
    // Lock stripes exist only for the MCS backend; lock_init is collective,
    // so the decision must be config-driven (identical on every member).
    let locks: Vec<DartLock> = if backend == KvBackend::McsLockPerBucket {
        (0..cfg.locks).map(|_| env.lock_init(team)).collect::<DartResult<_>>()?
    } else {
        Vec::new()
    };
    env.barrier(team)?;

    let zipf = Zipf::new(cfg.keys, cfg.zipf_exponent);
    let mut rng = Rng::new(cfg.seed ^ me as u64);
    let (mut sets, mut gets, mut hits) = (0u64, 0u64, 0u64);
    let mut lat = crate::bench_util::Samples::new();

    match backend {
        KvBackend::CasLockFree => {
            for _ in 0..cfg.ops_per_unit {
                let (key, is_get) = draw(&zipf, &mut rng, cfg.get_percent);
                let t = std::time::Instant::now();
                if is_get {
                    gets += 1;
                    if map.get(key)?.is_some() {
                        hits += 1;
                    }
                } else {
                    sets += 1;
                    map.put(key, value_of(key))?;
                    if sets % cfg.flush_every as u64 == 0 {
                        map.flush()?;
                    }
                }
                lat.push(t.elapsed().as_nanos() as f64);
            }
            map.flush()?;
        }
        KvBackend::McsLockPerBucket => {
            for _ in 0..cfg.ops_per_unit {
                let (key, is_get) = draw(&zipf, &mut rng, cfg.get_percent);
                let t = std::time::Instant::now();
                if is_get {
                    gets += 1;
                    if map.get(key)?.is_some() {
                        hits += 1;
                    }
                } else {
                    sets += 1;
                    let stripe = &locks[map.lock_index(key, cfg.locks)];
                    env.lock_acquire(stripe)?;
                    let res = map.put_exclusive(key, value_of(key));
                    env.lock_release(stripe)?;
                    res?;
                }
                lat.push(t.elapsed().as_nanos() as f64);
            }
        }
        KvBackend::OwnerShards => {
            let p = env.team_size(team)?;
            let comm = env.team_comm(team)?;
            let t_exchange = std::time::Instant::now();
            // Partition my stream by owner: request batches of
            // [kind, key] word pairs, in issue order.
            let mut reqs: Vec<Vec<u64>> = vec![Vec::new(); p];
            for _ in 0..cfg.ops_per_unit {
                let (key, is_get) = draw(&zipf, &mut rng, cfg.get_percent);
                let kind = if is_get {
                    gets += 1;
                    OP_GET
                } else {
                    sets += 1;
                    OP_SET
                };
                reqs[map.owner_of(key)].extend_from_slice(&[kind, key]);
            }
            // Eager sends never block, so send-all-then-serve is
            // deadlock-free (self included: the mailbox loops back).
            for (r, batch) in reqs.iter().enumerate() {
                comm.send(as_bytes(batch), r, TAG_KV_REQ)?;
            }
            // Serve every requester's batch with owner-local operations,
            // replying [found, value] per GET in request order.
            for r in 0..p {
                let (data, _) = comm.recv_vec(r, TAG_KV_REQ)?;
                let words: Vec<u64> = data
                    .chunks_exact(8)
                    .map(|c| u64::from_ne_bytes(c.try_into().unwrap()))
                    .collect();
                let mut replies: Vec<u64> = Vec::new();
                for op in words.chunks_exact(2) {
                    let (kind, key) = (op[0], op[1]);
                    if kind == OP_SET {
                        map.local_put(key, value_of(key))?;
                    } else {
                        match map.local_get(key)? {
                            Some(v) => replies.extend_from_slice(&[1, v]),
                            None => replies.extend_from_slice(&[0, 0]),
                        }
                    }
                }
                comm.send(as_bytes(&replies), r, TAG_KV_REP)?;
            }
            // Collect my GET replies.
            for r in 0..p {
                let (data, _) = comm.recv_vec(r, TAG_KV_REP)?;
                for rep in data.chunks_exact(16) {
                    if u64::from_ne_bytes(rep[..8].try_into().unwrap()) == 1 {
                        hits += 1;
                    }
                }
            }
            // Batched design: per-op latency is the exchange amortized
            // uniformly over the ops it carried.
            let per_op = t_exchange.elapsed().as_nanos() as f64 / cfg.ops_per_unit as f64;
            for _ in 0..cfg.ops_per_unit {
                lat.push(per_op);
            }
        }
    }

    map.flush()?;
    env.barrier(team)?;
    let checksum = map.content_checksum()?;
    let cas_retries = map.cas_retries();

    // Team-aggregate the per-unit tallies (order-independent sums).
    let local = [
        cfg.ops_per_unit as u64,
        sets,
        gets,
        hits,
        cas_retries,
        env.metrics.atomic_ops.get() - atomic_ops0,
        env.metrics.atomic_fastpath_ops.get() - fastpath0,
    ];
    let mut total = [0u64; 7];
    env.allreduce(team, &local, &mut total, crate::mpisim::MpiOp::Sum)?;
    // Worst-unit latency percentiles (max is the conservative aggregate
    // for a latency SLO, and it replicates the values on every unit).
    let my_lat = [lat.percentile(50.0), lat.percentile(95.0), lat.percentile(99.0)];
    let mut team_lat = [0f64; 3];
    env.allreduce(team, &my_lat, &mut team_lat, crate::mpisim::MpiOp::Max)?;

    for lock in locks {
        env.lock_free(lock)?;
    }
    map.free()?;
    Ok(KvReport {
        ops: total[0],
        sets: total[1],
        gets: total[2],
        hits: total[3],
        cas_retries: total[4],
        atomic_ops: total[5],
        atomic_fastpath_ops: total[6],
        checksum,
        p50_ns: team_lat[0],
        p95_ns: team_lat[1],
        p99_ns: team_lat[2],
    })
}
