//! PGAS mini-applications built on the public DART API + PJRT runtime.
//!
//! These are the workloads the paper's introduction motivates — shared-
//! memory-style scientific codes on distributed memory — and they double
//! as the end-to-end proof that the three layers compose: DART one-sided
//! communication (L3) around AOT JAX/Pallas compute artifacts (L2/L1).

pub mod bfs;
pub mod histogram;
pub mod kvstore;
pub mod matmul;
pub mod samplesort;
pub mod stencil;
pub mod stencil2d;
pub mod wqueue;
