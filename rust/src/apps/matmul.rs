//! Distributed SUMMA matrix multiply over the DART runtime.
//!
//! `C = A @ B` with `A (M×K)` row-distributed, `B (K×N)` row-distributed
//! (one K-panel per unit) and `C (M×N)` row-distributed. SUMMA iterates
//! over K-panels: at step `p`, the owner of panel `p` **broadcasts** it to
//! the team — the textbook SUMMA formulation — and every unit accumulates
//! `C_u += A_u[:, panel p] @ B_panel` with the AOT `summa_f32_*` artifact
//! (L1 Pallas GEMM tile inside an L2 JAX step).
//!
//! **Pipelined broadcasts** (the asynchronous-progress rewiring): the
//! broadcast of panel `p+1` is a *nonblocking* collective
//! ([`crate::dart::DartEnv::bcast_async`] → `MPI_Ibcast`) initiated
//! before the GEMM on panel `p` starts, and completed
//! ([`crate::dart::DartEnv::coll_wait`]) only when the next panel is
//! consumed. Under `Thread`/`Polling` progress modes the broadcast's
//! schedule advances *while the GEMM runs*; in `Caller` mode it advances
//! only inside the wait — the measurable difference the `perf_overlap`
//! bench and the progress-mode ablation are about.

use crate::dart::{DartEnv, DartErr, DartResult, TeamId};
use crate::mpisim::as_bytes_mut;
use crate::runtime::Engine;

/// Parameters of a distributed SUMMA run. With `P` units the global
/// problem is `M = mb·P`, `K = kb·P`, `N = nb`.
#[derive(Debug, Clone)]
pub struct SummaConfig {
    /// Rows of C (and A) per unit.
    pub mb: usize,
    /// Rows of B (columns of A) per unit — the K-panel depth.
    pub kb: usize,
    /// Full width of C and B.
    pub nb: usize,
    /// Artifact name (e.g. `summa_f32_64x64x64`).
    pub artifact: String,
    /// Team the multiply is collective over.
    pub team: TeamId,
}

impl SummaConfig {
    /// Configuration matching `summa_f32_64x64x64`.
    pub fn block64() -> Self {
        SummaConfig {
            mb: 64,
            kb: 64,
            nb: 64,
            artifact: "summa_f32_64x64x64".into(),
            team: crate::dart::DART_TEAM_ALL,
        }
    }
}

/// Per-unit result.
#[derive(Debug, Clone)]
pub struct SummaReport {
    /// My `mb × nb` block of C.
    pub c_local: Vec<f32>,
    /// Frobenius-norm checksum of the global C (identical on all units).
    pub global_norm: f64,
}

/// Deterministic test matrices: `A[i,j] = sin((i−j)/20)·0.1` (global
/// indices) — dense, structured, reproducible.
pub fn a_entry(i: usize, j: usize) -> f32 {
    ((i as f32 - j as f32) * 0.05).sin() * 0.1
}

/// `B[i,j] = cos((i+j)/20)·0.1` — the matching deterministic B matrix.
pub fn b_entry(i: usize, j: usize) -> f32 {
    ((i + j) as f32 * 0.05).cos() * 0.1
}

/// Run SUMMA on the calling unit. Collective over `cfg.team`.
pub fn run_distributed(env: &DartEnv, engine: &Engine, cfg: &SummaConfig) -> DartResult<SummaReport> {
    let team = cfg.team;
    let p = env.team_size(team)?;
    let me = env.team_myid(team)?;
    let (mb, kb, nb) = (cfg.mb, cfg.kb, cfg.nb);
    let k_total = kb * p;

    let exe = engine
        .load(&cfg.artifact)
        .map_err(|e| DartErr::Invalid(format!("artifact {}: {e}", cfg.artifact)))?;

    // My K-panel of B (kb × nb, row-major) and my A row-block live in
    // ordinary local memory; panels travel by (pipelined) broadcast.
    let my_b: Vec<f32> = (0..kb * nb).map(|i| b_entry(me * kb + i / nb, i % nb)).collect();
    let a_local: Vec<f32> =
        (0..mb * k_total).map(|i| a_entry(me * mb + i / k_total, i % k_total)).collect();

    env.barrier(team)?;

    let mut c_local = vec![0f32; mb * nb];
    let mut b_panel = vec![0f32; kb * nb];
    let mut b_next = vec![0f32; kb * nb];
    let mut a_panel = vec![0f32; mb * kb];
    // Prologue: panel 0 arrives by blocking broadcast (nothing to overlap
    // with yet). Panel `q` is owned by team rank `q`.
    if me == 0 {
        b_panel.copy_from_slice(&my_b);
    }
    env.bcast(team, as_bytes_mut(&mut b_panel), 0)?;
    for panel in 0..p {
        // Pipeline: initiate the nonblocking broadcast of panel `panel+1`
        // before computing on `panel`; the schedule advances while the
        // GEMM runs (Thread/Polling progress modes).
        let next_bcast = if panel + 1 < p {
            if me == panel + 1 {
                b_next.copy_from_slice(&my_b);
            }
            Some(env.bcast_async(team, as_bytes_mut(&mut b_next), panel + 1)?)
        } else {
            None
        };
        // Slice my A columns for this panel.
        for r in 0..mb {
            let src = &a_local[r * k_total + panel * kb..r * k_total + (panel + 1) * kb];
            a_panel[r * kb..(r + 1) * kb].copy_from_slice(src);
        }
        // C += A_panel @ B_panel on the compute engine.
        let outs = exe
            .run_f32(&[&c_local, &a_panel, &b_panel])
            .map_err(|e| DartErr::Invalid(format!("artifact execution: {e}")))?;
        c_local.copy_from_slice(&outs[0]);
        if let Some(h) = next_bcast {
            // Complete the pipelined broadcast, then rotate the buffers.
            env.coll_wait(h)?;
            std::mem::swap(&mut b_panel, &mut b_next);
        }
    }

    let local_sq: f64 = c_local.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let mut global_sq = [0f64];
    env.allreduce(team, &[local_sq], &mut global_sq, crate::mpisim::MpiOp::Sum)?;
    env.barrier(team)?;
    Ok(SummaReport { c_local, global_norm: global_sq[0].sqrt() })
}

/// Single-threaded reference: the full `C` for a `P`-unit problem.
pub fn reference(p: usize, mb: usize, kb: usize, nb: usize) -> Vec<f32> {
    let (m, k) = (mb * p, kb * p);
    let mut c = vec![0f32; m * nb];
    for i in 0..m {
        for kk in 0..k {
            let a = a_entry(i, kk);
            for j in 0..nb {
                c[i * nb + j] += a * b_entry(kk, j);
            }
        }
    }
    c
}
