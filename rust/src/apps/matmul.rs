//! Distributed SUMMA matrix multiply over the DART PGAS.
//!
//! `C = A @ B` with `A (M×K)` row-distributed, `B (K×N)` row-distributed
//! (one K-panel per unit) and `C (M×N)` row-distributed. SUMMA iterates
//! over K-panels: at step `p`, every unit *one-sidedly gets* panel `p` of
//! `B` from its owner's segment of the collective allocation — a pure PGAS
//! formulation: the owner does not participate (no bcast) — and
//! accumulates `C_u += A_u[:, panel p] @ B_panel` with the AOT
//! `summa_f32_*` artifact (L1 Pallas GEMM tile inside an L2 JAX step).
//!
//! Panel fetches run on the engine's batched-flush API
//! ([`crate::dart::DartEnv::get_async`] +
//! [`crate::dart::DartEnv::flush`]): panel `p+1` streams in while panel
//! `p` computes, overlapping communication with the GEMM.

use crate::dart::{DartEnv, DartErr, DartResult, TeamId};
use crate::mpisim::{as_bytes, as_bytes_mut};
use crate::runtime::Engine;

/// Parameters of a distributed SUMMA run. With `P` units the global
/// problem is `M = mb·P`, `K = kb·P`, `N = nb`.
#[derive(Debug, Clone)]
pub struct SummaConfig {
    /// Rows of C (and A) per unit.
    pub mb: usize,
    /// Rows of B (columns of A) per unit — the K-panel depth.
    pub kb: usize,
    /// Full width of C and B.
    pub nb: usize,
    /// Artifact name (e.g. `summa_f32_64x64x64`).
    pub artifact: String,
    pub team: TeamId,
}

impl SummaConfig {
    /// Configuration matching `summa_f32_64x64x64`.
    pub fn block64() -> Self {
        SummaConfig {
            mb: 64,
            kb: 64,
            nb: 64,
            artifact: "summa_f32_64x64x64".into(),
            team: crate::dart::DART_TEAM_ALL,
        }
    }
}

/// Per-unit result.
#[derive(Debug, Clone)]
pub struct SummaReport {
    /// My `mb × nb` block of C.
    pub c_local: Vec<f32>,
    /// Frobenius-norm checksum of the global C (identical on all units).
    pub global_norm: f64,
}

/// Deterministic test matrices: `A[i,j] = sin(i−j)·0.1`, `B[i,j] =
/// cos(i+j)·0.1` (global indices) — dense, structured, reproducible.
pub fn a_entry(i: usize, j: usize) -> f32 {
    ((i as f32 - j as f32) * 0.05).sin() * 0.1
}

pub fn b_entry(i: usize, j: usize) -> f32 {
    ((i + j) as f32 * 0.05).cos() * 0.1
}

/// Run SUMMA on the calling unit. Collective over `cfg.team`.
pub fn run_distributed(env: &DartEnv, engine: &Engine, cfg: &SummaConfig) -> DartResult<SummaReport> {
    let team = cfg.team;
    let p = env.team_size(team)?;
    let me = env.team_myid(team)?;
    let (mb, kb, nb) = (cfg.mb, cfg.kb, cfg.nb);
    let k_total = kb * p;

    let exe = engine
        .load(&cfg.artifact)
        .map_err(|e| DartErr::Invalid(format!("artifact {}: {e}", cfg.artifact)))?;

    // B is PGAS-resident: one aligned collective allocation, unit u's
    // segment holds K-panel u (kb × nb, row-major).
    let b_panel_bytes = (kb * nb * 4) as u64;
    let b_grid = env.team_memalloc_aligned(team, b_panel_bytes)?;
    let my_b: Vec<f32> =
        (0..kb * nb).map(|i| b_entry(me * kb + i / nb, i % nb)).collect();
    env.local_write(b_grid.with_unit(env.team_unit_l2g(team, me)?), as_bytes(&my_b))?;

    // A row-block lives in ordinary local memory (no one else reads it).
    let a_local: Vec<f32> =
        (0..mb * k_total).map(|i| a_entry(me * mb + i / k_total, i % k_total)).collect();

    env.barrier(team)?;

    let mut c_local = vec![0f32; mb * nb];
    let mut b_panel = vec![0f32; kb * nb];
    let mut b_next = vec![0f32; kb * nb];
    let mut a_panel = vec![0f32; mb * kb];
    // Panel pipeline on the engine's batched-flush API: fetch panel `p+1`
    // in deferred-completion mode while panel `p` computes, and pay the
    // remote-completion wait (`dart_flush`) only right before the data is
    // consumed. The owner still never participates (pure PGAS).
    let owner_of = |panel: usize| env.team_unit_l2g(team, panel);
    env.get_blocking(b_grid.with_unit(owner_of(0)?), as_bytes_mut(&mut b_panel))?;
    for panel in 0..p {
        // Prefetch the next panel before computing on the current one.
        if panel + 1 < p {
            let next_owner = owner_of(panel + 1)?;
            env.get_async(b_grid.with_unit(next_owner), as_bytes_mut(&mut b_next))?;
        }
        // Slice my A columns for this panel.
        for r in 0..mb {
            let src = &a_local[r * k_total + panel * kb..r * k_total + (panel + 1) * kb];
            a_panel[r * kb..(r + 1) * kb].copy_from_slice(src);
        }
        // C += A_panel @ B_panel on the compute engine.
        let outs = exe
            .run_f32(&[&c_local, &a_panel, &b_panel])
            .map_err(|e| DartErr::Invalid(format!("artifact execution: {e}")))?;
        c_local.copy_from_slice(&outs[0]);
        if panel + 1 < p {
            // Complete the prefetch, then rotate the buffers.
            env.flush(b_grid.with_unit(owner_of(panel + 1)?))?;
            std::mem::swap(&mut b_panel, &mut b_next);
        }
    }

    let local_sq: f64 = c_local.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let mut global_sq = [0f64];
    env.allreduce(team, &[local_sq], &mut global_sq, crate::mpisim::MpiOp::Sum)?;
    env.barrier(team)?;
    env.team_memfree(team, b_grid)?;
    Ok(SummaReport { c_local, global_norm: global_sq[0].sqrt() })
}

/// Single-threaded reference: the full `C` for a `P`-unit problem.
pub fn reference(p: usize, mb: usize, kb: usize, nb: usize) -> Vec<f32> {
    let (m, k) = (mb * p, kb * p);
    let mut c = vec![0f32; m * nb];
    for i in 0..m {
        for kk in 0..k {
            let a = a_entry(i, kk);
            for j in 0..nb {
                c[i * nb + j] += a * b_entry(kk, j);
            }
        }
    }
    c
}
