//! Graph500-style breadth-first search over [`crate::dash::Graph`] —
//! the first workload whose communication pattern is decided by the
//! data, not the programmer.
//!
//! Level-synchronous BFS with **CAS-claimed parents**: each round, every
//! unit walks its owned frontier rows through the zero-network local CSR
//! and races one [`crate::dash::Array::compare_and_swap`] per candidate
//! `(target, parent)` pair against the distributed parent array (`-1` →
//! parent). Whichever claim wins, the *level* a vertex receives is its
//! true BFS distance: claims in round `L` originate only from
//! distance-`L` frontier vertices, so level assignment is deterministic
//! even though the parent tree is race-dependent. Owners then scan their
//! partition for newly-claimed rows (the next frontier), and one
//! `allreduce` of the frontier size decides termination — the classic
//! DART-paper mix of fine-grained atomics and coarse collectives.
//!
//! With `combine` enabled, the locality split
//! ([`crate::dart::DartEnv::team_split_locality`], node scope) turns the
//! claim phase two-level: members of a node allgather their candidate
//! lists intra-node, dedup the union by target, and partition the
//! surviving claims round-robin — so one claim per (node, target)
//! crosses the interconnect instead of one per (unit, target). Candidate
//! dedup can only drop duplicate claims, so levels — and the whole
//! [`BfsSummary`] — are bit-identical with and without combining, which
//! the cross-configuration tests pin down.
//!
//! Everything is oracle-backed: [`reference_levels`] replays the same
//! seeded R-MAT edge stream sequentially, and [`run_checked`] verifies
//! level-by-level agreement, parent-edge existence (via coalesced remote
//! adjacency pulls), and level monotonicity along parent edges.

use crate::dart::{DartEnv, DartErr, DartResult, LocalityScope, TeamId, DART_TEAM_ALL};
use crate::dash::{Array, Graph, GraphConfig};
use crate::mpisim::{as_bytes, as_bytes_mut, MpiOp};

/// Parameters of a distributed BFS run.
#[derive(Debug, Clone)]
pub struct BfsConfig {
    /// The seeded R-MAT graph to build and traverse.
    pub graph: GraphConfig,
    /// Root vertex (must be `< graph.nverts()`).
    pub root: usize,
    /// Combine candidate claims intra-node before CASing (the locality-
    /// aware two-level claim phase). Levels are identical either way.
    pub combine: bool,
    /// Team the run is collective over.
    pub team: TeamId,
}

impl BfsConfig {
    /// A small default configuration over `DART_TEAM_ALL`.
    pub fn quick(scale: u32, edge_factor: usize, seed: u64) -> Self {
        BfsConfig {
            graph: GraphConfig { scale, edge_factor, seed },
            root: 0,
            combine: false,
            team: DART_TEAM_ALL,
        }
    }
}

/// The configuration-independent part of a BFS result: identical across
/// flat/hierarchical collectives, fastpath on/off, exec modes, and
/// combine on/off — the quantity the agreement tests compare.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BfsSummary {
    /// Vertices reached from the root (root included).
    pub reached: u64,
    /// Largest assigned level (0 if only the root is reachable).
    pub max_level: i64,
    /// Order-independent checksum `Σ (v+1)·(level(v)+1)` over reached
    /// vertices (wrapping).
    pub checksum: u64,
}

/// Result of a distributed BFS run (identical on every unit).
#[derive(Debug, Clone)]
pub struct BfsReport {
    /// The deterministic, race-independent traversal summary.
    pub summary: BfsSummary,
    /// Level-synchronous rounds executed (= `max_level` + 1, plus the
    /// empty terminating round).
    pub rounds: u64,
    /// CAS claims issued across the team (race- and config-dependent:
    /// intra-node combining lowers it).
    pub claim_attempts: u64,
    /// Directed edges stored across the team after dedup.
    pub nedges_stored: u64,
}

/// Sequential oracle: BFS levels (`-1` = unreached) over the identical
/// seeded edge stream the distributed build replays.
pub fn reference_levels(cfg: &GraphConfig, root: usize) -> Vec<i64> {
    let n = cfg.nverts();
    let mut adj: Vec<Vec<u64>> = vec![Vec::new(); n];
    for (a, b) in crate::dash::graph::edges(cfg) {
        if a != b {
            adj[a as usize].push(b);
            adj[b as usize].push(a);
        }
    }
    let mut levels = vec![-1i64; n];
    levels[root] = 0;
    let mut frontier = vec![root];
    let mut next = Vec::new();
    let mut level = 0i64;
    while !frontier.is_empty() {
        for &u in &frontier {
            for &v in &adj[u] {
                if levels[v as usize] == -1 {
                    levels[v as usize] = level + 1;
                    next.push(v as usize);
                }
            }
        }
        level += 1;
        std::mem::swap(&mut frontier, &mut next);
        next.clear();
    }
    levels
}

/// The [`BfsSummary`] a level vector implies — shared by the oracle and
/// the distributed run so the comparison is definitionally fair.
pub fn summarize_levels(levels: &[i64]) -> BfsSummary {
    let mut reached = 0u64;
    let mut max_level = 0i64;
    let mut checksum = 0u64;
    for (v, &l) in levels.iter().enumerate() {
        if l >= 0 {
            reached += 1;
            max_level = max_level.max(l);
            checksum = checksum.wrapping_add((v as u64 + 1).wrapping_mul(l as u64 + 1));
        }
    }
    BfsSummary { reached, max_level, checksum }
}

/// What the oracle predicts for `cfg` — compare against
/// [`BfsReport::summary`].
pub fn reference_summary(cfg: &BfsConfig) -> BfsSummary {
    summarize_levels(&reference_levels(&cfg.graph, cfg.root))
}

/// The distributed traversal core. Returns the report plus the level
/// and parent arrays (still allocated) and the graph, so callers can
/// validate before freeing.
fn bfs_core<'e>(
    env: &'e DartEnv,
    cfg: &BfsConfig,
) -> DartResult<(BfsReport, Array<'e, i64>, Array<'e, i64>, Graph<'e>)> {
    let n = cfg.graph.nverts();
    if cfg.root >= n {
        return Err(DartErr::Invalid(format!("BFS root {} out of 0..{n}", cfg.root)));
    }
    let team = cfg.team;
    let graph = Graph::build(env, team, cfg.graph)?;
    let parent: Array<'e, i64> = Array::new(env, team, *graph.pattern())?;
    let level: Array<'e, i64> = Array::new(env, team, *graph.pattern())?;
    let rows = graph.my_rows();
    // Initialize owner-locally: parent/level -1 everywhere, root claimed
    // by itself at level 0 (Graph500 convention parent[root] = root).
    let root = cfg.root;
    parent.with_local(|buf| buf.fill(-1))?;
    level.with_local(|buf| buf.fill(-1))?;
    if rows.contains(&root) {
        let l = root - rows.start;
        parent.with_local(|buf| buf[l] = root as i64)?;
        level.with_local(|buf| buf[l] = 0)?;
    }
    env.barrier(team)?;

    let split = if cfg.combine {
        Some(env.team_split_locality(team, LocalityScope::Node)?)
    } else {
        None
    };

    let mut frontier: Vec<usize> = if rows.contains(&root) { vec![root] } else { Vec::new() };
    let mut claim_attempts = 0u64;
    let mut rounds = 0u64;
    let mut cur_level = 0i64;
    loop {
        rounds += 1;
        // Candidate (target, parent) pairs from my owned frontier rows —
        // pure local CSR traversal, deduped by target.
        let mut cands: Vec<(u64, u64)> = Vec::new();
        for &u in &frontier {
            for &v in graph.local_neighbors(u)? {
                cands.push((v, u as u64));
            }
        }
        cands.sort_unstable();
        cands.dedup_by_key(|c| c.0);

        // Two-level claim phase: union the node's candidates, dedup by
        // target, and split the survivors round-robin so each claim
        // leaves the node at most once.
        if let Some(split) = &split {
            let lp = env.team_size(split.local)?;
            if lp > 1 {
                let mut counts = vec![0u64; lp];
                env.allgather(
                    split.local,
                    as_bytes(&[cands.len() as u64]),
                    as_bytes_mut(&mut counts),
                )?;
                let maxc = counts.iter().copied().max().unwrap_or(0) as usize;
                if maxc > 0 {
                    let mut send = vec![u64::MAX; 2 * maxc];
                    for (i, &(t, par)) in cands.iter().enumerate() {
                        send[2 * i] = t;
                        send[2 * i + 1] = par;
                    }
                    let mut recv = vec![0u64; 2 * maxc * lp];
                    env.allgather(split.local, as_bytes(&send), as_bytes_mut(&mut recv))?;
                    let mut merged: Vec<(u64, u64)> = Vec::new();
                    for (r, &count) in counts.iter().enumerate() {
                        let base = 2 * maxc * r;
                        for i in 0..count as usize {
                            merged.push((recv[base + 2 * i], recv[base + 2 * i + 1]));
                        }
                    }
                    merged.sort_unstable();
                    merged.dedup_by_key(|c| c.0);
                    let my_lrank = env.team_myid(split.local)?;
                    cands = merged
                        .into_iter()
                        .enumerate()
                        .filter(|(i, _)| i % lp == my_lrank)
                        .map(|(_, c)| c)
                        .collect();
                } else {
                    cands.clear();
                }
            }
        }

        // Race the claims. A lost race (old != -1) means the target was
        // reached this round by someone else or an earlier round — both
        // leave its level correct.
        for &(v, par) in &cands {
            parent.compare_and_swap(v as usize, -1, par as i64)?;
            claim_attempts += 1;
        }
        env.barrier(team)?;

        // Owners scan for newly-claimed rows: parent set, level not yet.
        let parents = parent.read_local()?;
        let mut next: Vec<usize> = Vec::new();
        level.with_local(|levels| {
            for (l, &p) in parents.iter().enumerate() {
                if p != -1 && levels[l] == -1 {
                    levels[l] = cur_level + 1;
                    next.push(rows.start + l);
                }
            }
        })?;
        let mut total = [0u64];
        env.allreduce(team, &[next.len() as u64], &mut total, MpiOp::Sum)?;
        if total[0] == 0 {
            break;
        }
        frontier = next;
        cur_level += 1;
        if cur_level > n as i64 {
            return Err(DartErr::Invalid("BFS failed to terminate".into()));
        }
    }

    // Replicated summary from owner-local partials.
    let my_summary = summarize_levels_at(&level.read_local()?, rows.start);
    let mut sums = [0u64; 3];
    env.allreduce(
        team,
        &[my_summary.reached, my_summary.checksum, graph.local_edge_count() as u64],
        &mut sums,
        MpiOp::Sum,
    )?;
    let mut maxes = [0i64];
    env.allreduce(team, &[my_summary.max_level], &mut maxes, MpiOp::Max)?;
    let mut attempts = [0u64];
    env.allreduce(team, &[claim_attempts], &mut attempts, MpiOp::Sum)?;
    let report = BfsReport {
        summary: BfsSummary { reached: sums[0], max_level: maxes[0], checksum: sums[1] },
        rounds,
        claim_attempts: attempts[0],
        nedges_stored: sums[2],
    };
    Ok((report, level, parent, graph))
}

/// [`summarize_levels`] over a local partition whose first global index
/// is `base` (so the checksum terms use global vertex ids).
fn summarize_levels_at(local: &[i64], base: usize) -> BfsSummary {
    let mut s = BfsSummary { reached: 0, max_level: 0, checksum: 0 };
    for (l, &lv) in local.iter().enumerate() {
        if lv >= 0 {
            s.reached += 1;
            s.max_level = s.max_level.max(lv);
            let v = (base + l) as u64;
            s.checksum = s.checksum.wrapping_add((v + 1).wrapping_mul(lv as u64 + 1));
        }
    }
    s
}

/// Run the distributed BFS. Collective over `cfg.team`; every unit
/// returns the same report.
pub fn run_distributed(env: &DartEnv, cfg: &BfsConfig) -> DartResult<BfsReport> {
    let (report, level, parent, graph) = bfs_core(env, cfg)?;
    level.free()?;
    parent.free()?;
    graph.free()?;
    Ok(report)
}

/// Run the distributed BFS and verify it against the sequential oracle
/// *in place*: owner-local levels must match [`reference_levels`]
/// exactly, every claimed parent edge must exist in the graph (checked
/// through coalesced remote adjacency pulls), parent levels must be
/// exactly one less than their child's, and unreached vertices must
/// stay unclaimed. Returns the report, or an `Err` naming the first
/// violated invariant.
pub fn run_checked(env: &DartEnv, cfg: &BfsConfig) -> DartResult<BfsReport> {
    let (report, level, parent, graph) = bfs_core(env, cfg)?;
    let oracle = reference_levels(&cfg.graph, cfg.root);
    let rows = graph.my_rows();
    let levels = level.read_local()?;
    let parents = parent.read_local()?;
    let mut verdict: DartResult<()> = Ok(());
    'scan: for (l, (&lv, &par)) in levels.iter().zip(&parents).enumerate() {
        let v = rows.start + l;
        if lv != oracle[v] {
            verdict = Err(DartErr::Invalid(format!(
                "level[{v}] = {lv}, oracle says {}",
                oracle[v]
            )));
            break 'scan;
        }
        if lv == -1 {
            if par != -1 {
                verdict = Err(DartErr::Invalid(format!("unreached {v} has parent {par}")));
                break 'scan;
            }
            continue;
        }
        if v == cfg.root {
            if par != cfg.root as i64 {
                verdict = Err(DartErr::Invalid(format!("root parent is {par}")));
                break 'scan;
            }
            continue;
        }
        let par = par as usize;
        if par >= graph.nverts() || oracle[par] != lv - 1 {
            verdict = Err(DartErr::Invalid(format!(
                "parent[{v}] = {par} breaks level monotonicity at level {lv}"
            )));
            break 'scan;
        }
        // Edge existence through the remote-pull path (neighbor lists
        // are sorted, so binary search is exact).
        if graph.get_neighbors(par)?.binary_search(&(v as u64)).is_err() {
            verdict = Err(DartErr::Invalid(format!("parent edge {par} → {v} does not exist")));
            break 'scan;
        }
    }
    // Surface everyone's verdict before freeing (collective), so one
    // failing unit cannot leave the team wedged in `free`.
    let failed = u64::from(verdict.is_err());
    let mut any = [0u64];
    env.allreduce(cfg.team, &[failed], &mut any, MpiOp::Max)?;
    level.free()?;
    parent.free()?;
    graph.free()?;
    verdict?;
    if any[0] != 0 {
        return Err(DartErr::Invalid("BFS validation failed on another unit".into()));
    }
    Ok(report)
}
