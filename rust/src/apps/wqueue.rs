//! Dynamic task farm — the work-stealing workload over
//! [`crate::dash::WorkQueue`] and the dynamic-memory subsystem.
//!
//! A deterministic set of tasks (seeded, deliberately **skewed**: early
//! producers seed far more work than late ones, so an ideal static
//! partition does not exist) is enqueued across the per-unit rings; every
//! unit then pops until the farm runs dry, stealing from its neighbours'
//! rings once its own is empty. Task results land in a collective results
//! array via deferred atomic accumulates.
//!
//! Termination uses the standard distributed-counter idiom: an empty
//! sweep of all rings is only a moment-in-time observation, so completion
//! is detected on a shared **done counter** (atomic `fetch_and_op` in
//! symmetric memory) that producers advance as they enqueue and consumers
//! advance as they retire — when `retired == enqueued_total` the farm is
//! drained for good.
//!
//! Everything is verifiable: task payloads are pure functions of the
//! seed, so [`reference_result`] replays the whole farm sequentially and
//! the distributed run must match it exactly — every task executed
//! exactly once, regardless of which unit stole it.

use crate::dart::{DartEnv, DartErr, DartResult, TeamId, DART_TEAM_ALL};
use crate::dash::WorkQueue;
use crate::mpisim::MpiOp;
use crate::testing::prop::Rng;

/// Parameters of a task-farm run.
#[derive(Debug, Clone)]
pub struct WqueueConfig {
    /// Total tasks enqueued across the team.
    pub tasks: usize,
    /// Slots per unit ring (small rings exercise the full/steal paths).
    pub ring_capacity: usize,
    /// Task-payload seed.
    pub seed: u64,
    /// Team the run is collective over.
    pub team: TeamId,
}

impl WqueueConfig {
    /// A small default configuration over `DART_TEAM_ALL`.
    pub fn quick(tasks: usize) -> Self {
        WqueueConfig { tasks, ring_capacity: 64, seed: 0xFA12_07A5, team: DART_TEAM_ALL }
    }
}

/// Result of a run (identical on every unit).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WqueueReport {
    /// Tasks retired across the team (must equal the configured total).
    pub retired: u64,
    /// Order-independent checksum over every task's computed result.
    pub checksum: u64,
    /// Pops this unit served from a remote ring (its share of
    /// `Metrics::wq_steals` growth during the run).
    pub my_steals: u64,
}

/// The task payload: a few rounds of splitmix keep it cheap but
/// order-sensitive enough that a lost or doubled task always changes the
/// checksum.
#[inline]
fn task_result(task_id: u64, seed: u64) -> u64 {
    let mut r = Rng::new(seed ^ task_id.wrapping_mul(0x9E37_79B9));
    r.next_u64() ^ task_id
}

/// How many of the `tasks` tasks producer `u` of `p` seeds: a skewed
/// front-loaded split (unit 0 the most, trailing units possibly none) —
/// the shape work stealing exists for.
fn tasks_of(u: usize, p: usize, tasks: usize) -> (u64, u64) {
    // Quadratic taper: unit i carries weight (p-i)²; unit 0 additionally
    // absorbs the rounding remainder so the counts always sum to `tasks`.
    let weights: Vec<u64> = (0..p).map(|i| ((p - i) * (p - i)) as u64).collect();
    let total_w: u64 = weights.iter().sum();
    let base: Vec<u64> = weights.iter().map(|&w| tasks as u64 * w / total_w).collect();
    let remainder = tasks as u64 - base.iter().sum::<u64>();
    let mut start = 0u64;
    for i in 0..p {
        let n = base[i] + if i == 0 { remainder } else { 0 };
        if i == u {
            return (start, n);
        }
        start += n;
    }
    unreachable!("unit {u} outside team of {p}")
}

/// Sequential reference: the checksum the farm must reproduce.
pub fn reference_result(cfg: &WqueueConfig) -> u64 {
    (0..cfg.tasks as u64).fold(0u64, |acc, t| acc ^ task_result(t, cfg.seed))
}

/// Run the distributed task farm. Collective over `cfg.team`.
pub fn run_distributed(env: &DartEnv, cfg: &WqueueConfig) -> DartResult<WqueueReport> {
    if cfg.tasks == 0 || cfg.ring_capacity == 0 {
        return Err(DartErr::Invalid("task farm needs tasks > 0 and ring slots > 0".into()));
    }
    let team = cfg.team;
    let p = env.team_size(team)?;
    let me = env.team_myid(team)?;
    let steals_before = env.metrics.wq_steals.get();

    let q = WorkQueue::new(env, team, cfg.ring_capacity)?;
    // Shared cells in symmetric memory: [retired counter, checksum].
    let cells = env.team_memalloc_aligned(team, 16)?;
    if me == 0 {
        env.local_write(cells, &[0u8; 16])?;
    }
    env.barrier(team)?;
    let retired_cell = cells;
    let checksum_cell = cells.add(8);

    // --- seed my (skewed) share of the tasks, spilling to neighbours'
    // rings when mine fills up — enqueue must never deadlock on a small
    // ring while every unit is still producing.
    let (start, count) = tasks_of(me, p, cfg.tasks);
    for t in start..start + count {
        let mut target = me;
        loop {
            if q.push_to(target, t)? {
                break;
            }
            // Ring full: drain one task myself (helps the farm along and
            // guarantees progress even with every ring full), then try
            // the next ring.
            if let Some(task) = q.pop()? {
                retire(env, &q, task, cfg.seed, retired_cell, checksum_cell, me)?;
            }
            target = (target + 1) % p;
        }
    }

    // --- consume until the farm is drained for good: the shared retired
    // counter is the termination proof, an empty sweep is only a hint.
    loop {
        if let Some(task) = q.pop()? {
            retire(env, &q, task, cfg.seed, retired_cell, checksum_cell, me)?;
            continue;
        }
        let retired = env.fetch_and_op(retired_cell, 0u64, MpiOp::NoOp)?;
        if retired >= cfg.tasks as u64 {
            break;
        }
        // Not drained — someone is still producing or mid-retire; give
        // the progress engine a tick and sweep again.
        env.progress_poll();
    }
    env.barrier(team)?;

    let retired = env.fetch_and_op(retired_cell, 0u64, MpiOp::NoOp)?;
    let checksum = env.fetch_and_op(checksum_cell, 0u64, MpiOp::NoOp)?;
    let my_steals = env.metrics.wq_steals.get() - steals_before;

    env.barrier(team)?;
    q.free()?;
    env.team_memfree(team, cells)?;
    Ok(WqueueReport { retired, checksum, my_steals })
}

/// Execute one task and publish its result: checksum XOR then the
/// retired-count increment — in that order, so `retired == total` proves
/// every result is already in the checksum cell.
fn retire(
    env: &DartEnv,
    _q: &WorkQueue<'_>,
    task: u64,
    seed: u64,
    retired_cell: crate::dart::GlobalPtr,
    checksum_cell: crate::dart::GlobalPtr,
    _me: usize,
) -> DartResult<()> {
    let result = task_result(task, seed);
    env.fetch_and_op(checksum_cell, result, MpiOp::Bxor)?;
    env.fetch_and_op(retired_cell, 1u64, MpiOp::Sum)?;
    Ok(())
}
