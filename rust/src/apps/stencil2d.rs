//! 2D-decomposed distributed stencil on the `dash` layer.
//!
//! Unlike [`crate::apps::stencil`] (1D row decomposition, contiguous row
//! halos only), this variant tiles the global grid over a `px × py` unit
//! grid. Since the `dash` port, all block bookkeeping — allocation
//! sizing, gptr arithmetic, neighbour offset math — lives in a
//! [`crate::dash::Matrix`] with a TILED [`crate::dash::Pattern`] (one
//! `b × b` tile per unit): the app asks for *global* coordinates and the
//! pattern's index maps do the rest.
//!
//! Every step exchanges **row halos**
//! ([`crate::dash::Matrix::get_row_async`] — one contiguous one-sided get
//! from the north/south neighbours) *and* **column halos**
//! ([`crate::dash::Matrix::get_col_async`] — the whole boundary column of
//! the west/east neighbours as ONE vector-typed strided get). A 5-point
//! stencil needs no corner cells, so the four halo edges suffice.
//!
//! The exchange still runs on the engine's batched-flush path: every
//! neighbour costs exactly one deferred-completion operation and a single
//! [`crate::dash::Matrix::flush`] completes the phase (asserted per-op by
//! `rust/tests/engine_tests.rs`).
//!
//! **Overlap structure** (the asynchronous-progress rewiring): the halo
//! transfers are *initiated* first, then the padded block's interior —
//! which depends only on local data — is assembled while they fly, with a
//! cooperative [`crate::dart::DartEnv::progress_poll`] between the copy
//! and the flush so the engine can retire the transfers before the flush
//! ever has to wait (`Polling`/`Thread` progress modes; in `Caller` mode
//! the poll is a no-op and the flush pays for completion, which is the
//! ablation baseline). The per-step residual reduction is a *nonblocking*
//! allreduce ([`crate::dart::DartEnv::allreduce_async`]) overlapped with
//! publishing the new block. Achieved overlap is visible in
//! [`crate::dart::Metrics::overlap_bytes`].
//!
//! The local sweep runs the same AOT Pallas artifact as the 1D app; the
//! result is verified against the sequential reference over the full
//! `py·B × px·B` grid.

use super::stencil::{initial_value, run_reference};
use crate::dart::{DartEnv, DartErr, DartResult, TeamId, DART_TEAM_ALL};
use crate::dash::Matrix;
use crate::mpisim::MpiOp;
use crate::runtime::Engine;

/// Parameters of a 2D-decomposed run. Requires `px · py == team size` and
/// a square per-unit block matching the artifact.
#[derive(Debug, Clone)]
pub struct Stencil2dConfig {
    /// Unit-grid width (columns of units).
    pub px: usize,
    /// Unit-grid height (rows of units).
    pub py: usize,
    /// Per-unit block edge (artifact input is `(block+2)²`).
    pub block: usize,
    /// Number of sweep steps.
    pub steps: usize,
    /// Artifact name (e.g. `stencil_f32_32x32`).
    pub artifact: String,
    /// Team the run is collective over.
    pub team: TeamId,
}

impl Stencil2dConfig {
    /// `px × py` units, 32×32 blocks (`stencil_f32_32x32`).
    pub fn block32(px: usize, py: usize, steps: usize) -> Self {
        Stencil2dConfig {
            px,
            py,
            block: 32,
            steps,
            artifact: "stencil_f32_32x32".into(),
            team: DART_TEAM_ALL,
        }
    }
}

/// Result (per unit; `residuals`/`global_checksum` identical everywhere).
#[derive(Debug, Clone)]
pub struct Stencil2dReport {
    /// Global residual after each step.
    pub residuals: Vec<f64>,
    /// Sum of the final global grid.
    pub global_checksum: f64,
}

/// Run the 2D-decomposed stencil. Collective over `cfg.team`.
pub fn run_distributed(
    env: &DartEnv,
    engine: &Engine,
    cfg: &Stencil2dConfig,
) -> DartResult<Stencil2dReport> {
    let team = cfg.team;
    let p = env.team_size(team)?;
    if cfg.px * cfg.py != p {
        return Err(DartErr::Invalid(format!(
            "unit grid {}×{} != team size {p}",
            cfg.px, cfg.py
        )));
    }
    let me = env.team_myid(team)?;
    let (ux, uy) = (me % cfg.px, me / cfg.px); // my unit-grid coordinate
    let b = cfg.block;
    let (rows_total, cols_total) = (cfg.py * b, cfg.px * b);
    let (row0, col0) = (uy * b, ux * b);

    let exe = engine
        .load(&cfg.artifact)
        .map_err(|e| DartErr::Invalid(format!("artifact {}: {e}", cfg.artifact)))?;
    if exe.artifact().inputs[0].dims != vec![b + 2, b + 2] {
        return Err(DartErr::Invalid(format!(
            "artifact {} expects {:?}, config block is {b}",
            cfg.artifact,
            exe.artifact().inputs[0].dims
        )));
    }

    // The distributed grid: a TILED matrix with one b×b tile per unit on
    // a py×px unit grid — team rank `uy·px + ux` is exactly the pattern's
    // unit-grid position, so the old hand-rolled neighbour/offset math
    // reduces to global coordinates.
    let grid: Matrix<'_, f32> =
        Matrix::new(env, team, rows_total, cols_total, b, b, cfg.py, cfg.px)?;
    debug_assert_eq!((grid.local_rows(), grid.local_cols()), (b, b));
    let mut local: Vec<f32> = (0..b * b)
        .map(|i| initial_value(row0 + i / b, col0 + i % b, rows_total, cols_total))
        .collect();
    grid.write_local(&local)?;
    env.barrier(team)?;

    let mut north = vec![0f32; b];
    let mut south = vec![0f32; b];
    let mut west = vec![0f32; b];
    let mut east = vec![0f32; b];
    let mut padded = vec![0f32; (b + 2) * (b + 2)];
    let mut residuals = Vec::with_capacity(cfg.steps);

    for _ in 0..cfg.steps {
        // --- halo exchange: one RMA operation per neighbour (contiguous
        // row gets, single vector-typed column gets), all in
        // deferred-completion mode; ONE flush completes the phase.
        if uy > 0 {
            grid.get_row_async(row0 - 1, col0, &mut north)?; // north's LAST row
        } else {
            north.fill(0.0);
        }
        if uy + 1 < cfg.py {
            grid.get_row_async(row0 + b, col0, &mut south)?;
        } else {
            south.fill(0.0);
        }
        if ux > 0 {
            grid.get_col_async(row0, col0 - 1, &mut west)?; // west's LAST column
        } else {
            west.fill(0.0);
        }
        if ux + 1 < cfg.px {
            grid.get_col_async(row0, col0 + b, &mut east)?; // east's FIRST column
        } else {
            east.fill(0.0);
        }
        // --- overlap: the padded interior depends only on local data, so
        // assemble it while the halo transfers fly, then give the progress
        // engine one cooperative tick before paying the flush.
        let wp = b + 2;
        padded.fill(0.0);
        for r in 0..b {
            padded[(r + 1) * wp + 1..(r + 1) * wp + 1 + b]
                .copy_from_slice(&local[r * b..(r + 1) * b]);
        }
        env.progress_poll();
        grid.flush()?;

        // --- halo edges now that the transfers have landed (corners are
        // unused by the 5-point sweep).
        padded[1..1 + b].copy_from_slice(&north);
        for r in 0..b {
            padded[(r + 1) * wp] = west[r];
            padded[(r + 1) * wp + 1 + b] = east[r];
        }
        padded[(b + 1) * wp + 1..(b + 1) * wp + 1 + b].copy_from_slice(&south);

        // --- local sweep on PJRT + nonblocking residual reduction,
        // overlapped with publishing the new block to the segment.
        let outs = exe
            .run_f32(&[&padded])
            .map_err(|e| DartErr::Invalid(format!("artifact execution: {e}")))?;
        local.copy_from_slice(&outs[0]);
        let mut global_res = [0f64];
        let res_h = env.allreduce_async(team, &[outs[1][0] as f64], &mut global_res, MpiOp::Sum)?;
        // The blocking allreduce this replaces doubled as the barrier that
        // kept a fast unit from overwriting its published block while a
        // slow neighbour was still halo-reading it; with the reduction now
        // asynchronous, that ordering needs an explicit barrier before the
        // write (and the usual one after, so the publication is visible
        // before the next step's gets). The in-flight allreduce overlaps
        // both barriers and the write itself.
        env.barrier(team)?;
        grid.write_local(&local)?;
        env.barrier(team)?;
        env.coll_wait(res_h)?;
        residuals.push(global_res[0]);
    }

    let local_sum: f64 = local.iter().map(|&v| v as f64).sum();
    let mut global = [0f64];
    env.allreduce(team, &[local_sum], &mut global, MpiOp::Sum)?;
    env.barrier(team)?;
    grid.free()?;
    Ok(Stencil2dReport { residuals, global_checksum: global[0] })
}

/// Sequential reference checksum for a `px × py` unit grid of `block²`
/// blocks after `steps` sweeps (delegates to the 1D app's reference —
/// the decomposition must not change the math).
pub fn reference_checksum(cfg: &Stencil2dConfig) -> f64 {
    let (grid, _) = run_reference(cfg.py * cfg.block, cfg.px * cfg.block, cfg.steps, 0.25);
    grid.iter().map(|&v| v as f64).sum()
}
