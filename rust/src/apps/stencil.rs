//! Distributed 2D heat diffusion over the DART PGAS: the end-to-end
//! application that composes all three layers.
//!
//! The global `rows × width` grid is distributed row-wise over the team:
//! every unit owns a `local_rows × width` block stored in a *collective
//! aligned* global allocation, so any unit can address any other unit's
//! rows by global pointer arithmetic alone (no communication, §III).
//!
//! Per step:
//! 1. **halo exchange** — one-sided `dart_get` of the neighbouring units'
//!    boundary rows (non-blocking handles + `waitall`);
//! 2. **local sweep** — the AOT-compiled JAX/Pallas stencil artifact
//!    executes on the unit's PJRT engine (L1+L2), returning the updated
//!    interior and the local squared-residual;
//! 3. **reduction** — `dart_allreduce` of the residual drives the
//!    convergence log;
//! 4. write-back into the global allocation and `dart_barrier`.
//!
//! Fixed (zero) boundary conditions on the global border.

use crate::dart::{DartEnv, DartErr, DartResult, TeamId};
use crate::mpisim::{as_bytes, as_bytes_mut, MpiOp};
use crate::runtime::Engine;

/// Parameters of a distributed stencil run.
#[derive(Debug, Clone)]
pub struct StencilConfig {
    /// Rows per unit (must match the artifact's input height − 2).
    pub local_rows: usize,
    /// Grid width (must match the artifact's input width − 2).
    pub width: usize,
    /// Diffusion steps (halo exchanges).
    pub steps: usize,
    /// Artifact name (e.g. `stencil_f32_64x64`).
    pub artifact: String,
    /// Team to run on.
    pub team: TeamId,
}

impl StencilConfig {
    /// The configuration matching the `stencil_f32_64x64` artifact.
    pub fn block64(steps: usize) -> Self {
        StencilConfig {
            local_rows: 64,
            width: 64,
            steps,
            artifact: "stencil_f32_64x64".into(),
            team: crate::dart::DART_TEAM_ALL,
        }
    }

    /// The small test configuration (`stencil_f32_32x32`).
    pub fn block32(steps: usize) -> Self {
        StencilConfig {
            local_rows: 32,
            width: 32,
            steps,
            artifact: "stencil_f32_32x32".into(),
            team: crate::dart::DART_TEAM_ALL,
        }
    }
}

/// Result of a distributed run (per unit; identical on all units for the
/// residual series).
#[derive(Debug, Clone)]
pub struct StencilReport {
    /// Global squared residual after each step (the "loss curve").
    pub residuals: Vec<f64>,
    /// Sum of the unit's final block (combine with allreduce for a global
    /// checksum).
    pub local_checksum: f64,
    /// Global checksum (sum over all blocks).
    pub global_checksum: f64,
}

/// Deterministic initial condition: a hot square in the global interior.
/// `row` is the global row index.
pub fn initial_value(row: usize, col: usize, rows_total: usize, width: usize) -> f32 {
    let hot_r = rows_total / 4..rows_total / 2;
    let hot_c = width / 4..width / 2;
    if hot_r.contains(&row) && hot_c.contains(&col) {
        100.0
    } else {
        0.0
    }
}

/// Run the distributed stencil on the calling unit. Collective over
/// `cfg.team`; every member must call with identical `cfg`.
pub fn run_distributed(env: &DartEnv, engine: &Engine, cfg: &StencilConfig) -> DartResult<StencilReport> {
    let team = cfg.team;
    let p = env.team_size(team)?;
    let me = env.team_myid(team)?;
    let (lr, w) = (cfg.local_rows, cfg.width);
    let rows_total = lr * p;
    let row0 = me * lr; // my first global row

    let exe = engine
        .load(&cfg.artifact)
        .map_err(|e| DartErr::Invalid(format!("artifact {}: {e}", cfg.artifact)))?;
    let sig = &exe.artifact().inputs[0];
    if sig.dims != vec![lr + 2, w + 2] {
        return Err(DartErr::Invalid(format!(
            "artifact {} expects {:?}, config is {}x{}",
            cfg.artifact,
            sig.dims,
            lr + 2,
            w + 2
        )));
    }

    // The distributed grid: one aligned collective allocation, my segment
    // holds my block row-major.
    let block_bytes = (lr * w * 4) as u64;
    let grid = env.team_memalloc_aligned(team, block_bytes)?;
    let my_block = grid.with_unit(env.team_unit_l2g(team, me)?);

    // Initial condition.
    let mut local: Vec<f32> = (0..lr * w)
        .map(|i| initial_value(row0 + i / w, i % w, rows_total, w))
        .collect();
    env.local_write(my_block, as_bytes(&local))?;
    env.barrier(team)?;

    let row_bytes = w * 4;
    let mut padded = vec![0f32; (lr + 2) * (w + 2)];
    let mut top_halo = vec![0f32; w];
    let mut bot_halo = vec![0f32; w];
    let mut residuals = Vec::with_capacity(cfg.steps);

    for _step in 0..cfg.steps {
        // --- 1. halo exchange: one-sided gets from the neighbours.
        let mut handles = Vec::with_capacity(2);
        if me > 0 {
            let up = env.team_unit_l2g(team, me - 1)?;
            // neighbour's LAST row
            let src = grid.with_unit(up).add(((lr - 1) * row_bytes) as u64);
            handles.push(env.get(src, as_bytes_mut(&mut top_halo))?);
        } else {
            top_halo.fill(0.0);
        }
        if me + 1 < p {
            let down = env.team_unit_l2g(team, me + 1)?;
            // neighbour's FIRST row
            let src = grid.with_unit(down);
            handles.push(env.get(src, as_bytes_mut(&mut bot_halo))?);
        } else {
            bot_halo.fill(0.0);
        }
        env.waitall(handles)?;

        // --- 2. assemble the padded block (zero left/right boundary).
        padded.fill(0.0);
        let wp = w + 2;
        padded[1..1 + w].copy_from_slice(&top_halo);
        for r in 0..lr {
            padded[(r + 1) * wp + 1..(r + 1) * wp + 1 + w]
                .copy_from_slice(&local[r * w..(r + 1) * w]);
        }
        padded[(lr + 1) * wp + 1..(lr + 1) * wp + 1 + w].copy_from_slice(&bot_halo);

        // --- 3. local sweep on the PJRT engine (L1 Pallas + L2 JAX).
        let outs = exe
            .run_f32(&[&padded])
            .map_err(|e| DartErr::Invalid(format!("artifact execution: {e}")))?;
        local.copy_from_slice(&outs[0]);
        let local_res = outs[1][0] as f64;

        // --- 4. global residual + write-back + step barrier.
        let mut global_res = [0f64];
        env.allreduce(team, &[local_res], &mut global_res, MpiOp::Sum)?;
        residuals.push(global_res[0]);
        env.local_write(my_block, as_bytes(&local))?;
        env.barrier(team)?;
    }

    let local_checksum: f64 = local.iter().map(|&v| v as f64).sum();
    let mut global_checksum = [0f64];
    env.allreduce(team, &[local_checksum], &mut global_checksum, MpiOp::Sum)?;
    env.barrier(team)?;
    env.team_memfree(team, grid)?;
    Ok(StencilReport { residuals, local_checksum, global_checksum: global_checksum[0] })
}

/// Single-threaded reference of the same computation (zero boundary),
/// used by the end-to-end tests and the example's verification step.
pub fn run_reference(rows: usize, width: usize, steps: usize, alpha: f32) -> (Vec<f32>, Vec<f64>) {
    let mut grid: Vec<f32> = (0..rows * width)
        .map(|i| initial_value(i / width, i % width, rows, width))
        .collect();
    let mut residuals = Vec::with_capacity(steps);
    let at = |g: &Vec<f32>, r: i64, c: i64| -> f32 {
        if r < 0 || c < 0 || r >= rows as i64 || c >= width as i64 {
            0.0
        } else {
            g[r as usize * width + c as usize]
        }
    };
    for _ in 0..steps {
        let mut next = vec![0f32; rows * width];
        let mut res = 0f64;
        for r in 0..rows as i64 {
            for c in 0..width as i64 {
                let center = at(&grid, r, c);
                let v = center
                    + alpha
                        * (at(&grid, r - 1, c) + at(&grid, r + 1, c) + at(&grid, r, c - 1)
                            + at(&grid, r, c + 1)
                            - 4.0 * center);
                next[r as usize * width + c as usize] = v;
                res += ((v - center) as f64).powi(2);
            }
        }
        grid = next;
        residuals.push(res);
    }
    (grid, residuals)
}
