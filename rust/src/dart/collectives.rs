//! DART collective communication (§III, §IV-B5) — blocking and
//! nonblocking.
//!
//! "The semantics of DART collective routines are the same as that of MPI.
//! Therefore, we can implement the DART collective interfaces
//! straightforwardly by using the MPI-3 collective counterparts. Before
//! calling the MPI-3 collective counterparts, we need to determine the
//! communicator based on the given teamID." — which is exactly what every
//! function here does: teamlist lookup, then delegate.
//!
//! The **nonblocking** family ([`DartEnv::barrier_async`],
//! [`DartEnv::bcast_async`], [`DartEnv::allgather_async`],
//! [`DartEnv::allreduce_async`]) delegates the same way to the substrate's
//! `MPI_I*` state machines ([`crate::mpisim::icoll`]) and returns a
//! [`DartCollHandle`] completed through the `coll_test`/`coll_test_all` /
//! `coll_wait`/`coll_wait_all` family — the collective mirror of the
//! one-sided `test`/`wait` handles. In `Thread`/`Polling` progress modes
//! the collective advances in the background while the unit computes.
//!
//! Roots are given as *team-relative* ranks (like MPI); use
//! [`crate::dart::DartEnv::team_unit_g2l`] to translate an absolute unit.

use super::gptr::TeamId;
use super::{DartEnv, DartResult};
use crate::mpisim::{as_bytes, as_bytes_mut, CollRequest, HasMpiType, MpiOp, Pod};

/// Completion handle of a nonblocking DART collective (the collective
/// analogue of [`super::DartHandle`]).
///
/// Wraps the substrate's [`CollRequest`]; output buffers stay mutably
/// borrowed until completion, so misuse is a compile error. Complete via
/// [`DartEnv::coll_wait`] / poll via [`DartEnv::coll_test`].
pub struct DartCollHandle<'buf> {
    req: Option<CollRequest<'buf>>,
}

impl DartCollHandle<'_> {
    /// An already-completed handle (degenerate cases).
    pub fn completed() -> Self {
        DartCollHandle { req: None }
    }
}

impl DartEnv {
    /// `dart_barrier(team)`.
    pub fn barrier(&self, team: TeamId) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.barrier()?)
    }

    /// `dart_bcast(buf, team, root)`: `buf` is input at `root`
    /// (team-relative), output elsewhere.
    pub fn bcast(&self, team: TeamId, buf: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.bcast(buf, root)?)
    }

    /// `dart_scatter`: the root's `send` (team_size × chunk bytes) is
    /// distributed in team-rank order; each unit receives into `recv`.
    pub fn scatter(&self, team: TeamId, send: &[u8], recv: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.scatter(send, recv, root)?)
    }

    /// `dart_gather`: every unit contributes `send`; the root's `recv`
    /// (team_size × send.len() bytes) is filled in team-rank order.
    pub fn gather(&self, team: TeamId, send: &[u8], recv: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.gather(send, recv, root)?)
    }

    /// `dart_allgather`.
    pub fn allgather(&self, team: TeamId, send: &[u8], recv: &mut [u8]) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.allgather(send, recv)?)
    }

    /// `dart_reduce` (typed): element-wise reduction to the root.
    pub fn reduce<T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &mut [T],
        op: MpiOp,
        root: usize,
    ) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        let recv_bytes: &mut [u8] =
            if comm.rank() == root { as_bytes_mut(recv) } else { &mut [] };
        Ok(comm.reduce(as_bytes(send), recv_bytes, op, T::MPI_TYPE, root)?)
    }

    /// `dart_allreduce` (typed).
    pub fn allreduce<T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &mut [T],
        op: MpiOp,
    ) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.allreduce(as_bytes(send), as_bytes_mut(recv), op, T::MPI_TYPE)?)
    }

    /// `dart_alltoall` (equal chunk size in bytes).
    pub fn alltoall(&self, team: TeamId, send: &[u8], recv: &mut [u8], chunk: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.alltoall(send, recv, chunk)?)
    }

    /// Typed bcast convenience.
    pub fn bcast_typed<T: Pod>(&self, team: TeamId, buf: &mut [T], root: usize) -> DartResult<()> {
        self.bcast(team, as_bytes_mut(buf), root)
    }

    // ------------------------------------------------------------------
    // Nonblocking collectives (dart_barrier_async / dart_bcast_async / …)
    // ------------------------------------------------------------------

    /// Shared initiation bookkeeping of the nonblocking family.
    fn coll_async_init(&self) {
        self.metrics.collectives.bump();
        self.metrics.coll_phases.bump();
    }

    /// `dart_barrier_async(team)`: the handle completes only once *every*
    /// member of `team` has entered the barrier.
    pub fn barrier_async(&self, team: TeamId) -> DartResult<DartCollHandle<'static>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle { req: Some(comm.ibarrier()?) })
    }

    /// `dart_bcast_async`: nonblocking [`DartEnv::bcast`]. `buf` is the
    /// payload at `root` (staged at initiation) and the output elsewhere,
    /// borrowed until the handle completes; the delivered bytes are
    /// identical to what the blocking bcast would deliver.
    pub fn bcast_async<'b>(
        &self,
        team: TeamId,
        buf: &'b mut [u8],
        root: usize,
    ) -> DartResult<DartCollHandle<'b>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle { req: Some(comm.ibcast(buf, root)?) })
    }

    /// `dart_allgather_async`: nonblocking [`DartEnv::allgather`].
    pub fn allgather_async<'b>(
        &self,
        team: TeamId,
        send: &[u8],
        recv: &'b mut [u8],
    ) -> DartResult<DartCollHandle<'b>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle { req: Some(comm.iallgather(send, recv)?) })
    }

    /// `dart_allreduce_async` (typed): nonblocking [`DartEnv::allreduce`].
    /// In `Thread` mode the element-wise reduction itself runs on the
    /// background progress thread while this unit computes.
    pub fn allreduce_async<'b, T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &'b mut [T],
        op: MpiOp,
    ) -> DartResult<DartCollHandle<'b>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle {
            req: Some(comm.iallreduce(as_bytes(send), as_bytes_mut(recv), op, T::MPI_TYPE)?),
        })
    }

    /// `dart_test` for collective handles: drive one progress step (on
    /// this collective only — `Polling` mode ticks the whole engine at
    /// *initiation* points and explicit [`DartEnv::progress_poll`] calls,
    /// not per completion test) and report completion. The completing call
    /// copies the staged result into the output buffer and releases the
    /// borrow.
    pub fn coll_test(&self, handle: &mut DartCollHandle<'_>) -> bool {
        let done = match handle.req.as_mut() {
            None => return true,
            Some(req) => req.test(),
        };
        if done {
            // Drop the request (releasing the output-buffer borrow) and
            // record the completion phase exactly once.
            handle.req = None;
            self.metrics.coll_phases.bump();
            self.sync_progress_metrics();
        }
        done
    }

    /// `dart_testall` for collective handles.
    pub fn coll_test_all(&self, handles: &mut [DartCollHandle<'_>]) -> bool {
        let mut all = true;
        for h in handles.iter_mut() {
            if !self.coll_test(h) {
                all = false;
            }
        }
        all
    }

    /// `dart_wait` for collective handles: block until complete.
    pub fn coll_wait(&self, mut handle: DartCollHandle<'_>) -> DartResult<()> {
        while !self.coll_test(&mut handle) {
            std::thread::yield_now();
        }
        Ok(())
    }

    /// `dart_waitall` for collective handles.
    pub fn coll_wait_all(&self, handles: Vec<DartCollHandle<'_>>) -> DartResult<()> {
        for h in handles {
            self.coll_wait(h)?;
        }
        Ok(())
    }
}
