//! DART collective communication (§III, §IV-B5).
//!
//! "The semantics of DART collective routines are the same as that of MPI.
//! Therefore, we can implement the DART collective interfaces
//! straightforwardly by using the MPI-3 collective counterparts. Before
//! calling the MPI-3 collective counterparts, we need to determine the
//! communicator based on the given teamID." — which is exactly what every
//! function here does: teamlist lookup, then delegate.
//!
//! Roots are given as *team-relative* ranks (like MPI); use
//! [`crate::dart::DartEnv::team_unit_g2l`] to translate an absolute unit.

use super::gptr::TeamId;
use super::{DartEnv, DartResult};
use crate::mpisim::{as_bytes, as_bytes_mut, HasMpiType, MpiOp, Pod};

impl DartEnv {
    /// `dart_barrier(team)`.
    pub fn barrier(&self, team: TeamId) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.barrier()?)
    }

    /// `dart_bcast(buf, team, root)`: `buf` is input at `root`
    /// (team-relative), output elsewhere.
    pub fn bcast(&self, team: TeamId, buf: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.bcast(buf, root)?)
    }

    /// `dart_scatter`: the root's `send` (team_size × chunk bytes) is
    /// distributed in team-rank order; each unit receives into `recv`.
    pub fn scatter(&self, team: TeamId, send: &[u8], recv: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.scatter(send, recv, root)?)
    }

    /// `dart_gather`: every unit contributes `send`; the root's `recv`
    /// (team_size × send.len() bytes) is filled in team-rank order.
    pub fn gather(&self, team: TeamId, send: &[u8], recv: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.gather(send, recv, root)?)
    }

    /// `dart_allgather`.
    pub fn allgather(&self, team: TeamId, send: &[u8], recv: &mut [u8]) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.allgather(send, recv)?)
    }

    /// `dart_reduce` (typed): element-wise reduction to the root.
    pub fn reduce<T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &mut [T],
        op: MpiOp,
        root: usize,
    ) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        let recv_bytes: &mut [u8] =
            if comm.rank() == root { as_bytes_mut(recv) } else { &mut [] };
        Ok(comm.reduce(as_bytes(send), recv_bytes, op, T::MPI_TYPE, root)?)
    }

    /// `dart_allreduce` (typed).
    pub fn allreduce<T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &mut [T],
        op: MpiOp,
    ) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.allreduce(as_bytes(send), as_bytes_mut(recv), op, T::MPI_TYPE)?)
    }

    /// `dart_alltoall` (equal chunk size in bytes).
    pub fn alltoall(&self, team: TeamId, send: &[u8], recv: &mut [u8], chunk: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.alltoall(send, recv, chunk)?)
    }

    /// Typed bcast convenience.
    pub fn bcast_typed<T: Pod>(&self, team: TeamId, buf: &mut [T], root: usize) -> DartResult<()> {
        self.bcast(team, as_bytes_mut(buf), root)
    }
}
