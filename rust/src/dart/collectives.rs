//! DART collective communication (§III, §IV-B5) — blocking and
//! nonblocking.
//!
//! "The semantics of DART collective routines are the same as that of MPI.
//! Therefore, we can implement the DART collective interfaces
//! straightforwardly by using the MPI-3 collective counterparts. Before
//! calling the MPI-3 collective counterparts, we need to determine the
//! communicator based on the given teamID." — which is exactly what every
//! function here does: teamlist lookup, then delegate.
//!
//! The **nonblocking** family ([`DartEnv::barrier_async`],
//! [`DartEnv::bcast_async`], [`DartEnv::allgather_async`],
//! [`DartEnv::allreduce_async`]) delegates the same way to the substrate's
//! `MPI_I*` state machines ([`crate::mpisim::icoll`]) and returns a
//! [`DartCollHandle`] completed through the `coll_test`/`coll_test_all` /
//! `coll_wait`/`coll_wait_all` family — the collective mirror of the
//! one-sided `test`/`wait` handles. In `Thread`/`Polling` progress modes
//! the collective advances in the background while the unit computes.
//!
//! Roots are given as *team-relative* ranks (like MPI); use
//! [`crate::dart::DartEnv::team_unit_g2l`] to translate an absolute unit.
//!
//! ## Hierarchical (two-level) collectives
//!
//! With [`crate::dart::DartConfig::hierarchical_collectives`] on,
//! [`DartEnv::allreduce`], [`DartEnv::bcast`], [`DartEnv::barrier`] and
//! [`DartEnv::allgather`] decompose along the machine hierarchy exposed by
//! [`crate::dart::locality`]: an **intra-node phase** over the node-local
//! teams, a **cross-node exchange** over the leader team, and an
//! **intra-node fan-out** — so the interconnect is crossed once per node
//! instead of once per unit (Zhou & Gracia's locality-awareness follow-up,
//! arXiv:1603.01536). Teams spanning a single node fall back to the flat
//! paths unchanged, as do the remaining collectives (scatter/gather/
//! reduce/alltoall) and the whole nonblocking family. Each executed phase
//! is counted in [`super::Metrics::hier_coll_intra_ops`] /
//! [`super::Metrics::hier_coll_inter_ops`], so tests can assert the
//! decomposition rather than trust it.

use super::gptr::TeamId;
use super::locality::{LocalityScope, LocalitySplit};
use super::{DartEnv, DartErr, DartResult};
use crate::mpisim::{as_bytes, as_bytes_mut, CollRequest, HasMpiType, MpiOp, Pod};

/// Completion handle of a nonblocking DART collective (the collective
/// analogue of [`super::DartHandle`]).
///
/// Wraps the substrate's [`CollRequest`]; output buffers stay mutably
/// borrowed until completion, so misuse is a compile error. Complete via
/// [`DartEnv::coll_wait`] / poll via [`DartEnv::coll_test`].
pub struct DartCollHandle<'buf> {
    req: Option<CollRequest<'buf>>,
}

impl DartCollHandle<'_> {
    /// An already-completed handle (degenerate cases).
    pub fn completed() -> Self {
        DartCollHandle { req: None }
    }
}

impl DartEnv {
    /// `dart_barrier(team)`. Two-level when
    /// [`crate::dart::DartConfig::hierarchical_collectives`] is on and the
    /// team spans multiple nodes.
    pub fn barrier(&self, team: TeamId) -> DartResult<()> {
        if let Some(split) = self.hier_split(team)? {
            return self.barrier_hier(split);
        }
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.barrier()?)
    }

    /// `dart_bcast(buf, team, root)`: `buf` is input at `root`
    /// (team-relative), output elsewhere. Two-level when
    /// [`crate::dart::DartConfig::hierarchical_collectives`] is on and the
    /// team spans multiple nodes.
    pub fn bcast(&self, team: TeamId, buf: &mut [u8], root: usize) -> DartResult<()> {
        if let Some(split) = self.hier_split(team)? {
            return self.bcast_hier(team, split, buf, root);
        }
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.bcast(buf, root)?)
    }

    /// `dart_scatter`: the root's `send` (team_size × chunk bytes) is
    /// distributed in team-rank order; each unit receives into `recv`.
    pub fn scatter(&self, team: TeamId, send: &[u8], recv: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.scatter(send, recv, root)?)
    }

    /// `dart_gather`: every unit contributes `send`; the root's `recv`
    /// (team_size × send.len() bytes) is filled in team-rank order.
    pub fn gather(&self, team: TeamId, send: &[u8], recv: &mut [u8], root: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.gather(send, recv, root)?)
    }

    /// `dart_allgather`. Two-level when
    /// [`crate::dart::DartConfig::hierarchical_collectives`] is on and the
    /// team spans multiple nodes.
    pub fn allgather(&self, team: TeamId, send: &[u8], recv: &mut [u8]) -> DartResult<()> {
        if let Some(split) = self.hier_split(team)? {
            return self.allgather_hier(team, split, send, recv);
        }
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.allgather(send, recv)?)
    }

    /// `dart_reduce` (typed): element-wise reduction to the root.
    pub fn reduce<T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &mut [T],
        op: MpiOp,
        root: usize,
    ) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        let recv_bytes: &mut [u8] =
            if comm.rank() == root { as_bytes_mut(recv) } else { &mut [] };
        Ok(comm.reduce(as_bytes(send), recv_bytes, op, T::MPI_TYPE, root)?)
    }

    /// `dart_allreduce` (typed). Two-level when
    /// [`crate::dart::DartConfig::hierarchical_collectives`] is on and the
    /// team spans multiple nodes: intra-node reduce to the node leader,
    /// leader allreduce across nodes, intra-node fan-out.
    pub fn allreduce<T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &mut [T],
        op: MpiOp,
    ) -> DartResult<()> {
        if let Some(split) = self.hier_split(team)? {
            return self.allreduce_hier(split, send, recv, op);
        }
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.allreduce(as_bytes(send), as_bytes_mut(recv), op, T::MPI_TYPE)?)
    }

    /// `dart_alltoall` (equal chunk size in bytes).
    pub fn alltoall(&self, team: TeamId, send: &[u8], recv: &mut [u8], chunk: usize) -> DartResult<()> {
        let comm = self.team_comm(team)?;
        self.metrics.collectives.bump();
        Ok(comm.alltoall(send, recv, chunk)?)
    }

    /// Typed bcast convenience.
    pub fn bcast_typed<T: Pod>(&self, team: TeamId, buf: &mut [T], root: usize) -> DartResult<()> {
        self.bcast(team, as_bytes_mut(buf), root)
    }

    // ------------------------------------------------------------------
    // Hierarchical (two-level) decompositions
    // ------------------------------------------------------------------

    /// Should `team`'s collectives take the two-level path? Returns the
    /// (cached, or freshly created) node-scope split when the feature is
    /// on *and* the team spans multiple nodes; `None` means flat. The
    /// decision is computed from launch-constant state (config +
    /// placement + team membership), so every member reaches the same
    /// verdict — a collective-consistency requirement.
    fn hier_split(&self, team: TeamId) -> DartResult<Option<LocalitySplit>> {
        if !self.config().hierarchical_collectives {
            return Ok(None);
        }
        if let Some(s) = self.locality_cache.borrow().get(&(team, LocalityScope::Node)) {
            return Ok(if s.domains > 1 { Some(*s) } else { None });
        }
        if self.hier_flat_teams.borrow().contains(&team) {
            return Ok(None);
        }
        // One-time span probe before committing to sub-team creation:
        // single-node teams keep the flat path, create nothing, and cache
        // the verdict (placement and membership are launch-constant).
        if self.team_node_span(team)? < 2 {
            self.hier_flat_teams.borrow_mut().insert(team);
            return Ok(None);
        }
        Ok(Some(self.team_split_locality(team, LocalityScope::Node)?))
    }

    /// Two-level barrier: everyone arrives within the node, the leaders
    /// agree across nodes, the node releases.
    fn barrier_hier(&self, split: LocalitySplit) -> DartResult<()> {
        self.metrics.collectives.bump();
        let local = self.team_comm(split.local)?;
        local.barrier()?;
        self.metrics.hier_coll_intra_ops.bump();
        if let Some(lt) = split.leaders {
            self.team_comm(lt)?.barrier()?;
            self.metrics.hier_coll_inter_ops.bump();
        }
        local.barrier()?;
        self.metrics.hier_coll_intra_ops.bump();
        Ok(())
    }

    /// Two-level bcast: fan out within the root's node, cross nodes once
    /// via the leader team, fan out within every other node.
    fn bcast_hier(
        &self,
        team: TeamId,
        split: LocalitySplit,
        buf: &mut [u8],
        root: usize,
    ) -> DartResult<()> {
        self.metrics.collectives.bump();
        let root_abs = self.team_unit_l2g(team, root)?;
        let root_node = self.placement().node_of(root_abs as usize);
        let my_node = self.placement().node_of(self.myid() as usize);
        let local = self.team_comm(split.local)?;
        // Phase 1 (root's node only): the root fans out within its node,
        // so its leader holds the payload for the cross-node exchange.
        if my_node == root_node {
            let lroot = self.team_unit_g2l(split.local, root_abs)?;
            local.bcast(buf, lroot)?;
            self.metrics.hier_coll_intra_ops.bump();
        }
        // Phase 2: leader exchange, rooted at the root node's leader.
        if let Some(lt) = split.leaders {
            let lcomm = self.team_comm(lt)?;
            let lgroup = self.team_get_group(lt)?;
            let root_leader = lgroup
                .members()
                .iter()
                .copied()
                .find(|&u| self.placement().node_of(u as usize) == root_node)
                .ok_or_else(|| DartErr::Invalid("no leader on the bcast root's node".into()))?;
            let lroot = self.team_unit_g2l(lt, root_leader)?;
            lcomm.bcast(buf, lroot)?;
            self.metrics.hier_coll_inter_ops.bump();
        }
        // Phase 3 (every other node): its leader — local rank 0, the
        // node's lowest member — fans the payload out.
        if my_node != root_node {
            local.bcast(buf, 0)?;
            self.metrics.hier_coll_intra_ops.bump();
        }
        Ok(())
    }

    /// Two-level allreduce: intra-node reduce to the node leader (local
    /// rank 0), leader allreduce of the node partials, intra-node fan-out.
    fn allreduce_hier<T: HasMpiType>(
        &self,
        split: LocalitySplit,
        send: &[T],
        recv: &mut [T],
        op: MpiOp,
    ) -> DartResult<()> {
        self.metrics.collectives.bump();
        let local = self.team_comm(split.local)?;
        let recv_bytes: &mut [u8] = if local.rank() == 0 { as_bytes_mut(recv) } else { &mut [] };
        local.reduce(as_bytes(send), recv_bytes, op, T::MPI_TYPE, 0)?;
        self.metrics.hier_coll_intra_ops.bump();
        if let Some(lt) = split.leaders {
            let lcomm = self.team_comm(lt)?;
            let partial = as_bytes(&*recv).to_vec();
            lcomm.allreduce(&partial, as_bytes_mut(recv), op, T::MPI_TYPE)?;
            self.metrics.hier_coll_inter_ops.bump();
        }
        local.bcast(as_bytes_mut(recv), 0)?;
        self.metrics.hier_coll_intra_ops.bump();
        Ok(())
    }

    /// Two-level allgather: intra-node gather to the leader, leader
    /// exchange of (padded) per-node blocks, team-rank-order reassembly at
    /// the leaders, intra-node fan-out. Handles uneven units-per-node via
    /// padding to the largest node's contribution.
    fn allgather_hier(
        &self,
        team: TeamId,
        split: LocalitySplit,
        send: &[u8],
        recv: &mut [u8],
    ) -> DartResult<()> {
        self.metrics.collectives.bump();
        let chunk = send.len();
        let members = self.team_get_group(team)?.members().to_vec();
        let n = members.len();
        if recv.len() != n * chunk {
            return Err(DartErr::Invalid(format!(
                "allgather: recv is {} bytes, expected {} members × {} bytes",
                recv.len(), n, chunk
            )));
        }
        // Node of every team rank, and the nodes in order of first
        // appearance. Members are sorted by unit id, so first-appearance
        // order == ascending leader-unit order == leader-team rank order.
        let node_of: Vec<usize> =
            members.iter().map(|&u| self.placement().node_of(u as usize)).collect();
        let mut node_order: Vec<usize> = Vec::new();
        for &d in &node_of {
            if !node_order.contains(&d) {
                node_order.push(d);
            }
        }
        let mut per_node = vec![0usize; node_order.len()];
        for &d in &node_of {
            let di = node_order.iter().position(|&x| x == d).unwrap();
            per_node[di] += 1;
        }
        let cap = per_node.iter().copied().max().unwrap_or(1);

        // Phase 1: intra-node gather to the leader (local rank 0); local
        // team order == ascending team rank within the node.
        let local = self.team_comm(split.local)?;
        let mut node_buf = vec![0u8; if local.rank() == 0 { local.size() * chunk } else { 0 }];
        local.gather(send, &mut node_buf, 0)?;
        self.metrics.hier_coll_intra_ops.bump();

        // Phase 2 (leaders): exchange padded per-node blocks, then rebuild
        // the team-rank-ordered result.
        if let Some(lt) = split.leaders {
            let lcomm = self.team_comm(lt)?;
            let mut padded = vec![0u8; cap * chunk];
            padded[..node_buf.len()].copy_from_slice(&node_buf);
            let mut all_nodes = vec![0u8; node_order.len() * cap * chunk];
            lcomm.allgather(&padded, &mut all_nodes)?;
            self.metrics.hier_coll_inter_ops.bump();
            let mut within = vec![0usize; node_order.len()];
            for r in 0..n {
                let di = node_order.iter().position(|&x| x == node_of[r]).unwrap();
                let src = (di * cap + within[di]) * chunk;
                within[di] += 1;
                recv[r * chunk..(r + 1) * chunk].copy_from_slice(&all_nodes[src..src + chunk]);
            }
        }

        // Phase 3: intra-node fan-out of the assembled result.
        local.bcast(recv, 0)?;
        self.metrics.hier_coll_intra_ops.bump();
        Ok(())
    }

    // ------------------------------------------------------------------
    // Nonblocking collectives (dart_barrier_async / dart_bcast_async / …)
    // ------------------------------------------------------------------

    /// Shared initiation bookkeeping of the nonblocking family.
    fn coll_async_init(&self) {
        self.metrics.collectives.bump();
        self.metrics.coll_phases.bump();
    }

    /// `dart_barrier_async(team)`: the handle completes only once *every*
    /// member of `team` has entered the barrier.
    pub fn barrier_async(&self, team: TeamId) -> DartResult<DartCollHandle<'static>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle { req: Some(comm.ibarrier()?) })
    }

    /// `dart_bcast_async`: nonblocking [`DartEnv::bcast`]. `buf` is the
    /// payload at `root` (staged at initiation) and the output elsewhere,
    /// borrowed until the handle completes; the delivered bytes are
    /// identical to what the blocking bcast would deliver.
    pub fn bcast_async<'b>(
        &self,
        team: TeamId,
        buf: &'b mut [u8],
        root: usize,
    ) -> DartResult<DartCollHandle<'b>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle { req: Some(comm.ibcast(buf, root)?) })
    }

    /// `dart_allgather_async`: nonblocking [`DartEnv::allgather`].
    pub fn allgather_async<'b>(
        &self,
        team: TeamId,
        send: &[u8],
        recv: &'b mut [u8],
    ) -> DartResult<DartCollHandle<'b>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle { req: Some(comm.iallgather(send, recv)?) })
    }

    /// `dart_allreduce_async` (typed): nonblocking [`DartEnv::allreduce`].
    /// In `Thread` mode the element-wise reduction itself runs on the
    /// background progress thread while this unit computes.
    pub fn allreduce_async<'b, T: HasMpiType>(
        &self,
        team: TeamId,
        send: &[T],
        recv: &'b mut [T],
        op: MpiOp,
    ) -> DartResult<DartCollHandle<'b>> {
        let comm = self.team_comm(team)?;
        self.coll_async_init();
        Ok(DartCollHandle {
            req: Some(comm.iallreduce(as_bytes(send), as_bytes_mut(recv), op, T::MPI_TYPE)?),
        })
    }

    /// `dart_test` for collective handles: drive one progress step (on
    /// this collective only — `Polling` mode ticks the whole engine at
    /// *initiation* points and explicit [`DartEnv::progress_poll`] calls,
    /// not per completion test) and report completion. The completing call
    /// copies the staged result into the output buffer and releases the
    /// borrow.
    pub fn coll_test(&self, handle: &mut DartCollHandle<'_>) -> bool {
        let done = match handle.req.as_mut() {
            None => return true,
            Some(req) => req.test(),
        };
        if done {
            // Drop the request (releasing the output-buffer borrow) and
            // record the completion phase exactly once.
            handle.req = None;
            self.metrics.coll_phases.bump();
            self.sync_progress_metrics();
        }
        done
    }

    /// `dart_testall` for collective handles.
    pub fn coll_test_all(&self, handles: &mut [DartCollHandle<'_>]) -> bool {
        let mut all = true;
        for h in handles.iter_mut() {
            if !self.coll_test(h) {
                all = false;
            }
        }
        all
    }

    /// `dart_wait` for collective handles: block until complete.
    pub fn coll_wait(&self, mut handle: DartCollHandle<'_>) -> DartResult<()> {
        while !self.coll_test(&mut handle) {
            crate::simnet::exec::coop_yield();
        }
        Ok(())
    }

    /// `dart_waitall` for collective handles.
    pub fn coll_wait_all(&self, handles: Vec<DartCollHandle<'_>>) -> DartResult<()> {
        for h in handles {
            self.coll_wait(h)?;
        }
        Ok(())
    }
}
