//! In-crate tests of the DART runtime over the mpisim substrate.
//!
//! These exercise the paper's protocols end to end on multi-unit worlds:
//! teams over sorted groups, aligned collective allocation + translation,
//! global-pointer dereference, one-sided transfers, and the MCS lock's
//! mutual exclusion and FIFO ordering.

use super::*;
use crate::mpisim::MpiOp;
use std::sync::atomic::{AtomicI64, AtomicUsize, Ordering as AOrd};
use std::sync::Mutex;

fn small(units: usize) -> DartConfig {
    DartConfig::with_units(units).with_pools(1 << 16, 1 << 16)
}

#[test]
fn init_exposes_identity() {
    run(small(5), |env| {
        assert!(env.myid() >= 0 && (env.myid() as usize) < 5);
        assert_eq!(env.size(), 5);
        assert_eq!(env.team_size(DART_TEAM_ALL).unwrap(), 5);
        assert_eq!(env.team_myid(DART_TEAM_ALL).unwrap(), env.myid() as usize);
    })
    .unwrap();
}

#[test]
fn non_collective_alloc_put_get() {
    run(small(3), |env| {
        // Every unit allocates in its own partition; unit 0 writes into
        // unit 2's memory; unit 2 reads it locally (Fig. 4 path).
        let gptr = env.memalloc(64).unwrap();
        assert!(!gptr.is_collective());
        assert_eq!(gptr.unitid, env.myid());
        // Exchange pointers via allgather of the 128-bit representation.
        let mine = gptr.to_bits().to_ne_bytes();
        let mut all = vec![0u8; 16 * 3];
        env.allgather(DART_TEAM_ALL, &mine, &mut all).unwrap();
        let gptr_of = |u: usize| {
            GlobalPtr::from_bits(u128::from_ne_bytes(all[u * 16..(u + 1) * 16].try_into().unwrap()))
        };
        if env.myid() == 0 {
            env.put_blocking(gptr_of(2), b"hello-unit2").unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 2 {
            let mut buf = [0u8; 11];
            env.local_read(gptr, &mut buf).unwrap();
            assert_eq!(&buf, b"hello-unit2");
            // And via a blocking self-get.
            let mut buf2 = [0u8; 11];
            env.get_blocking(gptr_of(2), &mut buf2).unwrap();
            assert_eq!(&buf2, b"hello-unit2");
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.memfree(gptr).unwrap();
    })
    .unwrap();
}

#[test]
fn collective_alloc_is_aligned_and_symmetric() {
    run(small(4), |env| {
        let g1 = env.team_memalloc_aligned(DART_TEAM_ALL, 128).unwrap();
        let g2 = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        assert!(g1.is_collective());
        // Aligned: every member computed the same offsets.
        let mut offs = [0u64; 2];
        let mine = [g1.offset, g2.offset];
        let mut all = vec![0u64; 2 * 4];
        env.allgather(
            DART_TEAM_ALL,
            crate::mpisim::as_bytes(&mine),
            crate::mpisim::as_bytes_mut(&mut all),
        )
        .unwrap();
        offs.copy_from_slice(&all[0..2]);
        for u in 0..4 {
            assert_eq!(&all[u * 2..u * 2 + 2], &offs, "offsets differ on unit {u}");
        }
        // Symmetric use: unit u writes to unit (u+1)%4's copy of g1.
        let me = env.myid();
        let next = (me + 1) % 4;
        let val = [me as i64; 4];
        env.put_blocking_typed(g1.with_unit(next), &val).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        let mut got = [0i64; 4];
        env.get_blocking_typed(g1.with_unit(me), &mut got).unwrap();
        assert_eq!(got, [(me + 3) % 4; 4].map(|x| x as i64));
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g2).unwrap();
        env.team_memfree(DART_TEAM_ALL, g1).unwrap();
    })
    .unwrap();
}

#[test]
fn collective_gptr_offsets_are_pool_relative() {
    run(small(2), |env| {
        let g1 = env.team_memalloc_aligned(DART_TEAM_ALL, 32).unwrap();
        let g2 = env.team_memalloc_aligned(DART_TEAM_ALL, 32).unwrap();
        // Pool-relative (not allocation-relative): the second allocation's
        // offset continues where the first ended (§IV-B3 "relative to the
        // base address of the memory region reserved for this team").
        assert_eq!(g1.offset, 0);
        assert_eq!(g2.offset, 32);
        // Addressing *within* an allocation crosses into the right window.
        let me = env.myid();
        env.put_blocking(g2.with_unit(me).add(8), &[0xEE; 4]).unwrap();
        let mut b = [0u8; 4];
        env.get_blocking(g2.with_unit(me).add(8), &mut b).unwrap();
        assert_eq!(b, [0xEE; 4]);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g1).unwrap();
        env.team_memfree(DART_TEAM_ALL, g2).unwrap();
    })
    .unwrap();
}

#[test]
fn nonblocking_handles_and_waitall() {
    run(small(2), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 4096).unwrap();
        if env.myid() == 0 {
            let mut handles = Vec::new();
            for i in 0..8u64 {
                let h = env
                    .put(g.with_unit(1).add(i * 8), &(i * 11).to_ne_bytes())
                    .unwrap();
                handles.push(h);
            }
            env.waitall(handles).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 1 {
            for i in 0..8u64 {
                let mut b = [0u8; 8];
                let h = env.get(g.with_unit(1).add(i * 8), &mut b).unwrap();
                env.wait(h).unwrap();
                assert_eq!(u64::from_ne_bytes(b), i * 11);
            }
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn team_create_sorted_subteam() {
    run(small(6), |env| {
        // Group built in scrambled order — DART sorts (paper Fig. 2).
        let w = env.mpi_world_group();
        let mut grp = DartGroup::new();
        for u in [5, 1, 3] {
            grp.addmember(u, &w).unwrap();
        }
        let team = env.team_create(DART_TEAM_ALL, &grp).unwrap();
        match env.myid() {
            1 | 3 | 5 => {
                let t = team.expect("member must get the team");
                assert_eq!(env.team_size(t).unwrap(), 3);
                // Sorted order ⇒ ranks 0,1,2 are units 1,3,5.
                let expect_rank = [1, 3, 5].iter().position(|&u| u == env.myid()).unwrap();
                assert_eq!(env.team_myid(t).unwrap(), expect_rank);
                assert_eq!(env.team_unit_l2g(t, 0).unwrap(), 1);
                assert_eq!(env.team_unit_g2l(t, 5).unwrap(), 2);
                // Collective allocation works on the sub-team.
                let g = env.team_memalloc_aligned(t, 64).unwrap();
                assert_eq!(g.segid, t);
                assert_eq!(g.unitid, 1); // first member
                let r = env.team_myid(t).unwrap();
                env.put_blocking(g.with_unit(env.myid()), &[r as u8; 8]).unwrap();
                env.barrier(t).unwrap();
                // Read the next member's copy.
                let next = env.team_unit_l2g(t, (r + 1) % 3).unwrap();
                let mut b = [0u8; 8];
                env.get_blocking(g.with_unit(next), &mut b).unwrap();
                assert_eq!(b, [((r + 1) % 3) as u8; 8]);
                env.barrier(t).unwrap();
                env.team_memfree(t, g).unwrap();
                env.team_destroy(t).unwrap();
            }
            _ => assert!(team.is_none()),
        }
    })
    .unwrap();
}

#[test]
fn teamlist_slots_recycle_but_ids_do_not() {
    run(small(2), |env| {
        let grp = env.group_all();
        let mut ids = Vec::new();
        for _ in 0..5 {
            let t = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
            ids.push(t);
            env.team_destroy(t).unwrap();
        }
        // Ids strictly increase — never reused (§IV-B2).
        assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids reused: {ids:?}");
        // Only DART_TEAM_ALL remains live.
        assert_eq!(env.live_teams(), vec![DART_TEAM_ALL]);
    })
    .unwrap();
}

#[test]
fn teamlist_exhaustion_is_reported() {
    let mut cfg = small(2);
    cfg.teamlist_size = 3; // ALL + 2 more
    run(cfg, |env| {
        let grp = env.group_all();
        let t1 = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        let t2 = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        match env.team_create(DART_TEAM_ALL, &grp) {
            Err(DartErr::TeamListFull(3)) => {}
            other => panic!("expected TeamListFull, got {other:?}"),
        }
        env.team_destroy(t2).unwrap();
        // A slot freed ⇒ creation works again, with a fresh id.
        let t3 = env.team_create(DART_TEAM_ALL, &grp).unwrap().unwrap();
        assert!(t3 > t2);
        env.team_destroy(t3).unwrap();
        env.team_destroy(t1).unwrap();
    })
    .unwrap();
}

#[test]
fn gptr_deref_errors() {
    run(small(2), |env| {
        // Null pointer.
        assert!(matches!(
            env.put_blocking(GlobalPtr::NULL, &[0]),
            Err(DartErr::InvalidGptr(_))
        ));
        // Unknown team in a collective pointer.
        let bogus = GlobalPtr::collective(0, 999, 0);
        assert!(matches!(env.put_blocking(bogus, &[0]), Err(DartErr::UnknownTeam(999))));
        // Offset outside any allocation.
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 16).unwrap();
        let past = g.with_unit(0).add(1 << 14);
        assert!(matches!(env.put_blocking(past, &[0]), Err(DartErr::InvalidGptr(_))));
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
        // Unit outside the world for a non-collective pointer.
        let far = GlobalPtr::non_collective(77, 0);
        assert!(matches!(env.get_blocking(far, &mut [0]), Err(DartErr::InvalidUnit(77))));
    })
    .unwrap();
}

#[test]
fn accumulate_and_atomics_via_gptr() {
    let total = AtomicI64::new(0);
    run(small(4), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        let target = g.with_unit(0);
        for _ in 0..25 {
            env.accumulate(target, &[1i64], MpiOp::Sum).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let mut v = [0i64];
            env.get_blocking_typed(target, &mut v).unwrap();
            total.store(v[0], AOrd::SeqCst);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    assert_eq!(total.load(AOrd::SeqCst), 100);
}

#[test]
fn mcs_lock_mutual_exclusion() {
    // A non-atomic read-modify-write protected by the DART lock: with 6
    // units × 30 increments the final count detects any exclusion failure.
    let finals = AtomicI64::new(0);
    run(small(6), |env| {
        let counter = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        let c0 = counter.with_unit(0);
        for _ in 0..30 {
            env.lock_acquire(&lock).unwrap();
            assert!(lock.is_held());
            let mut v = [0i64];
            env.get_blocking_typed(c0, &mut v).unwrap();
            v[0] += 1;
            env.put_blocking_typed(c0, &v).unwrap();
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let mut v = [0i64];
            env.get_blocking_typed(c0, &mut v).unwrap();
            finals.store(v[0], AOrd::SeqCst);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
        env.team_memfree(DART_TEAM_ALL, counter).unwrap();
    })
    .unwrap();
    assert_eq!(finals.load(AOrd::SeqCst), 180);
}

#[test]
fn mcs_lock_is_fifo_under_queueing() {
    // Build a guaranteed queue: unit 0 takes the lock, everyone else
    // enqueues in unit order (enforced by a chain of barriers), then unit 0
    // releases. Acquisition order must equal enqueue order.
    let order = Mutex::new(Vec::new());
    run(small(4), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            env.lock_acquire(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() != 0 {
            // Stagger enqueue: unit 1 first, then 2, then 3. The atomic
            // swap in lock_acquire orders the queue; the sleeps make the
            // intended order overwhelmingly likely to be the actual one.
            std::thread::sleep(std::time::Duration::from_millis(30 * env.myid() as u64));
            env.lock_acquire(&lock).unwrap();
            order.lock().unwrap().push(env.myid());
            env.lock_release(&lock).unwrap();
        } else {
            std::thread::sleep(std::time::Duration::from_millis(200));
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
    })
    .unwrap();
    assert_eq!(*order.lock().unwrap(), vec![1, 2, 3], "MCS lock must be FIFO");
}

#[test]
fn try_acquire_contended_and_free() {
    let successes = AtomicUsize::new(0);
    run(small(4), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.lock_try_acquire(&lock).unwrap() {
            successes.fetch_add(1, AOrd::SeqCst);
            // Hold it long enough that everyone else's try fails.
            std::thread::sleep(std::time::Duration::from_millis(50));
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        // After release, try succeeds again.
        if env.myid() == 2 {
            assert!(env.lock_try_acquire(&lock).unwrap());
            env.lock_release(&lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
    })
    .unwrap();
    assert_eq!(successes.load(AOrd::SeqCst), 1);
}

#[test]
fn multiple_locks_per_team_are_independent() {
    run(small(3), |env| {
        let l1 = env.lock_init(DART_TEAM_ALL).unwrap();
        let l2 = env.lock_init(DART_TEAM_ALL).unwrap();
        assert_ne!(l1.tag(), l2.tag());
        // Hold both simultaneously on one unit while others use l2 only.
        if env.myid() == 0 {
            env.lock_acquire(&l1).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_acquire(&l2).unwrap();
        env.lock_release(&l2).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            env.lock_release(&l1).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(l2).unwrap();
        env.lock_free(l1).unwrap();
    })
    .unwrap();
}

#[test]
fn lock_misuse_is_reported() {
    run(small(2), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).unwrap();
        assert!(matches!(env.lock_release(&lock), Err(DartErr::LockMisuse(_))));
        env.lock_acquire(&lock).unwrap();
        assert!(matches!(env.lock_acquire(&lock), Err(DartErr::LockMisuse(_))));
        env.lock_release(&lock).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        env.lock_free(lock).unwrap();
    })
    .unwrap();
}

#[test]
fn collectives_through_teams() {
    run(small(4), |env| {
        // bcast
        let mut v = if env.team_myid(DART_TEAM_ALL).unwrap() == 1 { [42u8] } else { [0u8] };
        env.bcast(DART_TEAM_ALL, &mut v, 1).unwrap();
        assert_eq!(v, [42]);
        // allreduce
        let mine = [env.myid() as i64];
        let mut sum = [0i64];
        env.allreduce(DART_TEAM_ALL, &mine, &mut sum, MpiOp::Sum).unwrap();
        assert_eq!(sum, [6]);
        // gather / scatter on a sub-team
        let grp = DartGroup::from_units(vec![0, 2]);
        let team = env.team_create(DART_TEAM_ALL, &grp).unwrap();
        if let Some(t) = team {
            let r = env.team_myid(t).unwrap() as u8;
            let mut all = [0u8; 2];
            env.gather(t, &[r + 10], if r == 0 { &mut all } else { &mut [] }, 0).unwrap();
            if r == 0 {
                assert_eq!(all, [10, 11]);
            }
            env.team_destroy(t).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn nested_teams_and_allocations() {
    run(small(8), |env| {
        // Split the world into halves, each half into pairs; allocate at
        // every level and check isolation.
        let halves = env.group_all().split(2).unwrap();
        let my_half = (env.myid() / 4) as usize;
        let mut half_team = None;
        for (i, h) in halves.iter().enumerate() {
            let t = env.team_create(DART_TEAM_ALL, h).unwrap();
            if i == my_half {
                assert!(t.is_some());
                half_team = t;
            }
        }
        let ht = half_team.unwrap();
        let hg = env.team_memalloc_aligned(ht, 64).unwrap();
        assert_eq!(hg.segid, ht);
        let hrank = env.team_myid(ht).unwrap();
        env.put_blocking(hg.with_unit(env.myid()), &[hrank as u8; 4]).unwrap();
        env.barrier(ht).unwrap();

        let pairs = env.team_get_group(ht).unwrap().split(2).unwrap();
        let my_pair = (env.myid() % 4 / 2) as usize;
        let mut pair_team = None;
        for (i, p) in pairs.iter().enumerate() {
            let t = env.team_create(ht, p).unwrap();
            if i == my_pair {
                pair_team = t;
            }
        }
        let pt = pair_team.unwrap();
        assert_eq!(env.team_size(pt).unwrap(), 2);
        let pg = env.team_memalloc_aligned(pt, 16).unwrap();
        let prank = env.team_myid(pt).unwrap();
        let partner = env.team_unit_l2g(pt, (prank + 1) % 2).unwrap();
        env.put_blocking(pg.with_unit(partner), &[env.myid() as u8; 8]).unwrap();
        env.barrier(pt).unwrap();
        let mut got = [0u8; 8];
        env.get_blocking(pg.with_unit(env.myid()), &mut got).unwrap();
        assert_eq!(got, [partner as u8; 8]);

        // Half-level allocation is untouched by pair traffic.
        let mut hbuf = [0u8; 4];
        env.get_blocking(hg.with_unit(env.myid()), &mut hbuf).unwrap();
        assert_eq!(hbuf, [hrank as u8; 4]);

        env.barrier(ht).unwrap();
        env.team_memfree(pt, pg).unwrap();
        env.team_destroy(pt).unwrap();
        env.team_memfree(ht, hg).unwrap();
        env.team_destroy(ht).unwrap();
    })
    .unwrap();
}

#[test]
fn memfree_validation() {
    run(small(2), |env| {
        let g = env.memalloc(32).unwrap();
        // Can't free someone else's non-collective memory.
        let other = GlobalPtr::non_collective((env.myid() + 1) % 2, 0);
        assert!(env.memfree(other).is_err());
        // Can't memfree a collective pointer.
        let cg = env.team_memalloc_aligned(DART_TEAM_ALL, 8).unwrap();
        assert!(env.memfree(cg).is_err());
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, cg).unwrap();
        env.memfree(g).unwrap();
        // Double free reported.
        assert!(env.memfree(g).is_err());
    })
    .unwrap();
}

#[test]
fn strided_put_get_column_exchange() {
    run(small(2), |env| {
        // A 8×8 byte matrix per unit; unit 0 writes a column into unit 1,
        // then reads it back with a strided get.
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let col: Vec<u8> = (10..18).collect();
            // column 3 of a row-major 8×8: offset 3, stride 8, block 1 —
            // one vector-typed request for the whole column.
            let h = env.put_strided(g.with_unit(1).add(3), &col, 8, 1, 8).unwrap();
            env.wait(h).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 1 {
            let mut mat = [0u8; 64];
            env.local_read(g.with_unit(1), &mut mat).unwrap();
            for r in 0..8 {
                assert_eq!(mat[r * 8 + 3], 10 + r as u8);
                assert_eq!(mat[r * 8 + 2], 0);
            }
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        if env.myid() == 0 {
            let mut col = [0u8; 8];
            let h = env.get_strided(g.with_unit(1).add(3), &mut col, 8, 1, 8).unwrap();
            env.wait(h).unwrap();
            assert_eq!(col, [10, 11, 12, 13, 14, 15, 16, 17]);
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn strided_validation() {
    run(small(1), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        // wrong buffer length
        assert!(env.put_strided(g, &[0u8; 7], 8, 1, 8).is_err());
        // stride < block
        assert!(env.put_strided(g, &[0u8; 8], 2, 4, 2).is_err());
        // last block out of range: 8 blocks of 8 at stride 8 needs 64; from
        // offset 8 it needs 72.
        assert!(env
            .put_strided(g.add(8), &[0u8; 64], 8, 8, 8)
            .is_err());
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}

#[test]
fn shmem_windows_numerically_identical() {
    // The §VI zero-copy fast path must not change any result.
    for shmem in [false, true] {
        let cfg = small(4).with_shmem_windows(shmem);
        run(cfg, |env| {
            let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
            let me = env.myid();
            env.put_blocking(g.with_unit((me + 1) % 4), &[me as u8 + 1; 16]).unwrap();
            env.barrier(DART_TEAM_ALL).unwrap();
            let mut got = [0u8; 16];
            env.get_blocking(g.with_unit(me), &mut got).unwrap();
            assert_eq!(got, [((me + 3) % 4) as u8 + 1; 16]);
            env.barrier(DART_TEAM_ALL).unwrap();
            env.team_memfree(DART_TEAM_ALL, g).unwrap();
        })
        .unwrap();
    }
}

#[test]
fn balanced_lock_tails_spread_hosts() {
    let cfg = small(4).with_balanced_lock_tails(true);
    run(cfg, |env| {
        let locks: Vec<_> = (0..4).map(|_| env.lock_init(DART_TEAM_ALL).unwrap()).collect();
        // Tails must live on 4 distinct units (seq % team_size).
        let hosts: Vec<i32> = locks.iter().map(|l| l.tail_unit()).collect();
        let mut sorted = hosts.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "tails not balanced: {hosts:?}");
        // And every lock still excludes correctly.
        for lock in &locks {
            env.lock_acquire(lock).unwrap();
            env.lock_release(lock).unwrap();
        }
        env.barrier(DART_TEAM_ALL).unwrap();
        for lock in locks {
            env.lock_free(lock).unwrap();
        }
    })
    .unwrap();
}

#[test]
fn metrics_track_operations() {
    run(small(2), |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 64).unwrap();
        env.put_blocking(g.with_unit(env.myid()), &[1; 8]).unwrap();
        let h = env.get(g.with_unit(env.myid()), &mut [0u8; 8]).unwrap();
        env.wait(h).unwrap();
        assert_eq!(env.metrics.puts_blocking.get(), 1);
        assert_eq!(env.metrics.gets.get(), 1);
        assert_eq!(env.metrics.allocs.get(), 1);
        assert!(env.metrics.bytes.get() >= 16);
        env.barrier(DART_TEAM_ALL).unwrap();
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
}
