//! The locality API: who shares my node, and teams that follow the
//! machine hierarchy.
//!
//! The DART-MPI evaluation (§V) shows intra-node and inter-node transfers
//! living in different performance regimes, and the two follow-up papers
//! promote that observation into a runtime design principle:
//!
//! - *"Leveraging MPI-3 Shared-Memory Extensions for Efficient PGAS
//!   Runtime Systems"* (Zhou et al., arXiv:1507.04799) — same-node
//!   transfers should be zero-copy load/store through shared-memory
//!   windows (the engine's fast path, [`crate::dart::engine`]);
//! - *"Towards performance portability through locality-awareness"*
//!   (Zhou & Gracia, arXiv:1603.01536) — the runtime should *expose* the
//!   node/NUMA hierarchy so applications and the runtime itself can route
//!   communication per locality tier.
//!
//! This module is that exposure for DART:
//!
//! - [`DartEnv::unit_locality`] — any unit's [`DomainCoord`] (node, NUMA
//!   domain, core), derived from the modelled
//!   [`crate::simnet::Placement`]; [`DartEnv::same_node`] answers the
//!   question the engine's fast path asks.
//! - [`DartEnv::team_split_locality`] — the `MPI_Comm_split_type`
//!   analogue: split a team into **domain-local teams** (one per node, or
//!   per NUMA domain, [`LocalityScope`]) plus a **cross-domain leader
//!   team** holding each domain's lowest-id member. The resulting
//!   [`LocalitySplit`] is memoized per `(team, scope)` on every member
//!   and torn down/invalidated with [`DartEnv::team_destroy`], so
//!   repeated splits — e.g. one per hierarchical collective
//!   ([`crate::dart::collectives`]) — cost nothing after the first.
//!
//! `team_split_locality` is **collective over the team** (it creates
//! sub-teams via [`DartEnv::team_create`]); every member must call it
//! with the same scope, and every member receives a consistent view: the
//! id of *its* domain-local team, and the leader team id only on leaders
//! (everyone else sees `None`, mirroring `DART_TEAM_NULL`).

use super::gptr::{TeamId, UnitId};
use super::{DartEnv, DartErr, DartGroup, DartResult};
use std::fmt;

/// Locality coordinate of one unit in the modelled machine hierarchy:
/// which node, which NUMA domain within the node, which core within the
/// domain (the three tiers of the paper's Hermit testbed, Fig. 7).
///
/// This *is* the simnet placement coordinate — the locality API exposes
/// the same `(node, numa, core)` triple the cost model routes by, under
/// the name the DART surface uses for it (one coordinate type, not two
/// to convert between).
pub type DomainCoord = crate::simnet::CoreCoord;

/// Which level of the hierarchy a locality split groups by — the DART
/// analogue of `MPI_Comm_split_type`'s `split_type` argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LocalityScope {
    /// One domain per **node**: members sharing a node land in the same
    /// local team (the scope shared-memory windows and the hierarchical
    /// collectives care about — `MPI_COMM_TYPE_SHARED`).
    Node,
    /// One domain per **(node, NUMA domain)** pair: the finer split for
    /// NUMA-aware placement decisions.
    Numa,
}

impl LocalityScope {
    /// Both scopes, in coarse-to-fine order (used by the split-cache
    /// teardown in [`DartEnv::team_destroy`]).
    pub const ALL: [LocalityScope; 2] = [LocalityScope::Node, LocalityScope::Numa];

    /// The domain key of a coordinate under this scope.
    #[inline]
    pub(crate) fn key(&self, c: DomainCoord) -> (usize, usize) {
        match self {
            LocalityScope::Node => (c.node, 0),
            LocalityScope::Numa => (c.node, c.numa),
        }
    }

    /// Short label for bench/table output.
    pub fn label(&self) -> &'static str {
        match self {
            LocalityScope::Node => "node",
            LocalityScope::Numa => "numa",
        }
    }
}

impl fmt::Display for LocalityScope {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Result of a [`DartEnv::team_split_locality`] call, cheap to copy and
/// identical in shape on every member of the parent team.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocalitySplit {
    /// The team of all parent members sharing *my* locality domain
    /// (always valid — every member belongs to exactly one domain).
    pub local: TeamId,
    /// The cross-domain leader team (one member per domain: the domain's
    /// lowest absolute unit id). `Some` only on leaders — everyone else
    /// gets `None`, like a `DART_TEAM_NULL` from `team_create`.
    pub leaders: Option<TeamId>,
    /// Am I my domain's leader? (Equivalent to `leaders.is_some()`.)
    pub is_leader: bool,
    /// Number of distinct domains the parent team spans; `1` means the
    /// split is degenerate (the local team mirrors the parent and the
    /// leader team is a singleton) and hierarchical collectives fall back
    /// to their flat paths.
    pub domains: usize,
}

impl DartEnv {
    /// `dart_unit_locality`: the [`DomainCoord`] of any unit, derived from
    /// the launch's modelled placement. Purely local — no communication.
    pub fn unit_locality(&self, unit: UnitId) -> DartResult<DomainCoord> {
        if unit < 0 || unit as usize >= self.size() {
            return Err(DartErr::InvalidUnit(unit));
        }
        Ok(self.placement().coord(unit as usize))
    }

    /// Do two units share a node? This is exactly the condition under
    /// which shared-memory windows make a transfer zero-copy (the engine's
    /// locality fast path asks the same question per operation).
    pub fn same_node(&self, a: UnitId, b: UnitId) -> DartResult<bool> {
        Ok(self.unit_locality(a)?.node == self.unit_locality(b)?.node)
    }

    /// Number of distinct nodes a team's members span. Purely local.
    pub fn team_node_span(&self, team: TeamId) -> DartResult<usize> {
        let group = self.team_get_group(team)?;
        Ok(self.placement().node_span(group.members().iter().map(|&u| u as usize)))
    }

    /// `dart_team_split_locality`: split `team` by locality domain
    /// (`MPI_Comm_split_type`-style). **Collective over `team`.**
    ///
    /// Creates — or returns the cached — [`LocalitySplit`]: one sub-team
    /// per domain the parent spans (each member learns the id of *its*
    /// domain's team), plus a leader team of each domain's lowest member.
    /// All sub-teams are ordinary DART teams (allocate on them, run
    /// collectives over them, translate ranks); they are owned by the
    /// split cache and torn down automatically when the parent team is
    /// destroyed.
    pub fn team_split_locality(
        &self,
        team: TeamId,
        scope: LocalityScope,
    ) -> DartResult<LocalitySplit> {
        if let Some(s) = self.locality_cache.borrow().get(&(team, scope)) {
            return Ok(*s);
        }
        let members = self.team_get_group(team)?.members().to_vec();
        let mut keys = Vec::with_capacity(members.len());
        for &u in &members {
            keys.push(scope.key(self.unit_locality(u)?));
        }
        // Distinct domains in ascending key order — identical on every
        // member, so the per-domain `team_create` calls below happen in
        // the same order everywhere (a collective-consistency must).
        let mut domains = keys.clone();
        domains.sort_unstable();
        domains.dedup();
        let my_key = scope.key(self.unit_locality(self.myid())?);

        let mut local: Option<TeamId> = None;
        for d in &domains {
            let mut units = Vec::new();
            for (i, &u) in members.iter().enumerate() {
                if keys[i] == *d {
                    units.push(u);
                }
            }
            let t = self.team_create(team, &DartGroup::from_units(units))?;
            if *d == my_key {
                local = t;
            }
        }
        let local = local.ok_or(DartErr::NotInTeam { unit: self.myid(), team })?;

        // Leader group: each domain's lowest member (members are sorted,
        // so the first hit per domain is the lowest).
        let mut leader_units = Vec::with_capacity(domains.len());
        for d in &domains {
            for (i, &u) in members.iter().enumerate() {
                if keys[i] == *d {
                    leader_units.push(u);
                    break;
                }
            }
        }
        let leaders = self.team_create(team, &DartGroup::from_units(leader_units))?;

        let split = LocalitySplit {
            local,
            leaders,
            is_leader: leaders.is_some(),
            domains: domains.len(),
        };
        self.locality_cache.borrow_mut().insert((team, scope), split);
        Ok(split)
    }

    /// Number of locality splits currently cached on this unit
    /// (diagnostics/tests — e.g. to assert cache invalidation).
    pub fn locality_splits_cached(&self) -> usize {
        self.locality_cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_keys_and_labels() {
        let c = DomainCoord { node: 2, numa: 3, core: 5 };
        assert_eq!(LocalityScope::Node.key(c), (2, 0));
        assert_eq!(LocalityScope::Numa.key(c), (2, 3));
        assert_eq!(LocalityScope::Node.label(), "node");
        assert_eq!(LocalityScope::Numa.to_string(), "numa");
        assert_eq!(c.to_string(), "n2:d3:c5");
    }
}
