//! DART global pointers — 128 bits: `{unitid:32, segid:16, flags:16,
//! addr_or_offset:64}` (paper §III).
//!
//! A global pointer addresses one location in the partitioned global
//! address space: `unitid` is the **absolute** unit (its rank in
//! `DART_TEAM_ALL`), `segid` identifies the team whose collective
//! allocation the pointer lives in, `flags` distinguishes collective from
//! non-collective allocations (§IV-B4), and the final 64 bits carry the
//! displacement:
//!
//! - *non-collective* pointers: displacement relative to the unit's
//!   partition base in the pre-reserved world window (Fig. 4) — these
//!   dereference "trivially, without the unit translations";
//! - *collective* pointers: displacement relative to the base of the
//!   team's reserved memory pool, **not** the beginning of the individual
//!   allocation (Fig. 5) — so aligned allocations let any unit locally
//!   compute a pointer to any member's copy.

use std::fmt;

/// Flag bit: the pointer refers to a *collective* global allocation.
pub const FLAG_COLLECTIVE: u16 = 1 << 0;

/// Flag bit: the pointer refers to *dynamically attached* memory
/// ([`crate::dart::DartEnv::memattach`], backed by the env's dynamic
/// window). The displacement is then the **absolute attach token** handed
/// out at attach time — not relative to any pool base — and `segid` is a
/// negative per-owner region id (team ids are non-negative, so dynamic
/// segments can never alias a team segment in resolution caches).
pub const FLAG_DYNAMIC: u16 = 1 << 1;

/// Absolute unit id (rank in `DART_TEAM_ALL`).
pub type UnitId = i32;

/// Team id (also used as the global pointer's segment id).
pub type TeamId = i16;

/// The default team containing all units (`DART_TEAM_ALL`).
pub const DART_TEAM_ALL: TeamId = 0;

/// 128-bit DART global pointer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GlobalPtr {
    /// Absolute unit id of the addressed memory's owner.
    pub unitid: UnitId,
    /// Segment id — the team id of the collective allocation (0 for
    /// non-collective pointers, which always live in the world window).
    pub segid: TeamId,
    /// Flag bits ([`FLAG_COLLECTIVE`], rest reserved).
    pub flags: u16,
    /// Displacement (see module docs for what it is relative to).
    pub offset: u64,
}

impl GlobalPtr {
    /// The null global pointer (`DART_GPTR_NULL`).
    pub const NULL: GlobalPtr = GlobalPtr { unitid: -1, segid: 0, flags: 0, offset: 0 };

    /// A non-collective pointer into `unit`'s world-window partition.
    pub fn non_collective(unit: UnitId, offset: u64) -> GlobalPtr {
        GlobalPtr { unitid: unit, segid: 0, flags: 0, offset }
    }

    /// A collective pointer into team `segid`'s memory pool.
    pub fn collective(unit: UnitId, segid: TeamId, offset: u64) -> GlobalPtr {
        GlobalPtr { unitid: unit, segid, flags: FLAG_COLLECTIVE, offset }
    }

    /// A dynamic pointer to `unit`'s attached region `segid` (negative),
    /// at absolute attach-token address `token`.
    pub fn dynamic(unit: UnitId, segid: TeamId, token: u64) -> GlobalPtr {
        GlobalPtr { unitid: unit, segid, flags: FLAG_DYNAMIC, offset: token }
    }

    /// Is this `DART_GPTR_NULL`?
    pub fn is_null(&self) -> bool {
        self.unitid < 0
    }

    /// Does the pointer refer to a collective allocation?
    pub fn is_collective(&self) -> bool {
        self.flags & FLAG_COLLECTIVE != 0
    }

    /// Does the pointer refer to dynamically attached memory?
    pub fn is_dynamic(&self) -> bool {
        self.flags & FLAG_DYNAMIC != 0
    }

    /// `dart_gptr_setunit`: the same location in another unit's copy of an
    /// aligned collective allocation (the paper's "advantageous property").
    #[must_use]
    pub fn with_unit(mut self, unit: UnitId) -> GlobalPtr {
        self.unitid = unit;
        self
    }

    /// `dart_gptr_incaddr`: advance the displacement by `bytes`.
    #[must_use]
    pub fn add(mut self, bytes: u64) -> GlobalPtr {
        self.offset += bytes;
        self
    }

    /// Pack into the 128-bit wire representation.
    pub fn to_bits(&self) -> u128 {
        ((self.unitid as u32 as u128) << 96)
            | ((self.segid as u16 as u128) << 80)
            | ((self.flags as u128) << 64)
            | self.offset as u128
    }

    /// Unpack from the 128-bit wire representation.
    pub fn from_bits(bits: u128) -> GlobalPtr {
        GlobalPtr {
            unitid: (bits >> 96) as u32 as i32,
            segid: (bits >> 80) as u16 as i16,
            flags: (bits >> 64) as u16,
            offset: bits as u64,
        }
    }
}

impl fmt::Display for GlobalPtr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_null() {
            return write!(f, "gptr(NULL)");
        }
        write!(
            f,
            "gptr(u{} seg{} {} +{})",
            self.unitid,
            self.segid,
            if self.is_dynamic() {
                "dyn"
            } else if self.is_collective() {
                "coll"
            } else {
                "priv"
            },
            self.offset
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_128_bits() {
        assert_eq!(std::mem::size_of::<GlobalPtr>(), 16);
    }

    #[test]
    fn bits_roundtrip() {
        let cases = [
            GlobalPtr::non_collective(0, 0),
            GlobalPtr::non_collective(12345, u64::MAX / 3),
            GlobalPtr::collective(7, 42, 0xdead_beef),
            GlobalPtr::collective(i32::MAX, i16::MAX, u64::MAX),
            GlobalPtr::dynamic(3, -1, 1 << 20),
            GlobalPtr::dynamic(0, i16::MIN, u64::MAX / 7),
            GlobalPtr::NULL,
        ];
        for g in cases {
            assert_eq!(GlobalPtr::from_bits(g.to_bits()), g, "roundtrip failed for {g}");
        }
    }

    #[test]
    fn null_detection() {
        assert!(GlobalPtr::NULL.is_null());
        assert!(!GlobalPtr::non_collective(0, 0).is_null());
    }

    #[test]
    fn setunit_preserves_offset() {
        let g = GlobalPtr::collective(1, 3, 128).with_unit(5);
        assert_eq!(g.unitid, 5);
        assert_eq!(g.segid, 3);
        assert_eq!(g.offset, 128);
        assert!(g.is_collective());
    }

    #[test]
    fn dynamic_flag_and_display() {
        let g = GlobalPtr::dynamic(2, -3, 0x10_0040);
        assert!(g.is_dynamic());
        assert!(!g.is_collective());
        assert_eq!(g.add(8).offset, 0x10_0048);
        assert!(format!("{g}").contains("dyn"));
    }

    #[test]
    fn add_advances_offset() {
        let g = GlobalPtr::non_collective(2, 100).add(28);
        assert_eq!(g.offset, 128);
        assert!(!g.is_collective());
    }
}
