//! The DART PGAS runtime on MPI-3 RMA — the paper's contribution.
//!
//! The API follows the five-part structure of §III:
//!
//! 1. **Initialization and shutdown** — [`run`] (spawns units, runs
//!    `dart_init`/`dart_exit` around the SPMD closure), [`DartEnv::myid`],
//!    [`DartEnv::size`].
//! 2. **Team and group management** — [`DartGroup`] (local, always
//!    sorted), [`DartEnv::team_create`], [`DartEnv::team_destroy`],
//!    [`DartEnv::team_myid`], [`DartEnv::team_size`], unit translation —
//!    plus the locality API ([`locality`]): [`DartEnv::unit_locality`]
//!    and the `MPI_Comm_split_type`-style
//!    [`DartEnv::team_split_locality`] that yields node-local teams and
//!    a cross-node leader team (the locality-awareness follow-up work).
//! 3. **Synchronization** — [`DartEnv::barrier`] and the MCS queue lock
//!    ([`lock::DartLock`]).
//! 4. **Global memory management** — [`DartEnv::memalloc`] /
//!    [`DartEnv::team_memalloc_aligned`] and the 128-bit [`GlobalPtr`].
//! 5. **Communication** — one-sided blocking/non-blocking put/get with
//!    handles ([`onesided`]), team collectives — blocking and nonblocking
//!    (`barrier_async`/`bcast_async`/… returning [`DartCollHandle`]) —
//!    ([`collectives`]), and the asynchronous progress engine
//!    ([`ProgressMode`], [`DartEnv::progress_poll`]) that retires deferred
//!    one-sided operations and advances nonblocking collectives in the
//!    background, making communication/computation overlap real rather
//!    than nominal (Zhou & Gracia's follow-up asynchronous-progress work).
//!
//! ## How the semantic gaps are bridged (paper §IV-B)
//!
//! | DART concept | MPI-3 realization here |
//! |---|---|
//! | sorted groups, non-collective creation | merge-sort union over `MPI_Group_incl` singletons ([`group`]) |
//! | never-reused team ids | bounded, linearly-scanned `teamlist` of recycled slots ([`team`]) |
//! | non-collective `dart_memalloc` | per-unit free-list over one pre-reserved world window ([`translation::FreeListAllocator`]) |
//! | collective aligned allocation | deterministic pool allocator + sub-window per allocation + translation table ([`translation::TranslationTable`]) |
//! | global pointer dereference | flags dispatch + absolute→relative unit translation ([`onesided`]) |
//! | RMA epochs | `lock_all` (shared) opened eagerly at init/allocation; never on the hot path |
//! | mutexes | MCS list-based queue lock from `fetch_and_op`/`compare_and_swap` ([`lock`]) |

pub mod collectives;
pub mod config;
pub mod engine;
pub mod gptr;
pub mod group;
pub mod locality;
pub mod lock;
pub mod metrics;
pub mod onesided;
pub mod team;
pub mod translation;

#[cfg(test)]
mod tests;

pub use collectives::DartCollHandle;
pub use config::DartConfig;
pub use gptr::{GlobalPtr, TeamId, UnitId, DART_TEAM_ALL, FLAG_COLLECTIVE, FLAG_DYNAMIC};
pub use group::DartGroup;
pub use locality::{DomainCoord, LocalityScope, LocalitySplit};
pub use lock::DartLock;
pub use metrics::{Metrics, MetricsSnapshot};
pub use onesided::DartHandle;

/// Re-export: the fault-injection surface lives in
/// [`crate::simnet::faults`] but is configured through
/// [`DartConfig::fault_plan`] and observed through
/// [`DartEnv::fault_stats`] / [`DartEnv::fault_trace`].
pub use crate::simnet::{FaultEvent, FaultKind, FaultPlan, FaultStats};

/// Re-export: the progress-mode knob lives in the substrate
/// ([`crate::mpisim::progress`]) but is configured through
/// [`DartConfig::progress_mode`].
pub use crate::mpisim::ProgressMode;

use crate::mpisim::{Mpi, MpiErr, Win, World, WorldConfig};
use crate::simnet::Placement;
use engine::SegmentCache;
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;
use std::sync::atomic::{AtomicI32, Ordering};
use std::sync::Arc;
use team::{TeamEntry, TeamRegistry};
use translation::FreeListAllocator;

/// Errors surfaced by the DART API.
#[derive(Debug)]
pub enum DartErr {
    /// An error propagated up from the MPI substrate.
    Mpi(MpiErr),
    /// A unit id outside `0..dart_size()`.
    InvalidUnit(UnitId),
    /// The team id is unknown on this unit (never created, or destroyed).
    UnknownTeam(TeamId),
    /// The unit is not a member of the team it addressed.
    NotInTeam {
        /// The absolute unit id that was looked up.
        unit: UnitId,
        /// The team it is not a member of.
        team: TeamId,
    },
    /// Every `teamlist` slot is occupied (capacity in the payload).
    TeamListFull(usize),
    /// The never-reused team id space is exhausted (§IV-B2).
    TeamIdOverflow,
    /// A global memory pool could not satisfy an allocation.
    OutOfMemory {
        /// Bytes requested.
        requested: u64,
        /// Pool capacity.
        pool: u64,
    },
    /// A malformed or dangling global pointer was dereferenced.
    InvalidGptr(String),
    /// A DART lock was used outside its contract (§IV-B6).
    LockMisuse(String),
    /// Any other invalid argument or state.
    Invalid(String),
}

impl fmt::Display for DartErr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DartErr::Mpi(e) => write!(f, "MPI substrate error: {e}"),
            DartErr::InvalidUnit(u) => write!(f, "invalid unit id {u}"),
            DartErr::UnknownTeam(t) => write!(f, "unknown or destroyed team {t}"),
            DartErr::NotInTeam { unit, team } => {
                write!(f, "unit {unit} is not a member of team {team}")
            }
            DartErr::TeamListFull(n) => {
                write!(f, "teamlist is full ({n} slots) — raise DartConfig::teamlist_size")
            }
            DartErr::TeamIdOverflow => write!(f, "team id space exhausted (ids are never reused)"),
            DartErr::OutOfMemory { requested, pool } => {
                write!(f, "global memory pool exhausted: requested {requested} bytes of {pool}")
            }
            DartErr::InvalidGptr(msg) => write!(f, "invalid global pointer: {msg}"),
            DartErr::LockMisuse(msg) => write!(f, "lock misuse: {msg}"),
            DartErr::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for DartErr {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DartErr::Mpi(e) => Some(e),
            _ => None,
        }
    }
}

impl From<MpiErr> for DartErr {
    fn from(e: MpiErr) -> Self {
        DartErr::Mpi(e)
    }
}

/// DART result alias.
pub type DartResult<T> = Result<T, DartErr>;

/// Reserved p2p tag for [`DartEnv::gptr_publish`]/[`DartEnv::gptr_accept`]
/// — far outside the small tag values applications use, so a publication
/// can never be matched by an application receive.
const DYN_PUBLISH_TAG: i32 = 0x44594e; // "DYN"

/// Marker trait for element types the typed layers above the byte-level
/// DART API ([`crate::dash`]) may store in distributed containers.
///
/// The DART communication API deliberately moves raw bytes (like real
/// DART-MPI's `void*` interfaces); `Element` gathers everything a typed
/// container needs on top of that: a [`crate::mpisim::Pod`] byte
/// representation, an [`crate::mpisim::MpiType`] tag so reductions work
/// ([`crate::mpisim::HasMpiType`]), ordering for `min`/`max` algorithms,
/// arithmetic for `sum`, and a default fill value for freshly allocated
/// global memory.
pub trait Element:
    crate::mpisim::HasMpiType
    + PartialOrd
    + Default
    + std::fmt::Debug
    + std::iter::Sum<Self>
    + std::ops::Add<Output = Self>
{
}

impl Element for u8 {}
impl Element for i16 {}
impl Element for i32 {}
impl Element for u32 {}
impl Element for i64 {}
impl Element for u64 {}
impl Element for f32 {}
impl Element for f64 {}

/// State shared across all units of one DART program (created before the
/// unit threads spawn).
struct DartShared {
    /// Team ids are handed out from here and **never reused** (§IV-B2).
    next_team_id: AtomicI32,
}

/// Per-unit mutable runtime state.
struct EnvState {
    registry: TeamRegistry,
    /// The pre-defined world window backing all non-collective
    /// allocations (Fig. 4), inside an eager shared epoch.
    world_win: Rc<Win>,
    /// My partition of the world window.
    nc_alloc: FreeListAllocator,
    /// The env's one dynamic window (paper §II's `MPI_Win_create_dynamic`
    /// half of the memory model): every [`DartEnv::memattach`] region on
    /// every unit lives here, inside the same eager shared epoch as the
    /// pools.
    dyn_win: crate::mpisim::DynWin,
    /// My live attached regions: attach token → `(segid, length)` — the
    /// detach-time validation and accounting record.
    dyn_segs: HashMap<u64, (TeamId, u64)>,
    /// Per-unit dynamic-segment id dispenser (handed out negated; wraps
    /// within `1..i16::MAX`, disambiguated by the globally unique tokens).
    next_dyn_seg: i16,
}

/// The per-unit DART runtime handle (what `dart_init` yields).
///
/// All DART calls go through this. It is bound to its unit's thread.
pub struct DartEnv {
    mpi: Mpi,
    myid: UnitId,
    size: usize,
    config: DartConfig,
    shared: Arc<DartShared>,
    state: RefCell<EnvState>,
    /// The communication engine's segment-resolution cache (§Perf): the
    /// §IV-B4 dereference chain is computed once per segment and memoized
    /// here, bypassing the registry scan + translation-table search on
    /// every subsequent one-sided operation. Invalidated by
    /// [`DartEnv::team_memfree`] / [`DartEnv::team_destroy`].
    pub(crate) seg_cache: RefCell<SegmentCache>,
    /// Memoized locality splits (`(team, scope)` → sub-team ids): a split
    /// is computed — and its sub-teams created — once per team and scope,
    /// then reused by every hierarchical collective. Entries (and their
    /// sub-teams) are torn down by [`DartEnv::team_destroy`].
    pub(crate) locality_cache: RefCell<HashMap<(TeamId, LocalityScope), LocalitySplit>>,
    /// Teams known to span a single node (the hierarchical-collective
    /// *flat-fallback* verdict, cached so the span probe runs once per
    /// team rather than on every collective). Valid for the team's whole
    /// lifetime — placement and membership are launch-constant — and
    /// dropped on [`DartEnv::team_destroy`].
    pub(crate) hier_flat_teams: RefCell<std::collections::HashSet<TeamId>>,
    /// Progress-engine bookkeeping: the `(ops, bytes)` retirement counters
    /// already mirrored into [`Metrics`] (see
    /// [`DartEnv::progress_poll`] and the flush family).
    pub(crate) progress_seen: Cell<(u64, u64)>,
    /// Fault-injection bookkeeping: the world-global counters already
    /// mirrored into this unit's [`Metrics`] `fault_*` fields
    /// (snapshot-diff, same pattern as `progress_seen`).
    pub(crate) fault_seen: Cell<FaultStats>,
    /// Hot-path operation counters.
    pub metrics: Metrics,
}

/// SPMD entry point: spawn `cfg.units` unit threads, run `dart_init`, call
/// `f(&env)` on every unit, then `dart_exit`, and join.
///
/// ```no_run
/// use dart::dart::{run, DartConfig, DART_TEAM_ALL};
/// run(DartConfig::with_units(4), |env| {
///     println!("unit {}/{}", env.myid(), env.size());
///     env.barrier(DART_TEAM_ALL).unwrap();
/// }).unwrap();
/// ```
pub fn run<F>(cfg: DartConfig, f: F) -> DartResult<()>
where
    F: Fn(&DartEnv) + Send + Sync,
{
    let shared = Arc::new(DartShared { next_team_id: AtomicI32::new(1) });
    let world_cfg = WorldConfig {
        nranks: cfg.units,
        topology: cfg.topology,
        pin: cfg.pin.clone(),
        cost: cfg.cost,
        pin_os_threads: cfg.pin_os_threads,
        progress: cfg.progress_mode,
        exec: cfg.exec,
        max_os_threads: cfg.max_os_threads,
        faults: cfg.fault_plan,
    };
    World::run(world_cfg, move |mpi| {
        let env = DartEnv::init(mpi, cfg.clone(), shared.clone()).expect("dart_init failed");
        f(&env);
        env.exit().expect("dart_exit failed");
    });
    Ok(())
}

impl DartEnv {
    /// `dart_init`: establish the world team (`DART_TEAM_ALL`), reserve
    /// the non-collective world window and the world team's collective
    /// pool, and open the eager shared epochs (§IV-B5).
    fn init(mpi: Mpi, config: DartConfig, shared: Arc<DartShared>) -> DartResult<Self> {
        let comm = mpi.comm_world();
        let alloc_win = |size: usize| {
            if config.shmem_windows {
                Win::allocate_shared(&comm, size)
            } else {
                Win::allocate(&comm, size)
            }
        };
        // Pre-reserved world window for non-collective allocations.
        let world_win = alloc_win(config.non_collective_pool)?;
        world_win.lock_all()?;
        // DART_TEAM_ALL's collective pool (sub-windows inherit the
        // shared-memory flavour).
        let pool = alloc_win(config.team_pool)?;
        pool.lock_all()?;
        // The dynamic window (paper §II): exposes no memory yet; units
        // register regions at runtime with `memattach`. Same shared-memory
        // flavour and eager epoch as the pools.
        let dyn_win = crate::mpisim::DynWin::create_with(&comm, config.shmem_windows)?;

        let mut registry = TeamRegistry::new(config.teamlist_size, config.indexed_teamlist);
        registry.insert(TeamEntry::new(
            DART_TEAM_ALL,
            comm.clone(),
            Rc::new(pool),
            config.team_pool as u64,
        ))?;

        let myid = mpi.world_rank() as UnitId;
        let size = mpi.world_size();
        let nc_alloc = FreeListAllocator::new(config.non_collective_pool as u64);
        let world_win = Rc::new(world_win);
        let seg_cache = RefCell::new(SegmentCache::new(
            world_win.clone(),
            dyn_win.win_rc(),
            config.segment_cache,
        ));
        Ok(DartEnv {
            mpi,
            myid,
            size,
            config,
            shared,
            state: RefCell::new(EnvState {
                registry,
                world_win,
                nc_alloc,
                dyn_win,
                dyn_segs: HashMap::new(),
                next_dyn_seg: 1,
            }),
            seg_cache,
            locality_cache: RefCell::new(HashMap::new()),
            hier_flat_teams: RefCell::new(std::collections::HashSet::new()),
            progress_seen: Cell::new((0, 0)),
            fault_seen: Cell::new(FaultStats::default()),
            metrics: Metrics::new(),
        })
    }

    /// `dart_exit`: collective teardown of whatever is still live.
    fn exit(self) -> DartResult<()> {
        // A final rendezvous so no unit tears down while others still
        // communicate. Window memory is reclaimed when handles drop;
        // epochs are released by `Win::drop`. Deliberately the *flat*
        // communicator barrier: routing through the hierarchical path
        // here could lazily create the whole locality split (sub-teams +
        // pool windows, never destroyed) purely to synchronize shutdown.
        self.team_comm(DART_TEAM_ALL)?.barrier()?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Identity & environment queries
    // ------------------------------------------------------------------

    /// `dart_myid`: my absolute unit id (rank in `DART_TEAM_ALL`).
    #[inline]
    pub fn myid(&self) -> UnitId {
        self.myid
    }

    /// `dart_size`: total number of units.
    #[inline]
    pub fn size(&self) -> usize {
        self.size
    }

    /// The modelled placement (topology + unit coordinates).
    pub fn placement(&self) -> &Placement {
        &self.mpi.state().placement
    }

    /// The launch configuration.
    pub fn config(&self) -> &DartConfig {
        &self.config
    }

    /// Snapshot of the **world-global** injected-fault counters (all zero
    /// without a [`DartConfig::fault_plan`]). Also mirrors the deltas into
    /// this unit's [`Metrics`] `fault_*` counters, and returns exactly the
    /// snapshot that was mirrored — so after a team barrier the returned
    /// stats and the unit's `fault_*` metrics always agree, even if a
    /// sibling unit books another event concurrently.
    pub fn fault_stats(&self) -> FaultStats {
        self.sync_fault_metrics();
        self.fault_seen.get()
    }

    /// The world's recorded dynamic fault events in canonical order — two
    /// runs of the same seeded scenario must return identical traces (the
    /// chaos suite's determinism oracle). Empty without a fault plan.
    pub fn fault_trace(&self) -> Vec<FaultEvent> {
        self.mpi.state().fault_trace()
    }

    /// `(slot limit, peak concurrently runnable units)` of the pooled
    /// execution gate, or `None` under
    /// [`crate::mpisim::ExecMode::ThreadPerRank`] (see
    /// [`DartConfig::with_exec`]). The scale smoke test asserts the peak
    /// stays at or below the configured bound.
    pub fn exec_gate_stats(&self) -> Option<(usize, usize)> {
        self.mpi.state().exec_gate_stats()
    }

    /// World-global count of modelled transfers that crossed a node
    /// boundary (see [`crate::mpisim::WorldState::inter_node_messages`]).
    /// Deterministic, so the scale bench asserts the hierarchical
    /// collectives' cross-node advantage on it rather than on wall time.
    pub fn inter_node_messages(&self) -> u64 {
        self.mpi.state().inter_node_messages()
    }

    /// Directed rank pairs that have communicated so far — the lazily
    /// populated channel table's population (see
    /// [`crate::mpisim::WorldState::active_channels`]). The scale bench
    /// asserts this stays far below `units²` under logarithmic collectives.
    pub fn active_channels(&self) -> usize {
        self.mpi.state().active_channels()
    }

    pub(crate) fn mpi(&self) -> &Mpi {
        &self.mpi
    }

    /// The world group (every unit) as a DART group.
    pub fn group_all(&self) -> DartGroup {
        DartGroup::from_units((0..self.size as UnitId).collect())
    }

    /// The MPI world group (for `dart_group_addmember`).
    pub fn mpi_world_group(&self) -> crate::mpisim::Group {
        self.mpi.group_world()
    }

    // ------------------------------------------------------------------
    // Teams (§IV-B2)
    // ------------------------------------------------------------------

    /// `dart_team_create(parent, group)`: collective over the *parent*
    /// team. Members of `group` (which must be a subset of the parent) get
    /// the new team's id; other parent members get `Ok(None)`
    /// (`DART_TEAM_NULL`).
    pub fn team_create(&self, parent: TeamId, group: &DartGroup) -> DartResult<Option<TeamId>> {
        if group.is_empty() {
            return Err(DartErr::Invalid("cannot create a team from an empty group".into()));
        }
        let parent_comm = {
            let st = self.state.borrow();
            st.registry.get(parent)?.comm.clone()
        };
        // Agree on the new id: the parent's rank-0 draws from the global
        // dispenser (ids are never reused), then broadcasts.
        let mut id_bytes = if parent_comm.rank() == 0 {
            let id = self.shared.next_team_id.fetch_add(1, Ordering::SeqCst);
            if id > i16::MAX as i32 {
                return Err(DartErr::TeamIdOverflow);
            }
            (id as i16).to_ne_bytes()
        } else {
            [0; 2]
        };
        parent_comm.bcast(&mut id_bytes, 0)?;
        let team_id = TeamId::from_ne_bytes(id_bytes);

        // Build the communicator: collective over the parent. The group is
        // sorted (DART invariant), so team rank == sorted position.
        let sub = parent_comm.create_from_group(&group.to_mpi())?;
        let Some(comm) = sub else {
            return Ok(None);
        };
        // Members reserve the team's collective pool and open its epoch.
        let pool = if self.config.shmem_windows {
            Win::allocate_shared(&comm, self.config.team_pool)?
        } else {
            Win::allocate(&comm, self.config.team_pool)?
        };
        pool.lock_all()?;
        let entry = TeamEntry::new(team_id, comm, Rc::new(pool), self.config.team_pool as u64);
        self.state.borrow_mut().registry.insert(entry)?;
        Ok(Some(team_id))
    }

    /// `dart_team_destroy`: collective over the team's members. Frees all
    /// of the team's collective allocations (in creation order — every
    /// member holds the same table), the pool, and recycles the teamlist
    /// slot. The id is never reused.
    pub fn team_destroy(&self, team: TeamId) -> DartResult<()> {
        if team == DART_TEAM_ALL {
            return Err(DartErr::Invalid("cannot destroy DART_TEAM_ALL".into()));
        }
        // Split sub-teams are owned by their parent's cached split:
        // destroying one directly would invalidate the cache only on the
        // *destroyed team's* members (team_destroy is collective over
        // them, not over the parent), leaving the other parent members
        // with a stale split and desynchronizing the next collective that
        // consults it. Reject it — destroying the parent cascades.
        {
            let cache = self.locality_cache.borrow();
            if cache.values().any(|s| s.local == team || s.leaders == Some(team)) {
                return Err(DartErr::Invalid(format!(
                    "team {team} is owned by a locality split — destroy its parent team \
                     instead (the split cascades)"
                )));
            }
        }
        // Locality-split teardown (collectively consistent: every member
        // of `team` caches the same split). Sub-teams derived *from* this
        // team are destroyed first — leader team (its members only), then
        // each member's domain-local team — recursing through any splits
        // of the sub-teams themselves. The guard above never fires during
        // this recursion: each `(team, scope)` entry is removed before its
        // sub-teams are destroyed.
        for scope in LocalityScope::ALL {
            let cached = self.locality_cache.borrow_mut().remove(&(team, scope));
            if let Some(s) = cached {
                if let Some(lt) = s.leaders {
                    self.team_destroy(lt)?;
                }
                self.team_destroy(s.local)?;
            }
        }
        self.hier_flat_teams.borrow_mut().remove(&team);
        let mut entry = self.state.borrow_mut().registry.remove(team)?;
        // Drop the engine's cached window handles for this team before the
        // exclusive-ownership check below.
        self.seg_cache.borrow_mut().invalidate_team(team);
        self.metrics.seg_cache_size.set(self.seg_cache.borrow().live() as u64);
        for e in entry.table.drain() {
            e.win.unlock_all()?;
            match Rc::try_unwrap(e.win) {
                Ok(w) => w.free()?,
                Err(_) => {
                    return Err(DartErr::Invalid(
                        "collective allocation window still referenced at team destroy".into(),
                    ))
                }
            }
        }
        entry.pool.unlock_all()?;
        match Rc::try_unwrap(entry.pool) {
            Ok(w) => w.free()?,
            Err(_) => {
                return Err(DartErr::Invalid("team pool still referenced at team destroy".into()))
            }
        }
        Ok(())
    }

    /// `dart_team_myid`: my rank within `team`.
    pub fn team_myid(&self, team: TeamId) -> DartResult<usize> {
        let st = self.state.borrow();
        Ok(st.registry.get(team)?.comm.rank())
    }

    /// `dart_team_size`.
    pub fn team_size(&self, team: TeamId) -> DartResult<usize> {
        let st = self.state.borrow();
        Ok(st.registry.get(team)?.comm.size())
    }

    /// `dart_team_get_group`: the team's members as a (sorted) DART group.
    pub fn team_get_group(&self, team: TeamId) -> DartResult<DartGroup> {
        let st = self.state.borrow();
        let entry = st.registry.get(team)?;
        Ok(DartGroup::from_units(
            entry.comm.rank_table().iter().map(|&w| w as UnitId).collect(),
        ))
    }

    /// `dart_team_unit_l2g`: team-relative rank → absolute unit id.
    pub fn team_unit_l2g(&self, team: TeamId, rel: usize) -> DartResult<UnitId> {
        let st = self.state.borrow();
        let entry = st.registry.get(team)?;
        Ok(entry.comm.world_rank_of(rel).map(|w| w as UnitId)?)
    }

    /// `dart_team_unit_g2l`: absolute unit id → team-relative rank.
    pub fn team_unit_g2l(&self, team: TeamId, unit: UnitId) -> DartResult<usize> {
        let st = self.state.borrow();
        let entry = st.registry.get(team)?;
        entry.rank_of_unit(unit).ok_or(DartErr::NotInTeam { unit, team })
    }

    /// Live team ids on this unit (diagnostics).
    pub fn live_teams(&self) -> Vec<TeamId> {
        self.state.borrow().registry.live_teams()
    }

    // ------------------------------------------------------------------
    // Global memory (§IV-B3)
    // ------------------------------------------------------------------

    /// `dart_memalloc`: **non-collective** (local) allocation of `nbytes`
    /// of globally accessible memory from my partition of the pre-reserved
    /// world window (Fig. 4). Returns a non-collective global pointer.
    pub fn memalloc(&self, nbytes: u64) -> DartResult<GlobalPtr> {
        let mut st = self.state.borrow_mut();
        let base = st.nc_alloc.alloc(nbytes)?;
        Ok(GlobalPtr::non_collective(self.myid, base))
    }

    /// `dart_memfree`: free a non-collective allocation. Only the owning
    /// unit may free (the allocation lives in *its* partition).
    pub fn memfree(&self, gptr: GlobalPtr) -> DartResult<()> {
        if gptr.is_collective() {
            return Err(DartErr::InvalidGptr("memfree on a collective pointer".into()));
        }
        if gptr.unitid != self.myid {
            return Err(DartErr::InvalidGptr(format!(
                "memfree of unit {}'s memory by unit {}",
                gptr.unitid, self.myid
            )));
        }
        self.state.borrow_mut().nc_alloc.free(gptr.offset)
    }

    /// `dart_team_memalloc_aligned`: **collective** over `team`; every
    /// member allocates `nbytes` and a window is created over that range
    /// of the team's pool (Fig. 5). Returns a collective global pointer
    /// whose offset is pool-relative and identical on every member
    /// (aligned + symmetric), initially pointing at the team's first
    /// member.
    ///
    /// Edge-case contract (asserted by `rust/tests/dart_integration.rs`):
    ///
    /// - `nbytes` is **per member** — it is *not* divided across the team,
    ///   so it need not be a multiple of the team size; every member
    ///   contributes `nbytes` rounded up to
    ///   [`translation::DART_ALIGN`]-byte granularity, and successive
    ///   allocations land [`translation::DART_ALIGN`]-aligned at identical
    ///   pool offsets on every member.
    /// - a **zero-byte** request is rejected with [`DartErr::Invalid`] on
    ///   every member (a zero-extent window has no addressable location a
    ///   global pointer could name).
    pub fn team_memalloc_aligned(&self, team: TeamId, nbytes: u64) -> DartResult<GlobalPtr> {
        let (base, len, pool, unit0) = {
            let mut st = self.state.borrow_mut();
            let entry = st.registry.get_mut(team)?;
            let base = entry.alloc.alloc(nbytes)?;
            let len = entry.alloc.size_of(base).expect("just allocated");
            let unit0 = entry.comm.world_rank_of(0)? as UnitId;
            (base, len, entry.pool.clone(), unit0)
        };
        // One window per collective allocation, over the pool sub-range
        // (collective call — must happen outside the RefCell borrow);
        // start its shared epoch eagerly (§IV-B5).
        let win = pool.create_sub(base as usize, len as usize)?;
        win.lock_all()?;
        {
            let mut st = self.state.borrow_mut();
            let entry = st.registry.get_mut(team)?;
            entry.table.add(base, len, Rc::new(win))?;
        }
        self.metrics.allocs.bump();
        Ok(GlobalPtr::collective(unit0, team, base))
    }

    /// `dart_team_memfree`: collective; frees the allocation `gptr` points
    /// into and its window.
    pub fn team_memfree(&self, team: TeamId, gptr: GlobalPtr) -> DartResult<()> {
        if !gptr.is_collective() || gptr.segid != team {
            return Err(DartErr::InvalidGptr(format!(
                "team_memfree({team}) of non-matching pointer {gptr}"
            )));
        }
        let (entry_win, base) = {
            let mut st = self.state.borrow_mut();
            let entry = st.registry.get_mut(team)?;
            let e = entry.table.remove(gptr.offset)?;
            entry.alloc.free(e.base)?;
            (e.win, e.base)
        };
        // Drop the engine's cached resolutions of this allocation: they
        // hold an `Rc` of its window (the exclusive-ownership check below
        // would fail), and a later allocation may reuse this pool offset.
        self.seg_cache.borrow_mut().invalidate_segment(team, base);
        self.metrics.seg_cache_size.set(self.seg_cache.borrow().live() as u64);
        entry_win.unlock_all()?;
        match Rc::try_unwrap(entry_win) {
            Ok(w) => Ok(w.free()?),
            Err(_) => Err(DartErr::Invalid(
                "collective allocation window still referenced at free".into(),
            )),
        }
    }

    /// Number of live collective allocations in a team (diagnostics).
    pub fn team_live_allocs(&self, team: TeamId) -> DartResult<usize> {
        Ok(self.state.borrow().registry.get(team)?.table.len())
    }

    // ------------------------------------------------------------------
    // Dynamic global memory (§II dynamic windows)
    // ------------------------------------------------------------------

    /// `dart_memattach`: **non-collective** registration of `nbytes` of
    /// fresh zeroed globally accessible memory — the second half of the
    /// paper's memory model, backed by the env's dynamic window
    /// (`MPI_Win_create_dynamic` + `MPI_Win_attach`) instead of any
    /// pre-reserved pool, so it is not bounded by
    /// [`DartConfig::non_collective_pool`].
    ///
    /// The returned pointer carries [`gptr::FLAG_DYNAMIC`], a fresh
    /// negative per-unit segment id, and the region's **attach token** as
    /// its displacement. Peers can use it only after learning it out of
    /// band — ship it with [`DartEnv::gptr_publish`]/[`DartEnv::gptr_accept`],
    /// [`DartEnv::gptr_bcast`], or any collective of your own. Every
    /// one-sided operation (async/blocking put/get, strided, accumulate,
    /// `fetch_and_op`, `compare_and_swap`, the locality fast path, flushes
    /// and the progress engine) works on it unchanged.
    pub fn memattach(&self, nbytes: u64) -> DartResult<GlobalPtr> {
        if nbytes == 0 {
            return Err(DartErr::Invalid("memattach of zero bytes".into()));
        }
        let mut st = self.state.borrow_mut();
        let token = st.dyn_win.attach(nbytes as usize)?;
        let segid = -st.next_dyn_seg;
        st.next_dyn_seg = if st.next_dyn_seg == i16::MAX { 1 } else { st.next_dyn_seg + 1 };
        st.dyn_segs.insert(token, (segid, nbytes));
        self.metrics.dyn_attach_ops.bump();
        self.metrics.dyn_bytes_attached.set(self.metrics.dyn_bytes_attached.get() + nbytes);
        Ok(GlobalPtr::dynamic(self.myid, segid, token))
    }

    /// `dart_memdetach`: withdraw a region this unit attached with
    /// [`DartEnv::memattach`]. **Non-collective and owner-only**; `gptr`
    /// must be the exact pointer `memattach` returned (not an interior
    /// pointer). My own cached resolutions are dropped here; remote units'
    /// caches invalidate lazily through the window's detach generation
    /// (see `mpisim::dynwin`) — their next operation on a pointer into the
    /// dead region re-resolves and fails, operations *racing* the detach
    /// read junk but never dangle.
    pub fn memdetach(&self, gptr: GlobalPtr) -> DartResult<()> {
        if !gptr.is_dynamic() {
            return Err(DartErr::InvalidGptr(format!("memdetach of non-dynamic {gptr}")));
        }
        if gptr.unitid != self.myid {
            return Err(DartErr::InvalidGptr(format!(
                "memdetach of unit {}'s region by unit {}",
                gptr.unitid, self.myid
            )));
        }
        let len = {
            let mut st = self.state.borrow_mut();
            let (segid, len) = *st.dyn_segs.get(&gptr.offset).ok_or_else(|| {
                DartErr::InvalidGptr(format!("{gptr} is not a live attach token"))
            })?;
            if segid != gptr.segid {
                return Err(DartErr::InvalidGptr(format!(
                    "{gptr} names segment {} but token belongs to segment {segid}",
                    gptr.segid
                )));
            }
            st.dyn_win.detach(gptr.offset)?;
            st.dyn_segs.remove(&gptr.offset);
            len
        };
        self.seg_cache.borrow_mut().invalidate_segment(gptr.segid, gptr.offset);
        self.metrics.seg_cache_size.set(self.seg_cache.borrow().live() as u64);
        self.metrics.dyn_detach_ops.bump();
        self.metrics.dyn_bytes_attached.set(self.metrics.dyn_bytes_attached.get() - len);
        Ok(())
    }

    /// Bytes currently attached by **this unit** via [`DartEnv::memattach`]
    /// (diagnostics; the world-wide figure is the sum over units).
    pub fn dyn_attached_bytes(&self) -> u64 {
        self.metrics.dyn_bytes_attached.get()
    }

    /// Point-to-point attach-token publication: ship `gptr` to unit `to`,
    /// who must call [`DartEnv::gptr_accept`]`(my id)`. The 128-bit wire
    /// form travels over the world communicator's two-sided channel on a
    /// reserved tag, so it cannot match an application `recv`.
    pub fn gptr_publish(&self, gptr: GlobalPtr, to: UnitId) -> DartResult<()> {
        if to < 0 || to as usize >= self.size {
            return Err(DartErr::InvalidUnit(to));
        }
        let comm = self.team_comm(DART_TEAM_ALL)?;
        Ok(comm.send(&gptr.to_bits().to_ne_bytes(), to as usize, DYN_PUBLISH_TAG)?)
    }

    /// Receive a global pointer published by unit `from` with
    /// [`DartEnv::gptr_publish`] (blocking).
    pub fn gptr_accept(&self, from: UnitId) -> DartResult<GlobalPtr> {
        if from < 0 || from as usize >= self.size {
            return Err(DartErr::InvalidUnit(from));
        }
        let comm = self.team_comm(DART_TEAM_ALL)?;
        let (bytes, _) = comm.recv_vec(from as usize, DYN_PUBLISH_TAG)?;
        let bytes: [u8; 16] = bytes
            .try_into()
            .map_err(|_| DartErr::Invalid("malformed gptr publication".into()))?;
        Ok(GlobalPtr::from_bits(u128::from_ne_bytes(bytes)))
    }

    /// Collective attach-token publication: broadcast `gptr` from `root`
    /// (team-relative rank) to every member of `team`.
    pub fn gptr_bcast(&self, team: TeamId, gptr: &mut GlobalPtr, root: usize) -> DartResult<()> {
        let mut bytes = gptr.to_bits().to_ne_bytes();
        self.bcast(team, &mut bytes, root)?;
        *gptr = GlobalPtr::from_bits(u128::from_ne_bytes(bytes));
        Ok(())
    }

    // ------------------------------------------------------------------
    // Internal plumbing shared with onesided/collectives/lock
    // ------------------------------------------------------------------

    // `deref_gptr` and `with_win` — the §IV-B4 dereference chain behind
    // every one-sided operation — now live in [`engine`], where they are
    // memoized by the segment cache. Only the registry slow path stays
    // here, next to the state it walks.

    /// The §IV-B4 slow path: resolve a *collective* pointer through the
    /// team registry and translation table, returning the covering
    /// allocation extent so the engine can memoize it.
    pub(crate) fn resolve_collective_slow(
        &self,
        gptr: GlobalPtr,
    ) -> DartResult<engine::Resolution> {
        let st = self.state.borrow();
        let entry = st.registry.get(gptr.segid)?;
        let target = entry
            .rank_of_unit(gptr.unitid)
            .ok_or(DartErr::NotInTeam { unit: gptr.unitid, team: gptr.segid })?;
        let e = entry
            .table
            .lookup_entry(gptr.offset)
            .ok_or_else(|| DartErr::InvalidGptr(format!("{gptr} not in any allocation")))?;
        Ok(engine::Resolution {
            segid: gptr.segid,
            unitid: gptr.unitid,
            base: e.base,
            len: e.len,
            target,
            win: e.win.clone(),
            dyn_gen: 0,
        })
    }

    /// The communicator of a team (for collectives and the lock).
    pub(crate) fn team_comm(&self, team: TeamId) -> DartResult<crate::mpisim::Comm> {
        Ok(self.state.borrow().registry.get(team)?.comm.clone())
    }

    /// Per-team lock-init sequence (collectively consistent, §IV-B6).
    pub(crate) fn next_lock_seq(&self, team: TeamId) -> DartResult<i32> {
        let mut st = self.state.borrow_mut();
        let entry = st.registry.get_mut(team)?;
        let seq = entry.lock_seq;
        entry.lock_seq += 1;
        Ok(seq)
    }

    /// Local read of memory this unit owns, through a global pointer.
    pub fn local_read(&self, gptr: GlobalPtr, buf: &mut [u8]) -> DartResult<()> {
        if gptr.unitid != self.myid {
            return Err(DartErr::InvalidGptr(format!(
                "local_read of unit {}'s memory on unit {}",
                gptr.unitid, self.myid
            )));
        }
        let (win, _target, disp) = self.deref_gptr(gptr)?;
        Ok(win.read_local(disp as usize, buf)?)
    }

    /// Local write to memory this unit owns, through a global pointer.
    pub fn local_write(&self, gptr: GlobalPtr, buf: &[u8]) -> DartResult<()> {
        if gptr.unitid != self.myid {
            return Err(DartErr::InvalidGptr(format!(
                "local_write of unit {}'s memory on unit {}",
                gptr.unitid, self.myid
            )));
        }
        let (win, _target, disp) = self.deref_gptr(gptr)?;
        Ok(win.write_local(disp as usize, buf)?)
    }
}
