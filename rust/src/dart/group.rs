//! DART groups: ordered sets of absolute unit ids, **always sorted
//! ascending** (paper §IV-B1, Fig. 2).
//!
//! This is the first semantic gap the paper bridges: DART group creation
//! is *non-collective* (`dart_group_addmember`) and operates on absolute
//! unit ids, while MPI groups are built collectively from relative ranks
//! and end up "arranged in a random fashion" after unions. Following the
//! paper:
//!
//! - [`DartGroup::union`] is a **merge-sort** of the two inputs;
//! - [`DartGroup::addmember`] first builds a singleton via
//!   `MPI_Group_incl(MPI_COMM_WORLD, 1, [unit])`, then merges it in with
//!   the sorting union — so "DART groups are guaranteed to be ordered once
//!   created".

use super::gptr::UnitId;
use super::{DartErr, DartResult};
use crate::mpisim::Group as MpiGroup;

/// An ordered (ascending, by absolute unit id) set of units.
///
/// Group operations are *local* (§III): unlike teams, no communication is
/// involved, so methods take `&self` and need no runtime handle.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct DartGroup {
    /// Invariant: strictly ascending absolute unit ids.
    members: Vec<UnitId>,
}

impl DartGroup {
    /// `dart_group_init`: the empty group.
    pub fn new() -> DartGroup {
        DartGroup { members: Vec::new() }
    }

    /// A group from arbitrary unit ids (sorted + deduplicated — the DART
    /// invariant is established on construction).
    pub fn from_units(mut units: Vec<UnitId>) -> DartGroup {
        units.sort_unstable();
        units.dedup();
        DartGroup { members: units }
    }

    /// `dart_group_addmember(g, unitid)`: insert one absolute unit id,
    /// keeping the group sorted.
    ///
    /// Implemented exactly as §IV-B1 describes: build the singleton MPI
    /// group `MPI_Group_incl(world, 1, [unitid])`, then merge it with the
    /// sorting [`DartGroup::union`] — rather than trusting MPI's unsorted
    /// union semantics.
    pub fn addmember(&mut self, unitid: UnitId, world: &MpiGroup) -> DartResult<()> {
        if unitid < 0 || unitid as usize >= world.size() {
            return Err(DartErr::InvalidUnit(unitid));
        }
        // MPI_Group_incl on MPI_COMM_WORLD's group: relative rank ==
        // absolute id there, which is what makes this correct.
        let singleton = world
            .incl(&[unitid as usize])
            .map_err(DartErr::Mpi)?;
        let merged = Self::union(self, &DartGroup::from_mpi(&singleton));
        *self = merged;
        Ok(())
    }

    /// `dart_group_delmember`.
    pub fn delmember(&mut self, unitid: UnitId) {
        self.members.retain(|&m| m != unitid);
    }

    /// `dart_group_union(g1, g2)`: **merge-sort** union (paper §IV-B1) —
    /// the output is sorted regardless of input order, unlike
    /// `MPI_Group_union` which appends.
    pub fn union(g1: &DartGroup, g2: &DartGroup) -> DartGroup {
        let (a, b) = (&g1.members, &g2.members);
        let mut out = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => {
                    out.push(a[i]);
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.push(b[j]);
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.push(a[i]);
                    i += 1;
                    j += 1;
                }
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
        DartGroup { members: out }
    }

    /// `dart_group_intersect`.
    pub fn intersect(g1: &DartGroup, g2: &DartGroup) -> DartGroup {
        DartGroup {
            members: g1.members.iter().copied().filter(|m| g2.ismember(*m)).collect(),
        }
    }

    /// `dart_group_ismember`.
    pub fn ismember(&self, unitid: UnitId) -> bool {
        self.members.binary_search(&unitid).is_ok()
    }

    /// `dart_group_size`.
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// No members?
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// `dart_group_getmembers`: the sorted absolute unit ids.
    pub fn members(&self) -> &[UnitId] {
        &self.members
    }

    /// `dart_group_split`: partition into `n` contiguous sub-groups of
    /// near-equal size (the first `size % n` parts get one extra member).
    pub fn split(&self, n: usize) -> DartResult<Vec<DartGroup>> {
        if n == 0 {
            return Err(DartErr::Invalid("split into 0 parts".into()));
        }
        let base = self.members.len() / n;
        let extra = self.members.len() % n;
        let mut parts = Vec::with_capacity(n);
        let mut at = 0;
        for k in 0..n {
            let len = base + usize::from(k < extra);
            parts.push(DartGroup { members: self.members[at..at + len].to_vec() });
            at += len;
        }
        Ok(parts)
    }

    /// Relative position of a unit within the group (the unit's rank in a
    /// team created from this group).
    pub fn rank_of(&self, unitid: UnitId) -> Option<usize> {
        self.members.binary_search(&unitid).ok()
    }

    /// Convert from an MPI group (member identities, re-sorted to DART
    /// order).
    pub fn from_mpi(g: &MpiGroup) -> DartGroup {
        DartGroup::from_units(g.members().iter().map(|&m| m as UnitId).collect())
    }

    /// Convert to an MPI group, in DART (sorted) order.
    pub fn to_mpi(&self) -> MpiGroup {
        MpiGroup::new(self.members.iter().map(|&m| m as usize).collect())
    }

    /// Check the sortedness invariant (used by property tests).
    pub fn is_sorted_invariant(&self) -> bool {
        self.members.windows(2).all(|w| w[0] < w[1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world(n: usize) -> MpiGroup {
        MpiGroup::new((0..n).collect())
    }

    #[test]
    fn addmember_keeps_sorted() {
        // Paper Fig. 2: members added in arbitrary order, group stays
        // ascending.
        let w = world(10);
        let mut g = DartGroup::new();
        for u in [5, 1, 9, 3, 0] {
            g.addmember(u, &w).unwrap();
        }
        assert_eq!(g.members(), &[0, 1, 3, 5, 9]);
        assert!(g.is_sorted_invariant());
    }

    #[test]
    fn addmember_duplicate_is_idempotent() {
        let w = world(4);
        let mut g = DartGroup::new();
        g.addmember(2, &w).unwrap();
        g.addmember(2, &w).unwrap();
        assert_eq!(g.members(), &[2]);
    }

    #[test]
    fn addmember_rejects_out_of_range() {
        let w = world(4);
        let mut g = DartGroup::new();
        assert!(g.addmember(4, &w).is_err());
        assert!(g.addmember(-1, &w).is_err());
    }

    #[test]
    fn union_merge_sorts() {
        // Contrast with MpiGroup::union_mpi, which appends unsorted.
        let g1 = DartGroup::from_units(vec![5, 1]);
        let g2 = DartGroup::from_units(vec![3, 1, 0]);
        let u = DartGroup::union(&g1, &g2);
        assert_eq!(u.members(), &[0, 1, 3, 5]);

        let mpi_u = g1.to_mpi().union_mpi(&g2.to_mpi());
        assert_ne!(
            mpi_u.members().iter().map(|&m| m as i32).collect::<Vec<_>>(),
            u.members(),
            "MPI union must NOT be sorted — that's the gap DART bridges"
        );
    }

    #[test]
    fn intersect_and_ismember() {
        let g1 = DartGroup::from_units(vec![1, 3, 5, 7]);
        let g2 = DartGroup::from_units(vec![3, 4, 5]);
        let i = DartGroup::intersect(&g1, &g2);
        assert_eq!(i.members(), &[3, 5]);
        assert!(i.ismember(3));
        assert!(!i.ismember(1));
    }

    #[test]
    fn split_balances() {
        let g = DartGroup::from_units((0..10).collect());
        let parts = g.split(3).unwrap();
        assert_eq!(parts.iter().map(|p| p.size()).collect::<Vec<_>>(), vec![4, 3, 3]);
        let rejoined = parts.iter().fold(DartGroup::new(), |acc, p| DartGroup::union(&acc, p));
        assert_eq!(rejoined, g);
    }

    #[test]
    fn delmember() {
        let mut g = DartGroup::from_units(vec![1, 2, 3]);
        g.delmember(2);
        assert_eq!(g.members(), &[1, 3]);
        g.delmember(9); // absent: no-op
        assert_eq!(g.members(), &[1, 3]);
    }

    #[test]
    fn rank_of_is_sorted_position() {
        let g = DartGroup::from_units(vec![10, 20, 30]);
        assert_eq!(g.rank_of(20), Some(1));
        assert_eq!(g.rank_of(15), None);
    }
}
