//! One-sided communication (§III, §IV-B5): blocking and non-blocking
//! put/get over global pointers, with `wait`/`test` completion calls.
//!
//! Every operation performs the §IV-B4 dereference chain:
//!
//! 1. flags dispatch — collective vs non-collective pointer;
//! 2. unit translation (collective only) — absolute unit id → team rank;
//! 3. window resolution — world window, or translation-table lookup;
//! 4. the MPI request-based RMA call, inside the eagerly-opened shared
//!    passive-target epoch (so no epoch calls appear here).
//!
//! Steps 1–3 are memoized by the communication engine's segment cache
//! ([`crate::dart::engine`]); the chain is walked in full only on the
//! first operation against a `(team, unit, allocation)` triple. The
//! engine also provides the deferred-completion variants
//! (`put_async`/`get_async` + `flush`/`flush_all`) of the handle-based
//! operations below.
//!
//! *Blocking* operations "do not return until the data transfers complete
//! both at the origin locally and at the target remotely" — put/get +
//! flush. *Non-blocking* operations return a [`DartHandle`] for
//! `dart_wait`/`dart_test`/`dart_waitall`/`dart_testall`.

use super::gptr::GlobalPtr;
use super::{DartEnv, DartResult};
use crate::mpisim::{as_bytes, as_bytes_mut, HasMpiType, MpiOp, Pod, RmaRequest};

/// Completion handle of a non-blocking DART one-sided operation
/// (`dart_handle_t`).
pub struct DartHandle {
    req: Option<RmaRequest>,
}

impl DartHandle {
    fn new(req: RmaRequest) -> Self {
        DartHandle { req: Some(req) }
    }

    /// An already-completed handle (zero-byte transfers).
    pub fn completed() -> Self {
        DartHandle { req: None }
    }

    /// Has the transfer completed?
    pub fn is_complete(&self) -> bool {
        self.req.as_ref().map_or(true, |r| r.test())
    }
}

impl DartEnv {
    // ------------------------------------------------------------------
    // Non-blocking (dart_put / dart_get)
    // ------------------------------------------------------------------

    /// `dart_put`: non-blocking transfer of `src` to the global location
    /// `gptr`. The returned handle must be completed with
    /// [`DartEnv::wait`] (or `waitall`) before `src`'s remote visibility
    /// is guaranteed.
    pub fn put(&self, gptr: GlobalPtr, src: &[u8]) -> DartResult<DartHandle> {
        let req =
            self.with_win(gptr, |win, target, disp| Ok(win.rput(src, target, disp as usize)?))?;
        self.metrics.puts.bump();
        self.metrics.bytes.add(src.len() as u64);
        Ok(DartHandle::new(req))
    }

    /// `dart_get`: non-blocking transfer from the global location `gptr`
    /// into `dst`. `dst` must not be read until the handle completes.
    pub fn get(&self, gptr: GlobalPtr, dst: &mut [u8]) -> DartResult<DartHandle> {
        let req =
            self.with_win(gptr, |win, target, disp| Ok(win.rget(dst, target, disp as usize)?))?;
        self.metrics.gets.bump();
        self.metrics.bytes.add(dst.len() as u64);
        Ok(DartHandle::new(req))
    }

    /// `dart_wait`: block until the operation behind `handle` completes.
    pub fn wait(&self, handle: DartHandle) -> DartResult<()> {
        if let Some(req) = handle.req {
            req.wait();
        }
        Ok(())
    }

    /// `dart_test`: non-blocking completion check. Returns the handle back
    /// if still in flight.
    pub fn test(&self, handle: DartHandle) -> Result<(), DartHandle> {
        if handle.is_complete() {
            Ok(())
        } else {
            Err(handle)
        }
    }

    /// `dart_waitall`.
    pub fn waitall(&self, handles: Vec<DartHandle>) -> DartResult<()> {
        let reqs: Vec<RmaRequest> = handles.into_iter().filter_map(|h| h.req).collect();
        RmaRequest::waitall(reqs);
        Ok(())
    }

    /// `dart_testall`: true iff every handle has completed.
    pub fn testall(&self, handles: &[DartHandle]) -> bool {
        handles.iter().all(|h| h.is_complete())
    }

    // ------------------------------------------------------------------
    // Blocking (dart_put_blocking / dart_get_blocking)
    // ------------------------------------------------------------------

    /// `dart_put_blocking`: returns only when the transfer is complete at
    /// both origin and target (put + flush).
    pub fn put_blocking(&self, gptr: GlobalPtr, src: &[u8]) -> DartResult<()> {
        self.with_win(gptr, |win, target, disp| Ok(win.put_flush(src, target, disp as usize)?))?;
        self.metrics.puts_blocking.bump();
        self.metrics.bytes.add(src.len() as u64);
        Ok(())
    }

    /// `dart_get_blocking`: returns only when `dst` holds the remote data.
    pub fn get_blocking(&self, gptr: GlobalPtr, dst: &mut [u8]) -> DartResult<()> {
        self.with_win(gptr, |win, target, disp| Ok(win.get_flush(dst, target, disp as usize)?))?;
        self.metrics.gets_blocking.bump();
        self.metrics.bytes.add(dst.len() as u64);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Strided transfers (column halos, sub-matrix exchange)
    // ------------------------------------------------------------------

    /// Strided non-blocking put: `count` blocks of `block` bytes from
    /// `src` (contiguous) to the target, where remote block `i` starts at
    /// `gptr.offset + i * stride` (`stride ≥ block`, in bytes).
    ///
    /// This is the access shape of a *column* halo in a row-major grid —
    /// the complement of the contiguous row halo the stencil app uses.
    /// The engine moves the whole pattern as **one** vector-typed RMA
    /// operation ([`crate::mpisim::Win::rput_vector`]) behind a single
    /// handle — one protocol handshake and one request, not `count`.
    pub fn put_strided(
        &self,
        gptr: GlobalPtr,
        src: &[u8],
        count: usize,
        block: usize,
        stride: u64,
    ) -> DartResult<DartHandle> {
        let ty = super::engine::strided_type(src.len(), count, block, stride)?;
        let req = self.with_win(gptr, |win, target, disp| {
            Ok(win.rput_vector(src, target, disp as usize, &ty)?)
        })?;
        self.metrics.puts.bump();
        self.metrics.bytes.add(src.len() as u64);
        Ok(DartHandle::new(req))
    }

    /// Strided non-blocking get: the mirror of [`DartEnv::put_strided`].
    pub fn get_strided(
        &self,
        gptr: GlobalPtr,
        dst: &mut [u8],
        count: usize,
        block: usize,
        stride: u64,
    ) -> DartResult<DartHandle> {
        let ty = super::engine::strided_type(dst.len(), count, block, stride)?;
        let req = self.with_win(gptr, |win, target, disp| {
            Ok(win.rget_vector(dst, target, disp as usize, &ty)?)
        })?;
        self.metrics.gets.bump();
        self.metrics.bytes.add(dst.len() as u64);
        Ok(DartHandle::new(req))
    }

    // ------------------------------------------------------------------
    // Typed conveniences
    // ------------------------------------------------------------------

    /// Typed blocking put of a slice of `T`.
    pub fn put_blocking_typed<T: Pod>(&self, gptr: GlobalPtr, src: &[T]) -> DartResult<()> {
        self.put_blocking(gptr, as_bytes(src))
    }

    /// Typed blocking get into a slice of `T`.
    pub fn get_blocking_typed<T: Pod>(&self, gptr: GlobalPtr, dst: &mut [T]) -> DartResult<()> {
        self.get_blocking(gptr, as_bytes_mut(dst))
    }

    /// `dart_accumulate`-style atomic element-wise update (MPI-3
    /// `MPI_Accumulate` under the hood).
    ///
    /// Deferred-completion, like [`DartEnv::put`]: the update is applied
    /// atomically and is immediately visible to other atomics, but remote
    /// completion (in the modelled-time sense) is deferred to the next
    /// covering [`DartEnv::flush`]/[`DartEnv::flush_all`] — so a phase of
    /// many accumulates pays **one** completion call, not one per op. For
    /// the old accumulate-then-flush semantics use
    /// [`DartEnv::accumulate_blocking`].
    pub fn accumulate<T: HasMpiType>(
        &self,
        gptr: GlobalPtr,
        src: &[T],
        op: MpiOp,
    ) -> DartResult<()> {
        self.accumulate_async(gptr, src, op)
    }

    /// Blocking accumulate: [`DartEnv::accumulate_async`] + a flush of the
    /// target's segment — returns only once the op is remotely complete.
    pub fn accumulate_blocking<T: HasMpiType>(
        &self,
        gptr: GlobalPtr,
        src: &[T],
        op: MpiOp,
    ) -> DartResult<()> {
        self.accumulate_async(gptr, src, op)?;
        self.flush(gptr)
    }

    /// Atomic fetch-and-op on a single `T` (exposed for lock-free
    /// algorithms beyond the built-in lock; paper §IV-B6). Synchronous —
    /// the old value must travel back — but on the locality fast path
    /// (shmem window + same-node target) the round trip collapses into one
    /// CPU atomic with no modelled wire time.
    pub fn fetch_and_op<T: HasMpiType>(
        &self,
        gptr: GlobalPtr,
        value: T,
        op: MpiOp,
    ) -> DartResult<T> {
        let fastpath = self.config().locality_fastpath;
        let old = self.with_win(gptr, |win, target, disp| {
            if fastpath && win.is_shmem_local(target) {
                self.metrics.atomic_fastpath_ops.bump();
                Ok(win.fetch_and_op_direct(value, target, disp as usize, op)?)
            } else {
                Ok(win.fetch_and_op_with(value, target, disp as usize, op)?)
            }
        })?;
        self.metrics.atomic_ops.bump();
        self.metrics.atomic_bytes.add(std::mem::size_of::<T>() as u64);
        Ok(old)
    }

    /// Atomic compare-and-swap on a single `T`. Synchronous, with the same
    /// locality fast path as [`DartEnv::fetch_and_op`].
    pub fn compare_and_swap<T: HasMpiType + PartialEq>(
        &self,
        gptr: GlobalPtr,
        compare: T,
        value: T,
    ) -> DartResult<T> {
        let fastpath = self.config().locality_fastpath;
        let old = self.with_win(gptr, |win, target, disp| {
            if fastpath && win.is_shmem_local(target) {
                self.metrics.atomic_fastpath_ops.bump();
                Ok(win.compare_and_swap_direct(compare, value, target, disp as usize)?)
            } else {
                Ok(win.compare_and_swap(compare, value, target, disp as usize)?)
            }
        })?;
        self.metrics.atomic_ops.bump();
        self.metrics.atomic_bytes.add(std::mem::size_of::<T>() as u64);
        Ok(old)
    }
}
