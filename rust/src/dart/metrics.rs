//! Lightweight per-unit operation counters for the DART hot path.
//!
//! `Cell`-based (the env is thread-local), so bumping a counter is a plain
//! store — cheap enough to leave enabled in release builds and in the
//! figure benches.
//!
//! [`Metrics::snapshot`] / [`MetricsSnapshot::delta`] support phase-scoped
//! accounting (take a snapshot, run a phase, diff), and [`Metrics::reset`]
//! zeroes everything — so a scenario that reuses one env across phases
//! (warm-up vs. measured, or successive chaos scenarios) never sees
//! leakage from an earlier phase.

use std::cell::Cell;
use std::fmt;

/// One monotonically increasing counter.
#[derive(Default)]
pub struct Counter(Cell<u64>);

impl Counter {
    /// Increment by one.
    #[inline]
    pub fn bump(&self) {
        self.0.set(self.0.get() + 1);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.set(self.0.get() + n);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.get()
    }

    /// Back to zero (see [`Metrics::reset`]).
    #[inline]
    pub fn reset(&self) {
        self.0.set(0);
    }
}

/// A level indicator: tracks a current value and its high-water mark
/// (counters only go up; a gauge follows a population that also shrinks,
/// like the live segment-cache entries).
#[derive(Default)]
pub struct Gauge {
    cur: Cell<u64>,
    peak: Cell<u64>,
}

impl Gauge {
    /// Set the current level (peak follows automatically).
    #[inline]
    pub fn set(&self, v: u64) {
        self.cur.set(v);
        if v > self.peak.get() {
            self.peak.set(v);
        }
    }

    /// Current level.
    #[inline]
    pub fn get(&self) -> u64 {
        self.cur.get()
    }

    /// High-water mark since creation.
    #[inline]
    pub fn peak(&self) -> u64 {
        self.peak.get()
    }

    /// Back to zero, peak included (see [`Metrics::reset`]).
    #[inline]
    pub fn reset(&self) {
        self.cur.set(0);
        self.peak.set(0);
    }
}

/// The single source of truth for the counter list: generates [`Metrics`]
/// (live `Counter`s), [`MetricsSnapshot`] (plain `u64`s), and the
/// snapshot/reset plumbing, so adding a counter is a one-line change that
/// cannot drift between the three.
macro_rules! define_metrics {
    ($( $(#[$meta:meta])* $name:ident ),+ $(,)?) => {
        /// Per-unit DART operation counters.
        #[derive(Default)]
        pub struct Metrics {
            $( $(#[$meta])* pub $name: Counter, )+
            /// Live entries in the segment-resolution cache (current +
            /// peak) — the scale satellite's visibility into cache growth
            /// across hundreds of live segments. Updated at insert and
            /// invalidation points. (Gauge, not a counter: excluded from
            /// [`MetricsSnapshot`].)
            pub seg_cache_size: Gauge,
            /// Bytes currently attached by this unit via
            /// [`crate::dart::DartEnv::memattach`] (current + peak).
            /// (Gauge, not a counter: excluded from [`MetricsSnapshot`].)
            pub dyn_bytes_attached: Gauge,
        }

        /// A plain-data copy of every [`Metrics`] counter at one instant —
        /// diff two with [`MetricsSnapshot::delta`] for phase-scoped
        /// accounting.
        #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
        pub struct MetricsSnapshot {
            $( $(#[$meta])* pub $name: u64, )+
        }

        impl Metrics {
            /// Copy every counter's current value.
            pub fn snapshot(&self) -> MetricsSnapshot {
                MetricsSnapshot { $( $name: self.$name.get(), )+ }
            }

            /// Zero every counter and the gauge — scenario isolation for
            /// runs that reuse one env across phases.
            pub fn reset(&self) {
                $( self.$name.reset(); )+
                self.seg_cache_size.reset();
                self.dyn_bytes_attached.reset();
            }
        }

        impl MetricsSnapshot {
            /// Per-counter difference `self - earlier` (counters are
            /// monotonic between resets, so take `earlier` first;
            /// wrapping, so a reset in between cannot panic).
            pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
                MetricsSnapshot { $( $name: self.$name.wrapping_sub(earlier.$name), )+ }
            }
        }
    };
}

define_metrics! {
    /// Non-blocking puts issued.
    puts,
    /// Non-blocking gets issued.
    gets,
    /// Blocking puts issued.
    puts_blocking,
    /// Blocking gets issued.
    gets_blocking,
    /// Bytes moved by one-sided operations.
    bytes,
    /// Collective global memory allocations.
    allocs,
    /// Collective operations (barrier/bcast/...).
    collectives,
    /// Lock acquisitions.
    lock_acquires,
    /// Explicit flush calls (`dart_flush`/`dart_flush_all`).
    flushes,
    /// Segment-cache hits on the §IV-B4 dereference chain.
    cache_hits,
    /// Segment-cache misses (full registry + translation-table walk).
    cache_misses,
    /// Progress-engine ticks driven by this unit's cooperative polls
    /// (`Polling` mode; background-thread ticks are world-global — see
    /// [`crate::dart::DartEnv::engine_ticks`]).
    progress_ticks,
    /// Deferred one-sided operations retired by the progress engine —
    /// completed in the background with zero caller time.
    overlap_ops,
    /// Bytes of deferred one-sided traffic retired by the progress engine
    /// (the "overlap achieved" number the `perf_overlap` bench reports).
    overlap_bytes,
    /// Nonblocking-collective phase transitions observed by this unit
    /// (one per initiation, one per completion).
    coll_phases,
    /// Contiguous runs issued by the `dash` layer's bulk transfers
    /// (`Array::copy_in`/`copy_out` and `dash::algorithms::copy`): each
    /// run is ONE one-sided operation covering many elements, so
    /// `dash_coalesced_runs ≪ elements moved` is the coalescing claim.
    dash_coalesced_runs,
    /// Bytes moved by `dash::algorithms::copy` redistributions.
    dash_redist_bytes,
    /// Intra-node phases executed by hierarchical collectives (node-local
    /// reduce/bcast/gather/barrier legs) — together with
    /// [`Metrics::hier_coll_inter_ops`] this makes the two-level
    /// decomposition assertable by tests.
    hier_coll_intra_ops,
    /// Leader-team (cross-node) phases executed by hierarchical
    /// collectives. Bumped only on units that are their node's leader —
    /// non-leaders never touch the interconnect in a hierarchical
    /// collective.
    hier_coll_inter_ops,
    /// Deferred one-sided operations completed by the engine's intra-node
    /// zero-copy fast path (shmem window + same-node target): the op
    /// bypassed the deferred-completion queue entirely — no progress-engine
    /// registration, nothing for a flush to wait on.
    locality_fastpath_ops,
    /// Atomic operations issued (`accumulate`/`accumulate_async`/
    /// `fetch_and_op`/`compare_and_swap`), any path.
    atomic_ops,
    /// Atomic operations completed via the intra-node CPU-atomic fast path
    /// (shmem window + same-node target): the hardware atomic was the
    /// whole operation — no modelled round trip, no engine registration.
    atomic_fastpath_ops,
    /// Bytes touched by atomic operations (operand bytes, not counted in
    /// [`Metrics::bytes`]).
    atomic_bytes,
    /// Dynamic-memory regions attached by this unit
    /// ([`crate::dart::DartEnv::memattach`]).
    dyn_attach_ops,
    /// Dynamic-memory regions detached by this unit
    /// ([`crate::dart::DartEnv::memdetach`]).
    dyn_detach_ops,
    /// Successful [`crate::dash::WorkQueue`] pops served from a *remote*
    /// unit's ring — work stealing in action.
    wq_steals,
    /// CAS retries inside [`crate::dash::WorkQueue`] enqueue-commit and
    /// dequeue-claim loops — the queue's contention indicator.
    wq_cas_retries,
    /// Injected per-message jitter events observed at this unit's sync
    /// points. **World-global mirror**: the fault layer counts events
    /// world-wide ([`crate::dart::DartEnv::fault_stats`]); this counter
    /// mirrors the running total so per-unit assertions (and the chaos
    /// suite) can prove the plan fired without a world handle.
    fault_jitter_events,
    /// Injected RMA-completion reorderings observed at this unit's sync
    /// points (world-global mirror, like [`Metrics::fault_jitter_events`]).
    fault_reorders,
    /// Starved progress ticks observed at this unit's sync points
    /// (world-global mirror, like [`Metrics::fault_jitter_events`]).
    fault_starved_ticks,
}

impl Metrics {
    /// Fresh all-zero counters.
    pub fn new() -> Self {
        Self::default()
    }
}

impl fmt::Display for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "puts={} gets={} puts_b={} gets_b={} bytes={} allocs={} colls={} locks={} \
             flushes={} cache_hit={} cache_miss={} ticks={} overlap_ops={} overlap_bytes={} \
             coll_phases={} dash_runs={} dash_redist={} hier_intra={} hier_inter={} fastpath={} \
             atomics={} atomic_fast={} atomic_bytes={} dyn_attach={} dyn_detach={} \
             wq_steals={} wq_retries={} fault_jitter={} fault_reorder={} \
             fault_starved={} seg_cache={}/{} dyn_bytes={}/{}",
            self.puts.get(),
            self.gets.get(),
            self.puts_blocking.get(),
            self.gets_blocking.get(),
            self.bytes.get(),
            self.allocs.get(),
            self.collectives.get(),
            self.lock_acquires.get(),
            self.flushes.get(),
            self.cache_hits.get(),
            self.cache_misses.get(),
            self.progress_ticks.get(),
            self.overlap_ops.get(),
            self.overlap_bytes.get(),
            self.coll_phases.get(),
            self.dash_coalesced_runs.get(),
            self.dash_redist_bytes.get(),
            self.hier_coll_intra_ops.get(),
            self.hier_coll_inter_ops.get(),
            self.locality_fastpath_ops.get(),
            self.atomic_ops.get(),
            self.atomic_fastpath_ops.get(),
            self.atomic_bytes.get(),
            self.dyn_attach_ops.get(),
            self.dyn_detach_ops.get(),
            self.wq_steals.get(),
            self.wq_cas_retries.get(),
            self.fault_jitter_events.get(),
            self.fault_reorders.get(),
            self.fault_starved_ticks.get(),
            self.seg_cache_size.get(),
            self.seg_cache_size.peak(),
            self.dyn_bytes_attached.get(),
            self.dyn_bytes_attached.peak()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_count() {
        let m = Metrics::new();
        m.puts.bump();
        m.puts.bump();
        m.bytes.add(128);
        assert_eq!(m.puts.get(), 2);
        assert_eq!(m.bytes.get(), 128);
        assert_eq!(m.gets.get(), 0);
        let s = m.to_string();
        assert!(s.contains("puts=2"));
        assert!(s.contains("fault_jitter=0"));
    }

    #[test]
    fn gauge_tracks_peak() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0);
        g.set(5);
        g.set(9);
        g.set(3);
        assert_eq!(g.get(), 3);
        assert_eq!(g.peak(), 9);
        let m = Metrics::new();
        m.seg_cache_size.set(7);
        assert!(m.to_string().contains("seg_cache=7/7"));
    }

    #[test]
    fn snapshot_delta_isolates_a_phase() {
        let m = Metrics::new();
        m.puts.add(5);
        m.fault_reorders.add(2);
        let before = m.snapshot();
        m.puts.add(3);
        m.fault_reorders.bump();
        m.overlap_bytes.add(100);
        let d = m.snapshot().delta(&before);
        assert_eq!(d.puts, 3);
        assert_eq!(d.fault_reorders, 1);
        assert_eq!(d.overlap_bytes, 100);
        assert_eq!(d.gets, 0, "untouched counters must diff to zero");
    }

    #[test]
    fn reset_zeroes_everything() {
        let m = Metrics::new();
        m.puts.add(7);
        m.fault_starved_ticks.add(4);
        m.seg_cache_size.set(9);
        m.dyn_bytes_attached.set(1024);
        m.reset();
        assert_eq!(m.snapshot(), MetricsSnapshot::default());
        assert_eq!(m.seg_cache_size.get(), 0);
        assert_eq!(m.seg_cache_size.peak(), 0);
        assert_eq!(m.dyn_bytes_attached.get(), 0);
        assert_eq!(m.dyn_bytes_attached.peak(), 0);
    }

    #[test]
    fn dynamic_counters_flow_through_snapshot_and_display() {
        let m = Metrics::new();
        m.dyn_attach_ops.bump();
        m.dyn_detach_ops.bump();
        m.wq_steals.add(3);
        m.wq_cas_retries.add(5);
        m.dyn_bytes_attached.set(256);
        m.dyn_bytes_attached.set(64);
        let before = MetricsSnapshot::default();
        let d = m.snapshot().delta(&before);
        assert_eq!(d.dyn_attach_ops, 1);
        assert_eq!(d.dyn_detach_ops, 1);
        assert_eq!(d.wq_steals, 3);
        assert_eq!(d.wq_cas_retries, 5);
        let s = m.to_string();
        assert!(s.contains("wq_steals=3"));
        assert!(s.contains("dyn_bytes=64/256"));
    }
}
