//! Lightweight per-unit operation counters for the DART hot path.
//!
//! `Cell`-based (the env is thread-local), so bumping a counter is a plain
//! store — cheap enough to leave enabled in release builds and in the
//! figure benches.

use std::cell::Cell;
use std::fmt;

/// One monotonically increasing counter.
#[derive(Default)]
pub struct Counter(Cell<u64>);

impl Counter {
    /// Increment by one.
    #[inline]
    pub fn bump(&self) {
        self.0.set(self.0.get() + 1);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.set(self.0.get() + n);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.get()
    }
}

/// A level indicator: tracks a current value and its high-water mark
/// (counters only go up; a gauge follows a population that also shrinks,
/// like the live segment-cache entries).
#[derive(Default)]
pub struct Gauge {
    cur: Cell<u64>,
    peak: Cell<u64>,
}

impl Gauge {
    /// Set the current level (peak follows automatically).
    #[inline]
    pub fn set(&self, v: u64) {
        self.cur.set(v);
        if v > self.peak.get() {
            self.peak.set(v);
        }
    }

    /// Current level.
    #[inline]
    pub fn get(&self) -> u64 {
        self.cur.get()
    }

    /// High-water mark since creation.
    #[inline]
    pub fn peak(&self) -> u64 {
        self.peak.get()
    }
}

/// Per-unit DART operation counters.
#[derive(Default)]
pub struct Metrics {
    /// Non-blocking puts issued.
    pub puts: Counter,
    /// Non-blocking gets issued.
    pub gets: Counter,
    /// Blocking puts issued.
    pub puts_blocking: Counter,
    /// Blocking gets issued.
    pub gets_blocking: Counter,
    /// Bytes moved by one-sided operations.
    pub bytes: Counter,
    /// Collective global memory allocations.
    pub allocs: Counter,
    /// Collective operations (barrier/bcast/...).
    pub collectives: Counter,
    /// Lock acquisitions.
    pub lock_acquires: Counter,
    /// Explicit flush calls (`dart_flush`/`dart_flush_all`).
    pub flushes: Counter,
    /// Segment-cache hits on the §IV-B4 dereference chain.
    pub cache_hits: Counter,
    /// Segment-cache misses (full registry + translation-table walk).
    pub cache_misses: Counter,
    /// Progress-engine ticks driven by this unit's cooperative polls
    /// (`Polling` mode; background-thread ticks are world-global — see
    /// [`crate::dart::DartEnv::engine_ticks`]).
    pub progress_ticks: Counter,
    /// Deferred one-sided operations retired by the progress engine —
    /// completed in the background with zero caller time.
    pub overlap_ops: Counter,
    /// Bytes of deferred one-sided traffic retired by the progress engine
    /// (the "overlap achieved" number the `perf_overlap` bench reports).
    pub overlap_bytes: Counter,
    /// Nonblocking-collective phase transitions observed by this unit
    /// (one per initiation, one per completion).
    pub coll_phases: Counter,
    /// Contiguous runs issued by the `dash` layer's bulk transfers
    /// (`Array::copy_in`/`copy_out` and `dash::algorithms::copy`): each
    /// run is ONE one-sided operation covering many elements, so
    /// `dash_coalesced_runs ≪ elements moved` is the coalescing claim.
    pub dash_coalesced_runs: Counter,
    /// Bytes moved by `dash::algorithms::copy` redistributions.
    pub dash_redist_bytes: Counter,
    /// Intra-node phases executed by hierarchical collectives (node-local
    /// reduce/bcast/gather/barrier legs) — together with
    /// [`Metrics::hier_coll_inter_ops`] this makes the two-level
    /// decomposition assertable by tests.
    pub hier_coll_intra_ops: Counter,
    /// Leader-team (cross-node) phases executed by hierarchical
    /// collectives. Bumped only on units that are their node's leader —
    /// non-leaders never touch the interconnect in a hierarchical
    /// collective.
    pub hier_coll_inter_ops: Counter,
    /// Deferred one-sided operations completed by the engine's intra-node
    /// zero-copy fast path (shmem window + same-node target): the op
    /// bypassed the deferred-completion queue entirely — no progress-engine
    /// registration, nothing for a flush to wait on.
    pub locality_fastpath_ops: Counter,
    /// Atomic operations issued (`accumulate`/`accumulate_async`/
    /// `fetch_and_op`/`compare_and_swap`), any path.
    pub atomic_ops: Counter,
    /// Atomic operations completed via the intra-node CPU-atomic fast path
    /// (shmem window + same-node target): the hardware atomic was the
    /// whole operation — no modelled round trip, no engine registration.
    pub atomic_fastpath_ops: Counter,
    /// Bytes touched by atomic operations (operand bytes, not counted in
    /// [`Metrics::bytes`]).
    pub atomic_bytes: Counter,
    /// Live entries in the segment-resolution cache (current + peak) —
    /// the scale satellite's visibility into cache growth across hundreds
    /// of live segments. Updated at insert and invalidation points.
    pub seg_cache_size: Gauge,
}

impl Metrics {
    /// Fresh all-zero counters.
    pub fn new() -> Self {
        Self::default()
    }
}

impl fmt::Display for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "puts={} gets={} puts_b={} gets_b={} bytes={} allocs={} colls={} locks={} \
             flushes={} cache_hit={} cache_miss={} ticks={} overlap_ops={} overlap_bytes={} \
             coll_phases={} dash_runs={} dash_redist={} hier_intra={} hier_inter={} fastpath={} \
             atomics={} atomic_fast={} atomic_bytes={} seg_cache={}/{}",
            self.puts.get(),
            self.gets.get(),
            self.puts_blocking.get(),
            self.gets_blocking.get(),
            self.bytes.get(),
            self.allocs.get(),
            self.collectives.get(),
            self.lock_acquires.get(),
            self.flushes.get(),
            self.cache_hits.get(),
            self.cache_misses.get(),
            self.progress_ticks.get(),
            self.overlap_ops.get(),
            self.overlap_bytes.get(),
            self.coll_phases.get(),
            self.dash_coalesced_runs.get(),
            self.dash_redist_bytes.get(),
            self.hier_coll_intra_ops.get(),
            self.hier_coll_inter_ops.get(),
            self.locality_fastpath_ops.get(),
            self.atomic_ops.get(),
            self.atomic_fastpath_ops.get(),
            self.atomic_bytes.get(),
            self.seg_cache_size.get(),
            self.seg_cache_size.peak()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_count() {
        let m = Metrics::new();
        m.puts.bump();
        m.puts.bump();
        m.bytes.add(128);
        assert_eq!(m.puts.get(), 2);
        assert_eq!(m.bytes.get(), 128);
        assert_eq!(m.gets.get(), 0);
        let s = m.to_string();
        assert!(s.contains("puts=2"));
    }

    #[test]
    fn gauge_tracks_peak() {
        let g = Gauge::default();
        assert_eq!(g.get(), 0);
        g.set(5);
        g.set(9);
        g.set(3);
        assert_eq!(g.get(), 3);
        assert_eq!(g.peak(), 9);
        let m = Metrics::new();
        m.seg_cache_size.set(7);
        assert!(m.to_string().contains("seg_cache=7/7"));
    }
}
