//! Lightweight per-unit operation counters for the DART hot path.
//!
//! `Cell`-based (the env is thread-local), so bumping a counter is a plain
//! store — cheap enough to leave enabled in release builds and in the
//! figure benches.

use std::cell::Cell;
use std::fmt;

/// One monotonically increasing counter.
#[derive(Default)]
pub struct Counter(Cell<u64>);

impl Counter {
    #[inline]
    pub fn bump(&self) {
        self.0.set(self.0.get() + 1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.set(self.0.get() + n);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.get()
    }
}

/// Per-unit DART operation counters.
#[derive(Default)]
pub struct Metrics {
    /// Non-blocking puts issued.
    pub puts: Counter,
    /// Non-blocking gets issued.
    pub gets: Counter,
    /// Blocking puts issued.
    pub puts_blocking: Counter,
    /// Blocking gets issued.
    pub gets_blocking: Counter,
    /// Bytes moved by one-sided operations.
    pub bytes: Counter,
    /// Collective global memory allocations.
    pub allocs: Counter,
    /// Collective operations (barrier/bcast/...).
    pub collectives: Counter,
    /// Lock acquisitions.
    pub lock_acquires: Counter,
    /// Explicit flush calls (`dart_flush`/`dart_flush_all`).
    pub flushes: Counter,
    /// Segment-cache hits on the §IV-B4 dereference chain.
    pub cache_hits: Counter,
    /// Segment-cache misses (full registry + translation-table walk).
    pub cache_misses: Counter,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }
}

impl fmt::Display for Metrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "puts={} gets={} puts_b={} gets_b={} bytes={} allocs={} colls={} locks={} \
             flushes={} cache_hit={} cache_miss={}",
            self.puts.get(),
            self.gets.get(),
            self.puts_blocking.get(),
            self.gets_blocking.get(),
            self.bytes.get(),
            self.allocs.get(),
            self.collectives.get(),
            self.lock_acquires.get(),
            self.flushes.get(),
            self.cache_hits.get(),
            self.cache_misses.get()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_count() {
        let m = Metrics::new();
        m.puts.bump();
        m.puts.bump();
        m.bytes.add(128);
        assert_eq!(m.puts.get(), 2);
        assert_eq!(m.bytes.get(), 128);
        assert_eq!(m.gets.get(), 0);
        let s = m.to_string();
        assert!(s.contains("puts=2"));
    }
}
