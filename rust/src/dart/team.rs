//! Teams and the `teamlist` mechanism (paper §IV-B2).
//!
//! A DART team is an ordered set of units identified by an integer id that
//! is **never reused**, even after destruction. Because ids grow without
//! bound, they cannot index a dense array; the paper's solution is a
//! bounded `teamlist` whose slots are linearly scanned (`teamlist[i] == -1`
//! marks a free slot) and recycled on destroy. The slot index is then "a
//! perfect index" into the per-team state: the communicator, the collective
//! memory pool and the translation table.
//!
//! The paper's future work notes the linear scan "can be significant when
//! the teamlist is extremely large"; [`TeamRegistry::new`] optionally
//! builds a direct-index map instead (`indexed_teamlist`, ablation A2).

use super::gptr::TeamId;
use super::translation::{FreeListAllocator, TranslationTable};
use super::{DartErr, DartResult};
use crate::mpisim::{Comm, Win};
use std::collections::HashMap;
use std::rc::Rc;

/// Per-unit state of one team this unit belongs to.
pub struct TeamEntry {
    /// The never-reused team id this slot currently holds.
    pub team_id: TeamId,
    /// The communicator realizing the team (`teams[teamID]` in the paper).
    pub comm: Comm,
    /// The team's reserved collective global memory pool, already inside a
    /// shared access epoch (`lock_all`, §IV-B5).
    pub pool: Rc<Win>,
    /// Allocator over the pool. Deterministic and driven only by
    /// collective calls, so every member computes identical offsets —
    /// that is what makes allocations *aligned* (§III).
    pub alloc: FreeListAllocator,
    /// offset → window translation table (§IV-B3).
    pub table: TranslationTable,
    /// Fast absolute-unit → team-rank translation (perf: avoids the
    /// O(size) scan of `Comm::rank_of_world` on the hot path).
    pub unit_map: HashMap<i32, usize>,
    /// Per-team lock-init sequence number (collective calls keep this in
    /// lock-step on every member; used for unique hand-off tags, §IV-B6).
    pub lock_seq: i32,
}

impl TeamEntry {
    /// Fresh team state around an established communicator and pool.
    pub fn new(team_id: TeamId, comm: Comm, pool: Rc<Win>, pool_size: u64) -> Self {
        let unit_map =
            comm.rank_table().iter().enumerate().map(|(r, &w)| (w as i32, r)).collect();
        TeamEntry {
            team_id,
            comm,
            pool,
            alloc: FreeListAllocator::new(pool_size),
            table: TranslationTable::new(),
            unit_map,
            lock_seq: 0,
        }
    }

    /// Absolute unit id → team-relative rank (the §IV-B4 unit translation).
    #[inline]
    pub fn rank_of_unit(&self, unit: i32) -> Option<usize> {
        self.unit_map.get(&unit).copied()
    }
}

/// The unit-local team registry: `teamlist` (slot → id) plus the per-slot
/// team state.
pub struct TeamRegistry {
    /// `teamlist[slot]` = team id, or -1 for a free slot (paper §IV-B2).
    teamlist: Vec<TeamId>,
    entries: Vec<Option<TeamEntry>>,
    /// Ablation A2: direct-index map instead of the linear scan.
    index: Option<HashMap<TeamId, usize>>,
}

impl TeamRegistry {
    /// Empty registry with `capacity` teamlist slots.
    pub fn new(capacity: usize, indexed: bool) -> Self {
        TeamRegistry {
            teamlist: vec![-1; capacity],
            entries: (0..capacity).map(|_| None).collect(),
            index: indexed.then(HashMap::new),
        }
    }

    /// Find the slot of a live team — the paper's linear `teamlist` scan
    /// (or the indexed alternative).
    #[inline]
    pub fn slot_of(&self, team: TeamId) -> Option<usize> {
        match &self.index {
            Some(map) => map.get(&team).copied(),
            None => self.teamlist.iter().position(|&t| t == team),
        }
    }

    /// Shared access to a live team's entry.
    #[inline]
    pub fn get(&self, team: TeamId) -> DartResult<&TeamEntry> {
        self.slot_of(team)
            .and_then(|s| self.entries[s].as_ref())
            .ok_or(DartErr::UnknownTeam(team))
    }

    /// Mutable access to a live team's entry.
    #[inline]
    pub fn get_mut(&mut self, team: TeamId) -> DartResult<&mut TeamEntry> {
        let slot = self.slot_of(team).ok_or(DartErr::UnknownTeam(team))?;
        self.entries[slot].as_mut().ok_or(DartErr::UnknownTeam(team))
    }

    /// Claim the first free slot for a new team (the paper's scan for
    /// `teamlist[i] == -1`).
    pub fn insert(&mut self, entry: TeamEntry) -> DartResult<usize> {
        if self.slot_of(entry.team_id).is_some() {
            return Err(DartErr::Invalid(format!("team {} already registered", entry.team_id)));
        }
        let slot = self
            .teamlist
            .iter()
            .position(|&t| t == -1)
            .ok_or(DartErr::TeamListFull(self.teamlist.len()))?;
        self.teamlist[slot] = entry.team_id;
        if let Some(map) = &mut self.index {
            map.insert(entry.team_id, slot);
        }
        self.entries[slot] = Some(entry);
        Ok(slot)
    }

    /// Release a team's slot (`teamlist[i] = -1`) and return its entry for
    /// teardown. The id is *not* recycled — ids are never reused.
    pub fn remove(&mut self, team: TeamId) -> DartResult<TeamEntry> {
        let slot = self.slot_of(team).ok_or(DartErr::UnknownTeam(team))?;
        self.teamlist[slot] = -1;
        if let Some(map) = &mut self.index {
            map.remove(&team);
        }
        self.entries[slot].take().ok_or(DartErr::UnknownTeam(team))
    }

    /// Ids of all live teams (ascending slot order).
    pub fn live_teams(&self) -> Vec<TeamId> {
        self.teamlist.iter().copied().filter(|&t| t != -1).collect()
    }

    /// Number of live teams.
    pub fn len(&self) -> usize {
        self.teamlist.iter().filter(|&&t| t != -1).count()
    }

    /// No live teams?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Property-test invariant: teamlist/index agree, entries match slots.
    pub fn check_invariants(&self) -> bool {
        for (slot, &t) in self.teamlist.iter().enumerate() {
            if (t == -1) != self.entries[slot].is_none() {
                return false;
            }
            if let Some(e) = &self.entries[slot] {
                if e.team_id != t {
                    return false;
                }
            }
            if let Some(map) = &self.index {
                if t != -1 && map.get(&t) != Some(&slot) {
                    return false;
                }
            }
        }
        true
    }
}
