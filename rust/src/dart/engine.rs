//! The unified one-sided communication engine: cached segment resolution
//! and explicit flush batching.
//!
//! Every one-sided operation must run the paper's §IV-B4 dereference chain
//! — flags dispatch, teamlist lookup, absolute-unit → team-rank
//! translation, translation-table search. The seed implementation paid
//! that chain in full on every put/get; locality information of this kind
//! is stable between allocation events, so the engine computes it **once**
//! and memoizes it (cf. arXiv:1609.09333, which makes the same argument
//! for caching locality/segment information at the runtime layer).
//!
//! Two pieces live here:
//!
//! - `SegmentCache` — a small per-unit cache of `Resolution` records
//!   (`(team, unit, allocation) → (window, target rank, extent)`).
//!   Lookups are a linear scan over at most `CACHE_SLOTS` integer
//!   comparisons — far cheaper than the registry scan + hash lookup +
//!   binary search it replaces. Entries are dropped by
//!   [`DartEnv::team_memfree`]/[`DartEnv::team_destroy`], which also keeps
//!   the exclusive-ownership check at window free time honest (the cache
//!   may not outlive the allocation's window).
//! - **Deferred-completion operations + explicit flushes** — the DART
//!   analogue of real DART-MPI's `dart_flush` family:
//!   [`DartEnv::put_async`]/[`DartEnv::get_async`] (and their strided
//!   vector variants) initiate a transfer without allocating a completion
//!   handle; [`DartEnv::flush`]/[`DartEnv::flush_all`] complete everything
//!   outstanding per target / per segment in one call. This decouples
//!   operation issue from completion so transfers batch and overlap
//!   (cf. arXiv:1609.08574).

use super::gptr::{GlobalPtr, TeamId, UnitId};
use super::{DartEnv, DartErr, DartResult};
use crate::mpisim::{VectorType, Win};
use std::rc::Rc;

/// One memoized §IV-B4 resolution: the window, MPI-relative target rank
/// and covering allocation extent of a collective global pointer.
pub(crate) struct Resolution {
    pub segid: TeamId,
    pub unitid: UnitId,
    /// Pool-relative start of the covering allocation.
    pub base: u64,
    /// Length of the covering allocation.
    pub len: u64,
    /// Team-relative (= window-relative) target rank.
    pub target: usize,
    /// The allocation's window.
    pub win: Rc<Win>,
}

/// Cache capacity. Halo exchanges touch a handful of `(neighbour,
/// allocation)` pairs per phase; eight slots cover every app in the repo
/// without making the linear scan noticeable.
pub(crate) const CACHE_SLOTS: usize = 8;

/// Per-unit segment-resolution cache (see module docs).
pub(crate) struct SegmentCache {
    /// The pre-reserved world window: non-collective pointers always
    /// resolve here, so the engine keeps the handle out of the `RefCell`'d
    /// registry state entirely.
    world_win: Rc<Win>,
    enabled: bool,
    slots: Vec<Option<Resolution>>,
    /// Round-robin eviction cursor.
    next_evict: usize,
}

impl SegmentCache {
    pub(crate) fn new(world_win: Rc<Win>, enabled: bool) -> Self {
        SegmentCache {
            world_win,
            enabled,
            slots: (0..CACHE_SLOTS).map(|_| None).collect(),
            next_evict: 0,
        }
    }

    #[inline]
    fn lookup(&self, gptr: GlobalPtr) -> Option<&Resolution> {
        if !self.enabled {
            return None;
        }
        self.slots.iter().flatten().find(|r| {
            r.segid == gptr.segid
                && r.unitid == gptr.unitid
                && gptr.offset >= r.base
                && gptr.offset - r.base < r.len
        })
    }

    fn insert(&mut self, r: Resolution) {
        if !self.enabled {
            return;
        }
        if let Some(empty) = self.slots.iter_mut().find(|s| s.is_none()) {
            *empty = Some(r);
            return;
        }
        let i = self.next_evict;
        self.next_evict = (i + 1) % self.slots.len();
        self.slots[i] = Some(r);
    }

    /// Drop every cached resolution of the allocation at `(team, base)` —
    /// called by `team_memfree` *before* it asserts exclusive ownership of
    /// the allocation's window, and before the pool offset can be reused.
    pub(crate) fn invalidate_segment(&mut self, team: TeamId, base: u64) {
        for s in &mut self.slots {
            if s.as_ref().is_some_and(|r| r.segid == team && r.base == base) {
                *s = None;
            }
        }
    }

    /// Drop every cached resolution of `team` — called by `team_destroy`.
    pub(crate) fn invalidate_team(&mut self, team: TeamId) {
        for s in &mut self.slots {
            if s.as_ref().is_some_and(|r| r.segid == team) {
                *s = None;
            }
        }
    }

    /// Number of live cached resolutions (diagnostics/tests).
    pub(crate) fn live(&self) -> usize {
        self.slots.iter().flatten().count()
    }
}

/// Validate a strided-transfer request and build its wire datatype.
pub(crate) fn strided_type(
    buf_len: usize,
    count: usize,
    block: usize,
    stride: u64,
) -> DartResult<VectorType> {
    if buf_len != count * block {
        return Err(DartErr::Invalid(format!(
            "strided transfer: buffer {buf_len} bytes != {count} × {block}"
        )));
    }
    // `stride ≥ block` is enforced by `VectorType::new` — the single
    // authority for datatype geometry.
    Ok(VectorType::new(count, block, stride as usize)?)
}

impl DartEnv {
    // ------------------------------------------------------------------
    // The §IV-B4 dereference chain, memoized
    // ------------------------------------------------------------------

    /// The single implementation of the memoized §IV-B4 chain: resolve
    /// `gptr` and run `f` with the owning window handle, target rank and
    /// displacement — borrow-scoped, so the hot path pays no `Rc`
    /// refcount traffic (callers that need ownership clone inside `f`).
    ///
    /// Cache hit: a handful of integer compares, no registry access.
    /// Cache miss: the full slow path
    /// ([`DartEnv::resolve_collective_slow`]), whose result is memoized.
    #[inline]
    fn resolve_scoped<R>(
        &self,
        gptr: GlobalPtr,
        f: impl FnOnce(&Rc<Win>, usize, u64) -> DartResult<R>,
    ) -> DartResult<R> {
        if gptr.is_null() {
            return Err(DartErr::InvalidGptr("null pointer dereference".into()));
        }
        if !gptr.is_collective() {
            // Fig. 4 path: "trivially dereferenced" against the world
            // window with the absolute unit as target.
            if gptr.unitid as usize >= self.size() {
                return Err(DartErr::InvalidUnit(gptr.unitid));
            }
            let cache = self.seg_cache.borrow();
            return f(&cache.world_win, gptr.unitid as usize, gptr.offset);
        }
        {
            let cache = self.seg_cache.borrow();
            if let Some(r) = cache.lookup(gptr) {
                self.metrics.cache_hits.bump();
                return f(&r.win, r.target, gptr.offset - r.base);
            }
        }
        self.metrics.cache_misses.bump();
        let r = self.resolve_collective_slow(gptr)?;
        let out = f(&r.win, r.target, gptr.offset - r.base);
        self.seg_cache.borrow_mut().insert(r);
        out
    }

    /// Scoped dereference: run `f` with the resolved window (the put/get
    /// hot path — no `Rc` clone).
    #[inline]
    pub(crate) fn with_win<R>(
        &self,
        gptr: GlobalPtr,
        f: impl FnOnce(&Win, usize, u64) -> DartResult<R>,
    ) -> DartResult<R> {
        self.resolve_scoped(gptr, |win, target, disp| f(win.as_ref(), target, disp))
    }

    /// Owning dereference: like [`DartEnv::with_win`] but returns a cloned
    /// window handle (atomics, local access — off the hot path).
    #[inline]
    pub(crate) fn deref_gptr(&self, gptr: GlobalPtr) -> DartResult<(Rc<Win>, usize, u64)> {
        self.resolve_scoped(gptr, |win, target, disp| Ok((win.clone(), target, disp)))
    }

    /// Live entries in the segment cache (diagnostics/tests).
    pub fn segment_cache_live(&self) -> usize {
        self.seg_cache.borrow().live()
    }

    // ------------------------------------------------------------------
    // Deferred-completion one-sided ops + explicit flushes
    // ------------------------------------------------------------------

    /// `dart_put` in *deferred-completion* mode: initiate the transfer and
    /// return immediately, without allocating a completion handle. Remote
    /// completion is deferred to the next [`DartEnv::flush`] /
    /// [`DartEnv::flush_all`] covering the target — so a phase of many
    /// puts pays one completion call per target instead of one per op.
    pub fn put_async(&self, gptr: GlobalPtr, src: &[u8]) -> DartResult<()> {
        self.with_win(gptr, |win, target, disp| Ok(win.put(src, target, disp as usize)?))?;
        self.metrics.puts.bump();
        self.metrics.bytes.add(src.len() as u64);
        Ok(())
    }

    /// `dart_get` in deferred-completion mode: `dst` may not be read until
    /// a flush covering the target completes.
    pub fn get_async(&self, gptr: GlobalPtr, dst: &mut [u8]) -> DartResult<()> {
        self.with_win(gptr, |win, target, disp| Ok(win.get(dst, target, disp as usize)?))?;
        self.metrics.gets.bump();
        self.metrics.bytes.add(dst.len() as u64);
        Ok(())
    }

    /// Strided deferred-completion put: one vector-typed RMA operation
    /// (see [`DartEnv::put_strided`] for the layout parameters).
    pub fn put_strided_async(
        &self,
        gptr: GlobalPtr,
        src: &[u8],
        count: usize,
        block: usize,
        stride: u64,
    ) -> DartResult<()> {
        let ty = strided_type(src.len(), count, block, stride)?;
        self.with_win(gptr, |win, target, disp| {
            Ok(win.put_vector(src, target, disp as usize, &ty)?)
        })?;
        self.metrics.puts.bump();
        self.metrics.bytes.add(src.len() as u64);
        Ok(())
    }

    /// Strided deferred-completion get: the mirror of
    /// [`DartEnv::put_strided_async`].
    pub fn get_strided_async(
        &self,
        gptr: GlobalPtr,
        dst: &mut [u8],
        count: usize,
        block: usize,
        stride: u64,
    ) -> DartResult<()> {
        let ty = strided_type(dst.len(), count, block, stride)?;
        self.with_win(gptr, |win, target, disp| {
            Ok(win.get_vector(dst, target, disp as usize, &ty)?)
        })?;
        self.metrics.gets.bump();
        self.metrics.bytes.add(dst.len() as u64);
        Ok(())
    }

    /// `dart_flush(gptr)`: block until every outstanding deferred
    /// operation *to the unit behind `gptr`* (on its segment's window) has
    /// completed remotely.
    pub fn flush(&self, gptr: GlobalPtr) -> DartResult<()> {
        self.with_win(gptr, |win, target, _| Ok(win.flush(target)?))?;
        self.metrics.flushes.bump();
        Ok(())
    }

    /// `dart_flush_all(gptr)`: block until every outstanding deferred
    /// operation on `gptr`'s segment window — to *any* target — has
    /// completed remotely. One call completes a whole halo-exchange phase.
    pub fn flush_all(&self, gptr: GlobalPtr) -> DartResult<()> {
        self.with_win(gptr, |win, _, _| Ok(win.flush_all()?))?;
        self.metrics.flushes.bump();
        Ok(())
    }
}
