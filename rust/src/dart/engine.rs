//! The unified one-sided communication engine: cached segment resolution
//! and explicit flush batching.
//!
//! Every one-sided operation must run the paper's §IV-B4 dereference chain
//! — flags dispatch, teamlist lookup, absolute-unit → team-rank
//! translation, translation-table search. The seed implementation paid
//! that chain in full on every put/get; locality information of this kind
//! is stable between allocation events, so the engine computes it **once**
//! and memoizes it (cf. arXiv:1609.09333, which makes the same argument
//! for caching locality/segment information at the runtime layer).
//!
//! Three pieces live here:
//!
//! - `SegmentCache` — a per-unit cache of `Resolution` records
//!   (`(team, unit, allocation) → (window, target rank, extent)`),
//!   sharded by `(team, unit)` key so a lookup is one hash probe plus a
//!   short covering-extent scan — O(1) in the number of live segments,
//!   far cheaper than the registry scan + hash lookup + binary search it
//!   replaces, and it stays that way with hundreds of live segments.
//!   The live-entry count is exported as the
//!   [`super::Metrics::seg_cache_size`] gauge. Entries are dropped by
//!   [`DartEnv::team_memfree`]/[`DartEnv::team_destroy`], which also keeps
//!   the exclusive-ownership check at window free time honest (the cache
//!   may not outlive the allocation's window).
//! - **Deferred-completion operations + explicit flushes** — the DART
//!   analogue of real DART-MPI's `dart_flush` family:
//!   [`DartEnv::put_async`]/[`DartEnv::get_async`] (and their strided
//!   vector variants) initiate a transfer without allocating a completion
//!   handle; [`DartEnv::flush`]/[`DartEnv::flush_all`] complete everything
//!   outstanding per target / per segment in one call. This decouples
//!   operation issue from completion so transfers batch and overlap
//!   (cf. arXiv:1609.08574).
//!
//! - **The intra-node zero-copy fast path** — with shared-memory windows
//!   on ([`crate::dart::DartConfig::shmem_windows`]) and a same-node
//!   target, `put_async`/`get_async` complete by direct load/store
//!   (arXiv:1507.04799): no deferred-op queue entry, no progress-engine
//!   registration, nothing for a flush to wait on. Counted in
//!   [`super::Metrics::locality_fastpath_ops`]; togglable via
//!   [`crate::dart::DartConfig::locality_fastpath`]. The strided vector
//!   variants deliberately stay on the deferred path — their value is the
//!   single-message packing, which the cost model books per message.
//!
//! Deferred operations are additionally registered with the substrate's
//! **asynchronous progress engine** ([`crate::mpisim::progress`]): in
//! `Thread` and `Polling` modes the engine retires them in the background
//! — an async put can reach remote completion with *zero* explicit flushes
//! — and the retired work is mirrored into [`super::Metrics`] as
//! overlap-achieved operations/bytes. A flush still gives the usual
//! completion guarantee in every mode; what the mode changes is who paid
//! for completion, which is exactly what the `perf_overlap` bench measures.

use super::gptr::{GlobalPtr, TeamId, UnitId};
use super::{DartEnv, DartErr, DartResult};
use crate::mpisim::{as_bytes, HasMpiType, MpiOp, ProgressMode, VectorType, Win};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

/// One memoized §IV-B4 resolution: the window, MPI-relative target rank
/// and covering allocation extent of a collective global pointer.
pub(crate) struct Resolution {
    pub segid: TeamId,
    pub unitid: UnitId,
    /// Pool-relative start of the covering allocation.
    pub base: u64,
    /// Length of the covering allocation.
    pub len: u64,
    /// Team-relative (= window-relative) target rank.
    pub target: usize,
    /// The allocation's window.
    pub win: Rc<Win>,
    /// Dynamic-window detach generation this resolution was taken at; 0
    /// for static (collective-pool) resolutions. A dynamic entry is valid
    /// only while the window's generation still equals this — detaches are
    /// non-collective, so remote caches invalidate lazily by comparing
    /// generations instead of being told (see `mpisim::dynwin`).
    pub dyn_gen: u64,
}

/// Hard cap on cached resolutions. Reaching it means the application
/// churns through allocations faster than it reuses them — flushing the
/// whole cache (rather than tracking LRU order on the hot path) keeps the
/// common case free and the degenerate case bounded.
pub(crate) const CACHE_MAX_ENTRIES: usize = 4096;

/// Per-unit segment-resolution cache, sharded by `(team, unit)`.
///
/// The seed design was a fixed 8-slot array with a linear scan — fine for
/// a handful of live segments, O(segments) once an application keeps
/// hundreds of allocations across many teams. Keying a hash map by the
/// gptr's `(segid, unitid)` makes the lookup O(1) in the number of live
/// segments; the short per-key bucket (one entry per distinct allocation
/// of that team touched toward that unit) is still scanned linearly for
/// the covering-extent check, which no hash can answer.
pub(crate) struct SegmentCache {
    /// The pre-reserved world window: non-collective pointers always
    /// resolve here, so the engine keeps the handle out of the `RefCell`'d
    /// registry state entirely.
    world_win: Rc<Win>,
    /// The env's one dynamic window: every dynamic pointer resolves here,
    /// same reasoning as `world_win`. Dynamic resolutions are cached in
    /// the same buckets (their negative segids can never collide with a
    /// team id) and carry the window's detach generation for lazy
    /// invalidation.
    dyn_win: Rc<Win>,
    enabled: bool,
    buckets: HashMap<(TeamId, UnitId), Vec<Resolution>>,
    /// Total resolutions across all buckets (kept so the size query and
    /// the cap check never walk the map).
    entries: usize,
}

impl SegmentCache {
    pub(crate) fn new(world_win: Rc<Win>, dyn_win: Rc<Win>, enabled: bool) -> Self {
        SegmentCache { world_win, dyn_win, enabled, buckets: HashMap::new(), entries: 0 }
    }

    #[inline]
    fn lookup(&self, gptr: GlobalPtr) -> Option<&Resolution> {
        if !self.enabled {
            return None;
        }
        self.buckets
            .get(&(gptr.segid, gptr.unitid))?
            .iter()
            .find(|r| gptr.offset >= r.base && gptr.offset - r.base < r.len)
    }

    fn insert(&mut self, r: Resolution) {
        if !self.enabled {
            return;
        }
        if self.entries >= CACHE_MAX_ENTRIES {
            self.buckets.clear();
            self.entries = 0;
        }
        self.buckets.entry((r.segid, r.unitid)).or_default().push(r);
        self.entries += 1;
    }

    /// Drop every cached resolution of the allocation at `(team, base)` —
    /// called by `team_memfree` *before* it asserts exclusive ownership of
    /// the allocation's window, and before the pool offset can be reused.
    pub(crate) fn invalidate_segment(&mut self, team: TeamId, base: u64) {
        let mut dropped = 0;
        self.buckets.retain(|&(segid, _), bucket| {
            if segid == team {
                let before = bucket.len();
                bucket.retain(|r| r.base != base);
                dropped += before - bucket.len();
            }
            !bucket.is_empty()
        });
        self.entries -= dropped;
    }

    /// Drop every cached resolution of `team` — called by `team_destroy`.
    pub(crate) fn invalidate_team(&mut self, team: TeamId) {
        let mut dropped = 0;
        self.buckets.retain(|&(segid, _), bucket| {
            if segid == team {
                dropped += bucket.len();
                false
            } else {
                true
            }
        });
        self.entries -= dropped;
    }

    /// Number of live cached resolutions (the size metric and tests).
    pub(crate) fn live(&self) -> usize {
        self.entries
    }
}

/// Validate a strided-transfer request and build its wire datatype.
pub(crate) fn strided_type(
    buf_len: usize,
    count: usize,
    block: usize,
    stride: u64,
) -> DartResult<VectorType> {
    if buf_len != count * block {
        return Err(DartErr::Invalid(format!(
            "strided transfer: buffer {buf_len} bytes != {count} × {block}"
        )));
    }
    // `stride ≥ block` is enforced by `VectorType::new` — the single
    // authority for datatype geometry.
    Ok(VectorType::new(count, block, stride as usize)?)
}

impl DartEnv {
    // ------------------------------------------------------------------
    // The §IV-B4 dereference chain, memoized
    // ------------------------------------------------------------------

    /// The single implementation of the memoized §IV-B4 chain: resolve
    /// `gptr` and run `f` with the owning window handle, target rank and
    /// displacement — borrow-scoped, so the hot path pays no `Rc`
    /// refcount traffic (callers that need ownership clone inside `f`).
    ///
    /// Cache hit: a handful of integer compares, no registry access.
    /// Cache miss: the full slow path
    /// ([`DartEnv::resolve_collective_slow`]), whose result is memoized.
    #[inline]
    fn resolve_scoped<R>(
        &self,
        gptr: GlobalPtr,
        f: impl FnOnce(&Rc<Win>, usize, u64) -> DartResult<R>,
    ) -> DartResult<R> {
        if gptr.is_null() {
            return Err(DartErr::InvalidGptr("null pointer dereference".into()));
        }
        if gptr.is_dynamic() {
            return self.resolve_dynamic_scoped(gptr, f);
        }
        if !gptr.is_collective() {
            // Fig. 4 path: "trivially dereferenced" against the world
            // window with the absolute unit as target.
            if gptr.unitid as usize >= self.size() {
                return Err(DartErr::InvalidUnit(gptr.unitid));
            }
            let cache = self.seg_cache.borrow();
            return f(&cache.world_win, gptr.unitid as usize, gptr.offset);
        }
        {
            let cache = self.seg_cache.borrow();
            if let Some(r) = cache.lookup(gptr) {
                self.metrics.cache_hits.bump();
                return f(&r.win, r.target, gptr.offset - r.base);
            }
        }
        self.metrics.cache_misses.bump();
        let r = self.resolve_collective_slow(gptr)?;
        let out = f(&r.win, r.target, gptr.offset - r.base);
        let live = {
            let mut cache = self.seg_cache.borrow_mut();
            cache.insert(r);
            cache.live()
        };
        self.metrics.seg_cache_size.set(live as u64);
        out
    }

    /// The dynamic arm of the dereference chain: resolve a
    /// [`super::gptr::FLAG_DYNAMIC`] pointer against the env's dynamic
    /// window. The displacement handed to `f` is the pointer's **absolute
    /// attach-token address** — `check_range`'s floor lookup resolves it,
    /// so no base subtraction happens here. Resolutions are memoized like
    /// collective ones, but a cache hit additionally requires the cached
    /// detach generation to still be current; a stale or missing entry
    /// re-resolves against the live attach table (and errors if the region
    /// was detached).
    fn resolve_dynamic_scoped<R>(
        &self,
        gptr: GlobalPtr,
        f: impl FnOnce(&Rc<Win>, usize, u64) -> DartResult<R>,
    ) -> DartResult<R> {
        if gptr.unitid as usize >= self.size() {
            return Err(DartErr::InvalidUnit(gptr.unitid));
        }
        {
            let cache = self.seg_cache.borrow();
            if let Some(r) = cache.lookup(gptr) {
                if r.dyn_gen == cache.dyn_win.dyn_generation() {
                    self.metrics.cache_hits.bump();
                    return f(&r.win, r.target, gptr.offset);
                }
            }
        }
        self.metrics.cache_misses.bump();
        let r = self.resolve_dynamic_slow(gptr)?;
        let out = f(&r.win, r.target, gptr.offset);
        let live = {
            let mut cache = self.seg_cache.borrow_mut();
            // Drop any stale resolution of the same region before
            // memoizing the fresh one, so the bucket never holds two
            // entries covering one extent.
            cache.invalidate_segment(gptr.segid, r.base);
            cache.insert(r);
            cache.live()
        };
        self.metrics.seg_cache_size.set(live as u64);
        out
    }

    /// The uncached dynamic slow path: look the token up in the live
    /// attach table. The generation is read **before** the region lookup —
    /// a detach racing with this resolution can then only produce an entry
    /// already marked stale (which re-resolves on next use), never a
    /// fresh-marked entry for a dead region.
    fn resolve_dynamic_slow(&self, gptr: GlobalPtr) -> DartResult<Resolution> {
        let win = self.seg_cache.borrow().dyn_win.clone();
        let gen = win.dyn_generation();
        let (base, len) =
            win.dyn_region_of(gptr.unitid as usize, gptr.offset).ok_or_else(|| {
                DartErr::InvalidGptr(format!("{gptr}: not in any attached region"))
            })?;
        Ok(Resolution {
            segid: gptr.segid,
            unitid: gptr.unitid,
            base,
            len: len as u64,
            // The dynamic window spans DART_TEAM_ALL, so the absolute
            // unit id IS the window-relative rank.
            target: gptr.unitid as usize,
            win,
            dyn_gen: gen,
        })
    }

    /// Scoped dereference: run `f` with the resolved window (the put/get
    /// hot path — no `Rc` clone).
    #[inline]
    pub(crate) fn with_win<R>(
        &self,
        gptr: GlobalPtr,
        f: impl FnOnce(&Win, usize, u64) -> DartResult<R>,
    ) -> DartResult<R> {
        self.resolve_scoped(gptr, |win, target, disp| f(win.as_ref(), target, disp))
    }

    /// Owning dereference: like [`DartEnv::with_win`] but returns a cloned
    /// window handle (atomics, local access — off the hot path).
    #[inline]
    pub(crate) fn deref_gptr(&self, gptr: GlobalPtr) -> DartResult<(Rc<Win>, usize, u64)> {
        self.resolve_scoped(gptr, |win, target, disp| Ok((win.clone(), target, disp)))
    }

    /// Live entries in the segment cache (diagnostics/tests).
    pub fn segment_cache_live(&self) -> usize {
        self.seg_cache.borrow().live()
    }

    // ------------------------------------------------------------------
    // Deferred-completion one-sided ops + explicit flushes
    // ------------------------------------------------------------------

    /// `dart_put` in *deferred-completion* mode: initiate the transfer and
    /// return immediately, without allocating a completion handle. Remote
    /// completion is deferred to the next [`DartEnv::flush`] /
    /// [`DartEnv::flush_all`] covering the target — so a phase of many
    /// puts pays one completion call per target instead of one per op —
    /// or, in `Thread`/`Polling` progress modes, to the engine retiring it
    /// in the background.
    ///
    /// **Locality fast path** (arXiv:1507.04799): when the segment lives
    /// in a shared-memory window ([`crate::dart::DartConfig::shmem_windows`])
    /// and the target unit shares this unit's node, the store itself IS
    /// the transfer — the operation completes here, enters neither the
    /// pending list nor the progress engine, and is counted in
    /// [`super::Metrics::locality_fastpath_ops`]. Flush semantics are
    /// preserved trivially (there is nothing left to complete), and no
    /// overlap credit is claimed (nothing was deferred). Disable with
    /// [`crate::dart::DartConfig::with_locality_fastpath`]`(false)` for
    /// the ablation.
    pub fn put_async(&self, gptr: GlobalPtr, src: &[u8]) -> DartResult<()> {
        self.poll_if_polling();
        let fastpath = self.config().locality_fastpath;
        let issued = self.with_win(gptr, |win, target, disp| {
            if fastpath && win.is_shmem_local(target) {
                win.store_direct(src, target, disp as usize)?;
                Ok(None)
            } else {
                Ok(Some((win.put(src, target, disp as usize)?, win.id(), target)))
            }
        })?;
        match issued {
            Some((at, win_id, target)) => {
                self.register_async(src.len() as u64, at, win_id, target)
            }
            None => self.metrics.locality_fastpath_ops.bump(),
        }
        self.metrics.puts.bump();
        self.metrics.bytes.add(src.len() as u64);
        Ok(())
    }

    /// `dart_get` in deferred-completion mode: `dst` may not be read until
    /// a flush covering the target completes — except on the locality fast
    /// path (shmem window + same-node target, see [`DartEnv::put_async`]),
    /// where the load completes in place and `dst` is valid on return.
    pub fn get_async(&self, gptr: GlobalPtr, dst: &mut [u8]) -> DartResult<()> {
        self.poll_if_polling();
        let fastpath = self.config().locality_fastpath;
        let issued = self.with_win(gptr, |win, target, disp| {
            if fastpath && win.is_shmem_local(target) {
                win.load_direct(dst, target, disp as usize)?;
                Ok(None)
            } else {
                Ok(Some((win.get(dst, target, disp as usize)?, win.id(), target)))
            }
        })?;
        match issued {
            Some((at, win_id, target)) => {
                self.register_async(dst.len() as u64, at, win_id, target)
            }
            None => self.metrics.locality_fastpath_ops.bump(),
        }
        self.metrics.gets.bump();
        self.metrics.bytes.add(dst.len() as u64);
        Ok(())
    }

    /// Strided deferred-completion put: one vector-typed RMA operation
    /// (see [`DartEnv::put_strided`] for the layout parameters).
    pub fn put_strided_async(
        &self,
        gptr: GlobalPtr,
        src: &[u8],
        count: usize,
        block: usize,
        stride: u64,
    ) -> DartResult<()> {
        self.poll_if_polling();
        let ty = strided_type(src.len(), count, block, stride)?;
        let (at, win_id, target) = self.with_win(gptr, |win, target, disp| {
            Ok((win.put_vector(src, target, disp as usize, &ty)?, win.id(), target))
        })?;
        self.register_async(src.len() as u64, at, win_id, target);
        self.metrics.puts.bump();
        self.metrics.bytes.add(src.len() as u64);
        Ok(())
    }

    /// Strided deferred-completion get: the mirror of
    /// [`DartEnv::put_strided_async`].
    pub fn get_strided_async(
        &self,
        gptr: GlobalPtr,
        dst: &mut [u8],
        count: usize,
        block: usize,
        stride: u64,
    ) -> DartResult<()> {
        self.poll_if_polling();
        let ty = strided_type(dst.len(), count, block, stride)?;
        let (at, win_id, target) = self.with_win(gptr, |win, target, disp| {
            Ok((win.get_vector(dst, target, disp as usize, &ty)?, win.id(), target))
        })?;
        self.register_async(dst.len() as u64, at, win_id, target);
        self.metrics.gets.bump();
        self.metrics.bytes.add(dst.len() as u64);
        Ok(())
    }

    /// `dart_accumulate` in deferred-completion mode: element-wise atomic
    /// `target := target (op) src`, initiated like [`DartEnv::put_async`]
    /// — one engine registration, remote completion deferred to the next
    /// covering [`DartEnv::flush`]/[`DartEnv::flush_all`] (or to the
    /// progress engine). The update is applied with lock-free per-element
    /// CPU atomics ([`crate::mpisim::atomics`]), so concurrent accumulates
    /// from many units to the same element never lose updates, and
    /// accumulates to *different* elements never contend.
    ///
    /// On the locality fast path (shmem window + same-node target) the CPU
    /// atomic IS the whole operation: it completes in place, skips the
    /// pending list and the engine, and is counted in
    /// [`super::Metrics::atomic_fastpath_ops`]. Results are bit-identical
    /// to the modelled path by construction — both funnel through the same
    /// atomic primitive; only the modelled completion time differs.
    pub fn accumulate_async<T: HasMpiType>(
        &self,
        gptr: GlobalPtr,
        src: &[T],
        op: MpiOp,
    ) -> DartResult<()> {
        self.poll_if_polling();
        let bytes = std::mem::size_of_val(src) as u64;
        let fastpath = self.config().locality_fastpath;
        let issued = self.with_win(gptr, |win, target, disp| {
            if fastpath && win.is_shmem_local(target) {
                win.accumulate_direct(as_bytes(src), target, disp as usize, op, T::MPI_TYPE)?;
                Ok(None)
            } else {
                Ok(Some((
                    win.accumulate(as_bytes(src), target, disp as usize, op, T::MPI_TYPE)?,
                    win.id(),
                    target,
                )))
            }
        })?;
        match issued {
            Some((at, win_id, target)) => self.register_async(bytes, at, win_id, target),
            None => self.metrics.atomic_fastpath_ops.bump(),
        }
        self.metrics.atomic_ops.bump();
        self.metrics.atomic_bytes.add(bytes);
        Ok(())
    }

    /// `dart_flush(gptr)`: block until every outstanding deferred
    /// operation *to the unit behind `gptr`* (on its segment's window) has
    /// completed remotely.
    pub fn flush(&self, gptr: GlobalPtr) -> DartResult<()> {
        // Snapshot engine retirement *before* waiting: anything the engine
        // retires while this flush blocks was paid for by the caller and
        // earns no overlap credit.
        let pre = self.mpi().state().progress_retired_of(self.myid() as usize);
        let (win_id, target) = self.with_win(gptr, |win, target, _| {
            win.flush(target)?;
            Ok((win.id(), target))
        })?;
        self.drain_after_flush(pre, win_id, Some(target));
        Ok(())
    }

    /// `dart_flush_all(gptr)`: block until every outstanding deferred
    /// operation on `gptr`'s segment window — to *any* target — has
    /// completed remotely. One call completes a whole halo-exchange phase.
    pub fn flush_all(&self, gptr: GlobalPtr) -> DartResult<()> {
        let pre = self.mpi().state().progress_retired_of(self.myid() as usize);
        let win_id = self.with_win(gptr, |win, _, _| {
            win.flush_all()?;
            Ok(win.id())
        })?;
        self.drain_after_flush(pre, win_id, None);
        Ok(())
    }

    // ------------------------------------------------------------------
    // The asynchronous progress engine's DART surface
    // ------------------------------------------------------------------

    /// Register a deferred-completion op with the progress engine.
    #[inline]
    fn register_async(&self, bytes: u64, complete_at: Instant, win: u64, target: usize) {
        self.mpi()
            .state()
            .progress_register_rma(self.myid() as usize, bytes, complete_at, win, target);
    }

    /// Opportunistic cooperative tick at operation-initiation points
    /// (`Polling` mode only): give the engine a chance to retire *earlier*
    /// operations before this one is registered.
    #[inline]
    pub(crate) fn poll_if_polling(&self) {
        if self.config().progress_mode == ProgressMode::Polling {
            self.progress_poll();
        }
    }

    /// Flush bookkeeping: the wait is over, so every covered entry of mine
    /// whose completion instant has passed is done — drop it *without*
    /// overlap credit (the caller paid for it). Overlap credit is mirrored
    /// only up to `pre`, the retirement snapshot taken before the flush
    /// began: work the engine happened to retire *while the caller was
    /// blocked waiting* is not overlap either.
    fn drain_after_flush(&self, pre: (u64, u64), win: u64, target: Option<usize>) {
        let me = self.myid() as usize;
        self.mpi().state().progress_drain_completed(me, win, target);
        self.metrics.flushes.bump();
        let (seen_ops, seen_bytes) = self.progress_seen.get();
        self.metrics.overlap_ops.add(pre.0 - seen_ops);
        self.metrics.overlap_bytes.add(pre.1 - seen_bytes);
        // Advance the seen-counters past anything retired during the wait
        // so no later sync point credits it.
        let post = self.mpi().state().progress_retired_of(me);
        self.progress_seen.set(post);
    }

    /// One explicit cooperative progress tick: retire pending deferred
    /// operations engine-wide and advance nonblocking collectives. Returns
    /// the number of RMA operations retired. A no-op in `Caller` mode —
    /// the whole point of that mode is that nobody ticks.
    ///
    /// Applications insert this between communication initiation and
    /// independent computation (see `apps::stencil2d`); each tick is
    /// charged [`crate::simnet::CostModel::progress_tick_ns`].
    pub fn progress_poll(&self) -> usize {
        if self.config().progress_mode == ProgressMode::Caller {
            return 0;
        }
        let retired = self.mpi().state().progress_tick();
        self.metrics.progress_ticks.bump();
        self.sync_progress_metrics();
        retired
    }

    /// Number of this unit's deferred-completion operations still
    /// registered with the progress engine (not yet retired by it, nor
    /// drained by a flush). Reaches zero without any flush in `Thread`
    /// mode — the "zero explicit flushes" property the follow-up paper is
    /// about.
    pub fn async_pending(&self) -> usize {
        let pending = self.mpi().state().progress_pending_of(self.myid() as usize);
        self.sync_progress_metrics();
        pending
    }

    /// Total engine wakeups in this launch (background thread + all units'
    /// polls). World-global; for per-unit poll counts see
    /// [`super::Metrics::progress_ticks`].
    pub fn engine_ticks(&self) -> u64 {
        self.mpi().state().progress_ticks_total()
    }

    /// Total modelled nanoseconds charged for engine wakeups in this
    /// launch (world-global) — the cost side of the progress-mode ablation.
    pub fn engine_tick_ns_charged(&self) -> u64 {
        self.mpi().state().progress_tick_ns_charged()
    }

    /// Mirror the engine's retirement counters for this unit into
    /// [`super::Metrics::overlap_ops`]/[`super::Metrics::overlap_bytes`].
    /// Called from every progress-related sync point.
    pub(crate) fn sync_progress_metrics(&self) {
        let (ops, bytes) = self.mpi().state().progress_retired_of(self.myid() as usize);
        let (seen_ops, seen_bytes) = self.progress_seen.get();
        self.metrics.overlap_ops.add(ops - seen_ops);
        self.metrics.overlap_bytes.add(bytes - seen_bytes);
        self.progress_seen.set((ops, bytes));
        self.sync_fault_metrics();
    }

    /// Mirror the world-global injected-fault counters into this unit's
    /// [`super::Metrics`] `fault_*` fields (snapshot-diff, so repeated
    /// sync points never double-count). A no-op without a fault plan.
    pub(crate) fn sync_fault_metrics(&self) {
        if self.config().fault_plan.is_none() {
            return;
        }
        let s = self.mpi().state().fault_stats();
        let seen = self.fault_seen.get();
        self.metrics.fault_jitter_events.add(s.jitter_events - seen.jitter_events);
        self.metrics.fault_reorders.add(s.reorders - seen.reorders);
        self.metrics.fault_starved_ticks.add(s.starved_ticks - seen.starved_ticks);
        self.fault_seen.set(s);
    }
}
