//! The per-team translation table (paper §IV-B3, Fig. 5).
//!
//! Every *collective* global memory allocation creates one MPI window over
//! a range of the team's reserved pool; the table records `(pool offset →
//! window)` so that dereferencing a collective global pointer — whose
//! offset is relative to the **pool base**, not the allocation — can find
//! the right window object and the window-relative displacement.

use super::{DartErr, DartResult};
use crate::mpisim::Win;
use std::rc::Rc;

/// One collective allocation: `[base, base+len)` of the team pool, exposed
/// through `win`.
pub struct TransEntry {
    /// Pool-relative start of the allocation.
    pub base: u64,
    /// Allocation length in bytes.
    pub len: u64,
    /// The allocation's RMA window.
    pub win: Rc<Win>,
}

/// Sorted-by-offset table of a team's collective allocations.
#[derive(Default)]
pub struct TranslationTable {
    /// Invariant: sorted by `base`, non-overlapping.
    entries: Vec<TransEntry>,
}

impl TranslationTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a new allocation. Keeps the table sorted.
    pub fn add(&mut self, base: u64, len: u64, win: Rc<Win>) -> DartResult<()> {
        let pos = self.entries.partition_point(|e| e.base < base);
        // Overlap checks against neighbours.
        if let Some(prev) = pos.checked_sub(1).and_then(|p| self.entries.get(p)) {
            if prev.base + prev.len > base {
                return Err(DartErr::Invalid(format!(
                    "allocation at {base} overlaps previous [{}, {})",
                    prev.base,
                    prev.base + prev.len
                )));
            }
        }
        if let Some(next) = self.entries.get(pos) {
            if base + len > next.base {
                return Err(DartErr::Invalid(format!(
                    "allocation at {base} overlaps next [{}, {})",
                    next.base,
                    next.base + next.len
                )));
            }
        }
        self.entries.insert(pos, TransEntry { base, len, win });
        Ok(())
    }

    /// Dereference a pool-relative offset: the covering window and the
    /// window-relative displacement. This is on the one-sided hot path.
    #[inline]
    pub fn lookup(&self, offset: u64) -> Option<(&Rc<Win>, u64)> {
        self.lookup_entry(offset).map(|e| (&e.win, offset - e.base))
    }

    /// Like [`TranslationTable::lookup`] but returns the full covering
    /// entry — the engine's segment cache memoizes its `[base, base+len)`
    /// extent so later offsets into the same allocation hit without a
    /// table search.
    #[inline]
    pub fn lookup_entry(&self, offset: u64) -> Option<&TransEntry> {
        let pos = self.entries.partition_point(|e| e.base <= offset);
        let e = &self.entries[pos.checked_sub(1)?];
        (offset < e.base + e.len).then_some(e)
    }

    /// Remove the allocation starting exactly at `base`, returning its
    /// window (for collective freeing).
    pub fn remove(&mut self, base: u64) -> DartResult<TransEntry> {
        match self.entries.binary_search_by_key(&base, |e| e.base) {
            Ok(i) => Ok(self.entries.remove(i)),
            Err(_) => Err(DartErr::InvalidGptr(format!("no collective allocation at offset {base}"))),
        }
    }

    /// Number of live allocations.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// No live allocations?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate entries in offset order (team teardown frees in creation
    /// order on every member, keeping the collective frees aligned).
    pub fn entries(&self) -> &[TransEntry] {
        &self.entries
    }

    /// Drain all entries in offset order.
    pub fn drain(&mut self) -> Vec<TransEntry> {
        std::mem::take(&mut self.entries)
    }

    /// Invariant check for property tests: sorted and non-overlapping.
    pub fn check_invariants(&self) -> bool {
        self.entries
            .windows(2)
            .all(|w| w[0].base + w[0].len <= w[1].base)
    }
}

/// First-fit free-list allocator with 8-byte alignment — manages both the
/// per-unit partition of the world window (non-collective allocations,
/// Fig. 4) and each team's collective pool (Fig. 5).
///
/// Determinism matters for the collective pool: every team member runs the
/// same alloc/free sequence (collective calls), so identical allocator
/// states yield identical offsets — which is exactly what makes DART's
/// *aligned* allocations line up without communication.
pub struct FreeListAllocator {
    size: u64,
    /// Sorted, coalesced free extents `(base, len)`.
    free: Vec<(u64, u64)>,
    /// Live allocation sizes by base (so `free(base)` needs no length).
    live: std::collections::HashMap<u64, u64>,
}

/// All DART allocations are 8-byte aligned.
pub const DART_ALIGN: u64 = 8;

impl FreeListAllocator {
    /// Allocator over `size` bytes, initially one free extent.
    pub fn new(size: u64) -> Self {
        FreeListAllocator {
            size,
            free: if size > 0 { vec![(0, size)] } else { vec![] },
            live: std::collections::HashMap::new(),
        }
    }

    /// Allocate `len` bytes (rounded up to [`DART_ALIGN`]); first fit.
    pub fn alloc(&mut self, len: u64) -> DartResult<u64> {
        if len == 0 {
            return Err(DartErr::Invalid("zero-size allocation".into()));
        }
        let len = len.div_ceil(DART_ALIGN) * DART_ALIGN;
        for i in 0..self.free.len() {
            let (base, flen) = self.free[i];
            if flen >= len {
                if flen == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (base + len, flen - len);
                }
                self.live.insert(base, len);
                return Ok(base);
            }
        }
        Err(DartErr::OutOfMemory { requested: len, pool: self.size })
    }

    /// Free the allocation starting at `base`, coalescing neighbours.
    pub fn free(&mut self, base: u64) -> DartResult<()> {
        let len = self
            .live
            .remove(&base)
            .ok_or_else(|| DartErr::InvalidGptr(format!("free of unallocated offset {base}")))?;
        let pos = self.free.partition_point(|&(b, _)| b < base);
        self.free.insert(pos, (base, len));
        // Coalesce with next, then previous.
        if pos + 1 < self.free.len() && self.free[pos].0 + self.free[pos].1 == self.free[pos + 1].0
        {
            self.free[pos].1 += self.free[pos + 1].1;
            self.free.remove(pos + 1);
        }
        if pos > 0 && self.free[pos - 1].0 + self.free[pos - 1].1 == self.free[pos].0 {
            self.free[pos - 1].1 += self.free[pos].1;
            self.free.remove(pos);
        }
        Ok(())
    }

    /// Size of the live allocation starting at `base` (rounded length).
    pub fn size_of(&self, base: u64) -> Option<u64> {
        self.live.get(&base).copied()
    }

    /// Total bytes currently allocated.
    pub fn used(&self) -> u64 {
        self.live.values().sum()
    }

    /// Pool capacity.
    pub fn capacity(&self) -> u64 {
        self.size
    }

    /// Property-test invariant: free list sorted, coalesced, disjoint from
    /// live allocations, and free+live == capacity.
    pub fn check_invariants(&self) -> bool {
        let sorted_coalesced = self
            .free
            .windows(2)
            .all(|w| w[0].0 + w[0].1 < w[1].0);
        let total_free: u64 = self.free.iter().map(|&(_, l)| l).sum();
        sorted_coalesced && total_free + self.used() == self.size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_is_aligned_first_fit() {
        let mut a = FreeListAllocator::new(1024);
        let x = a.alloc(10).unwrap(); // rounds to 16
        let y = a.alloc(8).unwrap();
        assert_eq!(x, 0);
        assert_eq!(y, 16);
        assert!(a.check_invariants());
    }

    #[test]
    fn free_coalesces() {
        let mut a = FreeListAllocator::new(256);
        let x = a.alloc(64).unwrap();
        let y = a.alloc(64).unwrap();
        let z = a.alloc(64).unwrap();
        a.free(y).unwrap();
        assert!(a.check_invariants());
        a.free(x).unwrap();
        a.free(z).unwrap();
        assert!(a.check_invariants());
        // fully coalesced: a 256-byte alloc must fit again
        assert_eq!(a.alloc(256).unwrap(), 0);
    }

    #[test]
    fn oom_and_reuse() {
        let mut a = FreeListAllocator::new(64);
        let x = a.alloc(64).unwrap();
        assert!(matches!(a.alloc(8), Err(DartErr::OutOfMemory { .. })));
        a.free(x).unwrap();
        assert!(a.alloc(8).is_ok());
    }

    #[test]
    fn double_free_is_error() {
        let mut a = FreeListAllocator::new(64);
        let x = a.alloc(8).unwrap();
        a.free(x).unwrap();
        assert!(a.free(x).is_err());
    }

    #[test]
    fn zero_alloc_is_error() {
        let mut a = FreeListAllocator::new(64);
        assert!(a.alloc(0).is_err());
    }

    #[test]
    fn deterministic_across_replicas() {
        // Two allocators fed the same sequence produce the same offsets —
        // the property aligned team allocations rely on.
        let mut a = FreeListAllocator::new(4096);
        let mut b = FreeListAllocator::new(4096);
        let mut offs_a = vec![];
        let mut offs_b = vec![];
        for (i, len) in [100u64, 24, 8, 512, 64].iter().enumerate() {
            offs_a.push(a.alloc(*len).unwrap());
            offs_b.push(b.alloc(*len).unwrap());
            if i == 2 {
                a.free(offs_a[1]).unwrap();
                b.free(offs_b[1]).unwrap();
            }
        }
        assert_eq!(offs_a, offs_b);
    }
}
