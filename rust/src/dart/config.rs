//! DART runtime configuration.

use crate::mpisim::{ExecMode, ProgressMode};
use crate::simnet::{CostModel, FaultPlan, PinPolicy, Topology};

/// Configuration for a DART SPMD launch ([`crate::dart::run`]).
#[derive(Clone)]
pub struct DartConfig {
    /// Number of units to spawn (one OS thread each).
    pub units: usize,
    /// Modelled cluster topology.
    pub topology: Topology,
    /// Unit → core placement policy.
    pub pin: PinPolicy,
    /// Network cost model injected into the MPI substrate.
    pub cost: CostModel,
    /// Pin OS threads to real cores (best effort).
    pub pin_os_threads: bool,
    /// Capacity of the `teamlist` array (paper §IV-B2): the maximum number
    /// of *live* teams per unit. Team ids themselves are unbounded and
    /// never reused; only slots are recycled.
    pub teamlist_size: usize,
    /// Bytes reserved per unit in the pre-defined world window that backs
    /// all *non-collective* allocations (`dart_memalloc`, Fig. 4).
    pub non_collective_pool: usize,
    /// Bytes reserved per unit in each team's collective memory pool
    /// (`dart_team_memalloc_aligned` carves aligned windows out of this,
    /// Fig. 5).
    pub team_pool: usize,
    /// Use a direct-index map instead of the paper's linear `teamlist`
    /// scan for team lookup (ablation A2; the paper's future work notes
    /// the scan "can be significant when the teamlist is extremely
    /// large").
    pub indexed_teamlist: bool,
    /// §VI future work: back DART global memory with MPI-3 **shared-memory
    /// windows** ("true zero-copy mechanisms, as opposed to traditional
    /// single-copy") — same-node one-sided transfers bypass the messaging
    /// protocol. Reproduces the paper's "promising preliminary results".
    pub shmem_windows: bool,
    /// §VI future work: "balance the distribution of the *tail* between
    /// all participating units of a team" — the i-th lock initialized on a
    /// team places its tail on member `i % team_size` instead of always
    /// unit 0, avoiding congestion when many locks live on one team.
    pub balanced_lock_tails: bool,
    /// Enable the communication engine's segment-resolution cache
    /// ([`crate::dart::engine`]): the §IV-B4 dereference chain (teamlist
    /// scan, unit translation, translation-table search) is memoized per
    /// `(team, unit, allocation)` instead of recomputed on every one-sided
    /// operation. On by default; disable for the hot-path ablation.
    pub segment_cache: bool,
    /// Locality-aware **two-level collectives** (Zhou & Gracia's
    /// locality-awareness follow-up, arXiv:1603.01536): `allreduce` /
    /// `bcast` / `barrier` / `allgather` decompose into an intra-node
    /// phase over node-local teams, a cross-node exchange over the leader
    /// team, and an intra-node fan-out — so a team collective crosses the
    /// interconnect once per node instead of once per unit. Teams that
    /// span a single node fall back to the flat paths. The decomposition
    /// is observable through [`crate::dart::Metrics::hier_coll_intra_ops`]
    /// / [`crate::dart::Metrics::hier_coll_inter_ops`].
    pub hierarchical_collectives: bool,
    /// The engine's **intra-node zero-copy fast path** (arXiv:1507.04799):
    /// when [`DartConfig::shmem_windows`] is on and the target unit shares
    /// the origin's node, `put_async`/`get_async` complete by direct
    /// load/store instead of entering the deferred-completion queue —
    /// nothing to register with the progress engine, nothing for a flush
    /// to drain. On by default (it only activates under shmem windows);
    /// disable for the `perf_locality` ablation.
    pub locality_fastpath: bool,
    /// Who drives asynchronous communication progress (the follow-up
    /// paper's design axis): `Caller` (progress only inside completion
    /// calls — the MPI default), `Thread` (a dedicated background progress
    /// thread per launch), or `Polling` (cooperative ticks at initiation
    /// points plus explicit [`crate::dart::DartEnv::progress_poll`] calls).
    /// Each engine wakeup is charged
    /// [`crate::simnet::CostModel::progress_tick_ns`].
    pub progress_mode: ProgressMode,
    /// How unit tasks are scheduled onto OS threads:
    /// [`ExecMode::ThreadPerRank`] (default, one freely runnable thread per
    /// unit) or [`ExecMode::Pooled`] (bounded-concurrency run-slot gate —
    /// required for 1024+-unit worlds to complete in wall-clock seconds).
    pub exec: ExecMode,
    /// Bound on concurrently runnable unit threads under
    /// [`ExecMode::Pooled`]; `0` = the machine's available parallelism.
    pub max_os_threads: usize,
    /// Seeded deterministic fault injection ([`crate::simnet::faults`]):
    /// `None` (default) is a friendly world; `Some(plan)` makes the
    /// substrate inject message jitter, persistently slow channels,
    /// RMA-completion reordering, starved progress ticks and straggler
    /// nodes — every event reproducible from the plan's seed alone, and
    /// counted in [`crate::dart::Metrics`] (`fault_*`) so tests can assert
    /// the plan fired.
    pub fault_plan: Option<FaultPlan>,
}

impl DartConfig {
    /// `units` units on a flat topology with no cost injection — the
    /// configuration tests use.
    pub fn with_units(units: usize) -> Self {
        DartConfig {
            units,
            topology: Topology::flat(units.max(1)),
            pin: PinPolicy::Block,
            cost: CostModel::zero(),
            pin_os_threads: false,
            teamlist_size: 64,
            non_collective_pool: 8 << 20,
            team_pool: 16 << 20,
            indexed_teamlist: false,
            shmem_windows: false,
            balanced_lock_tails: false,
            segment_cache: true,
            hierarchical_collectives: false,
            locality_fastpath: true,
            progress_mode: ProgressMode::Caller,
            exec: ExecMode::ThreadPerRank,
            max_os_threads: 0,
            fault_plan: None,
        }
    }

    /// `units` units block-placed on a Hermit-like cluster with the
    /// calibrated cost model — the configuration benches use.
    pub fn hermit(units: usize, nodes: usize) -> Self {
        DartConfig {
            topology: Topology::hermit(nodes),
            cost: CostModel::hermit(),
            ..Self::with_units(units)
        }
    }

    /// Builder-style override of the placement policy.
    #[must_use]
    pub fn with_pin(mut self, pin: PinPolicy) -> Self {
        self.pin = pin;
        self
    }

    /// Builder-style override of the cost model.
    #[must_use]
    pub fn with_cost(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }

    /// Builder-style override of the pool sizes.
    #[must_use]
    pub fn with_pools(mut self, non_collective: usize, team: usize) -> Self {
        self.non_collective_pool = non_collective;
        self.team_pool = team;
        self
    }

    /// Enable the §VI shared-memory-window fast path.
    #[must_use]
    pub fn with_shmem_windows(mut self, on: bool) -> Self {
        self.shmem_windows = on;
        self
    }

    /// Enable the §VI balanced lock-tail placement.
    #[must_use]
    pub fn with_balanced_lock_tails(mut self, on: bool) -> Self {
        self.balanced_lock_tails = on;
        self
    }

    /// Toggle the engine's segment-resolution cache (hot-path ablation).
    #[must_use]
    pub fn with_segment_cache(mut self, on: bool) -> Self {
        self.segment_cache = on;
        self
    }

    /// Builder-style override of the asynchronous-progress mode.
    #[must_use]
    pub fn with_progress_mode(mut self, mode: ProgressMode) -> Self {
        self.progress_mode = mode;
        self
    }

    /// Enable locality-aware two-level collectives.
    #[must_use]
    pub fn with_hierarchical_collectives(mut self, on: bool) -> Self {
        self.hierarchical_collectives = on;
        self
    }

    /// Toggle the engine's intra-node zero-copy fast path (only active
    /// when [`DartConfig::shmem_windows`] is also on).
    #[must_use]
    pub fn with_locality_fastpath(mut self, on: bool) -> Self {
        self.locality_fastpath = on;
        self
    }

    /// Builder-style override of the execution mode and its run-slot bound
    /// (`max_os_threads = 0` = available parallelism; ignored in
    /// thread-per-rank mode).
    #[must_use]
    pub fn with_exec(mut self, exec: ExecMode, max_os_threads: usize) -> Self {
        self.exec = exec;
        self.max_os_threads = max_os_threads;
        self
    }

    /// Install a specific fault plan (see [`crate::simnet::faults`]).
    #[must_use]
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Install [`FaultPlan::from_seed`]`(seed)` — every fault class live
    /// at seed-derived intensities; the chaos suite's one-knob entry.
    #[must_use]
    pub fn with_fault_seed(self, seed: u64) -> Self {
        self.with_fault_plan(FaultPlan::from_seed(seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = DartConfig::with_units(4);
        assert_eq!(c.units, 4);
        assert!(c.teamlist_size >= 2);
        assert!(c.non_collective_pool > 0 && c.team_pool > 0);
    }

    #[test]
    fn builders_compose() {
        let c = DartConfig::hermit(8, 2).with_pools(1 << 20, 2 << 20);
        assert_eq!(c.non_collective_pool, 1 << 20);
        assert_eq!(c.team_pool, 2 << 20);
        assert_eq!(c.topology.cores_per_node(), 32);
    }
}
