//! DART mutexes: the MCS list-based queuing lock over MPI-3 RMA atomics
//! (paper §IV-B6, Fig. 6).
//!
//! Every lock consists of:
//!
//! - **`tail`** — a non-collective global allocation on the team's first
//!   unit, holding the absolute id of the last unit in the queue, or -1
//!   when the lock is free;
//! - **`list`** — one cell per unit from a collective aligned allocation;
//!   a unit's own cell holds the absolute id of its *successor* in the
//!   queue (the next unit waiting), or -1.
//!
//! `acquire` atomically **fetch-and-swaps** its own id into `tail`
//! (`MPI_Fetch_and_op` with `MPI_REPLACE`); if the old value names a
//! predecessor, the unit enqueues itself in the predecessor's cell and
//! blocks in a zero-byte `MPI_Recv`. `release` uses
//! **compare-and-swap** on `tail` to detect whether it is the only queued
//! unit; otherwise it sends the zero-size hand-off notification to its
//! successor. The queue guarantees FIFO ordering of lock acquisition.

use super::gptr::{GlobalPtr, TeamId};
use super::{DartEnv, DartErr, DartResult};
use crate::mpisim::MpiOp;
use std::cell::Cell;

/// First tag used for lock hand-off messages (tags below are user/collective
/// space). Each lock gets `LOCK_TAG_BASE + teamID * MAX_LOCKS_PER_TEAM +
/// seq`, so locks never share a tag.
pub const LOCK_TAG_BASE: i32 = 1 << 20;

/// Maximum concurrently initialized locks per team (tag-space bound).
pub const MAX_LOCKS_PER_TEAM: i32 = 2048;

/// Sentinel: no unit (free lock / no successor).
const NIL: i64 = -1;

/// A DART team lock (`dart_lock_t`).
pub struct DartLock {
    team: TeamId,
    /// Global pointer to the queue tail (on the team's first unit).
    tail: GlobalPtr,
    /// Collective allocation: my cell holds my successor's absolute id.
    list: GlobalPtr,
    /// Hand-off message tag (unique per lock).
    tag: i32,
    /// Does this unit currently hold the lock?
    held: Cell<bool>,
}

impl DartEnv {
    /// `dart_team_lock_init`: collective over `team`. Allocates `tail` on
    /// the team's first unit (via `dart_memalloc`) and the distributed
    /// queue (via `dart_team_memalloc_aligned`), both initialized to -1
    /// (paper Fig. 6, step 1).
    pub fn lock_init(&self, team: TeamId) -> DartResult<DartLock> {
        let my_team_rank = self.team_myid(team)?;
        // Unique tag: collective lock-inits are ordered per team, so the
        // per-team sequence number agrees on every member.
        let seq = self.next_lock_seq(team)?;
        // The tail host: unit 0 of the team (paper §IV-B6), or — with the
        // §VI balanced-tails option — member `seq % team_size`, spreading
        // separate locks' tail traffic over the team.
        let tail_host = if self.config().balanced_lock_tails {
            (seq as usize) % self.team_size(team)?
        } else {
            0
        };
        let mut tail_bits = [0u8; 16];
        if my_team_rank == tail_host {
            let tail = self.memalloc(8)?;
            self.local_write(tail, &NIL.to_ne_bytes())?;
            tail_bits = tail.to_bits().to_ne_bytes();
        }
        self.bcast(team, &mut tail_bits, tail_host)?;
        let tail = GlobalPtr::from_bits(u128::from_ne_bytes(tail_bits));

        // The distributed queue: one cell per unit, aligned, init -1.
        let list = self.team_memalloc_aligned(team, 8)?;
        let my_cell = list.with_unit(self.myid());
        self.local_write(my_cell, &NIL.to_ne_bytes())?;

        if seq >= MAX_LOCKS_PER_TEAM {
            return Err(DartErr::LockMisuse(format!(
                "more than {MAX_LOCKS_PER_TEAM} locks initialized on team {team}"
            )));
        }
        let tag = LOCK_TAG_BASE + (team as i32) * MAX_LOCKS_PER_TEAM + seq;
        // All cells must be initialized before anyone can enqueue.
        self.barrier(team)?;
        Ok(DartLock { team, tail, list, tag, held: Cell::new(false) })
    }

    /// `dart_lock_acquire` (paper Fig. 6, step 2): FIFO blocking acquire.
    pub fn lock_acquire(&self, lock: &DartLock) -> DartResult<()> {
        if lock.held.get() {
            return Err(DartErr::LockMisuse("acquire of a lock already held".into()));
        }
        let me = self.myid() as i64;
        // My successor cell starts empty.
        let my_cell = lock.list.with_unit(self.myid());
        self.local_write(my_cell, &NIL.to_ne_bytes())?;
        // Atomic fetch-and-store: queue myself at the tail.
        let pred = self.fetch_and_op(lock.tail, me, MpiOp::Replace)?;
        if pred != NIL {
            // Someone holds the lock: register with the predecessor and
            // wait for its zero-size hand-off notification.
            let pred_cell = lock.list.with_unit(pred as i32);
            self.put_blocking(pred_cell, &me.to_ne_bytes())?;
            let world = self.team_comm(super::DART_TEAM_ALL)?;
            world.recv(&mut [], pred as usize, lock.tag)?;
        }
        lock.held.set(true);
        self.metrics.lock_acquires.bump();
        Ok(())
    }

    /// `dart_lock_try_acquire`: acquire iff the lock is free (does not
    /// enqueue).
    pub fn lock_try_acquire(&self, lock: &DartLock) -> DartResult<bool> {
        if lock.held.get() {
            return Err(DartErr::LockMisuse("try_acquire of a lock already held".into()));
        }
        let me = self.myid() as i64;
        // Reset my successor cell BEFORE the tail swap (same order as
        // `lock_acquire`): the instant the CAS below succeeds, a
        // concurrent `lock_acquire` may read us as its predecessor and
        // register in our cell — a reset after the swap could erase that
        // registration and deadlock the hand-off. Before the swap nobody
        // can name us as predecessor, so the early reset is safe.
        let my_cell = lock.list.with_unit(self.myid());
        self.local_write(my_cell, &NIL.to_ne_bytes())?;
        let old = self.compare_and_swap(lock.tail, NIL, me)?;
        if old == NIL {
            lock.held.set(true);
            self.metrics.lock_acquires.bump();
            Ok(true)
        } else {
            Ok(false)
        }
    }

    /// `dart_lock_release` (paper Fig. 6, steps 3–4): compare-and-swap the
    /// tail back to -1 if we are alone; otherwise hand off to the
    /// successor with a zero-size notification.
    pub fn lock_release(&self, lock: &DartLock) -> DartResult<()> {
        if !lock.held.get() {
            return Err(DartErr::LockMisuse("release of a lock not held".into()));
        }
        let me = self.myid() as i64;
        let old = self.compare_and_swap(lock.tail, me, NIL)?;
        if old != me {
            // A successor is enqueuing (it already swapped the tail but may
            // not have registered in our cell yet): wait for it to appear.
            let my_cell = lock.list.with_unit(self.myid());
            let successor = loop {
                let mut cell = [0u8; 8];
                self.local_read(my_cell, &mut cell)?;
                let s = i64::from_ne_bytes(cell);
                if s != NIL {
                    break s;
                }
                // The successor needs CPU time to register itself; on an
                // oversubscribed host a pure spin would stall it, and
                // under pooled execution it may even need our run slot.
                crate::simnet::exec::coop_yield();
            };
            // Reset my cell for the next acquisition, then notify.
            self.local_write(my_cell, &NIL.to_ne_bytes())?;
            let world = self.team_comm(super::DART_TEAM_ALL)?;
            world.send(&[], successor as usize, lock.tag)?;
        }
        lock.held.set(false);
        Ok(())
    }

    /// Diagnostic: the absolute unit id currently at the lock's queue
    /// tail, or `-1` when the lock is free. One blocking one-sided read
    /// of the tail cell — meant for tests and tooling that need to
    /// observe queue build-up (e.g. establishing a deterministic enqueue
    /// order), not for synchronization on the fast path.
    pub fn lock_tail(&self, lock: &DartLock) -> DartResult<i64> {
        let mut buf = [0u8; 8];
        self.get_blocking(lock.tail, &mut buf)?;
        Ok(i64::from_ne_bytes(buf))
    }

    /// `dart_team_lock_free`: collective over the team; the lock must be
    /// free everywhere.
    pub fn lock_free(&self, lock: DartLock) -> DartResult<()> {
        if lock.held.get() {
            return Err(DartErr::LockMisuse("freeing a lock while holding it".into()));
        }
        // No one may still be queued.
        self.barrier(lock.team)?;
        self.team_memfree(lock.team, lock.list)?;
        if lock.tail.unitid == self.myid() {
            self.memfree(lock.tail)?;
        }
        Ok(())
    }
}

impl DartLock {
    /// The team this lock belongs to.
    pub fn team(&self) -> TeamId {
        self.team
    }

    /// Does *this unit* currently hold the lock?
    pub fn is_held(&self) -> bool {
        self.held.get()
    }

    /// The lock's hand-off tag (diagnostics).
    pub fn tag(&self) -> i32 {
        self.tag
    }

    /// The absolute unit hosting this lock's tail (unit 0 of the team in
    /// the paper's scheme; spread over members with
    /// [`crate::dart::DartConfig::balanced_lock_tails`]).
    pub fn tail_unit(&self) -> i32 {
        self.tail.unitid
    }
}
