//! The asynchronous progress engine.
//!
//! MPI's one-sided model only guarantees progress *inside MPI calls*: a
//! deferred-completion operation or a nonblocking collective advances when
//! some rank happens to be in the library. The DART-MPI follow-up work
//! (Zhou & Gracia, "Asynchronous progress design for a MPI-based PGAS
//! one-sided communication system") shows that a dedicated progress path
//! is what turns *nominal* communication/computation overlap into *real*
//! overlap. This module is that path for the simulated substrate:
//!
//! - [`ProgressMode`] selects who makes progress: the **caller** (inside
//!   completion calls only — the MPI default), a dedicated background
//!   **thread** (one per [`crate::mpisim::World::run`]), or cooperative
//!   **polling** ticks issued by the runtime between operations.
//! - `ProgressShared` (crate-internal) is the per-world engine state: the
//!   queue of deferred-completion RMA operations awaiting retirement, the
//!   registry of in-flight nonblocking collectives
//!   ([`crate::mpisim::icoll`]), and the tick/retirement counters the
//!   ablations read.
//! - [`WorldState::progress_tick`] is one engine wakeup: it retires every
//!   pending RMA operation whose modelled completion instant has passed
//!   and advances every live nonblocking-collective state machine. Each
//!   wakeup is charged [`crate::simnet::CostModel::progress_tick_ns`]
//!   of modelled CPU time, so the mode ablation has a real cost axis.
//!
//! Retirement bookkeeping is per *origin rank*: the DART layer mirrors its
//! rank's retired-by-the-engine operation/byte counts into
//! [`crate::dart::Metrics`] as overlap-achieved work (bytes whose remote
//! completion consumed no caller time).

use super::icoll::CollState;
use super::WorldState;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Who drives communication progress (the follow-up paper's design axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProgressMode {
    /// Progress happens only inside the caller's own completion calls
    /// (`flush`, `wait`, `test`) — plain MPI semantics, zero extra cost,
    /// zero asynchronous overlap.
    #[default]
    Caller,
    /// A dedicated background thread ticks the engine continuously for the
    /// lifetime of the world: full asynchronous progress, paid for with
    /// [`crate::simnet::CostModel::progress_tick_ns`] per wakeup.
    Thread,
    /// Cooperative progress: the runtime ticks the engine opportunistically
    /// at operation-initiation points, and applications may insert explicit
    /// poll calls between communication and computation phases.
    Polling,
}

impl ProgressMode {
    /// Short label used by bench output and JSON.
    pub fn label(&self) -> &'static str {
        match self {
            ProgressMode::Caller => "caller",
            ProgressMode::Thread => "thread",
            ProgressMode::Polling => "polling",
        }
    }
}

impl fmt::Display for ProgressMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// One deferred-completion RMA operation awaiting retirement. Lives in its
/// origin rank's [`RmaShard`], so the origin is implicit in the shard index.
pub(crate) struct PendingRma {
    /// Payload size (for the overlap-achieved byte counters).
    bytes: u64,
    /// Modelled wire-completion instant.
    complete_at: Instant,
    /// Window the operation ran on (flushes drain per window).
    win: u64,
    /// Window-relative target rank (single-target flushes drain per target).
    target: usize,
}

/// One origin rank's slice of the deferred-completion queue.
///
/// Sharding by origin is exact, not probabilistic: registration, flush
/// drains and the pending-count query are all per-origin operations, so
/// each rank only ever touches its own shard — the world-global queue lock
/// the flat design serialized every rank on simply no longer exists.
struct RmaShard {
    /// This origin's pending operations.
    queue: Mutex<Vec<PendingRma>>,
    /// `queue.len()`, maintained outside the lock so that the hot-path
    /// pending query ([`WorldState::progress_pending_of`]) is a relaxed
    /// atomic load. Incremented *before* the push and decremented *after*
    /// the removal, so a nonzero queue is never reported empty.
    pending: AtomicU64,
    /// Registrations ever made by this origin — the stable per-origin
    /// sequence the fault layer's completion-reorder decisions hash.
    /// Registration happens on the origin's own thread, so the sequence
    /// follows program order and seeded decisions replay.
    reg_seq: AtomicU64,
}

/// Per-world shared state of the progress engine.
pub(crate) struct ProgressShared {
    /// Deferred-completion RMA operations, sharded by origin rank.
    rma: Vec<RmaShard>,
    /// Sum of all shards' `pending` — lets an engine tick skip the whole
    /// registry with one load when nothing is in flight.
    total_pending: AtomicU64,
    /// In-flight nonblocking collectives, keyed by `(context, seq)`.
    pub(crate) colls: Mutex<HashMap<u64, Arc<CollState>>>,
    /// Engine wakeups since world start (all drivers).
    ticks: AtomicU64,
    /// Total modelled ns charged for wakeups.
    tick_ns_charged: AtomicU64,
    /// Per-origin-rank operations retired by the engine.
    retired_ops: Vec<AtomicU64>,
    /// Per-origin-rank bytes retired by the engine.
    retired_bytes: Vec<AtomicU64>,
    /// Set when the world's ranks have joined; stops the progress thread.
    pub(crate) shutdown: AtomicBool,
}

impl ProgressShared {
    pub(crate) fn new(nranks: usize) -> Self {
        ProgressShared {
            rma: (0..nranks)
                .map(|_| RmaShard {
                    queue: Mutex::new(Vec::new()),
                    pending: AtomicU64::new(0),
                    reg_seq: AtomicU64::new(0),
                })
                .collect(),
            total_pending: AtomicU64::new(0),
            colls: Mutex::new(HashMap::new()),
            ticks: AtomicU64::new(0),
            tick_ns_charged: AtomicU64::new(0),
            retired_ops: (0..nranks).map(|_| AtomicU64::new(0)).collect(),
            retired_bytes: (0..nranks).map(|_| AtomicU64::new(0)).collect(),
            shutdown: AtomicBool::new(false),
        }
    }
}

impl WorldState {
    /// Register a deferred-completion RMA operation with the engine. Only
    /// the origin's shard is locked; counters go up *before* the push so a
    /// queued entry is never invisible to the pending query.
    ///
    /// With a fault plan live, a seeded fraction of registrations is held
    /// back past its wire completion — later-issued operations then retire
    /// *first*, the unordered-completion hazard MPI-3 RMA permits (and the
    /// chaos invariants probe `flush` and the MCS lock against).
    pub(crate) fn progress_register_rma(
        &self,
        origin: usize,
        bytes: u64,
        complete_at: Instant,
        win: u64,
        target: usize,
    ) {
        let shard = &self.progress.rma[origin];
        let mut complete_at = complete_at;
        if let Some(fs) = self.fault_state() {
            let seq = shard.reg_seq.fetch_add(1, Ordering::Relaxed);
            if let Some(hold) = fs.plan.reorder_hold_ns(origin as u64, seq) {
                complete_at += Duration::from_nanos(hold);
                fs.note_reorder(origin as u64, seq, hold);
            }
        }
        shard.pending.fetch_add(1, Ordering::Release);
        self.progress.total_pending.fetch_add(1, Ordering::Release);
        shard.queue.lock().unwrap().push(PendingRma { bytes, complete_at, win, target });
    }

    /// Number of `origin`'s registered operations not yet retired (by the
    /// engine) or drained (by a flush). Lock-free: one relaxed atomic load
    /// of the origin shard's counter — this is on the `async_pending()` hot
    /// path, which applications poll in overlap loops.
    pub fn progress_pending_of(&self, origin: usize) -> usize {
        self.progress.rma[origin].pending.load(Ordering::Acquire) as usize
    }

    /// Drop `count` entries' worth of pending-counter credit for `origin`
    /// (after removals under the shard lock).
    fn progress_note_removed(&self, origin: usize, count: usize) {
        if count > 0 {
            self.progress.rma[origin].pending.fetch_sub(count as u64, Ordering::Release);
            self.progress.total_pending.fetch_sub(count as u64, Ordering::Release);
        }
    }

    /// Drop `origin`'s completed entries *covered by a flush* — on window
    /// `win`, to `target` (or any target for a flush-all). These were
    /// completed by the caller's own wait, so they earn no overlap credit;
    /// operations on other windows/targets stay registered for the engine
    /// to retire. Locks only the origin's shard.
    pub(crate) fn progress_drain_completed(&self, origin: usize, win: u64, target: Option<usize>) {
        let now = Instant::now();
        let mut q = self.progress.rma[origin].queue.lock().unwrap();
        let before = q.len();
        q.retain(|e| {
            !(e.win == win && target.map_or(true, |t| e.target == t) && e.complete_at <= now)
        });
        let removed = before - q.len();
        drop(q);
        self.progress_note_removed(origin, removed);
    }

    /// `(operations, bytes)` of `origin`'s work retired by the engine so
    /// far — i.e. completed in the background with zero caller time.
    pub fn progress_retired_of(&self, origin: usize) -> (u64, u64) {
        (
            self.progress.retired_ops[origin].load(Ordering::Relaxed),
            self.progress.retired_bytes[origin].load(Ordering::Relaxed),
        )
    }

    /// Engine wakeups since world start (all drivers: thread + polls).
    pub fn progress_ticks_total(&self) -> u64 {
        self.progress.ticks.load(Ordering::Relaxed)
    }

    /// Nothing for the engine to do right now? (No pending RMA entries and
    /// no live nonblocking collectives — lets the Thread-mode service back
    /// off instead of burning a core ticking an empty engine.) The RMA side
    /// is one atomic load; only the collective registry takes a lock.
    pub(crate) fn progress_idle(&self) -> bool {
        self.progress.total_pending.load(Ordering::Acquire) == 0
            && self.progress.colls.lock().unwrap().is_empty()
    }

    /// Total modelled nanoseconds charged for engine wakeups.
    pub fn progress_tick_ns_charged(&self) -> u64 {
        self.progress.tick_ns_charged.load(Ordering::Relaxed)
    }

    /// One engine wakeup: retire every pending RMA operation whose modelled
    /// completion instant has passed, advance every live nonblocking
    /// collective, and charge the wakeup cost. Returns the number of RMA
    /// operations retired by this tick.
    ///
    /// With a fault plan live, a seeded fraction of wakeups is **starved**:
    /// the tick fires (it counts, it is charged) but retires nothing,
    /// advances nothing, and stalls for the plan's configured pause — the
    /// progress-starvation regime of the asynchronous-progress follow-up
    /// work. Starvation only delays background retirement; callers' own
    /// completion calls (`flush`, `wait`, `test`) still progress, as MPI
    /// semantics require.
    pub fn progress_tick(&self) -> usize {
        let tick_seq = self.progress.ticks.fetch_add(1, Ordering::Relaxed);
        if let Some(fs) = self.fault_state() {
            if fs.plan.starves_tick(tick_seq) {
                let stall = fs.plan.starve_stall_ns;
                fs.note_starved_tick(tick_seq, stall);
                if stall > 0 {
                    self.progress.tick_ns_charged.fetch_add(stall, Ordering::Relaxed);
                    crate::simnet::cost::spin_for(Duration::from_nanos(stall));
                }
                return 0;
            }
        }
        let now = Instant::now();
        let mut retired = 0usize;
        // Sharded sweep: the one-load early-out makes an idle tick free,
        // and a busy tick only locks shards that actually hold entries —
        // ranks registering new work contend on their own shard, never on
        // a world-global queue lock.
        if self.progress.total_pending.load(Ordering::Acquire) > 0 {
            for (origin, shard) in self.progress.rma.iter().enumerate() {
                if shard.pending.load(Ordering::Acquire) == 0 {
                    continue;
                }
                let mut q = shard.queue.lock().unwrap();
                let before = q.len();
                q.retain(|e| {
                    if e.complete_at <= now {
                        self.progress.retired_ops[origin].fetch_add(1, Ordering::Relaxed);
                        self.progress.retired_bytes[origin].fetch_add(e.bytes, Ordering::Relaxed);
                        false
                    } else {
                        true
                    }
                });
                let removed = before - q.len();
                drop(q);
                self.progress_note_removed(origin, removed);
                retired += removed;
            }
        }
        // Advance collectives outside the registry lock: `advance` books
        // transfers on the channel model, and holding the map lock across
        // that would serialize against every collective initiation.
        let live: Vec<Arc<CollState>> =
            self.progress.colls.lock().unwrap().values().cloned().collect();
        for c in &live {
            c.advance(self);
        }
        if self.cost.scale > 0.0 && self.cost.progress_tick_ns > 0.0 {
            let ns = self.cost.progress_tick_ns * self.cost.scale;
            self.progress.tick_ns_charged.fetch_add(ns as u64, Ordering::Relaxed);
            crate::simnet::cost::spin_for(Duration::from_nanos(ns as u64));
        }
        retired
    }
}

/// RAII handle of the Thread-mode background service: spawned before the
/// rank threads, stopped and joined when dropped (including on unwind, so
/// a panicking rank cannot leak a spinning progress thread).
pub(crate) struct ProgressThreadGuard {
    state: Arc<WorldState>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl ProgressThreadGuard {
    pub(crate) fn spawn(state: Arc<WorldState>) -> Self {
        let st = state.clone();
        let handle = std::thread::Builder::new()
            .name("mpi-progress".into())
            .spawn(move || {
                while !st.progress.shutdown.load(Ordering::Acquire) {
                    if st.progress_idle() {
                        // Nothing registered: back off instead of spinning
                        // a core on an empty engine. 50 µs bounds the extra
                        // retirement latency of the next registered op.
                        std::thread::sleep(Duration::from_micros(50));
                        continue;
                    }
                    st.progress_tick();
                    // The tick already paid its modelled wakeup cost; yield
                    // so oversubscribed rank threads are not starved.
                    std::thread::yield_now();
                }
            })
            .expect("spawn progress thread");
        ProgressThreadGuard { state, handle: Some(handle) }
    }
}

impl Drop for ProgressThreadGuard {
    fn drop(&mut self) {
        self.state.progress.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::{World, WorldConfig};

    #[test]
    fn tick_retires_passed_entries_only() {
        World::run(WorldConfig::local(2), |mpi| {
            if mpi.world_rank() != 0 {
                return;
            }
            let st = mpi.state();
            let now = Instant::now();
            st.progress_register_rma(0, 64, now, 1, 1);
            st.progress_register_rma(0, 128, now + Duration::from_secs(3600), 1, 1);
            assert_eq!(st.progress_pending_of(0), 2);
            let retired = st.progress_tick();
            assert_eq!(retired, 1);
            assert_eq!(st.progress_pending_of(0), 1);
            assert_eq!(st.progress_retired_of(0), (1, 64));
        });
    }

    #[test]
    fn drain_completed_earns_no_credit_and_is_scoped() {
        World::run(WorldConfig::local(2), |mpi| {
            if mpi.world_rank() != 0 {
                return;
            }
            let st = mpi.state();
            let now = Instant::now();
            st.progress_register_rma(0, 32, now, 1, 1); // covered by the flush
            st.progress_register_rma(0, 8, now, 1, 0); // other target
            st.progress_register_rma(0, 8, now, 2, 1); // other window
            st.progress_drain_completed(0, 1, Some(1));
            // Only the covered entry is gone, and nothing earned credit.
            assert_eq!(st.progress_pending_of(0), 2);
            assert_eq!(st.progress_retired_of(0), (0, 0));
            // A window-wide drain clears the same window's other target...
            st.progress_drain_completed(0, 1, None);
            assert_eq!(st.progress_pending_of(0), 1);
            // ...and the uncovered window's entry is still retirable with
            // full overlap credit by a later tick.
            assert_eq!(st.progress_tick(), 1);
            assert_eq!(st.progress_retired_of(0), (1, 8));
        });
    }

    #[test]
    fn thread_mode_ticks_and_shuts_down() {
        let mut cfg = WorldConfig::local(2);
        cfg.progress = ProgressMode::Thread;
        World::run(cfg, |mpi| {
            let st = mpi.state();
            st.progress_register_rma(mpi.world_rank(), 8, Instant::now(), 1, 0);
            let deadline = Instant::now() + Duration::from_secs(10);
            while st.progress_pending_of(mpi.world_rank()) > 0 {
                assert!(Instant::now() < deadline, "progress thread made no progress");
                std::thread::yield_now();
            }
            assert!(st.progress_ticks_total() > 0);
        });
        // Reaching here means the guard joined the thread cleanly.
    }

    #[test]
    fn pending_counters_are_per_origin() {
        World::run(WorldConfig::local(3), |mpi| {
            if mpi.world_rank() != 0 {
                return;
            }
            let st = mpi.state();
            let later = Instant::now() + Duration::from_secs(3600);
            st.progress_register_rma(0, 1, later, 1, 1);
            st.progress_register_rma(0, 1, later, 1, 2);
            st.progress_register_rma(2, 1, later, 1, 0);
            assert_eq!(st.progress_pending_of(0), 2);
            assert_eq!(st.progress_pending_of(1), 0);
            assert_eq!(st.progress_pending_of(2), 1);
            // A future-dated tick retires nothing and changes no counter.
            assert_eq!(st.progress_tick(), 0);
            assert_eq!(st.progress_pending_of(0), 2);
            assert_eq!(st.progress_pending_of(2), 1);
        });
    }

    #[test]
    fn mode_labels_are_stable() {
        assert_eq!(ProgressMode::Caller.label(), "caller");
        assert_eq!(ProgressMode::Thread.to_string(), "thread");
        assert_eq!(ProgressMode::Polling.label(), "polling");
        assert_eq!(ProgressMode::default(), ProgressMode::Caller);
    }
}
