//! RMA windows, passive-target synchronization, one-sided communication
//! and MPI-3 atomics.
//!
//! This is the substrate surface DART-MPI is built on (paper §IV-A):
//!
//! - [`Win::allocate`] — collective window allocation (`MPI_Win_allocate`),
//!   used for DART's pre-reserved world window and per-team memory pools;
//! - [`Win::create_sub`] — a window over a sub-range of an existing
//!   window's memory (`MPI_Win_create` on pool memory), used for each DART
//!   collective global allocation (paper Fig. 5);
//! - passive-target epochs: [`Win::lock`]/[`Win::unlock`] with
//!   [`LockKind::Shared`]/[`LockKind::Exclusive`], plus
//!   [`Win::lock_all`]/[`Win::unlock_all`]. DART opens *shared* epochs
//!   eagerly and keeps them open (§IV-B5), maximizing concurrency;
//! - one-sided [`Win::put`]/[`Win::get`]/[`Win::accumulate`] and the
//!   request-based [`Win::rput`]/[`Win::rget`] (`MPI_Rput`/`MPI_Rget`);
//! - [`Win::flush`]/[`Win::flush_all`] remote completion;
//! - atomics [`Win::fetch_and_op`] and [`Win::compare_and_swap`], the
//!   exact primitives the paper's MCS lock is built from (§IV-B6).
//!
//! Memory model: ranks share one address space, so the *public* and
//! *private* window copies coincide — this is MPI-3's **unified** memory
//! model, which the paper notes "fully matches the semantics of DART".
//! Concurrent conflicting accesses produce undefined *values* (torn bytes)
//! but never crash, mirroring MPI-3's relaxation over MPI-2 (§IV-A).
//!
//! Atomicity: the accumulate family (`accumulate`, `get_accumulate`,
//! `fetch_and_op`, `compare_and_swap`) is **lock-free** — every operation
//! resolves to per-element CPU atomics in [`super::atomics`] rather than a
//! per-window mutex, so disjoint elements never contend and same-element
//! conflicts serialize in hardware, exactly the guarantee MPI-3 gives
//! (atomic per basic element, undefined ordering across elements). Window
//! segments are 8-byte aligned to make the `AtomicU8..AtomicU64` overlay
//! sound. [`Win::accumulate`] is a deferrable request like `put` (retired
//! by `flush` or the progress engine); the `*_direct` variants complete
//! same-node ops entirely in the CPU atomic with no modelled traffic.

use super::atomics;
use super::comm::Comm;
use super::datatype::{as_bytes, HasMpiType, MpiOp, MpiType, Pod, VectorType};
use super::error::{MpiErr, MpiResult};
use super::request::RmaRequest;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Passive-target lock mode (`MPI_LOCK_SHARED` / `MPI_LOCK_EXCLUSIVE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockKind {
    /// Concurrent access epochs from many origins (the mode DART uses —
    /// exclusive locks "impair the concurrency of RMA operations", §IV-A).
    Shared,
    /// Mutual exclusion against all other epochs on the target.
    Exclusive,
}

/// One rank's exposed memory segment.
pub(crate) struct Segment {
    ptr: *mut u8,
    len: usize,
    owner: SegmentOwner,
}

#[allow(dead_code)]
pub(crate) enum SegmentOwner {
    /// The segment owns its allocation (window was `allocate`d).
    Owned,
    /// The segment borrows a parent window's memory (`create_sub`); the
    /// Arc keeps the parent's allocation alive.
    Parent(Arc<WinState>),
}

impl Segment {
    fn owned(len: usize) -> Segment {
        // Zero-initialized, stable heap allocation, backed by `u64`s so
        // the segment base is 8-byte aligned — any naturally-aligned
        // element inside it is then accessible with CPU atomics (see
        // [`super::atomics`]). We manage the buffer through a raw pointer
        // because many threads access it concurrently (that is the point
        // of an RMA window).
        let mem = vec![0u64; len.max(1).div_ceil(8)].into_boxed_slice();
        let ptr = Box::into_raw(mem) as *mut u8;
        Segment { ptr, len, owner: SegmentOwner::Owned }
    }
}

impl Drop for Segment {
    fn drop(&mut self) {
        if matches!(self.owner, SegmentOwner::Owned) {
            // Reconstruct the box allocated in `owned` (u64-backed).
            unsafe {
                drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(
                    self.ptr as *mut u64,
                    self.len.max(1).div_ceil(8),
                )));
            }
        }
    }
}

// Safety: Segment is a registered RMA region; concurrent access is governed
// by MPI RMA semantics (undefined values on conflicts, never memory
// unsafety beyond the region itself, which bounds checks enforce).
unsafe impl Send for Segment {}
unsafe impl Sync for Segment {}

/// Passive-target lock state of one target rank.
struct TargetLock {
    m: Mutex<LockSt>,
    cv: Condvar,
}

#[derive(Default)]
struct LockSt {
    shared: usize,
    exclusive: bool,
}

impl TargetLock {
    fn new() -> Self {
        TargetLock { m: Mutex::new(LockSt::default()), cv: Condvar::new() }
    }

    /// Condvar waits run inside [`crate::simnet::exec::blocking`]: a rank
    /// parked on a contended passive-target lock holds no run slot under
    /// pooled execution, so the current holder can always run and release.
    fn acquire(&self, kind: LockKind) {
        crate::simnet::exec::blocking(|| {
            let mut st = self.m.lock().unwrap();
            match kind {
                LockKind::Shared => {
                    while st.exclusive {
                        st = self.cv.wait(st).unwrap();
                    }
                    st.shared += 1;
                }
                LockKind::Exclusive => {
                    while st.exclusive || st.shared > 0 {
                        st = self.cv.wait(st).unwrap();
                    }
                    st.exclusive = true;
                }
            }
        })
    }

    fn release(&self, kind: LockKind) {
        let mut st = self.m.lock().unwrap();
        match kind {
            LockKind::Shared => st.shared -= 1,
            LockKind::Exclusive => st.exclusive = false,
        }
        self.cv.notify_all();
    }
}

/// Shared (cross-rank) state of one window.
pub struct WinState {
    pub(crate) id: u64,
    /// comm rank → world rank at creation time.
    comm_ranks: Vec<usize>,
    segments: Vec<OnceLock<Segment>>,
    locks: Vec<TargetLock>,
    /// `MPI_Win_allocate_shared` semantics: same-node peers access the
    /// memory load/store, so same-node transfers bypass the messaging
    /// protocol entirely (zero-copy; the paper's §VI future work).
    shmem: bool,
    /// `MPI_Win_create_dynamic` flavour: present iff this window was
    /// created with [`Win::allocate_dynamic`]. Every rank then exposes a
    /// zero-length static segment (so the epoch machinery above works
    /// unchanged) and displacements resolve through the per-rank attach
    /// tables instead of `segments` — the one branch in
    /// [`WinState::check_range`] below is the *entire* integration point:
    /// every one-sided op, atomic, vector transfer and local access
    /// funnels through it.
    pub(crate) dynamic: Option<super::dynwin::DynSide>,
}

impl WinState {
    fn segment(&self, target: usize) -> MpiResult<&Segment> {
        self.segments
            .get(target)
            .and_then(|s| s.get())
            .ok_or(MpiErr::RankOutOfRange(target, self.segments.len()))
    }

    fn check_range(&self, target: usize, disp: usize, len: usize) -> MpiResult<*mut u8> {
        if let Some(d) = &self.dynamic {
            // Dynamic windows address `(rank, attach-token + offset)`:
            // the floor lookup over the rank's attach table replaces the
            // static bounds check.
            self.segment(target)?; // uniform rank validation
            return d.resolve(target, disp as u64, len);
        }
        let seg = self.segment(target)?;
        if disp.checked_add(len).map_or(true, |end| end > seg.len) {
            return Err(MpiErr::DispOutOfRange { disp, len, size: seg.len });
        }
        Ok(unsafe { seg.ptr.add(disp) })
    }
}

/// Rank-local window handle. Like a real `MPI_Win`, it is bound to the rank
/// (thread) that created it: epoch state is per-origin.
pub struct Win {
    pub(crate) state: Arc<WinState>,
    comm: Comm,
    /// Epochs this origin currently holds: target → lock kind.
    epochs: RefCell<HashMap<usize, LockKind>>,
    /// Wire-completion instants of RMA ops not yet flushed, per target.
    pending: RefCell<Vec<(usize, Instant)>>,
    _not_send: std::marker::PhantomData<*const ()>,
}

impl Win {
    // ------------------------------------------------------------------
    // Construction (collective)
    // ------------------------------------------------------------------

    /// `MPI_Win_allocate`: collective over `comm`; every rank exposes a
    /// fresh zero-initialized segment of `local_size` bytes.
    pub fn allocate(comm: &Comm, local_size: usize) -> MpiResult<Win> {
        Self::build(comm, false, false, |_| Segment::owned(local_size))
    }

    /// `MPI_Win_allocate_shared`: like [`Win::allocate`], but same-node
    /// RMA is true zero-copy — transfers between ranks on the same
    /// modelled node skip the eager-protocol cost entirely and pay only a
    /// load/store cost (the paper's §VI: "especially for small message
    /// sizes, intra- and inter-NUMA communication becomes a lot more
    /// efficient"). Inter-node behaviour is unchanged.
    pub fn allocate_shared(comm: &Comm, local_size: usize) -> MpiResult<Win> {
        Self::build(comm, true, false, |_| Segment::owned(local_size))
    }

    /// `MPI_Win_allocate` with per-rank sizes.
    pub fn allocate_per_rank(comm: &Comm, local_size: usize, _sizes_hint: &[usize]) -> MpiResult<Win> {
        Self::build(comm, false, false, |_| Segment::owned(local_size))
    }

    /// `MPI_Win_create_dynamic`: collective; the window exposes **no**
    /// memory at creation — each rank registers remotely accessible memory
    /// later with [`Win::attach`] (paper §II) and ships the returned
    /// address token to peers out of band. Every rank publishes a
    /// zero-length static segment so the passive-target lock/epoch,
    /// flush/pending and [`Win::is_shmem_local`] machinery is shared
    /// verbatim with allocated windows; only displacement resolution
    /// differs (see [`WinState::check_range`]). With `shmem`, same-node
    /// transfers to attached regions take the zero-copy path like an
    /// `MPI_Win_allocate_shared` window.
    pub fn allocate_dynamic(comm: &Comm, shmem: bool) -> MpiResult<Win> {
        Self::build(comm, shmem, true, |_| Segment::owned(0))
    }

    /// A window over `[offset, offset+len)` of this window's memory on
    /// every rank — `MPI_Win_create` on registered pool memory, the paper's
    /// per-allocation window over the team's reserved pool (Fig. 5).
    /// Collective over the window's communicator; all ranks must pass the
    /// same `offset`/`len` (aligned allocation).
    pub fn create_sub(&self, offset: usize, len: usize) -> MpiResult<Win> {
        // Validate locally against my own segment (all segments are
        // symmetric for pool windows).
        let my_rank = self.comm.rank();
        let seg = self.state.segment(my_rank)?;
        if offset.checked_add(len).map_or(true, |end| end > seg.len) {
            return Err(MpiErr::DispOutOfRange { disp: offset, len, size: seg.len });
        }
        let parent = self.state.clone();
        let shmem = self.state.shmem;
        Self::build(&self.comm, shmem, false, move |rank| {
            let pseg = parent.segment(rank).expect("parent segment");
            Segment {
                ptr: unsafe { pseg.ptr.add(offset) },
                len,
                owner: SegmentOwner::Parent(parent.clone()),
            }
        })
    }

    fn build(
        comm: &Comm,
        shmem: bool,
        dynamic: bool,
        make_segment: impl Fn(usize) -> Segment,
    ) -> MpiResult<Win> {
        let world = comm.world().clone();
        let n = comm.size();
        // Rank 0 registers the WinState, then broadcasts its id. Bcast
        // ordering guarantees every rank observes the registry entry.
        let mut id = 0u64;
        if comm.rank() == 0 {
            id = world.next_win_id.fetch_add(1, Ordering::SeqCst);
            let st = Arc::new(WinState {
                id,
                comm_ranks: comm.rank_table().to_vec(),
                segments: (0..n).map(|_| OnceLock::new()).collect(),
                locks: (0..n).map(|_| TargetLock::new()).collect(),
                shmem,
                dynamic: dynamic.then(|| super::dynwin::DynSide::new(n)),
            });
            world.windows.write().unwrap().insert(id, st);
        }
        let mut buf = id.to_ne_bytes();
        comm.bcast(&mut buf, 0)?;
        id = u64::from_ne_bytes(buf);
        let state =
            world.windows.read().unwrap().get(&id).cloned().ok_or(MpiErr::UnknownWindow(id))?;
        // Publish my segment, then rendezvous so every segment is visible.
        let my_rank = comm.rank();
        state.segments[my_rank]
            .set(make_segment(my_rank))
            .map_err(|_| MpiErr::Invalid("segment set twice".into()))?;
        comm.barrier()?;
        Ok(Win {
            state,
            comm: comm.clone(),
            epochs: RefCell::new(HashMap::new()),
            pending: RefCell::new(Vec::new()),
            _not_send: std::marker::PhantomData,
        })
    }

    /// `MPI_Win_free`: collective; completes all epochs, unregisters the
    /// window. Memory is reclaimed when the last handle drops.
    pub fn free(self) -> MpiResult<()> {
        // Release anything this origin still holds (MPI would erroneously
        // abort; we are permissive to keep teardown simple).
        let held: Vec<(usize, LockKind)> =
            self.epochs.borrow().iter().map(|(&t, &k)| (t, k)).collect();
        for (t, k) in held {
            self.flush(t)?;
            self.state.locks[t].release(k);
        }
        self.comm.barrier()?;
        if self.comm.rank() == 0 {
            self.comm.world().windows.write().unwrap().remove(&self.state.id);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Introspection & local access
    // ------------------------------------------------------------------

    /// The communicator this window was created over.
    pub fn comm(&self) -> &Comm {
        &self.comm
    }

    /// Window id (diagnostics).
    pub fn id(&self) -> u64 {
        self.state.id
    }

    /// Size in bytes of `target`'s exposed segment.
    pub fn segment_len(&self, target: usize) -> MpiResult<usize> {
        Ok(self.state.segment(target)?.len)
    }

    /// Copy out of my own segment (the *private copy* — identical to the
    /// public one under the unified memory model).
    pub fn read_local(&self, disp: usize, buf: &mut [u8]) -> MpiResult<()> {
        let src = self.state.check_range(self.comm.rank(), disp, buf.len())?;
        unsafe { std::ptr::copy_nonoverlapping(src, buf.as_mut_ptr(), buf.len()) };
        Ok(())
    }

    /// Copy into my own segment.
    pub fn write_local(&self, disp: usize, buf: &[u8]) -> MpiResult<()> {
        let dst = self.state.check_range(self.comm.rank(), disp, buf.len())?;
        unsafe { std::ptr::copy_nonoverlapping(buf.as_ptr(), dst, buf.len()) };
        Ok(())
    }

    // ------------------------------------------------------------------
    // Passive-target synchronization
    // ------------------------------------------------------------------

    /// `MPI_Win_lock(kind, target)`: start a passive-target access epoch.
    pub fn lock(&self, kind: LockKind, target: usize) -> MpiResult<()> {
        self.state.segment(target)?; // validate target
        let mut epochs = self.epochs.borrow_mut();
        if epochs.contains_key(&target) {
            return Err(MpiErr::EpochAlreadyHeld { win: self.state.id, target });
        }
        self.state.locks[target].acquire(kind);
        epochs.insert(target, kind);
        Ok(())
    }

    /// `MPI_Win_unlock(target)`: complete all operations on `target` and
    /// end the epoch.
    pub fn unlock(&self, target: usize) -> MpiResult<()> {
        let kind = {
            let epochs = self.epochs.borrow();
            *epochs
                .get(&target)
                .ok_or(MpiErr::NoMatchingLock { win: self.state.id, target })?
        };
        self.flush(target)?;
        self.epochs.borrow_mut().remove(&target);
        self.state.locks[target].release(kind);
        Ok(())
    }

    /// `MPI_Win_lock_all`: shared epochs on every target. This is what
    /// DART issues right after every window creation (§IV-B5), so its
    /// one-sided operations never have to manage epochs.
    pub fn lock_all(&self) -> MpiResult<()> {
        for t in 0..self.comm.size() {
            self.lock(LockKind::Shared, t)?;
        }
        Ok(())
    }

    /// `MPI_Win_unlock_all`.
    pub fn unlock_all(&self) -> MpiResult<()> {
        for t in 0..self.comm.size() {
            self.unlock(t)?;
        }
        Ok(())
    }

    /// `MPI_Win_flush(target)`: block until all my outstanding operations
    /// on `target` are complete at the target.
    pub fn flush(&self, target: usize) -> MpiResult<()> {
        let mut latest: Option<Instant> = None;
        self.pending.borrow_mut().retain(|&(t, at)| {
            if t == target {
                latest = Some(latest.map_or(at, |l| l.max(at)));
                false
            } else {
                true
            }
        });
        if let Some(at) = latest {
            self.comm.world().wait_until(at);
        }
        Ok(())
    }

    /// `MPI_Win_flush_all`: complete all outstanding operations.
    pub fn flush_all(&self) -> MpiResult<()> {
        let latest = {
            let mut p = self.pending.borrow_mut();
            let latest = p.iter().map(|&(_, at)| at).max();
            p.clear();
            latest
        };
        if let Some(at) = latest {
            self.comm.world().wait_until(at);
        }
        Ok(())
    }

    fn assert_epoch(&self, target: usize) -> MpiResult<()> {
        if !self.epochs.borrow().contains_key(&target) {
            return Err(MpiErr::NoEpoch { win: self.state.id, target });
        }
        Ok(())
    }

    /// Queue a not-yet-flushed operation's completion instant. When the
    /// list has grown past a handful of entries, first prune those already
    /// in the past — flushes would not wait on them anyway — so workloads
    /// that rely on the progress engine instead of explicit flushes (the
    /// Thread-mode zero-flush pattern) cannot grow it without bound.
    fn push_pending(&self, target: usize, at: Instant) {
        let mut p = self.pending.borrow_mut();
        if p.len() >= 64 {
            let now = Instant::now();
            p.retain(|&(_, a)| a > now);
        }
        p.push((target, at));
    }

    // ------------------------------------------------------------------
    // One-sided communication
    // ------------------------------------------------------------------

    /// `MPI_Put`: transfer `origin` into `target`'s segment at byte
    /// displacement `disp`. Completes locally immediately (eager); remote
    /// completion at the next `flush`/`unlock`. Returns the modelled
    /// wire-completion instant (progress-engine bookkeeping/diagnostics).
    pub fn put(&self, origin: &[u8], target: usize, disp: usize) -> MpiResult<Instant> {
        self.assert_epoch(target)?;
        let dst = self.state.check_range(target, disp, origin.len())?;
        unsafe { std::ptr::copy_nonoverlapping(origin.as_ptr(), dst, origin.len()) };
        let at = self.book(target, origin.len());
        self.push_pending(target, at);
        Ok(at)
    }

    /// `MPI_Get`: transfer from `target`'s segment into `dest`. Returns
    /// the modelled wire-completion instant.
    pub fn get(&self, dest: &mut [u8], target: usize, disp: usize) -> MpiResult<Instant> {
        self.assert_epoch(target)?;
        let src = self.state.check_range(target, disp, dest.len())?;
        unsafe { std::ptr::copy_nonoverlapping(src, dest.as_mut_ptr(), dest.len()) };
        let at = self.book(target, dest.len());
        self.push_pending(target, at);
        Ok(at)
    }

    /// Fused put + flush of that one operation (§Perf): semantically
    /// `put(..); flush(target)` when no other operation is outstanding on
    /// `target`, without touching the pending list. Used by DART's
    /// blocking put.
    pub fn put_flush(&self, origin: &[u8], target: usize, disp: usize) -> MpiResult<()> {
        self.assert_epoch(target)?;
        let dst = self.state.check_range(target, disp, origin.len())?;
        unsafe { std::ptr::copy_nonoverlapping(origin.as_ptr(), dst, origin.len()) };
        let at = self.book(target, origin.len());
        // Earlier unflushed ops on this target still complete first (the
        // channel serializes), but their pending entries stay queued for
        // the next explicit flush.
        self.comm.world().wait_until(at);
        Ok(())
    }

    /// Fused get + flush (§Perf): see [`Win::put_flush`].
    pub fn get_flush(&self, dest: &mut [u8], target: usize, disp: usize) -> MpiResult<()> {
        self.assert_epoch(target)?;
        let src = self.state.check_range(target, disp, dest.len())?;
        unsafe { std::ptr::copy_nonoverlapping(src, dest.as_mut_ptr(), dest.len()) };
        let at = self.book(target, dest.len());
        self.comm.world().wait_until(at);
        Ok(())
    }

    /// `MPI_Rput`: like [`Win::put`] but returns a completion request.
    pub fn rput(&self, origin: &[u8], target: usize, disp: usize) -> MpiResult<RmaRequest> {
        self.assert_epoch(target)?;
        let dst = self.state.check_range(target, disp, origin.len())?;
        unsafe { std::ptr::copy_nonoverlapping(origin.as_ptr(), dst, origin.len()) };
        let at = self.book(target, origin.len());
        Ok(RmaRequest::new(self.comm.world().clone(), at))
    }

    /// `MPI_Rget`: like [`Win::get`] but returns a completion request.
    pub fn rget(&self, dest: &mut [u8], target: usize, disp: usize) -> MpiResult<RmaRequest> {
        self.assert_epoch(target)?;
        let src = self.state.check_range(target, disp, dest.len())?;
        unsafe { std::ptr::copy_nonoverlapping(src, dest.as_mut_ptr(), dest.len()) };
        let at = self.book(target, dest.len());
        Ok(RmaRequest::new(self.comm.world().clone(), at))
    }

    // ------------------------------------------------------------------
    // Vector (strided-datatype) one-sided communication
    // ------------------------------------------------------------------

    /// Scatter/gather setup shared by the vector ops: validate the packed
    /// origin length, bounds-check the full remote extent, and return the
    /// remote base pointer.
    fn vector_base(
        &self,
        target: usize,
        disp: usize,
        origin_len: usize,
        ty: &VectorType,
    ) -> MpiResult<*mut u8> {
        self.assert_epoch(target)?;
        if origin_len != ty.packed_len() {
            return Err(MpiErr::SizeMismatch { local: origin_len, remote: ty.packed_len() });
        }
        self.state.check_range(target, disp, ty.extent())
    }

    /// Scatter the packed `origin` into `count` remote blocks `stride`
    /// bytes apart, booking the whole pattern as **one** message of
    /// `packed_len` bytes — one protocol handshake, not `count`. Returns
    /// the modelled completion instant.
    fn vector_scatter(
        &self,
        origin: &[u8],
        target: usize,
        disp: usize,
        ty: &VectorType,
    ) -> MpiResult<Instant> {
        let base = self.vector_base(target, disp, origin.len(), ty)?;
        for (i, blk) in origin.chunks_exact(ty.block().max(1)).enumerate() {
            unsafe {
                std::ptr::copy_nonoverlapping(blk.as_ptr(), base.add(i * ty.stride()), blk.len())
            };
        }
        Ok(self.book(target, ty.packed_len()))
    }

    /// Gather `count` remote blocks into the packed `dest`; the mirror of
    /// [`Win::vector_scatter`].
    fn vector_gather(
        &self,
        dest: &mut [u8],
        target: usize,
        disp: usize,
        ty: &VectorType,
    ) -> MpiResult<Instant> {
        let base = self.vector_base(target, disp, dest.len(), ty)?;
        for (i, blk) in dest.chunks_exact_mut(ty.block().max(1)).enumerate() {
            unsafe {
                std::ptr::copy_nonoverlapping(base.add(i * ty.stride()), blk.as_mut_ptr(), blk.len())
            };
        }
        Ok(self.book(target, ty.packed_len()))
    }

    /// Vector put (`MPI_Put` with an `MPI_Type_vector` target datatype).
    /// Remote completion at the next `flush`/`unlock`. Returns the modelled
    /// wire-completion instant of the single underlying message.
    pub fn put_vector(
        &self,
        origin: &[u8],
        target: usize,
        disp: usize,
        ty: &VectorType,
    ) -> MpiResult<Instant> {
        let at = self.vector_scatter(origin, target, disp, ty)?;
        self.push_pending(target, at);
        Ok(at)
    }

    /// Vector get: gather `count` remote blocks into the packed `dest`.
    /// Returns the modelled wire-completion instant.
    pub fn get_vector(
        &self,
        dest: &mut [u8],
        target: usize,
        disp: usize,
        ty: &VectorType,
    ) -> MpiResult<Instant> {
        let at = self.vector_gather(dest, target, disp, ty)?;
        self.push_pending(target, at);
        Ok(at)
    }

    /// Request-based vector put (`MPI_Rput` + vector datatype): like
    /// [`Win::put_vector`] but returns a completion request for the single
    /// underlying message.
    pub fn rput_vector(
        &self,
        origin: &[u8],
        target: usize,
        disp: usize,
        ty: &VectorType,
    ) -> MpiResult<RmaRequest> {
        let at = self.vector_scatter(origin, target, disp, ty)?;
        Ok(RmaRequest::new(self.comm.world().clone(), at))
    }

    /// Request-based vector get: the mirror of [`Win::rput_vector`].
    pub fn rget_vector(
        &self,
        dest: &mut [u8],
        target: usize,
        disp: usize,
        ty: &VectorType,
    ) -> MpiResult<RmaRequest> {
        let at = self.vector_gather(dest, target, disp, ty)?;
        Ok(RmaRequest::new(self.comm.world().clone(), at))
    }

    /// `MPI_Accumulate`: element-wise `target := target (op) origin`,
    /// atomically per element w.r.t. other accumulate-family operations
    /// (lock-free CPU atomics — see [`super::atomics`]).
    ///
    /// Like [`Win::put`], the operation is a *deferrable request*: it
    /// completes locally on return (the update is already applied, since
    /// public and private copies coincide in the unified model), joins the
    /// pending list, and reaches remote completion at the next
    /// `flush`/`unlock` — or through the asynchronous progress engine.
    /// Returns the modelled wire-completion instant.
    pub fn accumulate(
        &self,
        origin: &[u8],
        target: usize,
        disp: usize,
        op: MpiOp,
        ty: MpiType,
    ) -> MpiResult<Instant> {
        self.assert_epoch(target)?;
        let dst = self.state.check_range(target, disp, origin.len())?;
        unsafe { atomics::atomic_reduce(op, ty, dst, origin)? };
        let at = self.book(target, origin.len());
        self.push_pending(target, at);
        Ok(at)
    }

    /// `MPI_Get_accumulate`: atomically fetch the target range into
    /// `result` and apply `target := target (op) origin`, element by
    /// element. With [`MpiOp::NoOp`] this is an atomic read of an array.
    pub fn get_accumulate(
        &self,
        origin: &[u8],
        result: &mut [u8],
        target: usize,
        disp: usize,
        op: MpiOp,
        ty: MpiType,
    ) -> MpiResult<()> {
        self.assert_epoch(target)?;
        if origin.len() != result.len() {
            return Err(MpiErr::SizeMismatch { local: origin.len(), remote: result.len() });
        }
        let dst = self.state.check_range(target, disp, origin.len())?;
        unsafe { atomics::atomic_fetch_reduce(op, ty, dst, origin, result)? };
        // Fetch + update: a full round trip, like the scalar atomics.
        let at = self.book(target, origin.len());
        self.comm.world().wait_until(at);
        let at = self.book_reverse(target, origin.len());
        self.comm.world().wait_until(at);
        Ok(())
    }

    // ------------------------------------------------------------------
    // MPI-3 atomics — the primitives under the paper's MCS lock (§IV-B6)
    // ------------------------------------------------------------------

    /// The shared memory side of the scalar atomics: atomically fetch the
    /// element and apply `op` via [`super::atomics`] (no cost booking —
    /// callers model whatever transport they represent).
    fn atomic_fetch_apply<T: HasMpiType + Pod>(
        &self,
        value: T,
        target: usize,
        disp: usize,
        op: MpiOp,
    ) -> MpiResult<T> {
        let n = std::mem::size_of::<T>();
        let dst = self.state.check_range(target, disp, n)?;
        let mut old = [0u8; 8];
        unsafe {
            atomics::atomic_fetch_reduce(
                op,
                T::MPI_TYPE,
                dst,
                as_bytes(std::slice::from_ref(&value)),
                &mut old[..n],
            )?;
        }
        Ok(unsafe { std::ptr::read_unaligned(old.as_ptr() as *const T) })
    }

    /// The shared memory side of compare-and-swap (bitwise comparison,
    /// per the MPI-3 definition).
    fn atomic_cas_apply<T: HasMpiType + Pod>(
        &self,
        compare: T,
        value: T,
        target: usize,
        disp: usize,
    ) -> MpiResult<T> {
        let n = std::mem::size_of::<T>();
        let dst = self.state.check_range(target, disp, n)?;
        let mut old = [0u8; 8];
        unsafe {
            atomics::atomic_cas(
                n,
                dst,
                as_bytes(std::slice::from_ref(&compare)),
                as_bytes(std::slice::from_ref(&value)),
                &mut old[..n],
            )?;
        }
        Ok(unsafe { std::ptr::read_unaligned(old.as_ptr() as *const T) })
    }

    /// `MPI_Fetch_and_op`: atomically `old := target; target := old (op)
    /// value; return old`. With [`MpiOp::Replace`] this is atomic swap
    /// (the paper's `fetch_and_store`); with [`MpiOp::NoOp`] an atomic read.
    ///
    /// Synchronous: the modelled round trip is paid before returning, like
    /// a real fetch-op that must deliver its result.
    pub fn fetch_and_op<T: HasMpiType + Pod>(
        &self,
        value: T,
        target: usize,
        disp: usize,
    ) -> MpiResult<T> {
        self.fetch_and_op_with(value, target, disp, MpiOp::Replace)
    }

    /// `MPI_Fetch_and_op` with an explicit op.
    pub fn fetch_and_op_with<T: HasMpiType + Pod>(
        &self,
        value: T,
        target: usize,
        disp: usize,
        op: MpiOp,
    ) -> MpiResult<T> {
        self.assert_epoch(target)?;
        let old = self.atomic_fetch_apply(value, target, disp, op)?;
        // Round trip: request + response.
        let n = std::mem::size_of::<T>();
        let at = self.book(target, n);
        self.comm.world().wait_until(at);
        let at = self.book_reverse(target, n);
        self.comm.world().wait_until(at);
        Ok(old)
    }

    /// `MPI_Compare_and_swap`: atomically `old := target; if old ==
    /// compare { target := value }; return old` (bitwise comparison).
    pub fn compare_and_swap<T: HasMpiType + Pod + PartialEq>(
        &self,
        compare: T,
        value: T,
        target: usize,
        disp: usize,
    ) -> MpiResult<T> {
        self.assert_epoch(target)?;
        let old = self.atomic_cas_apply(compare, value, target, disp)?;
        let n = std::mem::size_of::<T>();
        let at = self.book(target, n);
        self.comm.world().wait_until(at);
        let at = self.book_reverse(target, n);
        self.comm.world().wait_until(at);
        Ok(old)
    }

    // ------------------------------------------------------------------
    // Same-node direct atomics (shared-memory windows only)
    // ------------------------------------------------------------------

    /// Direct same-node accumulate: the CPU atomic IS the whole operation
    /// — nothing is booked on the channel model and nothing joins the
    /// pending list; the op is complete, locally and remotely, on return.
    /// Callers must have established [`Win::is_shmem_local`]`(target)`.
    /// Bit-identical to [`Win::accumulate`] by construction (same
    /// [`super::atomics`] primitive).
    pub(crate) fn accumulate_direct(
        &self,
        origin: &[u8],
        target: usize,
        disp: usize,
        op: MpiOp,
        ty: MpiType,
    ) -> MpiResult<()> {
        debug_assert!(self.is_shmem_local(target), "accumulate_direct on a non-local target");
        self.assert_epoch(target)?;
        let dst = self.state.check_range(target, disp, origin.len())?;
        unsafe { atomics::atomic_reduce(op, ty, dst, origin) }
    }

    /// Direct same-node fetch-and-op: no modelled round trip. See
    /// [`Win::accumulate_direct`].
    pub(crate) fn fetch_and_op_direct<T: HasMpiType + Pod>(
        &self,
        value: T,
        target: usize,
        disp: usize,
        op: MpiOp,
    ) -> MpiResult<T> {
        debug_assert!(self.is_shmem_local(target), "fetch_and_op_direct on a non-local target");
        self.assert_epoch(target)?;
        self.atomic_fetch_apply(value, target, disp, op)
    }

    /// Direct same-node compare-and-swap: no modelled round trip. See
    /// [`Win::accumulate_direct`].
    pub(crate) fn compare_and_swap_direct<T: HasMpiType + Pod + PartialEq>(
        &self,
        compare: T,
        value: T,
        target: usize,
        disp: usize,
    ) -> MpiResult<T> {
        debug_assert!(
            self.is_shmem_local(target),
            "compare_and_swap_direct on a non-local target"
        );
        self.assert_epoch(target)?;
        self.atomic_cas_apply(compare, value, target, disp)
    }

    // ------------------------------------------------------------------
    // Same-node zero-copy access (shared-memory windows only)
    // ------------------------------------------------------------------

    /// Direct same-node store into `target`'s segment: the memcpy IS the
    /// whole transfer (zero-copy), so nothing is booked on the channel
    /// model and nothing joins the pending list — the operation is
    /// complete, locally and remotely, on return. Callers must have
    /// established [`Win::is_shmem_local`]`(target)`.
    pub(crate) fn store_direct(&self, origin: &[u8], target: usize, disp: usize) -> MpiResult<()> {
        debug_assert!(self.is_shmem_local(target), "store_direct on a non-local target");
        self.assert_epoch(target)?;
        let dst = self.state.check_range(target, disp, origin.len())?;
        unsafe { std::ptr::copy_nonoverlapping(origin.as_ptr(), dst, origin.len()) };
        Ok(())
    }

    /// Direct same-node load from `target`'s segment: the mirror of
    /// [`Win::store_direct`].
    pub(crate) fn load_direct(&self, dest: &mut [u8], target: usize, disp: usize) -> MpiResult<()> {
        debug_assert!(self.is_shmem_local(target), "load_direct on a non-local target");
        self.assert_epoch(target)?;
        let src = self.state.check_range(target, disp, dest.len())?;
        unsafe { std::ptr::copy_nonoverlapping(src, dest.as_mut_ptr(), dest.len()) };
        Ok(())
    }

    // ------------------------------------------------------------------

    /// Is `target` reachable by plain load/store (shared-memory window on
    /// the same modelled node)? This is the criterion the DART engine's
    /// locality fast path keys on (arXiv:1507.04799: same-node peers of an
    /// `MPI_Win_allocate_shared` window address each other's segments
    /// directly).
    #[inline]
    pub(crate) fn is_shmem_local(&self, target: usize) -> bool {
        if !self.state.shmem {
            return false;
        }
        let w = self.comm.world();
        let src = w.placement.coord(self.comm.my_world());
        let dst = w.placement.coord(self.state.comm_ranks[target]);
        src.node == dst.node
    }

    #[inline]
    fn book(&self, target: usize, bytes: usize) -> Instant {
        if self.is_shmem_local(target) {
            // Zero-copy load/store: only the real memcpy is paid (already
            // done by the caller); no protocol cost is modelled.
            return Instant::now();
        }
        let src_w = self.comm.my_world();
        let dst_w = self.state.comm_ranks[target];
        self.comm.world().book_transfer(src_w, dst_w, bytes)
    }

    #[inline]
    fn book_reverse(&self, target: usize, bytes: usize) -> Instant {
        if self.is_shmem_local(target) {
            return Instant::now();
        }
        let src_w = self.comm.my_world();
        let dst_w = self.state.comm_ranks[target];
        self.comm.world().book_transfer(dst_w, src_w, bytes)
    }
}

impl Drop for Win {
    fn drop(&mut self) {
        // Release epochs this origin still holds so a dropped handle can't
        // deadlock other ranks.
        let held: Vec<(usize, LockKind)> =
            self.epochs.borrow().iter().map(|(&t, &k)| (t, k)).collect();
        for (t, k) in held {
            self.state.locks[t].release(k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::{as_bytes, as_bytes_mut, World, WorldConfig};

    #[test]
    fn put_get_roundtrip() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 64).unwrap();
            win.lock_all().unwrap();
            if c.rank() == 0 {
                win.put(b"remote-data", 1, 8).unwrap();
                win.flush(1).unwrap();
            }
            c.barrier().unwrap();
            if c.rank() == 1 {
                let mut buf = [0u8; 11];
                win.read_local(8, &mut buf).unwrap();
                assert_eq!(&buf, b"remote-data");
                // also via self-get
                let mut buf2 = [0u8; 11];
                win.get(&mut buf2, 1, 8).unwrap();
                win.flush(1).unwrap();
                assert_eq!(&buf2, b"remote-data");
            }
            c.barrier().unwrap();
            win.unlock_all().unwrap();
            win.free().unwrap();
        });
    }

    #[test]
    fn rma_requires_epoch() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            let r = win.put(&[1], (c.rank() + 1) % 2, 0);
            assert!(matches!(r, Err(MpiErr::NoEpoch { .. })));
            c.barrier().unwrap();
        });
    }

    #[test]
    fn bounds_checked() {
        World::run(WorldConfig::local(1), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            win.lock_all().unwrap();
            assert!(matches!(
                win.put(&[0u8; 4], 0, 6),
                Err(MpiErr::DispOutOfRange { .. })
            ));
            assert!(win.put(&[0u8; 4], 0, 4).is_ok());
            win.unlock_all().unwrap();
        });
    }

    #[test]
    fn exclusive_lock_excludes() {
        use std::sync::atomic::{AtomicI64, Ordering as AOrd};
        let acc = AtomicI64::new(0);
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            // Everyone hammers rank 0 under an exclusive lock; the final
            // value must equal the op count (no lost updates).
            for _ in 0..50 {
                win.lock(LockKind::Exclusive, 0).unwrap();
                let mut v = [0u8; 8];
                win.get(&mut v, 0, 0).unwrap();
                win.flush(0).unwrap();
                let mut x = i64::from_ne_bytes(v);
                x += 1;
                win.put(&x.to_ne_bytes(), 0, 0).unwrap();
                win.unlock(0).unwrap();
            }
            c.barrier().unwrap();
            if c.rank() == 0 {
                let mut v = [0u8; 8];
                win.read_local(0, &mut v).unwrap();
                acc.store(i64::from_ne_bytes(v), AOrd::SeqCst);
            }
            c.barrier().unwrap();
            win.free().unwrap();
        });
        assert_eq!(acc.load(std::sync::atomic::Ordering::SeqCst), 200);
    }

    #[test]
    fn accumulate_is_atomic() {
        use std::sync::atomic::{AtomicI64, Ordering as AOrd};
        let result = AtomicI64::new(0);
        World::run(WorldConfig::local(8), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            win.lock_all().unwrap();
            for _ in 0..100 {
                win.accumulate(as_bytes(&[1i64]), 0, 0, MpiOp::Sum, MpiType::I64).unwrap();
            }
            win.flush(0).unwrap();
            c.barrier().unwrap();
            if c.rank() == 0 {
                let mut v = [0i64];
                win.read_local(0, as_bytes_mut(&mut v)).unwrap();
                result.store(v[0], AOrd::SeqCst);
            }
            win.unlock_all().unwrap();
            c.barrier().unwrap();
        });
        assert_eq!(result.load(std::sync::atomic::Ordering::SeqCst), 800);
    }

    #[test]
    fn fetch_and_op_swap_is_atomic() {
        // Each rank swaps its id+1 into the slot; every value 0..n must be
        // observed exactly once across all fetch results + the final value.
        let seen = Mutex::new(Vec::new());
        World::run(WorldConfig::local(8), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            win.lock_all().unwrap();
            let old =
                win.fetch_and_op((c.rank() + 1) as i64, 0, 0).unwrap();
            seen.lock().unwrap().push(old);
            c.barrier().unwrap();
            if c.rank() == 0 {
                let mut v = [0i64];
                win.read_local(0, as_bytes_mut(&mut v)).unwrap();
                seen.lock().unwrap().push(v[0]);
            }
            win.unlock_all().unwrap();
            c.barrier().unwrap();
        });
        let mut all = seen.into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..=8).map(|x| x as i64).collect::<Vec<_>>());
    }

    #[test]
    fn compare_and_swap_only_one_wins() {
        use std::sync::atomic::{AtomicUsize, Ordering as AOrd};
        let winners = AtomicUsize::new(0);
        World::run(WorldConfig::local(8), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            win.lock_all().unwrap();
            c.barrier().unwrap();
            let old = win
                .compare_and_swap(0i64, (c.rank() + 1) as i64, 0, 0)
                .unwrap();
            if old == 0 {
                winners.fetch_add(1, AOrd::SeqCst);
            }
            win.unlock_all().unwrap();
            c.barrier().unwrap();
        });
        assert_eq!(winners.load(std::sync::atomic::Ordering::SeqCst), 1);
    }

    #[test]
    fn rput_rget_requests() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 16).unwrap();
            win.lock_all().unwrap();
            if c.rank() == 0 {
                let r = win.rput(&[7u8; 16], 1, 0).unwrap();
                r.wait();
            }
            c.barrier().unwrap();
            if c.rank() == 1 {
                let mut d = [0u8; 16];
                let r = win.rget(&mut d, 1, 0).unwrap();
                r.wait();
                assert_eq!(d, [7u8; 16]);
            }
            win.unlock_all().unwrap();
            c.barrier().unwrap();
        });
    }

    #[test]
    fn vector_put_get_roundtrip() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 64).unwrap();
            win.lock_all().unwrap();
            if c.rank() == 0 {
                // Scatter a column (block 1, stride 8) into rank 1's 8×8
                // matrix as ONE request.
                let col: Vec<u8> = (1..=8).collect();
                let ty = VectorType::new(8, 1, 8).unwrap();
                let r = win.rput_vector(&col, 1, 3, &ty).unwrap();
                r.wait();
            }
            c.barrier().unwrap();
            if c.rank() == 1 {
                let mut mat = [0u8; 64];
                win.read_local(0, &mut mat).unwrap();
                for row in 0..8 {
                    assert_eq!(mat[row * 8 + 3], row as u8 + 1);
                    assert_eq!(mat[row * 8 + 2], 0);
                }
            }
            c.barrier().unwrap();
            if c.rank() == 0 {
                // Gather it back with the pending-list variant + flush.
                let mut col = [0u8; 8];
                let ty = VectorType::new(8, 1, 8).unwrap();
                win.get_vector(&mut col, 1, 3, &ty).unwrap();
                win.flush(1).unwrap();
                assert_eq!(col, [1, 2, 3, 4, 5, 6, 7, 8]);
            }
            c.barrier().unwrap();
            win.unlock_all().unwrap();
            win.free().unwrap();
        });
    }

    #[test]
    fn vector_ops_validate_extent_and_packing() {
        World::run(WorldConfig::local(1), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 64).unwrap();
            win.lock_all().unwrap();
            // Packed-length mismatch.
            let ty = VectorType::new(4, 2, 8).unwrap();
            assert!(matches!(
                win.put_vector(&[0u8; 7], 0, 0, &ty),
                Err(MpiErr::SizeMismatch { .. })
            ));
            // Extent past the segment end: 4 blocks stride 8 from disp 48
            // needs 48 + 3*8 + 2 = 74 > 64.
            assert!(matches!(
                win.put_vector(&[0u8; 8], 0, 48, &ty),
                Err(MpiErr::DispOutOfRange { .. })
            ));
            // Exactly fitting is fine: from disp 38, extent 26 ends at 64.
            assert!(win.put_vector(&[0u8; 8], 0, 38, &ty).is_ok());
            win.unlock_all().unwrap();
        });
    }

    #[test]
    fn vector_books_one_message() {
        // Under the calibrated cost model, N strided blocks as one vector
        // op must book less channel time than N per-block ops (the
        // per-message overhead is paid once).
        let mut cfg = WorldConfig::hermit(2, 2);
        cfg.pin = crate::simnet::PinPolicy::ScatterNode;
        World::run(cfg, |mpi| {
            if mpi.world_rank() != 0 {
                let c = mpi.comm_world();
                let win = Win::allocate(&c, 4096).unwrap();
                win.lock_all().unwrap();
                c.barrier().unwrap();
                c.barrier().unwrap();
                win.unlock_all().unwrap();
                return;
            }
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 4096).unwrap();
            win.lock_all().unwrap();
            c.barrier().unwrap();
            let buf = [7u8; 512];
            let ty = VectorType::new(64, 8, 32).unwrap();
            let t0 = Instant::now();
            let vector_done = win.rput_vector(&buf, 1, 0, &ty).unwrap().complete_at();
            let vector_ns = (vector_done - t0).as_nanos() as i64;
            // Drain the channel before the per-block measurement —
            // otherwise the vector op's serialization slot rides into it
            // and cancels out of the comparison.
            mpi.state().wait_until(vector_done);
            let t1 = Instant::now();
            let mut last = t1;
            for i in 0..64 {
                last = win.rput(&buf[i * 8..(i + 1) * 8], 1, i * 32).unwrap().complete_at();
            }
            let blocks_ns = (last - t1).as_nanos() as i64;
            // 63 saved per-message overheads ≈ 3.8 µs; demand at least half
            // of that so real-clock jitter between the captures can't flake.
            assert!(
                vector_ns + 1900 < blocks_ns,
                "vector {vector_ns}ns not clearly cheaper than per-block {blocks_ns}ns"
            );
            c.barrier().unwrap();
            win.unlock_all().unwrap();
        });
    }

    #[test]
    fn sub_window_aliases_pool() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let pool = Win::allocate(&c, 256).unwrap();
            let sub = pool.create_sub(64, 128).unwrap();
            sub.lock_all().unwrap();
            pool.lock_all().unwrap();
            if c.rank() == 0 {
                sub.put(b"via-sub", 1, 0).unwrap();
                sub.flush(1).unwrap();
            }
            c.barrier().unwrap();
            if c.rank() == 1 {
                // visible through the parent pool at offset 64
                let mut buf = [0u8; 7];
                pool.read_local(64, &mut buf).unwrap();
                assert_eq!(&buf, b"via-sub");
            }
            c.barrier().unwrap();
            pool.unlock_all().unwrap();
            sub.unlock_all().unwrap();
            sub.free().unwrap();
            pool.free().unwrap();
        });
    }

    #[test]
    fn sub_window_out_of_range() {
        World::run(WorldConfig::local(1), |mpi| {
            let c = mpi.comm_world();
            let pool = Win::allocate(&c, 64).unwrap();
            assert!(pool.create_sub(32, 64).is_err());
        });
    }

    #[test]
    fn double_lock_is_error() {
        World::run(WorldConfig::local(1), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            win.lock(LockKind::Shared, 0).unwrap();
            assert!(matches!(
                win.lock(LockKind::Shared, 0),
                Err(MpiErr::EpochAlreadyHeld { .. })
            ));
            win.unlock(0).unwrap();
            assert!(matches!(win.unlock(0), Err(MpiErr::NoMatchingLock { .. })));
        });
    }

    #[test]
    fn get_accumulate_fetches_and_updates() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate(&c, 8).unwrap();
            win.lock_all().unwrap();
            // Everyone atomically adds 1 and fetches the pre-value: the
            // fetched values must be a permutation of 0..4.
            let mut fetched = [0u8; 8];
            win.get_accumulate(as_bytes(&[1i64]), &mut fetched, 0, 0, MpiOp::Sum, MpiType::I64)
                .unwrap();
            let old = i64::from_ne_bytes(fetched);
            assert!((0..4).contains(&old));
            c.barrier().unwrap();
            if c.rank() == 0 {
                let mut v = [0i64];
                win.read_local(0, as_bytes_mut(&mut v)).unwrap();
                assert_eq!(v[0], 4);
            }
            win.unlock_all().unwrap();
            c.barrier().unwrap();
        });
    }

    #[test]
    fn shmem_window_zero_copy_same_node() {
        use crate::simnet::{PinPolicy, Topology};
        use std::time::Instant;
        // Same data path, but same-node transfers through a shared window
        // must be much faster than through a regular window under the
        // Hermit cost model (the §VI future-work claim).
        let time_with = |shared: bool| -> f64 {
            let out = std::sync::Mutex::new(0f64);
            let cfg = WorldConfig {
                nranks: 2,
                topology: Topology::hermit(1),
                pin: PinPolicy::ScatterNuma, // inter-NUMA, same node
                cost: crate::simnet::CostModel::hermit(),
                pin_os_threads: false,
                progress: crate::mpisim::ProgressMode::Caller,
                exec: crate::mpisim::ExecMode::ThreadPerRank,
                max_os_threads: 0,
            };
            World::run(cfg, |mpi| {
                let c = mpi.comm_world();
                let win = if shared {
                    Win::allocate_shared(&c, 4096).unwrap()
                } else {
                    Win::allocate(&c, 4096).unwrap()
                };
                win.lock_all().unwrap();
                c.barrier().unwrap();
                if c.rank() == 0 {
                    let buf = [1u8; 64];
                    let mut best = f64::INFINITY;
                    for _ in 0..40 {
                        let t = Instant::now();
                        win.put(&buf, 1, 0).unwrap();
                        win.flush(1).unwrap();
                        best = best.min(t.elapsed().as_nanos() as f64);
                    }
                    *out.lock().unwrap() = best;
                }
                c.barrier().unwrap();
                win.unlock_all().unwrap();
            });
            out.into_inner().unwrap()
        };
        let regular = time_with(false);
        let shmem = time_with(true);
        assert!(
            shmem < regular / 2.0,
            "shmem window not faster: shmem={shmem}ns regular={regular}ns"
        );
    }

    #[test]
    fn store_load_direct_roundtrip_same_node() {
        use crate::simnet::{PinPolicy, Topology};
        let cfg = WorldConfig {
            nranks: 2,
            topology: Topology::hermit(1),
            pin: PinPolicy::ScatterNuma, // same node, distinct NUMA domains
            cost: crate::simnet::CostModel::hermit(),
            pin_os_threads: false,
            progress: crate::mpisim::ProgressMode::Caller,
            exec: crate::mpisim::ExecMode::ThreadPerRank,
            max_os_threads: 0,
        };
        World::run(cfg, |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate_shared(&c, 64).unwrap();
            win.lock_all().unwrap();
            c.barrier().unwrap();
            if c.rank() == 0 {
                assert!(win.is_shmem_local(1));
                win.store_direct(b"zerocopy", 1, 4).unwrap();
                let mut back = [0u8; 8];
                win.load_direct(&mut back, 1, 4).unwrap();
                assert_eq!(&back, b"zerocopy");
                // Out-of-range is still bounds-checked.
                assert!(matches!(
                    win.store_direct(&[0u8; 8], 1, 60),
                    Err(MpiErr::DispOutOfRange { .. })
                ));
            }
            c.barrier().unwrap();
            if c.rank() == 1 {
                let mut b = [0u8; 8];
                win.read_local(4, &mut b).unwrap();
                assert_eq!(&b, b"zerocopy");
            }
            win.unlock_all().unwrap();
            c.barrier().unwrap();
        });
    }

    #[test]
    fn shmem_window_inter_node_unchanged() {
        use crate::simnet::{PinPolicy, Topology};
        // Across nodes a shared window behaves like a regular one (the
        // messaging protocol still applies).
        let cfg = WorldConfig {
            nranks: 2,
            topology: Topology::hermit(2),
            pin: PinPolicy::ScatterNode,
            cost: crate::simnet::CostModel::hermit(),
            pin_os_threads: false,
            progress: crate::mpisim::ProgressMode::Caller,
            exec: crate::mpisim::ExecMode::ThreadPerRank,
            max_os_threads: 0,
        };
        World::run(cfg, |mpi| {
            let c = mpi.comm_world();
            let win = Win::allocate_shared(&c, 64).unwrap();
            win.lock_all().unwrap();
            c.barrier().unwrap();
            if c.rank() == 0 {
                let t = std::time::Instant::now();
                win.put(&[9u8; 8], 1, 0).unwrap();
                win.flush(1).unwrap();
                // inter-node latency ≈ 1400 ns must still be paid
                assert!(t.elapsed().as_nanos() > 800, "inter-node cost skipped");
            }
            c.barrier().unwrap();
            if c.rank() == 1 {
                let mut b = [0u8; 8];
                win.read_local(0, &mut b).unwrap();
                assert_eq!(b, [9u8; 8]);
            }
            win.unlock_all().unwrap();
            c.barrier().unwrap();
        });
    }

    #[test]
    fn windows_on_subcommunicator() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let sub = c.split(Some((mpi.world_rank() / 2) as i32), 0).unwrap().unwrap();
            let win = Win::allocate(&sub, 8).unwrap();
            win.lock_all().unwrap();
            // rank 0 of each half writes to rank 1 of that half
            if sub.rank() == 0 {
                let v = mpi.world_rank() as u64;
                win.put(&v.to_ne_bytes(), 1, 0).unwrap();
                win.flush(1).unwrap();
            }
            sub.barrier().unwrap();
            if sub.rank() == 1 {
                let mut b = [0u8; 8];
                win.read_local(0, &mut b).unwrap();
                assert_eq!(u64::from_ne_bytes(b), (mpi.world_rank() - 1) as u64);
            }
            win.unlock_all().unwrap();
            sub.barrier().unwrap();
        });
    }
}
