//! Lock-free per-element atomic memory operations — the single engine
//! under both the modelled RMA atomics and the same-node fastpath.
//!
//! MPI-3 guarantees element-wise atomicity among the accumulate family
//! (`MPI_Accumulate`, `MPI_Get_accumulate`, `MPI_Fetch_and_op`,
//! `MPI_Compare_and_swap`). The seed implementation serialized all of
//! them behind one window-wide mutex; that made every atomic a lock
//! acquisition, and — worse — it would have *raced* against any same-node
//! fast path that touched the element with plain CPU atomics (a mutexed
//! non-atomic read-modify-write and a CPU atomic on the same address are
//! a data race). Here both paths funnel through the same primitive: every
//! element is updated by a `compare_exchange_weak` loop (or a single
//! hardware swap/load where the op allows) on the
//! `AtomicU8`/`AtomicU16`/`AtomicU32`/`AtomicU64` overlaying its bytes.
//!
//! Consequences:
//!
//! - atomics from different origins to *different* elements proceed in
//!   parallel — element granularity, like NIC-side atomics on real
//!   hardware — while conflicting ops on the *same* element linearize;
//! - the modelled path and the zero-copy fastpath are the **same** memory
//!   operation, so their results are bit-identical by construction; only
//!   the modelled completion time differs;
//! - the hot path is genuinely lock-free: no mutex anywhere, and the
//!   integer CAS loop degenerates to a single hardware RMW for
//!   `Replace`/`NoOp`.
//!
//! All entry points alignment-check: window segments are 8-byte aligned
//! (see `Segment::owned` in [`super::window`]), so any naturally-aligned
//! displacement is atomically accessible; a misaligned element address is
//! reported as [`MpiErr::Invalid`] instead of silently tearing.

use super::datatype::{reduce_bytes, MpiOp, MpiType};
use super::error::{MpiErr, MpiResult};
use std::sync::atomic::{AtomicU16, AtomicU32, AtomicU64, AtomicU8, Ordering};

/// Define the per-width fetch-apply and compare-and-swap primitives. Both
/// return the element's old bits as the **first `size_of::<$uint>()`
/// bytes** of a `[u8; 8]`, which keeps the callers endianness-agnostic.
macro_rules! def_width {
    ($rmw:ident, $cas:ident, $uint:ty, $atomic:ty) => {
        /// Atomically `old := *p; *p := old (op) src; return old` for one
        /// element.
        ///
        /// # Safety
        /// `p` must point at a valid, naturally-aligned element inside a
        /// registered window segment.
        unsafe fn $rmw(p: *mut u8, op: MpiOp, ty: MpiType, src: &[u8]) -> MpiResult<[u8; 8]> {
            const N: usize = std::mem::size_of::<$uint>();
            let a = unsafe { &*(p as *const $atomic) };
            let old: $uint = match op {
                // Pure atomic read / pure atomic swap: one hardware op.
                MpiOp::NoOp => a.load(Ordering::SeqCst),
                MpiOp::Replace => {
                    a.swap(<$uint>::from_ne_bytes(src.try_into().unwrap()), Ordering::SeqCst)
                }
                // Everything else: CAS loop. The arithmetic (`reduce_bytes`)
                // is the same routine the non-atomic reduce paths use, so
                // results match them bit-for-bit.
                _ => {
                    let mut cur = a.load(Ordering::SeqCst);
                    loop {
                        let mut acc = cur.to_ne_bytes();
                        reduce_bytes(op, ty, &mut acc, src)?;
                        let new = <$uint>::from_ne_bytes(acc);
                        match a.compare_exchange_weak(cur, new, Ordering::SeqCst, Ordering::SeqCst)
                        {
                            Ok(_) => break cur,
                            Err(seen) => cur = seen,
                        }
                    }
                }
            };
            let mut out = [0u8; 8];
            out[..N].copy_from_slice(&old.to_ne_bytes());
            Ok(out)
        }

        /// Atomically `old := *p; if old == compare { *p := value };
        /// return old` for one element (bitwise comparison, like
        /// `MPI_Compare_and_swap`).
        ///
        /// # Safety
        /// Same contract as the fetch-apply variant.
        unsafe fn $cas(p: *mut u8, compare: &[u8], value: &[u8]) -> [u8; 8] {
            const N: usize = std::mem::size_of::<$uint>();
            let a = unsafe { &*(p as *const $atomic) };
            let cmp = <$uint>::from_ne_bytes(compare.try_into().unwrap());
            let val = <$uint>::from_ne_bytes(value.try_into().unwrap());
            let old = match a.compare_exchange(cmp, val, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(old) | Err(old) => old,
            };
            let mut out = [0u8; 8];
            out[..N].copy_from_slice(&old.to_ne_bytes());
            out
        }
    };
}

def_width!(rmw1, cas1, u8, AtomicU8);
def_width!(rmw2, cas2, u16, AtomicU16);
def_width!(rmw4, cas4, u32, AtomicU32);
def_width!(rmw8, cas8, u64, AtomicU64);

/// Validate that `p` can be accessed as one atomic element of `n` bytes.
#[inline]
fn check_element(p: *const u8, n: usize) -> MpiResult<()> {
    if !matches!(n, 1 | 2 | 4 | 8) {
        return Err(MpiErr::Invalid(format!("unsupported atomic element size {n}")));
    }
    if (p as usize) % n != 0 {
        return Err(MpiErr::Invalid(format!(
            "atomic access to misaligned address {p:p} (element size {n})"
        )));
    }
    Ok(())
}

/// Fetch-and-apply ONE element of `n` bytes at `p`; old bits land in the
/// first `n` bytes of the result.
///
/// # Safety
/// `p` must point at `n` valid bytes inside a registered window segment,
/// aligned to `n` (checked by the callers via [`check_element`]).
#[inline]
unsafe fn rmw_elem(n: usize, p: *mut u8, op: MpiOp, ty: MpiType, src: &[u8]) -> MpiResult<[u8; 8]> {
    match n {
        1 => unsafe { rmw1(p, op, ty, src) },
        2 => unsafe { rmw2(p, op, ty, src) },
        4 => unsafe { rmw4(p, op, ty, src) },
        _ => unsafe { rmw8(p, op, ty, src) },
    }
}

/// Element-wise atomic `dst := dst (op) src` over `src.len() / ty.size()`
/// consecutive elements — the memory side of `MPI_Accumulate`.
///
/// # Safety
/// `dst` must point at `src.len()` valid bytes inside a registered window
/// segment. Concurrent access to those bytes must go through this module
/// (which is exactly what the window's accumulate-family ops guarantee).
pub(crate) unsafe fn atomic_reduce(
    op: MpiOp,
    ty: MpiType,
    dst: *mut u8,
    src: &[u8],
) -> MpiResult<()> {
    let n = ty.size();
    if src.len() % n != 0 {
        return Err(MpiErr::SizeMismatch { local: src.len(), remote: src.len() / n * n });
    }
    check_element(dst, n)?;
    for (i, elem) in src.chunks_exact(n).enumerate() {
        unsafe { rmw_elem(n, dst.add(i * n), op, ty, elem)? };
    }
    Ok(())
}

/// Element-wise atomic fetch-then-apply: each element's pre-update value
/// lands in `result`, then `dst := dst (op) src` — the memory side of
/// `MPI_Get_accumulate` / `MPI_Fetch_and_op`.
///
/// # Safety
/// Same contract as [`atomic_reduce`]; `result` must be `src.len()` bytes.
pub(crate) unsafe fn atomic_fetch_reduce(
    op: MpiOp,
    ty: MpiType,
    dst: *mut u8,
    src: &[u8],
    result: &mut [u8],
) -> MpiResult<()> {
    let n = ty.size();
    if src.len() != result.len() || src.len() % n != 0 {
        return Err(MpiErr::SizeMismatch { local: result.len(), remote: src.len() });
    }
    check_element(dst, n)?;
    for (i, (elem, out)) in src.chunks_exact(n).zip(result.chunks_exact_mut(n)).enumerate() {
        let old = unsafe { rmw_elem(n, dst.add(i * n), op, ty, elem)? };
        out.copy_from_slice(&old[..n]);
    }
    Ok(())
}

/// Atomic compare-and-swap of ONE `n`-byte element (bitwise comparison);
/// the old bits land in `old_out` — the memory side of
/// `MPI_Compare_and_swap`.
///
/// # Safety
/// `dst` must point at `n` valid bytes inside a registered window segment,
/// with the same concurrent-access contract as [`atomic_reduce`].
pub(crate) unsafe fn atomic_cas(
    n: usize,
    dst: *mut u8,
    compare: &[u8],
    value: &[u8],
    old_out: &mut [u8],
) -> MpiResult<()> {
    if compare.len() != n || value.len() != n || old_out.len() != n {
        return Err(MpiErr::SizeMismatch { local: old_out.len(), remote: n });
    }
    check_element(dst, n)?;
    let old = match n {
        1 => unsafe { cas1(dst, compare, value) },
        2 => unsafe { cas2(dst, compare, value) },
        4 => unsafe { cas4(dst, compare, value) },
        _ => unsafe { cas8(dst, compare, value) },
    };
    old_out.copy_from_slice(&old[..n]);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn misaligned_element_rejected() {
        let mut buf = [0u8; 16];
        let base = buf.as_mut_ptr();
        // Find an address that is NOT 8-aligned within the buffer.
        let off = if (base as usize) % 8 == 0 { 4 } else { 0 };
        let r = unsafe {
            atomic_reduce(MpiOp::Sum, MpiType::U64, base.add(off + 1), &1u64.to_ne_bytes())
        };
        assert!(matches!(r, Err(MpiErr::Invalid(_))));
    }

    #[test]
    fn concurrent_sums_are_exact() {
        // 8 threads × 10_000 fetch-adds on one u64: the CAS loop must not
        // lose a single update.
        let mut word = vec![0u64; 1];
        let p = word.as_mut_ptr() as usize;
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(move || {
                    for _ in 0..10_000 {
                        unsafe {
                            atomic_reduce(
                                MpiOp::Sum,
                                MpiType::U64,
                                p as *mut u8,
                                &1u64.to_ne_bytes(),
                            )
                            .unwrap();
                        }
                    }
                });
            }
        });
        assert_eq!(word[0], 80_000);
    }

    #[test]
    fn fetch_reduce_returns_old_values() {
        let mut word = vec![5u32; 1];
        let mut old = [0u8; 4];
        unsafe {
            atomic_fetch_reduce(
                MpiOp::Sum,
                MpiType::U32,
                word.as_mut_ptr() as *mut u8,
                &7u32.to_ne_bytes(),
                &mut old,
            )
            .unwrap();
        }
        assert_eq!(u32::from_ne_bytes(old), 5);
        assert_eq!(word[0], 12);
    }

    #[test]
    fn cas_swaps_only_on_match() {
        let mut word = vec![3u64; 1];
        let p = word.as_mut_ptr() as *mut u8;
        let mut old = [0u8; 8];
        unsafe {
            atomic_cas(8, p, &9u64.to_ne_bytes(), &1u64.to_ne_bytes(), &mut old).unwrap();
        }
        assert_eq!((u64::from_ne_bytes(old), word[0]), (3, 3)); // no match
        unsafe {
            atomic_cas(8, p, &3u64.to_ne_bytes(), &1u64.to_ne_bytes(), &mut old).unwrap();
        }
        assert_eq!((u64::from_ne_bytes(old), word[0]), (3, 1)); // swapped
    }

    #[test]
    fn exactly_one_cas_winner_under_contention() {
        use std::sync::atomic::{AtomicUsize, Ordering as AOrd};
        let mut word = vec![0u64; 1];
        let p = word.as_mut_ptr() as usize;
        let winners = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for t in 1..=8u64 {
                let winners = &winners;
                s.spawn(move || {
                    let mut old = [0u8; 8];
                    unsafe {
                        atomic_cas(
                            8,
                            p as *mut u8,
                            &0u64.to_ne_bytes(),
                            &t.to_ne_bytes(),
                            &mut old,
                        )
                        .unwrap();
                    }
                    if u64::from_ne_bytes(old) == 0 {
                        winners.fetch_add(1, AOrd::SeqCst);
                    }
                });
            }
        });
        assert_eq!(winners.load(AOrd::SeqCst), 1);
    }

    #[test]
    fn multi_element_accumulate_is_element_granular() {
        // 4 threads each add a distinct pattern over 64 u32 elements; every
        // element must end at the exact sum of the four patterns.
        let mut arr = vec![0u32; 64];
        let p = arr.as_mut_ptr() as usize;
        std::thread::scope(|s| {
            for t in 1..=4u32 {
                s.spawn(move || {
                    let src: Vec<u8> =
                        (0..64u32).flat_map(|i| (t * 1000 + i).to_ne_bytes()).collect();
                    for _ in 0..100 {
                        unsafe {
                            atomic_reduce(MpiOp::Sum, MpiType::U32, p as *mut u8, &src).unwrap();
                        }
                    }
                });
            }
        });
        for (i, &v) in arr.iter().enumerate() {
            let expect = 100 * (1..=4u32).map(|t| t * 1000 + i as u32).sum::<u32>();
            assert_eq!(v, expect, "element {i}");
        }
    }

    #[test]
    fn float_sum_matches_sequential_apply() {
        // Bit-equality with the non-atomic reduce path on the same operand
        // order (single thread → deterministic order).
        let mut a = vec![1.5f64; 1];
        let mut b = 1.5f64;
        for i in 0..100 {
            let x = (i as f64) * 0.75;
            unsafe {
                atomic_reduce(MpiOp::Sum, MpiType::F64, a.as_mut_ptr() as *mut u8, &x.to_ne_bytes())
                    .unwrap();
            }
            let mut acc = b.to_ne_bytes();
            reduce_bytes(MpiOp::Sum, MpiType::F64, &mut acc, &x.to_ne_bytes()).unwrap();
            b = f64::from_ne_bytes(acc);
        }
        assert_eq!(a[0].to_bits(), b.to_bits());
    }
}
