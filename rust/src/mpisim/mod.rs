//! An MPI-3 subset implemented from scratch over OS threads + shared memory.
//!
//! This module plays the role Cray MPICH played in the paper: the
//! communication substrate underneath the DART runtime. It implements the
//! parts of MPI-3 that DART-MPI consumes, with the semantics the paper
//! leans on:
//!
//! - **ranks** are OS threads inside one process ([`World::run`] spawns one
//!   thread per rank and hands each a rank-local [`Mpi`] handle);
//! - **two-sided p2p** with tags, `MPI_ANY_SOURCE`/`MPI_ANY_TAG` matching
//!   and non-overtaking delivery ([`p2p`]);
//! - **groups** with MPI's relative-rank, order-sensitive semantics —
//!   including the append-without-sort `MPI_Group_union` behaviour the
//!   paper works around ([`group`]);
//! - **communicators** with isolated contexts, `split`/`create` ([`comm`]);
//! - **collectives**: barrier, bcast, gather(v), scatter, allgather,
//!   reduce, allreduce, alltoall, scan ([`collectives`]);
//! - **RMA windows** (collective allocate, sub-windows over reserved pools,
//!   dynamic attach), passive-target **lock/unlock/lock_all** with
//!   shared/exclusive epochs, **put/get/accumulate**, request-based
//!   **rput/rget**, **flush**, and the MPI-3 atomics **fetch_and_op** /
//!   **compare_and_swap** ([`window`]);
//! - the **RMA unified memory model** (§IV-A): public and private copies
//!   coincide because ranks share one address space;
//! - **nonblocking collectives** (`ibarrier`/`ibcast`/`iallgather`/
//!   `iallreduce`) as progress-engine state machines ([`icoll`]), and the
//!   **asynchronous progress engine** itself ([`progress`]) with its
//!   Caller/Thread/Polling modes ([`ProgressMode`]).
//!
//! Network behaviour is injected by [`crate::simnet::CostModel`] through a
//! virtual-time channel model ([`WorldState::book_transfer`]): every
//! directed rank pair owns a channel whose serialization (bandwidth + the
//! E1 bounce-buffer copy) occupies the channel, while wire latency
//! pipelines. Blocking operations spin until the modelled completion
//! instant; request-based operations carry it in their handle.

pub mod atomics;
pub mod collectives;
pub mod comm;
pub mod dynwin;
pub mod datatype;
pub mod error;
pub mod group;
pub mod icoll;
pub mod p2p;
pub mod progress;
pub mod request;
pub mod window;

pub use comm::Comm;
pub use dynwin::DynWin;
pub use datatype::{as_bytes, as_bytes_mut, HasMpiType, MpiOp, MpiType, Pod, VectorType};
pub use error::{MpiErr, MpiResult};
pub use group::Group;
pub use icoll::CollRequest;
pub use p2p::{Status, ANY_SOURCE, ANY_TAG};
pub use progress::ProgressMode;
pub use request::{RecvRequest, RmaRequest, SendRequest};
pub use window::{LockKind, Win};

use crate::simnet::faults::{FaultEvent, FaultPlan, FaultState, FaultStats};
use crate::simnet::{CostModel, PinPolicy, Placement, RunGate, Tier, Topology};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// How rank tasks are scheduled onto OS threads (see
/// [`crate::simnet::exec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// One freely runnable OS thread per rank — the compatibility default,
    /// right for worlds up to a few dozen ranks.
    #[default]
    ThreadPerRank,
    /// Bounded-concurrency execution: every rank still owns a (mostly
    /// kernel-parked) carrier thread for its blocked SPMD state, but at
    /// most [`WorldConfig::max_os_threads`] of them are runnable at any
    /// instant. This is what makes 1024+-rank worlds complete in wall-clock
    /// seconds instead of thrashing the scheduler.
    Pooled,
}

impl ExecMode {
    /// Short label used by bench output and JSON.
    pub fn label(&self) -> &'static str {
        match self {
            ExecMode::ThreadPerRank => "thread-per-rank",
            ExecMode::Pooled => "pooled",
        }
    }
}

/// Configuration for a simulated MPI world.
#[derive(Clone)]
pub struct WorldConfig {
    /// Number of ranks (= spawned threads).
    pub nranks: usize,
    /// Modelled cluster topology.
    pub topology: Topology,
    /// Rank → core placement policy.
    pub pin: PinPolicy,
    /// Network cost model (use [`CostModel::zero`] to disable injection).
    pub cost: CostModel,
    /// Also pin the OS threads to real cores (best effort).
    pub pin_os_threads: bool,
    /// Who drives asynchronous communication progress (see
    /// [`progress::ProgressMode`]); `Thread` spawns one background service
    /// thread per [`World::run`].
    pub progress: ProgressMode,
    /// Rank-task scheduling mode ([`ExecMode::ThreadPerRank`] by default).
    pub exec: ExecMode,
    /// Bound on concurrently *runnable* rank threads in
    /// [`ExecMode::Pooled`]; `0` means the machine's available parallelism.
    /// Ignored in thread-per-rank mode.
    pub max_os_threads: usize,
    /// Seeded deterministic fault injection ([`crate::simnet::faults`]):
    /// `None` (the default) runs a friendly world; `Some(plan)` injects
    /// message jitter, slow channels, completion reordering, starved
    /// progress ticks and straggler nodes — every event a pure function of
    /// the plan's seed.
    pub faults: Option<FaultPlan>,
}

impl WorldConfig {
    /// `nranks` ranks on a flat single-NUMA topology with no cost injection
    /// — the configuration unit tests use.
    pub fn local(nranks: usize) -> Self {
        WorldConfig {
            nranks,
            topology: Topology::flat(nranks.max(1)),
            pin: PinPolicy::Block,
            cost: CostModel::zero(),
            pin_os_threads: false,
            progress: ProgressMode::Caller,
            exec: ExecMode::ThreadPerRank,
            max_os_threads: 0,
            faults: None,
        }
    }

    /// `nranks` ranks block-placed on a Hermit-like cluster with the
    /// calibrated cost model.
    pub fn hermit(nranks: usize, nodes: usize) -> Self {
        WorldConfig {
            nranks,
            topology: Topology::hermit(nodes),
            pin: PinPolicy::Block,
            cost: CostModel::hermit(),
            pin_os_threads: false,
            progress: ProgressMode::Caller,
            exec: ExecMode::ThreadPerRank,
            max_os_threads: 0,
            faults: None,
        }
    }

    /// The effective run-slot bound: `max_os_threads`, defaulting to the
    /// machine's available parallelism when 0.
    pub fn effective_max_os_threads(&self) -> usize {
        if self.max_os_threads > 0 {
            self.max_os_threads
        } else {
            std::thread::available_parallelism().map_or(4, |n| n.get())
        }
    }
}

/// Lock shards of the lazily-populated channel table: enough to keep
/// contention negligible, few enough that an idle world costs nothing.
const CHANNEL_SHARDS: usize = 64;

/// Per-directed-pair channel state: the instant until which the pair's
/// serialization stage is occupied, and a message sequence number — the
/// stable per-channel key the fault layer's per-message jitter decisions
/// hash (program order on the booking thread, so seeded decisions replay).
struct Chan {
    busy: Instant,
    seq: u64,
}

/// Globally shared world state (one per [`World::run`] call).
pub struct WorldState {
    pub(crate) nranks: usize,
    pub(crate) placement: Placement,
    pub(crate) cost: CostModel,
    pub(crate) mailboxes: Vec<p2p::Mailbox>,
    pub(crate) windows: RwLock<HashMap<u64, Arc<window::WinState>>>,
    pub(crate) next_win_id: AtomicU64,
    pub(crate) next_context_id: AtomicU32,
    /// Directed-pair virtual-time channels, keyed `src * nranks + dst` and
    /// populated on first use — memory is O(active pairs), not O(nranks²),
    /// which is what lets 4096-rank worlds exist at all.
    channels: Vec<Mutex<HashMap<u64, Chan>>>,
    /// Live fault-injection state (`None` in a friendly world).
    faults: Option<FaultState>,
    /// Run-slot gate of the pooled execution mode (`None` in
    /// thread-per-rank mode).
    exec_gate: Option<Arc<RunGate>>,
    /// Modelled transfers whose endpoints sit on different nodes — the
    /// interconnect-crossing count the scale bench uses to show the
    /// hierarchical collectives' shrinking cross-node footprint.
    inter_node_msgs: AtomicU64,
    /// Asynchronous progress engine state (see [`progress`]).
    pub(crate) progress: progress::ProgressShared,
    pub(crate) finalized: AtomicBool,
}

impl WorldState {
    fn new(cfg: &WorldConfig) -> Arc<Self> {
        let placement = Placement::new(cfg.topology, cfg.nranks, &cfg.pin);
        let exec_gate = match cfg.exec {
            ExecMode::ThreadPerRank => None,
            ExecMode::Pooled => Some(Arc::new(RunGate::new(cfg.effective_max_os_threads()))),
        };
        Arc::new(WorldState {
            nranks: cfg.nranks,
            placement,
            cost: cfg.cost,
            mailboxes: (0..cfg.nranks).map(|_| p2p::Mailbox::new()).collect(),
            windows: RwLock::new(HashMap::new()),
            next_win_id: AtomicU64::new(1),
            next_context_id: AtomicU32::new(1),
            channels: (0..CHANNEL_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            faults: cfg.faults.map(|plan| FaultState::new(plan, cfg.topology.nodes)),
            exec_gate,
            inter_node_msgs: AtomicU64::new(0),
            progress: progress::ProgressShared::new(cfg.nranks),
            finalized: AtomicBool::new(false),
        })
    }

    /// Placement tier between two world ranks.
    #[inline]
    pub fn tier(&self, src: usize, dst: usize) -> Tier {
        self.placement.tier(src, dst)
    }

    /// Shard index of a directed-pair channel key (Fibonacci hash: the
    /// keys are dense small integers, so the multiply spreads adjacent
    /// pairs across shards).
    #[inline]
    fn channel_shard(key: u64) -> usize {
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 58) as usize % CHANNEL_SHARDS
    }

    /// Number of directed rank pairs that have ever communicated — the
    /// channel table's population (diagnostics; the scale test asserts it
    /// stays far below `nranks²` under logarithmic collectives).
    pub fn active_channels(&self) -> usize {
        self.channels.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// `(slot limit, peak concurrently runnable ranks)` of the pooled
    /// execution gate, or `None` in thread-per-rank mode. The peak is what
    /// the scale smoke test asserts stays at or below the configured bound.
    pub fn exec_gate_stats(&self) -> Option<(usize, usize)> {
        self.exec_gate.as_ref().map(|g| (g.limit(), g.peak_active()))
    }

    /// Total modelled transfers that crossed a node boundary since launch
    /// (diagnostics; deterministic, so the scale bench can assert on it).
    pub fn inter_node_messages(&self) -> u64 {
        self.inter_node_msgs.load(Ordering::Relaxed)
    }

    /// Book a `bytes`-sized transfer on the `src → dst` channel and return
    /// the modelled completion instant.
    ///
    /// Serialization time (bandwidth term plus, above the eager limit, the
    /// E1 double bounce-buffer copy) occupies the channel — back-to-back
    /// transfers queue up behind each other — while the tier's base latency
    /// pipelines (it is added after the serialization slot, so overlapped
    /// transfers pay it only once in aggregate).
    pub fn book_transfer(&self, src: usize, dst: usize, bytes: usize) -> Instant {
        self.book_transfer_after(src, dst, bytes, Instant::now())
    }

    /// [`WorldState::book_transfer`] with an earliest-start bound: the
    /// serialization slot begins no earlier than `not_before`. This is what
    /// lets the nonblocking-collective schedules ([`icoll`]) model
    /// logarithmic trees — a child's hop cannot start before its parent's
    /// hop delivered.
    pub(crate) fn book_transfer_after(
        &self,
        src: usize,
        dst: usize,
        bytes: usize,
        not_before: Instant,
    ) -> Instant {
        let now = Instant::now();
        let base = if not_before > now { not_before } else { now };
        if src == dst || (self.cost.scale <= 0.0 && self.faults.is_none()) {
            return base;
        }
        let tier = self.tier(src, dst);
        if tier == Tier::InterNode {
            self.inter_node_msgs.fetch_add(1, Ordering::Relaxed);
        }
        let tc = &self.cost.tiers[tier as usize];
        // Per-message protocol overhead + bandwidth term occupy the
        // channel; the tier's base latency pipelines (added below, after
        // the serialization slot).
        let mut serialize_ns = self.cost.msg_overhead_ns + bytes as f64 / tc.bytes_per_ns;
        if bytes > self.cost.eager_e0_limit {
            serialize_ns +=
                self.cost.e1_latency_ns + 2.0 * bytes as f64 / self.cost.e1_copy_bytes_per_ns;
        }
        serialize_ns *= self.cost.scale;
        let mut latency_ns = tc.latency_ns * self.cost.scale;
        let key = (src * self.nranks + dst) as u64;
        // Fault injection, stage 1 (seq-independent): a persistently slow
        // channel and/or a straggler endpoint multiply the modelled times.
        if let Some(fs) = &self.faults {
            let mut factor = 1.0f64;
            if let Some(f) = fs.plan.channel_slowdown(key) {
                factor *= f;
                fs.note_slow_channel_msg();
            }
            if fs.is_straggler(self.placement.node_of(src))
                || fs.is_straggler(self.placement.node_of(dst))
            {
                factor *= fs.plan.straggler_factor;
                fs.note_straggler_msg();
            }
            serialize_ns *= factor;
            latency_ns *= factor;
        }
        let mut shard = self.channels[Self::channel_shard(key)].lock().unwrap();
        let chan = shard.entry(key).or_insert(Chan { busy: base, seq: 0 });
        let msg_seq = chan.seq;
        chan.seq += 1;
        // Fault injection, stage 2 (under the shard lock, which owns the
        // per-channel message sequence): per-message jitter. Jitter is
        // *unscaled* modelled time, so a fault plan stays adversarial over
        // a zero-cost model.
        let mut jitter_ns = 0u64;
        if let Some(fs) = &self.faults {
            if let Some(j) = fs.plan.jitter_ns(key, msg_seq) {
                jitter_ns = j;
                fs.note_jitter(key, msg_seq, j);
            }
        }
        let serialize = Duration::from_nanos(serialize_ns as u64 + jitter_ns);
        let latency = Duration::from_nanos(latency_ns as u64);
        let start = if chan.busy > base { chan.busy } else { base };
        let done = start + serialize;
        chan.busy = done;
        drop(shard);
        done + latency
    }

    /// Snapshot of the world's injected-fault counters (all zero when no
    /// [`WorldConfig::faults`] plan is configured).
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.as_ref().map(|f| f.snapshot()).unwrap_or_default()
    }

    /// The recorded dynamic fault events in canonical (class/key/seq)
    /// order — the determinism oracle: two runs of the same seeded
    /// scenario must return identical traces. Empty without a fault plan.
    pub fn fault_trace(&self) -> Vec<FaultEvent> {
        self.faults.as_ref().map(|f| f.trace()).unwrap_or_default()
    }

    /// The configured fault plan, if any.
    pub fn fault_plan(&self) -> Option<FaultPlan> {
        self.faults.as_ref().map(|f| f.plan)
    }

    /// Crate-internal access for the progress engine's hooks.
    pub(crate) fn fault_state(&self) -> Option<&FaultState> {
        self.faults.as_ref()
    }

    /// Wait until `t` has passed (no-op if already past). Yield-aware: see
    /// [`crate::simnet::cost::spin_for`].
    #[inline]
    pub fn wait_until(&self, t: Instant) {
        let now = Instant::now();
        if t > now {
            crate::simnet::cost::spin_for(t - now);
        }
    }
}

/// Rank-local MPI handle, one per spawned thread. Not `Send`: like a real
/// MPI rank, it belongs to the thread it was created on.
pub struct Mpi {
    pub(crate) world: Arc<WorldState>,
    pub(crate) rank: usize,
    _not_send: std::marker::PhantomData<*const ()>,
}

impl Mpi {
    /// This rank's index in `MPI_COMM_WORLD`.
    #[inline]
    pub fn world_rank(&self) -> usize {
        self.rank
    }

    /// Total number of ranks.
    #[inline]
    pub fn world_size(&self) -> usize {
        self.world.nranks
    }

    /// The world communicator (`MPI_COMM_WORLD`).
    pub fn comm_world(&self) -> Comm {
        Comm::new_world(self.world.clone(), self.rank)
    }

    /// The group of `MPI_COMM_WORLD`.
    pub fn group_world(&self) -> Group {
        Group::new((0..self.world.nranks).collect())
    }

    /// Shared world state (used by the DART layer).
    pub fn state(&self) -> &Arc<WorldState> {
        &self.world
    }
}

/// Entry point: spawn `cfg.nranks` threads, run `f(mpi)` on each (SPMD),
/// join them all, and propagate the first panic if any.
pub struct World;

impl World {
    /// Run one simulated MPI world: spawn `cfg.nranks` rank threads, run
    /// `f(mpi)` on each, join them all. In
    /// [`ProgressMode::Thread`] an additional background
    /// progress-service thread runs for the duration of the world (stopped
    /// and joined on exit, including on panic unwind).
    pub fn run<F>(cfg: WorldConfig, f: F)
    where
        F: Fn(Mpi) + Send + Sync,
    {
        assert!(cfg.nranks > 0, "world must have at least one rank");
        let state = WorldState::new(&cfg);
        // Thread-mode asynchronous progress: start the service before the
        // ranks; the guard stops it when dropped (also during unwind).
        let _progress_guard = match cfg.progress {
            ProgressMode::Thread => Some(progress::ProgressThreadGuard::spawn(state.clone())),
            _ => None,
        };
        let f = Arc::new(f);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(cfg.nranks);
            for rank in 0..cfg.nranks {
                let state = state.clone();
                let f = f.clone();
                let pin_os = cfg.pin_os_threads;
                let coord = state.placement.coord(rank);
                let topo = cfg.topology;
                let builder = std::thread::Builder::new().name(format!("mpi-rank-{rank}"));
                handles.push(
                    builder
                        .spawn_scoped(s, move || {
                            if pin_os {
                                crate::simnet::pin_current_thread(topo.index_of(coord));
                            }
                            // Pooled mode: hold a run slot for the rank's
                            // lifetime (released around kernel parks and
                            // rotated at spin-yield points — see
                            // `simnet::exec`). Thread-per-rank: no gate.
                            let _slot = state
                                .exec_gate
                                .clone()
                                .map(crate::simnet::exec::enter);
                            let mpi = Mpi {
                                world: state,
                                rank,
                                _not_send: std::marker::PhantomData,
                            };
                            f(mpi);
                        })
                        .expect("spawn rank thread"),
                );
            }
            for h in handles {
                if let Err(e) = h.join() {
                    std::panic::resume_unwind(e);
                }
            }
        });
        state.finalized.store(true, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn world_runs_all_ranks() {
        let counter = AtomicUsize::new(0);
        World::run(WorldConfig::local(7), |mpi| {
            assert_eq!(mpi.world_size(), 7);
            counter.fetch_add(1 + mpi.world_rank(), Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), (1..=7).sum());
    }

    #[test]
    fn ranks_have_distinct_ids() {
        let seen = Mutex::new(vec![false; 5]);
        World::run(WorldConfig::local(5), |mpi| {
            let mut s = seen.lock().unwrap();
            assert!(!s[mpi.world_rank()]);
            s[mpi.world_rank()] = true;
        });
        assert!(seen.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn book_transfer_zero_cost_is_now() {
        World::run(WorldConfig::local(2), |mpi| {
            let t = mpi.state().book_transfer(0, 1, 1 << 20);
            assert!(t <= Instant::now());
        });
    }

    #[test]
    fn book_transfer_serializes_channel() {
        let mut cfg = WorldConfig::hermit(2, 1);
        cfg.cost.scale = 1.0;
        World::run(cfg, |mpi| {
            if mpi.world_rank() == 0 {
                let a = mpi.state().book_transfer(0, 1, 1 << 16);
                let b = mpi.state().book_transfer(0, 1, 1 << 16);
                assert!(b > a, "second transfer must queue behind the first");
            }
        });
    }

    #[test]
    fn pooled_world_runs_all_ranks_within_bound() {
        let mut cfg = WorldConfig::local(32);
        cfg.exec = ExecMode::Pooled;
        cfg.max_os_threads = 4;
        let counter = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        World::run(cfg, |mpi| {
            counter.fetch_add(1, Ordering::SeqCst);
            mpi.comm_world().barrier().unwrap();
            if mpi.world_rank() == 0 {
                let (limit, p) = mpi.state().exec_gate_stats().expect("pooled gate");
                assert_eq!(limit, 4);
                peak.store(p, Ordering::SeqCst);
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 32);
        let p = peak.load(Ordering::SeqCst);
        assert!(p >= 1 && p <= 4, "peak runnable {p} out of [1, 4]");
    }

    #[test]
    fn lazy_channels_only_count_used_pairs() {
        let mut cfg = WorldConfig::hermit(8, 1);
        cfg.cost.scale = 1.0;
        World::run(cfg, |mpi| {
            if mpi.world_rank() == 0 {
                mpi.state().book_transfer(0, 1, 64);
                mpi.state().book_transfer(0, 1, 64);
                mpi.state().book_transfer(2, 3, 64);
                assert_eq!(mpi.state().active_channels(), 2);
            }
        });
    }

    #[test]
    fn book_transfer_after_defers_start() {
        let mut cfg = WorldConfig::hermit(2, 1);
        cfg.cost.scale = 1.0;
        World::run(cfg, |mpi| {
            if mpi.world_rank() == 0 {
                let future = Instant::now() + Duration::from_millis(5);
                let t = mpi.state().book_transfer_after(0, 1, 1 << 10, future);
                assert!(t > future, "transfer must start no earlier than not_before");
            }
        });
    }

    #[test]
    #[should_panic]
    fn rank_panic_propagates() {
        World::run(WorldConfig::local(2), |mpi| {
            if mpi.world_rank() == 1 {
                panic!("boom");
            }
        });
    }
}
