//! An MPI-3 subset implemented from scratch over OS threads + shared memory.
//!
//! This module plays the role Cray MPICH played in the paper: the
//! communication substrate underneath the DART runtime. It implements the
//! parts of MPI-3 that DART-MPI consumes, with the semantics the paper
//! leans on:
//!
//! - **ranks** are OS threads inside one process ([`World::run`] spawns one
//!   thread per rank and hands each a rank-local [`Mpi`] handle);
//! - **two-sided p2p** with tags, `MPI_ANY_SOURCE`/`MPI_ANY_TAG` matching
//!   and non-overtaking delivery ([`p2p`]);
//! - **groups** with MPI's relative-rank, order-sensitive semantics —
//!   including the append-without-sort `MPI_Group_union` behaviour the
//!   paper works around ([`group`]);
//! - **communicators** with isolated contexts, `split`/`create` ([`comm`]);
//! - **collectives**: barrier, bcast, gather(v), scatter, allgather,
//!   reduce, allreduce, alltoall, scan ([`collectives`]);
//! - **RMA windows** (collective allocate, sub-windows over reserved pools,
//!   dynamic attach), passive-target **lock/unlock/lock_all** with
//!   shared/exclusive epochs, **put/get/accumulate**, request-based
//!   **rput/rget**, **flush**, and the MPI-3 atomics **fetch_and_op** /
//!   **compare_and_swap** ([`window`]);
//! - the **RMA unified memory model** (§IV-A): public and private copies
//!   coincide because ranks share one address space;
//! - **nonblocking collectives** (`ibarrier`/`ibcast`/`iallgather`/
//!   `iallreduce`) as progress-engine state machines ([`icoll`]), and the
//!   **asynchronous progress engine** itself ([`progress`]) with its
//!   Caller/Thread/Polling modes ([`ProgressMode`]).
//!
//! Network behaviour is injected by [`crate::simnet::CostModel`] through a
//! virtual-time channel model ([`WorldState::book_transfer`]): every
//! directed rank pair owns a channel whose serialization (bandwidth + the
//! E1 bounce-buffer copy) occupies the channel, while wire latency
//! pipelines. Blocking operations spin until the modelled completion
//! instant; request-based operations carry it in their handle.

pub mod collectives;
pub mod comm;
pub mod dynwin;
pub mod datatype;
pub mod error;
pub mod group;
pub mod icoll;
pub mod p2p;
pub mod progress;
pub mod request;
pub mod window;

pub use comm::Comm;
pub use dynwin::DynWin;
pub use datatype::{as_bytes, as_bytes_mut, HasMpiType, MpiOp, MpiType, Pod, VectorType};
pub use error::{MpiErr, MpiResult};
pub use group::Group;
pub use icoll::CollRequest;
pub use p2p::{Status, ANY_SOURCE, ANY_TAG};
pub use progress::ProgressMode;
pub use request::{RecvRequest, RmaRequest, SendRequest};
pub use window::{LockKind, Win};

use crate::simnet::{CostModel, PinPolicy, Placement, Tier, Topology};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Configuration for a simulated MPI world.
#[derive(Clone)]
pub struct WorldConfig {
    /// Number of ranks (= spawned threads).
    pub nranks: usize,
    /// Modelled cluster topology.
    pub topology: Topology,
    /// Rank → core placement policy.
    pub pin: PinPolicy,
    /// Network cost model (use [`CostModel::zero`] to disable injection).
    pub cost: CostModel,
    /// Also pin the OS threads to real cores (best effort).
    pub pin_os_threads: bool,
    /// Who drives asynchronous communication progress (see
    /// [`progress::ProgressMode`]); `Thread` spawns one background service
    /// thread per [`World::run`].
    pub progress: ProgressMode,
}

impl WorldConfig {
    /// `nranks` ranks on a flat single-NUMA topology with no cost injection
    /// — the configuration unit tests use.
    pub fn local(nranks: usize) -> Self {
        WorldConfig {
            nranks,
            topology: Topology::flat(nranks.max(1)),
            pin: PinPolicy::Block,
            cost: CostModel::zero(),
            pin_os_threads: false,
            progress: ProgressMode::Caller,
        }
    }

    /// `nranks` ranks block-placed on a Hermit-like cluster with the
    /// calibrated cost model.
    pub fn hermit(nranks: usize, nodes: usize) -> Self {
        WorldConfig {
            nranks,
            topology: Topology::hermit(nodes),
            pin: PinPolicy::Block,
            cost: CostModel::hermit(),
            pin_os_threads: false,
            progress: ProgressMode::Caller,
        }
    }
}

/// Globally shared world state (one per [`World::run`] call).
pub struct WorldState {
    pub(crate) nranks: usize,
    pub(crate) placement: Placement,
    pub(crate) cost: CostModel,
    pub(crate) mailboxes: Vec<p2p::Mailbox>,
    pub(crate) windows: RwLock<HashMap<u64, Arc<window::WinState>>>,
    pub(crate) next_win_id: AtomicU64,
    pub(crate) next_context_id: AtomicU32,
    /// Directed-pair virtual-time channels, indexed `src * nranks + dst`.
    channels: Vec<Mutex<Channel>>,
    /// Asynchronous progress engine state (see [`progress`]).
    pub(crate) progress: progress::ProgressShared,
    pub(crate) finalized: AtomicBool,
}

#[derive(Default)]
struct Channel {
    /// Instant until which the channel's serialization stage is occupied.
    busy_until: Option<Instant>,
}

impl WorldState {
    fn new(cfg: &WorldConfig) -> Arc<Self> {
        let placement = Placement::new(cfg.topology, cfg.nranks, &cfg.pin);
        Arc::new(WorldState {
            nranks: cfg.nranks,
            placement,
            cost: cfg.cost,
            mailboxes: (0..cfg.nranks).map(|_| p2p::Mailbox::new()).collect(),
            windows: RwLock::new(HashMap::new()),
            next_win_id: AtomicU64::new(1),
            next_context_id: AtomicU32::new(1),
            channels: (0..cfg.nranks * cfg.nranks).map(|_| Mutex::new(Channel::default())).collect(),
            progress: progress::ProgressShared::new(cfg.nranks),
            finalized: AtomicBool::new(false),
        })
    }

    /// Placement tier between two world ranks.
    #[inline]
    pub fn tier(&self, src: usize, dst: usize) -> Tier {
        self.placement.tier(src, dst)
    }

    /// Book a `bytes`-sized transfer on the `src → dst` channel and return
    /// the modelled completion instant.
    ///
    /// Serialization time (bandwidth term plus, above the eager limit, the
    /// E1 double bounce-buffer copy) occupies the channel — back-to-back
    /// transfers queue up behind each other — while the tier's base latency
    /// pipelines (it is added after the serialization slot, so overlapped
    /// transfers pay it only once in aggregate).
    pub fn book_transfer(&self, src: usize, dst: usize, bytes: usize) -> Instant {
        let now = Instant::now();
        if self.cost.scale <= 0.0 || src == dst {
            return now;
        }
        let tier = self.tier(src, dst);
        let tc = &self.cost.tiers[tier as usize];
        // Per-message protocol overhead + bandwidth term occupy the
        // channel; the tier's base latency pipelines (added below, after
        // the serialization slot).
        let mut serialize_ns = self.cost.msg_overhead_ns + bytes as f64 / tc.bytes_per_ns;
        if bytes > self.cost.eager_e0_limit {
            serialize_ns += self.cost.e1_latency_ns + 2.0 * bytes as f64 / self.cost.e1_copy_bytes_per_ns;
        }
        let serialize = Duration::from_nanos((serialize_ns * self.cost.scale) as u64);
        let latency = Duration::from_nanos((tc.latency_ns * self.cost.scale) as u64);
        let mut ch = self.channels[src * self.nranks + dst].lock().unwrap();
        let start = match ch.busy_until {
            Some(b) if b > now => b,
            _ => now,
        };
        let done = start + serialize;
        ch.busy_until = Some(done);
        drop(ch);
        done + latency
    }

    /// Wait until `t` has passed (no-op if already past). Yield-aware: see
    /// [`crate::simnet::cost::spin_for`].
    #[inline]
    pub fn wait_until(&self, t: Instant) {
        let now = Instant::now();
        if t > now {
            crate::simnet::cost::spin_for(t - now);
        }
    }
}

/// Rank-local MPI handle, one per spawned thread. Not `Send`: like a real
/// MPI rank, it belongs to the thread it was created on.
pub struct Mpi {
    pub(crate) world: Arc<WorldState>,
    pub(crate) rank: usize,
    _not_send: std::marker::PhantomData<*const ()>,
}

impl Mpi {
    /// This rank's index in `MPI_COMM_WORLD`.
    #[inline]
    pub fn world_rank(&self) -> usize {
        self.rank
    }

    /// Total number of ranks.
    #[inline]
    pub fn world_size(&self) -> usize {
        self.world.nranks
    }

    /// The world communicator (`MPI_COMM_WORLD`).
    pub fn comm_world(&self) -> Comm {
        Comm::new_world(self.world.clone(), self.rank)
    }

    /// The group of `MPI_COMM_WORLD`.
    pub fn group_world(&self) -> Group {
        Group::new((0..self.world.nranks).collect())
    }

    /// Shared world state (used by the DART layer).
    pub fn state(&self) -> &Arc<WorldState> {
        &self.world
    }
}

/// Entry point: spawn `cfg.nranks` threads, run `f(mpi)` on each (SPMD),
/// join them all, and propagate the first panic if any.
pub struct World;

impl World {
    /// Run one simulated MPI world: spawn `cfg.nranks` rank threads, run
    /// `f(mpi)` on each, join them all. In
    /// [`ProgressMode::Thread`] an additional background
    /// progress-service thread runs for the duration of the world (stopped
    /// and joined on exit, including on panic unwind).
    pub fn run<F>(cfg: WorldConfig, f: F)
    where
        F: Fn(Mpi) + Send + Sync,
    {
        assert!(cfg.nranks > 0, "world must have at least one rank");
        let state = WorldState::new(&cfg);
        // Thread-mode asynchronous progress: start the service before the
        // ranks; the guard stops it when dropped (also during unwind).
        let _progress_guard = match cfg.progress {
            ProgressMode::Thread => Some(progress::ProgressThreadGuard::spawn(state.clone())),
            _ => None,
        };
        let f = Arc::new(f);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(cfg.nranks);
            for rank in 0..cfg.nranks {
                let state = state.clone();
                let f = f.clone();
                let pin_os = cfg.pin_os_threads;
                let coord = state.placement.coord(rank);
                let topo = cfg.topology;
                let builder = std::thread::Builder::new().name(format!("mpi-rank-{rank}"));
                handles.push(
                    builder
                        .spawn_scoped(s, move || {
                            if pin_os {
                                crate::simnet::pin_current_thread(topo.index_of(coord));
                            }
                            let mpi = Mpi {
                                world: state,
                                rank,
                                _not_send: std::marker::PhantomData,
                            };
                            f(mpi);
                        })
                        .expect("spawn rank thread"),
                );
            }
            for h in handles {
                if let Err(e) = h.join() {
                    std::panic::resume_unwind(e);
                }
            }
        });
        state.finalized.store(true, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn world_runs_all_ranks() {
        let counter = AtomicUsize::new(0);
        World::run(WorldConfig::local(7), |mpi| {
            assert_eq!(mpi.world_size(), 7);
            counter.fetch_add(1 + mpi.world_rank(), Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), (1..=7).sum());
    }

    #[test]
    fn ranks_have_distinct_ids() {
        let seen = Mutex::new(vec![false; 5]);
        World::run(WorldConfig::local(5), |mpi| {
            let mut s = seen.lock().unwrap();
            assert!(!s[mpi.world_rank()]);
            s[mpi.world_rank()] = true;
        });
        assert!(seen.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn book_transfer_zero_cost_is_now() {
        World::run(WorldConfig::local(2), |mpi| {
            let t = mpi.state().book_transfer(0, 1, 1 << 20);
            assert!(t <= Instant::now());
        });
    }

    #[test]
    fn book_transfer_serializes_channel() {
        let mut cfg = WorldConfig::hermit(2, 1);
        cfg.cost.scale = 1.0;
        World::run(cfg, |mpi| {
            if mpi.world_rank() == 0 {
                let a = mpi.state().book_transfer(0, 1, 1 << 16);
                let b = mpi.state().book_transfer(0, 1, 1 << 16);
                assert!(b > a, "second transfer must queue behind the first");
            }
        });
    }

    #[test]
    #[should_panic]
    fn rank_panic_propagates() {
        World::run(WorldConfig::local(2), |mpi| {
            if mpi.world_rank() == 1 {
                panic!("boom");
            }
        });
    }
}
