//! Dynamic windows — `MPI_Win_create_dynamic` + `MPI_Win_attach`/`detach`
//! (paper §II: "a dynamic version which exposes no memory but allows the
//! user to register remotely accessible memory locally and dynamically at
//! each process").
//!
//! A dynamic window starts empty; each rank attaches regions at any time
//! and publishes the returned *address token* to peers out of band (in
//! real MPI the virtual address is shipped; here the token plays that
//! role). RMA targets `(rank, token + offset)`.
//!
//! The implementation is a side-table on the ordinary [`Win`]:
//! [`Win::allocate_dynamic`] builds a regular collective window whose
//! per-rank static segments are zero-length and whose [`DynSide`] holds
//! the per-rank attach tables. Displacement resolution —
//! `WinState::check_range`, the single choke point every one-sided
//! operation goes through — floor-looks-up the target rank's attach table
//! instead of bounds-checking the static segment. Everything else
//! (passive-target epochs, deferred completion + flush, request ops,
//! vector datatypes, the accumulate family and CPU-atomic fast paths,
//! shared-memory locality) is inherited verbatim, which is exactly what
//! lets the DART layer route `memattach` memory through its unchanged
//! engine/progress machinery.
//!
//! Three deliberate simulator choices:
//!
//! - **u64-backed regions, 8-byte-aligned tokens** — like static
//!   [`Win`] segments, so naturally aligned elements inside an attached
//!   region are sound targets for the lock-free accumulate/CAS path.
//! - **a detach graveyard** — `detach` withdraws the region from the
//!   attach table (subsequent resolutions fail) but parks the allocation
//!   until window teardown, so a pointer resolved by a *racing* RMA op
//!   stays valid; MPI makes such races erroneous, we make them
//!   value-undefined but memory-safe, matching the static windows' story.
//! - **a detach generation counter** — bumped on every detach; consumers
//!   that cache resolutions (the DART segment cache) compare generations
//!   to invalidate lazily, since a non-collective detach cannot reach
//!   into remote caches.

use super::comm::Comm;
use super::error::{MpiErr, MpiResult};
use super::window::Win;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

/// First address token handed out (a recognizable non-zero base, so a
/// zero/small displacement on a dynamic window is an obvious bug).
const DYN_BASE_ADDR: u64 = 1 << 20;

/// One attached region. Backed by `u64`s so the base is 8-byte aligned —
/// naturally aligned elements inside are then CPU-atomics-safe (see
/// [`super::atomics`]), which the DART work queue's CAS protocol relies on.
pub(crate) struct DynRegion {
    mem: Box<[u64]>,
    /// Exposed length in bytes.
    len: usize,
}

impl DynRegion {
    fn new(len: usize) -> DynRegion {
        DynRegion { mem: vec![0u64; len.max(1).div_ceil(8)].into_boxed_slice(), len }
    }

    #[inline]
    fn ptr(&self) -> *mut u8 {
        self.mem.as_ptr() as *mut u8
    }
}

/// The dynamic flavour's shared state: per-rank attach tables plus the
/// token dispenser and the detach-generation counter. Lives inside
/// `WinState`, one per dynamic window.
pub(crate) struct DynSide {
    /// Indexed by comm rank: attach-token base → region (sorted, so a
    /// displacement resolves by floor lookup).
    ranks: Vec<RwLock<BTreeMap<u64, DynRegion>>>,
    /// Address-token dispenser (region bases never collide, any rank).
    next_addr: AtomicU64,
    /// Bumped on every detach (any rank) — the cache-invalidation epoch.
    /// Starts at 1 so consumers can use 0 as "not a dynamic resolution".
    generation: AtomicU64,
    /// Currently attached bytes across all ranks (diagnostics/metrics).
    attached_bytes: AtomicU64,
    /// Detached regions parked until window teardown (pointer stability
    /// under racing ops — see module docs).
    graveyard: Mutex<Vec<DynRegion>>,
}

impl DynSide {
    pub(crate) fn new(nranks: usize) -> DynSide {
        DynSide {
            ranks: (0..nranks).map(|_| RwLock::new(BTreeMap::new())).collect(),
            next_addr: AtomicU64::new(DYN_BASE_ADDR),
            generation: AtomicU64::new(1),
            attached_bytes: AtomicU64::new(0),
            graveyard: Mutex::new(Vec::new()),
        }
    }

    fn rank(&self, target: usize) -> MpiResult<&RwLock<BTreeMap<u64, DynRegion>>> {
        self.ranks.get(target).ok_or(MpiErr::RankOutOfRange(target, self.ranks.len()))
    }

    /// Resolve `(target, addr, len)` to a raw pointer inside one attached
    /// region — the dynamic arm of `WinState::check_range`. The pointer
    /// outlives the table lock: region allocations are heap-stable and
    /// survive detach in the graveyard.
    pub(crate) fn resolve(&self, target: usize, addr: u64, len: usize) -> MpiResult<*mut u8> {
        let map = self.rank(target)?.read().unwrap();
        let (&base, region) = map.range(..=addr).next_back().ok_or(MpiErr::DispOutOfRange {
            disp: addr as usize,
            len,
            size: 0,
        })?;
        let off = (addr - base) as usize;
        if off.checked_add(len).map_or(true, |end| end > region.len) {
            return Err(MpiErr::DispOutOfRange { disp: addr as usize, len, size: region.len });
        }
        Ok(unsafe { region.ptr().add(off) })
    }

    fn attach(&self, rank: usize, size: usize) -> MpiResult<u64> {
        if size == 0 {
            return Err(MpiErr::Invalid("attach of empty region".into()));
        }
        // 8-byte-aligned spans with a guard gap, so tokens stay aligned
        // and an off-by-one displacement can never silently land in a
        // neighbouring region.
        let span = (size as u64).div_ceil(8) * 8 + 64;
        let base = self.next_addr.fetch_add(span, Ordering::SeqCst);
        self.rank(rank)?.write().unwrap().insert(base, DynRegion::new(size));
        self.attached_bytes.fetch_add(size as u64, Ordering::SeqCst);
        Ok(base)
    }

    fn detach(&self, rank: usize, addr: u64) -> MpiResult<()> {
        let removed = self.rank(rank)?.write().unwrap().remove(&addr);
        match removed {
            Some(region) => {
                self.attached_bytes.fetch_sub(region.len as u64, Ordering::SeqCst);
                self.graveyard.lock().unwrap().push(region);
                // Publish the withdrawal *after* the table change: a
                // consumer that observes the new generation and re-resolves
                // is guaranteed to miss the region.
                self.generation.fetch_add(1, Ordering::SeqCst);
                Ok(())
            }
            None => Err(MpiErr::Invalid(format!("detach of unattached address {addr}"))),
        }
    }

    fn region_of(&self, target: usize, addr: u64) -> Option<(u64, usize)> {
        let map = self.ranks.get(target)?.read().unwrap();
        let (&base, region) = map.range(..=addr).next_back()?;
        if addr - base < region.len as u64 {
            Some((base, region.len))
        } else {
            None
        }
    }
}

/// The dynamic-window surface on [`Win`]. Every method errors with
/// [`MpiErr::Invalid`] on a window that was not created with
/// [`Win::allocate_dynamic`] (except the queries, which report "not
/// dynamic" benignly).
impl Win {
    fn dyn_side(&self) -> MpiResult<&DynSide> {
        self.state
            .dynamic
            .as_ref()
            .ok_or_else(|| MpiErr::Invalid("attach/detach on a non-dynamic window".into()))
    }

    /// `MPI_Win_attach`: expose `size` fresh zeroed bytes on this rank;
    /// returns the address token peers use to target the region.
    /// Non-collective.
    pub fn attach(&self, size: usize) -> MpiResult<u64> {
        self.dyn_side()?.attach(self.comm().rank(), size)
    }

    /// `MPI_Win_detach`: withdraw one of this rank's regions (by its
    /// attach token). Non-collective; bumps the window's detach
    /// generation so resolution caches can invalidate lazily.
    pub fn detach(&self, addr: u64) -> MpiResult<()> {
        self.dyn_side()?.detach(self.comm().rank(), addr)
    }

    /// Was this window created with [`Win::allocate_dynamic`]?
    pub fn is_dynamic(&self) -> bool {
        self.state.dynamic.is_some()
    }

    /// The detach-generation counter: starts at 1, bumped on every detach
    /// by any rank. 0 means "not a dynamic window". Two equal readings
    /// bracket a detach-free interval, so a resolution cached at
    /// generation `g` is still valid whenever the counter still reads `g`.
    pub fn dyn_generation(&self) -> u64 {
        self.state.dynamic.as_ref().map_or(0, |d| d.generation.load(Ordering::SeqCst))
    }

    /// The `(token base, length)` of the attached region covering `addr`
    /// on `target`, or `None` (detached / never attached / not dynamic).
    pub fn dyn_region_of(&self, target: usize, addr: u64) -> Option<(u64, usize)> {
        self.state.dynamic.as_ref()?.region_of(target, addr)
    }

    /// Currently attached bytes across all ranks (0 on non-dynamic
    /// windows).
    pub fn dyn_attached_bytes(&self) -> u64 {
        self.state.dynamic.as_ref().map_or(0, |d| d.attached_bytes.load(Ordering::SeqCst))
    }
}

/// A dynamic RMA window handle with DART's idiom baked in: created
/// collectively, shared epochs opened eagerly on every target
/// (`lock_all`, §IV-B5), shared via `Rc` so resolution caches can hold
/// the same handle the owner does. Derefs to [`Win`], so the full
/// one-sided surface — put/get (deferred or fused), request ops, vector
/// transfers, accumulate/fetch-op/CAS and their same-node direct
/// variants, flush/flush_all — works on attached regions out of the box.
pub struct DynWin {
    win: Rc<Win>,
}

impl DynWin {
    /// `MPI_Win_create_dynamic` + eager `lock_all`: collective; exposes no
    /// memory yet.
    pub fn create(comm: &Comm) -> MpiResult<DynWin> {
        Self::create_with(comm, false)
    }

    /// Like [`DynWin::create`], with the shared-memory flavour: same-node
    /// transfers to attached regions go zero-copy / CPU-atomic direct.
    pub fn create_with(comm: &Comm, shmem: bool) -> MpiResult<DynWin> {
        let win = Win::allocate_dynamic(comm, shmem)?;
        win.lock_all()?;
        Ok(DynWin { win: Rc::new(win) })
    }

    /// A shared handle to the underlying window (what resolution caches
    /// store).
    pub fn win_rc(&self) -> Rc<Win> {
        self.win.clone()
    }
}

impl std::ops::Deref for DynWin {
    type Target = Win;

    fn deref(&self) -> &Win {
        &self.win
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::{as_bytes, MpiOp, MpiType, World, WorldConfig, ANY_SOURCE};

    #[test]
    fn attach_put_get_detach() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            // Rank 1 attaches and publishes the token.
            if c.rank() == 1 {
                let addr = win.attach(64).unwrap();
                c.send(&addr.to_ne_bytes(), 0, 1).unwrap();
                c.barrier().unwrap();
                let mut got = [0u8; 5];
                win.get_flush(&mut got, 1, addr as usize).unwrap();
                assert_eq!(&got, b"hello");
                win.detach(addr).unwrap();
            } else {
                let (bytes, _) = c.recv_vec(1, 1).unwrap();
                let addr = u64::from_ne_bytes(bytes.try_into().unwrap());
                win.put_flush(b"hello", 1, addr as usize).unwrap();
                c.barrier().unwrap();
            }
            c.barrier().unwrap();
        });
    }

    #[test]
    fn multiple_regions_resolve_correctly() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            if c.rank() == 0 {
                let a1 = win.attach(32).unwrap();
                let a2 = win.attach(32).unwrap();
                c.send(&a1.to_ne_bytes(), 1, 0).unwrap();
                c.send(&a2.to_ne_bytes(), 1, 0).unwrap();
                c.barrier().unwrap();
                let mut b1 = [0u8; 4];
                let mut b2 = [0u8; 4];
                win.get_flush(&mut b1, 0, (a1 + 8) as usize).unwrap();
                win.get_flush(&mut b2, 0, a2 as usize).unwrap();
                assert_eq!(b1, [1; 4]);
                assert_eq!(b2, [2; 4]);
            } else {
                let (b, _) = c.recv_vec(ANY_SOURCE, 0).unwrap();
                let a1 = u64::from_ne_bytes(b.try_into().unwrap());
                let (b, _) = c.recv_vec(ANY_SOURCE, 0).unwrap();
                let a2 = u64::from_ne_bytes(b.try_into().unwrap());
                // offset addressing within a region
                win.put_flush(&[1u8; 4], 0, (a1 + 8) as usize).unwrap();
                win.put_flush(&[2u8; 4], 0, a2 as usize).unwrap();
                c.barrier().unwrap();
            }
            c.barrier().unwrap();
        });
    }

    #[test]
    fn out_of_range_and_detached_errors() {
        World::run(WorldConfig::local(1), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            let addr = win.attach(16).unwrap();
            // beyond the region
            assert!(win.put_flush(&[0u8; 32], 0, addr as usize).is_err());
            assert!(win.put_flush(&[0u8; 8], 0, (addr + 12) as usize).is_err());
            let gen = win.dyn_generation();
            win.detach(addr).unwrap();
            assert_eq!(win.dyn_generation(), gen + 1, "detach must bump the generation");
            assert!(win.put_flush(&[0u8; 4], 0, addr as usize).is_err());
            assert!(win.detach(addr).is_err());
        });
    }

    #[test]
    fn two_dynamic_windows_are_independent() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let w1 = DynWin::create(&c).unwrap();
            let w2 = DynWin::create(&c).unwrap();
            if c.rank() == 0 {
                let a1 = w1.attach(8).unwrap();
                // The same token is meaningless on w2 (attach tables are
                // per-window even though the token ranges coincide).
                assert!(w2.put_flush(&[1u8; 4], 0, a1 as usize).is_err());
                w1.put_flush(&[1u8; 4], 0, a1 as usize).unwrap();
            }
            c.barrier().unwrap();
        });
    }

    #[test]
    fn deferred_ops_and_flush_work_on_attached_memory() {
        // The inherited deferred-completion path: put/get join the pending
        // list and complete at flush, exactly like a static window.
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            let addr = win.attach(64).unwrap();
            c.barrier().unwrap();
            let peer = (c.rank() + 1) % 2;
            let peer_tok = {
                // exchange tokens: everyone attached one region; swap bases
                c.send(&addr.to_ne_bytes(), peer, 7).unwrap();
                let (b, _) = c.recv_vec(peer, 7).unwrap();
                u64::from_ne_bytes(b.try_into().unwrap())
            };
            let v = (c.rank() as u64 + 1) * 0x1111;
            win.put(&v.to_ne_bytes(), peer, peer_tok as usize).unwrap();
            win.flush(peer).unwrap();
            c.barrier().unwrap();
            let mut mine = [0u8; 8];
            win.read_local(addr as usize, &mut mine).unwrap();
            assert_eq!(u64::from_ne_bytes(mine), (peer as u64 + 1) * 0x1111);
            c.barrier().unwrap();
        });
    }

    #[test]
    fn atomics_work_on_attached_memory() {
        use std::sync::atomic::{AtomicI64, Ordering as AOrd};
        let result = AtomicI64::new(0);
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            let mut tok = [0u8; 8];
            if c.rank() == 0 {
                tok = win.attach(8).unwrap().to_ne_bytes();
            }
            c.bcast(&mut tok, 0).unwrap();
            let addr = u64::from_ne_bytes(tok) as usize;
            c.barrier().unwrap();
            // Everyone hammers the shared cell: accumulate + fetch_and_op.
            for _ in 0..25 {
                win.accumulate(as_bytes(&[1i64]), 0, addr, MpiOp::Sum, MpiType::I64).unwrap();
            }
            win.flush(0).unwrap();
            let _ = win.fetch_and_op_with(1i64, 0, addr, MpiOp::Sum).unwrap();
            c.barrier().unwrap();
            if c.rank() == 0 {
                let mut v = [0u8; 8];
                win.read_local(addr, &mut v).unwrap();
                result.store(i64::from_ne_bytes(v), AOrd::SeqCst);
            }
            c.barrier().unwrap();
        });
        assert_eq!(result.load(std::sync::atomic::Ordering::SeqCst), 4 * 25 + 4);
    }

    #[test]
    fn attached_bytes_tracks_attach_and_detach() {
        World::run(WorldConfig::local(1), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            assert!(win.is_dynamic());
            assert_eq!(win.dyn_attached_bytes(), 0);
            let a = win.attach(100).unwrap();
            let b = win.attach(28).unwrap();
            assert_eq!(win.dyn_attached_bytes(), 128);
            assert_eq!(win.dyn_region_of(0, a + 99), Some((a, 100)));
            assert_eq!(win.dyn_region_of(0, a + 100), None);
            win.detach(a).unwrap();
            assert_eq!(win.dyn_attached_bytes(), 28);
            win.detach(b).unwrap();
            assert_eq!(win.dyn_attached_bytes(), 0);
        });
    }
}
