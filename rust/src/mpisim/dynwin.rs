//! Dynamic windows — `MPI_Win_create_dynamic` + `MPI_Win_attach`/`detach`
//! (paper §II: "a dynamic version which exposes no memory but allows the
//! user to register remotely accessible memory locally and dynamically at
//! each process").
//!
//! A dynamic window starts empty; each rank attaches regions at any time
//! and publishes the returned *address token* to peers out of band (in
//! real MPI the virtual address is shipped; here the token plays that
//! role). RMA targets `(rank, token + offset)`.

use super::comm::Comm;
use super::error::{MpiErr, MpiResult};
use super::window::LockKind;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// One attached region.
struct Region {
    mem: Box<[u8]>,
}

/// Per-rank attach table: token base → region (sorted for range lookup).
#[derive(Default)]
struct RankRegions {
    regions: BTreeMap<u64, Region>,
}

impl RankRegions {
    /// Resolve `(addr, len)` to a raw pointer inside one attached region.
    fn resolve(&self, addr: u64, len: usize) -> Option<*mut u8> {
        let (&base, region) = self.regions.range(..=addr).next_back()?;
        let off = addr - base;
        if off as usize + len <= region.mem.len() {
            // Box contents are heap-stable; many threads may target this
            // region concurrently under RMA semantics.
            Some(unsafe { (region.mem.as_ptr() as *mut u8).add(off as usize) })
        } else {
            None
        }
    }
}

struct DynState {
    /// Indexed by comm rank.
    ranks: Vec<RwLock<RankRegions>>,
    /// Address-token dispenser (region bases never collide, any rank).
    next_addr: AtomicU64,
    /// Simple passive-target lock per rank (shared only — what DART-style
    /// consumers use).
    epoch: Vec<(Mutex<usize>, Condvar)>,
}

/// A dynamic RMA window handle (rank-local).
pub struct DynWin {
    state: Arc<DynState>,
    comm: Comm,
    _not_send: std::marker::PhantomData<*const ()>,
}

impl DynWin {
    /// `MPI_Win_create_dynamic`: collective; exposes no memory yet.
    pub fn create(comm: &Comm) -> MpiResult<DynWin> {
        let n = comm.size();
        // Rendezvous: rank 0 builds the shared state, parks it in a
        // process-global side table under a globally unique key, and
        // broadcasts the key; everyone clones the Arc, then rank 0 cleans
        // the table entry up.
        static NEXT_KEY: AtomicU64 = AtomicU64::new(1);
        let mut key = 0u64;
        if comm.rank() == 0 {
            key = NEXT_KEY.fetch_add(1, Ordering::SeqCst);
            let st = Arc::new(DynState {
                ranks: (0..n).map(|_| RwLock::new(RankRegions::default())).collect(),
                next_addr: AtomicU64::new(1 << 20),
                epoch: (0..n).map(|_| (Mutex::new(0), Condvar::new())).collect(),
            });
            dyn_side_table().lock().unwrap().insert(key, st);
        }
        let mut kb = key.to_ne_bytes();
        comm.bcast(&mut kb, 0)?;
        key = u64::from_ne_bytes(kb);
        let state = dyn_side_table()
            .lock()
            .unwrap()
            .get(&key)
            .cloned()
            .ok_or(MpiErr::UnknownWindow(key))?;
        comm.barrier()?;
        if comm.rank() == 0 {
            dyn_side_table().lock().unwrap().remove(&key);
        }
        Ok(DynWin { state, comm: comm.clone(), _not_send: std::marker::PhantomData })
    }

    /// `MPI_Win_attach`: expose `size` fresh zeroed bytes; returns the
    /// address token peers use to target this region.
    pub fn attach(&self, size: usize) -> MpiResult<u64> {
        if size == 0 {
            return Err(MpiErr::Invalid("attach of empty region".into()));
        }
        let base = self
            .state
            .next_addr
            .fetch_add(size.next_power_of_two() as u64 + 64, Ordering::SeqCst);
        let mem = vec![0u8; size].into_boxed_slice();
        self.state.ranks[self.comm.rank()]
            .write()
            .unwrap()
            .regions
            .insert(base, Region { mem });
        Ok(base)
    }

    /// `MPI_Win_detach`: withdraw a region (by its attach token).
    pub fn detach(&self, addr: u64) -> MpiResult<()> {
        let removed =
            self.state.ranks[self.comm.rank()].write().unwrap().regions.remove(&addr);
        match removed {
            Some(_) => Ok(()),
            None => Err(MpiErr::Invalid(format!("detach of unattached address {addr}"))),
        }
    }

    /// `MPI_Win_lock(SHARED, target)` for the dynamic window.
    pub fn lock_shared(&self, target: usize) -> MpiResult<()> {
        let (m, _cv) = self
            .state
            .epoch
            .get(target)
            .ok_or(MpiErr::RankOutOfRange(target, self.comm.size()))?;
        *m.lock().unwrap() += 1;
        Ok(())
    }

    /// `MPI_Win_unlock`.
    pub fn unlock(&self, target: usize) -> MpiResult<()> {
        let (m, cv) = self
            .state
            .epoch
            .get(target)
            .ok_or(MpiErr::RankOutOfRange(target, self.comm.size()))?;
        let mut g = m.lock().unwrap();
        if *g == 0 {
            return Err(MpiErr::NoMatchingLock { win: 0, target });
        }
        *g -= 1;
        cv.notify_all();
        Ok(())
    }

    /// `MPI_Put` on an attached region (blocking through flush like the
    /// static window's put+flush; dynamic windows are not on DART's hot
    /// path, so the simpler completion model is fine).
    pub fn put(&self, origin: &[u8], target: usize, addr: u64) -> MpiResult<()> {
        let regions = self
            .state
            .ranks
            .get(target)
            .ok_or(MpiErr::RankOutOfRange(target, self.comm.size()))?
            .read()
            .unwrap();
        let dst = regions.resolve(addr, origin.len()).ok_or(MpiErr::DispOutOfRange {
            disp: addr as usize,
            len: origin.len(),
            size: 0,
        })?;
        unsafe { std::ptr::copy_nonoverlapping(origin.as_ptr(), dst, origin.len()) };
        drop(regions);
        let src_w = self.comm.my_world();
        let dst_w = self.comm.world_rank_of(target)?;
        let at = self.comm.world().book_transfer(src_w, dst_w, origin.len());
        self.comm.world().wait_until(at);
        Ok(())
    }

    /// `MPI_Get` on an attached region.
    pub fn get(&self, dest: &mut [u8], target: usize, addr: u64) -> MpiResult<()> {
        let regions = self
            .state
            .ranks
            .get(target)
            .ok_or(MpiErr::RankOutOfRange(target, self.comm.size()))?
            .read()
            .unwrap();
        let src = regions.resolve(addr, dest.len()).ok_or(MpiErr::DispOutOfRange {
            disp: addr as usize,
            len: dest.len(),
            size: 0,
        })?;
        unsafe { std::ptr::copy_nonoverlapping(src, dest.as_mut_ptr(), dest.len()) };
        drop(regions);
        let src_w = self.comm.my_world();
        let dst_w = self.comm.world_rank_of(target)?;
        let at = self.comm.world().book_transfer(dst_w, src_w, dest.len());
        self.comm.world().wait_until(at);
        Ok(())
    }

    /// Kind marker (diagnostics; mirrors `MPI_WIN_FLAVOR_DYNAMIC`).
    pub fn lock_kind_supported(&self) -> LockKind {
        LockKind::Shared
    }
}

/// Process-global side table used only during `DynWin::create` rendezvous.
/// (`std::sync::OnceLock` — the crate is dependency-free, no `once_cell`.)
fn dyn_side_table() -> &'static Mutex<std::collections::HashMap<u64, Arc<DynState>>> {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Mutex<std::collections::HashMap<u64, Arc<DynState>>>> = OnceLock::new();
    TABLE.get_or_init(|| Mutex::new(std::collections::HashMap::new()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::{World, WorldConfig, ANY_SOURCE};

    #[test]
    fn attach_put_get_detach() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            // Rank 1 attaches and publishes the token.
            if c.rank() == 1 {
                let addr = win.attach(64).unwrap();
                c.send(&addr.to_ne_bytes(), 0, 1).unwrap();
                c.barrier().unwrap();
                let mut got = [0u8; 5];
                win.get(&mut got, 1, addr).unwrap();
                assert_eq!(&got, b"hello");
                win.detach(addr).unwrap();
            } else {
                let (bytes, _) = c.recv_vec(1, 1).unwrap();
                let addr = u64::from_ne_bytes(bytes.try_into().unwrap());
                win.lock_shared(1).unwrap();
                win.put(b"hello", 1, addr).unwrap();
                win.unlock(1).unwrap();
                c.barrier().unwrap();
            }
            c.barrier().unwrap();
        });
    }

    #[test]
    fn multiple_regions_resolve_correctly() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            if c.rank() == 0 {
                let a1 = win.attach(32).unwrap();
                let a2 = win.attach(32).unwrap();
                c.send(&a1.to_ne_bytes(), 1, 0).unwrap();
                c.send(&a2.to_ne_bytes(), 1, 0).unwrap();
                c.barrier().unwrap();
                let mut b1 = [0u8; 4];
                let mut b2 = [0u8; 4];
                win.get(&mut b1, 0, a1 + 8).unwrap();
                win.get(&mut b2, 0, a2).unwrap();
                assert_eq!(b1, [1; 4]);
                assert_eq!(b2, [2; 4]);
            } else {
                let (b, _) = c.recv_vec(ANY_SOURCE, 0).unwrap();
                let a1 = u64::from_ne_bytes(b.try_into().unwrap());
                let (b, _) = c.recv_vec(ANY_SOURCE, 0).unwrap();
                let a2 = u64::from_ne_bytes(b.try_into().unwrap());
                // offset addressing within a region
                win.put(&[1u8; 4], 0, a1 + 8).unwrap();
                win.put(&[2u8; 4], 0, a2).unwrap();
                c.barrier().unwrap();
            }
            c.barrier().unwrap();
        });
    }

    #[test]
    fn out_of_range_and_detached_errors() {
        World::run(WorldConfig::local(1), |mpi| {
            let c = mpi.comm_world();
            let win = DynWin::create(&c).unwrap();
            let addr = win.attach(16).unwrap();
            // beyond the region
            assert!(win.put(&[0u8; 32], 0, addr).is_err());
            assert!(win.put(&[0u8; 8], 0, addr + 12).is_err());
            win.detach(addr).unwrap();
            assert!(win.put(&[0u8; 4], 0, addr).is_err());
            assert!(win.detach(addr).is_err());
        });
    }

    #[test]
    fn two_dynamic_windows_are_independent() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let w1 = DynWin::create(&c).unwrap();
            let w2 = DynWin::create(&c).unwrap();
            if c.rank() == 0 {
                let a1 = w1.attach(8).unwrap();
                // The same token is meaningless on w2.
                assert!(w2.put(&[1u8; 4], 0, a1).is_err());
                w1.put(&[1u8; 4], 0, a1).unwrap();
            }
            c.barrier().unwrap();
        });
    }
}
