//! MPI process groups — with MPI's order-sensitive, relative-rank semantics.
//!
//! A group is an ordered set of process identities (world ranks, since our
//! process identities coincide with `MPI_COMM_WORLD` ranks). The paper's
//! §IV-B1 leans on two MPI behaviours that this module reproduces
//! faithfully so the DART layer genuinely has something to fix:
//!
//! - [`Group::incl`] selects by **relative** rank in the parent group, and
//!   the output ordering follows the `ranks` argument, not process identity;
//! - [`Group::union_mpi`] **appends** the members of `g2` not already in
//!   `g1` in `g2`'s order — it does not sort. "For all practical purposes,
//!   the processes in each MPI group are arranged in a random fashion."

use super::error::{MpiErr, MpiResult};

/// An ordered set of process identities (world ranks).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Group {
    members: Vec<usize>,
}

impl Group {
    /// Build a group from an explicit member list (order preserved).
    /// Duplicate members are rejected.
    pub fn new(members: Vec<usize>) -> Group {
        debug_assert!(
            {
                let mut m = members.clone();
                m.sort_unstable();
                m.windows(2).all(|w| w[0] != w[1])
            },
            "duplicate members in group"
        );
        Group { members }
    }

    /// `MPI_GROUP_EMPTY`.
    pub fn empty() -> Group {
        Group { members: Vec::new() }
    }

    /// Number of members (`MPI_Group_size`).
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Is this `MPI_GROUP_EMPTY`?
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// The member list, in group order. Element `i` is the process identity
    /// (world rank) of group rank `i`.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    /// `MPI_Group_rank`: the calling process's rank in this group, by its
    /// world rank. `None` if not a member (`MPI_UNDEFINED`).
    pub fn rank_of(&self, world_rank: usize) -> Option<usize> {
        self.members.iter().position(|&m| m == world_rank)
    }

    /// Membership test.
    pub fn contains(&self, world_rank: usize) -> bool {
        self.rank_of(world_rank).is_some()
    }

    /// `MPI_Group_incl(parent, n, ranks)`: the group consisting of the
    /// processes with **relative** ranks `ranks[0..n]` in `self`, in that
    /// order. This is the operation whose relative-rank, order-following
    /// behaviour the paper's Fig. 3 illustrates.
    pub fn incl(&self, ranks: &[usize]) -> MpiResult<Group> {
        let mut members = Vec::with_capacity(ranks.len());
        for &r in ranks {
            let m = *self.members.get(r).ok_or(MpiErr::NotInGroup(r))?;
            if members.contains(&m) {
                return Err(MpiErr::Invalid(format!("duplicate rank {r} in incl")));
            }
            members.push(m);
        }
        Ok(Group { members })
    }

    /// `MPI_Group_excl`: all members except those with relative ranks in
    /// `ranks`, preserving order.
    pub fn excl(&self, ranks: &[usize]) -> MpiResult<Group> {
        for &r in ranks {
            if r >= self.members.len() {
                return Err(MpiErr::NotInGroup(r));
            }
        }
        let members = self
            .members
            .iter()
            .enumerate()
            .filter(|(i, _)| !ranks.contains(i))
            .map(|(_, &m)| m)
            .collect();
        Ok(Group { members })
    }

    /// `MPI_Group_union(g1, g2)`: `g1` followed by the members of `g2` not
    /// in `g1`, **in `g2`'s order — no sorting** (paper Fig. 3, bottom).
    pub fn union_mpi(&self, other: &Group) -> Group {
        let mut members = self.members.clone();
        for &m in &other.members {
            if !members.contains(&m) {
                members.push(m);
            }
        }
        Group { members }
    }

    /// `MPI_Group_intersection`: members of `g1` that are also in `g2`, in
    /// `g1`'s order.
    pub fn intersection(&self, other: &Group) -> Group {
        Group {
            members: self.members.iter().copied().filter(|m| other.contains(*m)).collect(),
        }
    }

    /// `MPI_Group_difference`: members of `g1` not in `g2`, in `g1`'s order.
    pub fn difference(&self, other: &Group) -> Group {
        Group {
            members: self.members.iter().copied().filter(|m| !other.contains(*m)).collect(),
        }
    }

    /// `MPI_Group_translate_ranks`: map relative ranks in `self` to relative
    /// ranks in `other` (`None` where the process is not in `other`).
    pub fn translate_ranks(&self, ranks: &[usize], other: &Group) -> MpiResult<Vec<Option<usize>>> {
        ranks
            .iter()
            .map(|&r| {
                let m = *self.members.get(r).ok_or(MpiErr::NotInGroup(r))?;
                Ok(other.rank_of(m))
            })
            .collect()
    }

    /// `MPI_Group_compare` ≈ MPI_IDENT: same members, same order.
    pub fn identical(&self, other: &Group) -> bool {
        self.members == other.members
    }

    /// `MPI_Group_compare` ≈ MPI_SIMILAR: same members, any order.
    pub fn similar(&self, other: &Group) -> bool {
        if self.members.len() != other.members.len() {
            return false;
        }
        let mut a = self.members.clone();
        let mut b = other.members.clone();
        a.sort_unstable();
        b.sort_unstable();
        a == b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world(n: usize) -> Group {
        Group::new((0..n).collect())
    }

    #[test]
    fn incl_is_relative_and_order_following() {
        // Paper Fig. 3: incl on a parent group uses relative ranks and the
        // output order follows the `ranks` array.
        let parent = Group::new(vec![4, 9, 2, 7]);
        let g = parent.incl(&[3, 0]).unwrap();
        assert_eq!(g.members(), &[7, 4]); // NOT sorted
    }

    #[test]
    fn union_appends_without_sorting() {
        let g1 = Group::new(vec![5, 1]);
        let g2 = Group::new(vec![3, 1, 0]);
        let u = g1.union_mpi(&g2);
        assert_eq!(u.members(), &[5, 1, 3, 0]); // g2's new members appended
    }

    #[test]
    fn excl_preserves_order() {
        let g = world(5).excl(&[1, 3]).unwrap();
        assert_eq!(g.members(), &[0, 2, 4]);
    }

    #[test]
    fn translate_ranks_roundtrip() {
        let g1 = Group::new(vec![2, 0, 3]);
        let g2 = Group::new(vec![3, 2]);
        let t = g1.translate_ranks(&[0, 1, 2], &g2).unwrap();
        assert_eq!(t, vec![Some(1), None, Some(0)]);
    }

    #[test]
    fn intersection_and_difference() {
        let g1 = Group::new(vec![4, 1, 3]);
        let g2 = Group::new(vec![3, 4]);
        assert_eq!(g1.intersection(&g2).members(), &[4, 3]);
        assert_eq!(g1.difference(&g2).members(), &[1]);
    }

    #[test]
    fn incl_out_of_range_is_error() {
        assert!(world(3).incl(&[3]).is_err());
    }

    #[test]
    fn compare_modes() {
        let a = Group::new(vec![1, 2]);
        let b = Group::new(vec![2, 1]);
        assert!(a.similar(&b));
        assert!(!a.identical(&b));
        assert!(a.identical(&a.clone()));
    }
}
