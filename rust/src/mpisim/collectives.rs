//! Collective operations, implemented over the p2p layer with reserved
//! (negative) tags — the same layering real MPI implementations use.
//!
//! All ranks of a communicator must call collectives in the same order
//! (an MPI requirement); a per-communicator sequence number keeps each
//! collective's traffic from matching any other's.
//!
//! Algorithms: dissemination barrier, binomial-tree bcast/reduce/gather/
//! scatter, Bruck allgather, staggered pairwise alltoall, linear scan
//! (gatherv stays linear — variable sizes defeat subtree packing). All
//! fan-in/fan-out is logarithmic in the communicator size, so no rank is
//! ever the endpoint of O(n) messages — the property the thousand-unit
//! weak-scaling bench depends on. The DART layer on top is oblivious to
//! the algorithm.

use super::comm::Comm;
use super::datatype::{reduce_bytes, MpiOp, MpiType};
use super::error::{MpiErr, MpiResult};
use std::sync::atomic::Ordering;

/// Tag-space partitioning: collectives use tags below this base, user p2p
/// uses tags ≥ 0. Each collective call gets `COLL_BASE - seq*MAX_ROUNDS -
/// round` so rounds never collide across calls.
const COLL_BASE: i32 = -2;
const MAX_ROUNDS: i32 = 64;

/// Binomial-tree geometry in rotated (vrank) space, shared by bcast,
/// reduce, gather and scatter: vrank `v`'s parent clears `v`'s lowest set
/// bit; its children are `v | bit` for every `bit` below that lowest set
/// bit; and its subtree covers the *contiguous* vrank interval
/// `[v, v + lsb(v))` clipped to `n` — which is what lets gather/scatter
/// ship whole subtrees as single contiguous slices.
#[inline]
fn lsb_or_top(v: usize, n: usize) -> usize {
    if v == 0 {
        n.next_power_of_two()
    } else {
        v & v.wrapping_neg()
    }
}

/// Number of vranks in `v`'s binomial subtree (including `v`) in an
/// `n`-rank communicator.
#[inline]
fn subtree_len(v: usize, n: usize) -> usize {
    lsb_or_top(v, n).min(n - v)
}

impl Comm {
    /// Fresh tag block for one collective invocation.
    fn coll_tag(&self) -> i32 {
        let seq = self.coll_seq.fetch_add(1, Ordering::Relaxed);
        COLL_BASE - (seq as i64 % ((i32::MAX / MAX_ROUNDS) as i64)) as i32 * MAX_ROUNDS
    }

    /// `MPI_Barrier`: dissemination algorithm, ⌈log2(n)⌉ rounds.
    pub fn barrier(&self) -> MpiResult<()> {
        let n = self.size();
        let me = self.rank();
        let tag = self.coll_tag();
        let mut round = 0;
        let mut dist = 1;
        while dist < n {
            let dst = (me + dist) % n;
            let src = (me + n - dist % n) % n;
            self.send_internal(&[], dst, tag - round, true)?;
            self.recv(&mut [], src, tag - round)?;
            dist <<= 1;
            round += 1;
        }
        Ok(())
    }

    /// `MPI_Bcast`: binomial tree rooted at `root`; `buf` is input at the
    /// root, output everywhere else.
    pub fn bcast(&self, buf: &mut [u8], root: usize) -> MpiResult<()> {
        let n = self.size();
        if root >= n {
            return Err(MpiErr::RankOutOfRange(root, n));
        }
        if n == 1 {
            return Ok(());
        }
        let tag = self.coll_tag();
        let vrank = (self.rank() + n - root) % n;

        // Receive from parent (all non-root vranks).
        if vrank != 0 {
            // parent clears the lowest set bit of vrank
            let parent_v = vrank & (vrank - 1);
            let parent = (parent_v + root) % n;
            self.recv(buf, parent, tag)?;
        }
        // Forward to children: set bits above my lowest set bit.
        let lowest = if vrank == 0 { n.next_power_of_two() } else { vrank & vrank.wrapping_neg() };
        let mut bit = 1;
        while bit < lowest && bit < n {
            let child_v = vrank | bit;
            if child_v != vrank && child_v < n {
                let child = (child_v + root) % n;
                self.send_internal(buf, child, tag, true)?;
            }
            bit <<= 1;
        }
        Ok(())
    }

    /// `MPI_Gather` with equal contribution sizes: every rank sends
    /// `sendbuf`; at the root, `recvbuf` (length `size() * sendbuf.len()`)
    /// is filled in rank order. Non-roots may pass an empty `recvbuf`.
    ///
    /// Binomial tree in rotated vrank space: each vrank packs its subtree's
    /// contributions (a contiguous vrank interval, so one slice) and sends
    /// them to its parent as a single message — ⌈log2(n)⌉ fan-in at the
    /// root instead of `n - 1`.
    pub fn gather(&self, sendbuf: &[u8], recvbuf: &mut [u8], root: usize) -> MpiResult<()> {
        let n = self.size();
        if root >= n {
            return Err(MpiErr::RankOutOfRange(root, n));
        }
        let chunk = sendbuf.len();
        if self.rank() == root && recvbuf.len() != n * chunk {
            return Err(MpiErr::SizeMismatch { local: recvbuf.len(), remote: n * chunk });
        }
        let tag = self.coll_tag();
        let vrank = (self.rank() + n - root) % n;
        // tmp[i * chunk ..] holds vrank (vrank + i)'s contribution.
        let sub = subtree_len(vrank, n);
        let mut tmp = vec![0u8; sub * chunk];
        tmp[..chunk].copy_from_slice(sendbuf);
        // Collect children (vrank | bit, each a contiguous sub-interval).
        let lowest = lsb_or_top(vrank, n);
        let mut bit = 1;
        while bit < lowest && vrank + bit < n {
            let child_v = vrank + bit;
            let child_sub = subtree_len(child_v, n);
            self.recv(&mut tmp[bit * chunk..(bit + child_sub) * chunk], (child_v + root) % n, tag)?;
            bit <<= 1;
        }
        if vrank != 0 {
            let parent_v = vrank & (vrank - 1);
            self.send_internal(&tmp, (parent_v + root) % n, tag, true)?;
        } else {
            // Un-rotate: vrank v's chunk belongs to comm rank (v + root) % n.
            for v in 0..n {
                let r = (v + root) % n;
                recvbuf[r * chunk..(r + 1) * chunk]
                    .copy_from_slice(&tmp[v * chunk..(v + 1) * chunk]);
            }
        }
        Ok(())
    }

    /// `MPI_Gatherv` with per-rank sizes discovered at the root: returns
    /// the concatenated payloads (rank order) at the root, `None` elsewhere.
    pub fn gatherv(&self, sendbuf: &[u8], root: usize) -> MpiResult<Option<Vec<Vec<u8>>>> {
        let n = self.size();
        if root >= n {
            return Err(MpiErr::RankOutOfRange(root, n));
        }
        let tag = self.coll_tag();
        if self.rank() == root {
            let mut parts = vec![Vec::new(); n];
            parts[root] = sendbuf.to_vec();
            for r in 0..n {
                if r != root {
                    let (data, _) = self.recv_vec(r, tag)?;
                    parts[r] = data;
                }
            }
            Ok(Some(parts))
        } else {
            self.send_internal(sendbuf, root, tag, true)?;
            Ok(None)
        }
    }

    /// `MPI_Scatter` with equal chunk sizes: the root's `sendbuf` (length
    /// `size() * chunk`) is split in rank order; every rank receives its
    /// chunk into `recvbuf` (length `chunk`). Non-roots pass `&[]`.
    ///
    /// Binomial tree (mirror of [`Comm::gather`]): the root ships each
    /// child its whole subtree interval in one message; interior vranks
    /// peel off their own chunk and forward sub-intervals — the root sends
    /// ⌈log2(n)⌉ messages instead of `n - 1`.
    pub fn scatter(&self, sendbuf: &[u8], recvbuf: &mut [u8], root: usize) -> MpiResult<()> {
        let n = self.size();
        if root >= n {
            return Err(MpiErr::RankOutOfRange(root, n));
        }
        let tag = self.coll_tag();
        let chunk = recvbuf.len();
        let vrank = (self.rank() + n - root) % n;
        let sub = subtree_len(vrank, n);
        // tmp[i * chunk ..] is vrank (vrank + i)'s chunk.
        let mut tmp;
        if vrank == 0 {
            if sendbuf.len() != n * chunk {
                return Err(MpiErr::SizeMismatch { local: sendbuf.len(), remote: n * chunk });
            }
            // Rotate comm-rank order into vrank order.
            tmp = vec![0u8; n * chunk];
            for v in 0..n {
                let r = (v + root) % n;
                tmp[v * chunk..(v + 1) * chunk]
                    .copy_from_slice(&sendbuf[r * chunk..(r + 1) * chunk]);
            }
        } else {
            tmp = vec![0u8; sub * chunk];
            let parent_v = vrank & (vrank - 1);
            self.recv(&mut tmp, (parent_v + root) % n, tag)?;
        }
        // Forward each child its contiguous subtree interval.
        let lowest = lsb_or_top(vrank, n);
        let mut bit = 1;
        while bit < lowest && vrank + bit < n {
            let child_v = vrank + bit;
            let child_sub = subtree_len(child_v, n);
            self.send_internal(
                &tmp[bit * chunk..(bit + child_sub) * chunk],
                (child_v + root) % n,
                tag,
                true,
            )?;
            bit <<= 1;
        }
        recvbuf.copy_from_slice(&tmp[..chunk]);
        Ok(())
    }

    /// `MPI_Allgather` (equal sizes): Bruck's algorithm, ⌈log2(n)⌉ rounds
    /// of doubling exchanges with no root bottleneck (the gather+bcast
    /// composition it replaces funnelled all `n` chunks through rank 0
    /// twice). After round `r`, `tmp[i]` holds rank `(me + i) % n`'s chunk
    /// for all `i < 2^r`; a final local rotation restores rank order.
    pub fn allgather(&self, sendbuf: &[u8], recvbuf: &mut [u8]) -> MpiResult<()> {
        let n = self.size();
        let me = self.rank();
        let chunk = sendbuf.len();
        if recvbuf.len() != n * chunk {
            return Err(MpiErr::SizeMismatch { local: recvbuf.len(), remote: n * chunk });
        }
        let tag = self.coll_tag();
        let mut tmp = vec![0u8; n * chunk];
        tmp[..chunk].copy_from_slice(sendbuf);
        let mut have = 1usize;
        let mut round = 0;
        while have < n {
            let cnt = have.min(n - have);
            let dst = (me + n - have) % n;
            let src = (me + have) % n;
            self.send_internal(&tmp[..cnt * chunk], dst, tag - round, true)?;
            self.recv(&mut tmp[have * chunk..(have + cnt) * chunk], src, tag - round)?;
            have += cnt;
            round += 1;
        }
        // tmp[i] = chunk of rank (me + i) % n  →  recvbuf in rank order.
        for r in 0..n {
            let i = (r + n - me) % n;
            recvbuf[r * chunk..(r + 1) * chunk].copy_from_slice(&tmp[i * chunk..(i + 1) * chunk]);
        }
        Ok(())
    }

    /// `MPI_Reduce`: element-wise `(op, ty)` reduction into the root's
    /// `recvbuf`. Binomial tree; reduction order is deterministic for a
    /// given size (children fold into parents by increasing bit).
    pub fn reduce(
        &self,
        sendbuf: &[u8],
        recvbuf: &mut [u8],
        op: MpiOp,
        ty: MpiType,
        root: usize,
    ) -> MpiResult<()> {
        let n = self.size();
        if root >= n {
            return Err(MpiErr::RankOutOfRange(root, n));
        }
        let tag = self.coll_tag();
        let vrank = (self.rank() + n - root) % n;
        let mut acc = sendbuf.to_vec();

        // Fold in children (reverse binomial bcast tree).
        let lowest = if vrank == 0 { n.next_power_of_two() } else { vrank & vrank.wrapping_neg() };
        let mut bits = Vec::new();
        let mut bit = 1;
        while bit < lowest && bit < n {
            if (vrank | bit) != vrank && (vrank | bit) < n {
                bits.push(bit);
            }
            bit <<= 1;
        }
        // Children must be folded from the highest bit down so the
        // reduction order mirrors the bcast tree's construction.
        for &b in bits.iter().rev() {
            let child_v = vrank | b;
            let child = (child_v + root) % n;
            let mut contrib = vec![0u8; acc.len()];
            self.recv(&mut contrib, child, tag)?;
            reduce_bytes(op, ty, &mut acc, &contrib)?;
        }
        if vrank != 0 {
            let parent_v = vrank & (vrank - 1);
            let parent = (parent_v + root) % n;
            self.send_internal(&acc, parent, tag, true)?;
        } else {
            if recvbuf.len() != acc.len() {
                return Err(MpiErr::SizeMismatch { local: recvbuf.len(), remote: acc.len() });
            }
            recvbuf.copy_from_slice(&acc);
        }
        Ok(())
    }

    /// `MPI_Allreduce`: reduce to rank 0, then bcast.
    pub fn allreduce(
        &self,
        sendbuf: &[u8],
        recvbuf: &mut [u8],
        op: MpiOp,
        ty: MpiType,
    ) -> MpiResult<()> {
        self.reduce(sendbuf, recvbuf, op, ty, 0)?;
        self.bcast(recvbuf, 0)
    }

    /// `MPI_Alltoall` (equal chunk sizes): `sendbuf` holds one chunk per
    /// destination in rank order; `recvbuf` receives one chunk per source.
    ///
    /// Staggered pairwise rounds: in round `i` every rank sends to
    /// `(me + i) % n` and receives from `(me - i) mod n` — each round is a
    /// perfect permutation, so no rank ever holds `n - 1` undelivered
    /// eager messages and no mailbox becomes a hotspot (the total message
    /// count stays the bandwidth-optimal `n(n-1)`; alltoall is inherently
    /// all-pairs).
    pub fn alltoall(&self, sendbuf: &[u8], recvbuf: &mut [u8], chunk: usize) -> MpiResult<()> {
        let n = self.size();
        if sendbuf.len() != n * chunk || recvbuf.len() != n * chunk {
            return Err(MpiErr::SizeMismatch { local: sendbuf.len(), remote: n * chunk });
        }
        let tag = self.coll_tag();
        let me = self.rank();
        recvbuf[me * chunk..(me + 1) * chunk]
            .copy_from_slice(&sendbuf[me * chunk..(me + 1) * chunk]);
        for i in 1..n {
            let dst = (me + i) % n;
            let src = (me + n - i) % n;
            self.send_internal(&sendbuf[dst * chunk..(dst + 1) * chunk], dst, tag, true)?;
            self.recv(&mut recvbuf[src * chunk..(src + 1) * chunk], src, tag)?;
        }
        Ok(())
    }

    /// `MPI_Scan` (inclusive): rank `i` receives the reduction of ranks
    /// `0..=i`. Linear chain.
    pub fn scan(
        &self,
        sendbuf: &[u8],
        recvbuf: &mut [u8],
        op: MpiOp,
        ty: MpiType,
    ) -> MpiResult<()> {
        let me = self.rank();
        let tag = self.coll_tag();
        if recvbuf.len() != sendbuf.len() {
            return Err(MpiErr::SizeMismatch { local: recvbuf.len(), remote: sendbuf.len() });
        }
        recvbuf.copy_from_slice(sendbuf);
        if me > 0 {
            let mut prefix = vec![0u8; sendbuf.len()];
            self.recv(&mut prefix, me - 1, tag)?;
            // recvbuf := prefix (op) mine, preserving left-to-right order.
            let mut acc = prefix;
            reduce_bytes(op, ty, &mut acc, recvbuf)?;
            recvbuf.copy_from_slice(&acc);
        }
        if me + 1 < self.size() {
            self.send_internal(recvbuf, me + 1, tag, true)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::datatype::{as_bytes, as_bytes_mut};
    use crate::mpisim::{World, WorldConfig};
    use std::sync::atomic::{AtomicUsize, Ordering as AOrd};

    #[test]
    fn barrier_synchronizes() {
        let phase = AtomicUsize::new(0);
        World::run(WorldConfig::local(6), |mpi| {
            let c = mpi.comm_world();
            phase.fetch_add(1, AOrd::SeqCst);
            c.barrier().unwrap();
            // After the barrier every rank must observe all 6 arrivals.
            assert_eq!(phase.load(AOrd::SeqCst), 6);
        });
    }

    #[test]
    fn bcast_all_roots_all_sizes() {
        for n in [1usize, 2, 3, 5, 8] {
            World::run(WorldConfig::local(n), |mpi| {
                let c = mpi.comm_world();
                for root in 0..n {
                    let mut buf = if c.rank() == root { [0xAB, root as u8] } else { [0, 0] };
                    c.bcast(&mut buf, root).unwrap();
                    assert_eq!(buf, [0xAB, root as u8]);
                }
            });
        }
    }

    #[test]
    fn gather_in_rank_order() {
        World::run(WorldConfig::local(5), |mpi| {
            let c = mpi.comm_world();
            let mine = [c.rank() as u8; 3];
            let mut all = vec![0u8; 15];
            c.gather(&mine, if c.rank() == 2 { &mut all } else { &mut [] }, 2).unwrap();
            if c.rank() == 2 {
                for r in 0..5 {
                    assert_eq!(&all[r * 3..(r + 1) * 3], &[r as u8; 3]);
                }
            }
        });
    }

    #[test]
    fn gatherv_variable_sizes() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let mine = vec![c.rank() as u8; c.rank() + 1];
            let parts = c.gatherv(&mine, 0).unwrap();
            if c.rank() == 0 {
                let parts = parts.unwrap();
                for (r, p) in parts.iter().enumerate() {
                    assert_eq!(p, &vec![r as u8; r + 1]);
                }
            } else {
                assert!(parts.is_none());
            }
        });
    }

    #[test]
    fn scatter_distributes_chunks() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let send: Vec<u8> = if c.rank() == 1 { (0..8).collect() } else { vec![] };
            let mut mine = [0u8; 2];
            c.scatter(&send, &mut mine, 1).unwrap();
            assert_eq!(mine, [2 * c.rank() as u8, 2 * c.rank() as u8 + 1]);
        });
    }

    #[test]
    fn allgather_everyone_sees_everything() {
        World::run(WorldConfig::local(5), |mpi| {
            let c = mpi.comm_world();
            let mine = [c.rank() as u32 * 10];
            let mut all = [0u32; 5];
            c.allgather(as_bytes(&mine), as_bytes_mut(&mut all)).unwrap();
            assert_eq!(all, [0, 10, 20, 30, 40]);
        });
    }

    #[test]
    fn reduce_sum_every_root() {
        World::run(WorldConfig::local(7), |mpi| {
            let c = mpi.comm_world();
            for root in 0..7 {
                let mine = [c.rank() as i64, 1];
                let mut out = [0i64; 2];
                c.reduce(
                    as_bytes(&mine),
                    if c.rank() == root { as_bytes_mut(&mut out) } else { &mut [] },
                    MpiOp::Sum,
                    MpiType::I64,
                    root,
                )
                .unwrap();
                if c.rank() == root {
                    assert_eq!(out, [21, 7]); // 0+..+6, 7×1
                }
            }
        });
    }

    #[test]
    fn allreduce_max_f64() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let mine = [c.rank() as f64 * 1.5];
            let mut out = [0f64];
            c.allreduce(as_bytes(&mine), as_bytes_mut(&mut out), MpiOp::Max, MpiType::F64)
                .unwrap();
            assert_eq!(out[0], 4.5);
        });
    }

    #[test]
    fn alltoall_transpose() {
        World::run(WorldConfig::local(3), |mpi| {
            let c = mpi.comm_world();
            let me = c.rank() as u8;
            // send chunk j = [me, j]
            let send: Vec<u8> = (0..3).flat_map(|j| [me, j as u8]).collect();
            let mut recv = vec![0u8; 6];
            c.alltoall(&send, &mut recv, 2).unwrap();
            for src in 0..3 {
                assert_eq!(&recv[src * 2..src * 2 + 2], &[src as u8, me]);
            }
        });
    }

    #[test]
    fn scan_prefix_sums() {
        World::run(WorldConfig::local(6), |mpi| {
            let c = mpi.comm_world();
            let mine = [1i32, c.rank() as i32];
            let mut out = [0i32; 2];
            c.scan(as_bytes(&mine), as_bytes_mut(&mut out), MpiOp::Sum, MpiType::I32).unwrap();
            let r = c.rank() as i32;
            assert_eq!(out, [r + 1, r * (r + 1) / 2]);
        });
    }

    #[test]
    fn collectives_on_subcommunicator() {
        World::run(WorldConfig::local(6), |mpi| {
            let c = mpi.comm_world();
            let sub = c.split(Some((mpi.world_rank() % 2) as i32), 0).unwrap().unwrap();
            let mine = [sub.rank() as i32 + 1];
            let mut out = [0i32];
            sub.allreduce(as_bytes(&mine), as_bytes_mut(&mut out), MpiOp::Sum, MpiType::I32)
                .unwrap();
            assert_eq!(out[0], 6); // 1+2+3 in each half
        });
    }

    #[test]
    fn interleaved_collectives_and_p2p() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            // p2p traffic in flight across a barrier must not be consumed
            // by the collective machinery.
            if c.rank() == 0 {
                c.send(b"user", 3, 11).unwrap();
            }
            c.barrier().unwrap();
            let mut buf = [0u8; 5];
            c.bcast(&mut buf, 1).unwrap();
            if c.rank() == 3 {
                let (m, _) = c.recv_vec(0, 11).unwrap();
                assert_eq!(m, b"user");
            }
        });
    }
}
