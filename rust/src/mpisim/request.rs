//! Request handles for non-blocking operations, plus `wait*`/`test*`.
//!
//! MPI-3's request-based RMA (`MPI_Rput`/`MPI_Rget`) is what DART's
//! non-blocking `dart_put`/`dart_get` handles map onto (§IV-B5); the
//! completion calls here are the substrate's `MPI_Wait/Test/Waitall/Testall`.

use super::comm::Comm;
use super::error::MpiResult;
use super::p2p::Status;
use super::WorldState;
use std::sync::Arc;
use std::time::Instant;

/// Completion handle of a request-based RMA operation (`MPI_Rput`/`MPI_Rget`).
///
/// The data movement happened eagerly at initiation (the unified memory
/// model makes that legal — results are simply visible "no later than"
/// completion); the handle carries the modelled wire-completion instant.
pub struct RmaRequest {
    world: Arc<WorldState>,
    complete_at: Instant,
}

impl RmaRequest {
    pub(crate) fn new(world: Arc<WorldState>, complete_at: Instant) -> Self {
        RmaRequest { world, complete_at }
    }

    /// `MPI_Wait`: block until the operation completes.
    pub fn wait(self) {
        self.world.wait_until(self.complete_at);
    }

    /// `MPI_Test`: has the operation completed? (Non-consuming; pair with
    /// `wait` once it returns true, or just drop the request.)
    pub fn test(&self) -> bool {
        Instant::now() >= self.complete_at
    }

    /// Modelled completion instant (diagnostics).
    pub fn complete_at(&self) -> Instant {
        self.complete_at
    }

    /// `MPI_Waitall` over a set of RMA requests.
    pub fn waitall(reqs: Vec<RmaRequest>) {
        if let Some(latest) = reqs.iter().map(|r| r.complete_at).max() {
            if let Some(r) = reqs.first() {
                r.world.wait_until(latest);
            }
        }
    }

    /// `MPI_Testall`: true iff every request has completed.
    pub fn testall(reqs: &[RmaRequest]) -> bool {
        reqs.iter().all(|r| r.test())
    }
}

/// Completion handle of an eager `MPI_Isend` (locally complete at creation).
pub struct SendRequest {
    _world: Arc<WorldState>,
}

impl SendRequest {
    pub(crate) fn completed(world: Arc<WorldState>) -> Self {
        SendRequest { _world: world }
    }

    /// `MPI_Wait`: eager sends are locally complete immediately.
    pub fn wait(self) {}

    /// `MPI_Test`.
    pub fn test(&self) -> bool {
        true
    }
}

/// Handle of a posted non-blocking receive. Matching is deferred to the
/// completion call (legal: MPI only guarantees progress inside MPI calls).
pub struct RecvRequest {
    comm: Comm,
    src: usize,
    tag: i32,
}

impl RecvRequest {
    pub(crate) fn new(comm: Comm, src: usize, tag: i32) -> Self {
        RecvRequest { comm, src, tag }
    }

    /// `MPI_Wait`: block until a matching message arrives; returns it.
    pub fn wait(self) -> MpiResult<(Vec<u8>, Status)> {
        self.comm.recv_vec(self.src, self.tag)
    }

    /// `MPI_Test`: complete the receive iff a matching message is already
    /// queued.
    pub fn test(self) -> MpiResult<Result<(Vec<u8>, Status), RecvRequest>> {
        if self.comm.iprobe(self.src, self.tag) {
            Ok(Ok(self.comm.recv_vec(self.src, self.tag)?))
        } else {
            Ok(Err(self))
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::mpisim::{World, WorldConfig};

    #[test]
    fn isend_completes_immediately() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            if c.rank() == 0 {
                let r = c.isend(b"nb", 1, 0).unwrap();
                assert!(r.test());
                r.wait();
            } else {
                let (d, _) = c.recv_vec(0, 0).unwrap();
                assert_eq!(d, b"nb");
            }
        });
    }

    #[test]
    fn irecv_wait_roundtrip() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            if c.rank() == 0 {
                c.send(b"later", 1, 2).unwrap();
            } else {
                let req = c.irecv(0, 2);
                let (d, st) = req.wait().unwrap();
                assert_eq!(d, b"later");
                assert_eq!(st.source, 0);
            }
        });
    }

    #[test]
    fn irecv_test_polls() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            if c.rank() == 0 {
                c.barrier().unwrap();
                c.send(b"x", 1, 1).unwrap();
            } else {
                let mut req = c.irecv(0, 1);
                // Nothing sent yet (pre-barrier) — test must not complete.
                match req.test().unwrap() {
                    Ok(_) => panic!("completed before send"),
                    Err(r) => req = r,
                }
                c.barrier().unwrap();
                // Poll until the message lands.
                loop {
                    match req.test().unwrap() {
                        Ok((d, _)) => {
                            assert_eq!(d, b"x");
                            break;
                        }
                        Err(r) => req = r,
                    }
                }
            }
        });
    }
}
