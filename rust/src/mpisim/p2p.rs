//! Two-sided point-to-point messaging: tagged, context-isolated, eager.
//!
//! Every rank owns a [`Mailbox`]; `send` books the transfer on the
//! virtual-time channel, deposits an envelope (eager copy — the E0/E1
//! distinction is costed by the channel model, see
//! [`crate::simnet::CostModel`]) and wakes the receiver. `recv` matches by
//! `(context, source, tag)` with `MPI_ANY_SOURCE`/`MPI_ANY_TAG` wildcards
//! and non-overtaking order, then waits out the envelope's modelled wire
//! time.
//!
//! The paper's DART uses p2p in two places: internally for all collectives
//! and for the zero-byte MCS-lock hand-off notification (§IV-B6), which is
//! an `MPI_Recv` on the waiting unit.

use super::comm::Comm;
use super::error::{MpiErr, MpiResult};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Wildcard source for [`Comm::recv`] (`MPI_ANY_SOURCE`).
pub const ANY_SOURCE: usize = usize::MAX;
/// Wildcard tag for [`Comm::recv`] (`MPI_ANY_TAG`).
pub const ANY_TAG: i32 = i32::MIN;

/// Completion information of a receive (`MPI_Status`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Status {
    /// Source rank, relative to the communicator the recv was posted on.
    pub source: usize,
    /// Tag of the matched message.
    pub tag: i32,
    /// Payload length in bytes.
    pub len: usize,
}

pub(crate) struct Envelope {
    pub ctx: u32,
    pub tag: i32,
    /// Source rank relative to the sending communicator (== receiving one,
    /// since contexts are communicator-unique).
    pub src: usize,
    pub data: Vec<u8>,
    /// Modelled wire completion instant.
    pub ready_at: Instant,
}

/// Per-rank incoming-message queue.
pub struct Mailbox {
    inner: Mutex<VecDeque<Envelope>>,
    cv: Condvar,
}

impl Mailbox {
    pub(crate) fn new() -> Self {
        Mailbox { inner: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    pub(crate) fn deposit(&self, env: Envelope) {
        let mut q = self.inner.lock().unwrap();
        q.push_back(env);
        self.cv.notify_all();
    }

    /// Block until an envelope matching `(ctx, src, tag)` is available and
    /// remove it. First match in arrival order — per-pair FIFO, so delivery
    /// is non-overtaking.
    ///
    /// The condvar wait runs inside [`crate::simnet::exec::blocking`]: under
    /// pooled execution a rank parked here holds no run slot, so a bounded
    /// pool can never deadlock on unmatched receives.
    pub(crate) fn take_match(&self, ctx: u32, src: usize, tag: i32) -> Envelope {
        crate::simnet::exec::blocking(|| {
            let mut q = self.inner.lock().unwrap();
            loop {
                if let Some(pos) = q.iter().position(|e| {
                    e.ctx == ctx
                        && (src == ANY_SOURCE || e.src == src)
                        && (tag == ANY_TAG || e.tag == tag)
                }) {
                    return q.remove(pos).unwrap();
                }
                q = self.cv.wait(q).unwrap();
            }
        })
    }

    /// Non-blocking probe: true if a matching envelope is queued.
    pub(crate) fn probe(&self, ctx: u32, src: usize, tag: i32) -> bool {
        let q = self.inner.lock().unwrap();
        q.iter().any(|e| {
            e.ctx == ctx
                && (src == ANY_SOURCE || e.src == src)
                && (tag == ANY_TAG || e.tag == tag)
        })
    }
}

impl Comm {
    /// Blocking standard-mode send (`MPI_Send`). Eager: the payload is
    /// buffered at the destination and the call returns once the local
    /// buffer is reusable (immediately, since we copy).
    ///
    /// User tags must be non-negative; negative tags are reserved for the
    /// collective machinery.
    pub fn send(&self, buf: &[u8], dst: usize, tag: i32) -> MpiResult<()> {
        self.send_internal(buf, dst, tag, false)
    }

    pub(crate) fn send_internal(
        &self,
        buf: &[u8],
        dst: usize,
        tag: i32,
        internal: bool,
    ) -> MpiResult<()> {
        if !internal && tag < 0 {
            return Err(MpiErr::Invalid(format!("user tag must be >= 0, got {tag}")));
        }
        let dst_world = self.world_rank_of(dst)?;
        let ready_at = self.world().book_transfer(self.my_world(), dst_world, buf.len());
        self.world().mailboxes[dst_world].deposit(Envelope {
            ctx: self.context(),
            tag,
            src: self.rank(),
            data: buf.to_vec(),
            ready_at,
        });
        Ok(())
    }

    /// Blocking receive (`MPI_Recv`). `src`/`tag` accept [`ANY_SOURCE`] /
    /// [`ANY_TAG`]. The payload must fit in `buf` (truncation is an error,
    /// like `MPI_ERR_TRUNCATE`); shorter messages are allowed.
    pub fn recv(&self, buf: &mut [u8], src: usize, tag: i32) -> MpiResult<Status> {
        if src != ANY_SOURCE {
            self.world_rank_of(src)?; // validate
        }
        let env = self.world().mailboxes[self.my_world()].take_match(self.context(), src, tag);
        self.world().wait_until(env.ready_at);
        if env.data.len() > buf.len() {
            return Err(MpiErr::SizeMismatch { local: buf.len(), remote: env.data.len() });
        }
        buf[..env.data.len()].copy_from_slice(&env.data);
        Ok(Status { source: env.src, tag: env.tag, len: env.data.len() })
    }

    /// Blocking receive into a fresh vector (for variable-size payloads).
    pub fn recv_vec(&self, src: usize, tag: i32) -> MpiResult<(Vec<u8>, Status)> {
        if src != ANY_SOURCE {
            self.world_rank_of(src)?;
        }
        let env = self.world().mailboxes[self.my_world()].take_match(self.context(), src, tag);
        self.world().wait_until(env.ready_at);
        let status = Status { source: env.src, tag: env.tag, len: env.data.len() };
        Ok((env.data, status))
    }

    /// Non-blocking send (`MPI_Isend`). Eager, so the returned request
    /// completes at the modelled local-completion instant.
    pub fn isend(&self, buf: &[u8], dst: usize, tag: i32) -> MpiResult<super::SendRequest> {
        self.send(buf, dst, tag)?;
        Ok(super::SendRequest::completed(self.world().clone()))
    }

    /// Non-blocking receive (`MPI_Irecv`). Matching is deferred to
    /// `wait`/`test` on the returned request (legal MPI behaviour: progress
    /// may happen inside completion calls).
    pub fn irecv(&self, src: usize, tag: i32) -> super::RecvRequest {
        super::RecvRequest::new(self.clone(), src, tag)
    }

    /// Non-blocking probe (`MPI_Iprobe`): is a matching message queued?
    pub fn iprobe(&self, src: usize, tag: i32) -> bool {
        self.world().mailboxes[self.my_world()].probe(self.context(), src, tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::{World, WorldConfig};

    #[test]
    fn send_recv_roundtrip() {
        World::run(WorldConfig::local(2), |mpi| {
            let comm = mpi.comm_world();
            if comm.rank() == 0 {
                comm.send(b"hello", 1, 7).unwrap();
            } else {
                let mut buf = [0u8; 16];
                let st = comm.recv(&mut buf, 0, 7).unwrap();
                assert_eq!(&buf[..st.len], b"hello");
                assert_eq!(st.source, 0);
                assert_eq!(st.tag, 7);
            }
        });
    }

    #[test]
    fn any_source_any_tag() {
        World::run(WorldConfig::local(3), |mpi| {
            let comm = mpi.comm_world();
            if comm.rank() != 0 {
                comm.send(&[comm.rank() as u8], 0, comm.rank() as i32).unwrap();
            } else {
                let mut seen = [false; 3];
                for _ in 0..2 {
                    let (data, st) = comm.recv_vec(ANY_SOURCE, ANY_TAG).unwrap();
                    assert_eq!(data[0] as usize, st.source);
                    assert_eq!(st.tag as usize, st.source);
                    seen[st.source] = true;
                }
                assert!(seen[1] && seen[2]);
            }
        });
    }

    #[test]
    fn non_overtaking_same_pair() {
        World::run(WorldConfig::local(2), |mpi| {
            let comm = mpi.comm_world();
            if comm.rank() == 0 {
                for i in 0..100u32 {
                    comm.send(&i.to_ne_bytes(), 1, 5).unwrap();
                }
            } else {
                for i in 0..100u32 {
                    let mut b = [0u8; 4];
                    comm.recv(&mut b, 0, 5).unwrap();
                    assert_eq!(u32::from_ne_bytes(b), i);
                }
            }
        });
    }

    #[test]
    fn tag_selectivity() {
        World::run(WorldConfig::local(2), |mpi| {
            let comm = mpi.comm_world();
            if comm.rank() == 0 {
                comm.send(b"a", 1, 1).unwrap();
                comm.send(b"b", 1, 2).unwrap();
            } else {
                // receive tag 2 first even though tag 1 arrived first
                let (d2, _) = comm.recv_vec(0, 2).unwrap();
                let (d1, _) = comm.recv_vec(0, 1).unwrap();
                assert_eq!((d1.as_slice(), d2.as_slice()), (&b"a"[..], &b"b"[..]));
            }
        });
    }

    #[test]
    fn self_send() {
        World::run(WorldConfig::local(1), |mpi| {
            let comm = mpi.comm_world();
            comm.send(b"self", 0, 3).unwrap();
            let (d, st) = comm.recv_vec(0, 3).unwrap();
            assert_eq!(d, b"self");
            assert_eq!(st.source, 0);
        });
    }

    #[test]
    fn truncation_is_error() {
        World::run(WorldConfig::local(2), |mpi| {
            let comm = mpi.comm_world();
            if comm.rank() == 0 {
                comm.send(&[0u8; 8], 1, 0).unwrap();
            } else {
                let mut small = [0u8; 4];
                assert!(matches!(
                    comm.recv(&mut small, 0, 0),
                    Err(MpiErr::SizeMismatch { .. })
                ));
            }
        });
    }

    #[test]
    fn negative_user_tag_rejected() {
        World::run(WorldConfig::local(1), |mpi| {
            let comm = mpi.comm_world();
            assert!(comm.send(b"", 0, -1).is_err());
        });
    }

    #[test]
    fn zero_byte_message() {
        // The MCS lock hand-off is a zero-size notification (§IV-B6).
        World::run(WorldConfig::local(2), |mpi| {
            let comm = mpi.comm_world();
            if comm.rank() == 0 {
                comm.send(&[], 1, 9).unwrap();
            } else {
                let st = comm.recv(&mut [], 0, 9).unwrap();
                assert_eq!(st.len, 0);
            }
        });
    }

    #[test]
    fn iprobe_sees_queued_message() {
        World::run(WorldConfig::local(2), |mpi| {
            let comm = mpi.comm_world();
            if comm.rank() == 0 {
                comm.send(b"x", 1, 4).unwrap();
                comm.send(b"done", 1, 5).unwrap();
            } else {
                comm.recv_vec(0, 5).unwrap(); // after this, tag-4 msg must be visible
                assert!(comm.iprobe(0, 4));
                assert!(!comm.iprobe(0, 6));
                comm.recv_vec(0, 4).unwrap();
            }
        });
    }
}
