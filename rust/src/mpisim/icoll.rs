//! Nonblocking collectives (`MPI_Ibarrier`/`MPI_Ibcast`/`MPI_Iallgather`/
//! `MPI_Iallreduce`) as progress-engine state machines.
//!
//! MPI-3 turns collectives into *schedules*: an initiation call posts the
//! rank's participation and returns a request; the schedule advances
//! whenever the library makes progress, and the request completes once the
//! rank's part of the schedule is done. This module implements that model
//! over shared state so that *any* agent — the calling rank inside
//! `test`/`wait` ([`crate::mpisim::ProgressMode::Caller`]), a background
//! thread ([`crate::mpisim::ProgressMode::Thread`]) or a cooperative poll
//! ([`crate::mpisim::ProgressMode::Polling`]) — can advance it:
//!
//! - every initiation enqueues the rank's **contribution** into a shared
//!   `CollState` (crate-internal) keyed by `(communicator context,
//!   collective sequence number)` — the same matching rule the blocking
//!   collectives use, so
//!   blocking and nonblocking calls interleave safely as long as all ranks
//!   issue collectives in the same order (an MPI requirement);
//! - when the state machine's inputs are complete (all ranks arrived, or
//!   the root posted for a bcast), the next progress step performs the
//!   **combining work** (gather assembly, reduction) and books the
//!   fan-out transfers on the virtual-time channel model — this is the
//!   work that a busy compute loop cannot do for itself, and exactly what
//!   the asynchronous progress engine exists to run in the background;
//! - each rank's [`CollRequest`] completes once its modelled transfer
//!   instant has passed; completion copies the staged result into the
//!   rank's output buffer (held by `&mut` borrow for the request's
//!   lifetime, so the MPI don't-touch-the-buffer rule is compiler-checked).
//!
//! The cost schedules mirror the logarithmic algorithms of the blocking
//! collectives ([`crate::mpisim::collectives`]): a barrier books a
//! binomial notification tree rooted at the last arrival, a bcast a
//! binomial tree from the root, allgather/allreduce the doubling rounds of
//! Bruck / recursive doubling — each hop booked with
//! `book_transfer_after`, so a child's transfer cannot start before its
//! parent's delivered and no rank is the endpoint of O(n) bookings.

use super::comm::Comm;
use super::datatype::{reduce_bytes, MpiOp, MpiType};
use super::error::{MpiErr, MpiResult};
use super::WorldState;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Which collective a [`CollState`] implements, with its static parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum CollKind {
    /// `MPI_Ibarrier`.
    Barrier,
    /// `MPI_Ibcast` from comm-relative `root`.
    Bcast { root: usize },
    /// `MPI_Iallgather` with equal per-rank contributions of `chunk` bytes.
    Allgather { chunk: usize },
    /// `MPI_Iallreduce` over `chunk`-byte buffers of `ty` elements.
    Allreduce { chunk: usize, op: MpiOp, ty: MpiType },
}

/// Shared state machine of one in-flight nonblocking collective.
pub(crate) struct CollState {
    kind: CollKind,
    n: usize,
    /// Comm rank → world rank (for channel bookings).
    ranks: Vec<usize>,
    inner: Mutex<CollInner>,
}

struct CollInner {
    /// Per-rank staged input (`None` until that rank initiates).
    contributions: Vec<Option<Vec<u8>>>,
    arrived: Vec<bool>,
    arrived_count: usize,
    /// Comm rank of the most recent arrival (models the notifier).
    last_arrival: usize,
    /// Staged output, set by the combining step (empty marker for barrier).
    result: Option<Vec<u8>>,
    /// Per-rank modelled completion instant, stamped with the result.
    complete_at: Vec<Option<Instant>>,
    /// Has the fan-out schedule been booked on the channel model? (Guards
    /// one-time booking for kinds whose result is staged before the
    /// schedule can run, i.e. bcast.)
    scheduled: bool,
    /// Ranks that have observed completion (state is dropped at `n`).
    finished: usize,
}

impl CollState {
    fn new(kind: CollKind, n: usize, ranks: Vec<usize>) -> Self {
        CollState {
            kind,
            n,
            ranks,
            inner: Mutex::new(CollInner {
                contributions: (0..n).map(|_| None).collect(),
                arrived: vec![false; n],
                arrived_count: 0,
                last_arrival: 0,
                result: None,
                complete_at: vec![None; n],
                scheduled: false,
                finished: 0,
            }),
        }
    }

    fn kind(&self) -> CollKind {
        self.kind
    }

    /// Record rank `me`'s initiation (with its staged contribution).
    ///
    /// Deliberately does **not** run a progress step: initiation only
    /// posts the schedule. The combining work happens in whoever advances
    /// next — a background tick, a poll, or a caller-side `test`/`wait` —
    /// which is precisely the observable difference between the progress
    /// modes (in `Caller` mode, a collective initiated before a compute
    /// phase makes zero headway until the compute phase ends).
    fn arrive(&self, me: usize, contribution: Option<Vec<u8>>) -> MpiResult<()> {
        {
            let mut inn = self.inner.lock().unwrap();
            if inn.arrived[me] {
                return Err(MpiErr::Invalid(
                    "rank initiated the same nonblocking collective twice".into(),
                ));
            }
            if let CollKind::Allgather { chunk } | CollKind::Allreduce { chunk, .. } = self.kind {
                let got = contribution.as_ref().map_or(0, |c| c.len());
                if got != chunk {
                    return Err(MpiErr::SizeMismatch { local: got, remote: chunk });
                }
            }
            inn.arrived[me] = true;
            inn.arrived_count += 1;
            inn.last_arrival = me;
            match self.kind {
                CollKind::Bcast { root } if me == root => {
                    // The root is locally complete as soon as its payload
                    // is staged (its buffer is input, never written).
                    inn.result = contribution;
                    inn.complete_at[me] = Some(Instant::now());
                }
                _ => inn.contributions[me] = contribution,
            }
        }
        Ok(())
    }

    /// Book a binomial-tree fan-out rooted at comm rank `root_c`: hop
    /// `parent → child` starts no earlier than the parent's own arrival
    /// instant, so depth accumulates logarithmically. Returns each comm
    /// rank's modelled arrival instant.
    fn book_binomial_tree(
        &self,
        world: &WorldState,
        root_c: usize,
        bytes: usize,
    ) -> Vec<Instant> {
        let n = self.n;
        // at[v] is indexed by vrank; vrank v is comm rank (v + root_c) % n.
        let mut at = vec![Instant::now(); n];
        // Ascending vrank order guarantees a parent's instant is final
        // before its (always higher-vrank) children read it.
        for v in 0..n {
            let lowest = if v == 0 { n.next_power_of_two() } else { v & v.wrapping_neg() };
            let mut bit = 1;
            while bit < lowest && v + bit < n {
                let parent = self.ranks[(v + root_c) % n];
                let child = self.ranks[(v + bit + root_c) % n];
                at[v + bit] = world.book_transfer_after(parent, child, bytes, at[v]);
                bit <<= 1;
            }
        }
        // Un-rotate to comm-rank indexing.
        let mut out = vec![Instant::now(); n];
        for (v, t) in at.into_iter().enumerate() {
            out[(v + root_c) % n] = t;
        }
        out
    }

    /// Book doubling rounds (Bruck / recursive doubling): in round `h ∈
    /// {1, 2, 4, …}` comm rank `r` receives `per_round(h)` bytes from
    /// `(r + h) % n`, ready when both endpoints finished the previous
    /// round. Returns each comm rank's final-round completion instant.
    fn book_doubling_rounds(
        &self,
        world: &WorldState,
        per_round: impl Fn(usize) -> usize,
    ) -> Vec<Instant> {
        let n = self.n;
        let mut at = vec![Instant::now(); n];
        let mut have = 1usize;
        while have < n {
            let bytes = per_round(have);
            // A fresh vec per round: every rank's new instant reads only
            // the previous round's values, independent of iteration order.
            let mut next = vec![Instant::now(); n];
            for r in 0..n {
                let src = (r + have) % n;
                let ready = at[r].max(at[src]);
                next[r] =
                    world.book_transfer_after(self.ranks[src], self.ranks[r], bytes, ready);
            }
            at = next;
            have += have.min(n - have);
        }
        at
    }

    /// One progress step: if the state machine's inputs are complete, do
    /// the combining work and stamp per-rank completion instants. Safe to
    /// call from any thread, any number of times (transitions are guarded).
    pub(crate) fn advance(&self, world: &WorldState) {
        let mut inn = self.inner.lock().unwrap();
        match self.kind {
            CollKind::Barrier => {
                if inn.arrived_count == self.n && inn.result.is_none() {
                    inn.result = Some(Vec::new());
                    // Zero-byte notification tree rooted at the last
                    // arrival — no rank is notified by O(n) hops.
                    let at = self.book_binomial_tree(world, inn.last_arrival, 0);
                    for (r, t) in at.into_iter().enumerate() {
                        inn.complete_at[r] = Some(t);
                    }
                }
            }
            CollKind::Bcast { root } => {
                if inn.result.is_some() && !inn.scheduled {
                    inn.scheduled = true;
                    let len = inn.result.as_ref().map_or(0, |d| d.len());
                    // Full binomial tree booked once when the root's
                    // payload is staged; ranks arriving later find their
                    // instant already stamped (eager delivery — same as a
                    // message waiting in a mailbox).
                    let at = self.book_binomial_tree(world, root, len);
                    for (r, t) in at.into_iter().enumerate() {
                        if r != root {
                            inn.complete_at[r] = Some(t);
                        }
                    }
                }
            }
            CollKind::Allgather { chunk } => {
                if inn.arrived_count == self.n && inn.result.is_none() {
                    let mut out = Vec::with_capacity(self.n * chunk);
                    for c in &inn.contributions {
                        out.extend_from_slice(c.as_ref().expect("all ranks contributed"));
                    }
                    inn.result = Some(out);
                    // Bruck rounds: round h moves min(h, n-h) chunks.
                    let n = self.n;
                    let at =
                        self.book_doubling_rounds(world, |h| chunk * h.min(n - h));
                    for (r, t) in at.into_iter().enumerate() {
                        inn.complete_at[r] = Some(t);
                    }
                }
            }
            CollKind::Allreduce { chunk, op, ty } => {
                if inn.arrived_count == self.n && inn.result.is_none() {
                    let mut acc = inn.contributions[0].clone().expect("rank 0 contributed");
                    for c in &inn.contributions[1..] {
                        // Lengths and element size were validated at
                        // initiation, so this cannot fail.
                        reduce_bytes(op, ty, &mut acc, c.as_ref().expect("contributed"))
                            .expect("validated at initiation");
                    }
                    inn.result = Some(acc);
                    // Recursive doubling: a chunk-sized exchange per round.
                    let at = self.book_doubling_rounds(world, |_| chunk);
                    for (r, t) in at.into_iter().enumerate() {
                        inn.complete_at[r] = Some(t);
                    }
                }
            }
        }
    }

    /// If rank `me`'s schedule has completed, copy the staged result into
    /// `dst` (taken out of the option) and count the rank finished.
    /// Returns `None` while incomplete, else `Some(all_ranks_finished)`.
    fn try_complete(&self, me: usize, dst: &mut Option<&mut [u8]>) -> Option<bool> {
        let mut inn = self.inner.lock().unwrap();
        match inn.complete_at[me] {
            Some(t) if Instant::now() >= t => {}
            _ => return None,
        }
        if let Some(d) = dst.take() {
            let res = inn.result.as_ref().expect("result staged before completion stamp");
            assert_eq!(
                d.len(),
                res.len(),
                "nonblocking-collective output buffer length mismatch"
            );
            d.copy_from_slice(res);
        }
        inn.finished += 1;
        Some(inn.finished == self.n)
    }
}

/// Completion handle of a nonblocking collective (`MPI_Request` of the
/// `MPI_I*` family).
///
/// Holds the rank's output buffer by `&mut` borrow until completion, so the
/// MPI rule that the buffer may not be touched while the collective is in
/// flight is enforced by the compiler. Complete with [`CollRequest::wait`]
/// or poll with [`CollRequest::test`]; dropping an incomplete request
/// leaks the collective's shared state for the lifetime of the world (MPI
/// makes abandoning an active request erroneous — don't).
pub struct CollRequest<'buf> {
    world: Arc<WorldState>,
    st: Arc<CollState>,
    key: u64,
    my_rank: usize,
    dst: Option<&'buf mut [u8]>,
    done: bool,
    _not_send: std::marker::PhantomData<*const ()>,
}

impl<'buf> CollRequest<'buf> {
    /// `MPI_Test`: drive one caller-side progress step and report whether
    /// this rank's part of the collective has completed. On the completing
    /// call the staged result is copied into the output buffer.
    pub fn test(&mut self) -> bool {
        if self.done {
            return true;
        }
        // Progress is legal inside any MPI call — this is what makes the
        // Caller mode work at all.
        self.st.advance(&self.world);
        if let Some(all_finished) = self.st.try_complete(self.my_rank, &mut self.dst) {
            self.done = true;
            if all_finished {
                self.world.progress.colls.lock().unwrap().remove(&self.key);
            }
        }
        self.done
    }

    /// `MPI_Wait`: block (spin-yield) until the collective completes for
    /// this rank.
    pub fn wait(mut self) {
        while !self.test() {
            crate::simnet::exec::coop_yield();
        }
    }

    /// Has completion already been observed (without driving progress)?
    pub fn is_done(&self) -> bool {
        self.done
    }
}

impl Comm {
    /// Post one rank's initiation: find or create the shared state for
    /// this `(context, seq)` slot, verify the call matches, and arrive.
    fn icoll_start(
        &self,
        kind: CollKind,
        contribution: Option<Vec<u8>>,
    ) -> MpiResult<(Arc<CollState>, u64)> {
        let seq = self.coll_seq.fetch_add(1, Ordering::Relaxed);
        let key = ((self.context() as u64) << 32) | seq as u64;
        let st = {
            let mut map = self.world().progress.colls.lock().unwrap();
            map.entry(key)
                .or_insert_with(|| {
                    Arc::new(CollState::new(kind, self.size(), self.rank_table().to_vec()))
                })
                .clone()
        };
        if st.kind() != kind {
            return Err(MpiErr::Invalid(format!(
                "mismatched nonblocking collective at the same sequence point: \
                 {:?} vs {:?} (all ranks must issue collectives in the same order)",
                st.kind(),
                kind
            )));
        }
        st.arrive(self.rank(), contribution)?;
        Ok((st, key))
    }

    fn icoll_request<'buf>(
        &self,
        st: Arc<CollState>,
        key: u64,
        dst: Option<&'buf mut [u8]>,
    ) -> CollRequest<'buf> {
        CollRequest {
            world: self.world().clone(),
            st,
            key,
            my_rank: self.rank(),
            dst,
            done: false,
            _not_send: std::marker::PhantomData,
        }
    }

    /// `MPI_Ibarrier`: the request completes only after *every* rank of the
    /// communicator has entered the barrier.
    pub fn ibarrier(&self) -> MpiResult<CollRequest<'static>> {
        let (st, key) = self.icoll_start(CollKind::Barrier, None)?;
        Ok(self.icoll_request(st, key, None))
    }

    /// `MPI_Ibcast`: `buf` is the payload at `root` (staged at initiation,
    /// so the root's request completes immediately) and the output buffer
    /// everywhere else (filled at completion, byte-for-byte identical to
    /// what [`Comm::bcast`] would deliver).
    ///
    /// A non-root buffer whose length differs from the root's payload is a
    /// program error (MPI: erroneous); it cannot be detected at initiation
    /// — the payload size is unknown until the root posts — so it panics
    /// at the completing `test`/`wait` instead of returning an error.
    pub fn ibcast<'buf>(&self, buf: &'buf mut [u8], root: usize) -> MpiResult<CollRequest<'buf>> {
        if root >= self.size() {
            return Err(MpiErr::RankOutOfRange(root, self.size()));
        }
        let me = self.rank();
        let contribution = (me == root).then(|| buf.to_vec());
        let (st, key) = self.icoll_start(CollKind::Bcast { root }, contribution)?;
        let dst = if me == root { None } else { Some(buf) };
        Ok(self.icoll_request(st, key, dst))
    }

    /// `MPI_Iallgather` (equal contribution sizes): at completion `recv`
    /// (length `size() × send.len()`) holds every rank's contribution in
    /// rank order.
    pub fn iallgather<'buf>(
        &self,
        send: &[u8],
        recv: &'buf mut [u8],
    ) -> MpiResult<CollRequest<'buf>> {
        let want = self.size() * send.len();
        if recv.len() != want {
            return Err(MpiErr::SizeMismatch { local: recv.len(), remote: want });
        }
        let (st, key) =
            self.icoll_start(CollKind::Allgather { chunk: send.len() }, Some(send.to_vec()))?;
        Ok(self.icoll_request(st, key, Some(recv)))
    }

    /// `MPI_Iallreduce`: element-wise `(op, ty)` reduction of every rank's
    /// `send` into every rank's `recv` (same length). The reduction itself
    /// runs as progress work — in Thread mode, on the background thread.
    pub fn iallreduce<'buf>(
        &self,
        send: &[u8],
        recv: &'buf mut [u8],
        op: MpiOp,
        ty: MpiType,
    ) -> MpiResult<CollRequest<'buf>> {
        if recv.len() != send.len() {
            return Err(MpiErr::SizeMismatch { local: recv.len(), remote: send.len() });
        }
        if send.len() % ty.size() != 0 {
            return Err(MpiErr::TypeMismatch { type_size: ty.size(), buf: send.len() });
        }
        let (st, key) = self.icoll_start(
            CollKind::Allreduce { chunk: send.len(), op, ty },
            Some(send.to_vec()),
        )?;
        Ok(self.icoll_request(st, key, Some(recv)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::datatype::{as_bytes, as_bytes_mut};
    use crate::mpisim::{ProgressMode, World, WorldConfig};
    use std::sync::atomic::AtomicBool;
    use std::sync::atomic::Ordering as AOrd;
    use std::time::Duration;

    #[test]
    fn ibarrier_gates_on_last_rank() {
        let released = AtomicBool::new(false);
        World::run(WorldConfig::local(3), |mpi| {
            let c = mpi.comm_world();
            if c.rank() == 2 {
                std::thread::sleep(Duration::from_millis(20));
                released.store(true, AOrd::SeqCst);
                c.ibarrier().unwrap().wait();
            } else {
                let mut req = c.ibarrier().unwrap();
                while !released.load(AOrd::SeqCst) {
                    assert!(!req.test(), "ibarrier completed before all ranks entered");
                    std::thread::yield_now();
                }
                req.wait();
            }
        });
    }

    #[test]
    fn ibcast_matches_blocking_bcast() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            for root in 0..4 {
                let pattern: Vec<u8> = (0..32).map(|i| (i * 7 + root) as u8).collect();
                let mut blocking = if c.rank() == root { pattern.clone() } else { vec![0; 32] };
                c.bcast(&mut blocking, root).unwrap();
                let mut nb = if c.rank() == root { pattern.clone() } else { vec![0; 32] };
                c.ibcast(&mut nb, root).unwrap().wait();
                assert_eq!(nb, blocking, "root {root}");
            }
        });
    }

    #[test]
    fn iallgather_in_rank_order() {
        World::run(WorldConfig::local(5), |mpi| {
            let c = mpi.comm_world();
            let mine = [c.rank() as u8; 3];
            let mut all = [0u8; 15];
            c.iallgather(&mine, &mut all).unwrap().wait();
            for r in 0..5 {
                assert_eq!(&all[r * 3..(r + 1) * 3], &[r as u8; 3]);
            }
        });
    }

    #[test]
    fn iallreduce_sums_like_blocking() {
        World::run(WorldConfig::local(6), |mpi| {
            let c = mpi.comm_world();
            let mine = [c.rank() as i64, 1];
            let mut nb = [0i64; 2];
            c.iallreduce(as_bytes(&mine), as_bytes_mut(&mut nb), MpiOp::Sum, MpiType::I64)
                .unwrap()
                .wait();
            assert_eq!(nb, [15, 6]); // 0+..+5, 6×1
        });
    }

    #[test]
    fn two_overlapping_nonblocking_collectives() {
        World::run(WorldConfig::local(3), |mpi| {
            let c = mpi.comm_world();
            let mut b1 = if c.rank() == 0 { [11u8; 8] } else { [0u8; 8] };
            let mut b2 = if c.rank() == 1 { [22u8; 8] } else { [0u8; 8] };
            // Initiate both before completing either; same order everywhere.
            let r1 = c.ibcast(&mut b1, 0).unwrap();
            let r2 = c.ibcast(&mut b2, 1).unwrap();
            r2.wait();
            r1.wait();
            assert_eq!(b1, [11u8; 8]);
            assert_eq!(b2, [22u8; 8]);
        });
    }

    #[test]
    fn thread_mode_advances_without_caller_progress() {
        let mut cfg = WorldConfig::hermit(2, 1);
        cfg.progress = ProgressMode::Thread;
        World::run(cfg, |mpi| {
            let c = mpi.comm_world();
            let mine = [mpi.world_rank() as i64 + 1];
            let mut out = [0i64];
            let mut req = c
                .iallreduce(as_bytes(&mine), as_bytes_mut(&mut out), MpiOp::Sum, MpiType::I64)
                .unwrap();
            // Compute (sleep) without touching the library; the background
            // thread performs the reduction meanwhile. `is_done` stays
            // honest (no caller-side progress), `test` observes the result.
            std::thread::sleep(Duration::from_millis(10));
            assert!(!req.is_done());
            while !req.test() {
                std::thread::yield_now();
            }
            assert_eq!(out, [3]);
        });
    }

    #[test]
    fn size_mismatches_are_rejected() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let mut small = [0u8; 4];
            assert!(matches!(
                c.iallgather(&[1u8; 4], &mut small),
                Err(MpiErr::SizeMismatch { .. })
            ));
            // Both ranks must fail identically to stay in lock-step.
            let mut odd = [0u8; 6];
            assert!(matches!(
                c.iallreduce(&[0u8; 6], &mut odd, MpiOp::Sum, MpiType::I32),
                Err(MpiErr::TypeMismatch { .. })
            ));
        });
    }
}
