//! Typed views over byte buffers plus the MPI reduction-op machinery.
//!
//! The substrate moves raw bytes (like real MPI's `void*` + datatype); this
//! module provides the safe typed casts used at the API boundary and the
//! `(op, datatype)` dispatch used by `MPI_Accumulate`, `MPI_Reduce` and the
//! atomics (`fetch_and_op`, `compare_and_swap`).

use super::error::{MpiErr, MpiResult};

/// Marker trait for plain-old-data element types that can cross the
/// substrate as raw bytes.
///
/// # Safety
/// Implementors must be `Copy`, have no padding with illegal values and be
/// valid for any bit pattern (all primitive numeric types qualify).
pub unsafe trait Pod: Copy + Send + Sync + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for i8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for i16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for usize {}
unsafe impl Pod for isize {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}

/// Reinterpret a typed slice as bytes.
pub fn as_bytes<T: Pod>(s: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, std::mem::size_of_val(s)) }
}

/// Reinterpret a typed mutable slice as bytes.
pub fn as_bytes_mut<T: Pod>(s: &mut [T]) -> &mut [u8] {
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr() as *mut u8, std::mem::size_of_val(s)) }
}

/// The element datatypes understood by the reduction machinery
/// (a subset of MPI's predefined datatypes, enough for DART).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // self-describing width/signedness tags
pub enum MpiType {
    U8,
    I16,
    I32,
    U32,
    I64,
    U64,
    F32,
    F64,
}

impl MpiType {
    /// Size of one element in bytes.
    pub fn size(&self) -> usize {
        match self {
            MpiType::U8 => 1,
            MpiType::I16 => 2,
            MpiType::I32 | MpiType::U32 | MpiType::F32 => 4,
            MpiType::I64 | MpiType::U64 | MpiType::F64 => 8,
        }
    }
}

/// Trait connecting Rust element types to their [`MpiType`] tag.
pub trait HasMpiType: Pod {
    /// The wire datatype tag of `Self`.
    const MPI_TYPE: MpiType;
}

impl HasMpiType for u8 {
    const MPI_TYPE: MpiType = MpiType::U8;
}
impl HasMpiType for i16 {
    const MPI_TYPE: MpiType = MpiType::I16;
}
impl HasMpiType for i32 {
    const MPI_TYPE: MpiType = MpiType::I32;
}
impl HasMpiType for u32 {
    const MPI_TYPE: MpiType = MpiType::U32;
}
impl HasMpiType for i64 {
    const MPI_TYPE: MpiType = MpiType::I64;
}
impl HasMpiType for u64 {
    const MPI_TYPE: MpiType = MpiType::U64;
}
impl HasMpiType for f32 {
    const MPI_TYPE: MpiType = MpiType::F32;
}
impl HasMpiType for f64 {
    const MPI_TYPE: MpiType = MpiType::F64;
}

/// An `MPI_Type_vector`-style strided datatype: `count` blocks of `block`
/// bytes, consecutive blocks `stride` bytes apart at the *remote* side.
/// The origin buffer is always packed (`count × block` contiguous bytes).
///
/// This is the access shape of a column halo in a row-major grid. Moving
/// it through [`crate::mpisim::Win::rput_vector`] /
/// [`crate::mpisim::Win::rget_vector`] costs **one** protocol handshake —
/// the way Cray MPICH packs non-contiguous transfers into a single
/// message — instead of one per block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VectorType {
    count: usize,
    block: usize,
    stride: usize,
}

impl VectorType {
    /// Build a vector type. `stride ≥ block` (blocks may not overlap;
    /// `stride == block` degenerates to a contiguous transfer).
    pub fn new(count: usize, block: usize, stride: usize) -> MpiResult<VectorType> {
        if stride < block {
            return Err(MpiErr::Invalid(format!(
                "vector type: stride {stride} smaller than block {block}"
            )));
        }
        Ok(VectorType { count, block, stride })
    }

    /// Number of blocks.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Block length in bytes.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Remote distance between block starts in bytes.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Bytes actually transferred (`count × block` — the packed size).
    pub fn packed_len(&self) -> usize {
        self.count * self.block
    }

    /// Remote footprint: distance from the first block's first byte to the
    /// last block's last byte.
    pub fn extent(&self) -> usize {
        if self.count == 0 {
            0
        } else {
            (self.count - 1) * self.stride + self.block
        }
    }
}

/// Predefined reduction / accumulate operations (MPI_SUM, MPI_REPLACE, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MpiOp {
    /// Element-wise sum (wrapping for integers, like MPI in practice).
    Sum,
    /// Element-wise product.
    Prod,
    /// Element-wise minimum.
    Min,
    /// Element-wise maximum.
    Max,
    /// Bitwise AND (integer types only).
    Band,
    /// Bitwise OR (integer types only).
    Bor,
    /// Bitwise XOR (integer types only).
    Bxor,
    /// `MPI_REPLACE` — target := origin (used by `fetch_and_op` to get
    /// atomic swap semantics, as the paper's MCS lock does).
    Replace,
    /// `MPI_NO_OP` — target unchanged (used by `fetch_and_op` to get an
    /// atomic read).
    NoOp,
}

macro_rules! arith_case {
    ($op:expr, $t:ty, $acc:expr, $src:expr) => {{
        let n = std::mem::size_of::<$t>();
        debug_assert_eq!($acc.len() % n, 0);
        for (a, s) in $acc.chunks_exact_mut(n).zip($src.chunks_exact(n)) {
            let mut av = <$t>::from_ne_bytes(a.try_into().unwrap());
            let sv = <$t>::from_ne_bytes(s.try_into().unwrap());
            av = apply_scalar::<$t>($op, av, sv);
            a.copy_from_slice(&av.to_ne_bytes());
        }
    }};
}

trait Scalar: Copy + PartialOrd {
    fn add(a: Self, b: Self) -> Self;
    fn mul(a: Self, b: Self) -> Self;
    fn band(a: Self, b: Self) -> Self;
    fn bor(a: Self, b: Self) -> Self;
    fn bxor(a: Self, b: Self) -> Self;
}

macro_rules! scalar_int {
    ($($t:ty),*) => {$(
        impl Scalar for $t {
            fn add(a: Self, b: Self) -> Self { a.wrapping_add(b) }
            fn mul(a: Self, b: Self) -> Self { a.wrapping_mul(b) }
            fn band(a: Self, b: Self) -> Self { a & b }
            fn bor(a: Self, b: Self) -> Self { a | b }
            fn bxor(a: Self, b: Self) -> Self { a ^ b }
        }
    )*};
}
scalar_int!(u8, i16, i32, u32, i64, u64);

macro_rules! scalar_float {
    ($($t:ty),*) => {$(
        impl Scalar for $t {
            fn add(a: Self, b: Self) -> Self { a + b }
            fn mul(a: Self, b: Self) -> Self { a * b }
            fn band(_: Self, _: Self) -> Self { panic!("bitwise op on float") }
            fn bor(_: Self, _: Self) -> Self { panic!("bitwise op on float") }
            fn bxor(_: Self, _: Self) -> Self { panic!("bitwise op on float") }
        }
    )*};
}
scalar_float!(f32, f64);

fn apply_scalar<T: Scalar>(op: MpiOp, acc: T, src: T) -> T {
    match op {
        MpiOp::Sum => T::add(acc, src),
        MpiOp::Prod => T::mul(acc, src),
        MpiOp::Min => {
            if src < acc {
                src
            } else {
                acc
            }
        }
        MpiOp::Max => {
            if src > acc {
                src
            } else {
                acc
            }
        }
        MpiOp::Band => T::band(acc, src),
        MpiOp::Bor => T::bor(acc, src),
        MpiOp::Bxor => T::bxor(acc, src),
        MpiOp::Replace => src,
        MpiOp::NoOp => acc,
    }
}

/// Element-wise `acc := acc (op) src` over byte buffers interpreted as
/// `ty`-typed arrays. Both buffers must be a multiple of the element size
/// and equal length.
pub fn reduce_bytes(op: MpiOp, ty: MpiType, acc: &mut [u8], src: &[u8]) -> MpiResult<()> {
    if acc.len() != src.len() {
        return Err(MpiErr::SizeMismatch { local: src.len(), remote: acc.len() });
    }
    if acc.len() % ty.size() != 0 {
        return Err(MpiErr::TypeMismatch { type_size: ty.size(), buf: acc.len() });
    }
    match ty {
        MpiType::U8 => arith_case!(op, u8, acc, src),
        MpiType::I16 => arith_case!(op, i16, acc, src),
        MpiType::I32 => arith_case!(op, i32, acc, src),
        MpiType::U32 => arith_case!(op, u32, acc, src),
        MpiType::I64 => arith_case!(op, i64, acc, src),
        MpiType::U64 => arith_case!(op, u64, acc, src),
        MpiType::F32 => arith_case!(op, f32, acc, src),
        MpiType::F64 => arith_case!(op, f64, acc, src),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip() {
        let v = [1i32, -2, 3];
        let b = as_bytes(&v);
        assert_eq!(b.len(), 12);
        let mut w = [0i32; 3];
        as_bytes_mut(&mut w).copy_from_slice(b);
        assert_eq!(v, w);
    }

    #[test]
    fn reduce_sum_i32() {
        let mut acc = [1i32, 2, 3];
        let src = [10i32, 20, 30];
        reduce_bytes(MpiOp::Sum, MpiType::I32, as_bytes_mut(&mut acc), as_bytes(&src)).unwrap();
        assert_eq!(acc, [11, 22, 33]);
    }

    #[test]
    fn reduce_minmax_f64() {
        let mut acc = [1.5f64, 9.0];
        let src = [2.5f64, 3.0];
        reduce_bytes(MpiOp::Max, MpiType::F64, as_bytes_mut(&mut acc), as_bytes(&src)).unwrap();
        assert_eq!(acc, [2.5, 9.0]);
        reduce_bytes(MpiOp::Min, MpiType::F64, as_bytes_mut(&mut acc), as_bytes(&[0.5f64, 4.0]))
            .unwrap();
        assert_eq!(acc, [0.5, 4.0]);
    }

    #[test]
    fn reduce_replace_and_noop() {
        let mut acc = [7u64];
        reduce_bytes(MpiOp::Replace, MpiType::U64, as_bytes_mut(&mut acc), as_bytes(&[42u64]))
            .unwrap();
        assert_eq!(acc, [42]);
        reduce_bytes(MpiOp::NoOp, MpiType::U64, as_bytes_mut(&mut acc), as_bytes(&[0u64]))
            .unwrap();
        assert_eq!(acc, [42]);
    }

    #[test]
    fn reduce_bitwise_i64() {
        let mut acc = [0b1100i64];
        reduce_bytes(MpiOp::Bxor, MpiType::I64, as_bytes_mut(&mut acc), as_bytes(&[0b1010i64]))
            .unwrap();
        assert_eq!(acc, [0b0110]);
    }

    #[test]
    fn reduce_size_mismatch_is_error() {
        let mut acc = [0u8; 4];
        assert!(matches!(
            reduce_bytes(MpiOp::Sum, MpiType::I32, &mut acc, &[0u8; 8]),
            Err(MpiErr::SizeMismatch { .. })
        ));
    }

    #[test]
    fn reduce_wrapping_sum_u8() {
        let mut acc = [250u8];
        reduce_bytes(MpiOp::Sum, MpiType::U8, &mut acc, &[10u8]).unwrap();
        assert_eq!(acc, [4]); // wraps, does not panic
    }

    #[test]
    fn vector_type_geometry() {
        let v = VectorType::new(8, 4, 32).unwrap();
        assert_eq!(v.packed_len(), 32);
        assert_eq!(v.extent(), 7 * 32 + 4);
        // contiguous degenerate case
        let c = VectorType::new(3, 16, 16).unwrap();
        assert_eq!(c.packed_len(), 48);
        assert_eq!(c.extent(), 48);
        // empty
        let e = VectorType::new(0, 8, 64).unwrap();
        assert_eq!(e.packed_len(), 0);
        assert_eq!(e.extent(), 0);
    }

    #[test]
    fn vector_type_rejects_overlapping_blocks() {
        assert!(matches!(VectorType::new(4, 8, 7), Err(MpiErr::Invalid(_))));
    }
}
