//! Error type for the MPI-3 substrate.

use thiserror::Error;

/// Errors surfaced by [`crate::mpisim`] operations.
///
/// Real MPI aborts by default; we return errors so the test suite can probe
/// misuse (e.g. RMA outside an access epoch) without killing the process.
#[derive(Debug, Error, Clone, PartialEq, Eq)]
pub enum MpiErr {
    #[error("rank {0} out of range (communicator size {1})")]
    RankOutOfRange(usize, usize),
    #[error("window displacement {disp}..{} out of range (segment size {size})", disp + len)]
    DispOutOfRange { disp: usize, len: usize, size: usize },
    #[error("RMA call outside an access epoch (win {win}, target {target})")]
    NoEpoch { win: u64, target: usize },
    #[error("epoch already held (win {win}, target {target})")]
    EpochAlreadyHeld { win: u64, target: usize },
    #[error("unlock without matching lock (win {win}, target {target})")]
    NoMatchingLock { win: u64, target: usize },
    #[error("window {0} is not known (freed or never created)")]
    UnknownWindow(u64),
    #[error("buffer size mismatch: local {local} bytes vs remote {remote} bytes")]
    SizeMismatch { local: usize, remote: usize },
    #[error("type size mismatch: op on {type_size}-byte type, buffer of {buf} bytes")]
    TypeMismatch { type_size: usize, buf: usize },
    #[error("group rank translation failed: rank {0} not in group")]
    NotInGroup(usize),
    #[error("communicator is empty for this rank (MPI_COMM_NULL)")]
    NullComm,
    #[error("request already consumed")]
    RequestConsumed,
    #[error("invalid argument: {0}")]
    Invalid(String),
    #[error("world finalized")]
    Finalized,
}

/// Substrate result alias.
pub type MpiResult<T> = Result<T, MpiErr>;
