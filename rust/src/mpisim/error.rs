//! Error type for the MPI-3 substrate.
//!
//! `Display`/`Error` are hand-implemented: the build environment is offline
//! and the crate is dependency-free (no `thiserror`).

use std::fmt;

/// Errors surfaced by [`crate::mpisim`] operations.
///
/// Real MPI aborts by default; we return errors so the test suite can probe
/// misuse (e.g. RMA outside an access epoch) without killing the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MpiErr {
    /// A rank outside the communicator: `(rank, communicator size)`.
    RankOutOfRange(usize, usize),
    /// A window access past the target segment's end.
    DispOutOfRange {
        /// Byte displacement of the access.
        disp: usize,
        /// Length of the access.
        len: usize,
        /// Size of the target's exposed segment.
        size: usize,
    },
    /// An RMA call outside any passive-target access epoch.
    NoEpoch {
        /// Window id.
        win: u64,
        /// Target rank.
        target: usize,
    },
    /// `MPI_Win_lock` while an epoch on the target is already held.
    EpochAlreadyHeld {
        /// Window id.
        win: u64,
        /// Target rank.
        target: usize,
    },
    /// `MPI_Win_unlock` without a matching lock.
    NoMatchingLock {
        /// Window id.
        win: u64,
        /// Target rank.
        target: usize,
    },
    /// A window id that was freed or never created.
    UnknownWindow(u64),
    /// Mismatched buffer sizes between the two sides of an operation.
    SizeMismatch {
        /// Local buffer size in bytes.
        local: usize,
        /// Expected/remote size in bytes.
        remote: usize,
    },
    /// A buffer whose length is not a multiple of the element size.
    TypeMismatch {
        /// Element size of the datatype.
        type_size: usize,
        /// Offending buffer length.
        buf: usize,
    },
    /// A group rank translation for a process not in the group.
    NotInGroup(usize),
    /// The communicator is `MPI_COMM_NULL` for this rank.
    NullComm,
    /// A completion call on an already-consumed request.
    RequestConsumed,
    /// Any other invalid argument.
    Invalid(String),
    /// An operation after the world finalized.
    Finalized,
}

impl fmt::Display for MpiErr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MpiErr::RankOutOfRange(rank, size) => {
                write!(f, "rank {rank} out of range (communicator size {size})")
            }
            MpiErr::DispOutOfRange { disp, len, size } => write!(
                f,
                "window displacement {disp}..{} out of range (segment size {size})",
                disp + len
            ),
            MpiErr::NoEpoch { win, target } => {
                write!(f, "RMA call outside an access epoch (win {win}, target {target})")
            }
            MpiErr::EpochAlreadyHeld { win, target } => {
                write!(f, "epoch already held (win {win}, target {target})")
            }
            MpiErr::NoMatchingLock { win, target } => {
                write!(f, "unlock without matching lock (win {win}, target {target})")
            }
            MpiErr::UnknownWindow(id) => {
                write!(f, "window {id} is not known (freed or never created)")
            }
            MpiErr::SizeMismatch { local, remote } => {
                write!(f, "buffer size mismatch: local {local} bytes vs remote {remote} bytes")
            }
            MpiErr::TypeMismatch { type_size, buf } => {
                write!(f, "type size mismatch: op on {type_size}-byte type, buffer of {buf} bytes")
            }
            MpiErr::NotInGroup(rank) => {
                write!(f, "group rank translation failed: rank {rank} not in group")
            }
            MpiErr::NullComm => write!(f, "communicator is empty for this rank (MPI_COMM_NULL)"),
            MpiErr::RequestConsumed => write!(f, "request already consumed"),
            MpiErr::Invalid(msg) => write!(f, "invalid argument: {msg}"),
            MpiErr::Finalized => write!(f, "world finalized"),
        }
    }
}

impl std::error::Error for MpiErr {}

/// Substrate result alias.
pub type MpiResult<T> = Result<T, MpiErr>;
