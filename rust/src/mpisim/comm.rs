//! Communicators: a group of ranks plus an isolated communication context.
//!
//! Like MPI, every communicator owns a *context id* so that traffic on one
//! communicator can never match receives on another, even between the same
//! pair of ranks with the same tag. New contexts are agreed collectively
//! (rank 0 of the parent allocates, then broadcasts over the parent), which
//! is also how real MPI implementations do it.

use super::error::{MpiErr, MpiResult};
use super::group::Group;
use super::WorldState;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// A communicator handle. Cheap to clone; clones share the collective
/// sequence counter (they are the *same* communicator).
#[derive(Clone)]
pub struct Comm {
    world: Arc<WorldState>,
    my_world: usize,
    ctx: u32,
    /// Communicator rank → world rank.
    ranks: Arc<Vec<usize>>,
    /// This rank's index within the communicator.
    my_rank: usize,
    /// Per-communicator collective sequence number. All ranks call
    /// collectives in the same order (an MPI requirement), so local
    /// counters stay in lock-step and serve as collective-unique tags.
    pub(crate) coll_seq: Arc<AtomicU32>,
}

impl Comm {
    /// `MPI_COMM_WORLD` for this rank.
    pub(crate) fn new_world(world: Arc<WorldState>, my_world: usize) -> Comm {
        let n = world.nranks;
        Comm {
            world,
            my_world,
            ctx: 0,
            ranks: Arc::new((0..n).collect()),
            my_rank: my_world,
            coll_seq: Arc::new(AtomicU32::new(0)),
        }
    }

    /// My rank within this communicator (`MPI_Comm_rank`).
    #[inline]
    pub fn rank(&self) -> usize {
        self.my_rank
    }

    /// Number of ranks in this communicator (`MPI_Comm_size`).
    #[inline]
    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// My world rank.
    #[inline]
    pub fn my_world(&self) -> usize {
        self.my_world
    }

    /// The context id (test/debug aid).
    #[inline]
    pub fn context(&self) -> u32 {
        self.ctx
    }

    /// Shared world state.
    #[inline]
    pub(crate) fn world(&self) -> &Arc<WorldState> {
        &self.world
    }

    /// Translate a communicator rank to a world rank.
    #[inline]
    pub fn world_rank_of(&self, comm_rank: usize) -> MpiResult<usize> {
        self.ranks
            .get(comm_rank)
            .copied()
            .ok_or(MpiErr::RankOutOfRange(comm_rank, self.ranks.len()))
    }

    /// Translate a world rank to a communicator rank, if the process is a
    /// member.
    #[inline]
    pub fn rank_of_world(&self, world_rank: usize) -> Option<usize> {
        // Fast path: on MPI_COMM_WORLD the mapping is the identity.
        if self.ctx == 0 {
            return (world_rank < self.ranks.len()).then_some(world_rank);
        }
        self.ranks.iter().position(|&w| w == world_rank)
    }

    /// The communicator's group (`MPI_Comm_group`).
    pub fn group(&self) -> Group {
        Group::new(self.ranks.as_ref().clone())
    }

    /// Full comm-rank → world-rank table.
    pub fn rank_table(&self) -> &[usize] {
        &self.ranks
    }

    /// Allocate a fresh context id, agreed by all members: rank 0 draws
    /// from the world counter and broadcasts it over `self`.
    fn agree_context(&self) -> MpiResult<u32> {
        let mut ctx = if self.rank() == 0 {
            self.world.next_context_id.fetch_add(1, Ordering::SeqCst)
        } else {
            0
        };
        let mut buf = ctx.to_ne_bytes();
        self.bcast(&mut buf, 0)?;
        ctx = u32::from_ne_bytes(buf);
        Ok(ctx)
    }

    /// `MPI_Comm_dup`: same group, fresh context. Collective.
    pub fn dup(&self) -> MpiResult<Comm> {
        let ctx = self.agree_context()?;
        Ok(Comm {
            world: self.world.clone(),
            my_world: self.my_world,
            ctx,
            ranks: self.ranks.clone(),
            my_rank: self.my_rank,
            coll_seq: Arc::new(AtomicU32::new(0)),
        })
    }

    /// `MPI_Comm_create(parent, group)`: collective over the parent; the
    /// members of `group` (given as world ranks, in group order) get the
    /// new communicator, everyone else gets `None` (`MPI_COMM_NULL`).
    pub fn create_from_group(&self, group: &Group) -> MpiResult<Option<Comm>> {
        for &w in group.members() {
            if self.rank_of_world(w).is_none() {
                return Err(MpiErr::Invalid(format!(
                    "group member (world rank {w}) is not in the parent communicator"
                )));
            }
        }
        let ctx = self.agree_context()?;
        match group.rank_of(self.my_world) {
            None => Ok(None),
            Some(my_rank) => Ok(Some(Comm {
                world: self.world.clone(),
                my_world: self.my_world,
                ctx,
                ranks: Arc::new(group.members().to_vec()),
                my_rank,
                coll_seq: Arc::new(AtomicU32::new(0)),
            })),
        }
    }

    /// `MPI_Comm_split(color, key)`: collective. Ranks with the same
    /// `color` form a new communicator, ordered by `(key, parent rank)`.
    /// `color = None` (MPI_UNDEFINED) yields `None`.
    pub fn split(&self, color: Option<i32>, key: i32) -> MpiResult<Option<Comm>> {
        // Gather (color?, key, world_rank) triples everywhere (allgather).
        let mine = [
            color.map_or(i64::MIN, |c| c as i64),
            key as i64,
            self.my_world as i64,
        ];
        let mut all = vec![0i64; 3 * self.size()];
        self.allgather(super::datatype::as_bytes(&mine), super::datatype::as_bytes_mut(&mut all))?;
        let ctx_base = self.agree_context_block()?;

        let my_color = match color {
            None => return Ok(None),
            Some(c) => c as i64,
        };
        // Deterministic color ordering: distinct colors sorted ascending,
        // each gets ctx_base + its index.
        let mut colors: Vec<i64> =
            all.chunks_exact(3).map(|t| t[0]).filter(|&c| c != i64::MIN).collect();
        colors.sort_unstable();
        colors.dedup();
        let color_idx = colors.binary_search(&my_color).unwrap();
        let ctx = ctx_base + color_idx as u32;

        let mut members: Vec<(i64, usize, usize)> = all
            .chunks_exact(3)
            .enumerate()
            .filter(|(_, t)| t[0] == my_color)
            .map(|(parent_rank, t)| (t[1], parent_rank, t[2] as usize))
            .collect();
        members.sort_unstable_by_key(|&(key, parent_rank, _)| (key, parent_rank));
        let ranks: Vec<usize> = members.iter().map(|&(_, _, w)| w).collect();
        let my_rank = ranks.iter().position(|&w| w == self.my_world).unwrap();
        Ok(Some(Comm {
            world: self.world.clone(),
            my_world: self.my_world,
            ctx,
            ranks: Arc::new(ranks),
            my_rank,
            coll_seq: Arc::new(AtomicU32::new(0)),
        }))
    }

    /// Allocate a *block* of context ids (one per split color): rank 0
    /// reserves a generous block, broadcasts the base.
    fn agree_context_block(&self) -> MpiResult<u32> {
        let mut base = if self.rank() == 0 {
            self.world.next_context_id.fetch_add(self.size() as u32, Ordering::SeqCst)
        } else {
            0
        };
        let mut buf = base.to_ne_bytes();
        self.bcast(&mut buf, 0)?;
        base = u32::from_ne_bytes(buf);
        Ok(base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpisim::{World, WorldConfig};

    #[test]
    fn world_comm_identity() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            assert_eq!(c.size(), 4);
            assert_eq!(c.rank(), mpi.world_rank());
            assert_eq!(c.world_rank_of(2).unwrap(), 2);
            assert_eq!(c.rank_of_world(3), Some(3));
        });
    }

    #[test]
    fn dup_isolates_context() {
        World::run(WorldConfig::local(2), |mpi| {
            let c = mpi.comm_world();
            let d = c.dup().unwrap();
            assert_ne!(c.context(), d.context());
            // A message on d must not match a recv on c.
            if c.rank() == 0 {
                d.send(b"on-dup", 1, 0).unwrap();
                c.send(b"on-world", 1, 0).unwrap();
            } else {
                let (m, _) = c.recv_vec(0, 0).unwrap();
                assert_eq!(m, b"on-world");
                let (m, _) = d.recv_vec(0, 0).unwrap();
                assert_eq!(m, b"on-dup");
            }
        });
    }

    #[test]
    fn create_from_group_orders_by_group() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            // group in non-sorted order: world ranks [3, 1]
            let g = Group::new(vec![3, 1]);
            let sub = c.create_from_group(&g).unwrap();
            match mpi.world_rank() {
                3 => assert_eq!(sub.unwrap().rank(), 0),
                1 => assert_eq!(sub.unwrap().rank(), 1),
                _ => assert!(sub.is_none()),
            }
        });
    }

    #[test]
    fn split_by_parity() {
        World::run(WorldConfig::local(5), |mpi| {
            let c = mpi.comm_world();
            let color = (mpi.world_rank() % 2) as i32;
            let sub = c.split(Some(color), mpi.world_rank() as i32).unwrap().unwrap();
            let expected_size = if color == 0 { 3 } else { 2 };
            assert_eq!(sub.size(), expected_size);
            assert_eq!(sub.world_rank_of(sub.rank()).unwrap(), mpi.world_rank());
            // key ordering = world rank ordering here
            let table = sub.rank_table().to_vec();
            let mut sorted = table.clone();
            sorted.sort_unstable();
            assert_eq!(table, sorted);
        });
    }

    #[test]
    fn split_undefined_color() {
        World::run(WorldConfig::local(3), |mpi| {
            let c = mpi.comm_world();
            let color = if mpi.world_rank() == 0 { None } else { Some(1) };
            let sub = c.split(color, 0).unwrap();
            if mpi.world_rank() == 0 {
                assert!(sub.is_none());
            } else {
                assert_eq!(sub.unwrap().size(), 2);
            }
        });
    }

    #[test]
    fn split_reverse_key_reverses_order() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let key = -(mpi.world_rank() as i32);
            let sub = c.split(Some(0), key).unwrap().unwrap();
            assert_eq!(sub.rank(), 3 - mpi.world_rank());
        });
    }

    #[test]
    fn nested_subcommunicators() {
        World::run(WorldConfig::local(4), |mpi| {
            let c = mpi.comm_world();
            let g = Group::new(vec![0, 1, 2]);
            if let Some(sub) = c.create_from_group(&g).unwrap() {
                let g2 = Group::new(vec![2, 0]);
                let subsub = sub.create_from_group(&g2).unwrap();
                match mpi.world_rank() {
                    2 => assert_eq!(subsub.unwrap().rank(), 0),
                    0 => assert_eq!(subsub.unwrap().rank(), 1),
                    _ => assert!(subsub.is_none()),
                }
            }
        });
    }
}
