//! `Graph` — a distributed CSR graph with owner-partitioned rows.
//!
//! The first *irregular* data structure in the dash layer: where every
//! other container's communication pattern is fixed by its [`Pattern`],
//! a graph's is decided by the data. Vertices are owner-partitioned
//! BLOCKED over the team (vertex `v`'s adjacency lives on `v`'s owner);
//! the storage is three BLOCKED [`Array`]s in symmetric global memory:
//!
//! - `adj_off[v]` — start offset of `v`'s neighbor list *within its
//!   owner's local adjacency storage*;
//! - `deg[v]` — `v`'s degree;
//! - `adj` — the concatenated neighbor lists, `edge_cap` slots per unit
//!   (the team-wide maximum local edge count, so the BLOCKED pattern
//!   lines local storage up with global index `unit · edge_cap`).
//!
//! Because a vertex's neighbor list is contiguous on its owner, a remote
//! adjacency pull ([`Graph::get_neighbors`]) is two scalar gets (offset
//! and degree) plus ONE coalesced vector-typed get of the whole list —
//! the access shape the DASH papers argue the runtime must make cheap.
//! Owners additionally keep a plain local CSR mirror
//! ([`Graph::local_neighbors`]) so traversal over owned rows costs no
//! one-sided traffic at all.
//!
//! Graphs are built from a seeded Kronecker/R-MAT generator
//! ([`rmat_edge`], Graph500's A/B/C/D = 0.57/0.19/0.19/0.05): edge `k`
//! is a pure function of `(seed, k)`, so every unit replays the same
//! edge stream and keeps the endpoints it owns — construction is
//! embarrassingly parallel and bit-reproducible for any team size. The
//! graph is stored undirected (each kept edge contributes both
//! directions), self-loops are dropped, and neighbor lists are sorted
//! and deduplicated so structure — not generation order — defines the
//! graph. An `edge_factor` of zero produces a legal edgeless graph
//! (`adj` is then a zero-length array — the empty-distribution case the
//! pattern layer explicitly supports).

use super::array::Array;
use super::pattern::Pattern;
use crate::dart::{DartEnv, DartErr, DartResult, TeamId};
use crate::mpisim::MpiOp;
use crate::testing::prop::Rng;

/// Parameters of a reproducible R-MAT graph.
#[derive(Debug, Clone, Copy)]
pub struct GraphConfig {
    /// log2 of the vertex count (`nverts = 1 << scale`).
    pub scale: u32,
    /// Directed edges generated per vertex (`nedges = edge_factor << scale`);
    /// zero yields an edgeless graph.
    pub edge_factor: usize,
    /// Generator seed; edge `k` is a pure function of `(seed, k)`.
    pub seed: u64,
}

impl GraphConfig {
    /// Vertex count `2^scale`.
    pub fn nverts(&self) -> usize {
        1usize << self.scale
    }

    /// Number of generated directed edge pairs (before self-loop and
    /// duplicate removal).
    pub fn nedges(&self) -> usize {
        self.edge_factor << self.scale
    }
}

/// The `k`-th R-MAT edge for `(seed, scale)` — a pure function, so every
/// unit (and the sequential oracle) generates the identical edge list
/// without communicating. Quadrant probabilities are Graph500's
/// (A, B, C, D) = (0.57, 0.19, 0.19, 0.05) per bit of recursion.
pub fn rmat_edge(seed: u64, scale: u32, k: u64) -> (u64, u64) {
    let mut rng = Rng::new(seed ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let (mut a, mut b) = (0u64, 0u64);
    for bit in 0..scale {
        let q = rng.next_u64() % 100;
        let (ai, bi) = if q < 57 {
            (0u64, 0u64)
        } else if q < 76 {
            (0, 1)
        } else if q < 95 {
            (1, 0)
        } else {
            (1, 1)
        };
        a |= ai << bit;
        b |= bi << bit;
    }
    (a, b)
}

/// The full directed edge stream of `cfg` (self-loops included — callers
/// filter), in generation order. Pure; used by both [`Graph::build`] and
/// the sequential BFS oracle.
pub fn edges(cfg: &GraphConfig) -> impl Iterator<Item = (u64, u64)> + '_ {
    (0..cfg.nedges() as u64).map(move |k| rmat_edge(cfg.seed, cfg.scale, k))
}

/// A distributed CSR graph (see module docs). Collectively built and
/// freed; cheap owner-local traversal plus coalesced remote pulls.
pub struct Graph<'e> {
    env: &'e DartEnv,
    team: TeamId,
    cfg: GraphConfig,
    /// Vertex ownership map (BLOCKED over the team).
    pattern: Pattern,
    /// Per-vertex start offset into the owner's `adj` slots.
    adj_off: Array<'e, u64>,
    /// Per-vertex degree.
    deg: Array<'e, u64>,
    /// Concatenated neighbor lists, `edge_cap` global slots per unit.
    adj: Array<'e, u64>,
    /// Team-wide maximum local (directed) edge count.
    edge_cap: usize,
    /// My team rank.
    myrank: usize,
    /// Global index of my first owned vertex.
    row0: usize,
    /// Local CSR mirror: `local_off[l]..local_off[l + 1]` indexes
    /// `local_adj` for owned row `row0 + l`.
    local_off: Vec<usize>,
    /// Local CSR mirror: concatenated neighbor lists of my rows.
    local_adj: Vec<u64>,
}

impl<'e> Graph<'e> {
    /// Collectively build the graph over `team`: every unit replays the
    /// seeded edge stream, keeps the directions whose source it owns
    /// (both directions of each generated pair — the graph is stored
    /// undirected), drops self-loops, sorts and dedups each neighbor
    /// list, and publishes its rows into the global CSR arrays.
    pub fn build(env: &'e DartEnv, team: TeamId, cfg: GraphConfig) -> DartResult<Graph<'e>> {
        if cfg.scale > 24 {
            return Err(DartErr::Invalid("graph scale > 24 is not simulatable".into()));
        }
        let n = cfg.nverts();
        let p = env.team_size(team)?;
        let me = env.team_myid(team)?;
        let pattern = Pattern::blocked(n, p)?;
        let extent = pattern.local_extent(me);
        let row0 = if extent == 0 { n } else { pattern.local_to_global(me, 0) };

        // Replicated generation: keep the directions I own.
        let mut lists: Vec<Vec<u64>> = vec![Vec::new(); extent];
        let owns = |v: u64| -> bool { (v as usize) >= row0 && (v as usize) < row0 + extent };
        for (a, b) in edges(&cfg) {
            if a == b {
                continue;
            }
            if owns(a) {
                lists[a as usize - row0].push(b);
            }
            if owns(b) {
                lists[b as usize - row0].push(a);
            }
        }
        let mut local_off = Vec::with_capacity(extent + 1);
        let mut local_adj = Vec::new();
        local_off.push(0);
        for list in &mut lists {
            list.sort_unstable();
            list.dedup();
            local_adj.extend_from_slice(list);
            local_off.push(local_adj.len());
        }

        // Team-wide adjacency capacity so BLOCKED local storage lines up
        // with global index unit · edge_cap on every member.
        let mut emax = [0u64];
        env.allreduce(team, &[local_adj.len() as u64], &mut emax, MpiOp::Max)?;
        let edge_cap = emax[0] as usize;

        let adj_off: Array<'e, u64> = Array::new(env, team, pattern)?;
        let deg: Array<'e, u64> = Array::new(env, team, pattern)?;
        let adj: Array<'e, u64> = Array::new(env, team, Pattern::blocked(edge_cap * p, p)?)?;
        adj_off.with_local(|buf| {
            for (l, slot) in buf.iter_mut().enumerate() {
                *slot = local_off[l] as u64;
            }
        })?;
        deg.with_local(|buf| {
            for (l, slot) in buf.iter_mut().enumerate() {
                *slot = (local_off[l + 1] - local_off[l]) as u64;
            }
        })?;
        adj.with_local(|buf| buf[..local_adj.len()].copy_from_slice(&local_adj))?;
        // No unit may pull a row before its owner published it.
        env.barrier(team)?;
        Ok(Graph {
            env,
            team,
            cfg,
            pattern,
            adj_off,
            deg,
            adj,
            edge_cap,
            myrank: me,
            row0,
            local_off,
            local_adj,
        })
    }

    /// Vertex count.
    pub fn nverts(&self) -> usize {
        self.cfg.nverts()
    }

    /// The generator configuration the graph was built from.
    pub fn config(&self) -> &GraphConfig {
        &self.cfg
    }

    /// The vertex-ownership pattern (BLOCKED over the team).
    pub fn pattern(&self) -> &Pattern {
        &self.pattern
    }

    /// The team the graph is distributed over.
    pub fn team(&self) -> TeamId {
        self.team
    }

    /// The runtime handle the graph was built with.
    pub fn env(&self) -> &'e DartEnv {
        self.env
    }

    /// Team rank owning vertex `v`.
    pub fn owner_of(&self, v: usize) -> usize {
        self.pattern.global_to_local(v).0
    }

    /// The global index range of my owned rows.
    pub fn my_rows(&self) -> std::ops::Range<usize> {
        self.row0..self.row0 + self.local_off.len() - 1
    }

    /// Directed edge count of my owned rows (after self-loop removal and
    /// deduplication).
    pub fn local_edge_count(&self) -> usize {
        self.local_adj.len()
    }

    /// Neighbor list of an **owned** vertex — pure local memory, the
    /// traversal hot path.
    pub fn local_neighbors(&self, v: usize) -> DartResult<&[u64]> {
        if !self.my_rows().contains(&v) {
            return Err(DartErr::Invalid(format!(
                "local_neighbors({v}) on rank {} owning {:?}",
                self.myrank,
                self.my_rows()
            )));
        }
        let l = v - self.row0;
        Ok(&self.local_adj[self.local_off[l]..self.local_off[l + 1]])
    }

    /// Neighbor list of **any** vertex: owned rows answer from the local
    /// CSR mirror; remote rows cost two scalar gets (offset, degree) and
    /// ONE coalesced vector-typed get of the contiguous list.
    pub fn get_neighbors(&self, v: usize) -> DartResult<Vec<u64>> {
        if self.my_rows().contains(&v) {
            return Ok(self.local_neighbors(v)?.to_vec());
        }
        let owner = self.owner_of(v);
        let off = self.adj_off.get(v)? as usize;
        let d = self.deg.get(v)? as usize;
        let mut list = vec![0u64; d];
        if d > 0 {
            self.adj.copy_out(owner * self.edge_cap + off, &mut list)?;
        }
        Ok(list)
    }

    /// Collectively release the backing global memory.
    pub fn free(self) -> DartResult<()> {
        self.adj_off.free()?;
        self.deg.free()?;
        self.adj.free()
    }
}
