//! `dash` — typed distributed data structures and owner-computes
//! algorithms on top of the DART runtime.
//!
//! The DART-MPI paper positions DART as the substrate of the **DASH**
//! C++ PGAS library ("DASH: A C++ PGAS Library for Distributed Data
//! Structures and Parallel Algorithms", Fuerlinger et al.); this module
//! is that missing top layer, built **strictly on the public `dart`
//! API** — global memory, one-sided engine ops, collectives — with no
//! private hooks into the runtime:
//!
//! - [`Pattern`] ([`pattern`]) — BLOCKED / CYCLIC / BLOCKCYCLIC(b) /
//!   TILED (2-D) data distributions as bijective global ↔ (unit, local
//!   offset) index maps, with contiguous-run queries for coalescing;
//! - [`Array`]`<T>` ([`array`]) and [`Matrix`]`<T>` ([`matrix`]) —
//!   typed containers over one symmetric
//!   [`crate::dart::DartEnv::team_memalloc_aligned`] allocation: global
//!   element get/put, run-coalesced bulk `copy_in`/`copy_out` on the
//!   engine's deferred-completion path, owner-computes local views, and
//!   the matrix's one-op halo accessors (contiguous row get, vector-typed
//!   column get);
//! - [`algorithms`] — owner-computes `fill`/`transform`/`sum`/
//!   `min_element`/`max_element` plus the pattern-redistributing
//!   [`algorithms::copy`], all combining per-unit work with one team
//!   collective;
//! - [`HashMap`]`<K, V>` ([`hashmap`]) — a distributed key-value map with
//!   consistent-hash routing, bucket-confined open addressing in
//!   symmetric global memory, and a lock-free insert/update hot path on
//!   the runtime's MPI-3 atomics (`compare_and_swap` claims + deferred
//!   `accumulate_async` publication), exercised at scale by
//!   `apps::kvstore` and the `perf_kv` bench;
//! - [`Vector`]`<T>` ([`vector`]) — the **growable** array over the
//!   dynamic half of the memory model
//!   ([`crate::dart::DartEnv::memattach`]): amortized-doubling collective
//!   `push` / non-collective `push_back_global`, pattern-preserving
//!   redistribution on growth, bit-identical to a preallocated [`Array`]
//!   of the final size;
//! - [`Graph`] ([`graph`]) — a distributed CSR graph with owner-
//!   partitioned rows over BLOCKED arrays and a seeded Kronecker/R-MAT
//!   generator: the first *irregular* container, whose communication
//!   pattern (coalesced remote adjacency pulls, CAS claims in the BFS
//!   app) is decided by the data rather than the pattern; exercised by
//!   `apps::bfs` and the `perf_graph` bench;
//! - [`WorkQueue`] ([`workqueue`]) — a global MPMC task queue over
//!   dynamic segments: per-unit rings, CAS-claimed head/tail on the
//!   atomics hot path, work stealing between units; exercised by
//!   `apps::wqueue` and the `perf_dynamic` bench.
//!
//! Element types are anything implementing the byte-API marker
//! [`crate::dart::Element`]. Operation coalescing is observable in
//! `Metrics::dash_coalesced_runs` / `Metrics::dash_redist_bytes` and
//! measured by the `perf_dash` bench (`BENCH_dash.json`).

pub mod algorithms;
pub mod array;
pub mod graph;
pub mod hashmap;
pub mod matrix;
pub mod pattern;
pub mod vector;
pub mod workqueue;

pub use crate::dart::Element;
pub use array::Array;
pub use graph::{Graph, GraphConfig};
pub use hashmap::HashMap;
pub use matrix::Matrix;
pub use pattern::{Layout, Pattern, Run};
pub use vector::Vector;
pub use workqueue::WorkQueue;
