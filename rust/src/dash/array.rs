//! `Array<T>` — a typed 1-D distributed array over DART global memory.
//!
//! The container owns one collective aligned allocation
//! ([`crate::dart::DartEnv::team_memalloc_aligned`]) of
//! `max_local_extent × size_of::<T>()` bytes per member — symmetric, so
//! any unit computes any element's global pointer locally (the paper's
//! "advantageous property" of aligned allocations) — and a [`Pattern`]
//! giving the element → (unit, local offset) map.
//!
//! Three access tiers, slowest to fastest:
//!
//! 1. **element** — [`Array::get`]/[`Array::put`]: one blocking one-sided
//!    op per element; fine for setup and tests, wrong for bulk data;
//! 2. **bulk** — [`Array::copy_in`]/[`Array::copy_out`]: the pattern's
//!    [`Pattern::runs`] coalesce the range into maximal contiguous runs,
//!    each moved as ONE deferred-completion engine op
//!    (`put_async`/`get_async`), completed by a single `flush_all`;
//!    issued-run counts land in `Metrics::dash_coalesced_runs`;
//! 3. **owner-computes** — [`Array::read_local`]/[`Array::write_local`]/
//!    [`Array::with_local`]: the unit's whole partition through
//!    `local_read`/`local_write`, no network at all. This is the access
//!    shape the owner-computes algorithms ([`super::algorithms`]) and the
//!    locality-awareness follow-up papers are about.

use super::pattern::Pattern;
use crate::dart::gptr::{GlobalPtr, TeamId, UnitId};
use crate::dart::{DartEnv, DartErr, DartResult, Element};
use crate::mpisim::{as_bytes, as_bytes_mut, MpiOp};
use std::marker::PhantomData;

/// A typed distributed 1-D array (see module docs).
pub struct Array<'e, T: Element> {
    pub(crate) env: &'e DartEnv,
    pub(crate) team: TeamId,
    pub(crate) pattern: Pattern,
    /// Base collective pointer of the backing allocation (team's first
    /// member; pool-relative offset identical on every member).
    pub(crate) gptr: GlobalPtr,
    /// Absolute unit id of every team rank (rank-indexed).
    pub(crate) units: Vec<UnitId>,
    /// My team-relative rank.
    pub(crate) myrank: usize,
    _elem: PhantomData<T>,
}

impl<'e, T: Element> Array<'e, T> {
    /// Collectively allocate a distributed array laid out by `pattern`
    /// over `team`. Every element starts as `T::default()`.
    ///
    /// `pattern.nunits()` must equal the team size.
    pub fn new(env: &'e DartEnv, team: TeamId, pattern: Pattern) -> DartResult<Array<'e, T>> {
        let p = env.team_size(team)?;
        if pattern.nunits() != p {
            return Err(DartErr::Invalid(format!(
                "pattern over {} units on a {p}-member team",
                pattern.nunits()
            )));
        }
        let cap = pattern.max_local_extent().max(1);
        let gptr = env.team_memalloc_aligned(team, (cap * std::mem::size_of::<T>()) as u64)?;
        let units: Vec<UnitId> =
            (0..p).map(|r| env.team_unit_l2g(team, r)).collect::<DartResult<_>>()?;
        let myrank = env.team_myid(team)?;
        let arr = Array { env, team, pattern, gptr, units, myrank, _elem: PhantomData };
        // Deterministic initial contents, then a rendezvous so no unit
        // reads a partition its owner has not initialized yet.
        let zeros = vec![T::default(); arr.local_len()];
        arr.write_local(&zeros)?;
        env.barrier(team)?;
        Ok(arr)
    }

    /// Convenience: a BLOCKED array of `n` elements over `team`.
    pub fn blocked(env: &'e DartEnv, team: TeamId, n: usize) -> DartResult<Array<'e, T>> {
        let p = env.team_size(team)?;
        Array::new(env, team, Pattern::blocked(n, p)?)
    }

    /// Convenience: a CYCLIC array of `n` elements over `team`.
    pub fn cyclic(env: &'e DartEnv, team: TeamId, n: usize) -> DartResult<Array<'e, T>> {
        let p = env.team_size(team)?;
        Array::new(env, team, Pattern::cyclic(n, p)?)
    }

    /// Convenience: a BLOCKCYCLIC(`block`) array of `n` elements.
    pub fn block_cyclic(
        env: &'e DartEnv,
        team: TeamId,
        n: usize,
        block: usize,
    ) -> DartResult<Array<'e, T>> {
        let p = env.team_size(team)?;
        Array::new(env, team, Pattern::block_cyclic(n, p, block)?)
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.pattern.len()
    }

    /// Whether the array holds zero elements. Empty arrays are legal
    /// (data-dependent decompositions produce them); every per-element
    /// operation on them is a no-op and collectives still synchronize.
    pub fn is_empty(&self) -> bool {
        self.pattern.is_empty()
    }

    /// The distribution pattern.
    pub fn pattern(&self) -> &Pattern {
        &self.pattern
    }

    /// The team this array is distributed over.
    pub fn team(&self) -> TeamId {
        self.team
    }

    /// The runtime handle the array was created with.
    pub fn env(&self) -> &'e DartEnv {
        self.env
    }

    /// My team-relative rank.
    pub fn myrank(&self) -> usize {
        self.myrank
    }

    /// Number of elements stored on this unit.
    pub fn local_len(&self) -> usize {
        self.pattern.local_extent(self.myrank)
    }

    /// Global pointer to local offset `local` of team rank `unit`'s
    /// partition — pure pointer arithmetic, no communication.
    pub(crate) fn gptr_of(&self, unit: usize, local: usize) -> GlobalPtr {
        self.gptr
            .with_unit(self.units[unit])
            .add((local * std::mem::size_of::<T>()) as u64)
    }

    /// Check a global range against the array bounds (the containers
    /// report [`DartErr::Invalid`] where the raw [`Pattern`] asserts;
    /// overflow-safe, so `start` near `usize::MAX` cannot wrap past it).
    fn check_range(&self, start: usize, len: usize) -> DartResult<()> {
        match start.checked_add(len) {
            Some(end) if end <= self.len() => Ok(()),
            _ => Err(DartErr::Invalid(format!(
                "global range {start}+{len} out of array bounds 0..{}",
                self.len()
            ))),
        }
    }

    /// Read one element (blocking one-sided get).
    pub fn get(&self, g: usize) -> DartResult<T> {
        self.check_range(g, 1)?;
        let (u, l) = self.pattern.global_to_local(g);
        let mut v = [T::default()];
        self.env.get_blocking(self.gptr_of(u, l), as_bytes_mut(&mut v))?;
        Ok(v[0])
    }

    /// Write one element (blocking one-sided put).
    pub fn put(&self, g: usize, value: T) -> DartResult<()> {
        self.check_range(g, 1)?;
        let (u, l) = self.pattern.global_to_local(g);
        self.env.put_blocking(self.gptr_of(u, l), as_bytes(&[value]))
    }

    /// Atomic element-wise update: `a[g] := a[g] (op) value`, lock-free
    /// and deferred ([`crate::dart::DartEnv::accumulate_async`]) — many
    /// units may accumulate into the same element concurrently without
    /// losing updates, and a phase of accumulates completes with ONE
    /// [`Array::flush`] instead of per-op round trips. Same-node targets
    /// complete via the CPU-atomic fast path.
    pub fn accumulate(&self, g: usize, value: T, op: MpiOp) -> DartResult<()> {
        self.check_range(g, 1)?;
        let (u, l) = self.pattern.global_to_local(g);
        self.env.accumulate_async(self.gptr_of(u, l), &[value], op)
    }

    /// Atomic fetch-and-op on one element: returns the value before the
    /// update. Synchronous (element-granularity MPI-3 atomics; same-node
    /// targets ride the CPU-atomic fast path).
    pub fn fetch_op(&self, g: usize, value: T, op: MpiOp) -> DartResult<T> {
        self.check_range(g, 1)?;
        let (u, l) = self.pattern.global_to_local(g);
        self.env.fetch_and_op(self.gptr_of(u, l), value, op)
    }

    /// Atomic compare-and-swap on one element: installs `value` iff the
    /// element equals `compare`, returning the previous value either way
    /// (the claim succeeded iff the return equals `compare`). This is the
    /// claim primitive irregular workloads race on — e.g. BFS parent
    /// claims on a distributed parent array.
    pub fn compare_and_swap(&self, g: usize, compare: T, value: T) -> DartResult<T> {
        self.check_range(g, 1)?;
        let (u, l) = self.pattern.global_to_local(g);
        self.env.compare_and_swap(self.gptr_of(u, l), compare, value)
    }

    /// Complete every outstanding deferred operation on this array's
    /// allocation (puts/gets from the bulk tier, accumulates) — one call
    /// per phase, the engine's explicit-flush discipline.
    pub fn flush(&self) -> DartResult<()> {
        self.env.flush_all(self.gptr)
    }

    /// Bulk write: scatter `src` into the global range
    /// `[start, start + src.len())`, coalescing each maximal contiguous
    /// run into ONE deferred-completion put, all completed by a single
    /// `flush_all`. Returns the number of one-sided operations issued
    /// (also added to `Metrics::dash_coalesced_runs`).
    pub fn copy_in(&self, start: usize, src: &[T]) -> DartResult<u64> {
        self.check_range(start, src.len())?;
        if src.is_empty() {
            return Ok(0);
        }
        let mut ops = 0u64;
        for run in self.pattern.runs(start, src.len()) {
            let off = run.global - start;
            self.env
                .put_async(self.gptr_of(run.unit, run.local), as_bytes(&src[off..off + run.len]))?;
            ops += 1;
        }
        self.env.metrics.dash_coalesced_runs.add(ops);
        self.env.flush_all(self.gptr)?;
        Ok(ops)
    }

    /// Deferred bulk write: like [`Array::copy_in`] but WITHOUT the
    /// trailing `flush_all`, so a caller scattering many disjoint ranges
    /// (the bucketed-redistribution pattern: one range per destination
    /// bucket, some of them empty) batches every run behind a single
    /// [`Array::flush`]. Returns the number of one-sided operations
    /// issued; an empty `src` issues none and is always legal.
    pub fn copy_in_async(&self, start: usize, src: &[T]) -> DartResult<u64> {
        self.check_range(start, src.len())?;
        let mut ops = 0u64;
        for run in self.pattern.runs(start, src.len()) {
            let off = run.global - start;
            self.env
                .put_async(self.gptr_of(run.unit, run.local), as_bytes(&src[off..off + run.len]))?;
            ops += 1;
        }
        self.env.metrics.dash_coalesced_runs.add(ops);
        Ok(ops)
    }

    /// Bulk read: gather the global range `[start, start + dst.len())`
    /// into `dst` — the mirror of [`Array::copy_in`] over deferred gets.
    /// Returns the number of one-sided operations issued.
    pub fn copy_out(&self, start: usize, dst: &mut [T]) -> DartResult<u64> {
        self.check_range(start, dst.len())?;
        if dst.is_empty() {
            return Ok(0);
        }
        let mut ops = 0u64;
        for run in self.pattern.runs(start, dst.len()) {
            let off = run.global - start;
            self.env.get_async(
                self.gptr_of(run.unit, run.local),
                as_bytes_mut(&mut dst[off..off + run.len]),
            )?;
            ops += 1;
        }
        self.env.metrics.dash_coalesced_runs.add(ops);
        self.env.flush_all(self.gptr)?;
        Ok(ops)
    }

    /// Copy of this unit's partition, in local storage order (use
    /// [`Pattern::local_to_global`] / [`Pattern::block_iter`] for the
    /// global anchors).
    pub fn read_local(&self) -> DartResult<Vec<T>> {
        let mut buf = vec![T::default(); self.local_len()];
        if !buf.is_empty() {
            self.env.local_read(self.gptr_of(self.myrank, 0), as_bytes_mut(&mut buf))?;
        }
        Ok(buf)
    }

    /// Replace this unit's partition. `src.len()` must equal
    /// [`Array::local_len`].
    pub fn write_local(&self, src: &[T]) -> DartResult<()> {
        if src.len() != self.local_len() {
            return Err(DartErr::Invalid(format!(
                "write_local of {} elements into a {}-element partition",
                src.len(),
                self.local_len()
            )));
        }
        if src.is_empty() {
            return Ok(());
        }
        self.env.local_write(self.gptr_of(self.myrank, 0), as_bytes(src))
    }

    /// The owner-computes local view: run `f` on this unit's partition
    /// and write any mutation back. Purely local — no synchronization;
    /// callers running SPMD phases add their own barrier.
    pub fn with_local<R>(&self, f: impl FnOnce(&mut [T]) -> R) -> DartResult<R> {
        let mut buf = self.read_local()?;
        let out = f(&mut buf);
        self.write_local(&buf)?;
        Ok(out)
    }

    /// Collectively free the backing global allocation. Not done in
    /// `Drop`: freeing is a collective call that can fail, which a
    /// destructor could neither order across units nor report.
    pub fn free(self) -> DartResult<()> {
        self.env.team_memfree(self.team, self.gptr)
    }
}
