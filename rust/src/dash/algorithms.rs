//! Owner-computes parallel algorithms over [`Array`].
//!
//! Every algorithm follows the DASH recipe: each unit touches **only its
//! own partition** through the zero-network local view
//! ([`Array::with_local`]/[`Array::read_local`]), then a single team
//! collective combines the per-unit partials — never one one-sided
//! operation per element. The exception is [`copy`], the redistribution
//! path: data must move, so it moves in pattern-coalesced runs (the
//! stress test for the [`Pattern`](super::Pattern) index maps).
//!
//! The combining collectives go through the DART layer, so on multi-node
//! launches with [`crate::dart::DartConfig::hierarchical_collectives`]
//! enabled, [`sum`]/[`min_element`]/[`max_element`] combine their
//! partials hierarchically (intra-node first, one interconnect crossing
//! per node) with no change here — the dash layer inherits locality
//! awareness from the runtime, exactly as the locality-aware follow-up
//! papers argue it should.

use super::array::Array;
use crate::dart::{DartResult, Element};
use crate::mpisim::{as_bytes, as_bytes_mut, MpiOp};

/// Set every element to `value`. Collective over the array's team.
pub fn fill<T: Element>(arr: &Array<'_, T>, value: T) -> DartResult<()> {
    arr.with_local(|local| local.fill(value))?;
    arr.env().barrier(arr.team())
}

/// Replace every element `x` at global index `g` with `f(g, x)` —
/// owner-computes, so `f` runs exactly once per element, on its owner.
/// Collective over the array's team.
pub fn transform<T: Element>(
    arr: &Array<'_, T>,
    f: impl Fn(usize, T) -> T,
) -> DartResult<()> {
    let pat = *arr.pattern();
    let me = arr.myrank();
    arr.with_local(|local| {
        for (l, x) in local.iter_mut().enumerate() {
            *x = f(pat.local_to_global(me, l), *x);
        }
    })?;
    arr.env().barrier(arr.team())
}

/// Global element sum: local partial + one `allreduce`. Collective.
pub fn sum<T: Element>(arr: &Array<'_, T>) -> DartResult<T> {
    let partial: T = arr.read_local()?.into_iter().sum();
    let mut total = [T::default()];
    arr.env().allreduce(arr.team(), &[partial], &mut total, MpiOp::Sum)?;
    Ok(total[0])
}

/// NaN detection through `PartialEq` (only NaN differs from itself;
/// integers never do).
#[allow(clippy::eq_op)]
fn is_nan<T: PartialEq>(x: &T) -> bool {
    x != x
}

/// Candidate selection shared by the local and cross-unit passes: prefer
/// non-NaN over NaN, then `better`, then the smaller global index.
fn prefer<T: Element>(
    best: Option<(usize, T)>,
    cand: (usize, T),
    better: &impl Fn(&T, &T) -> bool,
) -> Option<(usize, T)> {
    let Some((bg, bv)) = best else {
        return Some(cand);
    };
    let (g, v) = cand;
    let take = if is_nan(&bv) {
        !is_nan(&v)
    } else if is_nan(&v) {
        false
    } else {
        better(&v, &bv) || (v == bv && g < bg)
    };
    Some(if take { (g, v) } else { (bg, bv) })
}

/// Shared extremum scaffold: local scan with `better`, then an allgather
/// of `(candidate global index, value)` per unit and a replicated
/// reduction over the `p` candidates (ties resolve to the smallest global
/// index on every unit identically; NaN only wins over other NaNs).
fn extremum<T: Element>(
    arr: &Array<'_, T>,
    better: impl Fn(&T, &T) -> bool,
) -> DartResult<(usize, T)> {
    let pat = *arr.pattern();
    let me = arr.myrank();
    let local = arr.read_local()?;
    let mut best: Option<(usize, T)> = None;
    for (l, v) in local.iter().enumerate() {
        let g = pat.local_to_global(me, l);
        best = prefer(best, (g, *v), &better);
    }
    // Empty partitions send the u64::MAX sentinel every unit discards.
    let (my_g, my_v): (u64, T) = match best {
        Some((g, v)) => (g as u64, v),
        None => (u64::MAX, T::default()),
    };
    let p = pat.nunits();
    let mut all_g = vec![0u64; p];
    let mut all_v = vec![T::default(); p];
    let env = arr.env();
    env.allgather(arr.team(), as_bytes(&[my_g]), as_bytes_mut(&mut all_g))?;
    env.allgather(arr.team(), as_bytes(&[my_v]), as_bytes_mut(&mut all_v))?;
    let mut winner: Option<(usize, T)> = None;
    for (g, v) in all_g.iter().zip(&all_v) {
        if *g == u64::MAX {
            continue;
        }
        winner = prefer(winner, (*g as usize, *v), &better);
    }
    // Every unit can be empty now that zero-length patterns are legal;
    // an empty array has no extremum, and panicking inside a collective
    // would wedge the team, so report it as an error on every member.
    winner.ok_or_else(|| crate::dart::DartErr::Invalid("extremum of an empty array".into()))
}

/// Global minimum as `(global index, value)`; ties resolve to the
/// smallest index. Collective; every unit returns the same answer.
pub fn min_element<T: Element>(arr: &Array<'_, T>) -> DartResult<(usize, T)> {
    extremum(arr, |a, b| a < b)
}

/// Global maximum as `(global index, value)` — mirror of
/// [`min_element`].
pub fn max_element<T: Element>(arr: &Array<'_, T>) -> DartResult<(usize, T)> {
    extremum(arr, |a, b| a > b)
}

/// Distributed copy `src → dst`, **redistributing** between arbitrary
/// (possibly different) patterns of the same length on the same team.
///
/// Owner-computes on the source side: every unit walks its own partition
/// in source-local order ([`Pattern::block_iter`](super::Pattern::block_iter)),
/// intersects each owned run with the destination pattern's runs, and
/// pushes every intersection as ONE deferred-completion put — so a
/// BLOCKED → BLOCKCYCLIC(b) redistribution issues `local_len / b`-ish
/// operations, not `local_len`. One `flush_all` + one barrier complete
/// the exchange. Returns the number of one-sided operations this unit
/// issued (also in `Metrics::dash_coalesced_runs`; bytes in
/// `Metrics::dash_redist_bytes`).
///
/// Units with zero-length local extents (short arrays over wide teams,
/// empty buckets of a data-dependent decomposition, fully empty arrays)
/// participate only in the barriers: they issue no operations and
/// receive none, but must still call in — the exchange is collective.
pub fn copy<T: Element>(src: &Array<'_, T>, dst: &Array<'_, T>) -> DartResult<u64> {
    use crate::dart::DartErr;
    if src.len() != dst.len() {
        return Err(DartErr::Invalid(format!(
            "copy between arrays of different lengths ({} vs {})",
            src.len(),
            dst.len()
        )));
    }
    if src.team() != dst.team() {
        return Err(DartErr::Invalid("copy between arrays on different teams".into()));
    }
    let env = src.env();
    // All prior writes to src must be visible before anyone reads it out.
    env.barrier(src.team())?;
    let local = src.read_local()?;
    let mut ops = 0u64;
    let mut bytes = 0u64;
    for mine in src.pattern().block_iter(src.myrank()) {
        for run in dst.pattern().runs(mine.global, mine.len) {
            let off = mine.local + (run.global - mine.global);
            let payload = as_bytes(&local[off..off + run.len]);
            env.put_async(dst.gptr_of(run.unit, run.local), payload)?;
            ops += 1;
            bytes += payload.len() as u64;
        }
    }
    env.metrics.dash_coalesced_runs.add(ops);
    env.metrics.dash_redist_bytes.add(bytes);
    if ops > 0 {
        env.flush_all(dst.gptr)?;
    }
    env.barrier(src.team())?;
    Ok(ops)
}
