//! `WorkQueue` — a global MPMC task queue with work stealing, built on
//! DART dynamic global memory and the runtime's MPI-3 atomics.
//!
//! Each unit owns one bounded **ring** in a dynamically attached region
//! ([`crate::dart::DartEnv::memattach`]); the allgathered directory of
//! ring pointers makes every ring reachable from every unit, so any unit
//! may enqueue to, dequeue from, or **steal** from any ring — the
//! classic distributed task-farm shape (and the irregular-workload
//! gateway ROADMAP item 2 names).
//!
//! ## The lock-free protocol
//!
//! Three 8-byte control cells head each ring, followed by `cap` 8-byte
//! item slots; all transitions go through the runtime's atomic
//! `fetch_and_op`/`compare_and_swap` hot path (same-node rings collapse
//! to CPU atomics via the locality fast path):
//!
//! - **enqueue** — CAS-reserve a ticket on `tail_reserved` (full ⇒
//!   `Ok(false)`, nothing reserved), write the slot `ticket % cap`, then
//!   CAS-commit `tail_committed` from `ticket` to `ticket+1`. Commits
//!   therefore retire **in ticket order**; a slot is observable only
//!   after every earlier slot is written.
//! - **dequeue/steal** — read `tail_committed` then `head`; if work
//!   remains, read slot `head % cap` **before** CAS-claiming
//!   `head → head+1`. Reading first is safe: overwriting that slot
//!   requires a producer ticket `head + cap`, which the full-check only
//!   admits after `head` has already advanced — in which case our CAS
//!   loses. A won CAS is therefore proof the read value was valid, and
//!   each item is delivered **exactly once** (the chaos invariant
//!   `work_queue_exactly_once` sweeps this under fault injection).
//!
//! CAS retries land in `Metrics::wq_cas_retries`; successful pops served
//! from a remote ring land in `Metrics::wq_steals`.
//!
//! Items are opaque `u64` payloads (an index into task state the
//! application keeps elsewhere — the byte-level DART discipline). Zero is
//! a legal item: emptiness is tracked by the head/tail cells, never by
//! sentinel values.

use crate::dart::gptr::{GlobalPtr, TeamId, UnitId};
use crate::dart::{DartEnv, DartErr, DartResult};
use crate::mpisim::MpiOp;

/// Ring-control cell offsets (bytes).
const HEAD: u64 = 0;
const TAIL_RESERVED: u64 = 8;
const TAIL_COMMITTED: u64 = 16;
/// First item slot (bytes).
const SLOTS: u64 = 24;

/// A distributed MPMC work-stealing queue (see module docs).
pub struct WorkQueue<'e> {
    env: &'e DartEnv,
    team: TeamId,
    /// Slots per unit ring.
    cap: usize,
    /// Directory of the per-unit ring regions, team-rank indexed.
    dir: Vec<GlobalPtr>,
    /// My team-relative rank.
    myrank: usize,
}

impl<'e> WorkQueue<'e> {
    /// Collectively create a queue with a `cap`-slot ring per member.
    pub fn new(env: &'e DartEnv, team: TeamId, cap: usize) -> DartResult<WorkQueue<'e>> {
        if cap == 0 {
            return Err(DartErr::Invalid("work queue with zero-slot rings".into()));
        }
        let p = env.team_size(team)?;
        let myrank = env.team_myid(team)?;
        // Attached memory is zeroed, so head/tails start at 0 — empty.
        let mine = env.memattach(SLOTS + (cap as u64) * 8)?;
        let mut recv = vec![0u8; 16 * p];
        env.allgather(team, &mine.to_bits().to_ne_bytes(), &mut recv)?;
        let dir = recv
            .chunks_exact(16)
            .map(|c| GlobalPtr::from_bits(u128::from_ne_bytes(c.try_into().unwrap())))
            .collect();
        Ok(WorkQueue { env, team, cap, dir, myrank })
    }

    /// Slots per unit ring.
    pub fn ring_capacity(&self) -> usize {
        self.cap
    }

    /// Number of member rings.
    pub fn nrings(&self) -> usize {
        self.dir.len()
    }

    /// The team this queue is distributed over.
    pub fn team(&self) -> TeamId {
        self.team
    }

    /// Atomic read of a control cell (`fetch_and_op` + `MPI_NO_OP`).
    fn cell(&self, unit: usize, off: u64) -> DartResult<u64> {
        self.env.fetch_and_op(self.dir[unit].add(off), 0u64, MpiOp::NoOp)
    }

    /// Enqueue `item` onto team rank `unit`'s ring. `Ok(false)` means the
    /// ring was full and nothing was enqueued (spill to another ring or
    /// retry after consumers drain). Non-collective; any unit may target
    /// any ring.
    pub fn push_to(&self, unit: usize, item: u64) -> DartResult<bool> {
        if unit >= self.dir.len() {
            return Err(DartErr::Invalid(format!(
                "ring {unit} out of 0..{}",
                self.dir.len()
            )));
        }
        let ring = self.dir[unit];
        // CAS-reserve a ticket (never a blind fetch-add: a fetch-add with
        // rollback on full could hand the same ticket out twice, which
        // the in-order commit chain cannot survive).
        let ticket = loop {
            let t = self.cell(unit, TAIL_RESERVED)?;
            let head = self.cell(unit, HEAD)?;
            if t - head >= self.cap as u64 {
                return Ok(false);
            }
            let old = self.env.compare_and_swap(ring.add(TAIL_RESERVED), t, t + 1)?;
            if old == t {
                break t;
            }
            self.env.metrics.wq_cas_retries.bump();
        };
        let slot = ring.add(SLOTS + (ticket % self.cap as u64) * 8);
        self.env.put_blocking(slot, &item.to_ne_bytes())?;
        // Commit in ticket order: my commit can only land once every
        // earlier ticket's slot is committed.
        loop {
            let old = self.env.compare_and_swap(ring.add(TAIL_COMMITTED), ticket, ticket + 1)?;
            if old == ticket {
                return Ok(true);
            }
            self.env.metrics.wq_cas_retries.bump();
        }
    }

    /// Enqueue onto my own ring (the task-farm producer's default).
    pub fn push(&self, item: u64) -> DartResult<bool> {
        self.push_to(self.myrank, item)
    }

    /// Try to dequeue one item from team rank `unit`'s ring. `Ok(None)`
    /// means the ring was observed empty.
    pub fn try_pop_from(&self, unit: usize) -> DartResult<Option<u64>> {
        if unit >= self.dir.len() {
            return Err(DartErr::Invalid(format!(
                "ring {unit} out of 0..{}",
                self.dir.len()
            )));
        }
        let ring = self.dir[unit];
        loop {
            let committed = self.cell(unit, TAIL_COMMITTED)?;
            let head = self.cell(unit, HEAD)?;
            if head >= committed {
                return Ok(None);
            }
            // Read the slot BEFORE claiming it (see module docs for why
            // a won CAS proves this read was not torn by a producer).
            let mut buf = [0u8; 8];
            self.env
                .get_blocking(ring.add(SLOTS + (head % self.cap as u64) * 8), &mut buf)?;
            let old = self.env.compare_and_swap(ring.add(HEAD), head, head + 1)?;
            if old == head {
                return Ok(Some(u64::from_ne_bytes(buf)));
            }
            self.env.metrics.wq_cas_retries.bump();
        }
    }

    /// Dequeue one item: my own ring first, then **steal** round-robin
    /// from the other members' rings (successful remote pops bump
    /// `Metrics::wq_steals`). `Ok(None)` after one full sweep found every
    /// ring empty — which is a moment-in-time observation, not a
    /// termination proof; task farms detect completion with a counter
    /// (see `apps::wqueue`).
    pub fn pop(&self) -> DartResult<Option<u64>> {
        if let Some(item) = self.try_pop_from(self.myrank)? {
            return Ok(Some(item));
        }
        let p = self.dir.len();
        for d in 1..p {
            let victim = (self.myrank + d) % p;
            if let Some(item) = self.try_pop_from(victim)? {
                self.env.metrics.wq_steals.bump();
                return Ok(Some(item));
            }
        }
        Ok(None)
    }

    /// Items currently enqueued across all rings (a racy diagnostic sum —
    /// exact only while no producer or consumer is active).
    pub fn len(&self) -> DartResult<u64> {
        let mut total = 0;
        for u in 0..self.dir.len() {
            total += self.cell(u, TAIL_COMMITTED)? - self.cell(u, HEAD)?;
        }
        Ok(total)
    }

    /// `len() == 0`? (Same caveat as [`WorkQueue::len`].)
    pub fn is_empty(&self) -> DartResult<bool> {
        Ok(self.len()? == 0)
    }

    /// Collectively tear the queue down: detach my ring region.
    pub fn free(self) -> DartResult<()> {
        self.env.barrier(self.team)?;
        self.env.memdetach(self.dir[self.myrank])
    }
}
