//! Data-distribution patterns: bijective global ↔ (unit, local-offset)
//! index maps.
//!
//! A [`Pattern`] describes how the `n` elements of a distributed container
//! are partitioned over the `p` units of a team, in the four classic PGAS
//! distributions of the DASH paper (Fuerlinger et al., §data distribution):
//!
//! - **BLOCKED** — unit `u` owns one contiguous block of
//!   `⌈n/p⌉` elements (trailing units may own less, possibly zero);
//! - **CYCLIC** — element `g` lives on unit `g mod p` (round-robin);
//! - **BLOCKCYCLIC(b)** — blocks of `b` elements are dealt round-robin;
//! - **TILED** — the 2-D distribution: a `rows × cols` matrix is cut into
//!   `tile_rows × tile_cols` tiles dealt round-robin over a
//!   `pgrid_rows × pgrid_cols` unit grid; each unit stores its tiles as
//!   one **dense row-major local matrix** (ragged edge tiles supported).
//!
//! Every variant provides the same three total maps and their inverses:
//! [`Pattern::global_to_local`], [`Pattern::local_to_global`] and
//! [`Pattern::local_extent`] — together a bijection from `[0, n)` onto
//! `⋃_u {u} × [0, local_extent(u))`, property-tested (including uneven
//! `n % p ≠ 0` tails) by `rust/tests/dash_tests.rs`.
//!
//! The coalescing queries [`Pattern::run_len`], [`Pattern::runs`] and
//! [`Pattern::block_iter`] expose the *maximal contiguous runs* of a
//! pattern — index ranges contiguous in global space **and** in one
//! unit's local space at once. They are what lets the containers turn an
//! arbitrary bulk transfer into few one-sided operations instead of one
//! per element (cf. the locality-awareness follow-up, arXiv:1609.09333).

use crate::dart::{DartErr, DartResult};

/// How elements are dealt to units (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    /// One contiguous `⌈n/p⌉`-element block per unit.
    Blocked,
    /// Element `g` on unit `g mod p`.
    Cyclic,
    /// `block`-element chunks dealt round-robin.
    BlockCyclic {
        /// Elements per dealt chunk.
        block: usize,
    },
    /// 2-D tiles dealt round-robin over a unit grid; the linear global
    /// index is the row-major position `i * cols + j`.
    Tiled {
        /// Matrix height in elements.
        rows: usize,
        /// Matrix width in elements.
        cols: usize,
        /// Tile height in elements.
        tile_rows: usize,
        /// Tile width in elements.
        tile_cols: usize,
        /// Unit-grid height (`pgrid_rows * pgrid_cols == nunits`).
        pgrid_rows: usize,
        /// Unit-grid width.
        pgrid_cols: usize,
    },
}

/// One maximal contiguous run: `len` elements starting at global index
/// `global`, stored at `local..local+len` on team-relative unit `unit`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Run {
    /// First global index of the run.
    pub global: usize,
    /// Team-relative owner rank.
    pub unit: usize,
    /// First local offset (in elements) on the owner.
    pub local: usize,
    /// Run length in elements (≥ 1).
    pub len: usize,
}

/// A data-distribution pattern over `n` elements and `nunits` team members
/// (cheap to copy; all queries are O(1) arithmetic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pattern {
    n: usize,
    nunits: usize,
    layout: Layout,
}

/// Count of the elements in `[0, total)` that a 1-D tile-cyclic deal of
/// `tile`-sized chunks over `pgrid` slots assigns to slot `idx` — shared
/// by the BLOCKCYCLIC extent and both TILED axes.
fn dealt_extent(total: usize, tile: usize, pgrid: usize, idx: usize) -> usize {
    let ntiles = total.div_ceil(tile);
    if idx >= ntiles {
        return 0;
    }
    let owned = (ntiles - 1 - idx) / pgrid + 1;
    let mut size = owned * tile;
    // The globally-last chunk may be ragged; it can only be this slot's
    // last owned chunk, so earlier owned chunks are always full.
    if (ntiles - 1) % pgrid == idx && total % tile != 0 {
        size -= tile - total % tile;
    }
    size
}

impl Pattern {
    fn new(n: usize, nunits: usize, layout: Layout) -> DartResult<Pattern> {
        if nunits == 0 {
            return Err(DartErr::Invalid("pattern over zero units".into()));
        }
        // n == 0 is legal: every unit gets extent 0 and `runs`/`block_iter`
        // yield nothing. Data-dependent decompositions (sample-sort buckets,
        // edgeless graphs) produce genuinely empty distributions, so the
        // index maps must tolerate them instead of forcing callers to
        // special-case emptiness before construction.
        Ok(Pattern { n, nunits, layout })
    }

    /// A BLOCKED distribution of `n` elements over `nunits` units.
    pub fn blocked(n: usize, nunits: usize) -> DartResult<Pattern> {
        Pattern::new(n, nunits, Layout::Blocked)
    }

    /// A CYCLIC distribution of `n` elements over `nunits` units.
    pub fn cyclic(n: usize, nunits: usize) -> DartResult<Pattern> {
        Pattern::new(n, nunits, Layout::Cyclic)
    }

    /// A BLOCKCYCLIC(`block`) distribution of `n` elements.
    pub fn block_cyclic(n: usize, nunits: usize, block: usize) -> DartResult<Pattern> {
        if block == 0 {
            return Err(DartErr::Invalid("block-cyclic with zero block".into()));
        }
        Pattern::new(n, nunits, Layout::BlockCyclic { block })
    }

    /// A 2-D TILED distribution of a `rows × cols` matrix in
    /// `tile_rows × tile_cols` tiles over a `pgrid_rows × pgrid_cols`
    /// unit grid (`nunits = pgrid_rows * pgrid_cols`).
    pub fn tiled(
        rows: usize,
        cols: usize,
        tile_rows: usize,
        tile_cols: usize,
        pgrid_rows: usize,
        pgrid_cols: usize,
    ) -> DartResult<Pattern> {
        if tile_rows == 0 || tile_cols == 0 {
            return Err(DartErr::Invalid("tiled pattern with zero tile extent".into()));
        }
        if pgrid_rows == 0 || pgrid_cols == 0 {
            return Err(DartErr::Invalid("tiled pattern with empty unit grid".into()));
        }
        Pattern::new(
            rows * cols,
            pgrid_rows * pgrid_cols,
            Layout::Tiled { rows, cols, tile_rows, tile_cols, pgrid_rows, pgrid_cols },
        )
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether the pattern distributes zero elements (every unit then has
    /// local extent 0 and all run iterators are empty).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of team-relative units the pattern distributes over.
    pub fn nunits(&self) -> usize {
        self.nunits
    }

    /// The distribution variant.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// The BLOCKED block size `⌈n/p⌉`.
    fn blocked_size(&self) -> usize {
        self.n.div_ceil(self.nunits)
    }

    /// Map a global index to `(team-relative unit, local element offset)`.
    ///
    /// # Panics
    /// If `g >= self.len()`.
    pub fn global_to_local(&self, g: usize) -> (usize, usize) {
        assert!(g < self.n, "global index {g} out of range 0..{}", self.n);
        let p = self.nunits;
        match self.layout {
            Layout::Blocked => {
                let b = self.blocked_size();
                (g / b, g % b)
            }
            Layout::Cyclic => (g % p, g / p),
            Layout::BlockCyclic { block } => {
                let chunk = g / block;
                ((chunk % p), (chunk / p) * block + g % block)
            }
            Layout::Tiled { cols, tile_rows, tile_cols, pgrid_rows, pgrid_cols, .. } => {
                let (i, j) = (g / cols, g % cols);
                let (ur, uc) = ((i / tile_rows) % pgrid_rows, (j / tile_cols) % pgrid_cols);
                let w = dealt_extent(cols, tile_cols, pgrid_cols, uc);
                let lrow = (i / tile_rows / pgrid_rows) * tile_rows + i % tile_rows;
                let lcol = (j / tile_cols / pgrid_cols) * tile_cols + j % tile_cols;
                (ur * pgrid_cols + uc, lrow * w + lcol)
            }
        }
    }

    /// Inverse of [`Pattern::global_to_local`].
    ///
    /// # Panics
    /// If `unit >= nunits()` or `local >= local_extent(unit)`.
    pub fn local_to_global(&self, unit: usize, local: usize) -> usize {
        assert!(unit < self.nunits, "unit {unit} out of range 0..{}", self.nunits);
        assert!(
            local < self.local_extent(unit),
            "local offset {local} out of unit {unit}'s extent {}",
            self.local_extent(unit)
        );
        let p = self.nunits;
        match self.layout {
            Layout::Blocked => unit * self.blocked_size() + local,
            Layout::Cyclic => local * p + unit,
            Layout::BlockCyclic { block } => {
                ((local / block) * p + unit) * block + local % block
            }
            Layout::Tiled { cols, tile_rows, tile_cols, pgrid_rows, pgrid_cols, .. } => {
                let (ur, uc) = (unit / pgrid_cols, unit % pgrid_cols);
                let w = dealt_extent(cols, tile_cols, pgrid_cols, uc);
                let (lrow, lcol) = (local / w, local % w);
                let i = (lrow / tile_rows * pgrid_rows + ur) * tile_rows + lrow % tile_rows;
                let j = (lcol / tile_cols * pgrid_cols + uc) * tile_cols + lcol % tile_cols;
                i * cols + j
            }
        }
    }

    /// Number of elements unit `unit` owns (its local storage extent).
    ///
    /// # Panics
    /// If `unit >= nunits()`.
    pub fn local_extent(&self, unit: usize) -> usize {
        assert!(unit < self.nunits, "unit {unit} out of range 0..{}", self.nunits);
        match self.layout {
            Layout::Blocked => {
                let b = self.blocked_size();
                let lo = unit * b;
                if lo >= self.n {
                    0
                } else {
                    b.min(self.n - lo)
                }
            }
            Layout::Cyclic => {
                if unit >= self.n {
                    0
                } else {
                    (self.n - 1 - unit) / self.nunits + 1
                }
            }
            Layout::BlockCyclic { block } => dealt_extent(self.n, block, self.nunits, unit),
            Layout::Tiled { .. } => {
                let (h, w) = self.tiled_local_dims(unit);
                h * w
            }
        }
    }

    /// The largest [`Pattern::local_extent`] over all units — the
    /// symmetric per-unit allocation size the containers use.
    pub fn max_local_extent(&self) -> usize {
        (0..self.nunits).map(|u| self.local_extent(u)).max().unwrap_or(0)
    }

    /// TILED only: unit `unit`'s dense local matrix dimensions
    /// `(local rows, local cols)`.
    ///
    /// # Panics
    /// If the pattern is not TILED, or `unit >= nunits()`.
    pub fn tiled_local_dims(&self, unit: usize) -> (usize, usize) {
        assert!(unit < self.nunits, "unit {unit} out of range 0..{}", self.nunits);
        match self.layout {
            Layout::Tiled { rows, cols, tile_rows, tile_cols, pgrid_rows, pgrid_cols } => {
                let (ur, uc) = (unit / pgrid_cols, unit % pgrid_cols);
                (
                    dealt_extent(rows, tile_rows, pgrid_rows, ur),
                    dealt_extent(cols, tile_cols, pgrid_cols, uc),
                )
            }
            _ => panic!("tiled_local_dims on a 1-D pattern"),
        }
    }

    /// Length of the maximal run starting at global index `g` that is
    /// contiguous in global space, owned by one unit, and contiguous in
    /// that unit's local storage. Always ≥ 1.
    ///
    /// # Panics
    /// If `g >= self.len()`.
    pub fn run_len(&self, g: usize) -> usize {
        assert!(g < self.n, "global index {g} out of range 0..{}", self.n);
        let p = self.nunits;
        if p == 1 {
            // One unit: local storage mirrors global order in every layout.
            return self.n - g;
        }
        match self.layout {
            Layout::Blocked => {
                let b = self.blocked_size();
                ((g / b + 1) * b).min(self.n) - g
            }
            Layout::Cyclic => 1,
            Layout::BlockCyclic { block } => ((g / block + 1) * block).min(self.n) - g,
            Layout::Tiled { cols, tile_cols, pgrid_cols, .. } => {
                let j = g % cols;
                // Runs break at tile-column boundaries (owner changes when
                // pgrid_cols > 1) and always at the end of the matrix row.
                let limit = if pgrid_cols == 1 { cols } else { (j / tile_cols + 1) * tile_cols };
                limit.min(cols) - j
            }
        }
    }

    /// Iterate the maximal contiguous runs covering the global range
    /// `[start, start + len)`, in ascending global order. Each element of
    /// the range appears in exactly one [`Run`].
    ///
    /// # Panics
    /// If `start + len > self.len()`.
    pub fn runs(&self, start: usize, len: usize) -> impl Iterator<Item = Run> {
        assert!(start + len <= self.n, "range {start}+{len} out of 0..{}", self.n);
        let pat = *self;
        let end = start + len;
        let mut g = start;
        std::iter::from_fn(move || {
            if g >= end {
                return None;
            }
            let (unit, local) = pat.global_to_local(g);
            let len = pat.run_len(g).min(end - g);
            let run = Run { global: g, unit, local, len };
            g += len;
            Some(run)
        })
    }

    /// Iterate unit `unit`'s owned runs in **local storage order** (the
    /// owner-computes traversal: ascending local offset, each with its
    /// global anchor).
    ///
    /// # Panics
    /// If `unit >= nunits()`.
    pub fn block_iter(&self, unit: usize) -> impl Iterator<Item = Run> {
        let pat = *self;
        let extent = self.local_extent(unit);
        let mut l = 0usize;
        std::iter::from_fn(move || {
            if l >= extent {
                return None;
            }
            let g = pat.local_to_global(unit, l);
            let len = pat.run_len(g).min(extent - l);
            let run = Run { global: g, unit, local: l, len };
            l += len;
            Some(run)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_bijection(pat: &Pattern) {
        let mut seen = vec![false; pat.len()];
        let extents: Vec<usize> = (0..pat.nunits()).map(|u| pat.local_extent(u)).collect();
        assert_eq!(extents.iter().sum::<usize>(), pat.len(), "extents must cover n");
        for g in 0..pat.len() {
            let (u, l) = pat.global_to_local(g);
            assert!(u < pat.nunits());
            assert!(l < extents[u], "g={g} → ({u},{l}) beyond extent {}", extents[u]);
            assert_eq!(pat.local_to_global(u, l), g, "inverse broken at g={g}");
            assert!(!seen[g]);
            seen[g] = true;
        }
    }

    #[test]
    fn blocked_even_and_uneven() {
        check_bijection(&Pattern::blocked(12, 4).unwrap());
        check_bijection(&Pattern::blocked(13, 4).unwrap());
        check_bijection(&Pattern::blocked(3, 5).unwrap()); // some units empty
    }

    #[test]
    fn cyclic_and_block_cyclic() {
        check_bijection(&Pattern::cyclic(17, 4).unwrap());
        check_bijection(&Pattern::block_cyclic(37, 3, 4).unwrap());
        check_bijection(&Pattern::block_cyclic(8, 4, 16).unwrap()); // one short chunk
    }

    #[test]
    fn tiled_exact_and_ragged() {
        check_bijection(&Pattern::tiled(8, 8, 4, 4, 2, 2).unwrap());
        check_bijection(&Pattern::tiled(10, 14, 3, 4, 2, 2).unwrap());
    }

    #[test]
    fn runs_partition_and_coalesce() {
        let pat = Pattern::block_cyclic(64, 4, 8).unwrap();
        let runs: Vec<Run> = pat.runs(0, 64).collect();
        assert_eq!(runs.len(), 8, "64 elements in 8-element chunks → 8 runs");
        let mut g = 0;
        for r in &runs {
            assert_eq!(r.global, g);
            g += r.len;
        }
        assert_eq!(g, 64);
    }

    #[test]
    fn block_iter_walks_local_order() {
        let pat = Pattern::cyclic(10, 3).unwrap();
        for u in 0..3 {
            let mut l = 0;
            for r in pat.block_iter(u) {
                assert_eq!(r.local, l);
                assert_eq!(pat.local_to_global(u, r.local), r.global);
                l += r.len;
            }
            assert_eq!(l, pat.local_extent(u));
        }
    }

    #[test]
    fn invalid_shapes_rejected() {
        assert!(Pattern::cyclic(8, 0).is_err());
        assert!(Pattern::block_cyclic(8, 2, 0).is_err());
        assert!(Pattern::tiled(4, 4, 0, 2, 2, 1).is_err());
    }

    #[test]
    fn empty_patterns_are_legal_and_inert() {
        for pat in [
            Pattern::blocked(0, 4).unwrap(),
            Pattern::cyclic(0, 4).unwrap(),
            Pattern::block_cyclic(0, 4, 3).unwrap(),
            Pattern::tiled(0, 5, 2, 2, 2, 2).unwrap(),
        ] {
            assert!(pat.is_empty());
            assert_eq!(pat.len(), 0);
            assert_eq!(pat.max_local_extent(), 0);
            for u in 0..pat.nunits() {
                assert_eq!(pat.local_extent(u), 0);
                assert_eq!(pat.block_iter(u).count(), 0);
            }
            assert_eq!(pat.runs(0, 0).count(), 0);
        }
    }
}
