//! `Vector<T>` — a typed, **growable** 1-D distributed array over DART
//! dynamic global memory (the DASH paper's dynamic containers, on the
//! `memattach` half of the memory model).
//!
//! Where [`super::Array`] owns one fixed symmetric allocation, a `Vector`
//! owns one **dynamically attached region per unit**
//! ([`crate::dart::DartEnv::memattach`]) plus an allgathered directory of
//! the regions' global pointers — so capacity is bounded by nothing but
//! memory, and growth is a first-class operation:
//!
//! - [`Vector::push`] — collective amortized-doubling append: every
//!   member contributes one element per call (appended in team-rank
//!   order); when the claimed range exceeds capacity the vector doubles,
//!   redistributing into freshly attached regions;
//! - [`Vector::push_back_global`] — non-collective append: any unit
//!   CAS-claims the next free index (atomic `fetch_and_op` on the shared
//!   length cell) and writes it; at capacity it reports
//!   [`DartErr::Invalid`] — growth stays collective-only, because only a
//!   collective call can attach new regions on every member;
//! - growth is **pattern-preserving**: the BLOCKED distribution is
//!   recomputed over the new capacity and each unit redistributes its old
//!   block with the same coalescing-runs idiom as
//!   [`super::algorithms::copy`] (one deferred put per maximal run,
//!   counted in `Metrics::dash_coalesced_runs`/`dash_redist_bytes`), so a
//!   vector grown through any number of doublings is **bit-identical** to
//!   a preallocated [`super::Array`] of the final size — the invariant
//!   the chaos suite sweeps.
//!
//! The element access tiers mirror [`super::Array`]: blocking
//! element get/put, run-coalesced bulk [`Vector::copy_in`]/
//! [`Vector::copy_out`], and owner-computes local views.

use super::pattern::Pattern;
use crate::dart::gptr::{GlobalPtr, TeamId, UnitId};
use crate::dart::{DartEnv, DartErr, DartResult, Element};
use crate::mpisim::{as_bytes, as_bytes_mut, MpiOp};
use std::marker::PhantomData;

/// A typed growable distributed 1-D vector (see module docs).
pub struct Vector<'e, T: Element> {
    env: &'e DartEnv,
    team: TeamId,
    /// BLOCKED distribution of the current *capacity* (not length).
    pattern: Pattern,
    capacity: usize,
    /// Directory of the per-unit attached regions, team-rank indexed —
    /// rebuilt (allgather) on every growth.
    dir: Vec<GlobalPtr>,
    /// The shared length cell: an 8-byte symmetric allocation all
    /// appends `fetch_and_op` on.
    len_gptr: GlobalPtr,
    /// Absolute unit id of every team rank (rank-indexed).
    units: Vec<UnitId>,
    /// My team-relative rank.
    myrank: usize,
    _elem: PhantomData<T>,
}

impl<'e, T: Element> Vector<'e, T> {
    /// Collectively create an empty vector with room for `capacity`
    /// elements (at least one slot per member is reserved, so growth
    /// arithmetic never degenerates). Every slot starts as
    /// `T::default()`.
    pub fn with_capacity(
        env: &'e DartEnv,
        team: TeamId,
        capacity: usize,
    ) -> DartResult<Vector<'e, T>> {
        let p = env.team_size(team)?;
        let capacity = capacity.max(p);
        let pattern = Pattern::blocked(capacity, p)?;
        let units: Vec<UnitId> =
            (0..p).map(|r| env.team_unit_l2g(team, r)).collect::<DartResult<_>>()?;
        let myrank = env.team_myid(team)?;
        let dir = Self::attach_and_gather(env, team, &pattern)?;
        // The shared length cell lives in symmetric memory so every
        // member can compute its pointer; the first member zeroes it.
        let len_gptr = env.team_memalloc_aligned(team, 8)?;
        if myrank == 0 {
            env.local_write(len_gptr, &0u64.to_ne_bytes())?;
        }
        let v =
            Vector { env, team, pattern, capacity, dir, len_gptr, units, myrank, _elem: PhantomData };
        // Deterministic initial contents (same contract as `Array::new`),
        // then a rendezvous so no unit reads an uninitialized partition.
        let fill = vec![T::default(); v.local_len()];
        v.write_local(&fill)?;
        env.barrier(team)?;
        Ok(v)
    }

    /// Attach this unit's region for `pattern` (zeroed by the runtime)
    /// and allgather the directory. Collective.
    fn attach_and_gather(
        env: &DartEnv,
        team: TeamId,
        pattern: &Pattern,
    ) -> DartResult<Vec<GlobalPtr>> {
        let p = pattern.nunits();
        // Symmetric region size (max extent) so growth and directory
        // arithmetic never special-case the ragged last block.
        let bytes = (pattern.max_local_extent() * std::mem::size_of::<T>()).max(1);
        let mine = env.memattach(bytes as u64)?;
        let mut recv = vec![0u8; 16 * p];
        env.allgather(team, &mine.to_bits().to_ne_bytes(), &mut recv)?;
        Ok(recv
            .chunks_exact(16)
            .map(|c| GlobalPtr::from_bits(u128::from_ne_bytes(c.try_into().unwrap())))
            .collect())
    }

    /// Number of elements currently stored (atomic read of the shared
    /// length cell — coherent under concurrent appends).
    pub fn len(&self) -> DartResult<usize> {
        Ok(self.env.fetch_and_op(self.len_gptr, 0u64, MpiOp::NoOp)? as usize)
    }

    /// `len() == 0`?
    pub fn is_empty(&self) -> DartResult<bool> {
        Ok(self.len()? == 0)
    }

    /// Current capacity in elements.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The current distribution pattern (BLOCKED over the capacity).
    pub fn pattern(&self) -> &Pattern {
        &self.pattern
    }

    /// The team this vector is distributed over.
    pub fn team(&self) -> TeamId {
        self.team
    }

    /// Global pointer to local offset `local` of team rank `unit`'s
    /// region — directory lookup + pointer arithmetic, no communication.
    fn gptr_of(&self, unit: usize, local: usize) -> GlobalPtr {
        self.dir[unit].add((local * std::mem::size_of::<T>()) as u64)
    }

    fn check_range(&self, start: usize, len: usize) -> DartResult<()> {
        match start.checked_add(len) {
            Some(end) if end <= self.capacity => Ok(()),
            _ => Err(DartErr::Invalid(format!(
                "global range {start}+{len} out of vector capacity 0..{}",
                self.capacity
            ))),
        }
    }

    /// Read one element (blocking one-sided get). Bounds-checked against
    /// the *capacity*; reading at or past [`Vector::len`] yields
    /// `T::default()` fill.
    pub fn get(&self, g: usize) -> DartResult<T> {
        self.check_range(g, 1)?;
        let (u, l) = self.pattern.global_to_local(g);
        let mut v = [T::default()];
        self.env.get_blocking(self.gptr_of(u, l), as_bytes_mut(&mut v))?;
        Ok(v[0])
    }

    /// Write one element in place (blocking one-sided put).
    pub fn put(&self, g: usize, value: T) -> DartResult<()> {
        self.check_range(g, 1)?;
        let (u, l) = self.pattern.global_to_local(g);
        self.env.put_blocking(self.gptr_of(u, l), as_bytes(&[value]))
    }

    /// **Collective** append: every member contributes `value`; the team
    /// atomically claims a `team_size`-element range and member rank `r`
    /// writes slot `base + r`. Doubles the capacity first (collectively,
    /// with redistribution) whenever the claimed range would not fit —
    /// the amortized-doubling discipline. Returns the global index of
    /// *my* element. Not to be mixed with concurrent
    /// [`Vector::push_back_global`] calls.
    pub fn push(&mut self, value: T) -> DartResult<usize> {
        let p = self.units.len();
        // Agree on the base index, growing until the range fits. The
        // length is only advanced after the slots are written, so a
        // concurrent reader never sees a covered-but-unwritten slot.
        let base = loop {
            let mut b = [0u8; 8];
            if self.myrank == 0 {
                b = (self.len()? as u64).to_ne_bytes();
            }
            self.env.bcast(self.team, &mut b, 0)?;
            let base = u64::from_ne_bytes(b) as usize;
            if base + p <= self.capacity {
                break base;
            }
            let mut target = self.capacity.max(1);
            while base + p > target {
                target *= 2;
            }
            self.grow_to(target)?;
        };
        let g = base + self.myrank;
        let (u, l) = self.pattern.global_to_local(g);
        self.env.put_blocking(self.gptr_of(u, l), as_bytes(&[value]))?;
        self.env.barrier(self.team)?;
        if self.myrank == 0 {
            self.env.fetch_and_op(self.len_gptr, p as u64, MpiOp::Sum)?;
        }
        self.env.barrier(self.team)?;
        Ok(g)
    }

    /// **Non-collective** append: atomically claim the next free index
    /// and write `value` there; any unit may call at any time. At
    /// capacity the claim is rolled back and [`DartErr::Invalid`] is
    /// reported — growing needs every member's participation
    /// ([`Vector::push`] or [`Vector::reserve`]), which a non-collective
    /// call cannot provide. Returns the claimed global index.
    pub fn push_back_global(&self, value: T) -> DartResult<usize> {
        let idx = self.env.fetch_and_op(self.len_gptr, 1u64, MpiOp::Sum)? as usize;
        if idx >= self.capacity {
            // Surrender the claim (wrapping -1) so the length stays the
            // true element count for a later collective grow-and-retry.
            self.env.fetch_and_op(self.len_gptr, u64::MAX, MpiOp::Sum)?;
            return Err(DartErr::Invalid(format!(
                "vector full (len == capacity == {}): grow collectively with \
                 push() or reserve()",
                self.capacity
            )));
        }
        let (u, l) = self.pattern.global_to_local(idx);
        self.env.put_blocking(self.gptr_of(u, l), as_bytes(&[value]))?;
        Ok(idx)
    }

    /// **Collective**: grow capacity to at least `new_cap` (rounded up by
    /// doubling), redistributing existing elements. A no-op if the
    /// capacity already suffices.
    pub fn reserve(&mut self, new_cap: usize) -> DartResult<()> {
        let mut target = self.capacity.max(1);
        while target < new_cap {
            target *= 2;
        }
        if target > self.capacity {
            self.grow_to(target)?;
        }
        Ok(())
    }

    /// The collective growth step: attach regions for the new BLOCKED
    /// pattern, redistribute my old block into them (one deferred put per
    /// maximal contiguous run of the new pattern — the coalescing-copy
    /// idiom), then detach the old regions.
    fn grow_to(&mut self, new_cap: usize) -> DartResult<()> {
        debug_assert!(new_cap > self.capacity);
        let p = self.units.len();
        let new_pattern = Pattern::blocked(new_cap, p)?;
        let new_dir = Self::attach_and_gather(self.env, self.team, &new_pattern)?;
        // Default-fill my new region *before* any redistribution put can
        // land in it (the barrier orders the two phases), keeping the
        // `T::default()` fill contract through growth.
        let fill = vec![T::default(); new_pattern.local_extent(self.myrank)];
        if !fill.is_empty() {
            self.env.local_write(new_dir[self.myrank], as_bytes(&fill))?;
        }
        self.env.barrier(self.team)?;
        // Owner-computes redistribution of my old contiguous block.
        let old_extent = self.pattern.local_extent(self.myrank);
        if old_extent > 0 {
            let old_vals = self.read_local()?;
            let my_start = self.pattern.local_to_global(self.myrank, 0);
            let mut ops = 0u64;
            for run in new_pattern.runs(my_start, old_extent) {
                let off = run.global - my_start;
                let dst =
                    new_dir[run.unit].add((run.local * std::mem::size_of::<T>()) as u64);
                self.env.put_async(dst, as_bytes(&old_vals[off..off + run.len]))?;
                ops += 1;
            }
            self.env.metrics.dash_coalesced_runs.add(ops);
            self.env
                .metrics
                .dash_redist_bytes
                .add((old_extent * std::mem::size_of::<T>()) as u64);
            // One dynamic window per env: this completes every
            // redistribution put regardless of target region.
            self.env.flush_all(new_dir[self.myrank])?;
        }
        self.env.barrier(self.team)?;
        self.env.memdetach(self.dir[self.myrank])?;
        self.pattern = new_pattern;
        self.capacity = new_cap;
        self.dir = new_dir;
        Ok(())
    }

    /// Bulk write with run coalescing (see [`super::Array::copy_in`]).
    /// Returns the number of one-sided operations issued.
    pub fn copy_in(&self, start: usize, src: &[T]) -> DartResult<u64> {
        self.check_range(start, src.len())?;
        if src.is_empty() {
            return Ok(0);
        }
        let mut ops = 0u64;
        for run in self.pattern.runs(start, src.len()) {
            let off = run.global - start;
            self.env
                .put_async(self.gptr_of(run.unit, run.local), as_bytes(&src[off..off + run.len]))?;
            ops += 1;
        }
        self.env.metrics.dash_coalesced_runs.add(ops);
        self.env.flush_all(self.dir[self.myrank])?;
        Ok(ops)
    }

    /// Bulk read with run coalescing (see [`super::Array::copy_out`]).
    /// Returns the number of one-sided operations issued.
    pub fn copy_out(&self, start: usize, dst: &mut [T]) -> DartResult<u64> {
        self.check_range(start, dst.len())?;
        if dst.is_empty() {
            return Ok(0);
        }
        let mut ops = 0u64;
        for run in self.pattern.runs(start, dst.len()) {
            let off = run.global - start;
            self.env.get_async(
                self.gptr_of(run.unit, run.local),
                as_bytes_mut(&mut dst[off..off + run.len]),
            )?;
            ops += 1;
        }
        self.env.metrics.dash_coalesced_runs.add(ops);
        self.env.flush_all(self.dir[self.myrank])?;
        Ok(ops)
    }

    /// Number of capacity slots stored on this unit.
    pub fn local_len(&self) -> usize {
        self.pattern.local_extent(self.myrank)
    }

    /// Copy of this unit's region, in local storage order.
    pub fn read_local(&self) -> DartResult<Vec<T>> {
        let mut buf = vec![T::default(); self.local_len()];
        if !buf.is_empty() {
            self.env.local_read(self.dir[self.myrank], as_bytes_mut(&mut buf))?;
        }
        Ok(buf)
    }

    /// Replace this unit's region. `src.len()` must equal
    /// [`Vector::local_len`].
    pub fn write_local(&self, src: &[T]) -> DartResult<()> {
        if src.len() != self.local_len() {
            return Err(DartErr::Invalid(format!(
                "write_local of {} elements into a {}-element partition",
                src.len(),
                self.local_len()
            )));
        }
        if src.is_empty() {
            return Ok(());
        }
        self.env.local_write(self.dir[self.myrank], as_bytes(src))
    }

    /// The owner-computes local view (see [`super::Array::with_local`]).
    pub fn with_local<R>(&self, f: impl FnOnce(&mut [T]) -> R) -> DartResult<R> {
        let mut buf = self.read_local()?;
        let out = f(&mut buf);
        self.write_local(&buf)?;
        Ok(out)
    }

    /// Collectively tear the vector down: detach my region, free the
    /// length cell. Not done in `Drop` for the same reason as
    /// [`super::Array::free`].
    pub fn free(self) -> DartResult<()> {
        self.env.barrier(self.team)?;
        self.env.memdetach(self.dir[self.myrank])?;
        self.env.team_memfree(self.team, self.len_gptr)
    }
}
