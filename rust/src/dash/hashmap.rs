//! `HashMap<K, V>` — a distributed key-value map over DART global memory,
//! with a **lock-free** insert/update hot path on the runtime's MPI-3
//! atomics (the primitives the paper exposes in §IV-B6 precisely so
//! applications can avoid serializing on mutexes).
//!
//! Layout: one symmetric collective allocation per team member holding
//! `slots_per_unit` **slots** of three `u64` words — `[tag, key, value]`
//! (24 bytes). A slot is EMPTY while its tag word is zero; an occupied
//! slot's tag is the key's **fingerprint** (a 64-bit hash with the top
//! bit forced, so it can never read as EMPTY). Slots are grouped into
//! **buckets** of [`BUCKET_SLOTS`] and probing is *bucket-confined*: a
//! key probes only its own bucket's slots, in a fixed order. That keeps
//! every access O(bucket), and — crucially for the locks-vs-atomics
//! ablation — it means one lock per bucket really covers every slot an
//! operation under that lock can touch.
//!
//! Routing is **consistent hashing**: each team member contributes
//! [`VNODES`] points on a 64-bit ring; a key's owner is the member whose
//! point follows the key's hash. Unlike `hash % units` the assignment is
//! stable under ring edits, and the virtual nodes smooth the per-unit
//! share (cf. the DASH container designs over DART, arXiv:1610.01482).
//!
//! Three write disciplines share this one layout (so their final
//! contents are directly comparable):
//!
//! - [`HashMap::put`] — the **lock-free hot path**: claim an EMPTY tag
//!   with `compare_and_swap` (bounded retry within the bucket), then
//!   publish key and value with deferred atomic `accumulate_async`
//!   `Replace` writes — remote completion batches into the next
//!   [`HashMap::flush`], and same-node targets complete via the
//!   CPU-atomic fast path. Lost CAS races are counted in
//!   [`HashMap::cas_retries`].
//! - [`HashMap::put_exclusive`] — plain read-modify-write (no atomics),
//!   correct only under a caller-held lock covering the key's bucket
//!   (e.g. a [`crate::dart::DartLock`] stripe keyed by
//!   [`HashMap::lock_index`]) — the MCS-lock backend of the kvstore.
//! - [`HashMap::local_put`]/[`HashMap::local_get`] — owner-computes: the
//!   owning unit applies operations to its own partition with plain
//!   loads/stores; remote units ship requests via messages.
//!
//! [`HashMap::get`] is ONE coalesced 24-byte read per probed slot (and
//! the first probe hits for any key inserted without collisions). Reads
//! verify the stored key word, so a fingerprint collision cannot return
//! a wrong entry; the update path trusts the fingerprint alone (two live
//! keys colliding on 63 hash bits is a ~2⁻⁶³-per-pair event, documented
//! trade-off). Keys and values are any [`Element`] type (≤ 8 bytes),
//! stored zero-extended in their word.

use super::Element;
use crate::dart::gptr::{GlobalPtr, TeamId, UnitId};
use crate::dart::{DartEnv, DartErr, DartResult};
use crate::mpisim::{as_bytes, as_bytes_mut, MpiOp};
use std::cell::Cell;
use std::marker::PhantomData;

/// Slots per bucket — the probe horizon and the lock-coverage unit.
pub const BUCKET_SLOTS: usize = 16;

/// Virtual nodes per team member on the consistent-hash ring.
pub const VNODES: usize = 16;

/// Bytes per slot: three `u64` words `[tag, key, value]`.
pub const SLOT_BYTES: usize = 24;

/// Tag word of an empty slot.
const EMPTY: u64 = 0;

/// Fingerprints force the top bit so no occupied tag equals [`EMPTY`].
const FP_BIT: u64 = 1 << 63;

/// Salt decorrelating the bucket index from the ring position.
const BUCKET_SALT: u64 = 0x9E6C_63B2_27D4_1CF5;

/// splitmix64 finalizer — the repo's standard deterministic mix.
#[inline]
fn hash64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Zero-extend an element into its storage word.
#[inline]
fn bits_of<T: Element>(x: T) -> u64 {
    let mut b = [0u8; 8];
    let n = std::mem::size_of::<T>();
    b[..n].copy_from_slice(as_bytes(std::slice::from_ref(&x)));
    u64::from_ne_bytes(b)
}

/// Recover an element from its storage word.
#[inline]
fn from_bits<T: Element>(bits: u64) -> T {
    let b = bits.to_ne_bytes();
    let mut v = [T::default()];
    let n = std::mem::size_of::<T>();
    as_bytes_mut(&mut v).copy_from_slice(&b[..n]);
    v[0]
}

/// A distributed key-value map (see module docs).
pub struct HashMap<'e, K: Element, V: Element> {
    env: &'e DartEnv,
    team: TeamId,
    /// Base collective pointer of the backing allocation.
    gptr: GlobalPtr,
    /// Absolute unit id of every team rank (rank-indexed).
    units: Vec<UnitId>,
    myrank: usize,
    slots_per_unit: usize,
    /// Consistent-hash ring: sorted `(point, team rank)` pairs.
    ring: Vec<(u64, usize)>,
    /// Lost `compare_and_swap` claims on this unit (contention gauge).
    cas_retries: Cell<u64>,
    _kv: PhantomData<(K, V)>,
}

impl<'e, K: Element, V: Element> HashMap<'e, K, V> {
    /// Collectively create a map with (at least) `slots_per_unit` slots on
    /// every team member — rounded up to whole buckets. Keys and values
    /// must fit their 8-byte storage word (every built-in [`Element`]
    /// does).
    pub fn new(env: &'e DartEnv, team: TeamId, slots_per_unit: usize) -> DartResult<Self> {
        if std::mem::size_of::<K>() > 8 || std::mem::size_of::<V>() > 8 {
            return Err(DartErr::Invalid("hashmap keys/values must be at most 8 bytes".into()));
        }
        let slots = slots_per_unit.max(BUCKET_SLOTS).div_ceil(BUCKET_SLOTS) * BUCKET_SLOTS;
        let p = env.team_size(team)?;
        let gptr = env.team_memalloc_aligned(team, (slots * SLOT_BYTES) as u64)?;
        let units: Vec<UnitId> =
            (0..p).map(|r| env.team_unit_l2g(team, r)).collect::<DartResult<_>>()?;
        let myrank = env.team_myid(team)?;
        let mut ring: Vec<(u64, usize)> = (0..p)
            .flat_map(|r| (0..VNODES).map(move |v| (hash64(((r as u64) << 32) | v as u64), r)))
            .collect();
        ring.sort_unstable();
        let map = HashMap {
            env,
            team,
            gptr,
            units,
            myrank,
            slots_per_unit: slots,
            ring,
            cas_retries: Cell::new(0),
            _kv: PhantomData,
        };
        // Zero my partition (all slots EMPTY), then rendezvous so nobody
        // probes an uninitialized partition.
        let zeros = vec![0u8; slots * SLOT_BYTES];
        env.local_write(map.word_gptr(myrank, 0, 0), &zeros)?;
        env.barrier(team)?;
        Ok(map)
    }

    /// Slots per team member (rounded up to whole buckets).
    pub fn slots_per_unit(&self) -> usize {
        self.slots_per_unit
    }

    /// Buckets per team member.
    pub fn buckets_per_unit(&self) -> usize {
        self.slots_per_unit / BUCKET_SLOTS
    }

    /// Total slot capacity across the team.
    pub fn capacity(&self) -> usize {
        self.slots_per_unit * self.units.len()
    }

    /// The team this map is distributed over.
    pub fn team(&self) -> TeamId {
        self.team
    }

    /// The runtime handle the map was created with.
    pub fn env(&self) -> &'e DartEnv {
        self.env
    }

    /// Lost CAS claims on this unit since creation — the lock-free hot
    /// path's contention gauge (reported by the `perf_kv` bench).
    pub fn cas_retries(&self) -> u64 {
        self.cas_retries.get()
    }

    // ------------------------------------------------------------------
    // Routing
    // ------------------------------------------------------------------

    #[inline]
    fn fp(kb: u64) -> u64 {
        hash64(kb) | FP_BIT
    }

    #[inline]
    fn owner_of_bits(&self, kb: u64) -> usize {
        let h = hash64(kb);
        let i = self.ring.partition_point(|&(point, _)| point < h);
        self.ring[if i == self.ring.len() { 0 } else { i }].1
    }

    #[inline]
    fn bucket_of_bits(&self, kb: u64) -> usize {
        (hash64(kb ^ BUCKET_SALT) % self.buckets_per_unit() as u64) as usize
    }

    /// The team rank owning `key` (consistent-hash successor).
    pub fn owner_of(&self, key: K) -> usize {
        self.owner_of_bits(bits_of(key))
    }

    /// The bucket index `key` probes within its owner's partition.
    pub fn bucket_of(&self, key: K) -> usize {
        self.bucket_of_bits(bits_of(key))
    }

    /// Stripe index for lock-per-bucket schemes: a deterministic map of
    /// `key`'s (owner, bucket) pair onto `nlocks` stripes — every key of
    /// one bucket lands on the same stripe, so one held stripe lock covers
    /// the whole probe region of any key under it.
    pub fn lock_index(&self, key: K, nlocks: usize) -> usize {
        let kb = bits_of(key);
        let owner = self.owner_of_bits(kb) as u64;
        let bucket = self.bucket_of_bits(kb) as u64;
        (hash64((owner << 32) | bucket) % nlocks as u64) as usize
    }

    /// Global pointer to word `word` (0 = tag, 1 = key, 2 = value) of slot
    /// `slot` on team rank `rank`.
    #[inline]
    fn word_gptr(&self, rank: usize, slot: usize, word: usize) -> GlobalPtr {
        self.gptr.with_unit(self.units[rank]).add((slot * SLOT_BYTES + word * 8) as u64)
    }

    // ------------------------------------------------------------------
    // The lock-free hot path
    // ------------------------------------------------------------------

    /// Insert or update, lock-free: claim an EMPTY slot's tag with
    /// `compare_and_swap` (bounded retry within the key's bucket), then
    /// publish key and value as deferred atomic `Replace` writes. Returns
    /// `true` on a fresh insert, `false` on an update. Values written here
    /// are immediately visible to conflicting atomics; modelled remote
    /// completion batches into the next [`HashMap::flush`].
    pub fn put(&self, key: K, value: V) -> DartResult<bool> {
        let kb = bits_of(key);
        let vb = bits_of(value);
        let fp = Self::fp(kb);
        let owner = self.owner_of_bits(kb);
        let bucket = self.bucket_of_bits(kb);
        for i in 0..BUCKET_SLOTS {
            let slot = bucket * BUCKET_SLOTS + i;
            let mut tag_word = [0u8; 8];
            self.env.get_blocking(self.word_gptr(owner, slot, 0), &mut tag_word)?;
            let tag = u64::from_ne_bytes(tag_word);
            if tag == fp {
                // Update: one deferred atomic swap of the value word.
                self.env.accumulate_async(self.word_gptr(owner, slot, 2), &[vb], MpiOp::Replace)?;
                return Ok(false);
            }
            if tag != EMPTY {
                continue; // another key's slot
            }
            // Claim the EMPTY slot.
            let old = self.env.compare_and_swap(self.word_gptr(owner, slot, 0), EMPTY, fp)?;
            if old == EMPTY {
                self.env.accumulate_async(self.word_gptr(owner, slot, 1), &[kb], MpiOp::Replace)?;
                self.env.accumulate_async(self.word_gptr(owner, slot, 2), &[vb], MpiOp::Replace)?;
                return Ok(true);
            }
            self.cas_retries.set(self.cas_retries.get() + 1);
            if old == fp {
                // Lost the race to a concurrent insert of the same key:
                // it degenerates to an update.
                self.env.accumulate_async(self.word_gptr(owner, slot, 2), &[vb], MpiOp::Replace)?;
                return Ok(false);
            }
            // Lost to a different key: probe on.
        }
        Err(DartErr::Invalid(format!(
            "hashmap bucket overflow: bucket {bucket} on rank {owner} is full \
             ({BUCKET_SLOTS} slots) — size the map for a lower load factor"
        )))
    }

    /// Atomic read-modify-write of `key`'s value: `value := value (op)
    /// new`, element-atomic via the accumulate hot path. A key not yet
    /// present is inserted first (its value word starts zeroed, so e.g.
    /// `Sum` merges into zero). Deferred like [`HashMap::put`].
    pub fn merge(&self, key: K, value: V, op: MpiOp) -> DartResult<()> {
        let kb = bits_of(key);
        let fp = Self::fp(kb);
        let owner = self.owner_of_bits(kb);
        let bucket = self.bucket_of_bits(kb);
        for i in 0..BUCKET_SLOTS {
            let slot = bucket * BUCKET_SLOTS + i;
            let mut tag_word = [0u8; 8];
            self.env.get_blocking(self.word_gptr(owner, slot, 0), &mut tag_word)?;
            let mut tag = u64::from_ne_bytes(tag_word);
            if tag == EMPTY {
                let old = self.env.compare_and_swap(self.word_gptr(owner, slot, 0), EMPTY, fp)?;
                if old == EMPTY {
                    self.env.accumulate_async(
                        self.word_gptr(owner, slot, 1),
                        &[kb],
                        MpiOp::Replace,
                    )?;
                    self.env.accumulate_async(self.word_gptr(owner, slot, 2), &[value], op)?;
                    return Ok(());
                }
                self.cas_retries.set(self.cas_retries.get() + 1);
                tag = old;
            }
            if tag == fp {
                self.env.accumulate_async(self.word_gptr(owner, slot, 2), &[value], op)?;
                return Ok(());
            }
        }
        Err(DartErr::Invalid(format!(
            "hashmap bucket overflow: bucket {bucket} on rank {owner} is full"
        )))
    }

    /// Look `key` up: ONE coalesced 24-byte blocking read per probed slot
    /// (first probe hits in the common case). The stored key word is
    /// verified, so fingerprint collisions cannot alias reads.
    pub fn get(&self, key: K) -> DartResult<Option<V>> {
        let kb = bits_of(key);
        let fp = Self::fp(kb);
        let owner = self.owner_of_bits(kb);
        let bucket = self.bucket_of_bits(kb);
        for i in 0..BUCKET_SLOTS {
            let slot = bucket * BUCKET_SLOTS + i;
            let mut words = [0u64; 3];
            self.env
                .get_blocking(self.word_gptr(owner, slot, 0), as_bytes_mut(&mut words))?;
            if words[0] == EMPTY {
                return Ok(None); // probe chains never skip an EMPTY slot
            }
            if words[0] == fp && words[1] == kb {
                return Ok(Some(from_bits(words[2])));
            }
        }
        Ok(None)
    }

    /// Complete every outstanding deferred write on the map's allocation
    /// (one call per phase — the engine's explicit-flush discipline).
    pub fn flush(&self) -> DartResult<()> {
        self.env.flush_all(self.gptr)
    }

    // ------------------------------------------------------------------
    // The locked discipline (MCS backend)
    // ------------------------------------------------------------------

    /// Insert or update with plain reads and writes — **no atomics**. Only
    /// correct while the caller holds a lock covering `key`'s bucket (see
    /// [`HashMap::lock_index`]); this is the comparison point the MCS
    /// backend of the kvstore measures against the lock-free path.
    pub fn put_exclusive(&self, key: K, value: V) -> DartResult<bool> {
        let kb = bits_of(key);
        let vb = bits_of(value);
        let fp = Self::fp(kb);
        let owner = self.owner_of_bits(kb);
        let bucket = self.bucket_of_bits(kb);
        for i in 0..BUCKET_SLOTS {
            let slot = bucket * BUCKET_SLOTS + i;
            let mut words = [0u64; 3];
            self.env
                .get_blocking(self.word_gptr(owner, slot, 0), as_bytes_mut(&mut words))?;
            if words[0] == EMPTY {
                let fresh = [fp, kb, vb];
                self.env.put_blocking(self.word_gptr(owner, slot, 0), as_bytes(&fresh))?;
                return Ok(true);
            }
            if words[0] == fp {
                self.env.put_blocking(self.word_gptr(owner, slot, 2), &vb.to_ne_bytes())?;
                return Ok(false);
            }
        }
        Err(DartErr::Invalid(format!(
            "hashmap bucket overflow: bucket {bucket} on rank {owner} is full"
        )))
    }

    // ------------------------------------------------------------------
    // The owner-computes discipline (sharded backend)
    // ------------------------------------------------------------------

    /// Owner-side insert/update: plain local memory operations on this
    /// unit's own partition. Errs unless this unit owns `key` — the
    /// owner-computes backend routes requests to owners first.
    pub fn local_put(&self, key: K, value: V) -> DartResult<bool> {
        let kb = bits_of(key);
        let owner = self.owner_of_bits(kb);
        if owner != self.myrank {
            return Err(DartErr::Invalid(format!(
                "local_put of a key owned by rank {owner} on rank {}",
                self.myrank
            )));
        }
        let fp = Self::fp(kb);
        let bucket = self.bucket_of_bits(kb);
        for i in 0..BUCKET_SLOTS {
            let slot = bucket * BUCKET_SLOTS + i;
            let mut words = [0u64; 3];
            self.env.local_read(self.word_gptr(owner, slot, 0), as_bytes_mut(&mut words))?;
            if words[0] == EMPTY {
                let fresh = [fp, kb, bits_of(value)];
                self.env.local_write(self.word_gptr(owner, slot, 0), as_bytes(&fresh))?;
                return Ok(true);
            }
            if words[0] == fp {
                self.env
                    .local_write(self.word_gptr(owner, slot, 2), &bits_of(value).to_ne_bytes())?;
                return Ok(false);
            }
        }
        Err(DartErr::Invalid(format!(
            "hashmap bucket overflow: bucket {bucket} on rank {owner} is full"
        )))
    }

    /// Owner-side lookup on this unit's own partition (errs unless this
    /// unit owns `key`).
    pub fn local_get(&self, key: K) -> DartResult<Option<V>> {
        let kb = bits_of(key);
        let owner = self.owner_of_bits(kb);
        if owner != self.myrank {
            return Err(DartErr::Invalid(format!(
                "local_get of a key owned by rank {owner} on rank {}",
                self.myrank
            )));
        }
        let fp = Self::fp(kb);
        let bucket = self.bucket_of_bits(kb);
        for i in 0..BUCKET_SLOTS {
            let slot = bucket * BUCKET_SLOTS + i;
            let mut words = [0u64; 3];
            self.env.local_read(self.word_gptr(owner, slot, 0), as_bytes_mut(&mut words))?;
            if words[0] == EMPTY {
                return Ok(None);
            }
            if words[0] == fp && words[1] == kb {
                return Ok(Some(from_bits(words[2])));
            }
        }
        Ok(None)
    }

    // ------------------------------------------------------------------
    // Verification
    // ------------------------------------------------------------------

    /// Canonical content checksum, identical on every unit. Collective:
    /// each member scans its partition, sorts its live `(key, value)`
    /// pairs by key (slot order depends on insertion interleaving; the
    /// content set does not), folds them with FNV-1a, and the per-unit
    /// digests combine with an order-independent wrapping-sum allreduce.
    /// Two maps hold the same entries iff their checksums match (mod hash
    /// collisions) — regardless of which backend or exec mode filled them.
    pub fn content_checksum(&self) -> DartResult<u64> {
        let mut words = vec![0u64; self.slots_per_unit * 3];
        self.env.local_read(self.word_gptr(self.myrank, 0, 0), as_bytes_mut(&mut words))?;
        let mut pairs: Vec<(u64, u64)> = words
            .chunks_exact(3)
            .filter(|s| s[0] != EMPTY)
            .map(|s| (s[1], s[2]))
            .collect();
        pairs.sort_unstable();
        let mut digest = 0xCBF2_9CE4_8422_2325u64;
        for (kb, vb) in &pairs {
            for b in kb.to_ne_bytes().iter().chain(vb.to_ne_bytes().iter()) {
                digest = (digest ^ *b as u64).wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        // Make all-empty partitions contribute too (length folds in).
        digest = digest.wrapping_add(pairs.len() as u64);
        let mut sum = [0u64];
        self.env.allreduce(self.team, &[digest], &mut sum, MpiOp::Sum)?;
        Ok(sum[0])
    }

    /// Number of live entries on this unit's partition (local scan).
    pub fn local_len(&self) -> DartResult<usize> {
        let mut words = vec![0u64; self.slots_per_unit * 3];
        self.env.local_read(self.word_gptr(self.myrank, 0, 0), as_bytes_mut(&mut words))?;
        Ok(words.chunks_exact(3).filter(|s| s[0] != EMPTY).count())
    }

    /// Collectively free the backing global allocation (not done in
    /// `Drop`: freeing is a collective call that can fail).
    pub fn free(self) -> DartResult<()> {
        self.env.team_memfree(self.team, self.gptr)
    }
}
