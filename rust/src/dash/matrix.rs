//! `Matrix<T>` — a typed 2-D distributed matrix over a TILED pattern.
//!
//! A thin 2-D veneer over [`Array`]: global element `(i, j)` is the
//! linear index `i * cols + j` of a [`Pattern::tiled`] distribution, and
//! every unit stores its tiles as one dense row-major local matrix
//! (`local_rows() × local_cols()`), which is exactly the block layout the
//! stencil apps hand-rolled before this layer existed.
//!
//! On top of the array's element/bulk/local tiers the matrix adds the two
//! halo access shapes of a 2-D decomposition:
//!
//! - [`Matrix::get_row_async`] — a row segment inside one owner tile:
//!   ONE contiguous deferred-completion get;
//! - [`Matrix::get_col_async`] — a column segment inside one owner tile:
//!   ONE vector-typed strided get
//!   ([`crate::dart::DartEnv::get_strided_async`]), not one op per row.
//!
//! Both are completed by a single [`Matrix::flush`] per exchange phase,
//! preserving the engine's one-op-per-neighbour + one-flush-per-step
//! batching that `rust/tests/engine_tests.rs` asserts for `stencil2d`.

use super::array::Array;
use super::pattern::Pattern;
use crate::dart::gptr::TeamId;
use crate::dart::{DartEnv, DartErr, DartResult, Element};
use crate::mpisim::as_bytes_mut;

/// A typed distributed 2-D matrix (see module docs).
pub struct Matrix<'e, T: Element> {
    arr: Array<'e, T>,
    rows: usize,
    cols: usize,
}

impl<'e, T: Element> Matrix<'e, T> {
    /// Collectively allocate a `rows × cols` matrix tiled in
    /// `tile_rows × tile_cols` tiles over a `pgrid_rows × pgrid_cols`
    /// unit grid (`pgrid_rows * pgrid_cols` must equal the team size;
    /// team rank `r` sits at unit-grid position
    /// `(r / pgrid_cols, r % pgrid_cols)`). Elements start as
    /// `T::default()`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        env: &'e DartEnv,
        team: TeamId,
        rows: usize,
        cols: usize,
        tile_rows: usize,
        tile_cols: usize,
        pgrid_rows: usize,
        pgrid_cols: usize,
    ) -> DartResult<Matrix<'e, T>> {
        let pattern = Pattern::tiled(rows, cols, tile_rows, tile_cols, pgrid_rows, pgrid_cols)?;
        Ok(Matrix { arr: Array::new(env, team, pattern)?, rows, cols })
    }

    /// Matrix height in elements.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Matrix width in elements.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The underlying distributed array (linear row-major view).
    pub fn as_array(&self) -> &Array<'e, T> {
        &self.arr
    }

    /// The distribution pattern.
    pub fn pattern(&self) -> &Pattern {
        self.arr.pattern()
    }

    /// Height of this unit's dense local matrix.
    pub fn local_rows(&self) -> usize {
        self.arr.pattern().tiled_local_dims(self.arr.myrank()).0
    }

    /// Width of this unit's dense local matrix.
    pub fn local_cols(&self) -> usize {
        self.arr.pattern().tiled_local_dims(self.arr.myrank()).1
    }

    fn linear(&self, i: usize, j: usize) -> DartResult<usize> {
        if i >= self.rows || j >= self.cols {
            return Err(DartErr::Invalid(format!(
                "matrix index ({i}, {j}) out of {}×{}",
                self.rows, self.cols
            )));
        }
        Ok(i * self.cols + j)
    }

    /// Read one element (blocking one-sided get).
    pub fn get(&self, i: usize, j: usize) -> DartResult<T> {
        self.arr.get(self.linear(i, j)?)
    }

    /// Write one element (blocking one-sided put).
    pub fn put(&self, i: usize, j: usize, value: T) -> DartResult<()> {
        self.arr.put(self.linear(i, j)?, value)
    }

    /// Copy of this unit's dense `local_rows() × local_cols()` row-major
    /// local matrix.
    pub fn read_local(&self) -> DartResult<Vec<T>> {
        self.arr.read_local()
    }

    /// Replace this unit's local matrix (`src.len()` must be
    /// `local_rows() * local_cols()`).
    pub fn write_local(&self, src: &[T]) -> DartResult<()> {
        self.arr.write_local(src)
    }

    /// Owner-computes view of the local matrix (see
    /// [`Array::with_local`]).
    pub fn with_local<R>(&self, f: impl FnOnce(&mut [T]) -> R) -> DartResult<R> {
        self.arr.with_local(f)
    }

    /// Deferred-completion get of the row segment
    /// `(i, j0 .. j0 + dst.len())`. The segment must lie inside one
    /// owner's tile row (one contiguous run — the natural shape of a
    /// north/south halo), so it is issued as ONE engine operation;
    /// complete it with [`Matrix::flush`].
    pub fn get_row_async(&self, i: usize, j0: usize, dst: &mut [T]) -> DartResult<()> {
        if dst.is_empty() {
            return Ok(());
        }
        let g = self.linear(i, j0)?;
        self.linear(i, j0 + dst.len() - 1)?;
        let (unit, local) = self.arr.pattern().global_to_local(g);
        if self.arr.pattern().run_len(g) < dst.len() {
            return Err(DartErr::Invalid(format!(
                "row segment ({i}, {j0}..{}) crosses a tile boundary",
                j0 + dst.len()
            )));
        }
        self.arr.env().get_async(self.arr.gptr_of(unit, local), as_bytes_mut(dst))
    }

    /// Deferred-completion get of the column segment
    /// `(i0 .. i0 + dst.len(), j)`. The segment must lie inside one
    /// owner's tile column (the west/east halo shape); it moves as ONE
    /// vector-typed strided operation with the owner's local row width as
    /// the stride. Complete it with [`Matrix::flush`].
    pub fn get_col_async(&self, i0: usize, j: usize, dst: &mut [T]) -> DartResult<()> {
        if dst.is_empty() {
            return Ok(());
        }
        let g0 = self.linear(i0, j)?;
        let g1 = self.linear(i0 + dst.len() - 1, j)?;
        let (unit, local) = self.arr.pattern().global_to_local(g0);
        let (unit1, local1) = self.arr.pattern().global_to_local(g1);
        let (_, w) = self.arr.pattern().tiled_local_dims(unit);
        if unit1 != unit || local1 != local + (dst.len() - 1) * w {
            return Err(DartErr::Invalid(format!(
                "column segment ({i0}..{}, {j}) crosses a tile boundary",
                i0 + dst.len()
            )));
        }
        let size = std::mem::size_of::<T>();
        self.arr.env().get_strided_async(
            self.arr.gptr_of(unit, local),
            as_bytes_mut(dst),
            dst.len(),
            size,
            (w * size) as u64,
        )
    }

    /// Complete every outstanding deferred operation on the matrix's
    /// segment — one call per halo-exchange phase.
    pub fn flush(&self) -> DartResult<()> {
        self.arr.env().flush_all(self.arr.gptr)
    }

    /// Collectively free the backing global allocation (see
    /// [`Array::free`]).
    pub fn free(self) -> DartResult<()> {
        self.arr.free()
    }
}
