//! `dart` — the launcher/CLI of the DART-MPI reproduction.
//!
//! Subcommands (no external CLI crate is available offline, so parsing is
//! by hand):
//!
//! ```text
//! dart info                         show topology, artifacts, config
//! dart selftest                     quick end-to-end sanity run
//! dart stencil  [--units N] [--steps N] [--block 32|64] [--shmem]
//! dart matmul   [--units N] [--shmem]
//! dart bench    <fig8..fig15|all>   regenerate the paper's figures
//! ```

use dart::apps::{matmul, stencil};
use dart::bench_util::figure::{run_figure, Figure};
use dart::dart::{run, DartConfig};
use dart::runtime::{artifacts_dir, Artifact, Engine};
use dart::simnet::Topology;
use std::sync::Mutex;

/// CLI result alias (the crate is dependency-free; no `anyhow` offline).
type CliResult<T> = Result<T, Box<dyn std::error::Error>>;

fn parse_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn parse_opt(args: &[String], name: &str) -> Option<usize> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).and_then(|v| v.parse().ok())
}

fn cmd_info() -> CliResult<()> {
    println!("DART-MPI reproduction — PGAS runtime on an MPI-3 RMA substrate");
    let t = Topology::hermit(2);
    println!("\nmodelled topology (per node, Cray XE6 'Hermit', paper Fig. 7):");
    println!(
        "  {} NUMA domains × {} cores = {} cores/node",
        t.numa_per_node,
        t.cores_per_numa,
        t.cores_per_node()
    );
    let cost = dart::simnet::CostModel::hermit();
    println!("\ncost model (calibrated, §V shapes):");
    for (i, tier) in dart::simnet::Tier::ALL.iter().enumerate() {
        println!(
            "  {tier:<11} latency {:>6.0} ns   bandwidth {:>4.1} GB/s",
            cost.tiers[i].latency_ns, cost.tiers[i].bytes_per_ns
        );
    }
    println!(
        "  eager E0→E1 switch at {} B (+{} ns, double copy)",
        cost.eager_e0_limit, cost.e1_latency_ns
    );
    let dir = artifacts_dir();
    println!("\nartifacts ({}):", dir.display());
    match Artifact::discover(&dir) {
        Ok(names) if !names.is_empty() => {
            for n in names {
                let a = Artifact::load(&dir, &n)?;
                println!("  {n:<24} {} in / {} out", a.inputs.len(), a.outputs.len());
            }
        }
        _ => println!("  (none — run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_selftest() -> CliResult<()> {
    print!("selftest: 4-unit PGAS roundtrip ... ");
    run(DartConfig::with_units(4), |env| {
        let g = env.team_memalloc_aligned(dart::dart::DART_TEAM_ALL, 64).unwrap();
        let me = env.myid();
        env.put_blocking(g.with_unit((me + 1) % 4), &[me as u8; 8]).unwrap();
        env.barrier(dart::dart::DART_TEAM_ALL).unwrap();
        let mut got = [0u8; 8];
        env.get_blocking(g.with_unit(me), &mut got).unwrap();
        assert_eq!(got, [((me + 3) % 4) as u8; 8]);
        env.barrier(dart::dart::DART_TEAM_ALL).unwrap();
        env.team_memfree(dart::dart::DART_TEAM_ALL, g).unwrap();
    })?;
    println!("OK");
    print!("selftest: PJRT artifact execution ... ");
    let engine = Engine::new()?;
    let exe = engine.load("stencil_f32_32x32")?;
    let outs = exe.run_f32(&[&vec![1.0f32; 34 * 34]])?;
    assert!(outs[1][0].abs() < 1e-9);
    println!("OK (platform: {})", engine.platform());
    Ok(())
}

fn cmd_stencil(args: &[String]) -> CliResult<()> {
    let units = parse_opt(args, "--units").unwrap_or(4);
    let steps = parse_opt(args, "--steps").unwrap_or(100);
    let block = parse_opt(args, "--block").unwrap_or(64);
    let cfg = match block {
        32 => stencil::StencilConfig::block32(steps),
        64 => stencil::StencilConfig::block64(steps),
        other => return Err(format!("--block must be 32 or 64, got {other}").into()),
    };
    let dart_cfg = DartConfig::hermit(units, (units + 31) / 32)
        .with_shmem_windows(parse_flag(args, "--shmem"));
    println!("stencil: {units} units × {}×{} blocks, {steps} steps", cfg.local_rows, cfg.width);
    let report = Mutex::new(None);
    run(dart_cfg, |env| {
        let engine = Engine::new().expect("PJRT engine");
        let r = stencil::run_distributed(env, &engine, &cfg).expect("stencil");
        if env.myid() == 0 {
            *report.lock().unwrap() = Some(r);
        }
    })?;
    let r = report.into_inner().unwrap().unwrap();
    println!(
        "final residual {:.6e}, checksum {:.6}",
        r.residuals.last().unwrap(),
        r.global_checksum
    );
    Ok(())
}

fn cmd_matmul(args: &[String]) -> CliResult<()> {
    let units = parse_opt(args, "--units").unwrap_or(4);
    let cfg = matmul::SummaConfig::block64();
    let dart_cfg = DartConfig::hermit(units, (units + 31) / 32)
        .with_shmem_windows(parse_flag(args, "--shmem"));
    println!(
        "matmul: C({m}×{n}) = A({m}×{k}) @ B({k}×{n}) on {units} units",
        m = cfg.mb * units,
        k = cfg.kb * units,
        n = cfg.nb
    );
    let norm = Mutex::new(0f64);
    run(dart_cfg, |env| {
        let engine = Engine::new().expect("PJRT engine");
        let r = matmul::run_distributed(env, &engine, &cfg).expect("summa");
        if env.myid() == 0 {
            *norm.lock().unwrap() = r.global_norm;
        }
    })?;
    println!("global ||C||_F = {:.6}", norm.into_inner().unwrap());
    Ok(())
}

fn cmd_bench(args: &[String]) -> CliResult<()> {
    let which = args.first().map(String::as_str).unwrap_or("all");
    let figs: Vec<(&str, Figure)> = vec![
        ("fig8", Figure::DtctBlockingPut),
        ("fig9", Figure::DtctBlockingGet),
        ("fig10", Figure::DtitNonblockingPut),
        ("fig11", Figure::DtitNonblockingGet),
        ("fig12", Figure::BwBlockingPut),
        ("fig13", Figure::BwBlockingGet),
        ("fig14", Figure::BwNonblockingPut),
        ("fig15", Figure::BwNonblockingGet),
    ];
    let mut ran = false;
    for (name, fig) in figs {
        if which == "all" || which == name {
            run_figure(fig);
            ran = true;
        }
    }
    if !ran {
        return Err(format!("unknown figure {which:?} (use fig8..fig15 or all)").into());
    }
    Ok(())
}

fn main() -> CliResult<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("info") => cmd_info(),
        Some("selftest") => cmd_selftest(),
        Some("stencil") => cmd_stencil(&args[1..]),
        Some("matmul") => cmd_matmul(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        _ => {
            eprintln!("usage: dart <info|selftest|stencil|matmul|bench> [options]");
            eprintln!("  info                      topology, cost model, artifacts");
            eprintln!("  selftest                  quick end-to-end sanity check");
            eprintln!("  stencil [--units N] [--steps N] [--block 32|64] [--shmem]");
            eprintln!("  matmul  [--units N] [--shmem]");
            eprintln!("  bench   <fig8..fig15|all>   (DART_BENCH_QUICK=1 for short sweeps)");
            std::process::exit(2);
        }
    }
}
