//! Seeded chaos harness: run runtime invariants under active fault
//! injection ([`crate::simnet::faults`]) across many seeds.
//!
//! The contract mirrors deterministic-simulation testing à la
//! FoundationDB/TigerBeetle: a scenario is a pure function of its seed
//! (`Fn(u64) -> Result<FaultStats, String>`), the world it launches gets
//! [`FaultPlan::from_seed`]`(seed)` installed, and [`chaos_check`] sweeps
//! a seed list, accumulates the observed [`FaultStats`] (so callers can
//! assert every fault class actually fired), and — on failure — reports
//! the **smallest** failing seed after replaying it to confirm the
//! reproduction is deterministic. Re-run exactly one seed with
//! `DART_CHAOS_SEEDS=0x<seed>` (see [`seeds`]).
//!
//! The module ships the nine standing invariants the chaos suite
//! (`rust/tests/chaos_tests.rs`) and the CI `chaos-smoke` job sweep:
//! [`flush_completes_all`], [`mcs_fifo`], [`nonblocking_matches_blocking`],
//! [`hier_matches_flat`], [`kv_backends_agree`],
//! [`work_queue_exactly_once`], [`vector_growth_matches_prealloc`],
//! [`bfs_levels_deterministic`], [`sample_sort_is_permutation`].

use crate::apps::kvstore::{run_kv, KvBackend, KvConfig};
use crate::apps::wqueue::{reference_result, run_distributed, WqueueConfig};
use crate::dart::{DartConfig, DartEnv, GlobalPtr, UnitId, DART_TEAM_ALL};
use crate::dash::{Array, Pattern, Vector};
use crate::mpisim::{MpiOp, ProgressMode};
use crate::simnet::{CostModel, FaultStats, PinPolicy};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

/// A deterministic seed list: a splitmix64 chain from a fixed base, so
/// "the first `n` chaos seeds" means the same thing on every machine.
pub fn default_seeds(n: usize) -> Vec<u64> {
    let mut rng = super::prop::Rng::new(0xC4A0_5EED);
    (0..n).map(|_| rng.next_u64()).collect()
}

/// The seed list a chaos sweep should use: `DART_CHAOS_SEEDS` (a
/// comma-separated list of decimal or `0x`-hex seeds) when set and
/// non-empty — pinning CI smoke runs and replaying counterexamples —
/// otherwise [`default_seeds`]`(n)`.
pub fn seeds(n: usize) -> Vec<u64> {
    match std::env::var("DART_CHAOS_SEEDS") {
        Ok(list) if !list.trim().is_empty() => list.split(',').map(parse_seed).collect(),
        _ => default_seeds(n),
    }
}

fn parse_seed(tok: &str) -> u64 {
    let t = tok.trim();
    let parsed = match t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => t.parse(),
    };
    parsed.unwrap_or_else(|_| panic!("DART_CHAOS_SEEDS: unparsable seed {t:?}"))
}

/// Sweep `scenario` over `seeds`, returning the summed [`FaultStats`] so
/// the caller can assert the fault plan actually fired (a chaos test that
/// injected nothing proves nothing).
///
/// On failure: panics naming the **smallest** failing seed (the canonical
/// counterexample — scenarios don't have a size to shrink, so the seed
/// ordering stands in for it), the failure message, the outcome of a
/// confirming replay of that seed, and the `DART_CHAOS_SEEDS=` incantation
/// that re-runs exactly that seed. A scenario panic is caught and treated
/// as a failure of that seed, so one bad seed doesn't abort the sweep
/// before the report.
pub fn chaos_check(
    name: &str,
    seeds: &[u64],
    scenario: impl Fn(u64) -> Result<FaultStats, String>,
) -> FaultStats {
    let mut total = FaultStats::default();
    let mut failures: Vec<(u64, String)> = Vec::new();
    for &seed in seeds {
        match run_guarded(&scenario, seed) {
            Ok(stats) => total += stats,
            Err(msg) => failures.push((seed, msg)),
        }
    }
    if failures.is_empty() {
        return total;
    }
    failures.sort_by_key(|&(seed, _)| seed);
    let (seed, msg) = &failures[0];
    let replay = match run_guarded(&scenario, *seed) {
        Err(m) => format!("replay of the seed failed again (deterministic): {m}"),
        Ok(_) => format!(
            "replay of seed {seed:#x} PASSED — the scenario is not a pure function of its seed"
        ),
    };
    panic!(
        "chaos scenario {name:?}: {}/{} seeds failed\n  \
         smallest failing seed: {seed:#x}\n  failure: {msg}\n  {replay}\n  \
         reproduce with: DART_CHAOS_SEEDS={seed:#x} cargo test --test chaos_tests",
        failures.len(),
        seeds.len(),
    );
}

/// Run one seed, converting a scenario panic into `Err` so the sweep can
/// finish and report.
fn run_guarded(
    scenario: &impl Fn(u64) -> Result<FaultStats, String>,
    seed: u64,
) -> Result<FaultStats, String> {
    catch_unwind(AssertUnwindSafe(|| scenario(seed))).unwrap_or_else(|payload| {
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| (*s).to_string()))
            .unwrap_or_else(|| "non-string panic payload".into());
        Err(format!("panicked: {msg}"))
    })
}

/// Launch `cfg`, run `f` on every unit, and merge: any unit's `Err` fails
/// the scenario; otherwise return the world's final [`FaultStats`].
///
/// `f` must keep its collective call sequence identical on every unit even
/// while recording a failure (collect error strings, validate at the end)
/// — bailing out of a collective on one unit only would deadlock the rest.
fn world_check(
    cfg: DartConfig,
    f: impl Fn(&DartEnv) -> Result<(), String> + Send + Sync,
) -> Result<FaultStats, String> {
    let errs: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let stats: Mutex<FaultStats> = Mutex::new(FaultStats::default());
    crate::dart::run(cfg, |env| {
        let r = f(env);
        env.barrier(DART_TEAM_ALL).expect("chaos final barrier failed");
        if env.myid() == 0 {
            *stats.lock().unwrap() = env.fault_stats();
        }
        if let Err(msg) = r {
            errs.lock().unwrap().push(format!("unit {}: {msg}", env.myid()));
        }
    })
    .map_err(|e| format!("launch failed: {e:?}"))?;
    let mut errs = errs.into_inner().unwrap();
    if errs.is_empty() {
        Ok(stats.into_inner().unwrap())
    } else {
        errs.sort();
        Err(errs.join("; "))
    }
}

/// The invariants' base world: `units` units scattered over `nodes` nodes
/// (multi-node so slow-channel/straggler classes have interconnect traffic
/// to bite), **zero** cost model (fault delays are absolute ns, so chaos
/// sweeps don't pay modelled wire time), `Polling` progress (ticks happen
/// at deterministic program points), and the full seed-derived fault plan.
fn chaos_cfg(units: usize, nodes: usize, seed: u64) -> DartConfig {
    super::world(units)
        .nodes(nodes)
        .cost(CostModel::zero())
        .placement(PinPolicy::ScatterNode)
        .pools(1 << 16, 1 << 16)
        .progress(ProgressMode::Polling)
        .faults(seed)
        .build()
}

/// A value only `(seed, a, b)` determine — payload generator for the
/// invariants, so "the right bytes arrived" is checkable from scratch.
fn chaos_value(seed: u64, a: u64, b: u64) -> u64 {
    super::prop::Rng::new(seed ^ (a << 32) ^ b).next_u64()
}

/// Allocate `slots` zeroed u64 cells on unit 0's non-collective partition
/// and broadcast the pointer (the lock suite's shared-cells idiom).
fn shared_cells(env: &DartEnv, slots: usize) -> Result<GlobalPtr, String> {
    let mut bits = [0u8; 16];
    if env.myid() == 0 {
        let g = env.memalloc((slots * 8) as u64).map_err(|e| format!("memalloc: {e:?}"))?;
        for s in 0..slots {
            env.local_write(g.add((s * 8) as u64), &0u64.to_ne_bytes())
                .map_err(|e| format!("local_write: {e:?}"))?;
        }
        bits = g.to_bits().to_ne_bytes();
    }
    env.bcast(DART_TEAM_ALL, &mut bits, 0).map_err(|e| format!("bcast: {e:?}"))?;
    Ok(GlobalPtr::from_bits(u128::from_ne_bytes(bits)))
}

/// **Invariant: `flush_all` completes all outstanding asyncs.** Every unit
/// scatters one seeded u64 into its slot on every peer with `put_async`,
/// flushes, barriers — then every byte must be in place, no matter how the
/// plan jittered, reordered, or starved the deliveries.
pub fn flush_completes_all(seed: u64) -> Result<FaultStats, String> {
    world_check(chaos_cfg(4, 2, seed), |env| {
        let me = env.myid();
        let units = env.size();
        let g = env
            .team_memalloc_aligned(DART_TEAM_ALL, (units * 8) as u64)
            .map_err(|e| format!("alloc: {e:?}"))?;
        for p in 0..units {
            let v = chaos_value(seed, me as u64, p as u64);
            env.put_async(g.with_unit(p as UnitId).add(me as u64 * 8), &v.to_ne_bytes())
                .map_err(|e| format!("put_async: {e:?}"))?;
        }
        env.flush_all(g).map_err(|e| format!("flush_all: {e:?}"))?;
        env.barrier(DART_TEAM_ALL).map_err(|e| format!("barrier: {e:?}"))?;
        let mut bad = Vec::new();
        for w in 0..units {
            let mut buf = [0u8; 8];
            env.local_read(g.with_unit(me).add(w as u64 * 8), &mut buf)
                .map_err(|e| format!("local_read: {e:?}"))?;
            let (got, want) = (u64::from_ne_bytes(buf), chaos_value(seed, w as u64, me as u64));
            if got != want {
                bad.push(format!("writer {w}: got {got:#x} want {want:#x}"));
            }
        }
        env.barrier(DART_TEAM_ALL).map_err(|e| format!("barrier: {e:?}"))?;
        env.team_memfree(DART_TEAM_ALL, g).map_err(|e| format!("memfree: {e:?}"))?;
        if bad.is_empty() {
            Ok(())
        } else {
            Err(format!("writes lost after flush_all: {}", bad.join(", ")))
        }
    })
}

/// **Invariant: MCS hand-off stays FIFO.** Waiters enqueue themselves in a
/// forced order (each spins until its predecessor is the observed tail);
/// the lock must serve them in exactly that order even when the plan
/// reorders RMA completions and starves the progress engine.
pub fn mcs_fifo(seed: u64) -> Result<FaultStats, String> {
    const UNITS: usize = 4;
    let order: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let stats = world_check(chaos_cfg(UNITS, 2, seed), |env| {
        let lock = env.lock_init(DART_TEAM_ALL).map_err(|e| format!("lock_init: {e:?}"))?;
        // Cell 0: next free log slot; cells 1..UNITS: the log itself.
        let log = shared_cells(env, UNITS)?;
        env.barrier(DART_TEAM_ALL).map_err(|e| format!("barrier: {e:?}"))?;
        let me = env.myid();
        if me == 0 {
            env.lock_acquire(&lock).map_err(|e| format!("acquire: {e:?}"))?;
        }
        env.barrier(DART_TEAM_ALL).map_err(|e| format!("barrier: {e:?}"))?;
        if me > 0 {
            while env.lock_tail(&lock).map_err(|e| format!("tail: {e:?}"))? != (me - 1) as i64 {
                std::thread::yield_now();
            }
            env.lock_acquire(&lock).map_err(|e| format!("acquire: {e:?}"))?;
            let slot =
                env.fetch_and_op(log, 1u64, MpiOp::Sum).map_err(|e| format!("faop: {e:?}"))?;
            env.put_blocking(log.add(8 * (1 + slot)), &(me as u64).to_ne_bytes())
                .map_err(|e| format!("put: {e:?}"))?;
            env.lock_release(&lock).map_err(|e| format!("release: {e:?}"))?;
        } else {
            while env.lock_tail(&lock).map_err(|e| format!("tail: {e:?}"))? != (UNITS - 1) as i64 {
                std::thread::yield_now();
            }
            env.lock_release(&lock).map_err(|e| format!("release: {e:?}"))?;
        }
        env.barrier(DART_TEAM_ALL).map_err(|e| format!("barrier: {e:?}"))?;
        if me == 0 {
            let mut buf = [0u8; 8 * UNITS];
            env.get_blocking(log, &mut buf).map_err(|e| format!("get: {e:?}"))?;
            *order.lock().unwrap() = buf[8..]
                .chunks_exact(8)
                .map(|c| u64::from_ne_bytes(c.try_into().unwrap()))
                .collect();
            env.memfree(log).map_err(|e| format!("memfree: {e:?}"))?;
        }
        env.lock_free(lock).map_err(|e| format!("lock_free: {e:?}"))?;
        Ok(())
    })?;
    let served = order.into_inner().unwrap();
    let want: Vec<u64> = (1..UNITS as u64).collect();
    if served == want {
        Ok(stats)
    } else {
        Err(format!("MCS served waiters in order {served:?}, enqueue order was {want:?}"))
    }
}

/// **Invariant: nonblocking collectives deliver what blocking ones do.**
/// The async allreduce/allgather ride the icoll completion bookings the
/// plan jitters — the delivered bytes must still be bit-identical to the
/// blocking paths', and the u64 sum must be *exactly* the full-team sum.
pub fn nonblocking_matches_blocking(seed: u64) -> Result<FaultStats, String> {
    const ELEMS: u64 = 8;
    world_check(chaos_cfg(6, 3, seed), |env| {
        let me = env.myid() as u64;
        let units = env.size() as u64;
        let mine: Vec<u64> = (0..ELEMS).map(|i| chaos_value(seed, me, i)).collect();

        let mut blocking = vec![0u64; ELEMS as usize];
        env.allreduce(DART_TEAM_ALL, &mine, &mut blocking, MpiOp::Sum)
            .map_err(|e| format!("allreduce: {e:?}"))?;
        let mut nonblocking = vec![0u64; ELEMS as usize];
        let h = env
            .allreduce_async(DART_TEAM_ALL, &mine, &mut nonblocking, MpiOp::Sum)
            .map_err(|e| format!("allreduce_async: {e:?}"))?;
        env.coll_wait(h).map_err(|e| format!("coll_wait: {e:?}"))?;

        let expected: Vec<u64> = (0..ELEMS)
            .map(|i| (0..units).fold(0u64, |acc, u| acc.wrapping_add(chaos_value(seed, u, i))))
            .collect();
        if blocking != expected {
            return Err(format!("blocking allreduce wrong: {blocking:?} != {expected:?}"));
        }
        if nonblocking != blocking {
            return Err(format!(
                "nonblocking allreduce diverged: {nonblocking:?} != {blocking:?}"
            ));
        }

        let send = chaos_value(seed, me, 0xA11).to_ne_bytes();
        let mut recv_b = vec![0u8; 8 * units as usize];
        env.allgather(DART_TEAM_ALL, &send, &mut recv_b)
            .map_err(|e| format!("allgather: {e:?}"))?;
        let mut recv_nb = vec![0u8; 8 * units as usize];
        let h = env
            .allgather_async(DART_TEAM_ALL, &send, &mut recv_nb)
            .map_err(|e| format!("allgather_async: {e:?}"))?;
        env.coll_wait(h).map_err(|e| format!("coll_wait: {e:?}"))?;
        if recv_nb != recv_b {
            return Err("nonblocking allgather diverged from blocking".into());
        }
        Ok(())
    })
}

/// **Invariant: hierarchical collectives are bit-equal to flat ones.** Two
/// worlds under the *same* fault plan — one flat, one two-level — must
/// produce identical f64 allreduce bits and the exact u64 team sum:
/// faults may only move modelled time, never bytes.
pub fn hier_matches_flat(seed: u64) -> Result<FaultStats, String> {
    const ELEMS: u64 = 8;
    let mode = |hier: bool| -> Result<(Vec<u64>, FaultStats), String> {
        let bits: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        let mut cfg = chaos_cfg(6, 3, seed);
        cfg.hierarchical_collectives = hier;
        let stats = world_check(cfg, |env| {
            let me = env.myid() as u64;
            let units = env.size() as u64;
            let mine_f: Vec<f64> =
                (0..ELEMS).map(|i| chaos_value(seed, me, i) as f64 / 1e9).collect();
            let mut out_f = vec![0f64; ELEMS as usize];
            env.allreduce(DART_TEAM_ALL, &mine_f, &mut out_f, MpiOp::Sum)
                .map_err(|e| format!("allreduce f64: {e:?}"))?;

            let mine_u: Vec<u64> = (0..ELEMS).map(|i| chaos_value(seed, me, i)).collect();
            let mut out_u = vec![0u64; ELEMS as usize];
            env.allreduce(DART_TEAM_ALL, &mine_u, &mut out_u, MpiOp::Sum)
                .map_err(|e| format!("allreduce u64: {e:?}"))?;
            let expected: Vec<u64> = (0..ELEMS)
                .map(|i| {
                    (0..units).fold(0u64, |acc, u| acc.wrapping_add(chaos_value(seed, u, i)))
                })
                .collect();
            if out_u != expected {
                return Err(format!("u64 allreduce wrong: {out_u:?} != {expected:?}"));
            }
            if env.myid() == 0 {
                *bits.lock().unwrap() = out_f.iter().map(|v| v.to_bits()).collect();
            }
            Ok(())
        })?;
        Ok((bits.into_inner().unwrap(), stats))
    };
    let (flat, stats_flat) = mode(false)?;
    let (hier, stats_hier) = mode(true)?;
    if flat != hier {
        return Err(format!(
            "hierarchical allreduce not bit-equal to flat: {hier:?} != {flat:?}"
        ));
    }
    let mut total = stats_flat;
    total += stats_hier;
    Ok(total)
}

/// **Invariant: all three kvstore write disciplines agree.** The same
/// zipfian workload through `Cas`, `Mcs`, and `OwnerShards` backends —
/// each in its own faulted world — must land on one content checksum, and
/// every op must be accounted for.
pub fn kv_backends_agree(seed: u64) -> Result<FaultStats, String> {
    const UNITS: usize = 4;
    let kv = KvConfig {
        keys: 64,
        ops_per_unit: 60,
        get_percent: 50,
        zipf_exponent: 0.9,
        seed,
        slots_per_unit: 256,
        locks: 8,
        flush_every: 8,
        team: DART_TEAM_ALL,
    };
    let mut total = FaultStats::default();
    let mut sums: Vec<(&'static str, u64)> = Vec::new();
    for backend in KvBackend::ALL {
        let sum: Mutex<u64> = Mutex::new(0);
        // Default pools (the hashmap needs the room); multi-node + faults.
        let mut cfg = chaos_cfg(UNITS, 2, seed);
        cfg.non_collective_pool = 8 << 20;
        cfg.team_pool = 16 << 20;
        let stats = world_check(cfg, |env| {
            let report =
                run_kv(env, &kv, backend).map_err(|e| format!("run_kv: {e:?}"))?;
            if report.ops != (UNITS * kv.ops_per_unit) as u64 {
                return Err(format!(
                    "{}: {} ops accounted, expected {}",
                    backend.label(),
                    report.ops,
                    UNITS * kv.ops_per_unit
                ));
            }
            if env.myid() == 0 {
                *sum.lock().unwrap() = report.checksum;
            }
            Ok(())
        })?;
        total += stats;
        sums.push((backend.label(), sum.into_inner().unwrap()));
    }
    if sums.windows(2).all(|w| w[0].1 == w[1].1) {
        Ok(total)
    } else {
        Err(format!("kvstore backends disagree on final contents: {sums:?}"))
    }
}

/// **Invariant: the work-queue task farm retires every task exactly
/// once.** The `apps::wqueue` farm — skewed producers, tiny rings forcing
/// the full/spill paths, CAS-claimed dequeues, cross-ring stealing — runs
/// in a faulted multi-node world; the XOR checksum over retired task
/// results must equal the sequential reference (a lost task, a doubled
/// task, or a torn slot read each breaks it), and the retired count must
/// be exact, no matter how the plan reorders completions or starves the
/// progress engine.
pub fn work_queue_exactly_once(seed: u64) -> Result<FaultStats, String> {
    let wq = WqueueConfig { tasks: 160, ring_capacity: 8, seed, team: DART_TEAM_ALL };
    let want = reference_result(&wq);
    world_check(chaos_cfg(4, 2, seed), move |env| {
        let report = run_distributed(env, &wq).map_err(|e| format!("run_distributed: {e:?}"))?;
        if report.retired != wq.tasks as u64 {
            return Err(format!("{} tasks retired, expected {}", report.retired, wq.tasks));
        }
        if report.checksum != want {
            return Err(format!(
                "checksum {:#x} != sequential reference {want:#x} — a task was lost, \
                 doubled, or torn",
                report.checksum
            ));
        }
        Ok(())
    })
}

/// **Invariant: a vector grown under chaos is bit-identical to a
/// preallocated array.** Collective pushes drive `dash::Vector` through
/// ≥ 3 capacity doublings — attach, allgather, redistribution puts, and
/// detach all riding the faulted channels — and every unit's final
/// partition must equal, bit for bit, a `dash::Array` preallocated at the
/// final capacity and filled with the same seed-derived values.
pub fn vector_growth_matches_prealloc(seed: u64) -> Result<FaultStats, String> {
    world_check(chaos_cfg(4, 2, seed), move |env| {
        let team = DART_TEAM_ALL;
        let p = env.size();
        let me = env.team_myid(team).map_err(|e| format!("team_myid: {e:?}"))?;
        let mut v = Vector::<u64>::with_capacity(env, team, p)
            .map_err(|e| format!("with_capacity: {e:?}"))?;
        let cap0 = v.capacity();
        for _ in 0..16 {
            let base = v.len().map_err(|e| format!("len: {e:?}"))?;
            v.push(chaos_value(seed, (base + me) as u64, 0x7EC))
                .map_err(|e| format!("push: {e:?}"))?;
        }
        let n = v.len().map_err(|e| format!("len: {e:?}"))?;
        let doublings = (v.capacity() / cap0).ilog2();

        let arr = Array::<u64>::new(
            env,
            team,
            Pattern::blocked(v.capacity(), p).map_err(|e| format!("pattern: {e:?}"))?,
        )
        .map_err(|e| format!("array: {e:?}"))?;
        arr.with_local(|loc| {
            for (i, slot) in loc.iter_mut().enumerate() {
                let g = arr.pattern().local_to_global(me, i);
                *slot = if g < n { chaos_value(seed, g as u64, 0x7EC) } else { 0 };
            }
        })
        .map_err(|e| format!("with_local: {e:?}"))?;
        env.barrier(team).map_err(|e| format!("barrier: {e:?}"))?;
        let got = v.read_local().map_err(|e| format!("read_local: {e:?}"))?;
        let want = arr.read_local().map_err(|e| format!("read_local: {e:?}"))?;
        arr.free().map_err(|e| format!("array free: {e:?}"))?;
        v.free().map_err(|e| format!("vector free: {e:?}"))?;
        if doublings < 3 {
            return Err(format!("only {doublings} doublings ({cap0} → final)"));
        }
        if n != 16 * p {
            return Err(format!("length {n} after 16 collective pushes of {p}"));
        }
        if got != want {
            return Err(format!(
                "unit {me}: grown vector diverged from the preallocated array \
                 ({} differing slots)",
                got.iter().zip(&want).filter(|(a, b)| a != b).count()
            ));
        }
        Ok(())
    })
}

/// **Invariant: BFS levels are deterministic.** One faulted world runs the
/// level-synchronous traversal twice — flat claims, then intra-node
/// combining — over the same seeded R-MAT graph. The parent *trees* may
/// differ (CAS races resolve arbitrarily under reordered completions),
/// but the level summary must be bit-identical between the two modes and
/// equal to the sequential oracle's, no matter how the plan jitters the
/// claim traffic.
pub fn bfs_levels_deterministic(seed: u64) -> Result<FaultStats, String> {
    use crate::apps::bfs::{reference_summary, BfsConfig};
    let graph = crate::dash::GraphConfig { scale: 5, edge_factor: 4, seed };
    let flat = BfsConfig { graph, root: 0, combine: false, team: DART_TEAM_ALL };
    let combined = BfsConfig { combine: true, ..flat.clone() };
    let oracle = reference_summary(&flat);
    world_check(chaos_cfg(4, 2, seed), |env| {
        let a = crate::apps::bfs::run_distributed(env, &flat)
            .map_err(|e| format!("flat bfs: {e:?}"))?;
        let b = crate::apps::bfs::run_distributed(env, &combined)
            .map_err(|e| format!("combined bfs: {e:?}"))?;
        if a.summary != b.summary {
            return Err(format!(
                "combining changed the levels: flat {:?} vs combined {:?}",
                a.summary, b.summary
            ));
        }
        if a.summary != oracle {
            return Err(format!(
                "traversal diverged from the sequential oracle: {:?} vs {:?}",
                a.summary, oracle
            ));
        }
        Ok(())
    })
}

/// **Invariant: sample sort emits a sorted permutation.** A faulted world
/// runs the bucketed redistribution over a seed-chosen key distribution
/// (uniform, heavy-duplicate, or all-equal — the empty-bucket case). The
/// output must be globally sorted, carry exactly the input multiset
/// (order-independent checksums match), and place every key where the
/// sequential oracle puts it — even when the plan reorders or starves the
/// scatter's one-sided traffic.
pub fn sample_sort_is_permutation(seed: u64) -> Result<FaultStats, String> {
    use crate::apps::samplesort::{reference_checksums, KeyDist, SortConfig};
    let dist = [KeyDist::Uniform, KeyDist::Skewed, KeyDist::AllEqual][(seed % 3) as usize];
    let sort = SortConfig { n: 192, seed, dist, oversample: 4, team: DART_TEAM_ALL };
    let (multiset, position) = reference_checksums(&sort);
    world_check(chaos_cfg(4, 2, seed), |env| {
        let r = crate::apps::samplesort::run_distributed(env, &sort)
            .map_err(|e| format!("sample sort: {e:?}"))?;
        if !r.sorted_ok {
            return Err("output is not globally sorted".into());
        }
        if r.checksum_in != r.checksum_out {
            return Err(format!(
                "output is not a permutation of the input: in {:#x} out {:#x}",
                r.checksum_in, r.checksum_out
            ));
        }
        if r.count != sort.n as u64 {
            return Err(format!("{} keys out of {} survived redistribution", r.count, sort.n));
        }
        if (r.checksum_out, r.position_checksum) != (multiset, position) {
            return Err(format!(
                "output diverged from the sequential oracle: ({:#x}, {:#x}) vs ({multiset:#x}, \
                 {position:#x})",
                r.checksum_out, r.position_checksum
            ));
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_seeds_are_stable_and_distinct() {
        let a = default_seeds(8);
        assert_eq!(a, default_seeds(8));
        let mut b = a.clone();
        b.sort_unstable();
        b.dedup();
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn seed_parser_takes_decimal_and_hex() {
        assert_eq!(parse_seed(" 42 "), 42);
        assert_eq!(parse_seed("0xff"), 255);
        assert_eq!(parse_seed("0XDEAD"), 0xDEAD);
    }

    #[test]
    fn chaos_check_sums_stats_on_success() {
        let total = chaos_check("trivial", &[1, 2, 3], |seed| {
            Ok(FaultStats { jitter_events: seed, ..FaultStats::default() })
        });
        assert_eq!(total.jitter_events, 6);
    }

    #[test]
    #[should_panic(expected = "smallest failing seed: 0x2")]
    fn chaos_check_shrinks_to_smallest_failing_seed() {
        chaos_check("half-fail", &[9, 2, 5], |seed| {
            if seed >= 5 {
                Err("too big".into())
            } else if seed == 2 {
                Err("also bad".into())
            } else {
                Ok(FaultStats::default())
            }
        });
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn chaos_check_survives_scenario_panics_to_report() {
        chaos_check("panicky", &[1], |_| panic!("boom"));
    }
}
