//! Minimal property-based testing support (the `proptest` crate is not
//! available offline, so the suite brings its own).
//!
//! Deterministic splitmix64 generator + a `forall` runner that reports the
//! failing seed so any counterexample is reproducible with
//! `Rng::new(seed)`, plus the [`world`] launch builder shared by the
//! integration suites and the seeded [`chaos`] harness.

pub mod chaos;

use crate::dart::{DartConfig, DartEnv};
use crate::mpisim::{ExecMode, ProgressMode};
use crate::simnet::{CostModel, FaultPlan, PinPolicy};
use std::sync::Mutex;

/// Start building a test world of `units` units: flat single-node
/// topology, zero cost model, defaults identical to
/// [`DartConfig::with_units`]. Chain overrides, then [`WorldBuilder::launch`]
/// or [`WorldBuilder::collect`]:
///
/// ```no_run
/// use dart::testing::world;
/// let ids = world(4).faults(7).collect(|env| env.myid());
/// assert_eq!(ids, vec![0, 1, 2, 3]);
/// ```
pub fn world(units: usize) -> WorldBuilder {
    WorldBuilder { cfg: DartConfig::with_units(units) }
}

/// Fluent builder over [`DartConfig`] for the integration suites — hoists
/// the per-suite `cfg()` helpers into one place and adds the fault knob.
pub struct WorldBuilder {
    cfg: DartConfig,
}

impl WorldBuilder {
    /// Place the units on a Hermit-like cluster of `nodes` nodes with the
    /// calibrated cost model (the multi-node suites' base config).
    #[must_use]
    pub fn nodes(mut self, nodes: usize) -> Self {
        self.cfg.topology = crate::simnet::Topology::hermit(nodes);
        self.cfg.cost = CostModel::hermit();
        self
    }

    /// Override the machine topology without touching the cost model
    /// (for shapes [`WorldBuilder::nodes`] cannot express, e.g.
    /// oversubscribed or asymmetric placements).
    #[must_use]
    pub fn topology(mut self, topo: crate::simnet::Topology) -> Self {
        self.cfg.topology = topo;
        self
    }

    /// Override the unit → core placement policy.
    #[must_use]
    pub fn placement(mut self, pin: PinPolicy) -> Self {
        self.cfg.pin = pin;
        self
    }

    /// Override the window pool sizes (non-collective, team).
    #[must_use]
    pub fn pools(mut self, non_collective: usize, team: usize) -> Self {
        self.cfg.non_collective_pool = non_collective;
        self.cfg.team_pool = team;
        self
    }

    /// Override the network cost model.
    #[must_use]
    pub fn cost(mut self, cost: CostModel) -> Self {
        self.cfg.cost = cost;
        self
    }

    /// Override the asynchronous-progress mode.
    #[must_use]
    pub fn progress(mut self, mode: ProgressMode) -> Self {
        self.cfg.progress_mode = mode;
        self
    }

    /// Override the execution mode and its run-slot bound.
    #[must_use]
    pub fn exec(mut self, exec: ExecMode, max_os_threads: usize) -> Self {
        self.cfg.exec = exec;
        self.cfg.max_os_threads = max_os_threads;
        self
    }

    /// Toggle shared-memory windows.
    #[must_use]
    pub fn shmem(mut self, on: bool) -> Self {
        self.cfg.shmem_windows = on;
        self
    }

    /// Toggle the intra-node zero-copy fast path.
    #[must_use]
    pub fn fastpath(mut self, on: bool) -> Self {
        self.cfg.locality_fastpath = on;
        self
    }

    /// Toggle hierarchical two-level collectives.
    #[must_use]
    pub fn hierarchical(mut self, on: bool) -> Self {
        self.cfg.hierarchical_collectives = on;
        self
    }

    /// Install [`FaultPlan::from_seed`]`(seed)` — every fault class live at
    /// seed-derived intensities (see [`crate::simnet::faults`]).
    #[must_use]
    pub fn faults(mut self, seed: u64) -> Self {
        self.cfg.fault_plan = Some(FaultPlan::from_seed(seed));
        self
    }

    /// Install a specific fault plan (e.g. a single-class plan built with
    /// struct-update over [`FaultPlan::quiet`]).
    #[must_use]
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.cfg.fault_plan = Some(plan);
        self
    }

    /// Surrender the built [`DartConfig`] (for call sites that need knobs
    /// the builder doesn't cover).
    pub fn build(self) -> DartConfig {
        self.cfg
    }

    /// Launch the world and run `f` on every unit
    /// ([`crate::dart::run`] + unwrap).
    pub fn launch(self, f: impl Fn(&DartEnv) + Send + Sync) {
        crate::dart::run(self.cfg, f).expect("world launch failed");
    }

    /// Launch the world, run `f` on every unit, and return the per-unit
    /// results ordered by unit id — replaces the `Mutex`-capture
    /// boilerplate the suites used to hand-roll.
    pub fn collect<T: Send>(self, f: impl Fn(&DartEnv) -> T + Send + Sync) -> Vec<T> {
        let units = self.cfg.units;
        let out: Mutex<Vec<Option<T>>> = Mutex::new((0..units).map(|_| None).collect());
        crate::dart::run(self.cfg, |env| {
            let v = f(env);
            out.lock().unwrap()[env.myid() as usize] = Some(v);
        })
        .expect("world launch failed");
        out.into_inner()
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(u, v)| v.unwrap_or_else(|| panic!("unit {u} produced no result")))
            .collect()
    }
}

/// Property-based testing primitives: a deterministic RNG and the
/// [`prop::forall`] runner.
pub mod prop {
    /// splitmix64 — tiny, fast, deterministic.
    #[derive(Debug, Clone)]
    pub struct Rng(u64);

    impl Rng {
        /// Seeded generator; the same seed replays the same sequence.
        pub fn new(seed: u64) -> Self {
            Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
        }

        /// Next raw 64-bit draw.
        pub fn next_u64(&mut self) -> u64 {
            self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = self.0;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        }

        /// Uniform in `[0, n)`. `n` must be > 0.
        pub fn below(&mut self, n: usize) -> usize {
            (self.next_u64() % n as u64) as usize
        }

        /// Uniform in `[lo, hi)`.
        pub fn range(&mut self, lo: usize, hi: usize) -> usize {
            lo + self.below(hi - lo)
        }

        /// A fair coin flip.
        pub fn bool(&mut self) -> bool {
            self.next_u64() & 1 == 1
        }

        /// Pick one element of a slice.
        pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            &xs[self.below(xs.len())]
        }

        /// A random byte vector of length `len`.
        pub fn bytes(&mut self, len: usize) -> Vec<u8> {
            (0..len).map(|_| self.next_u64() as u8).collect()
        }

        /// A random subset (as sorted unique values) of `0..n`.
        pub fn subset(&mut self, n: usize) -> Vec<usize> {
            let mut v: Vec<usize> = (0..n).filter(|_| self.bool()).collect();
            if v.is_empty() && n > 0 {
                v.push(self.below(n));
            }
            v
        }
    }

    /// Run `test` on `cases` generated inputs; panic with the seed and
    /// case index on the first failure.
    ///
    /// ```no_run
    /// use dart::testing::prop::{forall, Rng};
    /// forall("sum-commutes", 100, |rng| (rng.below(10), rng.below(10)),
    ///        |&(a, b)| (a + b == b + a).then_some(()).ok_or("sum".into()));
    /// ```
    pub fn forall<T: std::fmt::Debug>(
        name: &str,
        cases: usize,
        gen: impl Fn(&mut Rng) -> T,
        test: impl Fn(&T) -> Result<(), String>,
    ) {
        let base_seed = 0xDA27_0001u64;
        for i in 0..cases {
            let seed = base_seed.wrapping_add(i as u64);
            let mut rng = Rng::new(seed);
            let input = gen(&mut rng);
            if let Err(msg) = test(&input) {
                panic!(
                    "property {name:?} failed at case {i} (seed {seed:#x}):\n  input: {input:?}\n  {msg}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::prop::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("add-commutes", 200, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failure() {
        forall("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn subset_is_sorted_unique() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let s = r.subset(20);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
