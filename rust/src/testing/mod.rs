//! Minimal property-based testing support (the `proptest` crate is not
//! available offline, so the suite brings its own).
//!
//! Deterministic splitmix64 generator + a `forall` runner that reports the
//! failing seed so any counterexample is reproducible with
//! `Rng::new(seed)`.

/// Property-based testing primitives: a deterministic RNG and the
/// [`prop::forall`] runner.
pub mod prop {
    /// splitmix64 — tiny, fast, deterministic.
    #[derive(Debug, Clone)]
    pub struct Rng(u64);

    impl Rng {
        /// Seeded generator; the same seed replays the same sequence.
        pub fn new(seed: u64) -> Self {
            Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
        }

        /// Next raw 64-bit draw.
        pub fn next_u64(&mut self) -> u64 {
            self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = self.0;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        }

        /// Uniform in `[0, n)`. `n` must be > 0.
        pub fn below(&mut self, n: usize) -> usize {
            (self.next_u64() % n as u64) as usize
        }

        /// Uniform in `[lo, hi)`.
        pub fn range(&mut self, lo: usize, hi: usize) -> usize {
            lo + self.below(hi - lo)
        }

        /// A fair coin flip.
        pub fn bool(&mut self) -> bool {
            self.next_u64() & 1 == 1
        }

        /// Pick one element of a slice.
        pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            &xs[self.below(xs.len())]
        }

        /// A random byte vector of length `len`.
        pub fn bytes(&mut self, len: usize) -> Vec<u8> {
            (0..len).map(|_| self.next_u64() as u8).collect()
        }

        /// A random subset (as sorted unique values) of `0..n`.
        pub fn subset(&mut self, n: usize) -> Vec<usize> {
            let mut v: Vec<usize> = (0..n).filter(|_| self.bool()).collect();
            if v.is_empty() && n > 0 {
                v.push(self.below(n));
            }
            v
        }
    }

    /// Run `test` on `cases` generated inputs; panic with the seed and
    /// case index on the first failure.
    ///
    /// ```no_run
    /// use dart::testing::prop::{forall, Rng};
    /// forall("sum-commutes", 100, |rng| (rng.below(10), rng.below(10)),
    ///        |&(a, b)| (a + b == b + a).then_some(()).ok_or("sum".into()));
    /// ```
    pub fn forall<T: std::fmt::Debug>(
        name: &str,
        cases: usize,
        gen: impl Fn(&mut Rng) -> T,
        test: impl Fn(&T) -> Result<(), String>,
    ) {
        let base_seed = 0xDA27_0001u64;
        for i in 0..cases {
            let seed = base_seed.wrapping_add(i as u64);
            let mut rng = Rng::new(seed);
            let input = gen(&mut rng);
            if let Err(msg) = test(&input) {
                panic!(
                    "property {name:?} failed at case {i} (seed {seed:#x}):\n  input: {input:?}\n  {msg}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::prop::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("add-commutes", 200, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failure() {
        forall("always-fails", 5, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn subset_is_sorted_unique() {
        let mut r = Rng::new(9);
        for _ in 0..100 {
            let s = r.subset(20);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
