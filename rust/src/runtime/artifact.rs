//! AOT artifact discovery and I/O-signature metadata.
//!
//! `python -m compile.aot` emits, per compiled step function, an HLO-text
//! file (`<name>.hlo.txt`) and a `.meta` sidecar whose line format is:
//!
//! ```text
//! input float32 66 66
//! output float32 64 64
//! output float32
//! ```
//!
//! (dtype followed by dims; a bare dtype is a scalar). This module locates
//! artifacts and parses the sidecars so the executor can validate shapes
//! before handing buffers to PJRT.

use super::{RuntimeErr, RuntimeResult};
use std::path::{Path, PathBuf};

/// Element type of an artifact tensor (only what the catalog uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[allow(missing_docs)] // self-describing dtype tags
pub enum DType {
    F32,
    F64,
    I32,
    I64,
}

impl DType {
    fn parse(s: &str) -> RuntimeResult<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "float64" | "f64" => Ok(DType::F64),
            "int32" | "i32" => Ok(DType::I32),
            "int64" | "i64" => Ok(DType::I64),
            other => Err(RuntimeErr::Meta(format!("unknown dtype {other:?}"))),
        }
    }

    /// Bytes per element.
    pub fn size(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 | DType::I64 => 8,
        }
    }
}

/// Shape+dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Element type.
    pub dtype: DType,
    /// Dimensions (empty = scalar).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One discovered artifact: HLO path plus its I/O signature.
#[derive(Debug, Clone)]
pub struct Artifact {
    /// Artifact name (the file stem).
    pub name: String,
    /// Path of the HLO-text file.
    pub hlo_path: PathBuf,
    /// Input signatures, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output signatures, in return order.
    pub outputs: Vec<TensorSpec>,
}

impl Artifact {
    /// Load `<dir>/<name>.hlo.txt` + `<dir>/<name>.meta`.
    pub fn load(dir: &Path, name: &str) -> RuntimeResult<Artifact> {
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        if !hlo_path.exists() {
            return Err(RuntimeErr::Missing(format!(
                "{} — run `make artifacts` first",
                hlo_path.display()
            )));
        }
        let meta_path = dir.join(format!("{name}.meta"));
        let meta = std::fs::read_to_string(&meta_path)
            .map_err(|e| RuntimeErr::Meta(format!("{}: {e}", meta_path.display())))?;
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for (lineno, line) in meta.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kind = parts.next().unwrap();
            let dtype = DType::parse(
                parts
                    .next()
                    .ok_or_else(|| RuntimeErr::Meta(format!("line {}: missing dtype", lineno + 1)))?,
            )?;
            let dims = parts
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|e| RuntimeErr::Meta(format!("line {}: {e}", lineno + 1)))
                })
                .collect::<RuntimeResult<Vec<_>>>()?;
            let spec = TensorSpec { dtype, dims };
            match kind {
                "input" => inputs.push(spec),
                "output" => outputs.push(spec),
                other => return Err(RuntimeErr::Meta(format!("line {}: bad kind {other:?}", lineno + 1))),
            }
        }
        Ok(Artifact { name: name.to_string(), hlo_path, inputs, outputs })
    }

    /// List artifact names available in `dir` (sorted).
    pub fn discover(dir: &Path) -> RuntimeResult<Vec<String>> {
        let mut names = Vec::new();
        let rd = std::fs::read_dir(dir)
            .map_err(|e| RuntimeErr::Missing(format!("{}: {e}", dir.display())))?;
        for entry in rd.flatten() {
            let p = entry.path();
            if let Some(fname) = p.file_name().and_then(|f| f.to_str()) {
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}

/// The artifacts directory: `$DART_ARTIFACTS` or `./artifacts` (relative to
/// the workspace root, where `make` runs).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("DART_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path, name: &str, meta: &str) {
        std::fs::write(dir.join(format!("{name}.hlo.txt")), "HloModule fake").unwrap();
        std::fs::write(dir.join(format!("{name}.meta")), meta).unwrap();
    }

    #[test]
    fn parse_meta_roundtrip() {
        let dir = std::env::temp_dir().join("dart-artifact-test-1");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir, "t", "input float32 66 66\noutput float32 64 64\noutput float32\n");
        let a = Artifact::load(&dir, "t").unwrap();
        assert_eq!(a.inputs, vec![TensorSpec { dtype: DType::F32, dims: vec![66, 66] }]);
        assert_eq!(a.outputs.len(), 2);
        assert_eq!(a.outputs[1].dims, Vec::<usize>::new()); // scalar
        assert_eq!(a.outputs[0].elements(), 4096);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_is_actionable() {
        let dir = std::env::temp_dir().join("dart-artifact-test-2");
        std::fs::create_dir_all(&dir).unwrap();
        let err = Artifact::load(&dir, "nope").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_meta_is_error() {
        let dir = std::env::temp_dir().join("dart-artifact-test-3");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir, "bad", "frobnicate float32 2\n");
        assert!(Artifact::load(&dir, "bad").is_err());
        write_meta(&dir, "bad2", "input notadtype 2\n");
        assert!(Artifact::load(&dir, "bad2").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn discover_lists_hlo_files() {
        let dir = std::env::temp_dir().join("dart-artifact-test-4");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir, "b_art", "input float32 1\noutput float32 1\n");
        write_meta(&dir, "a_art", "input float32 1\noutput float32 1\n");
        let names = Artifact::discover(&dir).unwrap();
        assert_eq!(names, vec!["a_art", "b_art"]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
