//! Artifact executor: load AOT-compiled kernel artifacts and run them from
//! the Rust request path (Python is build-time only).
//!
//! The interchange format is HLO **text** plus a `.meta` I/O-signature
//! sidecar (see `python/compile/aot.py`). The original execution path —
//! `HloModuleProto::from_text_file` → `XlaComputation` → `PjRtClient::
//! compile` → `execute` — needs the `xla`/PJRT native bindings, which are
//! not available in the offline build environment. The [`Engine`] here
//! therefore executes artifacts on a **native backend**: the catalog's
//! kernels (5-point stencil sweep + residual, GEMM accumulate) are
//! recognized from their `.meta` signatures and run as plain Rust,
//! numerically validated against `python/compile/kernels/ref.py` by
//! `rust/tests/runtime_artifacts.rs`. Swapping the PJRT client back in
//! only touches [`Executable::run_f32`]; the `Engine`/`Executable` API and
//! the artifact format are unchanged.
//!
//! Engines are not `Send` (mirroring PJRT handles), so every DART unit
//! that computes creates its own [`Engine`]; compiled executables are
//! cached per engine by name.

pub mod artifact;

pub use artifact::{artifacts_dir, Artifact, DType, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::rc::Rc;

/// Errors from the executor.
#[derive(Debug)]
pub enum RuntimeErr {
    /// Backend failure (unsupported artifact signature, execution error).
    Backend(String),
    /// A missing artifact file (`.hlo.txt` or `.meta`).
    Missing(String),
    /// A malformed `.meta` sidecar.
    Meta(String),
    /// An input buffer that does not match the artifact's signature.
    Shape {
        /// Artifact name.
        name: String,
        /// Expected element count (or input arity).
        expected: usize,
        /// What the caller supplied.
        got: usize,
    },
}

impl fmt::Display for RuntimeErr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeErr::Backend(msg) => write!(f, "executor backend error: {msg}"),
            RuntimeErr::Missing(what) => write!(f, "artifact missing: {what}"),
            RuntimeErr::Meta(msg) => write!(f, "artifact metadata error: {msg}"),
            RuntimeErr::Shape { name, expected, got } => write!(
                f,
                "shape mismatch for {name}: expected {expected} f32 elements, got {got}"
            ),
        }
    }
}

impl std::error::Error for RuntimeErr {}

/// Executor result alias.
pub type RuntimeResult<T> = Result<T, RuntimeErr>;

/// The compute kernel behind an artifact, selected from its I/O signature.
#[derive(Debug, Clone, Copy)]
enum Kernel {
    /// `stencil_step` (model.py): input `(h+2, w+2)` padded block →
    /// `(h, w)` interior after one 5-point sweep + scalar residual.
    Stencil { h: usize, w: usize, alpha: f32 },
    /// `summa_tile` (model.py): `(C, A, B)` → `C + A @ B`.
    Gemm { m: usize, k: usize, n: usize },
}

impl Kernel {
    /// Recognize the catalog's kernels from the `.meta` signature.
    fn select(a: &Artifact) -> RuntimeResult<Kernel> {
        let all_f32 = a.inputs.iter().chain(&a.outputs).all(|s| s.dtype == DType::F32);
        match (a.inputs.as_slice(), a.outputs.as_slice()) {
            ([inp], [out, res])
                if all_f32
                    && inp.dims.len() == 2
                    && out.dims.len() == 2
                    && res.dims.is_empty()
                    && inp.dims[0] == out.dims[0] + 2
                    && inp.dims[1] == out.dims[1] + 2 =>
            {
                Ok(Kernel::Stencil { h: out.dims[0], w: out.dims[1], alpha: 0.25 })
            }
            ([c, a_in, b_in], [out])
                if all_f32
                    && c.dims.len() == 2
                    && out.dims == c.dims
                    && a_in.dims.len() == 2
                    && b_in.dims.len() == 2
                    && a_in.dims[0] == c.dims[0]
                    && a_in.dims[1] == b_in.dims[0]
                    && b_in.dims[1] == c.dims[1] =>
            {
                Ok(Kernel::Gemm { m: c.dims[0], k: a_in.dims[1], n: c.dims[1] })
            }
            _ => Err(RuntimeErr::Backend(format!(
                "artifact {} has no native kernel for its signature ({} in / {} out)",
                a.name,
                a.inputs.len(),
                a.outputs.len()
            ))),
        }
    }

    fn execute(&self, inputs: &[&[f32]]) -> Vec<Vec<f32>> {
        match *self {
            Kernel::Stencil { h, w, alpha } => {
                let padded = inputs[0];
                let wp = w + 2;
                let mut out = vec![0f32; h * w];
                let mut residual = 0f64;
                for i in 0..h {
                    for j in 0..w {
                        let c = padded[(i + 1) * wp + (j + 1)];
                        let up = padded[i * wp + (j + 1)];
                        let down = padded[(i + 2) * wp + (j + 1)];
                        let left = padded[(i + 1) * wp + j];
                        let right = padded[(i + 1) * wp + (j + 2)];
                        let v = c + alpha * (up + down + left + right - 4.0 * c);
                        out[i * w + j] = v;
                        residual += ((v - c) as f64).powi(2);
                    }
                }
                vec![out, vec![residual as f32]]
            }
            Kernel::Gemm { m, k, n } => {
                let (c, a, b) = (inputs[0], inputs[1], inputs[2]);
                let mut out = c.to_vec();
                // ikj order: stream through B rows, accumulate in f32
                // (jnp.dot with preferred_element_type=f32).
                for i in 0..m {
                    for kk in 0..k {
                        let aik = a[i * k + kk];
                        let brow = &b[kk * n..(kk + 1) * n];
                        let crow = &mut out[i * n..(i + 1) * n];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
                vec![out]
            }
        }
    }
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    artifact: Artifact,
    kernel: Kernel,
}

impl Executable {
    /// The artifact's I/O signature.
    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Execute with f32 inputs (the catalog is all-f32); returns the flat
    /// f32 buffers of every output, in artifact order.
    ///
    /// Inputs are validated against the `.meta` signature before touching
    /// the backend, so shape bugs surface as [`RuntimeErr::Shape`] rather
    /// than a backend abort.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> RuntimeResult<Vec<Vec<f32>>> {
        let sig = &self.artifact;
        if inputs.len() != sig.inputs.len() {
            return Err(RuntimeErr::Shape {
                name: sig.name.clone(),
                expected: sig.inputs.len(),
                got: inputs.len(),
            });
        }
        for (spec, buf) in sig.inputs.iter().zip(inputs) {
            if spec.elements() != buf.len() {
                return Err(RuntimeErr::Shape {
                    name: sig.name.clone(),
                    expected: spec.elements(),
                    got: buf.len(),
                });
            }
        }
        let outs = self.kernel.execute(inputs);
        debug_assert_eq!(outs.len(), sig.outputs.len(), "output arity drift");
        for (spec, out) in sig.outputs.iter().zip(&outs) {
            debug_assert_eq!(out.len(), spec.elements().max(1), "output shape drift");
        }
        Ok(outs)
    }
}

/// A per-thread executor over an artifacts directory, with an executable
/// cache (the role a PJRT CPU client plays in a native-XLA build).
pub struct Engine {
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// Executor over the default artifacts directory.
    pub fn new() -> RuntimeResult<Engine> {
        Self::with_dir(artifacts_dir())
    }

    /// Executor over an explicit artifacts directory.
    pub fn with_dir(dir: PathBuf) -> RuntimeResult<Engine> {
        Ok(Engine { dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Backend platform name (diagnostics).
    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Artifact names available to this engine.
    pub fn available(&self) -> RuntimeResult<Vec<String>> {
        Artifact::discover(&self.dir)
    }

    /// Load an artifact by name and bind its kernel (cached).
    pub fn load(&self, name: &str) -> RuntimeResult<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let artifact = Artifact::load(&self.dir, name)?;
        let kernel = Kernel::select(&artifact)?;
        let exe = Rc::new(Executable { artifact, kernel });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn write_artifact(dir: &Path, name: &str, meta: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join(format!("{name}.hlo.txt")), "HloModule test").unwrap();
        std::fs::write(dir.join(format!("{name}.meta")), meta).unwrap();
    }

    #[test]
    fn stencil_kernel_selected_and_runs() {
        let dir = std::env::temp_dir().join("dart-runtime-test-1");
        write_artifact(&dir, "stencil_f32_4x4", "input float32 6 6\noutput float32 4 4\noutput float32\n");
        let e = Engine::with_dir(dir.clone()).unwrap();
        let exe = e.load("stencil_f32_4x4").unwrap();
        let padded = vec![1.0f32; 36];
        let outs = exe.run_f32(&[&padded]).unwrap();
        // Uniform field is a fixed point with zero residual.
        assert!(outs[0].iter().all(|&v| (v - 1.0).abs() < 1e-7));
        assert!(outs[1][0].abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gemm_kernel_accumulates() {
        let dir = std::env::temp_dir().join("dart-runtime-test-2");
        write_artifact(
            &dir,
            "summa_f32_2x3x2",
            "input float32 2 2\ninput float32 2 3\ninput float32 3 2\noutput float32 2 2\n",
        );
        let e = Engine::with_dir(dir.clone()).unwrap();
        let exe = e.load("summa_f32_2x3x2").unwrap();
        let c = [1.0f32, 0.0, 0.0, 1.0];
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let b = [7.0f32, 8.0, 9.0, 10.0, 11.0, 12.0]; // 3×2
        let outs = exe.run_f32(&[&c, &a, &b]).unwrap();
        // A@B = [[58, 64], [139, 154]]; plus identity C.
        assert_eq!(outs[0], vec![59.0, 64.0, 139.0, 155.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unsupported_signature_is_reported() {
        let dir = std::env::temp_dir().join("dart-runtime-test-3");
        write_artifact(&dir, "weird", "input float32 3\noutput float32 3\n");
        let e = Engine::with_dir(dir.clone()).unwrap();
        assert!(matches!(e.load("weird"), Err(RuntimeErr::Backend(_))));
        std::fs::remove_dir_all(&dir).ok();
    }
}
