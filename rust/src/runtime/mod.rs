//! PJRT/XLA executor: load AOT-compiled JAX/Pallas artifacts and run them
//! from the Rust request path (Python is build-time only).
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py` and
//! `/opt/xla-example/README.md`): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//!
//! PJRT handles are not `Send`, so every DART unit that computes creates
//! its own [`Engine`] (mirroring one-PJRT-client-per-process in a real
//! deployment); compiled executables are cached per engine by name.

pub mod artifact;

pub use artifact::{artifacts_dir, Artifact, DType, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use thiserror::Error;

/// Errors from the executor.
#[derive(Debug, Error)]
pub enum RuntimeErr {
    #[error("XLA/PJRT error: {0}")]
    Xla(String),
    #[error("artifact missing: {0}")]
    Missing(String),
    #[error("artifact metadata error: {0}")]
    Meta(String),
    #[error("shape mismatch for {name}: expected {expected} f32 elements, got {got}")]
    Shape { name: String, expected: usize, got: usize },
}

impl From<xla::Error> for RuntimeErr {
    fn from(e: xla::Error) -> Self {
        RuntimeErr::Xla(e.to_string())
    }
}

/// Executor result alias.
pub type RuntimeResult<T> = Result<T, RuntimeErr>;

/// A compiled artifact, ready to execute.
pub struct Executable {
    artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// The artifact's I/O signature.
    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Execute with f32 inputs (the catalog is all-f32); returns the flat
    /// f32 buffers of every output, in artifact order.
    ///
    /// Inputs are validated against the `.meta` signature before touching
    /// PJRT, so shape bugs surface as [`RuntimeErr::Shape`] rather than an
    /// XLA abort.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> RuntimeResult<Vec<Vec<f32>>> {
        let sig = &self.artifact;
        if inputs.len() != sig.inputs.len() {
            return Err(RuntimeErr::Shape {
                name: sig.name.clone(),
                expected: sig.inputs.len(),
                got: inputs.len(),
            });
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (spec, buf) in sig.inputs.iter().zip(inputs) {
            if spec.elements() != buf.len() {
                return Err(RuntimeErr::Shape {
                    name: sig.name.clone(),
                    expected: spec.elements(),
                    got: buf.len(),
                });
            }
            let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf);
            let lit = if dims.is_empty() { lit } else { lit.reshape(&dims)? };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let mut parts = result.to_tuple()?;
        let mut outs = Vec::with_capacity(parts.len());
        for (spec, lit) in sig.outputs.iter().zip(parts.drain(..)) {
            let v = lit.to_vec::<f32>()?;
            debug_assert_eq!(v.len(), spec.elements(), "output shape drift");
            outs.push(v);
        }
        Ok(outs)
    }
}

/// A per-thread PJRT CPU client with an executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    /// CPU PJRT client over the default artifacts directory.
    pub fn new() -> RuntimeResult<Engine> {
        Self::with_dir(artifacts_dir())
    }

    /// CPU PJRT client over an explicit artifacts directory.
    pub fn with_dir(dir: PathBuf) -> RuntimeResult<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()?, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available to this engine.
    pub fn available(&self) -> RuntimeResult<Vec<String>> {
        Artifact::discover(&self.dir)
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> RuntimeResult<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let artifact = Artifact::load(&self.dir, name)?;
        let proto = xla::HloModuleProto::from_text_file(&artifact.hlo_path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let exe = Rc::new(Executable { artifact, exe });
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}
