//! The paper's figures 8–15 as reusable measurement drivers.
//!
//! Each figure compares one DART one-sided operation against the
//! semantically equivalent raw MPI-3 sequence, over the message-size sweep
//! (1 B … 2 MiB) and the three placements of §V-A. The benches in
//! `rust/benches/fig*.rs` are thin wrappers around [`run_figure`].
//!
//! Metrics (§V-A):
//! - **DTCT** (data transfer completion time) for blocking put/get —
//!   the call does not return before remote completion;
//! - **DTIT** (data transfer initiation time) for non-blocking put/get —
//!   only the initiation is timed ("these calls return immediately after
//!   initiating the transfer"); completion is drained outside the timer;
//! - **bandwidth** — blocking: back-to-back completed ops; non-blocking:
//!   "many overlapping non-blocking operations" finished by one waitall.

use super::{
    adaptive_reps, fit_constant_overhead, paper_msg_sizes, paper_placements, print_comparison_table,
    quick_mode, quick_msg_sizes, Samples,
};
use crate::dart::{DartConfig, DartHandle, DART_TEAM_ALL};
use crate::mpisim::{RmaRequest, Win, World, WorldConfig};
use crate::simnet::PinPolicy;
use std::sync::Mutex;
use std::time::Instant;

/// Which figure is being regenerated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Figure {
    /// Fig. 8 — DTCT of blocking put.
    DtctBlockingPut,
    /// Fig. 9 — DTCT of blocking get.
    DtctBlockingGet,
    /// Fig. 10 — DTIT of non-blocking put.
    DtitNonblockingPut,
    /// Fig. 11 — DTIT of non-blocking get.
    DtitNonblockingGet,
    /// Fig. 12 — bandwidth of blocking put.
    BwBlockingPut,
    /// Fig. 13 — bandwidth of blocking get.
    BwBlockingGet,
    /// Fig. 14 — bandwidth of non-blocking put.
    BwNonblockingPut,
    /// Fig. 15 — bandwidth of non-blocking get.
    BwNonblockingGet,
}

impl Figure {
    /// The paper figure's title.
    pub fn title(&self) -> &'static str {
        match self {
            Figure::DtctBlockingPut => "Fig. 8 — DTCT of the Blocking Put Operation",
            Figure::DtctBlockingGet => "Fig. 9 — DTCT of the Blocking Get Operation",
            Figure::DtitNonblockingPut => "Fig. 10 — DTIT of the Non-blocking Put Operation",
            Figure::DtitNonblockingGet => "Fig. 11 — DTIT of the Non-blocking Get Operation",
            Figure::BwBlockingPut => "Fig. 12 — Bandwidth of the Blocking Put Operation",
            Figure::BwBlockingGet => "Fig. 13 — Bandwidth of the Blocking Get Operation",
            Figure::BwNonblockingPut => "Fig. 14 — Bandwidth of the Non-blocking Put Operation",
            Figure::BwNonblockingGet => "Fig. 15 — Bandwidth of the Non-blocking Get Operation",
        }
    }

    /// Bandwidth figure (12–15) vs latency figure (8–11)?
    pub fn is_bandwidth(&self) -> bool {
        matches!(
            self,
            Figure::BwBlockingPut | Figure::BwBlockingGet | Figure::BwNonblockingPut | Figure::BwNonblockingGet
        )
    }

    fn unit(&self) -> &'static str {
        if self.is_bandwidth() {
            "MB/s"
        } else {
            "ns"
        }
    }
}

/// Overlap depth for the non-blocking bandwidth figures.
const NB_WINDOW: usize = 32;
const BASE_REPS: usize = 256;

/// Measure the DART side of a figure: 2 units, unit 0 drives, returns
/// `(size, value)` rows (ns or MB/s).
pub fn measure_dart(fig: Figure, pin: PinPolicy, sizes: &[usize]) -> Vec<(usize, f64)> {
    let rows = Mutex::new(Vec::new());
    let cfg = DartConfig::hermit(2, 2).with_pin(pin);
    crate::dart::run(cfg, |env| {
        let g = env.team_memalloc_aligned(DART_TEAM_ALL, 1 << 21).unwrap();
        let target = g.with_unit(1);
        let me = env.myid();
        for &size in sizes {
            let src = vec![0x5Au8; size];
            let mut dst = vec![0u8; size];
            let reps = adaptive_reps(size, BASE_REPS);
            env.barrier(DART_TEAM_ALL).unwrap();
            if me == 0 {
                let value = match fig {
                    Figure::DtctBlockingPut => {
                        let mut s = Samples::new();
                        for _ in 0..reps {
                            let t = Instant::now();
                            env.put_blocking(target, &src).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                        }
                        s.median()
                    }
                    Figure::DtctBlockingGet => {
                        let mut s = Samples::new();
                        for _ in 0..reps {
                            let t = Instant::now();
                            env.get_blocking(target, &mut dst).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                        }
                        s.median()
                    }
                    Figure::DtitNonblockingPut => {
                        let mut s = Samples::new();
                        let mut handles: Vec<DartHandle> = Vec::with_capacity(reps);
                        for _ in 0..reps {
                            let t = Instant::now();
                            let h = env.put(target, &src).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                            handles.push(h);
                        }
                        env.waitall(handles).unwrap();
                        s.median()
                    }
                    Figure::DtitNonblockingGet => {
                        let mut s = Samples::new();
                        let mut handles: Vec<DartHandle> = Vec::with_capacity(reps);
                        for _ in 0..reps {
                            let t = Instant::now();
                            let h = env.get(target, &mut dst).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                            handles.push(h);
                        }
                        env.waitall(handles).unwrap();
                        s.median()
                    }
                    Figure::BwBlockingPut => {
                        let t = Instant::now();
                        for _ in 0..reps {
                            env.put_blocking(target, &src).unwrap();
                        }
                        super::bandwidth_mb_s(size * reps, t.elapsed().as_nanos() as f64)
                    }
                    Figure::BwBlockingGet => {
                        let t = Instant::now();
                        for _ in 0..reps {
                            env.get_blocking(target, &mut dst).unwrap();
                        }
                        super::bandwidth_mb_s(size * reps, t.elapsed().as_nanos() as f64)
                    }
                    Figure::BwNonblockingPut => {
                        let windows = (reps / NB_WINDOW).max(1);
                        let t = Instant::now();
                        for _ in 0..windows {
                            let mut handles = Vec::with_capacity(NB_WINDOW);
                            for _ in 0..NB_WINDOW {
                                handles.push(env.put(target, &src).unwrap());
                            }
                            env.waitall(handles).unwrap();
                        }
                        super::bandwidth_mb_s(
                            size * windows * NB_WINDOW,
                            t.elapsed().as_nanos() as f64,
                        )
                    }
                    Figure::BwNonblockingGet => {
                        let windows = (reps / NB_WINDOW).max(1);
                        let t = Instant::now();
                        for _ in 0..windows {
                            let mut handles = Vec::with_capacity(NB_WINDOW);
                            for _ in 0..NB_WINDOW {
                                handles.push(env.get(target, &mut dst).unwrap());
                            }
                            env.waitall(handles).unwrap();
                        }
                        super::bandwidth_mb_s(
                            size * windows * NB_WINDOW,
                            t.elapsed().as_nanos() as f64,
                        )
                    }
                };
                rows.lock().unwrap().push((size, value));
            }
            env.barrier(DART_TEAM_ALL).unwrap();
        }
        env.team_memfree(DART_TEAM_ALL, g).unwrap();
    })
    .unwrap();
    rows.into_inner().unwrap()
}

/// Measure the raw-MPI side: the semantically equivalent `mpisim` calls
/// without any DART layer ("overheads with respect to semantically
/// equivalent operations done in pure MPI", §V-A).
pub fn measure_mpi(fig: Figure, pin: PinPolicy, sizes: &[usize]) -> Vec<(usize, f64)> {
    let rows = Mutex::new(Vec::new());
    let mut cfg = WorldConfig::hermit(2, 2);
    cfg.pin = pin;
    World::run(cfg, |mpi| {
        let comm = mpi.comm_world();
        let win = Win::allocate(&comm, 1 << 21).unwrap();
        win.lock_all().unwrap();
        for &size in sizes {
            let src = vec![0x5Au8; size];
            let mut dst = vec![0u8; size];
            let reps = adaptive_reps(size, BASE_REPS);
            comm.barrier().unwrap();
            if comm.rank() == 0 {
                let value = match fig {
                    Figure::DtctBlockingPut => {
                        let mut s = Samples::new();
                        for _ in 0..reps {
                            let t = Instant::now();
                            win.put(&src, 1, 0).unwrap();
                            win.flush(1).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                        }
                        s.median()
                    }
                    Figure::DtctBlockingGet => {
                        let mut s = Samples::new();
                        for _ in 0..reps {
                            let t = Instant::now();
                            win.get(&mut dst, 1, 0).unwrap();
                            win.flush(1).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                        }
                        s.median()
                    }
                    Figure::DtitNonblockingPut => {
                        let mut s = Samples::new();
                        let mut reqs: Vec<RmaRequest> = Vec::with_capacity(reps);
                        for _ in 0..reps {
                            let t = Instant::now();
                            let r = win.rput(&src, 1, 0).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                            reqs.push(r);
                        }
                        RmaRequest::waitall(reqs);
                        s.median()
                    }
                    Figure::DtitNonblockingGet => {
                        let mut s = Samples::new();
                        let mut reqs: Vec<RmaRequest> = Vec::with_capacity(reps);
                        for _ in 0..reps {
                            let t = Instant::now();
                            let r = win.rget(&mut dst, 1, 0).unwrap();
                            s.push(t.elapsed().as_nanos() as f64);
                            reqs.push(r);
                        }
                        RmaRequest::waitall(reqs);
                        s.median()
                    }
                    Figure::BwBlockingPut => {
                        let t = Instant::now();
                        for _ in 0..reps {
                            win.put(&src, 1, 0).unwrap();
                            win.flush(1).unwrap();
                        }
                        super::bandwidth_mb_s(size * reps, t.elapsed().as_nanos() as f64)
                    }
                    Figure::BwBlockingGet => {
                        let t = Instant::now();
                        for _ in 0..reps {
                            win.get(&mut dst, 1, 0).unwrap();
                            win.flush(1).unwrap();
                        }
                        super::bandwidth_mb_s(size * reps, t.elapsed().as_nanos() as f64)
                    }
                    Figure::BwNonblockingPut => {
                        let windows = (reps / NB_WINDOW).max(1);
                        let t = Instant::now();
                        for _ in 0..windows {
                            let mut reqs = Vec::with_capacity(NB_WINDOW);
                            for _ in 0..NB_WINDOW {
                                reqs.push(win.rput(&src, 1, 0).unwrap());
                            }
                            RmaRequest::waitall(reqs);
                        }
                        super::bandwidth_mb_s(
                            size * windows * NB_WINDOW,
                            t.elapsed().as_nanos() as f64,
                        )
                    }
                    Figure::BwNonblockingGet => {
                        let windows = (reps / NB_WINDOW).max(1);
                        let t = Instant::now();
                        for _ in 0..windows {
                            let mut reqs = Vec::with_capacity(NB_WINDOW);
                            for _ in 0..NB_WINDOW {
                                reqs.push(win.rget(&mut dst, 1, 0).unwrap());
                            }
                            RmaRequest::waitall(reqs);
                        }
                        super::bandwidth_mb_s(
                            size * windows * NB_WINDOW,
                            t.elapsed().as_nanos() as f64,
                        )
                    }
                };
                rows.lock().unwrap().push((size, value));
            }
            comm.barrier().unwrap();
        }
        win.unlock_all().unwrap();
    });
    rows.into_inner().unwrap()
}

/// Regenerate one figure: sweep sizes × the three placements, print the
/// series (DART and pure-MPI, like the paper's two curves) and the
/// constant-overhead fit.
pub fn run_figure(fig: Figure) {
    let sizes = if quick_mode() { quick_msg_sizes() } else { paper_msg_sizes() };
    println!("==== {} ====", fig.title());
    println!(
        "(message sizes 1 B … 2 MiB; {} reps ≤4 KiB, adaptive above; medians of per-op times)",
        BASE_REPS
    );
    for (tier, pin) in paper_placements() {
        let dart = measure_dart(fig, pin.clone(), &sizes);
        let mpi = measure_mpi(fig, pin, &sizes);
        let rows: Vec<(usize, f64, f64)> =
            dart.iter().zip(&mpi).map(|(&(s, d), &(_, m))| (s, d, m)).collect();
        print_comparison_table(
            &format!("{} — {}", fig.title(), tier),
            fig.unit(),
            ("DART", "MPI"),
            &rows,
        );
        if !fig.is_bandwidth() {
            let (c, sd) = fit_constant_overhead(&dart, &mpi);
            println!(
                "constant-overhead fit t_DART − t_MPI = c: c = {:.0} ± {:.0} ns  [{tier}]",
                c, sd
            );
        }
    }
}
