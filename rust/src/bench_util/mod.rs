//! Measurement harness for the paper's evaluation (§V).
//!
//! criterion is unavailable in this environment (no network; only the
//! vendored crates resolve), so the figure benches are plain binaries with
//! `harness = false` built on this module: robust statistics
//! ([`Samples`]), the paper's message-size sweep, per-tier placement
//! configurations, and the constant-overhead model fit
//! `t_DART(m) − t_MPI(m) = c` the paper quotes its numbers from.

pub mod figure;

use crate::simnet::{PinPolicy, Tier};

/// A set of timing samples (nanoseconds).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    vals: Vec<f64>,
}

impl Samples {
    /// Empty sample set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn push(&mut self, v: f64) {
        self.vals.push(v);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    /// No samples yet?
    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.vals.is_empty() {
            return f64::NAN;
        }
        self.vals.iter().sum::<f64>() / self.vals.len() as f64
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.vals.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (self.vals.len() - 1) as f64)
            .sqrt()
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        self.vals.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// p-th percentile (0..=100), by sorting.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.vals.is_empty() {
            return f64::NAN;
        }
        let mut s = self.vals.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    /// Median (50th percentile).
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// The paper's message-size sweep: powers of two, 1 B … 2 MiB (§V-C
/// "varied the message size from 1 to 2^21 bytes").
pub fn paper_msg_sizes() -> Vec<usize> {
    (0..=21).map(|p| 1usize << p).collect()
}

/// A shorter sweep for smoke runs (hits both E0 and E1 regimes).
pub fn quick_msg_sizes() -> Vec<usize> {
    [0usize, 6, 10, 12, 13, 17, 21].iter().map(|&p| 1usize << p).collect()
}

/// The three placement configurations of §V-A, as (tier, pin policy) —
/// with `PinPolicy::Block` two units share a NUMA domain; `ScatterNuma`
/// puts them on distinct NUMA domains of one node; `ScatterNode` on
/// distinct nodes.
pub fn paper_placements() -> [(Tier, PinPolicy); 3] {
    [
        (Tier::IntraNuma, PinPolicy::Block),
        (Tier::InterNuma, PinPolicy::ScatterNuma),
        (Tier::InterNode, PinPolicy::ScatterNode),
    ]
}

/// Repetitions that adapt to message size so large-message points don't
/// dominate wall-clock: `base` reps up to 4 KiB, shrinking ×2 per further
/// doubling, floor 8.
pub fn adaptive_reps(size: usize, base: usize) -> usize {
    let mut reps = base;
    let mut s = 4096usize;
    while s < size {
        reps /= 2;
        s *= 2;
    }
    reps.max(8)
}

/// The paper's overhead model: fit `t_DART(m) − t_MPI(m) = c` over the
/// sweep; returns `(c, σ_c)`.
///
/// "We quote numbers from a model that assumes a constant overhead"
/// (§V-C); the paper also estimates measurement error from the standard
/// deviation, "typically less than 10% on data points" — i.e. noise is
/// *relative*, so millisecond-scale points carry microseconds of jitter.
/// We therefore fit by inverse-variance weighting with σ_i ∝ t_MPI(m_i):
/// a weighted mean of the deltas that lets the clean small-message points
/// dominate, exactly as a proper χ² fit of the paper's data would.
pub fn fit_constant_overhead(dart_ns: &[(usize, f64)], mpi_ns: &[(usize, f64)]) -> (f64, f64) {
    assert_eq!(dart_ns.len(), mpi_ns.len());
    let mut wsum = 0f64;
    let mut wdsum = 0f64;
    let weights: Vec<(f64, f64)> = dart_ns
        .iter()
        .zip(mpi_ns)
        .map(|(&(_, d), &(_, m))| {
            let w = 1.0 / (m * m).max(1.0);
            (w, d - m)
        })
        .collect();
    for &(w, d) in &weights {
        wsum += w;
        wdsum += w * d;
    }
    let c = wdsum / wsum;
    // Weighted standard deviation of the deltas around c.
    let var = weights.iter().map(|&(w, d)| w * (d - c) * (d - c)).sum::<f64>() / wsum;
    (c, var.sqrt())
}

/// Bandwidth in MB/s from bytes moved in `ns` nanoseconds.
pub fn bandwidth_mb_s(bytes: usize, ns: f64) -> f64 {
    (bytes as f64 / 1.0e6) / (ns / 1.0e9)
}

/// Human formatting of a nanosecond quantity.
pub fn fmt_ns(ns: f64) -> String {
    if !ns.is_finite() {
        "n/a".into()
    } else if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else {
        format!("{:.2} ms", ns / 1_000_000.0)
    }
}

/// Print one figure-style table: per size, two series and their delta.
/// `labels` names the two series — `("DART", "MPI")` for the paper
/// figures, but ablations compare other pairs (e.g. shmem vs regular
/// windows, vector vs per-block strided transfers).
pub fn print_comparison_table(
    title: &str,
    unit: &str,
    labels: (&str, &str),
    rows: &[(usize, f64, f64)], // (size, series_a, series_b)
) {
    println!("\n### {title}");
    println!(
        "{:>10} {:>16} {:>16} {:>12}",
        "bytes",
        format!("{} ({unit})", labels.0),
        format!("{} ({unit})", labels.1),
        "delta"
    );
    for &(size, a, b) in rows {
        println!("{:>10} {:>16.1} {:>16.1} {:>12.1}", size, a, b, a - b);
    }
}

/// Is this a smoke run? (`DART_BENCH_QUICK=1` trims sweeps so `cargo
/// bench` finishes fast; unset for the full paper sweep.)
pub fn quick_mode() -> bool {
    std::env::var_os("DART_BENCH_QUICK").is_some_and(|v| v != "0")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stats() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0, 4.0, 100.0] {
            s.push(v);
        }
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.mean(), 22.0);
        assert_eq!(s.min(), 1.0);
        assert!(s.stddev() > 40.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn paper_sweep_covers_protocol_switch() {
        let sizes = paper_msg_sizes();
        assert_eq!(sizes.first(), Some(&1));
        assert_eq!(sizes.last(), Some(&(1 << 21)));
        assert!(sizes.contains(&4096) && sizes.contains(&8192));
    }

    #[test]
    fn adaptive_reps_shrink() {
        assert_eq!(adaptive_reps(1, 512), 512);
        assert_eq!(adaptive_reps(4096, 512), 512);
        assert_eq!(adaptive_reps(8192, 512), 256);
        assert_eq!(adaptive_reps(1 << 21, 512), 8);
    }

    #[test]
    fn constant_overhead_fit() {
        let mpi: Vec<(usize, f64)> = (0..10).map(|i| (1 << i, 1000.0 + i as f64)).collect();
        let dart: Vec<(usize, f64)> = mpi.iter().map(|&(s, v)| (s, v + 100.0)).collect();
        let (c, sd) = fit_constant_overhead(&dart, &mpi);
        assert!((c - 100.0).abs() < 1e-9);
        assert!(sd < 1e-9);
    }

    #[test]
    fn bandwidth_math() {
        // 1 MB in 1 ms = 1000 MB/s
        assert!((bandwidth_mb_s(1_000_000, 1_000_000.0) - 1000.0).abs() < 1e-9);
    }
}
