//! Cluster topology and network cost model.
//!
//! The paper's testbed is "Hermit", a Cray XE6 at HLRS: every node has two
//! AMD Opteron 6276 (Interlagos) sockets, each socket two Orochi dies, each
//! die one NUMA domain of 8 cores — i.e. **4 NUMA domains × 8 cores = 32
//! cores per node** (paper Fig. 7) — connected by Cray's Gemini network.
//!
//! We do not have that machine, so this module reproduces the *structure*
//! the evaluation depends on: a hierarchical topology in which every pair of
//! processing units falls into one of three placement tiers
//! ([`Tier::IntraNuma`], [`Tier::InterNuma`], [`Tier::InterNode`]), and a
//! [`cost::CostModel`] that injects tier- and size-dependent transfer costs
//! into the [`crate::mpisim`] transport, including the Cray MPICH eager
//! E0→E1 protocol switch at 4 KiB that produces the characteristic jump in
//! the paper's figures 8/9 and the bandwidth dip in figure 15.

pub mod cost;
pub mod exec;
pub mod faults;
pub mod pinning;

pub use cost::{CostModel, Protocol, TierCost};
pub use exec::RunGate;
pub use faults::{FaultEvent, FaultKind, FaultPlan, FaultStats};
pub use pinning::{pin_current_thread, PinPolicy};

use std::fmt;

/// Hierarchical machine topology: `nodes × numa_per_node × cores_per_numa`.
///
/// Units (ranks) are placed onto core coordinates by a [`PinPolicy`]; the
/// topology then classifies any pair of units into a communication [`Tier`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Number of compute nodes in the cluster.
    pub nodes: usize,
    /// NUMA domains per node (Hermit: 4).
    pub numa_per_node: usize,
    /// Cores per NUMA domain (Hermit: 8).
    pub cores_per_numa: usize,
}

impl Topology {
    /// The paper's Cray XE6 "Hermit" node structure (Fig. 7), with a
    /// configurable node count.
    pub fn hermit(nodes: usize) -> Self {
        Topology { nodes, numa_per_node: 4, cores_per_numa: 8 }
    }

    /// A single shared-memory node with one NUMA domain — the degenerate
    /// topology used by unit tests that do not care about placement.
    pub fn flat(cores: usize) -> Self {
        Topology { nodes: 1, numa_per_node: 1, cores_per_numa: cores }
    }

    /// Total cores in the cluster.
    pub fn total_cores(&self) -> usize {
        self.nodes * self.numa_per_node * self.cores_per_numa
    }

    /// Cores per node.
    pub fn cores_per_node(&self) -> usize {
        self.numa_per_node * self.cores_per_numa
    }

    /// Decompose a flat core index into a coordinate.
    pub fn coord_of(&self, core_index: usize) -> CoreCoord {
        debug_assert!(core_index < self.total_cores(), "core index out of range");
        let per_node = self.cores_per_node();
        let node = core_index / per_node;
        let within = core_index % per_node;
        CoreCoord { node, numa: within / self.cores_per_numa, core: within % self.cores_per_numa }
    }

    /// Flatten a coordinate back to a core index.
    pub fn index_of(&self, c: CoreCoord) -> usize {
        c.node * self.cores_per_node() + c.numa * self.cores_per_numa + c.core
    }

    /// Classify the communication tier between two placed units.
    pub fn tier(&self, a: CoreCoord, b: CoreCoord) -> Tier {
        if a.node != b.node {
            Tier::InterNode
        } else if a.numa != b.numa {
            Tier::InterNuma
        } else {
            Tier::IntraNuma
        }
    }
}

/// Coordinate of one physical core: `(node, numa domain, core within domain)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CoreCoord {
    /// Node index within the cluster.
    pub node: usize,
    /// NUMA-domain index within the node.
    pub numa: usize,
    /// Core index within the NUMA domain.
    pub core: usize,
}

impl fmt::Display for CoreCoord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}:d{}:c{}", self.node, self.numa, self.core)
    }
}

/// Relative placement of two communication partners — the paper's three
/// benchmark configurations (§V-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    /// Both units on the same NUMA domain.
    IntraNuma,
    /// Same node, distinct NUMA domains (distinct processors in the paper).
    InterNuma,
    /// Distinct nodes (over the interconnect).
    InterNode,
}

impl Tier {
    /// All tiers, in the order the paper's figures present them.
    pub const ALL: [Tier; 3] = [Tier::IntraNuma, Tier::InterNuma, Tier::InterNode];

    /// Short label used by the bench harness output.
    pub fn label(&self) -> &'static str {
        match self {
            Tier::IntraNuma => "intra-NUMA",
            Tier::InterNuma => "inter-NUMA",
            Tier::InterNode => "inter-node",
        }
    }
}

impl fmt::Display for Tier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// A full placement: one core coordinate per unit, plus the topology that
/// interprets it. Produced by a [`PinPolicy`].
#[derive(Debug, Clone)]
pub struct Placement {
    /// The topology the coordinates are relative to.
    pub topology: Topology,
    coords: Vec<CoreCoord>,
}

impl Placement {
    /// Place `units` units according to `policy`.
    pub fn new(topology: Topology, units: usize, policy: &PinPolicy) -> Self {
        let coords = policy.place(&topology, units);
        Placement { topology, coords }
    }

    /// Number of placed units.
    pub fn units(&self) -> usize {
        self.coords.len()
    }

    /// Coordinate of `unit`.
    pub fn coord(&self, unit: usize) -> CoreCoord {
        self.coords[unit]
    }

    /// Communication tier between two units.
    pub fn tier(&self, a: usize, b: usize) -> Tier {
        self.topology.tier(self.coords[a], self.coords[b])
    }

    /// Node index of `unit` — the coarsest locality domain (the
    /// locality-aware follow-up papers route communication per node).
    pub fn node_of(&self, unit: usize) -> usize {
        self.coords[unit].node
    }

    /// `(node, numa)` domain of `unit` — the finer locality domain.
    pub fn numa_domain_of(&self, unit: usize) -> (usize, usize) {
        let c = self.coords[unit];
        (c.node, c.numa)
    }

    /// Do two units share a node? (The shmem zero-copy criterion.)
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.coords[a].node == self.coords[b].node
    }

    /// Number of distinct nodes a set of units spans. Single-node sets are
    /// where hierarchical collectives fall back to their flat paths.
    pub fn node_span(&self, units: impl Iterator<Item = usize>) -> usize {
        let mut nodes: Vec<usize> = units.map(|u| self.coords[u].node).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hermit_matches_fig7() {
        let t = Topology::hermit(2);
        assert_eq!(t.cores_per_node(), 32);
        assert_eq!(t.total_cores(), 64);
    }

    #[test]
    fn coord_roundtrip() {
        let t = Topology::hermit(3);
        for i in 0..t.total_cores() {
            assert_eq!(t.index_of(t.coord_of(i)), i);
        }
    }

    #[test]
    fn tier_classification() {
        let t = Topology::hermit(2);
        let a = t.coord_of(0); // node 0, numa 0, core 0
        let b = t.coord_of(1); // node 0, numa 0, core 1
        let c = t.coord_of(8); // node 0, numa 1, core 0
        let d = t.coord_of(32); // node 1
        assert_eq!(t.tier(a, b), Tier::IntraNuma);
        assert_eq!(t.tier(a, c), Tier::InterNuma);
        assert_eq!(t.tier(a, d), Tier::InterNode);
        assert_eq!(t.tier(a, a), Tier::IntraNuma);
    }

    #[test]
    fn placement_locality_queries() {
        let p = Placement::new(Topology::hermit(2), 4, &PinPolicy::ScatterNode);
        // ScatterNode: units 0,2 on node 0; units 1,3 on node 1.
        assert_eq!(p.node_of(0), 0);
        assert_eq!(p.node_of(1), 1);
        assert!(p.same_node(0, 2));
        assert!(!p.same_node(0, 1));
        assert_eq!(p.node_span(0..4), 2);
        assert_eq!(p.node_span([0, 2].into_iter()), 1);
        assert_eq!(p.numa_domain_of(0), (0, 0));
    }

    #[test]
    fn flat_topology_is_single_numa() {
        let t = Topology::flat(16);
        for i in 0..16 {
            for j in 0..16 {
                assert_eq!(t.tier(t.coord_of(i), t.coord_of(j)), Tier::IntraNuma);
            }
        }
    }
}
